#!/usr/bin/env python3
"""Diff two sets of BENCH_*.json tables (bench_results baselines vs a
fresh run) and print per-row deltas for every shared numeric column.

Usage:
    python3 scripts/bench_compare.py <baseline_dir> <current_dir>

Each BENCH_<table>.json is the hand-rolled `{"title", "headers",
"rows"}` shape `swsnn::bench::Table::json` emits. Rows are matched by
their first cell (the engine/config label). Purely informational: the
script always exits 0 — perf gating stays a human decision, this just
turns "is the fused plan still beating the unfused one?" into a
one-glance table on every CI run.

To (re)record a baseline on a reference machine:
    cd rust && cargo bench --bench e2e_serving -- --json
    cp bench_results/BENCH_*.json bench_results/baselines/
"""

import json
import sys
from pathlib import Path


def load_tables(directory: Path):
    tables = {}
    for path in sorted(directory.glob("BENCH_*.json")):
        try:
            tables[path.name] = json.loads(path.read_text())
        except (json.JSONDecodeError, OSError) as exc:
            print(f"  (skipping unreadable {path}: {exc})")
    return tables


def as_float(cell: str):
    try:
        return float(cell)
    except ValueError:
        return None


def compare_table(name: str, base: dict, cur: dict) -> None:
    print(f"\n== {name}: {cur.get('title', '')}")
    headers = cur.get("headers", [])
    if headers != base.get("headers", []):
        print("  (headers changed — raw comparison skipped)")
        return
    base_rows = {row[0]: row for row in base.get("rows", []) if row}
    for row in cur.get("rows", []):
        if not row:
            continue
        key = row[0]
        old = base_rows.get(key)
        if old is None:
            print(f"  {key}: new row (no baseline)")
            continue
        deltas = []
        for header, new_cell, old_cell in zip(headers[1:], row[1:], old[1:]):
            new_v, old_v = as_float(new_cell), as_float(old_cell)
            if new_v is None or old_v is None or old_v == 0:
                continue
            pct = 100.0 * (new_v - old_v) / old_v
            deltas.append(f"{header}: {old_v:g} -> {new_v:g} ({pct:+.1f}%)")
        print(f"  {key}: " + ("; ".join(deltas) if deltas else "no numeric columns matched"))
    for key in base_rows:
        if key not in {row[0] for row in cur.get("rows", []) if row}:
            print(f"  {key}: row disappeared from the current run")


def main() -> int:
    if len(sys.argv) != 3:
        print(__doc__)
        return 0
    baseline_dir, current_dir = Path(sys.argv[1]), Path(sys.argv[2])
    base = load_tables(baseline_dir)
    cur = load_tables(current_dir)
    if not base:
        print(f"no baselines under {baseline_dir} — nothing to compare "
              "(see bench_results/baselines/README.md to record one)")
        return 0
    shared = [name for name in cur if name in base]
    if not shared:
        print("no shared BENCH_*.json tables between the two directories")
        return 0
    for name in shared:
        compare_table(name, base[name], cur[name])
    only_base = [n for n in base if n not in cur]
    if only_base:
        print(f"\nbaseline-only tables (bench not run?): {', '.join(only_base)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
