#!/usr/bin/env python3
"""Diff two sets of BENCH_*.json tables (bench_results baselines vs a
fresh run) and print per-row deltas for every shared numeric column.

Usage:
    python3 scripts/bench_compare.py <baseline_dir> <current_dir>
    python3 scripts/bench_compare.py --record <src_dir> [dest_dir]

Each BENCH_<table>.json is the hand-rolled `{"title", "headers",
"rows"}` shape `swsnn::bench::Table::json` emits. Rows are matched by
their first cell (the engine/config label). Purely informational: the
script always exits 0 — perf gating stays a human decision, this just
turns "is the fused plan still beating the unfused one?" into a
one-glance table on every CI run.

To (re)record baselines on a reference machine:
    cd rust && cargo bench -- --json       # or a single --bench target
    python3 ../scripts/bench_compare.py --record bench_results
which snapshots every BENCH_*.json from <src_dir> into <dest_dir>
(default: rust/bench_results/baselines/, next to this script's repo).
Commit the snapshots to make the CI comparison step meaningful.
"""

import json
import shutil
import sys
from pathlib import Path


def load_tables(directory: Path):
    tables = {}
    for path in sorted(directory.glob("BENCH_*.json")):
        try:
            tables[path.name] = json.loads(path.read_text())
        except (json.JSONDecodeError, OSError) as exc:
            print(f"  (skipping unreadable {path}: {exc})")
    return tables


def as_float(cell: str):
    try:
        return float(cell)
    except ValueError:
        return None


def compare_table(name: str, base: dict, cur: dict) -> None:
    print(f"\n== {name}: {cur.get('title', '')}")
    headers = cur.get("headers", [])
    if headers != base.get("headers", []):
        print("  (headers changed — raw comparison skipped)")
        return
    base_rows = {row[0]: row for row in base.get("rows", []) if row}
    for row in cur.get("rows", []):
        if not row:
            continue
        key = row[0]
        old = base_rows.get(key)
        if old is None:
            print(f"  {key}: new row (no baseline)")
            continue
        deltas = []
        for header, new_cell, old_cell in zip(headers[1:], row[1:], old[1:]):
            new_v, old_v = as_float(new_cell), as_float(old_cell)
            if new_v is None or old_v is None or old_v == 0:
                continue
            pct = 100.0 * (new_v - old_v) / old_v
            deltas.append(f"{header}: {old_v:g} -> {new_v:g} ({pct:+.1f}%)")
        print(f"  {key}: " + ("; ".join(deltas) if deltas else "no numeric columns matched"))
    for key in base_rows:
        if key not in {row[0] for row in cur.get("rows", []) if row}:
            print(f"  {key}: row disappeared from the current run")


def record(src_dir: Path, dest_dir: Path) -> int:
    snapshots = sorted(src_dir.glob("BENCH_*.json"))
    if not snapshots:
        print(f"no BENCH_*.json under {src_dir} — run a bench with --json first")
        return 0
    dest_dir.mkdir(parents=True, exist_ok=True)
    for path in snapshots:
        shutil.copy2(path, dest_dir / path.name)
        print(f"recorded {path.name} -> {dest_dir}")
    return 0


def main() -> int:
    if len(sys.argv) >= 2 and sys.argv[1] == "--record":
        if len(sys.argv) not in (3, 4):
            print(__doc__)
            return 0
        default_dest = Path(__file__).resolve().parent.parent / "rust/bench_results/baselines"
        dest = Path(sys.argv[3]) if len(sys.argv) == 4 else default_dest
        return record(Path(sys.argv[2]), dest)
    if len(sys.argv) != 3:
        print(__doc__)
        return 0
    baseline_dir, current_dir = Path(sys.argv[1]), Path(sys.argv[2])
    base = load_tables(baseline_dir)
    cur = load_tables(current_dir)
    if not base:
        print(f"no baselines under {baseline_dir} — nothing to compare "
              "(see bench_results/baselines/README.md to record one)")
        return 0
    shared = [name for name in cur if name in base]
    if not shared:
        print("no shared BENCH_*.json tables between the two directories")
        return 0
    for name in shared:
        compare_table(name, base[name], cur[name])
    only_base = [n for n in base if n not in cur]
    if only_base:
        print(f"\nbaseline-only tables (bench not run?): {', '.join(only_base)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
