//! Dilated-convolution scenario (paper Fig 2 / Chaudhary et al. [4]):
//! a WaveNet-style dilation stack run layer by layer with both the
//! sliding and im2col backends, reporting per-layer speedups — the
//! workload where the paper reports up to 6.8×.
//!
//! Run: `cargo run --release --example dilated_wavenet`

use swsnn::bench::{bench, fmt_duration, BenchConfig, Table};
use swsnn::conv::{conv1d, Conv1dParams, ConvBackend};
use swsnn::workload::Rng;

fn main() {
    let mut rng = Rng::new(42);
    let n = 16_384;
    let channels = 8;
    let k = 7;
    let dilations = [1usize, 2, 4, 8, 16, 32, 64];
    let cfg = BenchConfig::from_env();

    println!("WaveNet dilation stack: n={n}, c={channels}, k={k}, dilations {dilations:?}\n");
    let mut table = Table::new(
        "Per-layer dilated conv: sliding vs im2col+GEMM",
        &["layer", "dilation", "rf", "im2col", "sliding", "speedup"],
    );

    let mut x = rng.vec_uniform(channels * n, -1.0, 1.0);
    let mut rf = 1usize;
    for (i, &d) in dilations.iter().enumerate() {
        let p = Conv1dParams::new(channels, channels, n, k)
            .with_dilation(d)
            .with_same_pad();
        let w = rng.vec_uniform(p.w_len(), -0.3, 0.3);
        rf += (k - 1) * d;

        let m_gemm = bench(&cfg, || {
            std::hint::black_box(conv1d(
                ConvBackend::Im2colGemm,
                std::hint::black_box(&x),
                &w,
                None,
                &p,
            ));
        });
        let m_slide = bench(&cfg, || {
            std::hint::black_box(conv1d(
                ConvBackend::Sliding,
                std::hint::black_box(&x),
                &w,
                None,
                &p,
            ));
        });
        table.row(vec![
            i.to_string(),
            d.to_string(),
            rf.to_string(),
            fmt_duration(m_gemm.median),
            fmt_duration(m_slide.median),
            format!("{:.2}x", m_gemm.median_ns() / m_slide.median_ns()),
        ]);

        // Actually advance the activations through the layer (sliding).
        x = conv1d(ConvBackend::Sliding, &x, &w, None, &p);
        // tanh-ish clamp to keep activations bounded layer over layer
        for v in &mut x {
            *v = v.tanh();
        }
    }
    println!("{}", table.markdown());
    println!(
        "final receptive field: {rf} samples — the long-context regime where im2col's {k}x memory blow-up hurts most"
    );
}
