//! Time-series / audio classification pipeline: the conv+pool+dense
//! config (`configs/audio_classifier.toml`) served through the native
//! coordinator, demonstrating the config system → model builder →
//! dynamic batcher path on a pool-heavy network (the paper's §2.3
//! operators doing real work).
//!
//! Run: `cargo run --release --example audio_pipeline`

use std::sync::Arc;

use swsnn::config::load_config;
use swsnn::conv::ConvBackend;
use swsnn::coordinator::{Coordinator, NativeEngine};
use swsnn::nn::Model;
use swsnn::workload::Rng;

/// Synthesize a labelled "tone vs noise" waveform: class 0 = band-limited
/// noise, class 1 = noisy sine burst.
fn waveform(rng: &mut Rng, n: usize, class: usize) -> Vec<f32> {
    let mut x = vec![0.0f32; n];
    match class {
        0 => {
            let mut prev = 0.0f32;
            for v in x.iter_mut() {
                prev = 0.7 * prev + 0.5 * rng.normal();
                *v = prev;
            }
        }
        _ => {
            let f = rng.uniform(0.02, 0.1);
            for (t, v) in x.iter_mut().enumerate() {
                *v = (2.0 * std::f32::consts::PI * f * t as f32).sin() + 0.3 * rng.normal();
            }
        }
    }
    x
}

fn main() -> anyhow::Result<()> {
    let cfg_path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs/audio_classifier.toml");
    let text = std::fs::read_to_string(cfg_path)?;
    let (mc, sc) = load_config(&text).map_err(anyhow::Error::msg)?;
    let mut rng = Rng::new(5);
    let model = Model::init(&mc, &mut rng)?;
    println!(
        "model {}: {} layers, {} params, {} MACs/row, out shape {:?}",
        mc.name,
        model.layer_count(),
        model.param_count(),
        model.macs_per_row(),
        model.out_shape()
    );
    let seq_len = mc.seq_len;

    let coord = Arc::new(Coordinator::start_replicated(
        NativeEngine::new(model, ConvBackend::Sliding, sc.max_batch),
        &sc,
    )?);
    println!("coordinator: {} engine workers", coord.worker_count());

    // Drive 200 requests from 4 concurrent clients; the (untrained)
    // network's logits are meaningless but the pipeline — batching,
    // shape flow, pooling stack — is fully exercised, and the two
    // classes must at least produce different logit patterns.
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for c in 0..4u64 {
        let coord = Arc::clone(&coord);
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(100 + c);
            let mut per_class_mean = [0.0f64; 2];
            for i in 0..50 {
                let class = i % 2;
                let x = waveform(&mut rng, seq_len, class);
                let logits = coord.infer(x).expect("inference");
                per_class_mean[class] += logits.iter().map(|v| *v as f64).sum::<f64>() / logits.len() as f64;
            }
            per_class_mean
        }));
    }
    let mut class_means = [0.0f64; 2];
    for h in handles {
        let m = h.join().unwrap();
        class_means[0] += m[0];
        class_means[1] += m[1];
    }
    let dt = t0.elapsed();
    let stats = coord.stats();
    println!(
        "\n200 requests in {:.2}s → {:.1} req/s (mean batch {:.2})",
        dt.as_secs_f64(),
        200.0 / dt.as_secs_f64(),
        stats.mean_batch
    );
    println!(
        "latency: queue-wait p50 {:.0}µs · inference p50 {:.0}µs · e2e p99 {:.0}µs",
        stats.queue_wait_p50_us, stats.inference_p50_us, stats.e2e_p99_us
    );
    println!(
        "class mean logits: noise {:.4}, tone {:.4} (distinct activations ✓)",
        class_means[0] / 100.0,
        class_means[1] / 100.0
    );
    assert_eq!(stats.completed, 200);
    assert!((class_means[0] - class_means[1]).abs() > 1e-6);
    Ok(())
}
