//! Quickstart: the paper in five minutes.
//!
//! 1. Sliding window sums with each §3 algorithm (Eq. 3).
//! 2. Dot product as a prefix sum of γ-pairs (Eq. 5–9).
//! 3. Pooling as sliding sums (§2.3).
//! 4. Convolution: sliding kernels vs im2col+GEMM (§2.5 + Fig 1).
//!
//! Run: `cargo run --release --example quickstart`

use swsnn::bench::{bench, fmt_duration, BenchConfig};
use swsnn::conv::{conv1d, Conv1dParams, ConvBackend};
use swsnn::ops::{dot_reference, dot_via_prefix, AddOp, MaxOp};
use swsnn::pool::{pool1d, Pool1dParams, PoolKind};
use swsnn::sliding::{self, Algo};
use swsnn::workload::Rng;

fn main() {
    let mut rng = Rng::new(2023);

    // ── 1. sliding window sums ────────────────────────────────────────
    println!("1) sliding window sums, w=5, all algorithms agree:");
    let xs = rng.vec_uniform(24, 0.0, 9.0);
    let want = sliding::sliding_naive(AddOp::<f32>::new(), &xs, 5);
    for algo in Algo::ALL {
        let got = sliding::run(algo, AddOp::<f32>::new(), &xs, 5, 16);
        assert_eq!(got.len(), want.len());
        let ok = got.iter().zip(&want).all(|(a, b)| (a - b).abs() < 1e-3);
        println!("   {:<18} {}", algo.name(), if ok { "✓" } else { "✗" });
        assert!(ok);
    }

    // ── 2. dot product as prefix sum (Eq. 5–9) ────────────────────────
    let a = rng.vec_uniform(8, -1.0, 1.0);
    let b = rng.vec_uniform(8, -1.0, 1.0);
    println!(
        "\n2) dot product via the Eq. 8 pair operator: {:.6} (reference {:.6})",
        dot_via_prefix(&a, &b),
        dot_reference(&a, &b)
    );

    // ── 3. pooling as sliding sums ────────────────────────────────────
    let x = rng.vec_uniform(4096, -1.0, 1.0);
    let p = Pool1dParams::new(1, 4096, 8).with_stride(8);
    let mx = pool1d(PoolKind::Max, &x, &p);
    let av = pool1d(PoolKind::Avg, &x, &p);
    println!(
        "\n3) pooling 4096 → {} windows: max[0]={:.3} avg[0]={:.3}",
        mx.len(),
        mx[0],
        av[0]
    );
    // Max pooling really is the sliding sum with ⊕ = max:
    let direct = sliding::auto(MaxOp::<f32>::new(), &x[..8], 8, 64)[0];
    assert_eq!(mx[0], direct);

    // ── 4. convolution: sliding vs im2col+GEMM ────────────────────────
    println!("\n4) conv1d N=100k, k=31 — the Fig 1 comparison:");
    let n = 100_000;
    let x = rng.vec_uniform(n, -1.0, 1.0);
    let w = rng.vec_uniform(31, -1.0, 1.0);
    let p = Conv1dParams::new(1, 1, n, 31);
    let cfg = BenchConfig::quick();
    let m_gemm = bench(&cfg, || {
        std::hint::black_box(conv1d(ConvBackend::Im2colGemm, std::hint::black_box(&x), &w, None, &p));
    });
    let m_slide = bench(&cfg, || {
        std::hint::black_box(conv1d(ConvBackend::Sliding, std::hint::black_box(&x), &w, None, &p));
    });
    println!(
        "   im2col+gemm {}   sliding {}   speedup {:.2}x",
        fmt_duration(m_gemm.median),
        fmt_duration(m_slide.median),
        m_gemm.median_ns() / m_slide.median_ns()
    );
    println!("\nquickstart done — see `swsnn bench-fig1` / `cargo bench` for the full figures.");
}
