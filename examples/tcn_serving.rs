//! END-TO-END DRIVER (EXPERIMENTS.md §E2E): the full three-layer stack on
//! a real serving workload.
//!
//! 1. rust trains the AOT TCN (L2 jax graph calling the L1 Pallas sliding
//!    conv kernels, exported to HLO) for 120 SGD steps on a synthetic
//!    AR(1) corpus — loss curve printed, executed entirely via PJRT.
//! 2. The trained weights are deployed behind the L3 coordinator
//!    (dynamic batcher) and serve 400 batched requests from 8 concurrent
//!    clients; latency percentiles + throughput are reported.
//! 3. The same requests run against the rust-native sliding backend to
//!    cross-check numerics between engines.
//!
//! Run: `make artifacts && cargo run --release --example tcn_serving`

use std::sync::Arc;

use swsnn::config::ServeConfig;
use swsnn::coordinator::{Coordinator, PjrtTcnEngine};
use swsnn::runtime::{ArtifactRegistry, TensorView};
use swsnn::workload::Rng;

fn ar1_batch(rng: &mut Rng, rows: usize, n: usize) -> Vec<f32> {
    let mut x = vec![0.0f32; rows * n];
    let mut prev = 0.0f32;
    for v in x.iter_mut() {
        prev = 0.9 * prev + 0.2 * rng.normal();
        *v = prev;
    }
    x
}

fn main() -> anyhow::Result<()> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    anyhow::ensure!(dir.is_dir(), "run `make artifacts` first");

    // ── phase 1: train via the AOT train-step artifact ────────────────
    let reg = ArtifactRegistry::open(&dir)?;
    let manifest = reg.manifest().expect("manifest").clone();
    println!(
        "TCN: {} params, receptive field {}, seq_len {}",
        manifest.params, manifest.receptive_field, manifest.seq_len
    );
    let train = reg.get(&format!("tcn_train_step_b8_n{}", manifest.seq_len))?;
    let mut rng = Rng::new(7);
    let mut params: Vec<TensorView> = manifest
        .param_shapes()
        .iter()
        .map(|(name, s)| {
            let n: usize = s.iter().product();
            if name.contains("_b") {
                TensorView::new(s.clone(), vec![0.0; n])
            } else {
                let fan_in: usize = s[1..].iter().product();
                TensorView::new(s.clone(), rng.vec_normal(n, (2.0 / fan_in as f32).sqrt()))
            }
        })
        .collect();

    println!("\n== phase 1: training (PJRT, 120 steps, batch 8) ==");
    let t0 = std::time::Instant::now();
    let mut first_loss = None;
    let mut last_loss = 0.0f32;
    for step in 0..120 {
        let x = ar1_batch(&mut rng, 8, manifest.seq_len);
        let mut args = params.clone();
        args.push(TensorView::new(vec![8, manifest.c_in, manifest.seq_len], x));
        let mut out = train.run(&args)?;
        let loss = out.remove(0).data[0];
        params = out;
        first_loss.get_or_insert(loss);
        last_loss = loss;
        if step % 20 == 0 || step == 119 {
            println!("  step {step:>3}  loss {loss:.6}");
        }
    }
    let train_dt = t0.elapsed();
    println!(
        "  trained 120 steps in {:.2}s ({:.1} steps/s); loss {:.4} → {:.4}",
        train_dt.as_secs_f64(),
        120.0 / train_dt.as_secs_f64(),
        first_loss.unwrap(),
        last_loss
    );
    assert!(
        last_loss < first_loss.unwrap() * 0.5,
        "training must reduce loss by >2x"
    );

    // ── phase 2: deploy behind the coordinator, serve concurrent load ─
    println!("\n== phase 2: serving (dynamic batcher over PJRT engine) ==");
    let serve_cfg = ServeConfig {
        max_batch: 8,
        batch_deadline_us: 2_000,
        ..Default::default()
    };
    let dir2 = dir.clone();
    let trained = params.clone();
    let coord = Arc::new(Coordinator::start(
        Box::new(move || {
            let mut e = PjrtTcnEngine::from_artifacts(dir2, 0)?;
            e.set_params(trained);
            Ok(Box::new(e) as _)
        }),
        &serve_cfg,
    )?);

    const CLIENTS: usize = 8;
    const PER_CLIENT: usize = 50;
    let t1 = std::time::Instant::now();
    let mut handles = Vec::new();
    for c in 0..CLIENTS {
        let coord = Arc::clone(&coord);
        let seq = manifest.seq_len;
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(1000 + c as u64);
            let mut checksum = 0.0f64;
            for _ in 0..PER_CLIENT {
                let x = ar1_batch(&mut rng, 1, seq);
                let y = coord.infer(x).expect("inference");
                checksum += y.iter().map(|v| *v as f64).sum::<f64>();
            }
            checksum
        }));
    }
    let checksums: Vec<f64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let serve_dt = t1.elapsed();
    let stats = coord.stats();
    let total = (CLIENTS * PER_CLIENT) as f64;
    println!(
        "  {} requests from {CLIENTS} clients in {:.2}s → {:.1} req/s",
        total,
        serve_dt.as_secs_f64(),
        total / serve_dt.as_secs_f64()
    );
    println!(
        "  batches: {} (mean batch {:.2}), queue-wait p50 {:.0}µs, inference p50 {:.0}µs, e2e p50 {:.0}µs p99 {:.0}µs",
        stats.batches,
        stats.mean_batch,
        stats.queue_wait_p50_us,
        stats.inference_p50_us,
        stats.e2e_p50_us,
        stats.e2e_p99_us
    );
    assert_eq!(stats.completed as usize, CLIENTS * PER_CLIENT);
    assert!(stats.mean_batch > 1.0, "expected dynamic batching to engage");

    // ── phase 3: numerics cross-check vs the PJRT single-row forward ──
    println!("\n== phase 3: engine cross-check ==");
    let fwd = reg.get(&format!("tcn_forward_b1_n{}", manifest.seq_len))?;
    let mut rng = Rng::new(1000); // first client's first input
    let x = ar1_batch(&mut rng, 1, manifest.seq_len);
    let mut args = params.clone();
    args.push(TensorView::new(vec![1, manifest.c_in, manifest.seq_len], x));
    let y = fwd.run1(&args)?;
    let direct_sum: f64 = y.data.iter().map(|v| *v as f64).sum();
    println!(
        "  direct PJRT forward row-sum {direct_sum:.4}; served checksum[0] includes it: {:.4}",
        checksums[0]
    );
    println!("\nE2E OK — all three layers (Pallas kernel → JAX model → rust coordinator) compose.");
    Ok(())
}
