//! The algorithms' origin story (paper §2.2, ref [11]): minimizer seeds
//! for genomic sequences via sliding-window minimum — "since min is an
//! associative operator, the sliding window minimum can be computed
//! using the faster version of the vector input algorithm."
//!
//! Pipeline: random DNA → 2-bit rolling k-mer hash → sliding minimum
//! (the paper's log-depth algorithm) → minimizer density check against
//! the theoretical 2/(w+1) expectation.
//!
//! Run: `cargo run --release --example genomics_minimizers`

use swsnn::bench::{bench, fmt_duration, BenchConfig, Table};
use swsnn::ops::MinOp;
use swsnn::pool::minimizer_positions;
use swsnn::sliding::{self, Algo};
use swsnn::workload::{dna_sequence, kmer_hashes, Rng};

fn main() {
    let mut rng = Rng::new(0xD9A);
    let n = 2_000_000;
    let kmer = 15;
    let seq = dna_sequence(&mut rng, n);
    let hashes = kmer_hashes(&seq, kmer);
    println!("DNA {n} bp → {} {kmer}-mer hashes\n", hashes.len());

    let cfg = BenchConfig::from_env();
    let op = MinOp::<u64>::new();
    let mut table = Table::new(
        "Sliding-window minimum over k-mer hashes",
        &["w", "naive", "vector_slide", "vector_slide_tree", "tree speedup", "density (exp 2/(w+1))"],
    );
    for w in [5usize, 10, 19, 31] {
        let m_naive = bench(&cfg, || {
            std::hint::black_box(sliding::run(
                Algo::Naive,
                op,
                std::hint::black_box(&hashes),
                w,
                64,
            ));
        });
        let m_lin = bench(&cfg, || {
            std::hint::black_box(sliding::run(
                Algo::VectorSlide,
                op,
                std::hint::black_box(&hashes),
                w,
                64,
            ));
        });
        let m_tree = bench(&cfg, || {
            std::hint::black_box(sliding::run(
                Algo::VectorSlideTree,
                op,
                std::hint::black_box(&hashes),
                w,
                64,
            ));
        });

        // Correctness: sliding minimum values match the deque minimizers.
        let mins = sliding::run(Algo::VectorSlideTree, op, &hashes, w, 64);
        let pos = minimizer_positions(&hashes, w);
        assert_eq!(mins.len(), pos.len());
        for (m, p) in mins.iter().zip(&pos) {
            assert_eq!(*m, hashes[*p]);
        }
        let distinct: std::collections::HashSet<usize> = pos.into_iter().collect();
        let density = distinct.len() as f64 / hashes.len() as f64;

        table.row(vec![
            w.to_string(),
            fmt_duration(m_naive.median),
            fmt_duration(m_lin.median),
            fmt_duration(m_tree.median),
            format!("{:.2}x", m_naive.median_ns() / m_tree.median_ns()),
            format!("{:.4} ({:.4})", density, 2.0 / (w as f64 + 1.0)),
        ]);
    }
    println!("{}", table.markdown());
    println!("density tracks the theoretical 2/(w+1) minimizer rate — the seeds are correct.");
}
