"""L1 Pallas kernels: sliding-window pooling (paper §2.3).

Average pooling = sliding sum with ``+``; max pooling = sliding sum with
``max``. Both kernels use the *associative doubling ladder* (the paper's
``O(log w)`` variant): window sums of size ``2^t`` are built by combining
two slid size-``2^(t-1)`` windows, and a non-power-of-two ``w`` finishes
with one extra combine — overlapping for idempotent ``max``, binary
decomposition for ``+``. ``ceil(log2 w)+1`` vector ops per tile instead
of ``w``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ladder(x, w: int, combine, n_out: int):
    """Log-depth sliding windows of size ``w`` over the last axis.

    ``x``: [..., n]; returns [..., n_out] where lane t = window [t, t+w).
    ``combine(a, b)`` must be associative; overlap-safe iff idempotent.
    """
    idempotent = combine is jnp.maximum or combine is jnp.minimum
    # Doubling ladder: win_t[lane j] = fold of x[j .. j+2^t).
    win = x
    size = 1
    while size * 2 <= w:
        win = combine(win[..., : win.shape[-1] - size], win[..., size:])
        size *= 2
    if size == w:
        return win[..., :n_out]
    rem = w - size
    if idempotent:
        # Overlapping union covers [t, t+w) exactly.
        return combine(win[..., :n_out], win[..., rem : rem + n_out])
    # Non-idempotent: recurse on the remainder chunk (binary decomposition).
    rest = _ladder(x[..., size:], rem, combine, n_out)
    return combine(win[..., :n_out], rest)


def _pool_kernel(x_ref, o_ref, *, w: int, mode: str):
    x = x_ref[0]  # [c, n]
    n_out = o_ref.shape[-1]
    if mode == "max":
        o_ref[0] = _ladder(x, w, jnp.maximum, n_out)
    elif mode == "min":
        o_ref[0] = _ladder(x, w, jnp.minimum, n_out)
    else:  # avg
        s = _ladder(x, w, jnp.add, n_out)
        o_ref[0] = s * (1.0 / w)


@functools.partial(jax.jit, static_argnames=("w", "stride", "mode"))
def pool1d_sliding(x, *, w: int, stride: int = 1, mode: str = "max"):
    """Sliding pooling over ``[batch, c, n]`` (valid mode).

    Dense windows from the log-ladder kernel, then stride decimation.
    """
    assert mode in ("max", "min", "avg"), mode
    batch, c, n = x.shape
    n_dense = n - w + 1
    assert n_dense >= 1, "input shorter than window"
    out = pl.pallas_call(
        functools.partial(_pool_kernel, w=w, mode=mode),
        out_shape=jax.ShapeDtypeStruct((batch, c, n_dense), x.dtype),
        grid=(batch,),
        in_specs=[pl.BlockSpec((1, c, n), lambda b: (b, 0, 0))],
        out_specs=pl.BlockSpec((1, c, n_dense), lambda b: (b, 0, 0)),
        interpret=True,
    )(x)
    if stride > 1:
        out = out[:, :, ::stride]
    return out


def _sliding_sum_kernel(x_ref, o_ref, *, w: int):
    n_out = o_ref.shape[-1]
    o_ref[...] = _ladder(x_ref[...], w, jnp.add, n_out)


@functools.partial(jax.jit, static_argnames=("w",))
def sliding_sum(x, *, w: int):
    """Dense sliding-window sum of a 1-D vector (the bare Eq. 3 kernel)."""
    (n,) = x.shape
    n_out = n - w + 1
    assert n_out >= 1
    return pl.pallas_call(
        functools.partial(_sliding_sum_kernel, w=w),
        out_shape=jax.ShapeDtypeStruct((n_out,), x.dtype),
        interpret=True,
    )(x)
