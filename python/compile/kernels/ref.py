"""Pure-jnp reference oracles for the L1 Pallas kernels.

Every Pallas kernel in this package has an entry here computed with plain
``jax.numpy``/``lax`` ops; pytest asserts allclose between kernel and
oracle across shape/dtype sweeps (hypothesis). These are also the L2
fallback path when a kernel variant is not AOT-compiled.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def conv1d_ref(x, w, bias=None, *, stride: int = 1, dilation: int = 1, pad: int = 0):
    """Reference 1-D convolution (cross-correlation).

    Args:
      x: ``[batch, c_in, n]`` input.
      w: ``[c_out, c_in, k]`` filters.
      bias: optional ``[c_out]``.
      stride/dilation/pad: the usual hyper-parameters (symmetric padding).

    Returns:
      ``[batch, c_out, n_out]``.
    """
    y = lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride,),
        padding=[(pad, pad)],
        rhs_dilation=(dilation,),
        dimension_numbers=("NCH", "OIH", "NCH"),
    )
    if bias is not None:
        y = y + bias[None, :, None]
    return y


def avg_pool1d_ref(x, w: int, *, stride: int = 1):
    """Reference average pooling over ``[batch, c, n]`` (valid mode)."""
    y = lax.reduce_window(
        x,
        0.0,
        lax.add,
        window_dimensions=(1, 1, w),
        window_strides=(1, 1, stride),
        padding="VALID",
    )
    return y / w


def max_pool1d_ref(x, w: int, *, stride: int = 1):
    """Reference max pooling over ``[batch, c, n]`` (valid mode)."""
    return lax.reduce_window(
        x,
        -jnp.inf,
        lax.max,
        window_dimensions=(1, 1, w),
        window_strides=(1, 1, stride),
        padding="VALID",
    )


def sliding_sum_ref(x, w: int):
    """Dense sliding-window sum of a 1-D vector (valid mode)."""
    c = jnp.concatenate([jnp.zeros(1, x.dtype), jnp.cumsum(x)])
    return c[w:] - c[:-w]


def sliding_min_ref(x, w: int):
    """Dense sliding-window minimum of a 1-D vector (valid mode)."""
    return lax.reduce_window(
        x,
        jnp.inf,
        lax.min,
        window_dimensions=(w,),
        window_strides=(1,),
        padding="VALID",
    )


def dot_via_pair_scan_ref(a, b):
    """Paper Eq. 5-9: dot product as a prefix scan of (u, v) pairs.

    Used by tests to validate the pair-operator algebra against jnp.dot —
    the same associativity argument the rust ``ops::ConvPair`` relies on.
    """
    a = jnp.asarray(a)
    b = jnp.asarray(b)
    alpha = jnp.where(a == 0, jnp.ones_like(a), a)
    beta = jnp.where(a == 0, jnp.zeros_like(b), b)
    # u_0 = 1, u_i = alpha_{i-1}/alpha_i, closing u_M = alpha_{M-1}.
    u = jnp.concatenate([jnp.ones(1, a.dtype), alpha[:-1] / alpha[1:], alpha[-1:]])
    v = jnp.concatenate([beta, jnp.zeros(1, a.dtype)])

    def op(c1, c2):
        u1, v1 = c1
        u2, v2 = c2
        return u1 * u2, u2 * v1 + v2

    (_, vs) = lax.associative_scan(op, (u, v))
    return vs[-1]
