"""L1 Pallas kernel: sliding-window 1-D convolution.

TPU adaptation of the paper's CPU-vector algorithm (DESIGN.md
§Hardware-Adaptation): instead of materializing the im2col matrix in HBM
(k x memory traffic), each grid step keeps one input row tile resident in
VMEM and accumulates one MXU matmul **per filter tap** over a shifted
view of the *unmodified* input:

    acc += W[:, :, tap] @ X[:, tap*dilation : tap*dilation + n_out]

which is exactly Algorithm 4's ``X (+)= Slide(Y, Y1, P-k)`` with the
slide realized as a VMEM offset and the FMA generalized to the MXU
``(c_out, c_in) x (c_in, n_block)`` contraction. VMEM footprint is
``c_in*(n_block + (k-1)*dilation) + c_out*n_block`` floats versus
im2col's ``c_in*k*n_block`` — the k-fold blow-up the paper removes.

All kernels are lowered with ``interpret=True``: the CPU PJRT plugin
cannot execute Mosaic custom-calls, and correctness is what the AOT
artifacts carry (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv1d_kernel(x_ref, w_ref, b_ref, o_ref, *, k: int, dilation: int):
    """One batch element: taps accumulate MXU matmuls over slid views."""
    n_out = o_ref.shape[-1]
    x = x_ref[0]          # [c_in, n_pad]   (VMEM-resident tile)
    w = w_ref[...]        # [c_out, c_in, k]
    acc = jnp.zeros(o_ref.shape[1:], dtype=jnp.float32)  # [c_out, n_out]
    for tap in range(k):  # static unroll: k MXU contractions
        off = tap * dilation
        xs = jax.lax.dynamic_slice_in_dim(x, off, n_out, axis=1)
        acc = acc + jnp.dot(w[:, :, tap], xs, preferred_element_type=jnp.float32)
    acc = acc + b_ref[...][:, None]
    o_ref[0] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("stride", "dilation", "pad"))
def conv1d_sliding(x, w, bias, *, stride: int = 1, dilation: int = 1, pad: int = 0):
    """Sliding-window conv via the Pallas kernel (differentiable).

    Args:
      x: ``[batch, c_in, n]``; w: ``[c_out, c_in, k]``; bias: ``[c_out]``.

    Stride is applied by decimating the dense (stride-1) output — the
    dense windows are what the sliding formulation produces naturally,
    and decimation inside the same jit keeps everything fused. The VJP
    is registered below: both cotangent computations are convolutions
    themselves (transposed / correlation forms), so training lowers to
    more of the same sliding structure.
    """
    return _conv1d_vjp(x, w, bias, stride, dilation, pad)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _conv1d_vjp(x, w, bias, stride, dilation, pad):
    return _conv1d_pallas(x, w, bias, stride, dilation, pad)


def _conv1d_pallas(x, w, bias, stride: int, dilation: int, pad: int):
    batch, c_in, n = x.shape
    c_out, c_in_w, k = w.shape
    assert c_in == c_in_w, (c_in, c_in_w)
    if pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (pad, pad)))
        n = n + 2 * pad
    eff_k = (k - 1) * dilation + 1
    n_dense = n - eff_k + 1
    assert n_dense >= 1, "input shorter than the receptive field"

    out = pl.pallas_call(
        functools.partial(_conv1d_kernel, k=k, dilation=dilation),
        out_shape=jax.ShapeDtypeStruct((batch, c_out, n_dense), x.dtype),
        grid=(batch,),
        in_specs=[
            pl.BlockSpec((1, c_in, n), lambda b: (b, 0, 0)),
            pl.BlockSpec((c_out, c_in, k), lambda b: (0, 0, 0)),
            pl.BlockSpec((c_out,), lambda b: (0,)),
        ],
        out_specs=pl.BlockSpec((1, c_out, n_dense), lambda b: (b, 0, 0)),
        interpret=True,
    )(x, w, bias)
    if stride > 1:
        out = out[:, :, ::stride]
    return out


def _conv1d_fwd(x, w, bias, stride, dilation, pad):
    y = _conv1d_pallas(x, w, bias, stride, dilation, pad)
    return y, (x, w)


def _conv1d_bwd(stride, dilation, pad, res, dy):
    """Cotangents — both are convolutions (stride-1 training path only).

    * ``dx = dy ⊛ flip(w)ᵀ`` with padding ``(k−1)·d − p`` (transposed
      conv): another sliding-window convolution.
    * ``dw[o,i,tap] = Σ_{b,t} dy[b,o,t] · x_pad[b,i,t + tap·d]``: one
      MXU-shaped contraction per tap over the unmodified (padded) input —
      the same slid-view schedule as the forward kernel.
    """
    assert stride == 1, "training path exports stride-1 convs only"
    x, w = res
    k = w.shape[-1]
    # dx: transposed conv, channels swapped, taps flipped.
    w_t = jnp.flip(w, axis=-1).transpose(1, 0, 2)  # [c_in, c_out, k]
    dx = _conv1d_pallas(
        dy,
        w_t,
        jnp.zeros((w.shape[1],), dy.dtype),
        1,
        dilation,
        (k - 1) * dilation - pad,
    )
    # dw: per-tap contraction over slid views of the padded input.
    x_pad = jnp.pad(x, ((0, 0), (0, 0), (pad, pad))) if pad else x
    n_out = dy.shape[-1]
    taps = []
    for tap in range(k):
        xs = jax.lax.dynamic_slice_in_dim(x_pad, tap * dilation, n_out, axis=2)
        taps.append(jnp.einsum("bot,bit->oi", dy, xs))
    dw = jnp.stack(taps, axis=-1)
    dbias = jnp.sum(dy, axis=(0, 2))
    return dx, dw, dbias


_conv1d_vjp.defvjp(_conv1d_fwd, _conv1d_bwd)


def vmem_footprint_bytes(c_in: int, c_out: int, k: int, n_block: int, dilation: int = 1) -> int:
    """Estimated VMEM bytes for one grid step (DESIGN.md perf model)."""
    halo = (k - 1) * dilation
    x_tile = c_in * (n_block + halo)
    w_tile = c_out * c_in * k
    acc = c_out * n_block
    return 4 * (x_tile + w_tile + acc)


def mxu_utilization_estimate(c_in: int, c_out: int, n_block: int) -> float:
    """Fraction of each 128x128 MXU pass doing useful work (perf model)."""

    def eff(dim: int, tile: int = 128) -> float:
        full = dim // tile
        rem = dim % tile
        used = full * tile + rem
        passes = full + (1 if rem else 0)
        return used / (passes * tile) if passes else 0.0

    return eff(c_out) * eff(c_in) * eff(n_block)
