"""L2: the JAX model — a dilated TCN built on the L1 sliding kernels.

This is the "model graph" layer of the three-layer stack: pure-jax
forward/backward that *calls the Pallas kernels* so everything lowers
into a single HLO module per artifact. Python never runs at serving
time — ``aot.py`` exports these functions as HLO text and the rust
runtime executes them.

Architecture (WaveNet/TCN shape — the 1-D dilated-conv workload the
paper's Fig 2 targets):

    stem:   conv k=7, c_in -> hidden
    blocks: residual { conv(k, d) -> relu -> conv(k, d) -> relu } x D,
            dilations d = 1, 2, 4, ..., receptive field grows 2^D
    head:   1x1 conv hidden -> c_out

Task for the e2e example: next-step prediction on synthetic AR series
(MSE loss), trained with plain SGD inside the exported train step.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .kernels.sliding_conv import conv1d_sliding


class TcnConfig(NamedTuple):
    """Static hyper-parameters (baked into each AOT artifact)."""

    c_in: int = 1
    hidden: int = 32
    c_out: int = 1
    kernel: int = 3
    stem_kernel: int = 7
    n_blocks: int = 4
    seq_len: int = 512

    @property
    def dilations(self):
        return tuple(2**i for i in range(self.n_blocks))

    @property
    def receptive_field(self) -> int:
        rf = self.stem_kernel
        for d in self.dilations:
            rf += 2 * (self.kernel - 1) * d
        return rf


def param_shapes(cfg: TcnConfig):
    """Ordered (name, shape) list — the flat parameter layout shared with
    the rust coordinator (which owns parameter state between steps)."""
    shapes = [
        ("stem_w", (cfg.hidden, cfg.c_in, cfg.stem_kernel)),
        ("stem_b", (cfg.hidden,)),
    ]
    for i in range(cfg.n_blocks):
        shapes += [
            (f"block{i}_w1", (cfg.hidden, cfg.hidden, cfg.kernel)),
            (f"block{i}_b1", (cfg.hidden,)),
            (f"block{i}_w2", (cfg.hidden, cfg.hidden, cfg.kernel)),
            (f"block{i}_b2", (cfg.hidden,)),
        ]
    shapes += [
        ("head_w", (cfg.c_out, cfg.hidden, 1)),
        ("head_b", (cfg.c_out,)),
    ]
    return shapes


def init_params(cfg: TcnConfig, seed: int = 0):
    """He-init parameters as a flat list of arrays (stable order)."""
    key = jax.random.PRNGKey(seed)
    params = []
    for _, shape in param_shapes(cfg):
        key, sub = jax.random.split(key)
        if len(shape) == 3:
            fan_in = shape[1] * shape[2]
            params.append(
                jax.random.normal(sub, shape, jnp.float32) * jnp.sqrt(2.0 / fan_in)
            )
        else:
            params.append(jnp.zeros(shape, jnp.float32))
    return params


def param_count(cfg: TcnConfig) -> int:
    total = 0
    for _, shape in param_shapes(cfg):
        n = 1
        for s in shape:
            n *= s
        total += n
    return total


def _same_pad(k: int, dilation: int) -> int:
    return (k - 1) * dilation // 2


def tcn_forward(params, x, cfg: TcnConfig):
    """Forward pass: ``[batch, c_in, n] -> [batch, c_out, n]``.

    Every conv is the L1 Pallas sliding kernel with same-padding so the
    sequence length is preserved end to end.
    """
    it = iter(params)

    def take():
        return next(it)

    h = conv1d_sliding(x, take(), take(), pad=_same_pad(cfg.stem_kernel, 1))
    h = jax.nn.relu(h)
    for d in cfg.dilations:
        pad = _same_pad(cfg.kernel, d)
        r = conv1d_sliding(h, take(), take(), dilation=d, pad=pad)
        r = jax.nn.relu(r)
        r = conv1d_sliding(r, take(), take(), dilation=d, pad=pad)
        r = jax.nn.relu(r)
        h = h + r  # residual
    y = conv1d_sliding(h, take(), take())
    return y


def mse_next_step_loss(params, x, cfg: TcnConfig):
    """Next-step prediction: predict x[t+1] from the causal-ish window.

    The model sees x[:, :, :-1] and regresses x[:, :, 1:].
    """
    pred = tcn_forward(params, x[:, :, :-1], cfg)
    target = x[:, : cfg.c_out, 1:]
    return jnp.mean((pred - target) ** 2)


@functools.partial(jax.jit, static_argnames=("cfg", "lr"))
def train_step(params, x, cfg: TcnConfig, lr: float = 1e-3):
    """One SGD step; returns (loss, new_params). Exported as one HLO."""
    loss, grads = jax.value_and_grad(mse_next_step_loss)(params, x, cfg)
    new_params = [p - lr * g for p, g in zip(params, grads)]
    return loss, new_params


@functools.partial(jax.jit, static_argnames=("cfg",))
def forward_jit(params, x, cfg: TcnConfig):
    return tcn_forward(params, x, cfg)
