"""L2 correctness: TCN model shapes, gradients, training dynamics, and
the AOT export path (HLO text round-trip invariants)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.aot import spec, to_hlo_text

CFG_SMALL = model.TcnConfig(seq_len=64, n_blocks=2, hidden=8)


def data(batch, cfg, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), (batch, cfg.c_in, cfg.seq_len))


class TestTcnModel:
    def test_param_shapes_consistent(self):
        shapes = model.param_shapes(CFG_SMALL)
        params = model.init_params(CFG_SMALL)
        assert len(shapes) == len(params)
        for (_, s), p in zip(shapes, params):
            assert tuple(p.shape) == s
        assert model.param_count(CFG_SMALL) == sum(int(np.prod(s)) for _, s in shapes)

    def test_forward_preserves_length(self):
        params = model.init_params(CFG_SMALL)
        x = data(3, CFG_SMALL)
        y = model.forward_jit(params, x, CFG_SMALL)
        assert y.shape == (3, CFG_SMALL.c_out, CFG_SMALL.seq_len)
        assert bool(jnp.all(jnp.isfinite(y)))

    def test_forward_batch_consistency(self):
        """Row i of a batched forward equals the single-row forward."""
        params = model.init_params(CFG_SMALL)
        x = data(4, CFG_SMALL)
        y_full = model.forward_jit(params, x, CFG_SMALL)
        y_one = model.forward_jit(params, x[1:2], CFG_SMALL)
        np.testing.assert_allclose(
            np.asarray(y_full[1:2]), np.asarray(y_one), atol=1e-5, rtol=1e-5
        )

    def test_receptive_field_formula(self):
        cfg = model.TcnConfig(kernel=3, stem_kernel=7, n_blocks=4)
        # stem 7, blocks add 2*(3-1)*d for d in 1,2,4,8 → 7 + 4*(1+2+4+8) = 67
        assert cfg.receptive_field == 67

    def test_gradients_flow_to_all_params(self):
        params = model.init_params(CFG_SMALL)
        x = data(2, CFG_SMALL)
        grads = jax.grad(model.mse_next_step_loss)(params, x, CFG_SMALL)
        assert len(grads) == len(params)
        for g, (name, _) in zip(grads, model.param_shapes(CFG_SMALL)):
            assert bool(jnp.all(jnp.isfinite(g))), name
            # head/stem weights must receive signal
            if name.endswith("_w") or "w1" in name or "w2" in name:
                assert float(jnp.max(jnp.abs(g))) > 0, name

    def test_training_reduces_loss(self):
        params = model.init_params(CFG_SMALL)
        x = data(8, CFG_SMALL, seed=3)
        losses = []
        for _ in range(10):
            loss, params = model.train_step(params, x, CFG_SMALL)
            losses.append(float(loss))
        assert losses[-1] < losses[0] * 0.7, losses

    def test_train_step_is_pure(self):
        params = model.init_params(CFG_SMALL)
        x = data(2, CFG_SMALL)
        l1, _ = model.train_step(params, x, CFG_SMALL)
        l2, _ = model.train_step(params, x, CFG_SMALL)
        assert float(l1) == float(l2)


class TestAotExport:
    def test_hlo_text_is_parseable_shape(self):
        cfg = CFG_SMALL
        pshapes = [spec(s) for _, s in model.param_shapes(cfg)]
        lowered = jax.jit(
            lambda p, x: (model.tcn_forward(p, x, cfg),)
        ).lower(pshapes, spec((1, cfg.c_in, cfg.seq_len)))
        text = to_hlo_text(lowered)
        assert text.startswith("HloModule"), text[:60]
        assert "ROOT" in text
        # Tuple return contract the rust loader relies on.
        assert "tuple" in text.lower()

    def test_export_contains_no_custom_calls(self):
        """interpret=True must lower to plain HLO (no Mosaic custom-call),
        otherwise the CPU PJRT client cannot execute the artifact."""
        cfg = CFG_SMALL
        pshapes = [spec(s) for _, s in model.param_shapes(cfg)]
        lowered = jax.jit(
            lambda p, x: (model.tcn_forward(p, x, cfg),)
        ).lower(pshapes, spec((1, cfg.c_in, cfg.seq_len)))
        text = to_hlo_text(lowered)
        assert "custom-call" not in text, "Mosaic custom-call leaked into AOT artifact"

    def test_train_step_exports(self):
        cfg = CFG_SMALL
        pshapes = [spec(s) for _, s in model.param_shapes(cfg)]
        lowered = jax.jit(
            lambda p, x: model.train_step(p, x, cfg)
        ).lower(pshapes, spec((4, cfg.c_in, cfg.seq_len)))
        text = to_hlo_text(lowered)
        assert text.startswith("HloModule")


class TestNumericsVsRust:
    """Golden vectors shared with rust integration tests: the same conv
    computed here and by rust/src/conv must agree through the artifact
    path. The canonical case is written to a file the rust test reads."""

    def test_write_golden(self, tmp_path=None):
        from compile.kernels.sliding_conv import conv1d_sliding

        x = jnp.asarray(np.linspace(-1, 1, 32, dtype=np.float32))[None, None, :]
        w = jnp.asarray(np.array([0.5, -0.25, 1.5], dtype=np.float32))[None, None, :]
        b = jnp.asarray([0.125], dtype=jnp.float32)
        y = conv1d_sliding(x, w, b, pad=1)
        out = np.asarray(y)[0, 0]
        # Deterministic spot values keep the golden file honest.
        assert out.shape == (32,)
        np.testing.assert_allclose(
            out[:3],
            [
                0.125 + (-0.25) * (-1.0) + 1.5 * (-1.0 + 2 / 31),
                0.125 + 0.5 * (-1.0) - 0.25 * (-1.0 + 2 / 31) + 1.5 * (-1.0 + 4 / 31),
                0.125 + 0.5 * (-1.0 + 2 / 31) - 0.25 * (-1.0 + 4 / 31) + 1.5 * (-1.0 + 6 / 31),
            ],
            rtol=1e-5,
        )
