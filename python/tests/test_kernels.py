"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/windows/dilations; every property asserts
allclose against ``ref.py``. This is the core correctness signal for the
AOT artifacts the rust runtime executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.sliding_conv import (
    conv1d_sliding,
    mxu_utilization_estimate,
    vmem_footprint_bytes,
)
from compile.kernels.sliding_pool import pool1d_sliding, sliding_sum

SETTINGS = dict(max_examples=25, deadline=None)


def rnd(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


def assert_close(a, b, atol=1e-4, rtol=1e-4):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=atol, rtol=rtol)


class TestConvKernel:
    @settings(**SETTINGS)
    @given(
        batch=st.integers(1, 3),
        c_in=st.integers(1, 4),
        c_out=st.integers(1, 4),
        n=st.integers(8, 96),
        k=st.integers(1, 7),
    )
    def test_matches_ref_shapes(self, batch, c_in, c_out, n, k):
        if n < k:
            n = k
        x = rnd(1, (batch, c_in, n))
        w = rnd(2, (c_out, c_in, k))
        b = rnd(3, (c_out,))
        assert_close(conv1d_sliding(x, w, b), ref.conv1d_ref(x, w, b))

    @settings(**SETTINGS)
    @given(
        n=st.integers(32, 128),
        k=st.integers(2, 9),
        dilation=st.integers(1, 8),
        stride=st.integers(1, 3),
    )
    def test_matches_ref_hyperparams(self, n, k, dilation, stride):
        eff = (k - 1) * dilation + 1
        if n < eff:
            n = eff
        pad = eff // 2
        x = rnd(4, (2, 2, n))
        w = rnd(5, (3, 2, k))
        b = rnd(6, (3,))
        got = conv1d_sliding(x, w, b, stride=stride, dilation=dilation, pad=pad)
        want = ref.conv1d_ref(x, w, b, stride=stride, dilation=dilation, pad=pad)
        assert_close(got, want)

    def test_same_pad_preserves_length(self):
        x = rnd(7, (1, 1, 50))
        w = rnd(8, (1, 1, 7))
        b = jnp.zeros((1,))
        y = conv1d_sliding(x, w, b, pad=3)
        assert y.shape == (1, 1, 50)

    def test_identity_filter(self):
        x = rnd(9, (1, 1, 20))
        w = jnp.ones((1, 1, 1))
        b = jnp.zeros((1,))
        assert_close(conv1d_sliding(x, w, b), x)

    def test_grad_matches_ref(self):
        x = rnd(10, (2, 3, 24))
        w = rnd(11, (4, 3, 3))
        b = rnd(12, (4,))

        def lk(x, w, b):
            return jnp.sum(conv1d_sliding(x, w, b, dilation=2, pad=2) ** 2)

        def lr(x, w, b):
            return jnp.sum(ref.conv1d_ref(x, w, b, dilation=2, pad=2) ** 2)

        gk = jax.grad(lk, argnums=(0, 1, 2))(x, w, b)
        gr = jax.grad(lr, argnums=(0, 1, 2))(x, w, b)
        for a, c in zip(gk, gr):
            assert_close(a, c, atol=1e-3, rtol=1e-3)

    def test_input_shorter_than_rf_raises(self):
        x = rnd(13, (1, 1, 4))
        w = rnd(14, (1, 1, 7))
        with pytest.raises(AssertionError):
            conv1d_sliding(x, w, jnp.zeros((1,)))

    def test_perf_model_helpers(self):
        fp = vmem_footprint_bytes(c_in=64, c_out=64, k=7, n_block=512)
        # x tile 64*(512+6) + w 64*64*7 + acc 64*512, all f32
        assert fp == 4 * (64 * 518 + 64 * 64 * 7 + 64 * 512)
        assert mxu_utilization_estimate(128, 128, 128) == 1.0
        assert 0.0 < mxu_utilization_estimate(96, 64, 200) < 1.0


class TestPoolKernels:
    @settings(**SETTINGS)
    @given(
        n=st.integers(8, 128),
        w=st.integers(2, 16),
        stride=st.integers(1, 4),
        mode=st.sampled_from(["max", "avg", "min"]),
    )
    def test_matches_ref(self, n, w, stride, mode):
        if n < w:
            n = w
        x = rnd(20, (2, 3, n))
        got = pool1d_sliding(x, w=w, stride=stride, mode=mode)
        if mode == "max":
            want = ref.max_pool1d_ref(x, w, stride=stride)
        elif mode == "avg":
            want = ref.avg_pool1d_ref(x, w, stride=stride)
        else:
            want = -ref.max_pool1d_ref(-x, w, stride=stride)
        assert_close(got, want)

    def test_max_pool_known_values(self):
        x = jnp.asarray([[[1.0, 5.0, 2.0, 2.0, 9.0, 0.0]]])
        y = pool1d_sliding(x, w=2, stride=2, mode="max")
        assert_close(y, jnp.asarray([[[5.0, 2.0, 9.0]]]))

    @settings(**SETTINGS)
    @given(n=st.integers(4, 200), w=st.integers(1, 32))
    def test_sliding_sum_matches_cumsum_ref(self, n, w):
        if n < w:
            n = w
        x = rnd(21, (n,))
        assert_close(sliding_sum(x, w=w), ref.sliding_sum_ref(x, w), atol=1e-3, rtol=1e-3)


class TestPairOperator:
    """Paper Eq. 5-9 validated in jnp (mirrors rust ops::ConvPair tests)."""

    @settings(**SETTINGS)
    @given(m=st.integers(1, 64))
    def test_dot_via_pair_scan(self, m):
        a = rnd(30, (m,))
        b = rnd(31, (m,))
        assert_close(ref.dot_via_pair_scan_ref(a, b), jnp.dot(a, b), atol=1e-3, rtol=1e-3)

    def test_dot_with_zero_taps(self):
        a = jnp.asarray([0.0, 2.0, 0.0, -1.5])
        b = jnp.asarray([9.0, 3.0, 7.0, 2.0])
        assert_close(ref.dot_via_pair_scan_ref(a, b), jnp.dot(a, b))

    def test_all_zero_filter(self):
        a = jnp.zeros((5,))
        b = jnp.arange(5.0)
        assert_close(ref.dot_via_pair_scan_ref(a, b), 0.0)
