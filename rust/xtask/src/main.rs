//! `cargo xtask check` — static contract checker for the swsnn kernel
//! core. Enforces the repo conventions that the PR 2–5 hot-path work
//! established but until now only sampled dynamically (see
//! docs/invariants.md):
//!
//! 1. **safety-comment** — every `unsafe` block/fn/impl carries a
//!    `// SAFETY:` comment on the same line or in the contiguous
//!    comment block directly above it.
//! 2. **arch-confinement** — `std::arch` / `core::arch` tokens appear
//!    only inside `src/simd/`, and there only under an item gated by
//!    `#[cfg(target_arch = ...)]`.
//! 3. **no-alloc** — hot-path modules (`sliding/`, `conv/`, `pool/`,
//!    `gemm/`, `simd/`, and the `// xtask: begin-hot` … `end-hot`
//!    regions of `nn/plan.rs`) contain no heap-allocation calls
//!    (`Vec::new`, `Vec::with_capacity`, `VecDeque::new`, `vec![`,
//!    `.to_vec()`, `.collect()`, `Box::new`) outside per-line
//!    `// alloc-ok: <why>` allowlist annotations.
//! 4. **into-coverage** — every public `*_into` kernel is referenced
//!    from at least one test under `tests/`.
//! 5. **fault-confinement** — the serving fault-injection harness stays
//!    out of release hot paths: `fault_point!` sites may appear only
//!    under `src/coordinator/` (the batcher plus the transport tier:
//!    `coordinator/transport.rs` and `coordinator/admission.rs`),
//!    direct `faults::` references only in `coordinator/faults.rs` and
//!    the macro definition in `coordinator/mod.rs`, and the
//!    `mod faults` declaration must be gated on
//!    `cfg(any(test, feature = "fault-injection"))`.
//!
//! The checker is a line-based scanner with a small lexer (comments,
//! strings, brace depth) — deliberately not a full parser, so it stays
//! std-only, builds in a blink, and its failure output is always a
//! plain `file:line`. `#[cfg(test)]` modules inside `src/` are exempt
//! from rules 2 and 3 (tests may allocate freely).

use std::path::{Path, PathBuf};
use std::process::ExitCode;

const HOT_DIRS: [&str; 5] = ["sliding", "conv", "pool", "gemm", "simd"];
const ALLOC_PATTERNS: [&str; 7] = [
    "Vec::new",
    "Vec::with_capacity",
    "VecDeque::new",
    "vec![",
    ".to_vec()",
    ".collect()",
    "Box::new",
];

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("check") | None => run_check(),
        Some(other) => {
            eprintln!("unknown xtask `{other}`; available: check");
            ExitCode::FAILURE
        }
    }
}

fn run_check() -> ExitCode {
    // CARGO_MANIFEST_DIR is rust/xtask; the crate under inspection is
    // its sibling `src/` + `tests/`.
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("xtask lives inside the rust/ crate dir")
        .to_path_buf();
    let src = root.join("src");
    let tests_dir = root.join("tests");

    let mut files = Vec::new();
    collect_rs_files(&src, &mut files);
    files.sort();

    let mut test_corpus = String::new();
    let mut test_files = Vec::new();
    collect_rs_files(&tests_dir, &mut test_files);
    for f in &test_files {
        test_corpus.push_str(&std::fs::read_to_string(f).unwrap_or_default());
        test_corpus.push('\n');
    }

    let mut violations: Vec<String> = Vec::new();
    let mut into_kernels: Vec<(String, String, usize)> = Vec::new();
    for path in &files {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) => {
                violations.push(format!("{}: unreadable: {e}", rel(path, &root)));
                continue;
            }
        };
        let file = analyze(&text);
        let relpath = rel(path, &root);
        check_safety_comments(&file, &relpath, &mut violations);
        check_arch_confinement(&file, &relpath, &mut violations);
        check_no_alloc(&file, &relpath, &root, path, &mut violations);
        check_fault_confinement(&file, &relpath, &mut violations);
        collect_into_kernels(&file, &relpath, &mut into_kernels);
    }
    for (name, relpath, line) in &into_kernels {
        if !test_corpus.contains(name.as_str()) {
            violations.push(format!(
                "{relpath}:{line}: [into-coverage] public kernel `{name}` is not \
                 referenced by any test under tests/"
            ));
        }
    }

    if violations.is_empty() {
        println!(
            "xtask check: {} source files, {} `_into` kernels covered, 0 violations",
            files.len(),
            into_kernels.len()
        );
        ExitCode::SUCCESS
    } else {
        for v in &violations {
            eprintln!("{v}");
        }
        eprintln!("xtask check: {} violation(s)", violations.len());
        ExitCode::FAILURE
    }
}

fn rel(path: &Path, root: &Path) -> String {
    path.strip_prefix(root)
        .unwrap_or(path)
        .display()
        .to_string()
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = std::fs::read_dir(dir) else {
        return;
    };
    for entry in entries.flatten() {
        let p = entry.path();
        if p.is_dir() {
            collect_rs_files(&p, out);
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
}

/// Per-line view of one source file after the lexing pass.
struct FileScan {
    /// Raw source lines (comments intact — annotations live here).
    raw: Vec<String>,
    /// Code-only text: comments stripped, string/char literal bodies
    /// blanked. Pattern matching runs on this so prose never trips a
    /// rule.
    code: Vec<String>,
    /// Line is inside a `#[cfg(test)]`-gated braced item.
    in_test: Vec<bool>,
    /// Line is inside a `#[cfg(target_arch = ...)]`-gated braced item.
    in_gated: Vec<bool>,
}

/// Lex + region-track one file. Regions are tracked by brace depth: a
/// `#[cfg(test)]` / `#[cfg(target_arch ...)]` attribute arms a pending
/// marker that attaches to the next `{` (the item body) and covers
/// lines until its matching `}`. An attribute that gates a braceless
/// item (`use`, statement) expires at the first `;` instead.
fn analyze(text: &str) -> FileScan {
    let raw: Vec<String> = text.lines().map(str::to_string).collect();
    let mut code = Vec::with_capacity(raw.len());
    let mut in_test = vec![false; raw.len()];
    let mut in_gated = vec![false; raw.len()];

    let mut in_block_comment = false;
    let mut depth: i64 = 0;
    // (kind, depth threshold): active while depth >= threshold.
    let mut stack: Vec<(u8, i64)> = Vec::new();
    let mut pending_test = false;
    let mut pending_gate = false;

    for (i, line) in raw.iter().enumerate() {
        let c = lex_line(line, &mut in_block_comment);
        let test_before = stack.iter().any(|&(k, _)| k == b'T');
        let gate_before = stack.iter().any(|&(k, _)| k == b'G');
        if c.contains("cfg(test)") {
            pending_test = true;
        }
        if c.contains("cfg(target_arch") && !c.contains("cfg(not") {
            pending_gate = true;
        }
        let mut saw_brace = false;
        for ch in c.chars() {
            match ch {
                '{' => {
                    depth += 1;
                    saw_brace = true;
                    if pending_test {
                        stack.push((b'T', depth));
                        pending_test = false;
                    }
                    if pending_gate {
                        stack.push((b'G', depth));
                        pending_gate = false;
                    }
                }
                '}' => {
                    depth -= 1;
                    while stack.last().is_some_and(|&(_, th)| depth < th) {
                        stack.pop();
                    }
                }
                _ => {}
            }
        }
        if (pending_test || pending_gate) && !saw_brace && c.contains(';') {
            pending_test = false;
            pending_gate = false;
        }
        in_test[i] = test_before || stack.iter().any(|&(k, _)| k == b'T');
        in_gated[i] = gate_before || stack.iter().any(|&(k, _)| k == b'G');
        code.push(c);
    }
    FileScan {
        raw,
        code,
        in_test,
        in_gated,
    }
}

/// Strip comments and literal bodies from one line. `in_block_comment`
/// carries `/* ... */` state across lines. String bodies become `""`
/// and char literals `' '` so brace counting and pattern matching never
/// see quoted text; lifetimes (`&'a`) are left alone.
fn lex_line(line: &str, in_block_comment: &mut bool) -> String {
    let chars: Vec<char> = line.chars().collect();
    let n = chars.len();
    let mut out = String::with_capacity(n);
    let mut i = 0;
    while i < n {
        if *in_block_comment {
            if chars[i] == '*' && i + 1 < n && chars[i + 1] == '/' {
                *in_block_comment = false;
                i += 2;
            } else {
                i += 1;
            }
            continue;
        }
        let ch = chars[i];
        if ch == '/' && i + 1 < n && chars[i + 1] == '/' {
            break; // line comment: rest of line is prose
        }
        if ch == '/' && i + 1 < n && chars[i + 1] == '*' {
            *in_block_comment = true;
            i += 2;
            continue;
        }
        // Raw strings: r"..." and r#"..."# (depth-1 is all the crate uses).
        if ch == 'r'
            && i + 1 < n
            && (chars[i + 1] == '"' || (chars[i + 1] == '#' && i + 2 < n && chars[i + 2] == '"'))
            && (i == 0 || !is_ident_char(chars[i - 1]))
        {
            let hashed = chars[i + 1] == '#';
            i += if hashed { 3 } else { 2 };
            while i < n {
                if chars[i] == '"' && (!hashed || (i + 1 < n && chars[i + 1] == '#')) {
                    i += if hashed { 2 } else { 1 };
                    break;
                }
                i += 1;
            }
            out.push_str("\"\"");
            continue;
        }
        if ch == '"' {
            i += 1;
            while i < n {
                if chars[i] == '\\' {
                    i += 2;
                } else if chars[i] == '"' {
                    i += 1;
                    break;
                } else {
                    i += 1;
                }
            }
            out.push_str("\"\"");
            continue;
        }
        if ch == '\'' {
            // Char literal iff it closes ('x' or '\x'); otherwise a
            // lifetime tick, which passes through.
            if i + 2 < n && chars[i + 1] == '\\' {
                i += 2;
                while i < n && chars[i] != '\'' {
                    i += 1;
                }
                i += 1;
                out.push_str("' '");
                continue;
            }
            if i + 2 < n && chars[i + 2] == '\'' {
                i += 3;
                out.push_str("' '");
                continue;
            }
            out.push(ch);
            i += 1;
            continue;
        }
        out.push(ch);
        i += 1;
    }
    out
}

fn is_ident_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Whole-word occurrence of `word` in `code` (so `unsafe_code` in an
/// attribute never matches `unsafe`).
fn contains_word(code: &str, word: &str) -> bool {
    let mut start = 0;
    while let Some(pos) = code[start..].find(word) {
        let at = start + pos;
        let before_ok = at == 0 || !is_ident_char(code[..at].chars().last().unwrap());
        let after = code[at + word.len()..].chars().next();
        let after_ok = after.is_none_or(|c| !is_ident_char(c));
        if before_ok && after_ok {
            return true;
        }
        start = at + word.len();
    }
    false
}

/// Rule 1: `unsafe` requires `// SAFETY:` on the line or in the
/// contiguous comment block directly above.
fn check_safety_comments(file: &FileScan, relpath: &str, violations: &mut Vec<String>) {
    for (i, code) in file.code.iter().enumerate() {
        if !contains_word(code, "unsafe") {
            continue;
        }
        if file.raw[i].contains("SAFETY:") || preceding_comments_contain(file, i, "SAFETY:") {
            continue;
        }
        violations.push(format!(
            "{relpath}:{}: [safety-comment] `unsafe` without a `// SAFETY:` comment \
             (same line or contiguous comment block above)",
            i + 1
        ));
    }
}

/// Scan the contiguous run of comment lines directly above line `i`.
fn preceding_comments_contain(file: &FileScan, i: usize, needle: &str) -> bool {
    for j in (0..i).rev() {
        let t = file.raw[j].trim_start();
        if t.starts_with("//") {
            if t.contains(needle) {
                return true;
            }
        } else {
            return false;
        }
    }
    false
}

/// Rule 2: `std::arch` / `core::arch` only inside `src/simd/`, and
/// there only under `#[cfg(target_arch = ...)]`-gated items.
fn check_arch_confinement(file: &FileScan, relpath: &str, violations: &mut Vec<String>) {
    let in_simd = relpath.starts_with("simd/") || relpath.contains("/simd/");
    for (i, code) in file.code.iter().enumerate() {
        if !code.contains("std::arch") && !code.contains("core::arch") {
            continue;
        }
        if !in_simd {
            violations.push(format!(
                "{relpath}:{}: [arch-confinement] std::arch/core::arch outside src/simd/",
                i + 1
            ));
        } else if !file.in_gated[i] && !file.in_test[i] {
            violations.push(format!(
                "{relpath}:{}: [arch-confinement] std::arch use not inside a \
                 #[cfg(target_arch = ...)]-gated item",
                i + 1
            ));
        }
    }
}

/// Rule 3: allocation calls in hot-path code need a per-line
/// `// alloc-ok: <why>` annotation (same line, or in the comment lines
/// directly above the statement).
fn check_no_alloc(
    file: &FileScan,
    relpath: &str,
    _root: &Path,
    path: &Path,
    violations: &mut Vec<String>,
) {
    let in_hot_dir = HOT_DIRS
        .iter()
        .any(|d| relpath.starts_with(&format!("{d}/")) || relpath.contains(&format!("/{d}/")));
    let is_plan = path.ends_with("nn/plan.rs");
    if !in_hot_dir && !is_plan {
        return;
    }
    // For nn/plan.rs only the marked run-path regions are in scope; the
    // compile/probe half of the file allocates by design.
    let mut hot = vec![in_hot_dir; file.raw.len()];
    if is_plan {
        let (mut begins, mut ends) = (0usize, 0usize);
        let mut on = false;
        for (i, line) in file.raw.iter().enumerate() {
            if line.contains("xtask: begin-hot") {
                on = true;
                begins += 1;
            }
            if line.contains("xtask: end-hot") {
                on = false;
                ends += 1;
            }
            hot[i] = on;
        }
        if begins != ends || begins == 0 {
            violations.push(format!(
                "{relpath}:1: [no-alloc] unbalanced or missing \
                 `// xtask: begin-hot`/`end-hot` markers ({begins} begin, {ends} end)"
            ));
            return;
        }
    }
    for (i, code) in file.code.iter().enumerate() {
        if !hot[i] || file.in_test[i] {
            continue;
        }
        let Some(pat) = ALLOC_PATTERNS.iter().find(|p| code.contains(**p)) else {
            continue;
        };
        if file.raw[i].contains("alloc-ok:") || statement_annotated(file, i) {
            continue;
        }
        violations.push(format!(
            "{relpath}:{}: [no-alloc] `{pat}` in a hot-path module without an \
             `// alloc-ok:` annotation",
            i + 1
        ));
    }
}

/// Walk upward from line `i` through the current statement's
/// continuation lines (lines not ending a previous statement/block)
/// and any comment lines, looking for an `alloc-ok:` annotation. Stops
/// at blank lines, `;`, `{`, or `}` terminators, or after 12 lines.
fn statement_annotated(file: &FileScan, i: usize) -> bool {
    let lo = i.saturating_sub(12);
    for j in (lo..i).rev() {
        let t = file.raw[j].trim();
        if t.starts_with("//") {
            if t.contains("alloc-ok:") {
                return true;
            }
            continue;
        }
        if t.is_empty() || t.ends_with(';') || t.ends_with('{') || t.ends_with('}') {
            return false;
        }
    }
    false
}

/// Rule 5: fault-injection confinement. `fault_point!` sites live only
/// under `src/coordinator/` — the batcher/supervisor (`batcher.rs`) and
/// the transport tier (`transport.rs` with its `transport.*` sites;
/// `admission.rs` is covered by the same directory scope) — direct
/// `faults::` references only in `coordinator/faults.rs` (the registry)
/// and `coordinator/mod.rs` (the macro definition + gated `mod`
/// declaration). The `mod faults`
/// declaration itself must carry the
/// `cfg(any(test, feature = "fault-injection"))` gate so plain release
/// builds compile zero injection branches.
fn check_fault_confinement(file: &FileScan, relpath: &str, violations: &mut Vec<String>) {
    let in_coordinator = relpath.contains("/coordinator/");
    let is_faults = relpath.ends_with("coordinator/faults.rs");
    let is_coord_mod = relpath.ends_with("coordinator/mod.rs");
    for (i, code) in file.code.iter().enumerate() {
        if code.contains("fault_point!") && !in_coordinator {
            violations.push(format!(
                "{relpath}:{}: [fault-confinement] `fault_point!` site outside \
                 src/coordinator/",
                i + 1
            ));
        }
        if code.contains("faults::") && !is_faults && !is_coord_mod && !file.in_test[i] {
            violations.push(format!(
                "{relpath}:{}: [fault-confinement] direct `faults::` reference outside \
                 coordinator/faults.rs and the coordinator/mod.rs macro",
                i + 1
            ));
        }
        if is_coord_mod && code.contains("mod faults") {
            // The gate mentions the feature name inside a string, which
            // the lexer blanks — look at the raw lines.
            let gated = file.raw[i].contains("fault-injection")
                || (i > 0 && file.raw[i - 1].contains("fault-injection"));
            if !gated {
                violations.push(format!(
                    "{relpath}:{}: [fault-confinement] `mod faults` must be gated on \
                     cfg(any(test, feature = \"fault-injection\"))",
                    i + 1
                ));
            }
        }
    }
}

/// Rule 4 harvest: public `fn *_into` definitions outside test modules.
fn collect_into_kernels(file: &FileScan, relpath: &str, out: &mut Vec<(String, String, usize)>) {
    for (i, code) in file.code.iter().enumerate() {
        if file.in_test[i] {
            continue;
        }
        let t = code.trim_start();
        let Some(rest) = t.strip_prefix("pub fn ") else {
            continue;
        };
        let name: String = rest.chars().take_while(|c| is_ident_char(*c)).collect();
        if name.ends_with("_into") {
            out.push((name, relpath.to_string(), i + 1));
        }
    }
}
