//! Deterministic wire-protocol fuzz + transport-hardening tests
//! (`docs/robustness.md`, "Transport & admission").
//!
//! The invariants under attack: a malformed, truncated, oversized, or
//! stalled frame (1) produces a *typed* outcome on that connection —
//! wire code 10 where a response is possible, a silent close where it
//! isn't — (2) never kills the listener, and (3) never leaks a ticket,
//! so the coordinator's terminal-state ledger balances after every
//! abuse schedule. Mutations are seeded (`workload::Rng`), so a failure
//! reproduces byte-for-byte.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use swsnn::config::ServeConfig;
use swsnn::coordinator::{
    serve_tcp_with, Coordinator, CoordinatorStats, Engine, QuotaConfig, TcpClient,
    TransportConfig,
};
use swsnn::workload::Rng;

const ROW: usize = 4;

/// Echo engine with toy streaming sessions (mirrors the chaos harness):
/// infers echo their row, steps echo their packet.
#[derive(Clone, Default)]
struct EchoEngine {
    next: u32,
    live: std::collections::HashSet<u32>,
}

impl Engine for EchoEngine {
    fn input_len(&self) -> usize {
        ROW
    }
    fn output_len(&self) -> usize {
        ROW
    }
    fn infer(&self, x: &[f32], _batch: usize) -> anyhow::Result<Vec<f32>> {
        Ok(x.to_vec())
    }
    fn name(&self) -> String {
        "fuzz-echo".into()
    }
    fn session_open(&mut self) -> anyhow::Result<u32> {
        let id = self.next;
        self.next += 1;
        self.live.insert(id);
        Ok(id)
    }
    fn session_step(&mut self, id: u32, x: &[f32], out: &mut Vec<f32>) -> anyhow::Result<usize> {
        anyhow::ensure!(self.live.contains(&id), "unknown session id {id}");
        out.clear();
        out.extend_from_slice(x);
        Ok(x.len())
    }
    fn session_close(&mut self, id: u32) -> anyhow::Result<()> {
        anyhow::ensure!(self.live.remove(&id), "unknown session id {id}");
        Ok(())
    }
    fn live_sessions(&self) -> usize {
        self.live.len()
    }
}

fn fuzz_config() -> ServeConfig {
    ServeConfig {
        max_batch: 4,
        batch_deadline_us: 200,
        workers: 1,
        queue_capacity: 64,
        ..Default::default()
    }
}

struct TestServer {
    coord: Arc<Coordinator>,
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    server: std::thread::JoinHandle<()>,
}

fn start_server(tcfg: TransportConfig) -> TestServer {
    let coord =
        Arc::new(Coordinator::start_replicated(EchoEngine::default(), &fuzz_config()).unwrap());
    let stop = Arc::new(AtomicBool::new(false));
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();
    let server = {
        let coord = Arc::clone(&coord);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            serve_tcp_with(coord, "127.0.0.1:0", tcfg, stop, move |addr| {
                addr_tx.send(addr).unwrap();
            })
            .unwrap();
        })
    };
    let addr = addr_rx.recv_timeout(Duration::from_secs(5)).unwrap();
    TestServer {
        coord,
        addr,
        stop,
        server,
    }
}

impl TestServer {
    /// Stop the listener (all clients must be dropped first), join it,
    /// and drain the coordinator to its final stats.
    fn finish(self) -> CoordinatorStats {
        self.stop.store(true, Ordering::SeqCst);
        self.server.join().unwrap();
        Arc::try_unwrap(self.coord)
            .ok()
            .expect("server still holds the coordinator")
            .shutdown()
    }
}

/// A canonical valid infer frame: `u32 n | u32 ttl_ms | n × f32`.
fn valid_infer_frame() -> Vec<u8> {
    let mut buf = Vec::with_capacity(8 + ROW * 4);
    buf.extend_from_slice(&(ROW as u32).to_le_bytes());
    buf.extend_from_slice(&0u32.to_le_bytes());
    for i in 0..ROW {
        buf.extend_from_slice(&(i as f32).to_le_bytes());
    }
    buf
}

/// Fire raw bytes at the server, close the write side, and drain
/// whatever comes back until the server closes (or 2 s pass).
fn send_raw(addr: std::net::SocketAddr, bytes: &[u8]) -> Vec<u8> {
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(2))).unwrap();
    let _ = s.write_all(bytes);
    let _ = s.shutdown(std::net::Shutdown::Write);
    let mut got = Vec::new();
    let _ = s.read_to_end(&mut got);
    got
}

fn assert_listener_alive(addr: std::net::SocketAddr) {
    let mut client = TcpClient::connect(addr).unwrap();
    let row = vec![1.5f32; ROW];
    assert_eq!(
        client.infer(&row).unwrap(),
        row,
        "listener must keep serving after abuse"
    );
}

/// Seeded mutation sweep over a valid frame: truncations, oversized
/// length prefixes, unknown magics, byte flips, mid-frame EOFs. Every
/// case must leave the listener serving and the ledger balanced.
#[test]
fn mutated_frames_never_kill_listener_and_ledger_balances() {
    let srv = start_server(TransportConfig {
        idle_timeout: Duration::from_millis(500),
        ..Default::default()
    });
    let valid = valid_infer_frame();
    let mut rng = Rng::new(0xF422_0010);
    for case in 0..60u32 {
        let mut bytes = valid.clone();
        match case % 5 {
            0 => {
                // Truncated frame: cut anywhere inside the frame.
                let cut = 1 + (rng.next_u64() as usize) % (bytes.len() - 1);
                bytes.truncate(cut);
            }
            1 => {
                // Oversized length prefix, below the control range.
                let n = (1u32 << 22) + 1 + (rng.next_u64() as u32 % 1_000_000);
                bytes[..4].copy_from_slice(&n.to_le_bytes());
            }
            2 => {
                // Unknown magic in the reserved control range (skip the
                // five assigned magics 0xFFFF_FF01..=05).
                let m = 0xFFFF_FF10u32 | (rng.next_u64() as u32 & 0xEF);
                bytes[..4].copy_from_slice(&m.to_le_bytes());
            }
            3 => {
                // Single byte flip anywhere in the frame.
                let idx = (rng.next_u64() as usize) % bytes.len();
                bytes[idx] ^= 1 << (rng.next_u64() % 8);
            }
            _ => {
                // Mid-frame EOF: header only, no payload.
                bytes.truncate(8);
            }
        }
        let _ = send_raw(srv.addr, &bytes);
    }
    assert_listener_alive(srv.addr);
    let stats = srv.finish();
    assert_eq!(
        stats.terminal(),
        stats.submitted,
        "every accepted request must reach exactly one terminal state"
    );
}

#[test]
fn oversized_and_unknown_magic_get_typed_decode_errors() {
    let srv = start_server(TransportConfig::default());

    // Oversized length prefix → wire code 10, then close.
    let mut bytes = Vec::new();
    bytes.extend_from_slice(&(u32::MAX / 2).to_le_bytes());
    let got = send_raw(srv.addr, &bytes);
    assert_eq!(got.first(), Some(&10u8), "oversized prefix → decode error");

    // Unknown control magic → wire code 10, then close.
    let mut bytes = Vec::new();
    bytes.extend_from_slice(&0xFFFF_FFEEu32.to_le_bytes());
    let got = send_raw(srv.addr, &bytes);
    assert_eq!(got.first(), Some(&10u8), "unknown magic → decode error");

    // Both were counted, and the listener still serves.
    assert_listener_alive(srv.addr);
    let mut client = TcpClient::connect(srv.addr).unwrap();
    let stats = client.stats_map().unwrap();
    assert!(
        stats["decode_errors"] >= 2.0,
        "decode errors must be counted, got {:?}",
        stats.get("decode_errors")
    );
    drop(client);
    let stats = srv.finish();
    assert_eq!(stats.terminal(), stats.submitted);
}

#[test]
fn mid_frame_eof_closes_connection_without_response() {
    let srv = start_server(TransportConfig::default());
    // Header promises ROW floats; send none and close.
    let mut bytes = Vec::new();
    bytes.extend_from_slice(&(ROW as u32).to_le_bytes());
    bytes.extend_from_slice(&0u32.to_le_bytes());
    let got = send_raw(srv.addr, &bytes);
    assert!(got.is_empty(), "truncated frame gets no response, got {got:?}");
    assert_listener_alive(srv.addr);
    let stats = srv.finish();
    assert_eq!(stats.submitted, 1, "only the liveness probe was submitted");
    assert_eq!(stats.terminal(), stats.submitted);
}

/// Slow-loris: a peer that sends a partial frame and stalls is dropped
/// once the idle timeout lapses — typed as a decode error — instead of
/// pinning its handler thread for the life of the socket.
#[test]
fn slow_loris_partial_frame_is_dropped_on_idle_timeout() {
    let srv = start_server(TransportConfig {
        idle_timeout: Duration::from_millis(200),
        ..Default::default()
    });
    let mut s = TcpStream::connect(srv.addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    // First word of a frame, then silence: the server is now mid-frame.
    s.write_all(&(ROW as u32).to_le_bytes()).unwrap();
    let start = std::time::Instant::now();
    let mut got = Vec::new();
    let n = s.read_to_end(&mut got);
    assert!(n.is_ok(), "server should close (EOF), not reset: {n:?}");
    assert!(
        start.elapsed() < Duration::from_secs(4),
        "stalled connection must be dropped near the 200ms idle timeout"
    );
    drop(s);
    assert_listener_alive(srv.addr);
    let mut client = TcpClient::connect(srv.addr).unwrap();
    let stats = client.stats_map().unwrap();
    assert!(stats["decode_errors"] >= 1.0, "stall counts as decode error");
    drop(client);
    let stats = srv.finish();
    assert_eq!(stats.terminal(), stats.submitted);
}

/// Session control frames, inference frames, and an engine error all
/// interleave on one connection without desynchronizing the stream.
#[test]
fn interleaved_session_and_infer_frames_share_a_connection() {
    let srv = start_server(TransportConfig::default());
    let mut client = TcpClient::connect(srv.addr).unwrap();
    let row = vec![2.0f32; ROW];

    let sid = client.session_open(None).unwrap();
    assert_eq!(client.infer(&row).unwrap(), row);
    assert_eq!(client.session_step(sid, &row).unwrap(), row);
    // Unknown session id → typed engine error (code 1), connection
    // stays usable (only *decode* errors close it).
    let err = client.session_step(sid + 1000, &row).unwrap_err().to_string();
    assert!(err.contains("code 1"), "engine error expected, got: {err}");
    assert_eq!(client.infer(&row).unwrap(), row);
    client.session_close(sid).unwrap();
    assert_eq!(client.infer(&row).unwrap(), row);

    drop(client);
    let stats = srv.finish();
    assert_eq!(stats.sessions_opened, 1);
    assert_eq!(stats.sessions_closed, 1);
    assert_eq!(stats.terminal(), stats.submitted);
}

/// The join-handle leak regression (PR 10): 100 sequential short-lived
/// connections must leave at most `max_connections` live handles, and a
/// stats round-trip must agree with the coordinator's own ledger.
#[test]
fn connection_churn_reaps_finished_handles() {
    let srv = start_server(TransportConfig {
        max_connections: 8,
        ..Default::default()
    });
    let row = vec![3.0f32; ROW];
    for _ in 0..100 {
        let mut client = TcpClient::connect(srv.addr).unwrap();
        assert_eq!(client.infer(&row).unwrap(), row);
    }
    let mut client = TcpClient::connect(srv.addr).unwrap();
    let map = client.stats_map().unwrap();
    assert!(
        map["handles_live"] <= 8.0,
        "reaper must bound live handles, got {}",
        map["handles_live"]
    );
    assert!(map["conns_accepted"] >= 101.0);
    assert!(map["conns_open"] >= 1.0, "this stats connection is open");
    // Wire stats match the coordinator's own counters.
    let direct = srv.coord.stats();
    assert_eq!(map["submitted"] as u64, direct.submitted);
    assert_eq!(map["completed"] as u64, direct.completed);
    assert_eq!(map["completed"] as u64, 100);
    drop(client);
    let stats = srv.finish();
    assert_eq!(stats.terminal(), stats.submitted);
}

/// Over-capacity connections are refused with wire code 8
/// (`Shed::ConnLimit`) and a close — not a silent reset.
#[test]
fn conn_limit_refuses_with_typed_wire_code() {
    let srv = start_server(TransportConfig {
        max_connections: 2,
        ..Default::default()
    });
    let row = vec![4.0f32; ROW];
    let mut c1 = TcpClient::connect(srv.addr).unwrap();
    let mut c2 = TcpClient::connect(srv.addr).unwrap();
    assert_eq!(c1.infer(&row).unwrap(), row);
    assert_eq!(c2.infer(&row).unwrap(), row);
    // Third connection: read the refusal without sending anything (the
    // server writes code 8 at accept time, then closes).
    let mut s = TcpStream::connect(srv.addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    let mut got = Vec::new();
    s.read_to_end(&mut got).unwrap();
    assert_eq!(got.first(), Some(&8u8), "expected ConnLimit wire code 8");
    drop(s);
    // Capacity frees up once a held connection closes.
    drop(c1);
    std::thread::sleep(Duration::from_millis(50));
    assert_listener_alive(srv.addr);
    drop(c2);
    let stats = srv.finish();
    assert_eq!(stats.terminal(), stats.submitted);
}

/// Admission fairness over the wire: a tenant flooding far beyond its
/// token-bucket rate collects `QuotaExceeded` (code 9) sheds, while a
/// well-behaved tenant pacing under the rate is never rejected.
#[test]
fn flooding_tenant_cannot_starve_well_behaved_tenant() {
    let srv = start_server(TransportConfig {
        quota: QuotaConfig {
            rate_per_sec: 20,
            burst: 2,
        },
        ..Default::default()
    });
    let row = vec![5.0f32; ROW];

    // Tenant 7 floods 40 back-to-back requests.
    let mut flooder = TcpClient::connect(srv.addr).unwrap();
    flooder.set_tenant(7).unwrap();
    let mut flood_ok = 0u32;
    let mut flood_shed = 0u32;
    for _ in 0..40 {
        match flooder.infer(&row) {
            Ok(out) => {
                assert_eq!(out, row);
                flood_ok += 1;
            }
            Err(e) => {
                let msg = e.to_string();
                assert!(msg.contains("code 9"), "expected quota shed, got: {msg}");
                flood_shed += 1;
            }
        }
    }
    assert!(flood_shed > 0, "40 back-to-back requests must exceed 20 rps");
    assert!(flood_ok >= 2, "the burst depth is always admitted");

    // Tenant 8 paces well under the rate on its own connection — its
    // bucket is untouched by the flood, so nothing is shed.
    let mut polite = TcpClient::connect(srv.addr).unwrap();
    polite.set_tenant(8).unwrap();
    for _ in 0..5 {
        assert_eq!(polite.infer(&row).unwrap(), row, "paced tenant starved");
        std::thread::sleep(Duration::from_millis(100));
    }

    // Per-tenant counters surfaced over the wire.
    let map = polite.stats_map().unwrap();
    assert_eq!(map["tenant.7.shed"] as u32, flood_shed);
    assert_eq!(map["tenant.7.accepted"] as u32, flood_ok);
    assert_eq!(map["tenant.8.shed"] as u32, 0);
    assert!(map["quota_shed"] as u32 >= flood_shed);
    drop(flooder);
    drop(polite);

    // Quota sheds happen *before* submission: the terminal ledger
    // balances without them.
    let stats = srv.finish();
    assert_eq!(stats.terminal(), stats.submitted);
    assert_eq!(stats.submitted as u32, flood_ok + 5);
}
