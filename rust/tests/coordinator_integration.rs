//! Coordinator integration: batching behaviour, backpressure, shape
//! validation, TCP front-end, and the PJRT-engine serving path.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use swsnn::config::{load_config, ServeConfig};
use swsnn::conv::ConvBackend;
use swsnn::coordinator::{
    serve_tcp, serve_tcp_with, Coordinator, Engine, NativeEngine, PjrtTcnEngine, ServeError, Shed,
    SubmitError, TcpClient, TransportConfig,
};
use swsnn::nn::Model;
use swsnn::workload::Rng;

const CFG: &str = r#"
[model]
name = "itest"
c_in = 1
seq_len = 32

[layer.0]
type = "conv"
c_out = 4
k = 3

[layer.1]
type = "conv"
c_out = 1
k = 3
"#;

fn native_coordinator(serve: &ServeConfig) -> Coordinator {
    let (mc, _) = load_config(CFG).unwrap();
    let mut rng = Rng::new(1);
    let model = Model::init(&mc, &mut rng).unwrap();
    let engine = NativeEngine::new(model, ConvBackend::Sliding, serve.max_batch);
    Coordinator::start_native(engine, serve).unwrap()
}

/// Plan cache: one compiled plan per batch size, reused afterwards, and
/// every batch size produces the same per-row results.
#[test]
fn engine_caches_one_plan_per_batch_size() {
    let (mc, _) = load_config(CFG).unwrap();
    let model = Model::init(&mc, &mut Rng::new(1)).unwrap();
    let reference = Model::init(&mc, &mut Rng::new(1)).unwrap(); // same seed → same params
    let mut engine =
        NativeEngine::with_choice(model, swsnn::conv::BackendChoice::Auto, 8);
    assert_eq!(engine.cached_plans(), 0);

    let mut rng = Rng::new(41);
    let rows: Vec<Vec<f32>> = (0..4).map(|_| rng.vec_uniform(32, -1.0, 1.0)).collect();

    let mut y1 = Vec::new();
    engine.infer_into(&rows[0], 1, &mut y1).unwrap();
    assert_eq!(engine.cached_plans(), 1, "first batch size compiles one plan");

    let x4: Vec<f32> = rows.iter().flatten().copied().collect();
    let mut y4 = Vec::new();
    engine.infer_into(&x4, 4, &mut y4).unwrap();
    assert_eq!(engine.cached_plans(), 2, "second batch size compiles a second plan");

    // Repeats hit the cache instead of compiling more plans.
    engine.infer_into(&rows[1], 1, &mut y1).unwrap();
    engine.infer_into(&x4, 4, &mut y4).unwrap();
    assert_eq!(engine.cached_plans(), 2);

    // Same outputs from both cached plans: every batched row must equal
    // the single-row forward of identical parameters.
    assert_eq!(y4.len(), 4 * 32);
    for (i, row) in rows.iter().enumerate() {
        let want = reference.forward(row, 1, ConvBackend::Sliding).unwrap().data;
        let got = &y4[i * 32..(i + 1) * 32];
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-4, "row {i}: {a} vs {b}");
        }
    }
}

/// Startup batch-bucket precompilation: after `warmup` with buckets
/// [1, 8, 32], steady-state inference at those batch sizes is served
/// entirely from the plan cache (zero compiles — the compile counter
/// stays flat) and never regrows the plan arena (zero per-request
/// allocations in the plan layer).
#[test]
fn warmup_precompiles_buckets_and_steady_state_never_compiles() {
    let (mc, _) = load_config(CFG).unwrap();
    let model = Model::init(&mc, &mut Rng::new(1)).unwrap();
    let mut engine = NativeEngine::with_choice(model, swsnn::conv::BackendChoice::Auto, 32);
    assert_eq!(engine.plan_compiles(), 0);
    engine.warmup(&[1, 8, 32]).unwrap();
    assert_eq!(engine.cached_plans(), 3, "one plan per configured bucket");
    assert_eq!(engine.plan_compiles(), 3);
    let arena = engine.arena_len();
    assert!(arena > 0, "warm-up pre-grows the plan arena");

    let mut rng = Rng::new(41);
    let mut y = Vec::new();
    for batch in [1usize, 8, 32, 8, 1, 32] {
        let x = rng.vec_uniform(batch * 32, -1.0, 1.0);
        engine.infer_into(&x, batch, &mut y).unwrap();
        assert_eq!(y.len(), batch * 32);
        assert!(y.iter().all(|v| v.is_finite()));
    }
    assert_eq!(
        engine.plan_compiles(),
        3,
        "steady-state inference at a warmed bucket compiled a plan"
    );
    assert_eq!(engine.cached_plans(), 3);
    assert!(engine.plan_cache_hits() >= 6, "requests must hit the cache");
    assert_eq!(
        engine.arena_len(),
        arena,
        "steady-state inference at a warmed bucket grew the arena"
    );

    // Out-of-range buckets are ignored, not errors; repeats are free.
    engine.warmup(&[0, 8, 64]).unwrap();
    assert_eq!(engine.cached_plans(), 3);
    assert_eq!(engine.plan_compiles(), 3);
}

/// The coordinator wires `serve.batch_buckets` through to every worker's
/// engine warm-up at startup (replicated engines included) and serving
/// behaves normally afterwards.
#[test]
fn coordinator_startup_warms_configured_buckets() {
    let serve = ServeConfig {
        max_batch: 8,
        batch_deadline_us: 500,
        workers: 2,
        batch_buckets: vec![1, 4, 8],
        ..Default::default()
    };
    let (mc, _) = load_config(CFG).unwrap();
    let model = Model::init(&mc, &mut Rng::new(1)).unwrap();
    let engine = NativeEngine::with_choice(model, swsnn::conv::BackendChoice::Auto, 8);
    let coord = Coordinator::start_replicated(engine, &serve).unwrap();
    assert_eq!(coord.worker_count(), 2);
    let mut rng = Rng::new(77);
    for _ in 0..6 {
        let y = coord.infer(rng.vec_uniform(32, -1.0, 1.0)).unwrap();
        assert_eq!(y.len(), 32);
        assert!(y.iter().all(|v| v.is_finite()));
    }
    let stats = coord.shutdown();
    assert_eq!(stats.completed, 6);
    assert_eq!(stats.rejected, 0);
}

/// The batcher pads every collected batch up to the smallest configured
/// bucket, so engines only ever execute warmed batch sizes — and the pad
/// rows are dropped before distribution (responses still match their
/// requests exactly).
#[test]
fn batcher_pads_batches_to_configured_buckets() {
    use std::sync::Mutex;
    struct SizeRecorder {
        row: usize,
        seen: Arc<Mutex<Vec<usize>>>,
    }
    impl Engine for SizeRecorder {
        fn input_len(&self) -> usize {
            self.row
        }
        fn output_len(&self) -> usize {
            self.row
        }
        fn infer(&self, x: &[f32], batch: usize) -> anyhow::Result<Vec<f32>> {
            assert_eq!(x.len(), batch * self.row, "padded input shape");
            self.seen.lock().unwrap().push(batch);
            Ok(x.to_vec()) // echo — pad rows come back too, batcher drops them
        }
        fn name(&self) -> String {
            "size-recorder".into()
        }
    }
    let seen = Arc::new(Mutex::new(Vec::new()));
    let serve = ServeConfig {
        max_batch: 8,
        batch_deadline_us: 5_000,
        batch_buckets: vec![4, 8],
        ..Default::default()
    };
    let coord = Coordinator::start_native(
        SizeRecorder {
            row: 3,
            seen: Arc::clone(&seen),
        },
        &serve,
    )
    .unwrap();
    let inputs: Vec<Vec<f32>> = (0..5)
        .map(|i| vec![i as f32, i as f32 + 0.5, -(i as f32)])
        .collect();
    let tickets: Vec<_> = inputs
        .iter()
        .map(|x| coord.submit(x.clone()).unwrap())
        .collect();
    for (x, t) in inputs.iter().zip(tickets) {
        let y = t.wait().unwrap();
        assert_eq!(&y, x, "pad rows leaked into a response");
    }
    // However the 5 requests were grouped, every executed batch was
    // padded to a configured bucket (4 or 8) — never an arbitrary size.
    let sizes = seen.lock().unwrap().clone();
    assert!(!sizes.is_empty());
    for s in sizes {
        assert!(s == 4 || s == 8, "engine saw unpadded batch size {s}");
    }
    coord.shutdown();
}

/// A failing warm-up fails coordinator startup (same contract as a
/// failing engine factory).
#[test]
fn warmup_failure_fails_startup() {
    struct BadWarmup;
    impl Engine for BadWarmup {
        fn input_len(&self) -> usize {
            2
        }
        fn output_len(&self) -> usize {
            2
        }
        fn infer(&self, x: &[f32], _batch: usize) -> anyhow::Result<Vec<f32>> {
            Ok(x.to_vec())
        }
        fn warmup(&mut self, _buckets: &[usize]) -> anyhow::Result<()> {
            anyhow::bail!("no memory for plans")
        }
        fn name(&self) -> String {
            "bad-warmup".into()
        }
    }
    let err = Coordinator::start_native(BadWarmup, &ServeConfig::default())
        .err()
        .expect("warm-up failure must fail startup");
    let msg = format!("{err:#}"); // full chain: context + root cause
    assert!(msg.contains("warm-up failed"), "{msg}");
    assert!(msg.contains("no memory for plans"), "{msg}");
}

#[test]
fn single_request_roundtrip() {
    let coord = native_coordinator(&ServeConfig::default());
    let mut rng = Rng::new(2);
    let out = coord.infer(rng.vec_uniform(32, -1.0, 1.0)).unwrap();
    assert_eq!(out.len(), 32);
    assert!(out.iter().all(|v| v.is_finite()));
}

#[test]
fn bad_shape_rejected_immediately() {
    let coord = native_coordinator(&ServeConfig::default());
    match coord.try_submit(vec![0.0; 31]) {
        Err(SubmitError::BadShape { expected: 32, got: 31 }) => {}
        other => panic!("{other:?}"),
    }
    let stats = coord.stats();
    assert_eq!(stats.rejected, 1);
}

#[test]
fn responses_match_unbatched_reference() {
    // Whatever batches form, each row's response must equal the
    // single-row forward of the same engine.
    let (mc, _) = load_config(CFG).unwrap();
    let mut rng = Rng::new(3);
    let model = Model::init(&mc, &mut rng).unwrap();
    let reference = Model::init(&mc, &mut Rng::new(3)).unwrap(); // same seed → same params

    let serve = ServeConfig {
        max_batch: 4,
        batch_deadline_us: 2000,
        ..Default::default()
    };
    let coord =
        Coordinator::start_native(NativeEngine::new(model, ConvBackend::Sliding, 4), &serve)
            .unwrap();

    let mut rng2 = Rng::new(77);
    let inputs: Vec<Vec<f32>> = (0..10).map(|_| rng2.vec_uniform(32, -1.0, 1.0)).collect();
    let tickets: Vec<_> = inputs
        .iter()
        .map(|x| coord.submit(x.clone()).unwrap())
        .collect();
    for (x, t) in inputs.iter().zip(tickets) {
        let got = t.wait().unwrap();
        let want = reference.forward(x, 1, ConvBackend::Sliding).unwrap().data;
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }
    let stats = coord.shutdown();
    assert_eq!(stats.completed, 10);
    assert!(stats.batches <= 10);
}

#[test]
fn deadline_batching_aggregates() {
    // Concurrent submitters with a long deadline should form
    // multi-row batches.
    let serve = ServeConfig {
        max_batch: 8,
        batch_deadline_us: 20_000,
        ..Default::default()
    };
    let coord = Arc::new(native_coordinator(&serve));
    let mut handles = Vec::new();
    for i in 0..16 {
        let c = Arc::clone(&coord);
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(100 + i);
            c.infer(rng.vec_uniform(32, -1.0, 1.0)).unwrap()
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let stats = coord.stats();
    assert_eq!(stats.completed, 16);
    assert!(
        stats.mean_batch > 1.0,
        "expected batching, got mean batch {}",
        stats.mean_batch
    );
}

#[test]
fn backpressure_overload_signal() {
    // An engine that blocks until released fills the queue; try_submit
    // must report Overloaded rather than deadlocking.
    struct StuckEngine(Arc<AtomicBool>);
    impl Engine for StuckEngine {
        fn input_len(&self) -> usize {
            4
        }
        fn output_len(&self) -> usize {
            4
        }
        fn infer(&self, x: &[f32], _batch: usize) -> anyhow::Result<Vec<f32>> {
            while !self.0.load(Ordering::SeqCst) {
                std::thread::sleep(Duration::from_millis(1));
            }
            Ok(x.to_vec())
        }
        fn name(&self) -> String {
            "stuck".into()
        }
    }
    let release = Arc::new(AtomicBool::new(false));
    let serve = ServeConfig {
        max_batch: 1,
        queue_capacity: 2,
        batch_deadline_us: 0,
        ..Default::default()
    };
    let coord = Coordinator::start_native(StuckEngine(Arc::clone(&release)), &serve).unwrap();
    // One in-flight + fill the queue, then overload.
    let _t0 = coord.submit(vec![0.0; 4]).unwrap();
    std::thread::sleep(Duration::from_millis(20)); // let worker pick t0
    let _t1 = coord.submit(vec![0.0; 4]).unwrap();
    let _t2 = coord.submit(vec![0.0; 4]).unwrap();
    let mut saw_overload = false;
    for _ in 0..50 {
        match coord.try_submit(vec![0.0; 4]) {
            Err(SubmitError::Overloaded) => {
                saw_overload = true;
                break;
            }
            Ok(_) => continue,
            Err(e) => panic!("unexpected {e:?}"),
        }
    }
    assert!(saw_overload, "queue never signalled backpressure");
    release.store(true, Ordering::SeqCst);
}

#[test]
fn engine_error_propagates_to_all_waiters() {
    struct FailEngine;
    impl Engine for FailEngine {
        fn input_len(&self) -> usize {
            2
        }
        fn output_len(&self) -> usize {
            2
        }
        fn infer(&self, _x: &[f32], _batch: usize) -> anyhow::Result<Vec<f32>> {
            anyhow::bail!("numerical explosion")
        }
        fn name(&self) -> String {
            "fail".into()
        }
    }
    let serve = ServeConfig {
        max_batch: 4,
        batch_deadline_us: 5_000,
        ..Default::default()
    };
    let coord = Coordinator::start_native(FailEngine, &serve).unwrap();
    let t1 = coord.submit(vec![0.0; 2]).unwrap();
    let t2 = coord.submit(vec![0.0; 2]).unwrap();
    for t in [t1, t2] {
        let err = t.wait().unwrap_err();
        assert!(matches!(err, ServeError::Engine(_)), "{err:?}");
        assert!(err.to_string().contains("numerical explosion"), "{err}");
    }
    let stats = coord.shutdown();
    assert_eq!(stats.failed, 2);
    assert_eq!(stats.completed, 0);
}

/// Regression for the client-hang bug: a worker that panics mid-batch
/// must complete every in-flight slot with a typed `WorkerLost` error —
/// `wait_timeout` returns the error, never times out to `None`. Without
/// a respawn factory (start_native) the dying worker was the last one,
/// so it also closes the queue and drains it: later submissions fail
/// fast instead of queueing forever.
#[test]
fn worker_panic_completes_waiters_with_worker_lost() {
    struct PanicEngine;
    impl Engine for PanicEngine {
        fn input_len(&self) -> usize {
            2
        }
        fn output_len(&self) -> usize {
            2
        }
        fn infer(&self, _x: &[f32], _batch: usize) -> anyhow::Result<Vec<f32>> {
            panic!("engine exploded mid-batch")
        }
        fn name(&self) -> String {
            "panic".into()
        }
    }
    let serve = ServeConfig {
        max_batch: 4,
        batch_deadline_us: 5_000,
        ..Default::default()
    };
    let coord = Coordinator::start_native(PanicEngine, &serve).unwrap();
    let t1 = coord.submit(vec![0.0; 2]).unwrap();
    let t2 = coord.submit(vec![1.0; 2]).unwrap();
    for t in [t1, t2] {
        let resp = t
            .wait_timeout(Duration::from_secs(5))
            .expect("panicked worker leaked a waiter (wait_timeout returned None)");
        assert_eq!(resp.unwrap_err(), ServeError::Shed(Shed::WorkerLost));
    }
    // The pool is fully dead: admission fails fast, nothing hangs.
    let mut saw_terminal_submit = false;
    for _ in 0..200 {
        match coord.submit(vec![0.0; 2]) {
            Err(SubmitError::Closed) => {
                saw_terminal_submit = true;
                break;
            }
            Err(e) => panic!("unexpected {e:?}"),
            Ok(t) => {
                // Raced a submit in before the dying worker closed the
                // queue — it must still reach a terminal state.
                let resp = t.wait_timeout(Duration::from_secs(5)).expect("leaked waiter");
                assert!(resp.is_err());
            }
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    assert!(saw_terminal_submit, "queue never closed after last worker died");
    let stats = coord.stats();
    assert!(stats.worker_panics >= 1);
    assert_eq!(stats.live_workers, 0);
    assert!(stats.worker_lost >= 2, "stats: {stats:?}");
}

/// Supervised restart: a worker that panics once is replaced with a
/// fresh engine (re-running warm-up) within the restart budget, and the
/// coordinator keeps serving.
#[test]
fn supervisor_restarts_panicked_worker() {
    #[derive(Clone)]
    struct PanicOnce {
        armed: Arc<AtomicBool>,
        warmups: Arc<AtomicUsize>,
    }
    impl Engine for PanicOnce {
        fn input_len(&self) -> usize {
            2
        }
        fn output_len(&self) -> usize {
            2
        }
        fn infer(&self, x: &[f32], _batch: usize) -> anyhow::Result<Vec<f32>> {
            if self.armed.swap(false, Ordering::SeqCst) {
                panic!("injected engine crash");
            }
            Ok(x.to_vec())
        }
        fn warmup(&mut self, _buckets: &[usize]) -> anyhow::Result<()> {
            self.warmups.fetch_add(1, Ordering::SeqCst);
            Ok(())
        }
        fn name(&self) -> String {
            "panic-once".into()
        }
    }
    let armed = Arc::new(AtomicBool::new(true));
    let warmups = Arc::new(AtomicUsize::new(0));
    let serve = ServeConfig {
        max_batch: 1,
        batch_deadline_us: 0,
        workers: 1,
        restart_budget: 3,
        restart_backoff_ms: 1,
        ..Default::default()
    };
    let coord = Coordinator::start_replicated(
        PanicOnce {
            armed: Arc::clone(&armed),
            warmups: Arc::clone(&warmups),
        },
        &serve,
    )
    .unwrap();
    assert_eq!(warmups.load(Ordering::SeqCst), 1, "startup warm-up");

    // First request trips the panic → typed WorkerLost, not a hang.
    let t = coord.submit(vec![0.0; 2]).unwrap();
    let resp = t.wait_timeout(Duration::from_secs(5)).expect("leaked waiter");
    assert_eq!(resp.unwrap_err(), ServeError::Shed(Shed::WorkerLost));

    // The supervisor restarted the worker with a fresh engine — serving
    // continues on the same coordinator.
    let y = coord.infer(vec![3.0, 4.0]).unwrap();
    assert_eq!(y, vec![3.0, 4.0]);
    // The restarted worker is live again (workers decrement the count as
    // they exit during shutdown, so sample before).
    assert_eq!(coord.stats().live_workers, 1);
    let stats = coord.shutdown();
    assert_eq!(stats.worker_panics, 1);
    assert_eq!(stats.worker_restarts, 1);
    assert!(
        warmups.load(Ordering::SeqCst) >= 2,
        "restart must re-run warm-up"
    );
}

/// Restart-budget exhaustion: an engine that always panics burns its
/// budget, the pool degrades to zero workers, and every ticket obtained
/// along the way still reaches a terminal state — nobody hangs.
#[test]
fn restart_budget_exhaustion_degrades_without_hang() {
    #[derive(Clone)]
    struct AlwaysPanic;
    impl Engine for AlwaysPanic {
        fn input_len(&self) -> usize {
            2
        }
        fn output_len(&self) -> usize {
            2
        }
        fn infer(&self, _x: &[f32], _batch: usize) -> anyhow::Result<Vec<f32>> {
            panic!("chronically broken engine")
        }
        fn name(&self) -> String {
            "always-panic".into()
        }
    }
    let serve = ServeConfig {
        max_batch: 2,
        batch_deadline_us: 0,
        workers: 1,
        restart_budget: 2,
        restart_backoff_ms: 1,
        ..Default::default()
    };
    let coord = Coordinator::start_replicated(AlwaysPanic, &serve).unwrap();
    let mut tickets = Vec::new();
    let mut closed = false;
    for _ in 0..500 {
        match coord.submit(vec![0.0; 2]) {
            Ok(t) => tickets.push(t),
            Err(SubmitError::Closed) => {
                closed = true;
                break;
            }
            Err(e) => panic!("unexpected {e:?}"),
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    assert!(closed, "pool never closed after exhausting its restart budget");
    assert!(!tickets.is_empty());
    for t in tickets {
        let resp = t.wait_timeout(Duration::from_secs(5)).expect("leaked waiter");
        assert_eq!(resp.unwrap_err(), ServeError::Shed(Shed::WorkerLost));
    }
    let stats = coord.stats();
    // 1 initial run + up to 2 restarts, each ending in a panic.
    assert_eq!(stats.worker_restarts, 2);
    assert_eq!(stats.worker_panics, 3);
    assert_eq!(stats.live_workers, 0);
}

/// Deadline propagation: a request whose TTL expires while an earlier
/// request occupies the worker is shed with a typed error before any
/// compute is spent on it.
#[test]
fn expired_requests_shed_before_compute() {
    struct SlowEngine(Arc<AtomicUsize>);
    impl Engine for SlowEngine {
        fn input_len(&self) -> usize {
            2
        }
        fn output_len(&self) -> usize {
            2
        }
        fn infer(&self, x: &[f32], _batch: usize) -> anyhow::Result<Vec<f32>> {
            self.0.fetch_add(1, Ordering::SeqCst);
            std::thread::sleep(Duration::from_millis(50));
            Ok(x.to_vec())
        }
        fn name(&self) -> String {
            "slow".into()
        }
    }
    let infers = Arc::new(AtomicUsize::new(0));
    let serve = ServeConfig {
        max_batch: 1, // one request per batch: r2 waits for r1's compute
        batch_deadline_us: 0,
        ..Default::default()
    };
    let coord = Coordinator::start_native(SlowEngine(Arc::clone(&infers)), &serve).unwrap();
    let t1 = coord.submit(vec![1.0; 2]).unwrap();
    std::thread::sleep(Duration::from_millis(10)); // worker picked t1
    let t2 = coord
        .submit_with_ttl(vec![2.0; 2], Some(Duration::from_millis(1)))
        .unwrap();
    assert_eq!(t1.wait().unwrap(), vec![1.0; 2]);
    let resp = t2.wait_timeout(Duration::from_secs(5)).expect("leaked waiter");
    assert_eq!(resp.unwrap_err(), ServeError::Shed(Shed::DeadlineExpired));
    let stats = coord.shutdown();
    assert_eq!(stats.shed_deadline, 1);
    assert_eq!(stats.completed, 1);
    assert_eq!(
        infers.load(Ordering::SeqCst),
        1,
        "expired request must not reach the engine"
    );
}

/// Graceful drain: shutdown runs every queued request to a terminal
/// state (here: completion — the workers are healthy) and records the
/// drain latency; the terminal ledger balances.
#[test]
fn shutdown_drains_queued_requests_to_terminal_states() {
    let serve = ServeConfig {
        max_batch: 4,
        batch_deadline_us: 200,
        workers: 2,
        ..Default::default()
    };
    let coord = Coordinator::start_replicated(IdEngine, &serve).unwrap();
    let tickets: Vec<_> = (0..40)
        .map(|i| coord.submit(vec![i as f32; 4]).unwrap())
        .collect();
    let stats = coord.shutdown();
    assert_eq!(stats.submitted, 40);
    assert_eq!(
        stats.terminal(),
        40,
        "drain left non-terminal requests: {stats:?}"
    );
    for t in tickets {
        let resp = t
            .wait_timeout(Duration::from_secs(1))
            .expect("shutdown leaked a waiter");
        assert!(resp.is_ok(), "healthy drain must complete requests");
    }
}

#[test]
fn factory_error_fails_start() {
    let serve = ServeConfig::default();
    let res = Coordinator::start(Box::new(|| anyhow::bail!("no artifacts here")), &serve);
    assert!(res.is_err());
    assert!(res.err().unwrap().to_string().contains("no artifacts"));
}

#[test]
fn factory_error_fails_start_multi_and_tears_down() {
    let serve = ServeConfig::default();
    let factories: Vec<swsnn::coordinator::EngineFactory> = vec![
        Box::new(|| {
            Ok(Box::new(IdEngine) as Box<dyn Engine>)
        }),
        Box::new(|| anyhow::bail!("second engine exploded")),
    ];
    let res = Coordinator::start_multi(factories, &serve);
    let err = res.err().expect("must fail").to_string();
    assert!(err.contains("second engine exploded"), "{err}");
}

#[test]
fn mismatched_engine_shapes_fail_start_multi() {
    struct WideEngine;
    impl Engine for WideEngine {
        fn input_len(&self) -> usize {
            8
        }
        fn output_len(&self) -> usize {
            8
        }
        fn infer(&self, x: &[f32], _batch: usize) -> anyhow::Result<Vec<f32>> {
            Ok(x.to_vec())
        }
        fn name(&self) -> String {
            "wide".into()
        }
    }
    let serve = ServeConfig::default();
    let factories: Vec<swsnn::coordinator::EngineFactory> = vec![
        Box::new(|| Ok(Box::new(IdEngine) as Box<dyn Engine>)),
        Box::new(|| Ok(Box::new(WideEngine) as Box<dyn Engine>)),
    ];
    let err = Coordinator::start_multi(factories, &serve)
        .err()
        .expect("shape mismatch must fail startup")
        .to_string();
    assert!(err.contains("shape mismatch"), "{err}");
}

/// Identity engine used by the multi-worker tests.
#[derive(Clone)]
struct IdEngine;

impl Engine for IdEngine {
    fn input_len(&self) -> usize {
        4
    }
    fn output_len(&self) -> usize {
        4
    }
    fn infer(&self, x: &[f32], _batch: usize) -> anyhow::Result<Vec<f32>> {
        Ok(x.iter().map(|v| v * 2.0 + 1.0).collect())
    }
    fn name(&self) -> String {
        "affine".into()
    }
}

/// N workers drain a burst without dropping or duplicating tickets:
/// every response must be the transform of *its own* request, and the
/// completion count must match exactly.
#[test]
fn multi_worker_pool_drains_burst_without_loss() {
    let serve = ServeConfig {
        max_batch: 4,
        batch_deadline_us: 200,
        workers: 4,
        queue_capacity: 512,
        ..Default::default()
    };
    let coord = Coordinator::start_replicated(IdEngine, &serve).unwrap();
    assert_eq!(coord.worker_count(), 4);

    let inputs: Vec<Vec<f32>> = (0..200)
        .map(|i| vec![i as f32, i as f32 + 0.25, -(i as f32), 0.5])
        .collect();
    let tickets: Vec<_> = inputs
        .iter()
        .map(|x| coord.submit(x.clone()).unwrap())
        .collect();
    for (x, t) in inputs.iter().zip(tickets) {
        let y = t.wait().unwrap();
        assert_eq!(y.len(), 4);
        for (a, b) in y.iter().zip(x) {
            assert_eq!(*a, b * 2.0 + 1.0, "response routed to wrong request");
        }
    }
    let stats = coord.shutdown();
    assert_eq!(stats.submitted, 200);
    assert_eq!(stats.completed, 200, "burst dropped or duplicated tickets");
    assert_eq!(stats.rejected, 0);
}

/// Concurrent clients against N workers: with several engines draining,
/// a long-deadline burst still completes exactly once per request.
#[test]
fn multi_worker_concurrent_clients() {
    let serve = ServeConfig {
        max_batch: 8,
        batch_deadline_us: 2_000,
        workers: 3,
        ..Default::default()
    };
    let coord = Arc::new(Coordinator::start_replicated(IdEngine, &serve).unwrap());
    let mut handles = Vec::new();
    for c in 0..6u64 {
        let coord = Arc::clone(&coord);
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(500 + c);
            for _ in 0..25 {
                let x = rng.vec_uniform(4, -1.0, 1.0);
                let y = coord.infer(x.clone()).unwrap();
                for (a, b) in y.iter().zip(&x) {
                    assert_eq!(*a, b * 2.0 + 1.0);
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let stats = coord.stats();
    assert_eq!(stats.completed, 150);
    assert_eq!(stats.rejected, 0);
}

#[test]
fn tcp_roundtrip_and_error_frames() {
    let coord = Arc::new(native_coordinator(&ServeConfig::default()));
    let stop = Arc::new(AtomicBool::new(false));
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();
    let server = {
        let coord = Arc::clone(&coord);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            serve_tcp(coord, "127.0.0.1:0", stop, move |addr| {
                addr_tx.send(addr).unwrap();
            })
            .unwrap();
        })
    };
    let addr = addr_rx.recv_timeout(Duration::from_secs(5)).unwrap();
    let mut client = TcpClient::connect(addr).unwrap();
    let mut rng = Rng::new(9);
    let out = client.infer(&rng.vec_uniform(32, -1.0, 1.0)).unwrap();
    assert_eq!(out.len(), 32);
    // Wrong shape → server-side error frame, connection stays usable.
    let err = client.infer(&[1.0, 2.0]).unwrap_err();
    assert!(err.to_string().contains("bad input shape"), "{err}");
    let out2 = client.infer(&rng.vec_uniform(32, -1.0, 1.0)).unwrap();
    assert_eq!(out2.len(), 32);

    stop.store(true, Ordering::SeqCst);
    drop(client);
    server.join().unwrap();
}

/// The stats wire frame reports the same ledger the coordinator holds
/// in memory, plus live transport counters.
#[test]
fn tcp_stats_frame_matches_coordinator_ledger() {
    let coord = Arc::new(native_coordinator(&ServeConfig::default()));
    let stop = Arc::new(AtomicBool::new(false));
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();
    let server = {
        let coord = Arc::clone(&coord);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            serve_tcp(coord, "127.0.0.1:0", stop, move |addr| {
                addr_tx.send(addr).unwrap();
            })
            .unwrap();
        })
    };
    let addr = addr_rx.recv_timeout(Duration::from_secs(5)).unwrap();
    let mut client = TcpClient::connect(addr).unwrap();
    let mut rng = Rng::new(17);
    for _ in 0..7 {
        client.infer(&rng.vec_uniform(32, -1.0, 1.0)).unwrap();
    }
    let map = client.stats_map().unwrap();
    let direct = coord.stats();
    assert_eq!(map["submitted"] as u64, direct.submitted);
    assert_eq!(map["completed"] as u64, direct.completed);
    assert_eq!(map["completed"] as u64, 7);
    assert_eq!(map["conns_accepted"] as u64, 1);
    assert!(map["conns_open"] >= 1.0, "this connection is open");
    assert_eq!(map["decode_errors"] as u64, 0);
    assert!(map["wire_frames"] as u64 >= 7, "data frames are metered");

    stop.store(true, Ordering::SeqCst);
    drop(client);
    server.join().unwrap();
}

/// A connection idle past the transport idle timeout is closed by the
/// server (quietly — boundary idleness is not a decode error); new
/// connections are unaffected.
#[test]
fn tcp_idle_connection_is_closed_after_timeout() {
    let coord = Arc::new(native_coordinator(&ServeConfig::default()));
    let stop = Arc::new(AtomicBool::new(false));
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();
    let tcfg = TransportConfig {
        idle_timeout: Duration::from_millis(150),
        ..Default::default()
    };
    let server = {
        let coord = Arc::clone(&coord);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            serve_tcp_with(coord, "127.0.0.1:0", tcfg, stop, move |addr| {
                addr_tx.send(addr).unwrap();
            })
            .unwrap();
        })
    };
    let addr = addr_rx.recv_timeout(Duration::from_secs(5)).unwrap();
    let mut rng = Rng::new(23);
    let mut idler = TcpClient::connect(addr).unwrap();
    idler.infer(&rng.vec_uniform(32, -1.0, 1.0)).unwrap();
    // Sit idle well past the timeout: the server hangs up.
    std::thread::sleep(Duration::from_millis(500));
    assert!(
        idler.infer(&rng.vec_uniform(32, -1.0, 1.0)).is_err(),
        "idle connection should have been closed by the server"
    );
    drop(idler);
    // The listener still serves fresh connections, and the idle close
    // was not miscounted as a protocol abuse.
    let mut client = TcpClient::connect(addr).unwrap();
    client.infer(&rng.vec_uniform(32, -1.0, 1.0)).unwrap();
    let map = client.stats_map().unwrap();
    assert_eq!(map["decode_errors"] as u64, 0);

    stop.store(true, Ordering::SeqCst);
    drop(client);
    server.join().unwrap();
}

#[test]
fn pjrt_engine_serves_requests() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.is_dir() {
        eprintln!("skipping: artifacts/ missing");
        return;
    }
    let serve = ServeConfig {
        max_batch: 8,
        batch_deadline_us: 3_000,
        ..Default::default()
    };
    let dir2 = dir.clone();
    let coord = Coordinator::start(
        Box::new(move || Ok(Box::new(PjrtTcnEngine::from_artifacts(dir2, 42)?) as _)),
        &serve,
    )
    .unwrap();
    assert!(coord.engine_name().starts_with("pjrt/"));
    assert_eq!(coord.input_len(), 512);

    let done = Arc::new(AtomicUsize::new(0));
    let coord = Arc::new(coord);
    let mut handles = Vec::new();
    for i in 0..12u64 {
        let c = Arc::clone(&coord);
        let d = Arc::clone(&done);
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(1000 + i);
            let out = c.infer(rng.vec_uniform(512, -1.0, 1.0)).unwrap();
            assert_eq!(out.len(), 512);
            assert!(out.iter().all(|v| v.is_finite()));
            d.fetch_add(1, Ordering::SeqCst);
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(done.load(Ordering::SeqCst), 12);
    let stats = coord.stats();
    assert_eq!(stats.completed, 12);
}
