//! Streaming stateful sessions (`nn::session`), end to end:
//!
//! 1. **Property-tested parity**: `Session::step_into` over random
//!    chain-only models (kernel sizes × strides × dilations × padding ×
//!    pool interleavings) × arbitrary packet splits × mid-stream resets
//!    is bit-identical to `forward_eager_into` on the full history, and
//!    to the fused batch plan across thread counts {1, 2, 4, 8}.
//! 2. Forced SIMD tiers on `configs/tcn_stream.toml`: the streamed
//!    output stays bit-identical to eager under every supported tier
//!    (single `#[test]` — the tier override is process-global).
//! 3. **Steady-state counters**: once a session is open, stepping does
//!    zero slab growths and zero plan compiles (`NativeEngine` counter
//!    asserts — the acceptance criterion for O(1) amortized work).
//! 4. Serving integration: coordinator open/step/close round-trip with
//!    `CoordinatorStats` session counters, idle-TTL eviction shedding as
//!    `Shed::DeadlineExpired`, session-capacity admission, and the TCP
//!    wire frames via `TcpClient::session_{open,step,close}`.

use std::cell::{Cell, RefCell};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use swsnn::config::{load_config, LayerConfig, ModelConfig, ServeConfig};
use swsnn::conv::{BackendChoice, ConvBackend};
use swsnn::coordinator::{
    serve_tcp, Coordinator, Engine, NativeEngine, ServeError, Shed, TcpClient, Ticket,
};
use swsnn::exec::Executor;
use swsnn::nn::{EagerScratch, Model, Plan, PlanScratch, PlannerConfig, Session};
use swsnn::prop::{check, ensure, PropConfig};
use swsnn::simd::{self, SimdTier};
use swsnn::workload::Rng;

/// Planar [c, n] eager forward of the full input — the oracle every
/// streamed emission must match bit-for-bit.
fn oracle(model: &Model, planar: &[f32], scratch: &mut EagerScratch) -> Vec<f32> {
    let mut out = Vec::new();
    model
        .forward_eager_into(planar, 1, ConvBackend::Sliding, scratch, &mut out)
        .unwrap();
    out
}

/// Interleave planar [c, n] to the session wire order [t, c].
fn interleave(planar: &[f32], c: usize) -> Vec<f32> {
    let n = planar.len() / c;
    let mut out = vec![0.0; planar.len()];
    for t in 0..n {
        for ch in 0..c {
            out[t * c + ch] = planar[ch * n + t];
        }
    }
    out
}

/// Drive one session over `stream` with the given per-packet sample
/// counts, asserting the `pending_out_samples` prediction and the
/// zero-growth contract on every step. Returns the concatenated [t, c]
/// emissions.
fn stream_session(
    sess: &mut Session,
    model: &Model,
    stream: &[f32],
    splits: &[usize],
) -> Vec<f32> {
    let c_in = sess.spec().in_channels();
    let c_out = sess.spec().out_channels();
    let grows = sess.grows();
    let mut dst = vec![f32::NAN; sess.spec().out_len() * c_out];
    let mut got = Vec::new();
    let mut off = 0usize;
    for &take in splits {
        let chunk = &stream[off * c_in..(off + take) * c_in];
        off += take;
        let predicted = sess.pending_out_samples(take);
        let r = sess.step_into(model, chunk, &mut dst).unwrap();
        assert_eq!(r, predicted, "pending_out_samples mispredicted the emit count");
        got.extend_from_slice(&dst[..r * c_out]);
    }
    assert_eq!(sess.grows(), grows, "a steady-state step grew the slab");
    got
}

/// Random chain-only stack: sliding convs (strided / dilated / padded)
/// and non-overlapping pools — every layer streamable, so the whole
/// model compiles to one fused chain a session can capture.
fn random_stream_config(g: &mut swsnn::prop::Gen, idx: usize) -> ModelConfig {
    let c_in = 1 + g.usize_in(0, 3);
    let seq_len = 40 + g.usize_in(0, 120);
    let n_layers = 1 + g.usize_in(0, 4);
    let mut layers = Vec::new();
    for _ in 0..n_layers {
        if g.usize_in(0, 4) == 0 {
            let w = 2 + g.usize_in(0, 2);
            layers.push(LayerConfig::Pool {
                kind: ["max", "avg", "min"][g.usize_in(0, 3)].to_string(),
                w,
                stride: w + g.usize_in(0, 2),
            });
        } else {
            layers.push(LayerConfig::Conv {
                c_out: 1 + g.usize_in(0, 5),
                k: [1, 2, 3, 5, 7, 9][g.usize_in(0, 6)],
                stride: 1 + g.usize_in(0, 2),
                dilation: 1 + g.usize_in(0, 2),
                same_pad: g.usize_in(0, 3) == 0,
                relu: g.bool(),
                backend: None,
                quantize: false,
            });
        }
    }
    ModelConfig {
        name: format!("stream{idx}"),
        c_in,
        seq_len,
        layers,
    }
}

#[test]
fn prop_session_step_into_matches_full_forward() {
    let eager_scratch = RefCell::new(EagerScratch::default());
    let plan_scratch = RefCell::new(PlanScratch::default());
    let case = Cell::new(0usize);
    check(
        PropConfig {
            cases: 30,
            ..Default::default()
        },
        "session step_into ≡ eager forward on the full history",
        |g| {
            let idx = case.get();
            case.set(idx + 1);
            let mc = random_stream_config(g, idx);
            let seed = g.rng.next_u64();
            let Ok(model) = Model::init(&mc, &mut Rng::new(seed)) else {
                return Ok(()); // generator produced a collapsing shape
            };
            let (c_out, n_out) = model.out_shape();
            if n_out == 0 {
                return Ok(());
            }
            let cfg = PlannerConfig {
                backend: BackendChoice::Fixed(ConvBackend::Sliding),
                ..PlannerConfig::default()
            };
            let plan = Plan::compile(&model, 1, &cfg).map_err(|e| e.to_string())?;
            let planar = Rng::new(seed ^ 0xc0de).vec_uniform(mc.c_in * mc.seq_len, -1.0, 1.0);
            let stream = interleave(&planar, mc.c_in);
            let want = interleave(
                &oracle(&model, &planar, &mut eager_scratch.borrow_mut()),
                c_out,
            );

            let mut sess = Session::open(&plan, &model).map_err(|e| e.to_string())?;

            // Mid-stream reset: absorb a junk prefix, rewind, and the
            // replay below must still match the oracle bit-for-bit.
            if g.bool() {
                let junk = 1 + g.usize_in(0, mc.seq_len - 1);
                let mut sink = vec![0.0f32; n_out * c_out];
                sess.step_into(&model, &stream[..junk * mc.c_in], &mut sink)
                    .map_err(|e| e.to_string())?;
                sess.reset();
                ensure(sess.samples_seen() == 0, "reset kept samples_seen")?;
            }

            // Arbitrary packet splits covering the whole stream.
            let mut splits = Vec::new();
            let mut left = mc.seq_len;
            while left > 0 {
                let take = (1 + g.usize_in(0, 9)).min(left);
                splits.push(take);
                left -= take;
            }
            let got = stream_session(&mut sess, &model, &stream, &splits);
            ensure(sess.finished(), "full stream did not finish the session")?;
            ensure(
                got.len() == want.len(),
                format!("emitted {} floats, oracle has {}", got.len(), want.len()),
            )?;
            for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                ensure(
                    a.to_bits() == b.to_bits(),
                    format!("{}: output {i}: {a} vs {b} (splits {splits:?})", mc.name),
                )?;
            }

            // The fused batch plan under a random thread count agrees
            // with the same bits — the session is exactly the chain.
            let threads = *g.choose(&[1usize, 2, 4, 8]);
            let ex = Executor::new(threads);
            let mut batch = Vec::new();
            plan.run_with_into(&ex, &model, &planar, &mut plan_scratch.borrow_mut(), &mut batch)
                .map_err(|e| e.to_string())?;
            ensure(
                interleave(&batch, c_out) == got,
                format!("{}: fused plan (threads {threads}) != session", mc.name),
            )
        },
    );
}

/// The SIMD tiers worth forcing on this host: the portable oracle plus
/// whatever the hardware actually dispatches.
fn tiers() -> Vec<SimdTier> {
    let mut ts = vec![SimdTier::Generic];
    for t in [SimdTier::Avx2, SimdTier::Sse2, SimdTier::Neon] {
        if t.is_supported() {
            ts.push(t);
        }
    }
    ts
}

fn load_stream_model(seed: u64) -> (ModelConfig, ServeConfig, Model) {
    let text = std::fs::read_to_string(
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs/tcn_stream.toml"),
    )
    .unwrap();
    let (mc, serve) = load_config(&text).unwrap();
    let model = Model::init(&mc, &mut Rng::new(seed)).unwrap();
    (mc, serve, model)
}

/// Forced SIMD tiers × thread counts on the shipped streaming config:
/// the kernels under the chain sweep change with the tier, the streamed
/// bits must not.
#[test]
fn session_parity_under_forced_tiers_and_threads() {
    let (mc, _, model) = load_stream_model(11);
    let (c_out, _) = model.out_shape();
    let cfg = PlannerConfig {
        backend: BackendChoice::Fixed(ConvBackend::Sliding),
        ..PlannerConfig::default()
    };
    let plan = Plan::compile(&model, 1, &cfg).unwrap();
    let planar = Rng::new(12).vec_uniform(mc.c_in * mc.seq_len, -1.0, 1.0);
    let stream = interleave(&planar, mc.c_in);
    let splits: Vec<usize> = {
        let mut v = Vec::new();
        let (mut left, mut k) = (mc.seq_len, 1usize);
        while left > 0 {
            let take = k.min(left);
            v.push(take);
            left -= take;
            k = k % 11 + 1;
        }
        v
    };
    let mut plan_scratch = PlanScratch::default();
    for tier in tiers() {
        simd::force_tier(Some(tier));
        let mut eager_scratch = EagerScratch::default();
        let want = interleave(&oracle(&model, &planar, &mut eager_scratch), c_out);
        let mut sess = Session::open(&plan, &model).unwrap();
        let got = stream_session(&mut sess, &model, &stream, &splits);
        assert_eq!(got.len(), want.len(), "{tier:?}");
        for (i, (a, b)) in got.iter().zip(&want).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{tier:?} output {i}: {a} vs {b}");
        }
        for threads in [1usize, 2, 4, 8] {
            let ex = Executor::new(threads);
            let mut batch = Vec::new();
            plan.run_with_into(&ex, &model, &planar, &mut plan_scratch, &mut batch)
                .unwrap();
            assert_eq!(
                interleave(&batch, c_out),
                got,
                "{tier:?} threads={threads}: fused plan != session"
            );
        }
    }
    simd::force_tier(None);
}

/// Acceptance criterion: steady-state session steps do zero allocations
/// (slab `grows` flat) and zero plan compiles (`NativeEngine` counter
/// flat) — open pays the one-time cost, stepping never does.
#[test]
fn steady_state_steps_allocate_nothing_and_compile_nothing() {
    let (mc, _, model) = load_stream_model(13);
    let reference = {
        let m = Model::init(&mc, &mut Rng::new(13)).unwrap(); // same seed → same params
        let mut scratch = EagerScratch::default();
        let planar = Rng::new(14).vec_uniform(mc.c_in * mc.seq_len, -1.0, 1.0);
        let want = interleave(&oracle(&m, &planar, &mut scratch), m.out_shape().0);
        (planar, want)
    };
    let mut engine =
        NativeEngine::with_choice(model, BackendChoice::Fixed(ConvBackend::Sliding), 8);
    let id = engine.session_open().unwrap();
    assert_eq!(engine.plan_compiles(), 1, "open compiles the batch-1 plan once");
    assert_eq!(engine.live_sessions(), 1);

    let stream = interleave(&reference.0, mc.c_in);
    let compiles = engine.plan_compiles();
    let grows = engine.session_grows();
    let mut got = Vec::new();
    let mut out = Vec::new();
    for chunk in stream.chunks(6 * mc.c_in) {
        engine.session_step(id, chunk, &mut out).unwrap();
        got.extend_from_slice(&out);
        assert_eq!(engine.plan_compiles(), compiles, "a step compiled a plan");
        assert_eq!(engine.session_grows(), grows, "a step grew the session slab");
    }
    let want = &reference.1;
    assert_eq!(got.len(), want.len());
    for (i, (a, b)) in got.iter().zip(want).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "output {i}: {a} vs {b}");
    }
    engine.session_close(id).unwrap();
    assert_eq!(engine.live_sessions(), 0);
    assert!(
        engine.session_close(id).is_err(),
        "closing a closed session must fail"
    );
}

fn wait(t: Ticket) -> Result<Vec<f32>, ServeError> {
    t.wait_timeout(Duration::from_secs(10)).expect("leaked waiter")
}

/// Coordinator round-trip: open/step/close through the batcher, with
/// session counters in `CoordinatorStats` and bit-parity against eager.
#[test]
fn coordinator_sessions_roundtrip_with_counters() {
    let (mc, serve, model) = load_stream_model(15);
    let reference = Model::init(&mc, &mut Rng::new(15)).unwrap();
    let engine = NativeEngine::with_choice(model, BackendChoice::Fixed(ConvBackend::Sliding), 8);
    let coord = Coordinator::start_native(engine, &serve).unwrap();

    let sid = wait(coord.open_session(0).unwrap()).unwrap()[0].to_bits();
    let planar = Rng::new(16).vec_uniform(mc.c_in * mc.seq_len, -1.0, 1.0);
    let stream = interleave(&planar, mc.c_in);
    let mut scratch = EagerScratch::default();
    let want = interleave(&oracle(&reference, &planar, &mut scratch), reference.out_shape().0);
    let mut got = Vec::new();
    let mut steps = 0u64;
    for chunk in stream.chunks(10 * mc.c_in) {
        got.extend(wait(coord.step_session(sid, chunk.to_vec()).unwrap()).unwrap());
        steps += 1;
    }
    assert_eq!(got.len(), want.len());
    for (i, (a, b)) in got.iter().zip(&want).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "output {i}: {a} vs {b}");
    }

    // Stepping an unknown id is a typed engine failure, not a hang.
    match wait(coord.step_session(sid + 1, vec![0.0; mc.c_in]).unwrap()) {
        Err(ServeError::Engine(msg)) => assert!(msg.contains("unknown session"), "{msg}"),
        other => panic!("unknown-id step returned {other:?}"),
    }
    wait(coord.close_session(sid).unwrap()).unwrap();
    match wait(coord.step_session(sid, vec![0.0; mc.c_in]).unwrap()) {
        Err(ServeError::Engine(msg)) => assert!(msg.contains("unknown session"), "{msg}"),
        other => panic!("closed-id step returned {other:?}"),
    }

    let stats = coord.shutdown();
    assert_eq!(stats.sessions_opened, 1);
    assert_eq!(stats.sessions_closed, 1);
    assert_eq!(stats.session_steps, steps);
    assert_eq!(stats.sessions_evicted, 0);
    assert_eq!(stats.failed, 2, "the two bad-id steps");
    assert_eq!(stats.terminal(), stats.submitted, "ledger must balance");
}

/// Idle sessions ride the shed taxonomy: a step arriving after the TTL
/// sheds as `DeadlineExpired`, the slot is evicted, and the wire id is
/// dead from then on.
#[test]
fn idle_session_ttl_evicts_and_sheds() {
    let (mc, serve, model) = load_stream_model(17);
    let engine = NativeEngine::with_choice(model, BackendChoice::Fixed(ConvBackend::Sliding), 8);
    let coord = Coordinator::start_native(engine, &serve).unwrap();

    let sid = wait(coord.open_session(500).unwrap()).unwrap()[0].to_bits();
    // A prompt step lands inside the TTL and refreshes it.
    wait(coord.step_session(sid, vec![0.25; 4 * mc.c_in]).unwrap()).unwrap();
    std::thread::sleep(Duration::from_millis(1_500));
    match wait(coord.step_session(sid, vec![0.25; mc.c_in]).unwrap()) {
        Err(ServeError::Shed(Shed::DeadlineExpired)) => {}
        other => panic!("expired step returned {other:?}"),
    }
    match wait(coord.step_session(sid, vec![0.25; mc.c_in]).unwrap()) {
        Err(ServeError::Engine(msg)) => assert!(msg.contains("unknown session"), "{msg}"),
        other => panic!("evicted-id step returned {other:?}"),
    }
    let stats = coord.shutdown();
    assert_eq!(stats.sessions_evicted, 1);
    assert_eq!(stats.shed_deadline, 1);
    assert_eq!(stats.terminal(), stats.submitted, "ledger must balance");
}

/// `serve.session_capacity` bounds live slots per worker; opens past
/// the cap fail typed, and closing frees a slot for the next open.
#[test]
fn session_capacity_bounds_live_sessions() {
    let (_, _, model) = load_stream_model(19);
    let serve = ServeConfig {
        session_capacity: 1,
        ..Default::default()
    };
    let engine = NativeEngine::with_choice(model, BackendChoice::Fixed(ConvBackend::Sliding), 8);
    let coord = Coordinator::start_native(engine, &serve).unwrap();
    let sid = wait(coord.open_session(0).unwrap()).unwrap()[0].to_bits();
    match wait(coord.open_session(0).unwrap()) {
        Err(ServeError::Engine(msg)) => assert!(msg.contains("session capacity"), "{msg}"),
        other => panic!("over-capacity open returned {other:?}"),
    }
    wait(coord.close_session(sid).unwrap()).unwrap();
    let sid2 = wait(coord.open_session(0).unwrap()).unwrap()[0].to_bits();
    assert_ne!(sid, sid2, "wire ids are never reused");
    let stats = coord.shutdown();
    assert_eq!(stats.sessions_opened, 2);
    assert_eq!(stats.failed, 1);
    assert_eq!(stats.terminal(), stats.submitted, "ledger must balance");
}

/// The TCP wire frames: open (ttl'd), step packets bit-identical to
/// eager, error frames for bad ids, close — on one connection.
#[test]
fn tcp_session_frames_roundtrip() {
    let (mc, serve, model) = load_stream_model(21);
    let reference = Model::init(&mc, &mut Rng::new(21)).unwrap();
    let engine = NativeEngine::with_choice(model, BackendChoice::Fixed(ConvBackend::Sliding), 8);
    let coord = Arc::new(Coordinator::start_native(engine, &serve).unwrap());
    let stop = Arc::new(AtomicBool::new(false));
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();
    let server = {
        let coord = Arc::clone(&coord);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            serve_tcp(coord, "127.0.0.1:0", stop, move |addr| {
                addr_tx.send(addr).unwrap();
            })
            .unwrap();
        })
    };
    let addr = addr_rx.recv_timeout(Duration::from_secs(5)).unwrap();
    let mut client = TcpClient::connect(addr).unwrap();

    let sid = client.session_open(None).unwrap();
    let planar = Rng::new(22).vec_uniform(mc.c_in * mc.seq_len, -1.0, 1.0);
    let stream = interleave(&planar, mc.c_in);
    let mut scratch = EagerScratch::default();
    let want = interleave(&oracle(&reference, &planar, &mut scratch), reference.out_shape().0);
    let mut got = Vec::new();
    for chunk in stream.chunks(16 * mc.c_in) {
        got.extend(client.session_step(sid, chunk).unwrap());
    }
    assert_eq!(got.len(), want.len());
    for (i, (a, b)) in got.iter().zip(&want).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "output {i}: {a} vs {b}");
    }
    // Bad id → error frame; the connection stays usable.
    let one_sample = vec![0.0f32; mc.c_in];
    let err = client.session_step(sid + 1, &one_sample).unwrap_err();
    assert!(err.to_string().contains("server error"), "{err}");
    client.session_close(sid).unwrap();
    let err = client.session_step(sid, &one_sample).unwrap_err();
    assert!(err.to_string().contains("server error"), "{err}");
    // Plain inference still works on the same connection after session
    // traffic (frame dispatch keeps the two request kinds separate).
    let row = Rng::new(23).vec_uniform(mc.c_in * mc.seq_len, -1.0, 1.0);
    let out = client.infer(&row).unwrap();
    assert_eq!(out.len(), coord.output_len());

    stop.store(true, Ordering::SeqCst);
    drop(client);
    server.join().unwrap();
}
