//! Property tests (mini-harness in `swsnn::prop`): operator laws, the
//! full algorithm family vs the naive oracle under random inputs, conv
//! backend agreement, boundary-mode invariants, and coordinator
//! batching invariants under randomized load.

use swsnn::config::ServeConfig;
use swsnn::conv::{
    conv1d, conv2d_sliding_with, conv2d_sliding_with_into, Conv1dParams, Conv2dParams, ConvBackend,
};
use swsnn::coordinator::{Coordinator, Engine};
use swsnn::exec::Executor;
use swsnn::ops::{
    dot_reference, dot_via_prefix, dot_via_tree_reduce, AddOp, AssocOp, ConvPair, Epilogue, MaxOp,
    MinOp, Pair,
};
use swsnn::pool::{
    minimizer_positions, pool1d, pool1d_naive, sliding_minimum, Pool1dParams, PoolKind,
};
use swsnn::prop::{check, ensure, ensure_close, PropConfig};
use swsnn::sliding::{self, Algo, Boundary};

fn cfg(cases: usize) -> PropConfig {
    PropConfig {
        cases,
        ..Default::default()
    }
}

// ───────────────────────── operator laws ─────────────────────────────

#[test]
fn prop_assoc_ops_identity_and_associativity() {
    check(cfg(200), "monoid laws", |g| {
        let a = g.f32_in(-10.0, 10.0);
        let b = g.f32_in(-10.0, 10.0);
        let c = g.f32_in(-10.0, 10.0);
        // max/min: exact laws
        let max = MaxOp::<f32>::new();
        ensure(max.combine(max.identity(), a) == a, "max identity")?;
        ensure(
            max.combine(a, max.combine(b, c)) == max.combine(max.combine(a, b), c),
            "max assoc",
        )?;
        let min = MinOp::<f32>::new();
        ensure(min.combine(a, min.identity()) == a, "min identity")?;
        // add: identity exact, associativity within FP tolerance
        let add = AddOp::<f32>::new();
        ensure(add.combine(add.identity(), a) == a, "add identity")?;
        ensure_close(
            add.combine(a, add.combine(b, c)),
            add.combine(add.combine(a, b), c),
            1e-5,
            "add assoc",
        )
    });
}

#[test]
fn prop_conv_pair_is_associative_and_noncommutative_in_general() {
    check(cfg(300), "ConvPair laws", |g| {
        let op = ConvPair;
        let mk = |g: &mut swsnn::prop::Gen| {
            Pair::new(g.f32_in(0.25, 4.0), g.f32_in(-3.0, 3.0))
        };
        let a = mk(g);
        let b = mk(g);
        let c = mk(g);
        let lhs = op.combine(a, op.combine(b, c));
        let rhs = op.combine(op.combine(a, b), c);
        ensure_close(lhs.u, rhs.u, 1e-4, "u assoc")?;
        ensure_close(lhs.v, rhs.v, 1e-3, "v assoc")?;
        // identity both sides
        let idl = op.combine(op.identity(), a);
        let idr = op.combine(a, op.identity());
        ensure(idl == a && idr == a, "identity")
    });
}

#[test]
fn prop_dot_product_prefix_formulation() {
    check(cfg(200), "Eq. 5-9 dot product", |g| {
        let m = g.usize_in(1, 48);
        // Mix in exact zeros to exercise the Eq. 5 patch.
        let mut a = g.vec_f32_len(m, -2.0, 2.0);
        for v in a.iter_mut() {
            if g.bool() && g.bool() {
                *v = 0.0;
            }
        }
        let b = g.vec_f32_len(m, -2.0, 2.0);
        let want = dot_reference(&a, &b);
        ensure_close(dot_via_prefix(&a, &b), want, 1e-2, "linear scan")?;
        ensure_close(dot_via_tree_reduce(&a, &b), want, 1e-2, "tree reduce")
    });
}

// ──────────────────── algorithm family invariants ────────────────────

#[test]
fn prop_all_algorithms_match_naive_random_inputs() {
    check(cfg(120), "family vs naive", |g| {
        let n = g.usize_in(1, 180);
        let xs = g.vec_f32_len(n, -5.0, 5.0);
        let w = g.usize_in(1, 20);
        let p = *g.choose(&[8usize, 16, 32, 64]);
        let op = AddOp::<f32>::new();
        let want = sliding::sliding_naive(op, &xs, w);
        for algo in Algo::ALL {
            let got = sliding::run(algo, op, &xs, w, p);
            ensure(
                got.len() == want.len(),
                format!("{algo:?} len {} vs {}", got.len(), want.len()),
            )?;
            for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                ensure_close(*a, *b, 1e-3, &format!("{algo:?} n={n} w={w} p={p} idx={i}"))?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_max_windows_are_exact_under_all_algorithms() {
    // max is exact in FP — no tolerance allowed.
    check(cfg(120), "max exactness", |g| {
        let n = g.usize_in(1, 150);
        let xs = g.vec_f32_len(n, -100.0, 100.0);
        let w = g.usize_in(1, 16);
        let op = MaxOp::<f32>::new();
        let want = sliding::sliding_naive(op, &xs, w);
        for algo in Algo::ALL {
            let got = sliding::run(algo, op, &xs, w, 32);
            ensure(got == want, format!("{algo:?} n={n} w={w}"))?;
        }
        Ok(())
    });
}

#[test]
fn prop_window_count_formula() {
    check(cfg(200), "output length", |g| {
        let n = g.usize_in(0, 200);
        let w = g.usize_in(1, 40);
        let xs = g.vec_f32_len(n, -1.0, 1.0);
        let got = sliding::sliding_naive(AddOp::<f32>::new(), &xs, w).len();
        let want = if n >= w { n - w + 1 } else { 0 };
        ensure(got == want, format!("n={n} w={w}: {got} vs {want}"))
    });
}

#[test]
fn prop_boundary_extension_lengths() {
    check(cfg(150), "boundary lengths", |g| {
        let n = g.usize_in(1, 120);
        let w = g.usize_in(1, 15.min(n + 2));
        let xs = g.vec_f32_len(n, -1.0, 1.0);
        let op = AddOp::<f32>::new();
        for mode in [Boundary::SamePad, Boundary::Mirror, Boundary::Periodic] {
            let ext = sliding::extend(op, &xs, w, mode);
            ensure(
                ext.len() == n + w - 1,
                format!("{mode:?} n={n} w={w}: ext {}", ext.len()),
            )?;
            let out = sliding::sliding_naive(op, &ext, w);
            ensure(out.len() == n, format!("{mode:?} output length"))?;
        }
        Ok(())
    });
}

#[test]
fn prop_sliding_minimum_matches_deque_minimizers() {
    check(cfg(100), "minimizer agreement", |g| {
        let n = g.usize_in(1, 300);
        let w = g.usize_in(1, 24);
        let xs: Vec<u64> = (0..n).map(|_| g.rng.next_u64() % 1000).collect();
        if n < w {
            return Ok(());
        }
        let mins = sliding_minimum(&xs, w);
        let pos = minimizer_positions(&xs, w);
        ensure(mins.len() == pos.len(), "length")?;
        for (m, p) in mins.iter().zip(&pos) {
            ensure(*m == xs[*p], format!("min {m} vs xs[{p}]"))?;
        }
        Ok(())
    });
}

/// The strided non-overlapping pooling fold (PR 3's allocation-free
/// `stride ≥ w` fast path): every batched/multi-channel random shape
/// must match the naive dense-sweep-then-decimate oracle — exactly for
/// max/min (order-insensitive in FP), within the `·(1/w)` rounding
/// identity for avg.
#[test]
fn prop_nonoverlapping_strided_pool_matches_naive() {
    check(cfg(80), "nonoverlap pool fold", |g| {
        let w = g.usize_in(1, 10);
        let stride = w + g.usize_in(0, 5); // stride ≥ w: the fold path
        let channels = g.usize_in(1, 4);
        let batch = g.usize_in(1, 3);
        let n = g.usize_in(w, w + 150);
        let p = Pool1dParams::new(channels, n, w)
            .with_batch(batch)
            .with_stride(stride);
        let x = g.vec_f32_len(batch * channels * n, -50.0, 50.0);
        for kind in [PoolKind::Max, PoolKind::Min] {
            ensure(
                pool1d(kind, &x, &p) == pool1d_naive(kind, &x, &p),
                format!("{kind:?} b={batch} c={channels} n={n} w={w} s={stride}"),
            )?;
        }
        let got = pool1d(PoolKind::Avg, &x, &p);
        let want = pool1d_naive(PoolKind::Avg, &x, &p);
        ensure(got.len() == want.len(), "avg length")?;
        for (a, b) in got.iter().zip(&want) {
            ensure_close(*a, *b, 1e-5, &format!("avg n={n} w={w} s={stride}"))?;
        }
        Ok(())
    });
}

/// `Epilogue::ReluAdd` fused into conv2d's destination writes must be
/// bit-identical to the unfused formulation (raw kernel output, then a
/// separate relu pass, then `+= skip`) for random shapes, strides,
/// padding, and thread counts — the epilogue contract PR 3 shipped
/// without randomized coverage.
#[test]
fn prop_conv2d_relu_add_epilogue_fused_equals_unfused() {
    check(cfg(40), "conv2d ReluAdd epilogue", |g| {
        let c_in = g.usize_in(1, 3);
        let c_out = g.usize_in(1, 3);
        let kh = g.usize_in(1, 4);
        let kw = g.usize_in(1, 4);
        let h = g.usize_in(kh, kh + 10);
        let w = g.usize_in(kw, kw + 10);
        let stride = g.usize_in(1, 3);
        let pad = g.usize_in(0, 2);
        let batch = g.usize_in(1, 3);
        let p = Conv2dParams::new(c_in, c_out, h, w, kh, kw)
            .with_batch(batch)
            .with_stride(stride)
            .with_pad(pad);
        if p.h_out() == 0 || p.w_out() == 0 {
            return Ok(());
        }
        let x = g.vec_f32_len(p.x_len(), -1.0, 1.0);
        let wt = g.vec_f32_len(p.w_len(), -1.0, 1.0);
        let b = g.vec_f32_len(c_out, -0.5, 0.5);
        let skip = g.vec_f32_len(p.y_len(), -2.0, 2.0);
        let ex = Executor::new(*g.choose(&[1usize, 2, 4]));
        // Unfused reference: raw output, relu pass, then the skip add —
        // exactly the eager residual formulation.
        let mut want = conv2d_sliding_with(&ex, &x, &wt, Some(&b), &p);
        for (v, s) in want.iter_mut().zip(&skip) {
            let r = if *v < 0.0 { 0.0 } else { *v };
            *v = r + s;
        }
        // Fused: dirty destination, epilogue riding the kernel writes.
        let mut got = vec![f32::NAN; p.y_len()];
        conv2d_sliding_with_into(&ex, &x, &wt, Some(&b), &p, Epilogue::ReluAdd(&skip), &mut got);
        ensure(
            got == want,
            format!("fused ReluAdd != unfused for {p:?}"),
        )
    });
}

// ───────────────────── conv backend agreement ────────────────────────

#[test]
fn prop_conv_backends_agree_random_hyperparams() {
    check(cfg(60), "conv backends", |g| {
        let k = g.usize_in(1, 9);
        let dilation = g.usize_in(1, 4);
        let stride = g.usize_in(1, 3);
        let c_in = g.usize_in(1, 3);
        let c_out = g.usize_in(1, 3);
        let batch = g.usize_in(1, 2);
        let eff = (k - 1) * dilation + 1;
        let n = g.usize_in(eff, eff + 80);
        let pad = g.usize_in(0, eff);
        let p = Conv1dParams::new(c_in, c_out, n, k)
            .with_batch(batch)
            .with_dilation(dilation)
            .with_stride(stride)
            .with_pad(pad);
        if p.n_out() == 0 {
            return Ok(());
        }
        let x = g.vec_f32_len(p.x_len(), -1.0, 1.0);
        let w = g.vec_f32_len(p.w_len(), -1.0, 1.0);
        let want = conv1d(ConvBackend::Direct, &x, &w, None, &p);
        for backend in [ConvBackend::Sliding, ConvBackend::Im2colGemm, ConvBackend::SlidingPair] {
            let got = conv1d(backend, &x, &w, None, &p);
            ensure(got.len() == want.len(), format!("{backend:?} len"))?;
            for (a, b) in got.iter().zip(&want) {
                ensure_close(*a, *b, 3e-2, &format!("{backend:?} {p:?}"))?;
            }
        }
        Ok(())
    });
}

// ─────────────────── coordinator invariants ──────────────────────────

/// Echo engine: output = input row. Lets properties check routing
/// (response i belongs to request i) under random batch formation.
struct EchoEngine {
    row: usize,
}

impl Engine for EchoEngine {
    fn input_len(&self) -> usize {
        self.row
    }
    fn output_len(&self) -> usize {
        self.row
    }
    fn infer(&self, x: &[f32], batch: usize) -> anyhow::Result<Vec<f32>> {
        assert_eq!(x.len(), batch * self.row);
        Ok(x.to_vec())
    }
    fn name(&self) -> String {
        "echo".into()
    }
}

#[test]
fn prop_coordinator_routes_responses_to_correct_requests() {
    check(cfg(12), "batcher routing", |g| {
        let row = g.usize_in(1, 16);
        let n_req = g.usize_in(1, 40);
        let deadline = g.usize_in(0, 2000) as u64;
        let serve = ServeConfig {
            max_batch: *g.choose(&[1usize, 3, 8]),
            batch_deadline_us: deadline,
            ..Default::default()
        };
        let coord = Coordinator::start_native(EchoEngine { row }, &serve)
            .map_err(|e| e.to_string())?;
        let inputs: Vec<Vec<f32>> = (0..n_req).map(|_| g.vec_f32_len(row, -9.0, 9.0)).collect();
        let tickets: Vec<_> = inputs
            .iter()
            .map(|x| coord.submit(x.clone()).map_err(|e| e.to_string()))
            .collect::<Result<_, _>>()?;
        for (x, t) in inputs.iter().zip(tickets) {
            let y = t.wait().map_err(|e| e.to_string())?;
            ensure(y == *x, "echo mismatch — response routed to wrong request")?;
        }
        let stats = coord.shutdown();
        ensure(
            stats.completed == n_req as u64,
            format!("completed {} vs {}", stats.completed, n_req),
        )?;
        ensure(stats.rejected == 0, "unexpected rejections")
    });
}

#[test]
fn prop_coordinator_never_exceeds_max_batch() {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;
    struct MaxTracker {
        row: usize,
        max_seen: Arc<AtomicUsize>,
        cap: usize,
    }
    impl Engine for MaxTracker {
        fn input_len(&self) -> usize {
            self.row
        }
        fn output_len(&self) -> usize {
            self.row
        }
        fn infer(&self, x: &[f32], batch: usize) -> anyhow::Result<Vec<f32>> {
            self.max_seen.fetch_max(batch, Ordering::SeqCst);
            Ok(x.to_vec())
        }
        fn name(&self) -> String {
            "tracker".into()
        }
    }
    check(cfg(8), "max batch bound", |g| {
        let cap = g.usize_in(1, 6);
        let max_seen = Arc::new(AtomicUsize::new(0));
        let serve = ServeConfig {
            max_batch: cap,
            batch_deadline_us: 500,
            ..Default::default()
        };
        let coord = Coordinator::start_native(
            MaxTracker {
                row: 4,
                max_seen: Arc::clone(&max_seen),
                cap,
            },
            &serve,
        )
        .map_err(|e| e.to_string())?;
        let tickets: Vec<_> = (0..30)
            .map(|_| coord.submit(g.vec_f32_len(4, 0.0, 1.0)))
            .collect::<Result<_, _>>()
            .map_err(|e| e.to_string())?;
        for t in tickets {
            t.wait().map_err(|e| e.to_string())?;
        }
        let seen = max_seen.load(Ordering::SeqCst);
        ensure(seen <= cap, format!("batch {seen} exceeded cap {cap}"))
    });
}
