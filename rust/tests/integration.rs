//! Cross-module integration: config → model → conv backends → pooling →
//! algorithm family, plus the experiment *shape* assertions from
//! DESIGN.md §4 (fast variants of the Fig 1 / Fig 2 win criteria).

use swsnn::bench::{bench, BenchConfig};
use swsnn::config::load_config;
use swsnn::conv::{conv1d, conv1d_pair_tree, Conv1dParams, ConvBackend};
use swsnn::nn::Model;
use swsnn::ops::{AddOp, ConvPair, MaxOp, MinOp, MulOp};
use swsnn::pool::{pool1d, pool1d_naive, Pool1dParams, PoolKind};
use swsnn::sliding::{self, Algo, Boundary};
use swsnn::workload::{chaudhary_dilated_suite, Rng};

/// Every algorithm × every operator × assorted (w, P, N) — the full
/// compatibility matrix in one sweep.
#[test]
fn algorithm_operator_matrix() {
    let mut rng = Rng::new(0xA11);
    for n in [50usize, 333, 1024] {
        let xs = rng.vec_uniform(n, -2.0, 2.0);
        for w in [2usize, 3, 7, 13] {
            for p in [16usize, 64] {
                let add = AddOp::<f32>::new();
                let max = MaxOp::<f32>::new();
                let min = MinOp::<f32>::new();
                let want_add = sliding::sliding_naive(add, &xs, w);
                let want_max = sliding::sliding_naive(max, &xs, w);
                let want_min = sliding::sliding_naive(min, &xs, w);
                for algo in Algo::ALL {
                    let got = sliding::run(algo, add, &xs, w, p);
                    assert_eq!(got.len(), want_add.len());
                    for (a, b) in got.iter().zip(&want_add) {
                        assert!((a - b).abs() < 1e-3, "{algo:?} add n={n} w={w} p={p}");
                    }
                    let got = sliding::run(algo, max, &xs, w, p);
                    assert_eq!(got, want_max, "{algo:?} max n={n} w={w} p={p}");
                    let got = sliding::run(algo, min, &xs, w, p);
                    assert_eq!(got, want_min, "{algo:?} min n={n} w={w} p={p}");
                }
            }
        }
    }
}

/// Positive-product windows survive every algorithm (MulOp is the
/// non-idempotent non-add monoid in the matrix).
#[test]
fn product_windows_all_algorithms() {
    let mut rng = Rng::new(0xA12);
    let xs: Vec<f32> = (0..200).map(|_| rng.uniform(0.9, 1.1)).collect();
    let op = MulOp::<f32>::new();
    let want = sliding::sliding_naive(op, &xs, 6);
    for algo in Algo::ALL {
        let got = sliding::run(algo, op, &xs, 6, 32);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-3 * b.abs().max(1.0), "{algo:?}");
        }
    }
}

/// The γ-pair evaluation (literal Eq. 7–9) agrees with direct conv on
/// both linear and tree folds, across dilation/stride/pad.
#[test]
fn pair_formulation_full_hyperparameter_grid() {
    let mut rng = Rng::new(0xA13);
    for (k, d, s, pad) in [
        (3usize, 1usize, 1usize, 0usize),
        (4, 2, 1, 3),
        (5, 3, 2, 6),
        (7, 1, 1, 3),
    ] {
        let p = Conv1dParams::new(1, 1, 96, k)
            .with_dilation(d)
            .with_stride(s)
            .with_pad(pad);
        let x = rng.vec_uniform(p.x_len(), -1.0, 1.0);
        let w = rng.vec_uniform(p.w_len(), -1.0, 1.0);
        let want = conv1d(ConvBackend::Direct, &x, &w, None, &p);
        for (name, got) in [
            ("pair", conv1d(ConvBackend::SlidingPair, &x, &w, None, &p)),
            ("pair_tree", conv1d_pair_tree(&x, &w, None, &p)),
        ] {
            assert_eq!(got.len(), want.len());
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() < 5e-2 * (1.0 + b.abs()), "{name} k={k} d={d} s={s}");
            }
        }
    }
}

/// Boundary modes compose with the algorithm family (same-length output,
/// correct edge values).
#[test]
fn boundary_modes_compose_with_algorithms() {
    let mut rng = Rng::new(0xA14);
    let xs = rng.vec_uniform(64, -1.0, 1.0);
    let op = MaxOp::<f32>::new();
    for mode in [Boundary::SamePad, Boundary::Mirror, Boundary::Periodic] {
        let ext = sliding::extend(op, &xs, 5, mode);
        let want = sliding::sliding_naive(op, &ext, 5);
        assert_eq!(want.len(), 64, "{mode:?}");
        for algo in [Algo::VectorSlide, Algo::PingPong, Algo::VectorInputLog] {
            let got = sliding::run(algo, op, &ext, 5, 32);
            assert_eq!(got, want, "{mode:?} {algo:?}");
        }
    }
}

/// Config-driven model runs identically on all conv backends — the
/// "backend router can swap engines without changing results" guarantee
/// the coordinator relies on.
#[test]
fn model_backend_equivalence_from_config() {
    let text = std::fs::read_to_string(
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs/audio_classifier.toml"),
    )
    .unwrap();
    let (mc, _) = load_config(&text).unwrap();
    let mut rng = Rng::new(0xA15);
    let model = Model::init(&mc, &mut rng).unwrap();
    let x = rng.vec_uniform(mc.seq_len, -1.0, 1.0);
    let want = model.forward(&x, 1, ConvBackend::Direct).unwrap();
    for backend in [ConvBackend::Sliding, ConvBackend::Im2colGemm] {
        let got = model.forward(&x, 1, backend).unwrap();
        assert_eq!(got.shape, want.shape);
        for (a, b) in got.data.iter().zip(&want.data) {
            assert!((a - b).abs() < 1e-2 * (1.0 + b.abs()), "{backend:?}");
        }
    }
}

/// FIG1 shape criterion (quick variant): sliding beats im2col+GEMM at
/// moderate k, and the advantage grows with k.
#[test]
fn fig1_shape_sliding_wins_and_grows() {
    let cfg = BenchConfig::quick();
    let mut rng = Rng::new(0xF1);
    let n = 200_000;
    let x = rng.vec_uniform(n, -1.0, 1.0);
    let mut speedups = Vec::new();
    for k in [7usize, 63] {
        let w = rng.vec_uniform(k, -1.0, 1.0);
        let p = Conv1dParams::new(1, 1, n, k);
        let mg = bench(&cfg, || {
            std::hint::black_box(conv1d(ConvBackend::Im2colGemm, std::hint::black_box(&x), &w, None, &p));
        });
        let ms = bench(&cfg, || {
            std::hint::black_box(conv1d(ConvBackend::Sliding, std::hint::black_box(&x), &w, None, &p));
        });
        speedups.push(mg.median_ns() / ms.median_ns());
    }
    assert!(speedups[0] > 1.0, "sliding must win at k=7: {speedups:?}");
    assert!(
        speedups[1] > speedups[0],
        "speedup must grow with k: {speedups:?}"
    );
}

/// FIG2 shape criterion (quick variant): sliding wins on the dilated
/// small-set workloads.
#[test]
fn fig2_shape_dilated_small_set_wins() {
    let cfg = BenchConfig::quick();
    let mut rng = Rng::new(0xF2);
    let suite = chaudhary_dilated_suite();
    let (name, p) = suite
        .iter()
        .find(|(name, _)| name.starts_with("small/"))
        .unwrap();
    let x = rng.vec_uniform(p.x_len(), -1.0, 1.0);
    let w = rng.vec_uniform(p.w_len(), -1.0, 1.0);
    let mg = bench(&cfg, || {
        std::hint::black_box(conv1d(ConvBackend::Im2colGemm, std::hint::black_box(&x), &w, None, p));
    });
    let ms = bench(&cfg, || {
        std::hint::black_box(conv1d(ConvBackend::Sliding, std::hint::black_box(&x), &w, None, p));
    });
    let speedup = mg.median_ns() / ms.median_ns();
    assert!(speedup > 1.5, "{name}: dilated sliding speedup {speedup:.2} ≤ 1.5");
}

/// TBL-P shape criterion: sliding pooling beats naive recomputation for
/// large windows.
#[test]
fn pooling_shape_sliding_beats_naive_at_large_w() {
    let cfg = BenchConfig::quick();
    let mut rng = Rng::new(0xF3);
    let x = rng.vec_uniform(200_000, -1.0, 1.0);
    let p = Pool1dParams::new(1, 200_000, 32);
    let mn = bench(&cfg, || {
        std::hint::black_box(pool1d_naive(PoolKind::Max, std::hint::black_box(&x), &p));
    });
    let ms = bench(&cfg, || {
        std::hint::black_box(pool1d(PoolKind::Max, std::hint::black_box(&x), &p));
    });
    let speedup = mn.median_ns() / ms.median_ns();
    assert!(speedup > 2.0, "pooling speedup {speedup:.2} ≤ 2 at w=32");
}

/// ConvPair associativity at the integration level: folding γ chains in
/// different association orders gives the same dot product.
#[test]
fn conv_pair_association_orders_agree() {
    use swsnn::ops::AssocOp;
    let mut rng = Rng::new(0xF4);
    for m in [2usize, 5, 9, 16] {
        let gammas: Vec<swsnn::ops::Pair> = (0..m)
            .map(|_| swsnn::ops::Pair::new(rng.uniform(0.5, 2.0), rng.uniform(-1.0, 1.0)))
            .collect();
        let op = ConvPair;
        // Left fold.
        let mut left = op.identity();
        for g in &gammas {
            left = op.combine(left, *g);
        }
        // Right fold.
        let mut right = op.identity();
        for g in gammas.iter().rev() {
            right = op.combine(*g, right);
        }
        // Balanced tree via scan module.
        let tree = swsnn::scan::reduce_tree(op, &gammas);
        assert!((left.v - right.v).abs() < 1e-3, "m={m}");
        assert!((left.v - tree.v).abs() < 1e-3, "m={m}");
        assert!((left.u - tree.u).abs() < 1e-3 * left.u.abs().max(1.0), "m={m}");
    }
}
