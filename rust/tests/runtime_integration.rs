//! Integration: rust runtime executes the AOT artifacts produced by
//! `python/compile/aot.py` and agrees with the rust-native conv/pool
//! implementations — the cross-language correctness seam of the stack.
//!
//! Requires `make artifacts` (skips cleanly if the directory is absent,
//! so `cargo test` stays green in a fresh checkout).

use swsnn::conv::{conv1d_sliding, Conv1dParams};
use swsnn::pool::{pool1d, Pool1dParams, PoolKind};
use swsnn::runtime::{ArtifactRegistry, TensorView};
use swsnn::workload::Rng;

fn registry() -> Option<ArtifactRegistry> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.is_dir() {
        eprintln!("skipping: artifacts/ missing (run `make artifacts`)");
        return None;
    }
    Some(ArtifactRegistry::open(dir).expect("open registry"))
}

#[test]
fn lists_expected_artifacts() {
    let Some(reg) = registry() else { return };
    let names = reg.list().unwrap();
    for expect in [
        "conv1d_sliding_k3_n4096",
        "conv1d_sliding_k31_n4096",
        "pool_max_w8_n4096",
        "tcn_forward_b1_n512",
        "tcn_train_step_b8_n512",
    ] {
        assert!(names.iter().any(|n| n == expect), "missing {expect}: {names:?}");
    }
}

#[test]
fn manifest_matches_python_layout() {
    let Some(reg) = registry() else { return };
    let m = reg.manifest().expect("manifest.toml");
    assert_eq!(m.param_count(), m.params, "layout drifted from model.py");
    assert_eq!(m.seq_len, 512);
}

#[test]
fn conv_artifact_matches_rust_conv() {
    let Some(reg) = registry() else { return };
    for k in [3usize, 7, 15, 31] {
        let name = format!("conv1d_sliding_k{k}_n4096");
        let exe = reg.get(&name).expect("compile artifact");
        let mut rng = Rng::new(42 + k as u64);
        let x = rng.vec_uniform(4096, -1.0, 1.0);
        let w = rng.vec_uniform(k, -1.0, 1.0);
        let b = rng.vec_uniform(1, -0.5, 0.5);

        let out = exe
            .run1(&[
                TensorView::new(vec![1, 1, 4096], x.clone()),
                TensorView::new(vec![1, 1, k], w.clone()),
                TensorView::new(vec![1], b.clone()),
            ])
            .expect("execute");
        assert_eq!(out.shape, vec![1, 1, 4096], "same-pad output");

        let p = Conv1dParams::new(1, 1, 4096, k).with_pad((k - 1) / 2);
        let want = conv1d_sliding(&x, &w, Some(&b), &p);
        assert_eq!(want.len(), out.data.len());
        let mut max_diff = 0f32;
        for (a, c) in out.data.iter().zip(&want) {
            max_diff = max_diff.max((a - c).abs());
        }
        assert!(max_diff < 1e-3, "k={k} max diff {max_diff}");
    }
}

#[test]
fn dilated_conv_artifact_matches_rust() {
    let Some(reg) = registry() else { return };
    let exe = reg.get("conv1d_sliding_k31_d16_n8192").expect("compile");
    let mut rng = Rng::new(7);
    let x = rng.vec_uniform(8192, -1.0, 1.0);
    let w = rng.vec_uniform(31, -1.0, 1.0);
    let b = vec![0.25f32];
    let out = exe
        .run1(&[
            TensorView::new(vec![1, 1, 8192], x.clone()),
            TensorView::new(vec![1, 1, 31], w.clone()),
            TensorView::new(vec![1], b.clone()),
        ])
        .expect("execute");
    let p = Conv1dParams::new(1, 1, 8192, 31)
        .with_dilation(16)
        .with_pad((31 - 1) * 16 / 2);
    let want = conv1d_sliding(&x, &w, Some(&b), &p);
    assert_eq!(out.data.len(), want.len());
    for (i, (a, c)) in out.data.iter().zip(&want).enumerate() {
        assert!((a - c).abs() < 1e-3, "idx {i}: {a} vs {c}");
    }
}

#[test]
fn pool_artifacts_match_rust_pool() {
    let Some(reg) = registry() else { return };
    let mut rng = Rng::new(11);
    let x = rng.vec_uniform(4 * 4096, -2.0, 2.0);
    for (name, kind) in [
        ("pool_max_w8_n4096", PoolKind::Max),
        ("pool_avg_w8_n4096", PoolKind::Avg),
    ] {
        let exe = reg.get(name).expect("compile");
        let out = exe
            .run1(&[TensorView::new(vec![1, 4, 4096], x.clone())])
            .expect("execute");
        let p = Pool1dParams::new(4, 4096, 8).with_stride(8);
        let want = pool1d(kind, &x, &p);
        assert_eq!(out.data.len(), want.len(), "{name}");
        for (a, c) in out.data.iter().zip(&want) {
            assert!((a - c).abs() < 1e-4, "{name}: {a} vs {c}");
        }
    }
}

#[test]
fn tcn_forward_executes_and_is_batch_consistent() {
    let Some(reg) = registry() else { return };
    let m = reg.manifest().expect("manifest").clone();
    let mut rng = Rng::new(3);
    let params: Vec<TensorView> = m
        .param_shapes()
        .iter()
        .map(|(_, s)| {
            let n: usize = s.iter().product();
            TensorView::new(s.clone(), rng.vec_normal(n, 0.1))
        })
        .collect();

    let x1 = rng.vec_uniform(m.seq_len, -1.0, 1.0);
    let mut args1 = params.clone();
    args1.push(TensorView::new(vec![1, m.c_in, m.seq_len], x1.clone()));
    let exe1 = reg.get("tcn_forward_b1_n512").expect("b1");
    let y1 = exe1.run1(&args1).expect("run b1");
    assert_eq!(y1.shape, vec![1, m.c_out, m.seq_len]);
    assert!(y1.data.iter().all(|v| v.is_finite()));

    // Batch 4 with row 2 = x1 must reproduce y1 in row 2.
    let exe4 = reg.get("tcn_forward_b4_n512").expect("b4");
    let mut xb = rng.vec_uniform(4 * m.seq_len, -1.0, 1.0);
    xb[2 * m.seq_len..3 * m.seq_len].copy_from_slice(&x1);
    let mut args4 = params.clone();
    args4.push(TensorView::new(vec![4, m.c_in, m.seq_len], xb));
    let y4 = exe4.run1(&args4).expect("run b4");
    let row = &y4.data[2 * m.seq_len..3 * m.seq_len];
    for (a, c) in row.iter().zip(&y1.data) {
        assert!((a - c).abs() < 1e-4, "batch row mismatch: {a} vs {c}");
    }
}

#[test]
fn tcn_train_step_reduces_loss() {
    let Some(reg) = registry() else { return };
    let m = reg.manifest().expect("manifest").clone();
    let exe = reg.get("tcn_train_step_b8_n512").expect("train step");
    let mut rng = Rng::new(5);
    let mut params: Vec<TensorView> = m
        .param_shapes()
        .iter()
        .map(|(name, s)| {
            let n: usize = s.iter().product();
            if name.ends_with('b') || name.contains("_b") {
                TensorView::new(s.clone(), vec![0.0; n])
            } else {
                let fan_in: usize = s[1..].iter().product();
                TensorView::new(s.clone(), rng.vec_normal(n, (2.0 / fan_in as f32).sqrt()))
            }
        })
        .collect();

    // Smooth AR(1) batch — same family as the python tests.
    let mut x = vec![0.0f32; 8 * m.seq_len];
    let mut prev = 0.0f32;
    for v in x.iter_mut() {
        prev = 0.9 * prev + 0.2 * rng.normal();
        *v = prev;
    }

    let mut losses = Vec::new();
    for _ in 0..5 {
        let mut args = params.clone();
        args.push(TensorView::new(vec![8, m.c_in, m.seq_len], x.clone()));
        let mut out = exe.run(&args).expect("train step");
        assert_eq!(out.len(), 1 + params.len(), "loss + new params");
        let loss = out.remove(0);
        assert!(loss.shape.is_empty());
        losses.push(loss.data[0]);
        params = out;
    }
    assert!(
        losses[4] < losses[0],
        "loss should fall across steps: {losses:?}"
    );
    assert!(losses.iter().all(|l| l.is_finite()), "{losses:?}");
}
