//! Parallel/serial parity: the worker-pool fan-out added to the conv,
//! pool, and sliding kernels must be **bit-identical** to the serial
//! sweep for every thread count — partitioning may only change *where*
//! an output is computed, never the per-output combine order. All
//! comparisons here are exact (`assert_eq!` on the f32 vectors).

use swsnn::conv::{
    conv1d_direct, conv1d_sliding_with, conv2d_direct, conv2d_sliding_with, Conv1dParams,
    Conv2dParams,
};
use swsnn::exec::Executor;
use swsnn::ops::{AddOp, MaxOp, MinOp, MulOp};
use swsnn::pool::{pool1d_naive, pool1d_with, pool2d_naive, pool2d_with, Pool1dParams,
    Pool2dParams, PoolKind};
use swsnn::sliding::{self, Algo, Boundary};
use swsnn::workload::Rng;

const THREADS: [usize; 4] = [1, 2, 4, 8];

fn conv1d_case(p: &Conv1dParams, with_bias: bool, seed: u64) {
    let mut rng = Rng::new(seed);
    let x = rng.vec_uniform(p.x_len(), -1.0, 1.0);
    let w = rng.vec_uniform(p.w_len(), -1.0, 1.0);
    let b = rng.vec_uniform(p.c_out, -0.5, 0.5);
    let bias = with_bias.then_some(b.as_slice());
    let serial = conv1d_sliding_with(&Executor::new(1), &x, &w, bias, p);
    for t in THREADS {
        let ex = Executor::new(t);
        let got = conv1d_sliding_with(&ex, &x, &w, bias, p);
        assert_eq!(got, serial, "conv1d parity threads={t} {p:?}");
    }
    // Sanity anchor: the serial reference itself agrees with direct.
    let want = conv1d_direct(&x, &w, bias, p);
    assert_eq!(serial.len(), want.len());
    for (i, (a, c)) in serial.iter().zip(&want).enumerate() {
        assert!(
            (a - c).abs() <= 1e-3 * (1.0 + c.abs()),
            "{p:?} idx {i}: {a} vs {c}"
        );
    }
}

#[test]
fn conv1d_parallel_bit_identical_single_row() {
    // The Fig-1 shape: one output row, parallel only via column segments.
    conv1d_case(&Conv1dParams::new(1, 1, 200_000, 9), false, 0x51);
    conv1d_case(&Conv1dParams::new(1, 1, 120_000, 63), true, 0x52);
}

#[test]
fn conv1d_parallel_bit_identical_multi_row() {
    conv1d_case(&Conv1dParams::new(2, 3, 9_000, 5).with_batch(2), true, 0x53);
    conv1d_case(&Conv1dParams::new(4, 8, 5_000, 7), false, 0x54);
}

#[test]
fn conv1d_parallel_bit_identical_hyperparams() {
    conv1d_case(&Conv1dParams::new(1, 2, 50_000, 7).with_same_pad(), true, 0x55);
    conv1d_case(
        &Conv1dParams::new(2, 2, 40_000, 5).with_stride(2).with_pad(3),
        false,
        0x56,
    );
    conv1d_case(
        &Conv1dParams::new(1, 1, 60_000, 9).with_dilation(4).with_same_pad(),
        true,
        0x57,
    );
}

/// Segment boundaries vs the 4096-element cache block vs the 8/4/1 tap
/// unroll: every k mod 8 residue over an n_out that forces within-row
/// segmentation, with and without dilation.
#[test]
fn conv1d_parallel_bit_identical_block_edges() {
    for k in 8usize..=16 {
        let n_out = 3 * 8192 + 5;
        conv1d_case(&Conv1dParams::new(1, 1, n_out + k - 1, k), false, 0x60 + k as u64);
    }
    for d in [2usize, 3] {
        let k = 9;
        let n_out = 2 * 8192 + 1;
        conv1d_case(
            &Conv1dParams::new(1, 1, n_out + (k - 1) * d, k).with_dilation(d),
            true,
            0x80 + d as u64,
        );
    }
}

#[test]
fn conv2d_parallel_bit_identical() {
    let mut rng = Rng::new(0x2D2);
    for p in [
        Conv2dParams::new(2, 4, 64, 64, 3, 3).with_same_pad(),
        Conv2dParams::new(1, 1, 96, 96, 5, 5),
        Conv2dParams::new(2, 2, 48, 40, 3, 3).with_stride(2).with_pad(1).with_batch(2),
    ] {
        let x = rng.vec_uniform(p.x_len(), -1.0, 1.0);
        let w = rng.vec_uniform(p.w_len(), -1.0, 1.0);
        let b = rng.vec_uniform(p.c_out, -0.5, 0.5);
        let serial = conv2d_sliding_with(&Executor::new(1), &x, &w, Some(&b), &p);
        for t in THREADS {
            let ex = Executor::new(t);
            let got = conv2d_sliding_with(&ex, &x, &w, Some(&b), &p);
            assert_eq!(got, serial, "conv2d parity threads={t} {p:?}");
        }
        let want = conv2d_direct(&x, &w, Some(&b), &p);
        for (a, c) in serial.iter().zip(&want) {
            assert!((a - c).abs() <= 1e-3 * (1.0 + c.abs()), "{p:?}");
        }
    }
}

#[test]
fn pool1d_parallel_bit_identical() {
    let mut rng = Rng::new(0x1D90011);
    for (channels, batch, n) in [(1usize, 1usize, 150_000usize), (8, 2, 4_000)] {
        let x = rng.vec_uniform(batch * channels * n, -2.0, 2.0);
        for kind in [PoolKind::Avg, PoolKind::Max, PoolKind::Min] {
            for mode in [Boundary::Valid, Boundary::SamePad] {
                for stride in [1usize, 4] {
                    let p = Pool1dParams::new(channels, n, 16)
                        .with_batch(batch)
                        .with_stride(stride)
                        .with_boundary(mode);
                    let serial = pool1d_with(&Executor::new(1), kind, &x, &p);
                    for t in THREADS {
                        let ex = Executor::new(t);
                        let got = pool1d_with(&ex, kind, &x, &p);
                        assert_eq!(
                            got, serial,
                            "pool1d parity threads={t} {kind:?} {mode:?} s={stride}"
                        );
                    }
                }
            }
        }
    }
    // Anchor one configuration against the naive oracle.
    let n = 2_000;
    let x = rng.vec_uniform(n, -2.0, 2.0);
    let p = Pool1dParams::new(1, n, 8).with_stride(2);
    let got = pool1d_with(&Executor::new(4), PoolKind::Max, &x, &p);
    let want = pool1d_naive(PoolKind::Max, &x, &p);
    assert_eq!(got, want);
}

#[test]
fn pool2d_parallel_bit_identical() {
    let mut rng = Rng::new(0x2D90012);
    let p = Pool2dParams::new(4, 64, 64, 3, 3).with_batch(2).with_strides(2, 2);
    let x = rng.vec_uniform(2 * 4 * 64 * 64, -3.0, 3.0);
    for kind in [PoolKind::Avg, PoolKind::Max, PoolKind::Min] {
        let serial = pool2d_with(&Executor::new(1), kind, &x, &p);
        for t in THREADS {
            let ex = Executor::new(t);
            let got = pool2d_with(&ex, kind, &x, &p);
            assert_eq!(got, serial, "pool2d parity threads={t} {kind:?}");
        }
        let want = pool2d_naive(kind, &x, &p);
        for (a, c) in serial.iter().zip(&want) {
            assert!((a - c).abs() < 1e-3, "{kind:?}");
        }
    }
}

/// Every algorithm, every thread count: `run_with` must equal
/// `run_serial` exactly. Chunk-parallel-safe algorithms are dispatched
/// with halo chunking; the rest must fall back to the serial sweep.
#[test]
fn sliding_run_bit_identical_all_algorithms() {
    let mut rng = Rng::new(0x5A11);
    let xs = rng.vec_uniform(150_000, -1.0, 1.0);
    let op = AddOp::<f32>::new();
    for w in [3usize, 7, 16] {
        for algo in Algo::ALL {
            let serial = sliding::run_serial(algo, op, &xs, w, 16);
            for t in THREADS {
                let ex = Executor::new(t);
                let got = sliding::run_with(&ex, algo, op, &xs, w, 16);
                assert_eq!(got, serial, "{algo:?} add w={w} threads={t}");
            }
        }
    }
}

#[test]
fn sliding_run_bit_identical_lattice_and_integer_ops() {
    let mut rng = Rng::new(0x5A12);
    let xs = rng.vec_uniform(140_000, -100.0, 100.0);
    let ints: Vec<u64> = (0..140_000u64).map(|_| rng.next_u64() % 10_000).collect();
    for algo in [Algo::VectorSlide, Algo::VectorSlideTree, Algo::FlatTree] {
        let want_max = sliding::run_serial(algo, MaxOp::<f32>::new(), &xs, 9, 32);
        let want_min = sliding::run_serial(algo, MinOp::<u64>::new(), &ints, 9, 32);
        for t in THREADS {
            let ex = Executor::new(t);
            assert_eq!(
                sliding::run_with(&ex, algo, MaxOp::<f32>::new(), &xs, 9, 32),
                want_max,
                "{algo:?} max threads={t}"
            );
            assert_eq!(
                sliding::run_with(&ex, algo, MinOp::<u64>::new(), &ints, 9, 32),
                want_min,
                "{algo:?} min threads={t}"
            );
        }
    }
}

#[test]
fn sliding_auto_bit_identical_across_threads() {
    let mut rng = Rng::new(0x5A13);
    let xs = rng.vec_uniform(150_000, -1.0, 1.0);
    let mul_xs: Vec<f32> = xs.iter().map(|v| 1.0 + 0.001 * v).collect();
    for w in [1usize, 2, 5, 64] {
        let serial = sliding::auto_serial(AddOp::<f32>::new(), &xs, w, 64);
        let serial_mul = sliding::auto_serial(MulOp::<f32>::new(), &mul_xs, w, 64);
        for t in THREADS {
            let ex = Executor::new(t);
            assert_eq!(
                sliding::auto_with(&ex, AddOp::<f32>::new(), &xs, w, 64),
                serial,
                "auto add w={w} threads={t}"
            );
            assert_eq!(
                sliding::auto_with(&ex, MulOp::<f32>::new(), &mul_xs, w, 64),
                serial_mul,
                "auto mul w={w} threads={t}"
            );
        }
    }
}
