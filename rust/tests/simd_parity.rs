//! SIMD/generic parity: every `std::arch` tier the host supports must be
//! **bit-identical** to the portable generic fallback, at the kernel
//! level and through the full conv/pool/sliding stacks.
//!
//! All tier forcing lives in ONE test function: `simd::force_tier` is a
//! process-global override, and the libtest harness runs `#[test]` fns
//! concurrently within this binary.

use swsnn::conv::{
    conv1d_quantized_into, conv1d_sliding_with, quantized_scratch_len, Conv1dParams, QuantParams,
};
use swsnn::exec::Executor;
use swsnn::ops::{AddOp, Epilogue, MaxOp};
use swsnn::pool::{pool1d_with, Pool1dParams, PoolKind};
use swsnn::simd::{self, SimdTier};
use swsnn::sliding::{self, Algo};
use swsnn::workload::Rng;

#[test]
fn all_supported_tiers_bit_identical_to_generic() {
    let mut rng = Rng::new(0x51D);
    let ex1 = Executor::new(1);
    let ex4 = Executor::new(4);

    // Inputs sized to cross the 4096 conv block and the 8-lane /
    // 4-lane vector tails.
    let xs = rng.vec_uniform(50_007, -1.0, 1.0);

    let conv_cases: Vec<Conv1dParams> = vec![
        Conv1dParams::new(1, 1, 20_000, 3),
        Conv1dParams::new(1, 1, 20_011, 9),
        Conv1dParams::new(2, 2, 9_001, 7).with_same_pad(),
        Conv1dParams::new(1, 2, 8_000, 5).with_dilation(3).with_same_pad(),
        Conv1dParams::new(2, 1, 7_003, 4).with_stride(2).with_pad(2),
    ];
    let conv_inputs: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)> = conv_cases
        .iter()
        .map(|p| {
            (
                rng.vec_uniform(p.x_len(), -1.0, 1.0),
                rng.vec_uniform(p.w_len(), -1.0, 1.0),
                rng.vec_uniform(p.c_out, -0.5, 0.5),
            )
        })
        .collect();
    let pool_p = Pool1dParams::new(2, 30_000, 16).with_batch(1);
    let pool_x = rng.vec_uniform(2 * 30_000, -2.0, 2.0);

    // int8 inputs for the quantized sweep: full i8 range including the
    // lane tails (4_099 is not a multiple of any vector width).
    let qsrc: Vec<i8> = (0..4_099).map(|i| ((i * 73 + 5) % 256 - 128) as i8).collect();
    let quant_cases: Vec<(Conv1dParams, bool)> = vec![
        (Conv1dParams::new(2, 3, 5_000, 7).with_same_pad(), true),
        (
            Conv1dParams::new(1, 2, 6_001, 5).with_batch(2).with_stride(2).with_dilation(2).with_pad(3),
            false,
        ),
    ];
    let quant_inputs: Vec<(Vec<i8>, Vec<i8>, Vec<f32>)> = quant_cases
        .iter()
        .map(|(p, _)| {
            (
                (0..p.x_len() as i64).map(|i| ((i * 31 + 17) % 256 - 128) as i8).collect(),
                (0..p.w_len() as i64).map(|i| ((i * 97 + 3) % 256 - 128) as i8).collect(),
                rng.vec_uniform(p.c_out, -0.5, 0.5),
            )
        })
        .collect();
    let xp = QuantParams { scale: 0.05, zero_point: 3 };
    let wp = QuantParams { scale: 0.02, zero_point: -5 };

    // References under the forced generic tier.
    simd::force_tier(Some(SimdTier::Generic));
    assert_eq!(simd::tier(), SimdTier::Generic);
    let kernel_src = rng.vec_uniform(1_003, -3.0, 3.0);
    let kernel_base = rng.vec_uniform(1_003, -3.0, 3.0);
    let conv_refs: Vec<Vec<f32>> = conv_cases
        .iter()
        .zip(&conv_inputs)
        .map(|(p, (x, w, b))| conv1d_sliding_with(&ex1, x, w, Some(b.as_slice()), p))
        .collect();
    let slide_refs: Vec<Vec<f32>> = [Algo::ScalarInput, Algo::VectorSlide, Algo::FlatTree]
        .iter()
        .map(|a| sliding::run_serial(*a, AddOp::<f32>::new(), &xs, 12, 16))
        .collect();
    let max_ref = sliding::run_serial(Algo::FlatTree, MaxOp::<f32>::new(), &xs, 9, 16);
    let auto_ref = sliding::auto_with(&ex4, AddOp::<f32>::new(), &xs, 63, 64);
    let pool_ref = pool1d_with(&ex1, PoolKind::Avg, &pool_x, &pool_p);

    // Quantized conv references under the generic tier. The i32
    // accumulation is exact (associativity holds for wrapping integer
    // adds), so every tier must reproduce these f32 outputs *bitwise*.
    let quant_refs: Vec<Vec<f32>> = quant_cases
        .iter()
        .zip(&quant_inputs)
        .map(|((p, with_bias), (qx, qw, b))| {
            let mut acc = vec![i32::MIN; quantized_scratch_len(p)];
            let mut y = vec![f32::NAN; p.y_len()];
            let bias = with_bias.then_some(b.as_slice());
            conv1d_quantized_into(qx, qw, xp, wp, bias, p, Epilogue::Relu, &mut acc, &mut y);
            y
        })
        .collect();

    let tiers = [SimdTier::Avx512, SimdTier::Avx2, SimdTier::Sse2, SimdTier::Neon];
    for t in tiers.into_iter().filter(|t| t.is_supported()) {
        simd::force_tier(Some(t));
        assert_eq!(simd::tier(), t);

        // Kernel level.
        let mut got = kernel_base.clone();
        simd::add_assign_f32(&mut got, &kernel_src);
        let mut want = kernel_base.clone();
        simd::add_assign_f32_generic(&mut want, &kernel_src);
        assert_eq!(got, want, "{t:?} add_assign");

        let mut got = kernel_base.clone();
        simd::max_assign_f32(&mut got, &kernel_src);
        let mut want = kernel_base.clone();
        simd::max_assign_f32_generic(&mut want, &kernel_src);
        assert_eq!(got, want, "{t:?} max_assign");

        let mut got = kernel_base.clone();
        simd::min_assign_f32(&mut got, &kernel_src);
        let mut want = kernel_base.clone();
        simd::min_assign_f32_generic(&mut want, &kernel_src);
        assert_eq!(got, want, "{t:?} min_assign");

        let mut got = kernel_base.clone();
        simd::fma_tap1_f32(&mut got, &kernel_src, 0.73);
        let mut want = kernel_base.clone();
        simd::fma_tap1_f32_generic(&mut want, &kernel_src, 0.73);
        assert_eq!(got, want, "{t:?} fma_tap1");

        let taps = [0.25f32, -1.5, 0.5, 2.0];
        let nn = kernel_base.len() - 3;
        let mut got = kernel_base[..nn].to_vec();
        simd::fma_tap4_f32(&mut got, &kernel_src, taps);
        let mut want = kernel_base[..nn].to_vec();
        simd::fma_tap4_f32_generic(&mut want, &kernel_src, taps);
        assert_eq!(got, want, "{t:?} fma_tap4");

        // int8 tap kernels: dispatched vs generic oracle, exact. The
        // nonzero seed in `acc` checks the accumulate (not overwrite)
        // semantics; 4_001 outputs exercise the vector tails.
        let mut got = vec![7i32; 4_001];
        simd::dot_i8_tap(&mut got, &qsrc, -77);
        let mut want = vec![7i32; 4_001];
        simd::dot_i8_tap_generic(&mut want, &qsrc, -77);
        assert_eq!(got, want, "{t:?} dot_i8_tap");

        let mut got = vec![-3i32; 4_001];
        simd::sum_i8_tap(&mut got, &qsrc);
        let mut want = vec![-3i32; 4_001];
        simd::sum_i8_tap_generic(&mut want, &qsrc);
        assert_eq!(got, want, "{t:?} sum_i8_tap");

        // Full quantized conv: bit-identical across tiers.
        for (((p, with_bias), (qx, qw, b)), want) in
            quant_cases.iter().zip(&quant_inputs).zip(&quant_refs)
        {
            let mut acc = vec![i32::MIN; quantized_scratch_len(p)];
            let mut y = vec![f32::NAN; p.y_len()];
            let bias = with_bias.then_some(b.as_slice());
            conv1d_quantized_into(qx, qw, xp, wp, bias, p, Epilogue::Relu, &mut acc, &mut y);
            assert_eq!(&y, want, "{t:?} conv1d_quantized {p:?}");
        }

        // Full conv stack, serial and parallel.
        for ((p, (x, w, b)), want) in conv_cases.iter().zip(&conv_inputs).zip(&conv_refs) {
            let got1 = conv1d_sliding_with(&ex1, x, w, Some(b.as_slice()), p);
            assert_eq!(&got1, want, "{t:?} conv serial {p:?}");
            let got4 = conv1d_sliding_with(&ex4, x, w, Some(b.as_slice()), p);
            assert_eq!(&got4, want, "{t:?} conv parallel {p:?}");
        }

        // Sliding algorithms through the VecReg / flat-tree paths.
        for (a, want) in [Algo::ScalarInput, Algo::VectorSlide, Algo::FlatTree]
            .iter()
            .zip(&slide_refs)
        {
            let got = sliding::run_serial(*a, AddOp::<f32>::new(), &xs, 12, 16);
            assert_eq!(&got, want, "{t:?} {a:?}");
        }
        assert_eq!(
            sliding::run_serial(Algo::FlatTree, MaxOp::<f32>::new(), &xs, 9, 16),
            max_ref,
            "{t:?} flat_tree max"
        );
        assert_eq!(
            sliding::auto_with(&ex4, AddOp::<f32>::new(), &xs, 63, 64),
            auto_ref,
            "{t:?} auto parallel"
        );
        assert_eq!(
            pool1d_with(&ex1, PoolKind::Avg, &pool_x, &pool_p),
            pool_ref,
            "{t:?} pool1d avg"
        );
    }

    // Restore auto-detection for any later code in this process.
    simd::force_tier(None);
    assert!(simd::tier().is_supported());
}

#[test]
fn tier_surface_is_sane() {
    // No force_tier here: the override is process-global and the big
    // parity test owns it for this binary.
    assert!(SimdTier::Generic.is_supported());
    assert!(!SimdTier::Generic.has_fused_fma());
    assert!(SimdTier::Avx512.has_fused_fma());
    // Cross-architecture tiers are mutually exclusive.
    assert!(!(SimdTier::Sse2.is_supported() && SimdTier::Neon.is_supported()));
    assert!(!(SimdTier::Avx512.is_supported() && SimdTier::Neon.is_supported()));
    // AVX-512F implies the AVX2 tier's prerequisites on every real CPU
    // this crate targets; the dispatch order relies on it.
    if SimdTier::Avx512.is_supported() {
        assert!(SimdTier::Avx2.is_supported());
    }
}
