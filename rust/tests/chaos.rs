//! Chaos harness: fault-injection schedules against the serving
//! coordinator (`--features fault-injection`; see `docs/robustness.md`).
//!
//! The core invariant under test: **every accepted request reaches
//! exactly one terminal state** — completion, engine error, deadline
//! shed, worker-lost, or drain — no matter which fault schedule is
//! active. "Exactly one" is enforced structurally by the first-wins
//! `ResponseSlot::complete`; "at least one" (nobody hangs) is what the
//! schedules here try to break.
//!
//! The fault registry is process-global, so every test serializes on
//! [`lock`] and starts from `faults::reset()`.

#![cfg(feature = "fault-injection")]

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use swsnn::config::ServeConfig;
use swsnn::coordinator::faults::{self, FaultKind};
use swsnn::coordinator::{serve_tcp, Coordinator, Engine, ServeError, Shed, TcpClient};
use swsnn::workload::Rng;

/// Serializes chaos tests (the fault registry is process-global).
fn lock() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Injected panics are caught by the supervisor; keep their backtraces
/// out of the test output. Anything else still reaches the default hook.
fn quiet_injected_panics() {
    static ONCE: std::sync::Once = std::sync::Once::new();
    ONCE.call_once(|| {
        let default = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let payload = info.payload();
            let msg = payload
                .downcast_ref::<&str>()
                .copied()
                .map(String::from)
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_default();
            if !msg.contains("injected fault at") {
                default(info);
            }
        }));
    });
}

const ROW: usize = 4;

#[derive(Clone)]
struct EchoEngine;

impl Engine for EchoEngine {
    fn input_len(&self) -> usize {
        ROW
    }
    fn output_len(&self) -> usize {
        ROW
    }
    fn infer(&self, x: &[f32], _batch: usize) -> anyhow::Result<Vec<f32>> {
        Ok(x.to_vec())
    }
    fn name(&self) -> String {
        "chaos-echo".into()
    }
}

/// Echo engine with toy streaming sessions: steps echo their packet.
/// Exercises the coordinator's session plumbing (guards, counters,
/// eviction) without dragging real NN state into the chaos harness.
#[derive(Clone, Default)]
struct SessionEchoEngine {
    next: u32,
    live: std::collections::HashSet<u32>,
}

impl Engine for SessionEchoEngine {
    fn input_len(&self) -> usize {
        ROW
    }
    fn output_len(&self) -> usize {
        ROW
    }
    fn infer(&self, x: &[f32], _batch: usize) -> anyhow::Result<Vec<f32>> {
        Ok(x.to_vec())
    }
    fn name(&self) -> String {
        "chaos-session-echo".into()
    }
    fn session_open(&mut self) -> anyhow::Result<u32> {
        let id = self.next;
        self.next += 1;
        self.live.insert(id);
        Ok(id)
    }
    fn session_step(&mut self, id: u32, x: &[f32], out: &mut Vec<f32>) -> anyhow::Result<usize> {
        anyhow::ensure!(self.live.contains(&id), "unknown session id {id}");
        out.clear();
        out.extend_from_slice(x);
        Ok(x.len())
    }
    fn session_close(&mut self, id: u32) -> anyhow::Result<()> {
        anyhow::ensure!(self.live.remove(&id), "unknown session id {id}");
        Ok(())
    }
    fn live_sessions(&self) -> usize {
        self.live.len()
    }
}

/// Echo engine with a fixed per-batch service time — lets the soak test
/// offer a load that provably exceeds capacity.
#[derive(Clone)]
struct PacedEngine(Duration);

impl Engine for PacedEngine {
    fn input_len(&self) -> usize {
        ROW
    }
    fn output_len(&self) -> usize {
        ROW
    }
    fn infer(&self, x: &[f32], _batch: usize) -> anyhow::Result<Vec<f32>> {
        std::thread::sleep(self.0);
        Ok(x.to_vec())
    }
    fn name(&self) -> String {
        "chaos-paced".into()
    }
}

fn chaos_config(workers: usize, bucketed: bool) -> ServeConfig {
    ServeConfig {
        max_batch: 4,
        batch_deadline_us: 200,
        workers,
        queue_capacity: 64,
        batch_buckets: if bucketed { vec![1, 2, 4] } else { Vec::new() },
        restart_budget: 2,
        restart_backoff_ms: 1,
        ..Default::default()
    }
}

/// The acceptance-criteria matrix: random fault schedules × worker
/// counts {1, 2, 4, 8} × bucketed/unbucketed execution, with concurrent
/// submitters mixing blocking, non-blocking, and TTL-stamped requests.
/// Every accepted ticket must reach a terminal state, and the stats
/// ledger must balance exactly.
#[test]
fn every_request_reaches_exactly_one_terminal_state_under_chaos() {
    let _g = lock();
    quiet_injected_panics();
    let mut rng = Rng::new(0xC4A05);

    // `admission.submit` runs on the *caller's* thread, so its schedule
    // is restricted to stalls; panic schedules target worker/supervisor
    // sites, which the supervision machinery must absorb.
    const STALL_SITES: [&str; 1] = ["admission.submit"];
    const CRASH_SITES: [&str; 4] = [
        "worker.batch_collected",
        "worker.infer",
        "worker.distribute",
        "supervisor.respawn",
    ];

    for &workers in &[1usize, 2, 4, 8] {
        for &bucketed in &[false, true] {
            faults::reset();
            let n_faults = 1 + (rng.next_u64() % 3) as usize;
            let mut schedule = Vec::new();
            for _ in 0..n_faults {
                let (site, kind) = if rng.next_u64() % 4 == 0 {
                    let site = STALL_SITES[(rng.next_u64() as usize) % STALL_SITES.len()];
                    (site, FaultKind::Sleep(Duration::from_millis(1 + rng.next_u64() % 5)))
                } else {
                    let site = CRASH_SITES[(rng.next_u64() as usize) % CRASH_SITES.len()];
                    let kind = if rng.next_u64() % 2 == 0 {
                        FaultKind::Panic
                    } else {
                        FaultKind::Sleep(Duration::from_millis(1 + rng.next_u64() % 5))
                    };
                    (site, kind)
                };
                let skip = (rng.next_u64() % 8) as usize;
                let fires = 1 + (rng.next_u64() % 3) as usize;
                faults::arm(site, kind, skip, fires);
                schedule.push(format!("{site}:{kind:?} skip={skip} fires={fires}"));
            }
            let ctx = format!(
                "workers={workers} bucketed={bucketed} schedule=[{}]",
                schedule.join(", ")
            );

            let coord = Coordinator::start_replicated(EchoEngine, &chaos_config(workers, bucketed))
                .expect("startup");
            let accepted = AtomicUsize::new(0);
            let never_terminal = AtomicUsize::new(0);
            std::thread::scope(|s| {
                for t in 0..4usize {
                    let coord = &coord;
                    let accepted = &accepted;
                    let never_terminal = &never_terminal;
                    s.spawn(move || {
                        for i in 0..24usize {
                            let x = vec![(t * 100 + i) as f32; ROW];
                            let res = match i % 3 {
                                0 => coord.try_submit(x),
                                1 => coord.submit_with_ttl(x, Some(Duration::from_millis(20))),
                                _ => coord.submit(x),
                            };
                            if let Ok(ticket) = res {
                                accepted.fetch_add(1, Ordering::SeqCst);
                                if ticket.wait_timeout(Duration::from_secs(10)).is_none() {
                                    never_terminal.fetch_add(1, Ordering::SeqCst);
                                }
                            }
                        }
                    });
                }
            });
            assert_eq!(
                never_terminal.load(Ordering::SeqCst),
                0,
                "accepted request(s) never reached a terminal state ({ctx})"
            );
            let stats = coord.shutdown();
            assert_eq!(
                stats.submitted,
                accepted.load(Ordering::SeqCst) as u64,
                "accepted-ticket count disagrees with stats ({ctx})"
            );
            assert_eq!(
                stats.terminal(),
                stats.submitted,
                "terminal ledger does not balance ({ctx}): {stats:?}"
            );
        }
    }
    faults::reset();
}

/// A panic injected at `worker.infer` loses the in-flight batch with a
/// typed error, then the supervisor restarts the worker within budget
/// and serving continues on the same coordinator.
#[test]
fn injected_worker_panic_restarts_within_budget() {
    let _g = lock();
    quiet_injected_panics();
    faults::reset();
    faults::arm("worker.infer", FaultKind::Panic, 0, 1);

    let coord = Coordinator::start_replicated(EchoEngine, &chaos_config(1, false)).unwrap();
    let t = coord.submit(vec![1.0; ROW]).unwrap();
    let resp = t.wait_timeout(Duration::from_secs(10)).expect("leaked waiter");
    assert_eq!(resp.unwrap_err(), ServeError::Shed(Shed::WorkerLost));
    assert_eq!(faults::fired("worker.infer"), 1);

    let y = coord.infer(vec![2.0; ROW]).unwrap();
    assert_eq!(y, vec![2.0; ROW]);
    let stats = coord.shutdown();
    assert_eq!(stats.worker_panics, 1);
    assert_eq!(stats.worker_restarts, 1);
    assert_eq!(stats.terminal(), stats.submitted);
    faults::reset();
}

/// A panic injected *after* inference (`worker.distribute`) exercises
/// the drop-guard with results already computed: waiters still get the
/// typed `WorkerLost`, never a half-distributed batch.
#[test]
fn injected_panic_after_compute_still_yields_terminal_errors() {
    let _g = lock();
    quiet_injected_panics();
    faults::reset();
    faults::arm("worker.distribute", FaultKind::Panic, 0, 1);

    let coord = Coordinator::start_replicated(EchoEngine, &chaos_config(1, false)).unwrap();
    let t = coord.submit(vec![3.0; ROW]).unwrap();
    let resp = t.wait_timeout(Duration::from_secs(10)).expect("leaked waiter");
    assert_eq!(resp.unwrap_err(), ServeError::Shed(Shed::WorkerLost));
    let stats = coord.shutdown();
    assert_eq!(stats.worker_panics, 1);
    assert_eq!(stats.terminal(), stats.submitted);
    faults::reset();
}

/// Respawn failures burn the restart budget: with `supervisor.respawn`
/// rigged to panic on every attempt, one worker crash degrades the pool
/// to zero workers — and every ticket still terminates.
#[test]
fn respawn_panics_exhaust_budget_and_degrade() {
    let _g = lock();
    quiet_injected_panics();
    faults::reset();
    faults::arm("worker.infer", FaultKind::Panic, 0, 1);
    faults::arm("supervisor.respawn", FaultKind::Panic, 0, usize::MAX);

    let coord = Coordinator::start_replicated(EchoEngine, &chaos_config(1, false)).unwrap();
    let t = coord.submit(vec![1.0; ROW]).unwrap();
    let resp = t.wait_timeout(Duration::from_secs(10)).expect("leaked waiter");
    assert_eq!(resp.unwrap_err(), ServeError::Shed(Shed::WorkerLost));

    // Both restart attempts panicked inside the respawn path.
    let stats = coord.stats();
    assert_eq!(faults::fired("supervisor.respawn"), 2);
    assert_eq!(stats.worker_restarts, 0);
    assert_eq!(stats.live_workers, 0);
    assert_eq!(stats.terminal(), stats.submitted);
    faults::reset();
}

/// A queue stall (sleep at `worker.batch_collected`) delays batches past
/// tight TTLs: stalled requests are shed with the typed deadline error
/// instead of burning compute, and the ledger still balances.
#[test]
fn injected_stall_sheds_expired_requests() {
    let _g = lock();
    quiet_injected_panics();
    faults::reset();
    faults::arm(
        "worker.batch_collected",
        FaultKind::Sleep(Duration::from_millis(25)),
        0,
        usize::MAX,
    );

    let mut cfg = chaos_config(1, false);
    cfg.max_batch = 1; // one request per batch: each stall delays the next
    let coord = Coordinator::start_replicated(EchoEngine, &cfg).unwrap();
    let tickets: Vec<_> = (0..8)
        .map(|i| {
            coord
                .submit_with_ttl(vec![i as f32; ROW], Some(Duration::from_millis(5)))
                .unwrap()
        })
        .collect();
    let mut shed = 0u64;
    for t in tickets {
        let resp = t.wait_timeout(Duration::from_secs(10)).expect("leaked waiter");
        if resp == Err(ServeError::Shed(Shed::DeadlineExpired)) {
            shed += 1;
        }
    }
    let stats = coord.shutdown();
    assert!(shed > 0, "25ms stalls vs 5ms TTLs must shed something");
    assert_eq!(stats.shed_deadline, shed);
    assert_eq!(stats.terminal(), stats.submitted, "{stats:?}");
    faults::reset();
}

/// A panic injected at `worker.session_step` must leave the stepping
/// request in exactly one terminal state (`WorkerLost`, via the session
/// op guard), restart the worker within budget, and keep the stats
/// ledger balanced with session counters in play. The respawned worker
/// starts sessionless, so a stale id fails with a typed engine error —
/// honest, terminal, never a hang.
#[test]
fn injected_session_step_panic_stays_terminal() {
    let _g = lock();
    quiet_injected_panics();
    faults::reset();

    let cfg = chaos_config(1, false);
    let coord = Coordinator::start_replicated(SessionEchoEngine::default(), &cfg).unwrap();
    let wait = |t: swsnn::coordinator::Ticket| {
        t.wait_timeout(Duration::from_secs(10)).expect("leaked waiter")
    };
    // Open a session (response payload: one f32 whose bits are the id)
    // and step it once cleanly.
    let id = wait(coord.open_session(0).unwrap()).unwrap()[0].to_bits();
    let ok = wait(coord.step_session(id, vec![1.0; 2]).unwrap()).unwrap();
    assert_eq!(ok, vec![1.0; 2]);

    // Arm the session-step site: the injected panic fires before the
    // engine runs, the guard completes the slot with `WorkerLost`.
    faults::arm("worker.session_step", FaultKind::Panic, 0, 1);
    let resp = wait(coord.step_session(id, vec![2.0; 2]).unwrap());
    assert_eq!(resp.unwrap_err(), ServeError::Shed(Shed::WorkerLost));
    assert_eq!(faults::fired("worker.session_step"), 1);

    // The respawned worker owns no sessions: the stale id terminates
    // with a typed engine error, not a hang.
    match wait(coord.step_session(id, vec![3.0; 2]).unwrap()) {
        Err(ServeError::Engine(msg)) => {
            assert!(msg.contains("unknown session"), "{msg}")
        }
        other => panic!("stale session step must fail typed, got {other:?}"),
    }

    let stats = coord.shutdown();
    assert_eq!(stats.worker_panics, 1);
    assert_eq!(stats.worker_restarts, 1);
    assert_eq!(stats.sessions_opened, 1);
    assert_eq!(stats.session_steps, 1, "only the pre-fault step succeeded");
    assert_eq!(stats.terminal(), stats.submitted, "{stats:?}");
    faults::reset();
}

/// Satellite soak: ~4× sustained overload for a bounded wall-clock
/// budget. Queue depth stays within the configured bound, the shed
/// counters actually engage (queue-full backpressure and deadline
/// drops), and no accepted request is left without a terminal response.
#[test]
fn soak_overload_4x_sheds_and_stays_terminal() {
    let _g = lock();
    quiet_injected_panics();
    faults::reset(); // no faults: pure overload

    let cfg = ServeConfig {
        max_batch: 4,
        batch_deadline_us: 100,
        workers: 2,
        queue_capacity: 16,
        request_ttl_ms: 5, // default TTL stamped on every plain submit
        ..Default::default()
    };
    // Capacity ≈ workers · max_batch / 300µs ≈ 26k rows/s; four tight
    // submit loops offer far more than 4× that.
    let coord = Coordinator::start_replicated(PacedEngine(Duration::from_micros(300)), &cfg)
        .expect("startup");
    let budget = Duration::from_millis(800);
    let accepted = AtomicUsize::new(0);
    let offered = AtomicUsize::new(0);
    let never_terminal = AtomicUsize::new(0);
    let max_depth = AtomicUsize::new(0);

    std::thread::scope(|s| {
        for t in 0..4usize {
            let coord = &coord;
            let accepted = &accepted;
            let offered = &offered;
            let never_terminal = &never_terminal;
            s.spawn(move || {
                let start = Instant::now();
                let mut tickets = Vec::new();
                let mut i = 0usize;
                while start.elapsed() < budget {
                    let x = vec![(t * 7 + i) as f32; ROW];
                    offered.fetch_add(1, Ordering::Relaxed);
                    // Every 10th request carries an already-expired TTL
                    // so the deadline-shed path engages deterministically.
                    let res = if i % 10 == 0 {
                        coord.try_submit_with_ttl(x, Some(Duration::ZERO))
                    } else {
                        coord.try_submit(x)
                    };
                    if let Ok(ticket) = res {
                        accepted.fetch_add(1, Ordering::SeqCst);
                        tickets.push(ticket);
                    }
                    i += 1;
                    if i % 64 == 0 {
                        std::thread::yield_now();
                    }
                }
                for ticket in tickets {
                    if ticket.wait_timeout(Duration::from_secs(10)).is_none() {
                        never_terminal.fetch_add(1, Ordering::SeqCst);
                    }
                }
            });
        }
        // Sample queue depth while the flood runs: the bounded channel
        // must never report more than its configured capacity.
        let sampler_start = Instant::now();
        while sampler_start.elapsed() < budget {
            let d = coord.queue_depth();
            max_depth.fetch_max(d, Ordering::Relaxed);
            std::thread::sleep(Duration::from_millis(1));
        }
    });

    assert_eq!(
        never_terminal.load(Ordering::SeqCst),
        0,
        "soak leaked accepted requests without a terminal response"
    );
    assert!(
        max_depth.load(Ordering::Relaxed) <= cfg.queue_capacity,
        "queue depth {} exceeded capacity {}",
        max_depth.load(Ordering::Relaxed),
        cfg.queue_capacity
    );
    let stats = coord.shutdown();
    assert_eq!(stats.submitted, accepted.load(Ordering::SeqCst) as u64);
    assert!(
        stats.shed_queue_full > 0,
        "4x overload must trip queue-full backpressure: {stats:?}"
    );
    assert!(
        stats.shed_deadline > 0,
        "expired-TTL requests must be shed: {stats:?}"
    );
    assert_eq!(
        stats.terminal(),
        stats.submitted,
        "soak ledger does not balance: {stats:?}"
    );
    assert!(offered.load(Ordering::Relaxed) as u64 > 4 * stats.submitted / 2);
    faults::reset();
}

// --- Transport-tier fault injection ---------------------------------
//
// The `transport.*` sites live on connection-handler threads
// (`coordinator/transport.rs`). The invariant they attack: a fault in
// one handler kills at most that one connection — the listener keeps
// accepting, and the coordinator ledger still balances, because the
// sites fire either before submission (`accept`, `frame`) or after the
// request is already terminal (`respond`).

/// Boot a TCP server over an echo coordinator; returns the pieces the
/// test needs to drive and later drain it.
fn start_tcp(
    workers: usize,
) -> (
    Arc<Coordinator>,
    std::net::SocketAddr,
    Arc<AtomicBool>,
    std::thread::JoinHandle<()>,
) {
    let coord =
        Arc::new(Coordinator::start_replicated(EchoEngine, &chaos_config(workers, false)).unwrap());
    let stop = Arc::new(AtomicBool::new(false));
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();
    let server = {
        let coord = Arc::clone(&coord);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            serve_tcp(coord, "127.0.0.1:0", stop, move |addr| {
                addr_tx.send(addr).unwrap();
            })
            .unwrap();
        })
    };
    let addr = addr_rx.recv_timeout(Duration::from_secs(5)).unwrap();
    (coord, addr, stop, server)
}

fn drain_tcp(
    coord: Arc<Coordinator>,
    stop: Arc<AtomicBool>,
    server: std::thread::JoinHandle<()>,
) -> swsnn::coordinator::CoordinatorStats {
    stop.store(true, Ordering::SeqCst);
    server.join().unwrap();
    Arc::try_unwrap(coord)
        .ok()
        .expect("server still holds the coordinator")
        .shutdown()
}

/// A panic at `transport.accept` (handler start) kills that connection
/// before it reads a single byte; the listener accepts the next one.
#[test]
fn injected_accept_panic_kills_one_connection_not_the_listener() {
    let _g = lock();
    quiet_injected_panics();
    faults::reset();
    faults::arm("transport.accept", FaultKind::Panic, 0, 1);

    let (coord, addr, stop, server) = start_tcp(1);
    let mut doomed = TcpClient::connect(addr).unwrap();
    assert!(
        doomed.infer(&[1.0; ROW]).is_err(),
        "handler panicked before the first read; the response is an EOF"
    );
    drop(doomed);
    assert_eq!(faults::fired("transport.accept"), 1);

    let mut client = TcpClient::connect(addr).unwrap();
    assert_eq!(client.infer(&[2.0; ROW]).unwrap(), vec![2.0; ROW]);
    drop(client);
    let stats = drain_tcp(coord, stop, server);
    // The doomed connection never submitted anything.
    assert_eq!(stats.submitted, 1);
    assert_eq!(stats.terminal(), stats.submitted);
    faults::reset();
}

/// A panic at `transport.frame` fires after decode but *before*
/// submission: the request never enters the ledger, so nothing leaks.
#[test]
fn injected_frame_panic_fires_before_submission() {
    let _g = lock();
    quiet_injected_panics();
    faults::reset();
    faults::arm("transport.frame", FaultKind::Panic, 0, 1);

    let (coord, addr, stop, server) = start_tcp(1);
    let mut doomed = TcpClient::connect(addr).unwrap();
    assert!(doomed.infer(&[3.0; ROW]).is_err());
    drop(doomed);
    assert_eq!(faults::fired("transport.frame"), 1);

    let mut client = TcpClient::connect(addr).unwrap();
    assert_eq!(client.infer(&[4.0; ROW]).unwrap(), vec![4.0; ROW]);
    drop(client);
    let stats = drain_tcp(coord, stop, server);
    assert_eq!(stats.submitted, 1, "panicked frame must not be submitted");
    assert_eq!(stats.terminal(), stats.submitted);
    faults::reset();
}

/// A panic at `transport.respond` fires with the response already in
/// hand — the request is terminal (completed) even though the wire
/// write never happens. The client loses the answer; the ledger doesn't.
#[test]
fn injected_respond_panic_is_already_terminal() {
    let _g = lock();
    quiet_injected_panics();
    faults::reset();
    faults::arm("transport.respond", FaultKind::Panic, 0, 1);

    let (coord, addr, stop, server) = start_tcp(1);
    let mut doomed = TcpClient::connect(addr).unwrap();
    assert!(
        doomed.infer(&[5.0; ROW]).is_err(),
        "response was computed but the handler died before writing it"
    );
    drop(doomed);
    assert_eq!(faults::fired("transport.respond"), 1);

    let mut client = TcpClient::connect(addr).unwrap();
    assert_eq!(client.infer(&[6.0; ROW]).unwrap(), vec![6.0; ROW]);
    drop(client);
    let stats = drain_tcp(coord, stop, server);
    assert_eq!(stats.submitted, 2);
    assert_eq!(stats.completed, 2, "lost-on-the-wire request still completed");
    assert_eq!(stats.terminal(), stats.submitted);
    faults::reset();
}

/// A stalled handler (`Sleep` at `transport.frame`) delays its own
/// connection but doesn't block the listener or other connections.
#[test]
fn injected_handler_stall_does_not_block_other_connections() {
    let _g = lock();
    quiet_injected_panics();
    faults::reset();
    faults::arm(
        "transport.frame",
        FaultKind::Sleep(Duration::from_millis(200)),
        0,
        1,
    );

    let (coord, addr, stop, server) = start_tcp(1);
    let mut slow = TcpClient::connect(addr).unwrap();
    let slow_thread = std::thread::spawn(move || {
        let y = slow.infer(&[7.0; ROW]).unwrap();
        drop(slow);
        y
    });
    // While the armed handler sleeps, a second connection is served.
    std::thread::sleep(Duration::from_millis(20));
    let t0 = Instant::now();
    let mut fast = TcpClient::connect(addr).unwrap();
    assert_eq!(fast.infer(&[8.0; ROW]).unwrap(), vec![8.0; ROW]);
    assert!(
        t0.elapsed() < Duration::from_millis(150),
        "an unrelated stalled handler must not delay this connection"
    );
    drop(fast);
    assert_eq!(slow_thread.join().unwrap(), vec![7.0; ROW]);
    let stats = drain_tcp(coord, stop, server);
    assert_eq!(stats.terminal(), stats.submitted);
    faults::reset();
}
