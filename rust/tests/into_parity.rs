//! Contracts of the write-into-destination (`_into`) kernel APIs:
//!
//! 1. `_into` variants are **bit-identical** to their `Vec`-returning
//!    wrappers even when the destination starts out full of garbage —
//!    i.e. every kernel overwrites every output element (the invariant
//!    that makes buffer recycling in the serving path sound).
//! 2. `chunked_halo` edge cases: `w = 1`, `w` larger than a parallel
//!    chunk, empty input, and input shorter than `w`, across thread
//!    counts {1, 2, 4, 8}.

use swsnn::conv::{
    conv1d_sliding_with, conv1d_sliding_with_into, conv2d_sliding_with, conv2d_sliding_with_into,
    Conv1dParams, Conv2dParams,
};
use swsnn::exec::Executor;
use swsnn::nn::{ForwardScratch, Model};
use swsnn::ops::{AddOp, Epilogue, MaxOp, MulOp};
use swsnn::pool::{
    pool1d_with, pool1d_with_into, pool2d_with, pool2d_with_into, Pool1dParams, Pool2dParams,
    PoolKind,
};
use swsnn::sliding::{self, Algo, Boundary};
use swsnn::workload::Rng;

const THREADS: [usize; 4] = [1, 2, 4, 8];

/// Garbage fill that any correct kernel must fully overwrite.
const DIRT: f32 = 777.75;

#[test]
fn sliding_into_matches_vec_with_dirty_dst() {
    let mut rng = Rng::new(0x1701);
    let xs = rng.vec_uniform(150_000, -1.0, 1.0);
    let op = AddOp::<f32>::new();
    for w in [1usize, 2, 3, 7, 16, 63] {
        for algo in Algo::ALL {
            let want = sliding::run_serial(algo, op, &xs, w, 16);
            for t in THREADS {
                let ex = Executor::new(t);
                let mut out = vec![DIRT; want.len()];
                sliding::run_with_into(&ex, algo, op, &xs, w, 16, &mut out);
                assert_eq!(out, want, "{algo:?} w={w} threads={t}");
            }
        }
        let want = sliding::auto_serial(op, &xs, w, 64);
        for t in THREADS {
            let ex = Executor::new(t);
            let mut out = vec![DIRT; want.len()];
            sliding::auto_with_into(&ex, op, &xs, w, 64, &mut out);
            assert_eq!(out, want, "auto w={w} threads={t}");
        }
    }
}

#[test]
fn sliding_into_non_add_ops() {
    let mut rng = Rng::new(0x1702);
    let xs = rng.vec_uniform(80_000, -50.0, 50.0);
    let mul_xs: Vec<f32> = xs.iter().map(|v| 1.0 + 0.0001 * v).collect();
    for t in THREADS {
        let ex = Executor::new(t);
        let want = sliding::run_serial(Algo::FlatTree, MaxOp::<f32>::new(), &xs, 9, 32);
        let mut out = vec![DIRT; want.len()];
        sliding::run_with_into(&ex, Algo::FlatTree, MaxOp::<f32>::new(), &xs, 9, 32, &mut out);
        assert_eq!(out, want, "max threads={t}");

        let want = sliding::auto_serial(MulOp::<f32>::new(), &mul_xs, 5, 64);
        let mut out = vec![DIRT; want.len()];
        sliding::auto_with_into(&ex, MulOp::<f32>::new(), &mul_xs, 5, 64, &mut out);
        assert_eq!(out, want, "mul threads={t}");
    }
}

#[test]
fn chunked_halo_empty_and_short_inputs() {
    let op = AddOp::<f32>::new();
    let empty: [f32; 0] = [];
    let short = [1.0f32, 2.0];
    for t in THREADS {
        let ex = Executor::new(t);
        assert!(sliding::run_with(&ex, Algo::FlatTree, op, &empty, 3, 16).is_empty());
        assert!(sliding::auto_with(&ex, op, &empty, 1, 64).is_empty());
        // Input shorter than the window → zero outputs.
        assert!(sliding::run_with(&ex, Algo::FlatTree, op, &short, 3, 16).is_empty());
        assert!(sliding::auto_with(&ex, op, &short, 5, 64).is_empty());
        let mut out: Vec<f32> = Vec::new();
        sliding::auto_with_into(&ex, op, &short, 5, 64, &mut out);
        assert!(out.is_empty());
    }
}

#[test]
fn chunked_halo_w1_large_input() {
    // w = 1 is a copy; large enough that the chunk dispatch engages
    // (2 × 32768 outputs).
    let mut rng = Rng::new(0x1703);
    let xs = rng.vec_uniform(70_000, -1.0, 1.0);
    let want = sliding::auto_serial(AddOp::<f32>::new(), &xs, 1, 64);
    assert_eq!(want, xs);
    for t in THREADS {
        let ex = Executor::new(t);
        assert_eq!(sliding::auto_with(&ex, AddOp::<f32>::new(), &xs, 1, 64), want, "threads={t}");
    }
}

#[test]
fn chunked_halo_window_larger_than_chunk() {
    // m = 66_000 outputs, w = 40_000: with 4+ threads the chunk length
    // (~22_000) is smaller than the window, so every chunk's halo
    // extends far past the next chunk's start. Exercises both the
    // general-associative (add) and idempotent-overlap (max) flat-tree
    // paths under extreme halo overlap.
    let w = 40_000usize;
    let m = 66_000usize;
    let mut rng = Rng::new(0x1704);
    let xs = rng.vec_uniform(m + w - 1, -1.0, 1.0);
    let want_add = sliding::run_serial(Algo::FlatTree, AddOp::<f32>::new(), &xs, w, 16);
    let want_max = sliding::run_serial(Algo::FlatTree, MaxOp::<f32>::new(), &xs, w, 16);
    assert_eq!(want_add.len(), m);
    for t in THREADS {
        let ex = Executor::new(t);
        assert_eq!(
            sliding::run_with(&ex, Algo::FlatTree, AddOp::<f32>::new(), &xs, w, 16),
            want_add,
            "add threads={t}"
        );
        assert_eq!(
            sliding::run_with(&ex, Algo::FlatTree, MaxOp::<f32>::new(), &xs, w, 16),
            want_max,
            "max threads={t}"
        );
    }
}

#[test]
fn conv1d_into_matches_vec_with_dirty_dst() {
    let mut rng = Rng::new(0x1705);
    for (p, with_bias) in [
        (Conv1dParams::new(1, 1, 120_000, 9), false),
        (Conv1dParams::new(2, 3, 9_000, 5).with_batch(2), true),
        (Conv1dParams::new(1, 2, 50_000, 7).with_same_pad(), true),
        (Conv1dParams::new(2, 2, 40_000, 5).with_stride(2).with_pad(3), false),
    ] {
        let x = rng.vec_uniform(p.x_len(), -1.0, 1.0);
        let w = rng.vec_uniform(p.w_len(), -1.0, 1.0);
        let b = rng.vec_uniform(p.c_out, -0.5, 0.5);
        let bias = with_bias.then_some(b.as_slice());
        for t in THREADS {
            let ex = Executor::new(t);
            let want = conv1d_sliding_with(&ex, &x, &w, bias, &p);
            let mut y = vec![DIRT; p.y_len()];
            conv1d_sliding_with_into(&ex, &x, &w, bias, &p, Epilogue::None, &mut y);
            assert_eq!(y, want, "conv1d threads={t} {p:?}");
        }
    }
}

#[test]
fn conv2d_into_matches_vec_with_dirty_dst() {
    let mut rng = Rng::new(0x1706);
    let p = Conv2dParams::new(2, 3, 48, 40, 3, 3).with_same_pad().with_batch(2);
    let x = rng.vec_uniform(p.x_len(), -1.0, 1.0);
    let w = rng.vec_uniform(p.w_len(), -1.0, 1.0);
    for t in THREADS {
        let ex = Executor::new(t);
        let want = conv2d_sliding_with(&ex, &x, &w, None, &p);
        let mut y = vec![DIRT; p.y_len()];
        conv2d_sliding_with_into(&ex, &x, &w, None, &p, Epilogue::None, &mut y);
        assert_eq!(y, want, "conv2d threads={t}");
    }
}

#[test]
fn pool_into_matches_vec_with_dirty_dst() {
    let mut rng = Rng::new(0x1707);
    let x = rng.vec_uniform(2 * 3 * 5_000, -2.0, 2.0);
    for kind in [PoolKind::Avg, PoolKind::Max, PoolKind::Min] {
        for stride in [1usize, 4] {
            for mode in [Boundary::Valid, Boundary::SamePad] {
                let p = Pool1dParams::new(3, 5_000, 16)
                    .with_batch(2)
                    .with_stride(stride)
                    .with_boundary(mode);
                for t in THREADS {
                    let ex = Executor::new(t);
                    let want = pool1d_with(&ex, kind, &x, &p);
                    let mut y = vec![DIRT; p.y_len()];
                    pool1d_with_into(&ex, kind, &x, &p, &mut y);
                    assert_eq!(y, want, "pool1d {kind:?} s={stride} {mode:?} threads={t}");
                }
            }
        }
    }
    let p2 = Pool2dParams::new(4, 48, 48, 3, 3).with_batch(2).with_strides(2, 2);
    let x2 = rng.vec_uniform(2 * 4 * 48 * 48, -3.0, 3.0);
    for kind in [PoolKind::Avg, PoolKind::Max, PoolKind::Min] {
        for t in THREADS {
            let ex = Executor::new(t);
            let want = pool2d_with(&ex, kind, &x2, &p2);
            let mut y = vec![DIRT; p2.y_len()];
            pool2d_with_into(&ex, kind, &x2, &p2, &mut y);
            assert_eq!(y, want, "pool2d {kind:?} threads={t}");
        }
    }
}

#[test]
fn model_forward_into_recycles_buffers_bit_identically() {
    let cfg = r#"
[model]
name = "t"
c_in = 2
seq_len = 96

[layer.0]
type = "conv"
c_out = 4
k = 5
same_pad = true
relu = true

[layer.1]
type = "residual"
k = 3
dilation = 2

[layer.2]
type = "pool"
kind = "max"
w = 2
stride = 2

[layer.3]
type = "dense"
out = 3
"#;
    let (mc, _) = swsnn::config::load_config(cfg).unwrap();
    let mut rng = Rng::new(0x1708);
    let model = Model::init(&mc, &mut rng).unwrap();
    let mut scratch = ForwardScratch::default();
    let mut out = Vec::new();
    // Run several different inputs through the SAME scratch: stale
    // activations from request i must not leak into request i+1.
    for backend in [
        swsnn::conv::ConvBackend::Sliding,
        swsnn::conv::ConvBackend::Im2colGemm,
    ] {
        for i in 0..4 {
            let x = rng.vec_uniform(2 * 96, -1.0, 1.0);
            let want = model.forward(&x, 1, backend).unwrap();
            let (c, n) = model
                .forward_into(&x, 1, backend, &mut scratch, &mut out)
                .unwrap();
            assert_eq!(out, want.data, "{backend:?} request {i}");
            assert_eq!(want.shape, vec![1, c], "n={n}");
        }
    }
}
