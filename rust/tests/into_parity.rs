//! Contracts of the write-into-destination (`_into`) kernel APIs:
//!
//! 1. `_into` variants are **bit-identical** to their `Vec`-returning
//!    wrappers even when the destination starts out full of garbage —
//!    i.e. every kernel overwrites every output element (the invariant
//!    that makes buffer recycling in the serving path sound).
//! 2. `chunked_halo` edge cases: `w = 1`, `w` larger than a parallel
//!    chunk, empty input, and input shorter than `w`, across thread
//!    counts {1, 2, 4, 8}.

use swsnn::conv::{
    conv1d_direct, conv1d_direct_into, conv1d_im2col_epilogue_into, conv1d_im2col_with,
    conv1d_quantized, conv1d_quantized_into, conv1d_sliding_into, conv1d_sliding_with,
    conv1d_sliding_with_into, conv2d_sliding, conv2d_sliding_into, conv2d_sliding_with,
    conv2d_sliding_with_into, im2col_expand, im2col_expand_into, quantized_scratch_len,
    Conv1dParams, Conv2dParams, QuantParams,
};
use swsnn::exec::Executor;
use swsnn::nn::{ForwardScratch, Model};
use swsnn::ops::{AddOp, Epilogue, MaxOp, MulOp};
use swsnn::pool::{
    pool1d, pool1d_into, pool1d_overlap_strided_with_into, pool1d_row_dense_into,
    pool1d_row_dense_with, pool1d_with, pool1d_with_into, pool2d, pool2d_into, pool2d_with,
    pool2d_with_into, Pool1dParams, Pool2dParams, PoolKind, POOL_SCRATCH_TASKS,
};
use swsnn::sliding::{self, Algo, Boundary};
use swsnn::workload::Rng;

const THREADS: [usize; 4] = [1, 2, 4, 8];

/// Garbage fill that any correct kernel must fully overwrite.
const DIRT: f32 = 777.75;

#[test]
fn sliding_into_matches_vec_with_dirty_dst() {
    let mut rng = Rng::new(0x1701);
    let xs = rng.vec_uniform(150_000, -1.0, 1.0);
    let op = AddOp::<f32>::new();
    for w in [1usize, 2, 3, 7, 16, 63] {
        for algo in Algo::ALL {
            let want = sliding::run_serial(algo, op, &xs, w, 16);
            for t in THREADS {
                let ex = Executor::new(t);
                let mut out = vec![DIRT; want.len()];
                sliding::run_with_into(&ex, algo, op, &xs, w, 16, &mut out);
                assert_eq!(out, want, "{algo:?} w={w} threads={t}");
            }
        }
        let want = sliding::auto_serial(op, &xs, w, 64);
        for t in THREADS {
            let ex = Executor::new(t);
            let mut out = vec![DIRT; want.len()];
            sliding::auto_with_into(&ex, op, &xs, w, 64, &mut out);
            assert_eq!(out, want, "auto w={w} threads={t}");
        }
    }
}

#[test]
fn sliding_into_non_add_ops() {
    let mut rng = Rng::new(0x1702);
    let xs = rng.vec_uniform(80_000, -50.0, 50.0);
    let mul_xs: Vec<f32> = xs.iter().map(|v| 1.0 + 0.0001 * v).collect();
    for t in THREADS {
        let ex = Executor::new(t);
        let want = sliding::run_serial(Algo::FlatTree, MaxOp::<f32>::new(), &xs, 9, 32);
        let mut out = vec![DIRT; want.len()];
        sliding::run_with_into(&ex, Algo::FlatTree, MaxOp::<f32>::new(), &xs, 9, 32, &mut out);
        assert_eq!(out, want, "max threads={t}");

        let want = sliding::auto_serial(MulOp::<f32>::new(), &mul_xs, 5, 64);
        let mut out = vec![DIRT; want.len()];
        sliding::auto_with_into(&ex, MulOp::<f32>::new(), &mul_xs, 5, 64, &mut out);
        assert_eq!(out, want, "mul threads={t}");
    }
}

#[test]
fn chunked_halo_empty_and_short_inputs() {
    let op = AddOp::<f32>::new();
    let empty: [f32; 0] = [];
    let short = [1.0f32, 2.0];
    for t in THREADS {
        let ex = Executor::new(t);
        assert!(sliding::run_with(&ex, Algo::FlatTree, op, &empty, 3, 16).is_empty());
        assert!(sliding::auto_with(&ex, op, &empty, 1, 64).is_empty());
        // Input shorter than the window → zero outputs.
        assert!(sliding::run_with(&ex, Algo::FlatTree, op, &short, 3, 16).is_empty());
        assert!(sliding::auto_with(&ex, op, &short, 5, 64).is_empty());
        let mut out: Vec<f32> = Vec::new();
        sliding::auto_with_into(&ex, op, &short, 5, 64, &mut out);
        assert!(out.is_empty());
    }
}

#[test]
fn chunked_halo_w1_large_input() {
    // w = 1 is a copy; large enough that the chunk dispatch engages
    // (2 × 32768 outputs).
    let mut rng = Rng::new(0x1703);
    let xs = rng.vec_uniform(70_000, -1.0, 1.0);
    let want = sliding::auto_serial(AddOp::<f32>::new(), &xs, 1, 64);
    assert_eq!(want, xs);
    for t in THREADS {
        let ex = Executor::new(t);
        assert_eq!(sliding::auto_with(&ex, AddOp::<f32>::new(), &xs, 1, 64), want, "threads={t}");
    }
}

#[test]
fn chunked_halo_window_larger_than_chunk() {
    // m = 66_000 outputs, w = 40_000: with 4+ threads the chunk length
    // (~22_000) is smaller than the window, so every chunk's halo
    // extends far past the next chunk's start. Exercises both the
    // general-associative (add) and idempotent-overlap (max) flat-tree
    // paths under extreme halo overlap.
    let w = 40_000usize;
    let m = 66_000usize;
    let mut rng = Rng::new(0x1704);
    let xs = rng.vec_uniform(m + w - 1, -1.0, 1.0);
    let want_add = sliding::run_serial(Algo::FlatTree, AddOp::<f32>::new(), &xs, w, 16);
    let want_max = sliding::run_serial(Algo::FlatTree, MaxOp::<f32>::new(), &xs, w, 16);
    assert_eq!(want_add.len(), m);
    for t in THREADS {
        let ex = Executor::new(t);
        assert_eq!(
            sliding::run_with(&ex, Algo::FlatTree, AddOp::<f32>::new(), &xs, w, 16),
            want_add,
            "add threads={t}"
        );
        assert_eq!(
            sliding::run_with(&ex, Algo::FlatTree, MaxOp::<f32>::new(), &xs, w, 16),
            want_max,
            "max threads={t}"
        );
    }
}

#[test]
fn conv1d_into_matches_vec_with_dirty_dst() {
    let mut rng = Rng::new(0x1705);
    for (p, with_bias) in [
        (Conv1dParams::new(1, 1, 120_000, 9), false),
        (Conv1dParams::new(2, 3, 9_000, 5).with_batch(2), true),
        (Conv1dParams::new(1, 2, 50_000, 7).with_same_pad(), true),
        (Conv1dParams::new(2, 2, 40_000, 5).with_stride(2).with_pad(3), false),
    ] {
        let x = rng.vec_uniform(p.x_len(), -1.0, 1.0);
        let w = rng.vec_uniform(p.w_len(), -1.0, 1.0);
        let b = rng.vec_uniform(p.c_out, -0.5, 0.5);
        let bias = with_bias.then_some(b.as_slice());
        for t in THREADS {
            let ex = Executor::new(t);
            let want = conv1d_sliding_with(&ex, &x, &w, bias, &p);
            let mut y = vec![DIRT; p.y_len()];
            conv1d_sliding_with_into(&ex, &x, &w, bias, &p, Epilogue::None, &mut y);
            assert_eq!(y, want, "conv1d threads={t} {p:?}");
        }
    }
}

#[test]
fn conv2d_into_matches_vec_with_dirty_dst() {
    let mut rng = Rng::new(0x1706);
    let p = Conv2dParams::new(2, 3, 48, 40, 3, 3).with_same_pad().with_batch(2);
    let x = rng.vec_uniform(p.x_len(), -1.0, 1.0);
    let w = rng.vec_uniform(p.w_len(), -1.0, 1.0);
    for t in THREADS {
        let ex = Executor::new(t);
        let want = conv2d_sliding_with(&ex, &x, &w, None, &p);
        let mut y = vec![DIRT; p.y_len()];
        conv2d_sliding_with_into(&ex, &x, &w, None, &p, Epilogue::None, &mut y);
        assert_eq!(y, want, "conv2d threads={t}");
    }
}

#[test]
fn quantized_into_matches_vec_with_dirty_dst() {
    let mut rng = Rng::new(0x170D);
    for p in [
        Conv1dParams::new(2, 3, 4_000, 5).with_batch(2).with_same_pad(),
        Conv1dParams::new(1, 2, 6_001, 7).with_stride(2).with_dilation(2).with_pad(3),
    ] {
        let x = rng.vec_uniform(p.x_len(), -1.0, 1.0);
        let w = rng.vec_uniform(p.w_len(), -1.0, 1.0);
        let xp = QuantParams::from_slice(&x);
        let wp = QuantParams::from_slice(&w);

        // quantize_slice_into over a dirty destination matches the
        // Vec-returning form.
        let mut qx = vec![-77i8; x.len()];
        xp.quantize_slice_into(&x, &mut qx);
        assert_eq!(qx, xp.quantize_slice(&x), "quantize_slice_into {p:?}");
        let qw = wp.quantize_slice(&w);

        // conv1d_quantized_into with dirty i32 scratch AND dirty f32
        // dst is bitwise equal to the allocating wrapper.
        let want = conv1d_quantized(&qx, &qw, xp, wp, &p);
        let mut acc = vec![i32::MIN; quantized_scratch_len(&p)];
        let mut y = vec![DIRT; p.y_len()];
        conv1d_quantized_into(&qx, &qw, xp, wp, None, &p, Epilogue::None, &mut acc, &mut y);
        assert_eq!(y, want, "conv1d_quantized_into {p:?}");
    }
}

#[test]
fn pool_into_matches_vec_with_dirty_dst() {
    let mut rng = Rng::new(0x1707);
    let x = rng.vec_uniform(2 * 3 * 5_000, -2.0, 2.0);
    for kind in [PoolKind::Avg, PoolKind::Max, PoolKind::Min] {
        for stride in [1usize, 4] {
            for mode in [Boundary::Valid, Boundary::SamePad] {
                let p = Pool1dParams::new(3, 5_000, 16)
                    .with_batch(2)
                    .with_stride(stride)
                    .with_boundary(mode);
                for t in THREADS {
                    let ex = Executor::new(t);
                    let want = pool1d_with(&ex, kind, &x, &p);
                    let mut y = vec![DIRT; p.y_len()];
                    pool1d_with_into(&ex, kind, &x, &p, &mut y);
                    assert_eq!(y, want, "pool1d {kind:?} s={stride} {mode:?} threads={t}");
                }
            }
        }
    }
    let p2 = Pool2dParams::new(4, 48, 48, 3, 3).with_batch(2).with_strides(2, 2);
    let x2 = rng.vec_uniform(2 * 4 * 48 * 48, -3.0, 3.0);
    for kind in [PoolKind::Avg, PoolKind::Max, PoolKind::Min] {
        for t in THREADS {
            let ex = Executor::new(t);
            let want = pool2d_with(&ex, kind, &x2, &p2);
            let mut y = vec![DIRT; p2.y_len()];
            pool2d_with_into(&ex, kind, &x2, &p2, &mut y);
            assert_eq!(y, want, "pool2d {kind:?} threads={t}");
        }
    }
}

#[test]
fn serial_sliding_into_variants_match_vec_with_dirty_dst() {
    use swsnn::sliding::scalar_input::{
        sliding_scalar_input_unbounded, sliding_scalar_input_unbounded_into,
    };
    use swsnn::sliding::{
        sliding_flat_tree, sliding_flat_tree_into, sliding_naive, sliding_naive_into,
        sliding_scalar_input, sliding_scalar_input_into, sliding_vector_slide,
        sliding_vector_slide_into, sliding_vector_slide_tree, sliding_vector_slide_tree_into,
        sliding_w2, sliding_w2_into,
    };
    let mut rng = Rng::new(0x1709);
    let xs = rng.vec_uniform(4_096, -1.0, 1.0);
    let op = AddOp::<f32>::new();
    const P: usize = 16;
    for w in [1usize, 2, 3, 8, 15] {
        let m = xs.len() - w + 1;

        let mut out = vec![DIRT; m];
        sliding_naive_into(op, &xs, w, &mut out);
        assert_eq!(out, sliding_naive(op, &xs, w), "naive w={w}");

        let mut out = vec![DIRT; m];
        sliding_flat_tree_into(op, &xs, w, &mut out);
        assert_eq!(out, sliding_flat_tree(op, &xs, w), "flat_tree w={w}");

        let mut out = vec![DIRT; m];
        sliding_scalar_input_into(op, &xs, w, P, &mut out);
        assert_eq!(out, sliding_scalar_input(op, &xs, w, P), "scalar_input w={w}");

        let mut out = vec![DIRT; m];
        sliding_scalar_input_unbounded_into(op, &xs, w, &mut out);
        assert_eq!(
            out,
            sliding_scalar_input_unbounded(op, &xs, w),
            "scalar_input_unbounded w={w}"
        );

        let mut out = vec![DIRT; m];
        sliding_vector_slide_into(op, &xs, w, P, &mut out);
        assert_eq!(out, sliding_vector_slide(op, &xs, w, P), "vector_slide w={w}");

        let mut out = vec![DIRT; m];
        sliding_vector_slide_tree_into(op, &xs, w, P, &mut out);
        assert_eq!(
            out,
            sliding_vector_slide_tree(op, &xs, w, P),
            "vector_slide_tree w={w}"
        );

        for algo in Algo::ALL {
            let mut out = vec![DIRT; m];
            sliding::run_serial_into(algo, op, &xs, w, P, &mut out);
            assert_eq!(out, sliding::run_serial(algo, op, &xs, w, P), "{algo:?} w={w}");
        }

        let mut out = vec![DIRT; m];
        sliding::auto_serial_into(op, &xs, w, 64, &mut out);
        assert_eq!(out, sliding::auto_serial(op, &xs, w, 64), "auto_serial w={w}");

        // Global-executor convenience wrapper: the chunked dispatch it
        // delegates to is bit-identical to the serial sweep.
        let mut out = vec![DIRT; m];
        sliding::auto_into(op, &xs, w, 64, &mut out);
        assert_eq!(out, sliding::auto_serial(op, &xs, w, 64), "auto w={w}");
    }
    let mut out = vec![DIRT; xs.len() - 1];
    sliding_w2_into(op, &xs, &mut out);
    assert_eq!(out, sliding_w2(op, &xs), "w2");
}

#[test]
fn streaming_push_slice_into_overwrites_nan_poisoned_dst() {
    // The streaming accumulator's `_into` form must honor the same
    // overwrite-everything contract as the batch kernels: a NaN-filled
    // destination comes out bit-identical to the batch oracle on the
    // same prefix (any unwritten element would surface as a NaN, and
    // NaN != NaN fails the comparison). Packets split at awkward sizes
    // so emission starts and stops mid-packet.
    use swsnn::simd::MAX_LANES;
    use swsnn::sliding::{sliding_scalar_input, StreamingSlidingSum};
    let mut rng = Rng::new(0x170E);
    let xs = rng.vec_uniform(333, -2.0, 2.0);
    for w in [1usize, 2, 5, 16] {
        let want = sliding_scalar_input(AddOp::<f32>::new(), &xs, w, MAX_LANES);
        let mut s = StreamingSlidingSum::new(AddOp::<f32>::new(), w);
        let mut got: Vec<f32> = Vec::new();
        for chunk in xs.chunks(7) {
            let mut dst = vec![f32::NAN; s.pending_out_len(chunk.len())];
            s.push_slice_into(chunk, &mut dst);
            got.extend_from_slice(&dst);
        }
        let gb: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
        let wb: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
        assert_eq!(gb, wb, "w={w}");
        assert!(got.iter().all(|v| v.is_finite()), "NaN leaked w={w}");
    }
}

#[test]
fn conv_into_convenience_and_im2col_match_vec_with_dirty_dst() {
    let mut rng = Rng::new(0x170A);
    let p = Conv1dParams::new(2, 3, 6_000, 5).with_batch(2);
    let x = rng.vec_uniform(p.x_len(), -1.0, 1.0);
    let w = rng.vec_uniform(p.w_len(), -1.0, 1.0);
    let b = rng.vec_uniform(p.c_out, -0.5, 0.5);
    let bias = Some(b.as_slice());

    // Global-executor wrapper: chunk dispatch is bit-identical across
    // thread counts, so the 1-thread reference is exact.
    let want = conv1d_sliding_with(&Executor::new(1), &x, &w, bias, &p);
    let mut y = vec![DIRT; p.y_len()];
    conv1d_sliding_into(&x, &w, bias, &p, Epilogue::None, &mut y);
    assert_eq!(y, want, "conv1d_sliding_into");

    let mut y = vec![DIRT; p.y_len()];
    conv1d_direct_into(&x, &w, bias, &p, &mut y);
    assert_eq!(y, conv1d_direct(&x, &w, bias, &p), "conv1d_direct_into");

    // im2col: the expansion and the epilogue-fused GEMM path against
    // their Vec-returning forms (same backend, so exact equality).
    let p1 = Conv1dParams::new(3, 2, 400, 7).with_dilation(2);
    let x1 = rng.vec_uniform(p1.x_len(), -1.0, 1.0);
    let w1 = rng.vec_uniform(p1.w_len(), -1.0, 1.0);
    let mut cols = vec![DIRT; p1.c_in * p1.k * p1.n_out()];
    im2col_expand_into(&x1, &p1, &mut cols);
    assert_eq!(cols, im2col_expand(&x1, &p1), "im2col_expand_into");

    for t in THREADS {
        let ex = Executor::new(t);
        let want = conv1d_im2col_with(&ex, &x1, &w1, None, &p1);
        let mut y = vec![DIRT; p1.y_len()];
        let mut col = vec![DIRT; p1.c_in * p1.k * p1.n_out()];
        conv1d_im2col_epilogue_into(&ex, &x1, &w1, None, &p1, Epilogue::None, &mut col, &mut y);
        assert_eq!(y, want, "conv1d_im2col_epilogue_into threads={t}");
    }

    let p2 = Conv2dParams::new(2, 2, 24, 20, 3, 3).with_same_pad();
    let x2 = rng.vec_uniform(p2.x_len(), -1.0, 1.0);
    let w2 = rng.vec_uniform(p2.w_len(), -1.0, 1.0);
    let want = conv2d_sliding(&x2, &w2, None, &p2);
    let mut y = vec![DIRT; p2.y_len()];
    conv2d_sliding_into(&x2, &w2, None, &p2, Epilogue::None, &mut y);
    assert_eq!(y, want, "conv2d_sliding_into");
}

#[test]
fn pool_convenience_and_row_dense_into_match_vec() {
    let mut rng = Rng::new(0x170B);
    let p = Pool1dParams::new(3, 4_000, 8).with_batch(2).with_stride(2);
    let x = rng.vec_uniform(2 * 3 * 4_000, -2.0, 2.0);
    for kind in [PoolKind::Avg, PoolKind::Max, PoolKind::Min] {
        let mut y = vec![DIRT; p.y_len()];
        pool1d_into(kind, &x, &p, &mut y);
        assert_eq!(y, pool1d(kind, &x, &p), "pool1d_into {kind:?}");
    }
    let p2 = Pool2dParams::new(2, 24, 24, 2, 2);
    let x2 = rng.vec_uniform(2 * 24 * 24, -2.0, 2.0);
    for kind in [PoolKind::Avg, PoolKind::Max, PoolKind::Min] {
        let mut y = vec![DIRT; p2.y_len()];
        pool2d_into(kind, &x2, &p2, &mut y);
        assert_eq!(y, pool2d(kind, &x2, &p2), "pool2d_into {kind:?}");
    }
    // Dense per-row windows across boundary modes.
    let row = rng.vec_uniform(777, -2.0, 2.0);
    for mode in [Boundary::Valid, Boundary::SamePad] {
        for kind in [PoolKind::Avg, PoolKind::Max, PoolKind::Min] {
            for t in THREADS {
                let ex = Executor::new(t);
                let want = pool1d_row_dense_with(&ex, kind, &row, 9, mode);
                let mut dst = vec![DIRT; want.len()];
                pool1d_row_dense_into(&ex, kind, &row, 9, mode, &mut dst);
                assert_eq!(dst, want, "row_dense {kind:?} {mode:?} threads={t}");
            }
        }
    }
}

#[test]
fn pool_overlap_strided_into_overwrites_nan_poisoned_dst() {
    // NaN is the nastiest dirt: any blend of an unwritten destination
    // element into the output propagates it, so exact equality with the
    // Vec-returning reference proves every element (of `y` *and* of the
    // consulted scratch prefix) was freshly produced. Covers both the
    // serial path and the task fan-out (rows > POOL_SCRATCH_TASKS).
    let mut rng = Rng::new(0x170C);
    for (channels, n) in [(3usize, 5_000usize), (40, 2_000)] {
        let p = Pool1dParams::new(channels, n, 7).with_batch(2).with_stride(3);
        let x = rng.vec_uniform(2 * channels * n, -2.0, 2.0);
        let tasks = (2 * channels).min(POOL_SCRATCH_TASKS);
        for kind in [PoolKind::Avg, PoolKind::Max, PoolKind::Min] {
            for t in THREADS {
                let ex = Executor::new(t);
                let want = pool1d_with(&ex, kind, &x, &p);
                let mut dense = vec![f32::NAN; tasks * p.dense_len()];
                let mut y = vec![f32::NAN; p.y_len()];
                pool1d_overlap_strided_with_into(&ex, kind, &x, &p, &mut dense, &mut y);
                assert_eq!(y, want, "{kind:?} channels={channels} threads={t}");
                assert!(
                    y.iter().all(|v| v.is_finite()),
                    "NaN leaked through {kind:?} channels={channels} threads={t}"
                );
            }
        }
    }
}

#[test]
fn model_forward_into_recycles_buffers_bit_identically() {
    let cfg = r#"
[model]
name = "t"
c_in = 2
seq_len = 96

[layer.0]
type = "conv"
c_out = 4
k = 5
same_pad = true
relu = true

[layer.1]
type = "residual"
k = 3
dilation = 2

[layer.2]
type = "pool"
kind = "max"
w = 2
stride = 2

[layer.3]
type = "dense"
out = 3
"#;
    let (mc, _) = swsnn::config::load_config(cfg).unwrap();
    let mut rng = Rng::new(0x1708);
    let model = Model::init(&mc, &mut rng).unwrap();
    let mut scratch = ForwardScratch::default();
    let mut out = Vec::new();
    // Run several different inputs through the SAME scratch: stale
    // activations from request i must not leak into request i+1.
    for backend in [
        swsnn::conv::ConvBackend::Sliding,
        swsnn::conv::ConvBackend::Im2colGemm,
    ] {
        for i in 0..4 {
            let x = rng.vec_uniform(2 * 96, -1.0, 1.0);
            let want = model.forward(&x, 1, backend).unwrap();
            let (c, n) = model
                .forward_into(&x, 1, backend, &mut scratch, &mut out)
                .unwrap();
            assert_eq!(out, want.data, "{backend:?} request {i}");
            assert_eq!(want.shape, vec![1, c], "n={n}");
        }
    }
}
