//! Contract of the compiled execution plans (`nn::plan`):
//!
//! 1. `Plan::run_into` is **bit-identical** to the eager reference path
//!    (`Model::forward_eager_into`) for every fixed backend, across
//!    random models (conv/pool/residual/dense mixes), batch sizes,
//!    dirty reused arenas, forced SIMD tiers, and thread counts
//!    {1, 2, 4, 8}.
//! 2. `Model::forward_into` (the compile-then-run wrapper) agrees with
//!    both.
//! 3. Per-layer TOML `backend =` overrides beat the deployment-level
//!    choice, and `Auto` plans stay numerically faithful to the direct
//!    oracle.
//! 4. Empty models fail at `init`/`compile` time, not at serve time.
//! 5. **Fused chain** plans (conv/pool runs swept through ring-buffer
//!    tiles) are bit-identical to the unfused plan and to the eager
//!    path, across tiers, threads, and dirty arenas (see also
//!    `tests/chain_fusion.rs` for the randomized halo-arithmetic
//!    sweep).
//! 6. **Autotuned** plans are bit-identical to the eager path with each
//!    layer's backend pinned to the plan's measured choice (small_k maps
//!    to sliding — the two share the exact per-output fused chain,
//!    pinned below).

use swsnn::config::{LayerConfig, ModelConfig};
use swsnn::conv::{
    conv1d_sliding, conv1d_small_k_into, BackendChoice, Conv1dParams, ConvBackend,
};
use swsnn::exec::Executor;
use swsnn::nn::{EagerScratch, Model, Plan, PlanKernel, PlanScratch, PlannerConfig};
use swsnn::ops::Epilogue;
use swsnn::simd::{self, SimdTier};
use swsnn::workload::Rng;

const THREADS: [usize; 4] = [1, 2, 4, 8];

/// Random layer stack. Shapes that collapse to an empty output are
/// rejected by `Model::init`, so the generator only has to be *mostly*
/// right; callers skip configs init refuses.
fn random_config(rng: &mut Rng, idx: usize) -> ModelConfig {
    let c_in = 1 + rng.below(3);
    let seq_len = 24 + rng.below(72);
    let n_layers = 1 + rng.below(4);
    let mut layers = Vec::new();
    for li in 0..n_layers {
        if li + 1 == n_layers && rng.below(3) == 0 {
            layers.push(LayerConfig::Dense {
                out: 1 + rng.below(5),
                relu: rng.below(2) == 0,
            });
            break;
        }
        match rng.below(5) {
            0 => layers.push(LayerConfig::Pool {
                kind: ["max", "avg", "min"][rng.below(3)].to_string(),
                w: 2,
                stride: 2,
            }),
            1 => layers.push(LayerConfig::Residual {
                k: 3,
                dilation: 1 + rng.below(3),
                backend: None,
            }),
            _ => layers.push(LayerConfig::Conv {
                c_out: 1 + rng.below(6),
                k: [1, 2, 3, 5, 7][rng.below(5)],
                stride: 1 + rng.below(2),
                dilation: 1 + rng.below(2),
                same_pad: rng.below(4) != 0,
                relu: rng.below(2) == 0,
                backend: None,
                quantize: false,
            }),
        }
    }
    ModelConfig {
        name: format!("rand{idx}"),
        c_in,
        seq_len,
        layers,
    }
}

/// The SIMD tiers worth forcing on this host: the portable oracle plus
/// whatever the hardware actually dispatches.
fn tiers() -> Vec<SimdTier> {
    let mut ts = vec![SimdTier::Generic];
    for t in [SimdTier::Avx2, SimdTier::Sse2, SimdTier::Neon] {
        if t.is_supported() {
            ts.push(t);
        }
    }
    ts
}

#[test]
fn plan_bit_identical_to_eager_across_random_models() {
    let mut rng = Rng::new(0x9147);
    // Dirty reused scratch: one plan arena and one eager scratch shared
    // across every model/backend/batch — stale contents must never leak.
    let mut plan_scratch = PlanScratch::default();
    let mut eager_scratch = EagerScratch::default();
    let mut built = 0usize;
    let mut attempts = 0usize;
    while built < 10 && attempts < 60 {
        attempts += 1;
        let mc = random_config(&mut rng, attempts);
        let Ok(model) = Model::init(&mc, &mut Rng::new(attempts as u64)) else {
            continue; // generator produced a shape that collapses — fine
        };
        built += 1;
        let batch = [1usize, 2, 5][built % 3];
        let x = rng.vec_uniform(batch * mc.c_in * mc.seq_len, -1.0, 1.0);
        for backend in [
            ConvBackend::Sliding,
            ConvBackend::Im2colGemm,
            ConvBackend::Direct,
            ConvBackend::SlidingPair,
        ] {
            let mut want = Vec::new();
            model
                .forward_eager_into(&x, batch, backend, &mut eager_scratch, &mut want)
                .unwrap();
            let cfg = PlannerConfig {
                backend: BackendChoice::Fixed(backend),
                ..Default::default()
            };
            let plan = Plan::compile(&model, batch, &cfg).unwrap();
            let threads = THREADS[(built + backend as usize) % THREADS.len()];
            let ex = Executor::new(threads);
            let mut got = Vec::new();
            plan.run_with_into(&ex, &model, &x, &mut plan_scratch, &mut got)
                .unwrap();
            assert_eq!(
                got, want,
                "model {} batch {batch} backend {backend:?} threads {threads}: plan != eager",
                mc.name
            );
        }
    }
    assert!(built >= 8, "generator rejected too many configs ({built}/10)");
}

#[test]
fn plan_parity_under_forced_simd_tiers_and_threads() {
    const CFG_TOML: &str = r#"
[model]
name = "tiered"
c_in = 2
seq_len = 96

[layer.0]
type = "conv"
c_out = 8
k = 7

[layer.1]
type = "residual"
k = 3
dilation = 2

[layer.2]
type = "pool"
kind = "max"
w = 2
stride = 2

[layer.3]
type = "dense"
out = 3
"#;
    let (mc, _) = swsnn::config::load_config(CFG_TOML).unwrap();
    let model = Model::init(&mc, &mut Rng::new(31)).unwrap();
    let mut rng = Rng::new(32);
    let x = rng.vec_uniform(2 * 2 * 96, -1.0, 1.0);
    let mut plan_scratch = PlanScratch::default();
    for tier in tiers() {
        simd::force_tier(Some(tier));
        for backend in [ConvBackend::Sliding, ConvBackend::Im2colGemm] {
            let mut want = Vec::new();
            model
                .forward_eager_into(&x, 2, backend, &mut EagerScratch::default(), &mut want)
                .unwrap();
            let cfg = PlannerConfig {
                backend: BackendChoice::Fixed(backend),
                ..Default::default()
            };
            let plan = Plan::compile(&model, 2, &cfg).unwrap();
            for threads in THREADS {
                let ex = Executor::new(threads);
                let mut got = Vec::new();
                plan.run_with_into(&ex, &model, &x, &mut plan_scratch, &mut got)
                    .unwrap();
                assert_eq!(got, want, "tier {tier:?} backend {backend:?} threads {threads}");
            }
        }
    }
    simd::force_tier(None);
}

#[test]
fn forward_into_wrapper_matches_plan_and_eager() {
    let mut rng = Rng::new(0x77);
    let mc = ModelConfig {
        name: "wrap".into(),
        c_in: 1,
        seq_len: 64,
        layers: vec![
            LayerConfig::Conv {
                c_out: 4,
                k: 5,
                stride: 1,
                dilation: 1,
                same_pad: true,
                relu: true,
                backend: None,
                quantize: false,
            },
            LayerConfig::Residual { k: 3, dilation: 2, backend: None },
            LayerConfig::Dense { out: 3, relu: false },
        ],
    };
    let model = Model::init(&mc, &mut Rng::new(5)).unwrap();
    let mut fw_scratch = swsnn::nn::ForwardScratch::default();
    for i in 0..3 {
        let batch = 1 + i;
        let x = rng.vec_uniform(batch * 64, -1.0, 1.0);
        let mut eager = Vec::new();
        let mut es = EagerScratch::default();
        model
            .forward_eager_into(&x, batch, ConvBackend::Sliding, &mut es, &mut eager)
            .unwrap();
        let mut wrapped = Vec::new();
        let (c, n) = model
            .forward_into(&x, batch, ConvBackend::Sliding, &mut fw_scratch, &mut wrapped)
            .unwrap();
        assert_eq!((c, n), model.out_shape());
        assert_eq!(wrapped, eager, "batch {batch}");
    }
}

#[test]
fn per_layer_override_beats_fixed_choice() {
    let mc = ModelConfig {
        name: "override".into(),
        c_in: 1,
        seq_len: 48,
        layers: vec![
            LayerConfig::Conv {
                c_out: 4,
                k: 5,
                stride: 1,
                dilation: 1,
                same_pad: true,
                relu: true,
                backend: Some(ConvBackend::Im2colGemm),
                quantize: false,
            },
            LayerConfig::Residual { k: 3, dilation: 1, backend: Some(ConvBackend::Direct) },
        ],
    };
    let model = Model::init(&mc, &mut Rng::new(6)).unwrap();
    let cfg = PlannerConfig {
        backend: BackendChoice::Fixed(ConvBackend::Sliding),
        ..Default::default()
    };
    let plan = Plan::compile(&model, 1, &cfg).unwrap();
    assert_eq!(plan.kernels(), vec![PlanKernel::Im2col, PlanKernel::Direct]);
    // Overrides apply identically on the eager path → still bit-equal.
    let mut rng = Rng::new(8);
    let x = rng.vec_uniform(48, -1.0, 1.0);
    let mut want = Vec::new();
    model
        .forward_eager_into(&x, 1, ConvBackend::Sliding, &mut EagerScratch::default(), &mut want)
        .unwrap();
    let mut got = Vec::new();
    plan.run_into(&model, &x, &mut PlanScratch::default(), &mut got).unwrap();
    assert_eq!(got, want);
}

#[test]
fn auto_plan_faithful_to_direct_oracle() {
    let mut rng = Rng::new(0xA0);
    let mc = ModelConfig {
        name: "auto".into(),
        c_in: 1,
        seq_len: 80,
        layers: vec![
            // Qualifies for small_k under Auto.
            LayerConfig::Conv {
                c_out: 1,
                k: 3,
                stride: 1,
                dilation: 1,
                same_pad: false,
                relu: false,
                backend: None,
                quantize: false,
            },
            // Fat reduction, small receptive field → im2col under Auto.
            LayerConfig::Conv {
                c_out: 16,
                k: 3,
                stride: 1,
                dilation: 1,
                same_pad: true,
                relu: true,
                backend: None,
                quantize: false,
            },
            // Wide dilated filter → sliding under Auto.
            LayerConfig::Conv {
                c_out: 2,
                k: 7,
                stride: 1,
                dilation: 4,
                same_pad: true,
                relu: false,
                backend: None,
                quantize: false,
            },
        ],
    };
    let model = Model::init(&mc, &mut Rng::new(44)).unwrap();
    let plan = Plan::compile(&model, 2, &PlannerConfig::default()).unwrap();
    assert_eq!(
        plan.kernels(),
        vec![PlanKernel::SmallK, PlanKernel::Im2col, PlanKernel::Sliding],
        "cost model choices drifted: {}",
        plan.describe()
    );
    let x = rng.vec_uniform(2 * 80, -1.0, 1.0);
    let mut got = Vec::new();
    plan.run_into(&model, &x, &mut PlanScratch::default(), &mut got).unwrap();
    let mut want = Vec::new();
    model
        .forward_eager_into(&x, 2, ConvBackend::Direct, &mut EagerScratch::default(), &mut want)
        .unwrap();
    assert_eq!(got.len(), want.len());
    for (i, (g, t)) in got.iter().zip(&want).enumerate() {
        assert!(
            (g - t).abs() <= 1e-3 * (1.0 + t.abs()),
            "auto plan vs direct oracle at {i}: {g} vs {t}"
        );
    }
}

/// Fused-chain plans must be bit-identical to both the unfused plan
/// and the eager reference — across forced SIMD tiers, thread counts
/// {1, 2, 4, 8}, and one dirty arena shared by every run.
#[test]
fn fused_chain_parity_across_tiers_and_threads() {
    const CFG_TOML: &str = r#"
[model]
name = "fused"
c_in = 2
seq_len = 96

[layer.0]
type = "conv"
c_out = 6
k = 7

[layer.1]
type = "pool"
kind = "max"
w = 2
stride = 2

[layer.2]
type = "conv"
c_out = 4
k = 5
relu = false

[layer.3]
type = "pool"
kind = "avg"
w = 3
stride = 3

[layer.4]
type = "pool"
kind = "min"
w = 2
stride = 2

[layer.5]
type = "dense"
out = 3
"#;
    let (mc, _) = swsnn::config::load_config(CFG_TOML).unwrap();
    let model = Model::init(&mc, &mut Rng::new(21)).unwrap();
    let batch = 3;
    let cfg_fused = PlannerConfig {
        backend: BackendChoice::Fixed(ConvBackend::Sliding),
        ..Default::default()
    };
    let cfg_unfused = PlannerConfig {
        fuse: false,
        ..cfg_fused
    };
    let fused = Plan::compile(&model, batch, &cfg_fused).unwrap();
    // Every layer up to the dense head is chain-eligible (sliding
    // convs, non-overlapping pools — w=2/s=2, w=3/s=3, w=2/s=2), so the
    // whole prefix groups into ONE fused chain of five stages; the
    // dense head stays a separate step.
    assert_eq!(fused.fused_steps(), 1, "{}", fused.describe());
    assert_eq!(fused.fused_layers(), 5, "{}", fused.describe());
    assert_eq!(fused.kernels().len(), 2);
    assert_eq!(fused.layer_kernels().len(), 6);
    let unfused = Plan::compile(&model, batch, &cfg_unfused).unwrap();
    assert_eq!(unfused.fused_steps(), 0);
    assert_eq!(unfused.kernels().len(), 6);

    let mut rng = Rng::new(22);
    let x = rng.vec_uniform(batch * 2 * 96, -1.0, 1.0);
    let mut scratch = PlanScratch::default();
    for tier in tiers() {
        simd::force_tier(Some(tier));
        let mut want = Vec::new();
        model
            .forward_eager_into(
                &x,
                batch,
                ConvBackend::Sliding,
                &mut EagerScratch::default(),
                &mut want,
            )
            .unwrap();
        for threads in THREADS {
            let ex = Executor::new(threads);
            let mut got_fused = Vec::new();
            fused
                .run_with_into(&ex, &model, &x, &mut scratch, &mut got_fused)
                .unwrap();
            assert_eq!(
                got_fused, want,
                "tier {tier:?} threads {threads}: fused plan != eager"
            );
            let mut got_unfused = Vec::new();
            unfused
                .run_with_into(&ex, &model, &x, &mut scratch, &mut got_unfused)
                .unwrap();
            assert_eq!(
                got_fused, got_unfused,
                "tier {tier:?} threads {threads}: fused plan != unfused plan"
            );
        }
    }
    simd::force_tier(None);
}

/// The random-model sweep under `Autotune` (+ fusion, the default): the
/// measured choice is timing-dependent, so the eager reference pins each
/// layer's backend to whatever the plan actually chose — bit-identical
/// regardless of which kernels won the probes. Dirty shared arena,
/// rotating thread counts.
#[test]
fn autotuned_plans_bit_identical_to_eager_with_matching_kernels() {
    let mut rng = Rng::new(0xA117);
    let mut plan_scratch = PlanScratch::default();
    let mut built = 0usize;
    let mut attempts = 0usize;
    while built < 8 && attempts < 60 {
        attempts += 1;
        let mc = random_config(&mut rng, attempts);
        let seed = 4000 + attempts as u64;
        let Ok(model) = Model::init(&mc, &mut Rng::new(seed)) else {
            continue;
        };
        built += 1;
        let batch = [1usize, 2, 4][built % 3];
        let x = rng.vec_uniform(batch * mc.c_in * mc.seq_len, -1.0, 1.0);
        let cfg = PlannerConfig {
            backend: BackendChoice::Auto,
            autotune: true,
            ..Default::default()
        };
        let plan = Plan::compile(&model, batch, &cfg).unwrap();
        // Rebuild the same model (same init seed → same weights) with
        // each conv-shaped layer pinned to the plan's measured kernel;
        // small_k maps to sliding (bit-identical chain, pinned below).
        let lk = plan.layer_kernels();
        assert_eq!(lk.len(), mc.layers.len());
        let mut mc_ref = mc.clone();
        for (layer, k) in mc_ref.layers.iter_mut().zip(&lk) {
            let over = match k {
                PlanKernel::Sliding | PlanKernel::SmallK => Some(ConvBackend::Sliding),
                PlanKernel::Im2col => Some(ConvBackend::Im2colGemm),
                PlanKernel::Direct => Some(ConvBackend::Direct),
                _ => None,
            };
            match layer {
                LayerConfig::Conv { backend, .. } => *backend = over,
                LayerConfig::Residual { backend, .. } => *backend = over,
                _ => {}
            }
        }
        let model_ref = Model::init(&mc_ref, &mut Rng::new(seed)).unwrap();
        let mut want = Vec::new();
        model_ref
            .forward_eager_into(
                &x,
                batch,
                ConvBackend::Sliding,
                &mut EagerScratch::default(),
                &mut want,
            )
            .unwrap();
        let threads = THREADS[built % THREADS.len()];
        let ex = Executor::new(threads);
        let mut got = Vec::new();
        plan.run_with_into(&ex, &model, &x, &mut plan_scratch, &mut got)
            .unwrap();
        assert_eq!(
            got, want,
            "model {} batch {batch} threads {threads} plan [{}]: autotuned plan != eager",
            mc.name,
            plan.describe()
        );
    }
    assert!(built >= 6, "generator rejected too many configs ({built}/8)");
}

/// Pin the mapping the autotune parity test relies on: for qualifying
/// shapes the small-k kernel's per-output chain (bias seed, ascending
/// fused taps) is the *same* chain as the sliding kernel's — the two are
/// bitwise equal on every SIMD tier.
#[test]
fn small_k_bitwise_equals_sliding_for_qualifying_shapes() {
    let mut rng = Rng::new(0x511d);
    for tier in tiers() {
        simd::force_tier(Some(tier));
        for k in [3usize, 5] {
            for n in [16usize, 100, 1000] {
                let p = Conv1dParams::new(1, 1, n, k).with_batch(2);
                let x = rng.vec_uniform(p.x_len(), -1.0, 1.0);
                let w = rng.vec_uniform(k, -1.0, 1.0);
                let b = [0.25f32];
                let want = conv1d_sliding(&x, &w, Some(&b), &p);
                let mut got = vec![f32::NAN; p.y_len()];
                assert!(conv1d_small_k_into(&x, &w, Some(&b), &p, Epilogue::None, &mut got));
                assert_eq!(got, want, "tier {tier:?} k={k} n={n}");
            }
        }
    }
    simd::force_tier(None);
}

#[test]
fn empty_model_fails_at_init_not_serve() {
    let mc = ModelConfig {
        name: "empty".into(),
        c_in: 1,
        seq_len: 8,
        layers: vec![],
    };
    let err = Model::init(&mc, &mut Rng::new(1)).unwrap_err().to_string();
    assert!(err.contains("no layers"), "{err}");
}

#[test]
fn plan_rejects_foreign_model_and_bad_batch() {
    let mc = ModelConfig {
        name: "a".into(),
        c_in: 1,
        seq_len: 32,
        layers: vec![LayerConfig::Conv {
            c_out: 2,
            k: 3,
            stride: 1,
            dilation: 1,
            same_pad: true,
            relu: true,
            backend: None,
            quantize: false,
        }],
    };
    let model = Model::init(&mc, &mut Rng::new(2)).unwrap();
    let plan = Plan::compile(&model, 2, &PlannerConfig::default()).unwrap();
    let mut out = Vec::new();
    // Wrong input length for the compiled batch.
    assert!(plan
        .run_into(&model, &[0.0; 32], &mut PlanScratch::default(), &mut out)
        .is_err());
    // A model with a different layer count is rejected.
    let mc2 = ModelConfig {
        layers: vec![
            mc.layers[0].clone(),
            LayerConfig::Pool { kind: "max".into(), w: 2, stride: 2 },
        ],
        ..mc
    };
    let model2 = Model::init(&mc2, &mut Rng::new(2)).unwrap();
    assert!(plan
        .run_into(&model2, &[0.0; 64], &mut PlanScratch::default(), &mut out)
        .is_err());
}
