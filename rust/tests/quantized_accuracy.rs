//! Accuracy contract of the int8 quantized conv backend.
//!
//! The quantized kernel computes `Σ ŵ·x̂` **exactly** (i32 accumulation
//! of dequantization-equivalent products), so its error against the f32
//! sliding oracle is bounded purely by the per-element rounding of the
//! affine quantizer: with per-tensor scales `sx`, `sw` and `k_total =
//! c_in · k` products per output,
//!
//! ```text
//! |y_q − y_f32| ≤ k_total · (max|x|·sw/2 + max|w|·sx/2 + sx·sw/4)
//! ```
//!
//! (each product's error is `|w−ŵ|·|x| + |ŵ−w|·|x−x̂| + |w|·|x−x̂|`
//! with `|x−x̂| ≤ sx/2`, `|w−ŵ| ≤ sw/2`; padded positions dequantize to
//! exactly 0 and contribute no error). The property test derives this
//! bound per case — it is not a hand-tuned tolerance.

use swsnn::conv::{
    conv1d_quantized_into, conv1d_sliding, quantized_scratch_len, Conv1dParams, QuantParams,
};
use swsnn::ops::Epilogue;
use swsnn::prop::{self, PropConfig};

fn amax(xs: &[f32]) -> f32 {
    xs.iter().fold(0.0f32, |m, v| m.max(v.abs()))
}

#[test]
fn quantized_conv_error_bounded_by_scales() {
    prop::check(
        PropConfig {
            cases: 96,
            ..Default::default()
        },
        "int8 conv tracks f32 sliding within the k·scale bound",
        |g| {
            let c_in = g.usize_in(1, 4);
            let c_out = g.usize_in(1, 4);
            let k = g.usize_in(1, 8);
            let n = g.usize_in(k, k + g.size.max(8));
            let p = Conv1dParams::new(c_in, c_out, n, k)
                .with_batch(g.usize_in(1, 3))
                .with_stride(g.usize_in(1, 4))
                .with_dilation(g.usize_in(1, 3))
                .with_pad(g.usize_in(0, k + 1));
            let x = g.vec_f32_len(p.x_len(), -2.0, 2.0);
            let w = g.vec_f32_len(p.w_len(), -1.5, 1.5);
            let b = g.vec_f32_len(p.c_out, -0.5, 0.5);
            let bias = g.bool().then_some(b.as_slice());

            let xp = QuantParams::from_slice(&x);
            let wp = QuantParams::from_slice(&w);
            let qx = xp.quantize_slice(&x);
            let qw = wp.quantize_slice(&w);

            // Dilation can push effective_k past the padded input →
            // empty output; the kernel must accept that and write
            // nothing (y_len() is 0 then, so the zip below is empty).
            let want = conv1d_sliding(&x, &w, bias, &p);
            let mut acc = vec![i32::MIN; quantized_scratch_len(&p)];
            let mut y = vec![f32::NAN; p.y_len()];
            conv1d_quantized_into(&qx, &qw, xp, wp, bias, &p, Epilogue::None, &mut acc, &mut y);
            prop::ensure(y.len() == want.len(), "output length mismatch")?;

            let k_total = (c_in * k) as f32;
            let (sx, sw) = (xp.scale, wp.scale);
            let bound =
                k_total * (amax(&x) * sw / 2.0 + amax(&w) * sx / 2.0 + sx * sw / 4.0) + 1e-4;
            for (i, (a, b)) in y.iter().zip(&want).enumerate() {
                prop::ensure(a.is_finite(), format!("y[{i}] not finite: {a}"))?;
                prop::ensure(
                    (a - b).abs() <= bound,
                    format!("y[{i}]: quantized {a} vs f32 {b}, derived bound {bound} ({p:?})"),
                )?;
            }
            Ok(())
        },
    );
}

#[test]
fn quantized_into_overwrites_nan_poisoned_dst() {
    // NaN is the nastiest dirt: any unwritten destination element (or
    // any read of one) propagates into the output. Under
    // `--features check-invariants` the kernel additionally poisons its
    // destination with a sentinel on entry and asserts every element
    // was overwritten on exit — this test drives that path with a dirty
    // buffer so the sentinel machinery is exercised, feature on or off.
    let p = Conv1dParams::new(3, 2, 1_000, 5).with_batch(2).with_same_pad();
    let mut rng = swsnn::workload::Rng::new(0x0_8A1);
    let x = rng.vec_uniform(p.x_len(), -1.0, 1.0);
    let w = rng.vec_uniform(p.w_len(), -1.0, 1.0);
    let b = rng.vec_uniform(p.c_out, -0.5, 0.5);
    let xp = QuantParams::from_slice(&x);
    let wp = QuantParams::from_slice(&w);
    let qx = xp.quantize_slice(&x);
    let qw = wp.quantize_slice(&w);

    let mut acc = vec![0i32; quantized_scratch_len(&p)];
    let mut want = vec![0.0f32; p.y_len()];
    conv1d_quantized_into(&qx, &qw, xp, wp, Some(&b), &p, Epilogue::Relu, &mut acc, &mut want);

    let mut acc = vec![i32::MIN; quantized_scratch_len(&p)];
    let mut y = vec![f32::NAN; p.y_len()];
    conv1d_quantized_into(&qx, &qw, xp, wp, Some(&b), &p, Epilogue::Relu, &mut acc, &mut y);
    assert_eq!(y, want, "dirty scratch/dst must not change the output");
    assert!(y.iter().all(|v| v.is_finite()), "NaN leaked through");
}
