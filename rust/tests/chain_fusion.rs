//! Contract of fused chain segments (`nn::plan`'s `FusedChain`):
//!
//! 1. **Halo arithmetic, property-tested**: random segment lengths ×
//!    kernel sizes/strides/dilations/padding × pool interleavings ×
//!    forced tile sizes × thread counts × dirty arenas — the fused
//!    sweep is bitwise identical to the unfused plan and to the eager
//!    reference. Residuals and overlapping pools are mixed in so the
//!    generator also exercises segment breaks mid-model.
//! 2. Forced SIMD tiers × tiny tiles: heavy halo handoff on every
//!    stage boundary, still bit-identical (single `#[test]` so the
//!    process-global tier override never races inside this binary).
//! 3. `configs/tcn_deep.toml` compiles to ONE eight-layer chain and is
//!    bit-identical to eager at the serving batch size.
//! 4. Under autotune the fuse/no-fuse decision is probed per segment,
//!    recorded on the plan, and served from the tune cache on
//!    recompile — with execution staying bit-identical either way.

use std::cell::{Cell, RefCell};

use swsnn::config::{load_config, LayerConfig, ModelConfig};
use swsnn::conv::{BackendChoice, ConvBackend};
use swsnn::exec::Executor;
use swsnn::nn::{EagerScratch, Model, Plan, PlanKernel, PlanScratch, PlannerConfig};
use swsnn::prop::{check, ensure, PropConfig};
use swsnn::simd::{self, SimdTier};
use swsnn::workload::Rng;

/// Random chain-heavy stack: mostly chain-eligible layers (sliding
/// convs, non-overlapping pools) with the occasional residual or
/// overlapping pool so segments also break mid-model.
fn random_chain_config(g: &mut swsnn::prop::Gen, idx: usize) -> ModelConfig {
    let c_in = 1 + g.usize_in(0, 3);
    let seq_len = 48 + g.usize_in(0, 112);
    let n_layers = 2 + g.usize_in(0, 5);
    let mut layers = Vec::new();
    for _ in 0..n_layers {
        match g.usize_in(0, 10) {
            0 => layers.push(LayerConfig::Residual {
                k: 3,
                dilation: 1 + g.usize_in(0, 2),
                backend: None,
            }),
            // Overlapping strided pool (stride < w): breaks the chain
            // and runs the arena-scratch dense path.
            1 => layers.push(LayerConfig::Pool {
                kind: "max".to_string(),
                w: 3 + g.usize_in(0, 2),
                stride: 2,
            }),
            // Non-overlapping pool (stride ≥ w, including gapped
            // stride > w): chains.
            2 | 3 => {
                let w = 2 + g.usize_in(0, 2);
                layers.push(LayerConfig::Pool {
                    kind: ["max", "avg", "min"][g.usize_in(0, 3)].to_string(),
                    w,
                    stride: w + g.usize_in(0, 2),
                });
            }
            _ => layers.push(LayerConfig::Conv {
                c_out: 1 + g.usize_in(0, 5),
                k: [1, 2, 3, 5, 7, 9][g.usize_in(0, 6)],
                stride: 1 + g.usize_in(0, 2),
                dilation: 1 + g.usize_in(0, 2),
                same_pad: g.usize_in(0, 4) != 0,
                relu: g.bool(),
                backend: None,
                quantize: false,
            }),
        }
    }
    ModelConfig {
        name: format!("chain{idx}"),
        c_in,
        seq_len,
        layers,
    }
}

#[test]
fn prop_fused_chain_bit_identical_to_unfused_and_eager() {
    // One dirty arena + eager scratch shared across every case: stale
    // ring-buffer and activation contents must never leak into results.
    let plan_scratch = RefCell::new(PlanScratch::default());
    let eager_scratch = RefCell::new(EagerScratch::default());
    let case = Cell::new(0usize);
    check(
        PropConfig {
            cases: 40,
            ..Default::default()
        },
        "fused chain ≡ unfused plan ≡ eager",
        |g| {
            let idx = case.get();
            case.set(idx + 1);
            let mc = random_chain_config(g, idx);
            let seed = g.rng.next_u64();
            let Ok(model) = Model::init(&mc, &mut Rng::new(seed)) else {
                return Ok(()); // generator produced a collapsing shape
            };
            let batch = 1 + g.usize_in(0, 4);
            let x =
                Rng::new(seed ^ 0x5a5a).vec_uniform(batch * mc.c_in * mc.seq_len, -1.0, 1.0);
            let tile = *g.choose(&[None, Some(1usize), Some(2), Some(3), Some(5), Some(17)]);
            let threads = *g.choose(&[1usize, 2, 4, 8]);
            let ex = Executor::new(threads);
            let base = PlannerConfig {
                backend: BackendChoice::Fixed(ConvBackend::Sliding),
                chain_tile: tile,
                ..PlannerConfig::default()
            };
            let fused = Plan::compile(&model, batch, &base).map_err(|e| e.to_string())?;
            let unfused = Plan::compile(
                &model,
                batch,
                &PlannerConfig {
                    fuse: false,
                    ..base
                },
            )
            .map_err(|e| e.to_string())?;
            ensure(unfused.fused_steps() == 0, "unfused plan fused something")?;
            let mut want = Vec::new();
            model
                .forward_eager_into(
                    &x,
                    batch,
                    ConvBackend::Sliding,
                    &mut eager_scratch.borrow_mut(),
                    &mut want,
                )
                .map_err(|e| e.to_string())?;
            let mut got_fused = Vec::new();
            fused
                .run_with_into(
                    &ex,
                    &model,
                    &x,
                    &mut plan_scratch.borrow_mut(),
                    &mut got_fused,
                )
                .map_err(|e| e.to_string())?;
            let mut got_unfused = Vec::new();
            unfused
                .run_with_into(
                    &ex,
                    &model,
                    &x,
                    &mut plan_scratch.borrow_mut(),
                    &mut got_unfused,
                )
                .map_err(|e| e.to_string())?;
            ensure(
                got_fused == want,
                format!(
                    "fused != eager ({} tile {tile:?} threads {threads} batch {batch}: {})",
                    mc.name,
                    fused.describe()
                ),
            )?;
            ensure(
                got_fused == got_unfused,
                format!(
                    "fused != unfused ({} tile {tile:?} threads {threads} batch {batch})",
                    mc.name
                ),
            )
        },
    );
}

/// The SIMD tiers worth forcing on this host: the portable oracle plus
/// whatever the hardware actually dispatches.
fn tiers() -> Vec<SimdTier> {
    let mut ts = vec![SimdTier::Generic];
    for t in [SimdTier::Avx2, SimdTier::Sse2, SimdTier::Neon] {
        if t.is_supported() {
            ts.push(t);
        }
    }
    ts
}

/// Forced SIMD tiers × tiny forced tiles × thread counts on a fixed
/// deep stack: maximal halo traffic on every stage boundary, still
/// bit-identical to eager.
#[test]
fn fused_chain_parity_under_forced_tiers_and_tiny_tiles() {
    const CFG: &str = r#"
[model]
name = "tiered_chain"
c_in = 2
seq_len = 120

[layer.0]
type = "conv"
c_out = 5
k = 7

[layer.1]
type = "conv"
c_out = 4
k = 5
dilation = 2

[layer.2]
type = "pool"
kind = "max"
w = 2
stride = 2

[layer.3]
type = "conv"
c_out = 3
k = 3

[layer.4]
type = "pool"
kind = "avg"
w = 2
stride = 3

[layer.5]
type = "conv"
c_out = 2
k = 3
relu = false
"#;
    let (mc, _) = load_config(CFG).unwrap();
    let model = Model::init(&mc, &mut Rng::new(77)).unwrap();
    let batch = 3;
    let mut rng = Rng::new(78);
    let x = rng.vec_uniform(batch * 2 * 120, -1.0, 1.0);
    let mut scratch = PlanScratch::default();
    for tier in tiers() {
        simd::force_tier(Some(tier));
        let mut want = Vec::new();
        model
            .forward_eager_into(
                &x,
                batch,
                ConvBackend::Sliding,
                &mut EagerScratch::default(),
                &mut want,
            )
            .unwrap();
        for tile in [1usize, 4, 64] {
            let plan = Plan::compile(
                &model,
                batch,
                &PlannerConfig {
                    backend: BackendChoice::Fixed(ConvBackend::Sliding),
                    chain_tile: Some(tile),
                    ..PlannerConfig::default()
                },
            )
            .unwrap();
            assert_eq!(plan.fused_steps(), 1, "{}", plan.describe());
            assert_eq!(plan.fused_layers(), 6, "{}", plan.describe());
            for threads in [1usize, 2, 4, 8] {
                let ex = Executor::new(threads);
                let mut got = Vec::new();
                plan.run_with_into(&ex, &model, &x, &mut scratch, &mut got)
                    .unwrap();
                assert_eq!(got, want, "tier {tier:?} tile {tile} threads {threads}");
            }
        }
    }
    simd::force_tier(None);
}

/// The `chain_fusion` bench model compiles to a single eight-layer
/// chain at the serving batch size and runs bit-identically to eager
/// (the whole stack is one arena pass — no ping/pong activations at
/// all, so the plan's activation regions are empty).
#[test]
fn tcn_deep_compiles_to_one_chain_and_matches_eager() {
    let text = std::fs::read_to_string(
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs/tcn_deep.toml"),
    )
    .unwrap();
    let (mc, _) = load_config(&text).unwrap();
    let model = Model::init(&mc, &mut Rng::new(1)).unwrap();
    let cfg = PlannerConfig {
        backend: BackendChoice::Fixed(ConvBackend::Sliding),
        ..PlannerConfig::default()
    };
    let plan = Plan::compile(&model, 8, &cfg).unwrap();
    assert_eq!(plan.kernels(), vec![PlanKernel::FusedChain], "{}", plan.describe());
    assert_eq!(plan.fused_layers(), 8, "{}", plan.describe());
    let mut rng = Rng::new(2);
    let x = rng.vec_uniform(8 * model.c_in * model.seq_len, -1.0, 1.0);
    let mut got = Vec::new();
    plan.run_into(&model, &x, &mut PlanScratch::default(), &mut got)
        .unwrap();
    let mut want = Vec::new();
    model
        .forward_eager_into(
            &x,
            8,
            ConvBackend::Sliding,
            &mut EagerScratch::default(),
            &mut want,
        )
        .unwrap();
    assert_eq!(got, want, "{}", plan.describe());
}

/// Under autotune the fuse/no-fuse decision is measured on the whole
/// segment, recorded on the plan, and served from the process-wide
/// tune cache on recompile — and execution matches eager whichever way
/// the probe decided.
#[test]
fn autotune_probes_segments_and_serves_recompiles_from_cache() {
    const CFG: &str = r#"
[model]
name = "seg_tune"
c_in = 1
seq_len = 73

[layer.0]
type = "conv"
c_out = 5
k = 7

[layer.1]
type = "pool"
kind = "max"
w = 2
stride = 2

[layer.2]
type = "conv"
c_out = 3
k = 5
relu = false
"#;
    let (mc, _) = load_config(CFG).unwrap();
    let model = Model::init(&mc, &mut Rng::new(21)).unwrap();
    // Uncommon batch so concurrent tests cannot pre-seed the key.
    let batch = 7;
    let cfg = PlannerConfig {
        backend: BackendChoice::Fixed(ConvBackend::Sliding),
        autotune: true,
        ..PlannerConfig::default()
    };
    let plan = Plan::compile(&model, batch, &cfg).unwrap();
    assert_eq!(plan.segment_tuning().len(), 1, "{:?}", plan.segment_tuning());
    let first = &plan.segment_tuning()[0];
    assert_eq!(first.layers, (0, 2));
    if !first.cached {
        assert!(first.fused_micros.is_finite() && first.fused_micros > 0.0);
        assert!(first.unfused_micros.is_finite() && first.unfused_micros > 0.0);
    }
    // Recompiles are served from the tune cache with the same decision.
    let again = Plan::compile(&model, batch, &cfg).unwrap();
    assert_eq!(again.segment_tuning().len(), 1);
    assert!(again.segment_tuning()[0].cached, "{:?}", again.segment_tuning());
    assert_eq!(again.segment_tuning()[0].fused, first.fused);
    assert_eq!(again.fused_steps(), plan.fused_steps());
    // Bit-identical to eager whichever way the probe decided.
    let mut rng = Rng::new(22);
    let x = rng.vec_uniform(batch * 73, -1.0, 1.0);
    let mut got = Vec::new();
    plan.run_into(&model, &x, &mut PlanScratch::default(), &mut got)
        .unwrap();
    let mut want = Vec::new();
    model
        .forward_eager_into(
            &x,
            batch,
            ConvBackend::Sliding,
            &mut EagerScratch::default(),
            &mut want,
        )
        .unwrap();
    assert_eq!(got, want);
}
