//! The paper's Eq. 5–9: dot product as a prefix sum.
//!
//! Given vectors `a` (filter) and `b` (signal window), the dot product
//! `c = Σ aᵢ·bᵢ` is re-expressed as a prefix sum over pairs
//! `γᵢ = (uᵢ, vᵢ)` with the associative (but non-commutative) operator
//!
//! ```text
//! (u₁, v₁) ⊕ (u₂, v₂) = (u₁·u₂,  u₂·v₁ + v₂)          (Eq. 8)
//! ```
//!
//! This is the classic first-order linear-recurrence semiring (Blelloch
//! 1993): scanning it evaluates `vₖ₊₁' = uₖ₊₁·vₖ' + vₖ₊₁`, i.e. a Horner
//! chain of fused multiply-adds. With `uᵢ = αᵢ₋₁/αᵢ` (the filter-ratio
//! encoding of Eq. 7) the bottom lane of the last prefix equals the dot
//! product, computable in `log(M)` parallel FMA steps.

use super::AssocOp;

/// A `(u, v)` pair element (paper Eq. 7).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Pair {
    /// Multiplier component (filter-ratio chain).
    pub u: f32,
    /// Accumulator component.
    pub v: f32,
}

impl Pair {
    #[inline(always)]
    pub const fn new(u: f32, v: f32) -> Self {
        Self { u, v }
    }
}

/// Eq. 8 operator. Associative, non-commutative.
#[derive(Clone, Copy, Debug, Default)]
pub struct ConvPair;

impl AssocOp for ConvPair {
    type Elem = Pair;

    /// Identity is `(1, 0)`: `(1,0)⊕(u,v) = (u, v)` and
    /// `(u,v)⊕(1,0) = (u, 1·v+0) = (u, v)`.
    #[inline(always)]
    fn identity(&self) -> Pair {
        Pair::new(1.0, 0.0)
    }

    /// `(u₁,v₁) ⊕ (u₂,v₂) = (u₁u₂, u₂v₁ + v₂)` — one mul + one FMA.
    #[inline(always)]
    fn combine(&self, a: Pair, b: Pair) -> Pair {
        Pair::new(a.u * b.u, b.u.mul_add(a.v, b.v))
    }

    fn is_commutative(&self) -> bool {
        false
    }

    fn name(&self) -> &'static str {
        "conv_pair"
    }
}

/// Encode filter `a` and signal window `b` into the γ sequence of Eq. 7.
///
/// Zero filter taps are patched per Eq. 5: `αᵢ = 1, βᵢ = 0` wherever
/// `aᵢ = 0`, which leaves the dot product unchanged while keeping the
/// ratios `αᵢ₋₁/αᵢ` finite.
///
/// Returns `M + 1` pairs; scanning them with [`ConvPair`] puts the dot
/// product `Σ aᵢbᵢ` in the `v` component of the final prefix (times the
/// trailing `u = 1` normalization pair).
pub fn encode_gamma(a: &[f32], b: &[f32]) -> Vec<Pair> {
    assert_eq!(a.len(), b.len(), "filter/window length mismatch");
    let m = a.len();
    // Eq. 5 patch.
    let alpha = |i: usize| -> f32 {
        if a[i] == 0.0 {
            1.0
        } else {
            a[i]
        }
    };
    let beta = |i: usize| -> f32 {
        if a[i] == 0.0 {
            0.0
        } else {
            b[i]
        }
    };
    let mut gamma = Vec::with_capacity(m + 1);
    for i in 0..=m {
        let u = if i == 0 {
            1.0
        } else if i < m {
            alpha(i - 1) / alpha(i)
        } else {
            // Final pair: u = α_{M-1}/1 folds the last ratio chain back to
            // the raw dot product; v = 0 per Eq. 7.
            alpha(m - 1)
        };
        let v = if i < m { beta(i) } else { 0.0 };
        gamma.push(Pair::new(u, v));
    }
    gamma
}

/// Evaluate a dot product through the Eq. 7–9 prefix-sum formulation.
///
/// The γ encoding multiplies each β by the *remaining* ratio chain; after
/// the closing pair (u = α_{M-1}, v = 0) every term has been re-scaled by
/// exactly its own α, recovering `Σ αᵢβᵢ = Σ aᵢbᵢ` (Eq. 6).
pub fn dot_via_prefix(a: &[f32], b: &[f32]) -> f32 {
    let gamma = encode_gamma(a, b);
    let op = ConvPair;
    let mut acc = op.identity();
    for g in &gamma {
        acc = op.combine(acc, *g);
    }
    acc.v
}

/// Reference dot product (plain accumulation) for cross-checks.
pub fn dot_reference(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Evaluate the γ scan with a log-depth tree reduce (paper: "δ_M could be
/// evaluated using reduce algorithm in log(M) parallel steps").
pub fn dot_via_tree_reduce(a: &[f32], b: &[f32]) -> f32 {
    let mut gamma = encode_gamma(a, b);
    let op = ConvPair;
    let mut n = gamma.len();
    while n > 1 {
        let half = n / 2;
        for i in 0..half {
            gamma[i] = op.combine(gamma[2 * i], gamma[2 * i + 1]);
        }
        if n % 2 == 1 {
            gamma[half] = gamma[n - 1];
            n = half + 1;
        } else {
            n = half;
        }
    }
    gamma[0].v
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_close(a: f32, b: f32) {
        let tol = 1e-4 * (1.0 + a.abs().max(b.abs()));
        assert!((a - b).abs() <= tol, "{a} vs {b}");
    }

    #[test]
    fn identity_laws() {
        let op = ConvPair;
        let x = Pair::new(2.5, -3.0);
        assert_eq!(op.combine(op.identity(), x), x);
        assert_eq!(op.combine(x, op.identity()), x);
    }

    #[test]
    fn associativity_exact_cases() {
        let op = ConvPair;
        let a = Pair::new(2.0, 1.0);
        let b = Pair::new(0.5, -4.0);
        let c = Pair::new(4.0, 3.0);
        let lhs = op.combine(a, op.combine(b, c));
        let rhs = op.combine(op.combine(a, b), c);
        assert_close(lhs.u, rhs.u);
        assert_close(lhs.v, rhs.v);
    }

    #[test]
    fn dot_simple() {
        let a = [1.0, 2.0, 3.0];
        let b = [4.0, 5.0, 6.0];
        assert_close(dot_via_prefix(&a, &b), 32.0);
        assert_close(dot_via_tree_reduce(&a, &b), 32.0);
    }

    #[test]
    fn dot_with_zero_taps() {
        // Eq. 5: zero filter entries must not blow up the ratio chain.
        let a = [0.0, 2.0, 0.0, -1.5];
        let b = [9.0, 3.0, 7.0, 2.0];
        assert_close(dot_via_prefix(&a, &b), dot_reference(&a, &b));
        assert_close(dot_via_tree_reduce(&a, &b), dot_reference(&a, &b));
    }

    #[test]
    fn dot_all_zero_filter() {
        let a = [0.0; 5];
        let b = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_close(dot_via_prefix(&a, &b), 0.0);
    }

    #[test]
    fn dot_single_element() {
        assert_close(dot_via_prefix(&[3.0], &[7.0]), 21.0);
    }

    #[test]
    fn dot_matches_reference_many() {
        // Deterministic pseudo-random cross-check.
        let mut state = 0x9e3779b97f4a7c15u64;
        let mut next = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            ((state >> 11) as f64 / (1u64 << 53) as f64) as f32 * 4.0 - 2.0
        };
        for m in [1usize, 2, 3, 7, 16, 33] {
            let a: Vec<f32> = (0..m).map(|_| next()).collect();
            let b: Vec<f32> = (0..m).map(|_| next()).collect();
            assert_close(dot_via_prefix(&a, &b), dot_reference(&a, &b));
            assert_close(dot_via_tree_reduce(&a, &b), dot_reference(&a, &b));
        }
    }
}
