//! Fused element-wise epilogues for the `_into` kernel family.
//!
//! The eager layer stack used to run bias, ReLU, and the residual
//! skip-add as *separate memory passes* over the activation tensor —
//! three streams of the output where one suffices. An [`Epilogue`] is
//! instead handed to the kernel and applied to each output span right
//! after that span's accumulation completes (while it is still
//! cache-resident): `conv1d_sliding_with_into` applies it per row
//! segment, `conv2d_sliding_with_into` per plane-row group, and the
//! GEMM path per output row band.
//!
//! Every variant is a pure element-wise map, so applying it per
//! disjoint span is **bit-identical** to applying it in one pass after
//! the full kernel — which is exactly how the eager reference path
//! (`Model::forward_eager_into`) still computes it. The `ReluAdd` skip
//! tensor is indexed by the span's *flat* position in the full output,
//! so parallel workers writing disjoint spans read disjoint skip spans.

/// Element-wise tail fused into a kernel's destination write.
#[derive(Clone, Copy, Debug, Default)]
pub enum Epilogue<'a> {
    /// No tail — the kernel's raw output.
    #[default]
    None,
    /// `y ← max(y, 0)` — the conv bias+ReLU tail (bias is already fused
    /// into the kernels' accumulator seed).
    Relu,
    /// `y ← max(y, 0) + skip[flat]` — the TCN residual closing add.
    /// `skip` must have the same flat layout and length as the full
    /// output tensor (residual blocks preserve shape).
    ReluAdd(&'a [f32]),
}

impl Epilogue<'_> {
    /// Apply to an output span whose first element has flat index
    /// `flat` in the full output tensor. Element order and operation
    /// order match the unfused reference (`relu` pass, then `+= skip`),
    /// so fused and unfused evaluation are bit-identical.
    #[inline]
    pub fn apply(&self, y: &mut [f32], flat: usize) {
        match self {
            Epilogue::None => {}
            Epilogue::Relu => {
                for v in y.iter_mut() {
                    if *v < 0.0 {
                        *v = 0.0;
                    }
                }
            }
            Epilogue::ReluAdd(skip) => {
                let s = &skip[flat..flat + y.len()];
                for (v, &sv) in y.iter_mut().zip(s) {
                    let r = if *v < 0.0 { 0.0 } else { *v };
                    *v = r + sv;
                }
            }
        }
    }

    /// Validate the skip tensor against the kernel's full output length
    /// (call once at kernel entry, before any partitioning).
    #[inline]
    pub fn check_len(&self, y_len: usize) {
        if let Epilogue::ReluAdd(s) = self {
            assert_eq!(s.len(), y_len, "epilogue skip length");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relu_clamps_negatives_only() {
        let mut y = [-1.0f32, 0.0, 2.5, -0.0];
        Epilogue::Relu.apply(&mut y, 0);
        assert_eq!(y, [0.0, 0.0, 2.5, -0.0]);
    }

    #[test]
    fn relu_add_uses_flat_offset() {
        let skip = [10.0f32, 20.0, 30.0, 40.0];
        let mut y = [-1.0f32, 3.0];
        Epilogue::ReluAdd(&skip).apply(&mut y, 2);
        assert_eq!(y, [30.0, 43.0]);
    }

    #[test]
    fn spanwise_matches_full_pass() {
        let skip: Vec<f32> = (0..32).map(|i| i as f32 * 0.5 - 8.0).collect();
        let base: Vec<f32> = (0..32).map(|i| ((i * 7) % 13) as f32 - 6.0).collect();
        let epi = Epilogue::ReluAdd(&skip);
        let mut whole = base.clone();
        epi.apply(&mut whole, 0);
        let mut pieces = base.clone();
        for (i, chunk) in pieces.chunks_mut(5).enumerate() {
            epi.apply(chunk, i * 5);
        }
        assert_eq!(whole, pieces);
    }

    #[test]
    #[should_panic]
    fn skip_length_checked() {
        Epilogue::ReluAdd(&[0.0; 3]).check_len(4);
    }
}
