//! Operator algebra for sliding-window sums.
//!
//! The paper's algorithm family is generic over a binary operator `⊕`.
//! Everything below implements [`AssocOp`]: an *associative* operator with
//! an identity element, over a copyable element type. Associativity is what
//! licenses the `O(log w)`-depth prefix/suffix evaluation (paper §2.1–2.2);
//! the plain `O(w)`-depth variants of the algorithms only need a monoid.
//!
//! The star of the show is [`ConvPair`] — the pair operator of paper Eq. 8
//! that turns a dot product into a prefix sum, which is what lets
//! convolution ride the same sliding-sum machinery as pooling.

mod conv_pair;
mod epilogue;
pub use conv_pair::{dot_reference, dot_via_prefix, dot_via_tree_reduce, encode_gamma, ConvPair, Pair};
pub use epilogue::Epilogue;

/// An associative binary operator with identity, over element type `T`.
///
/// Laws (checked by property tests in `rust/tests/proptests.rs`):
/// * `combine(identity(), x) == x == combine(x, identity())`
/// * `combine(a, combine(b, c)) == combine(combine(a, b), c)`
///   (exactly for lattice/integer ops; up to FP rounding for `+`/`×`).
///
/// Operators are value-semantic descriptors (`Copy + Send + Sync`), so
/// the data-parallel dispatch in [`crate::sliding`] can share them
/// across worker-pool threads.
pub trait AssocOp: Copy + Send + Sync + 'static {
    /// Element type flowing through the operator.
    type Elem: Copy + PartialEq + std::fmt::Debug + Send + Sync + 'static;

    /// Identity element: `identity ⊕ x = x ⊕ identity = x`.
    fn identity(&self) -> Self::Elem;

    /// The operator `⊕`.
    fn combine(&self, a: Self::Elem, b: Self::Elem) -> Self::Elem;

    /// Lane-wise `dst[i] ← dst[i] ⊕ src[i]` over
    /// `min(dst.len(), src.len())` — the inner loop of
    /// [`crate::simd::VecReg::combine_assign`] and the flat-tree doubling
    /// ladder. The default is the plain fold loop; the `f32`
    /// instantiations of add/max/min override it with the
    /// runtime-dispatched `std::arch` kernels in [`crate::simd`].
    /// Overrides must stay bit-identical to this default (asserted by
    /// `tests/simd_parity.rs`).
    #[inline]
    fn combine_assign_slices(&self, dst: &mut [Self::Elem], src: &[Self::Elem]) {
        for (d, s) in dst.iter_mut().zip(src) {
            *d = self.combine(*d, *s);
        }
    }

    /// Whether `⊕` also commutes. Commutativity is *not* required by any
    /// algorithm here (Eq. 8's pair operator is non-commutative), but the
    /// dispatcher may exploit it for cheaper suffix-sum construction.
    fn is_commutative(&self) -> bool {
        false
    }

    /// Whether `x ⊕ x = x` (max/min). Idempotence lets the log-depth
    /// sliding variants cover any window size with two overlapping
    /// power-of-two windows instead of a full binary decomposition.
    fn is_idempotent(&self) -> bool {
        false
    }

    /// Human-readable name for bench tables and diagnostics.
    fn name(&self) -> &'static str;
}

/// Scalar element suitable for the arithmetic operators below.
pub trait Scalar:
    Copy + PartialEq + PartialOrd + std::fmt::Debug + Send + Sync + 'static
{
    const ZERO: Self;
    const ONE: Self;
    /// Smallest representable value (identity for `max`).
    const MIN_VALUE: Self;
    /// Largest representable value (identity for `min`).
    const MAX_VALUE: Self;
    fn add(self, rhs: Self) -> Self;
    fn mul(self, rhs: Self) -> Self;
    fn maximum(self, rhs: Self) -> Self;
    fn minimum(self, rhs: Self) -> Self;
}

macro_rules! impl_scalar_float {
    ($t:ty) => {
        impl Scalar for $t {
            const ZERO: Self = 0.0;
            const ONE: Self = 1.0;
            const MIN_VALUE: Self = <$t>::NEG_INFINITY;
            const MAX_VALUE: Self = <$t>::INFINITY;
            #[inline(always)]
            fn add(self, rhs: Self) -> Self {
                self + rhs
            }
            #[inline(always)]
            fn mul(self, rhs: Self) -> Self {
                self * rhs
            }
            #[inline(always)]
            fn maximum(self, rhs: Self) -> Self {
                if self > rhs { self } else { rhs }
            }
            #[inline(always)]
            fn minimum(self, rhs: Self) -> Self {
                if self < rhs { self } else { rhs }
            }
        }
    };
}

macro_rules! impl_scalar_int {
    ($t:ty) => {
        impl Scalar for $t {
            const ZERO: Self = 0;
            const ONE: Self = 1;
            const MIN_VALUE: Self = <$t>::MIN;
            const MAX_VALUE: Self = <$t>::MAX;
            #[inline(always)]
            fn add(self, rhs: Self) -> Self {
                self.wrapping_add(rhs)
            }
            #[inline(always)]
            fn mul(self, rhs: Self) -> Self {
                self.wrapping_mul(rhs)
            }
            #[inline(always)]
            fn maximum(self, rhs: Self) -> Self {
                if self > rhs { self } else { rhs }
            }
            #[inline(always)]
            fn minimum(self, rhs: Self) -> Self {
                if self < rhs { self } else { rhs }
            }
        }
    };
}

impl_scalar_float!(f32);
impl_scalar_float!(f64);
impl_scalar_int!(i32);
impl_scalar_int!(i64);
impl_scalar_int!(u32);
impl_scalar_int!(u64);

/// `⊕ = +` — the average-pooling / plain windowed-sum operator (paper §2.3).
#[derive(Clone, Copy, Debug, Default)]
pub struct AddOp<T>(std::marker::PhantomData<T>);

impl<T> AddOp<T> {
    pub const fn new() -> Self {
        Self(std::marker::PhantomData)
    }
}

impl<T: Scalar> AssocOp for AddOp<T> {
    type Elem = T;
    #[inline(always)]
    fn identity(&self) -> T {
        T::ZERO
    }
    #[inline(always)]
    fn combine(&self, a: T, b: T) -> T {
        a.add(b)
    }
    #[inline]
    fn combine_assign_slices(&self, dst: &mut [T], src: &[T]) {
        if let (Some(d), Some(s)) = (crate::simd::as_f32_mut(dst), crate::simd::as_f32(src)) {
            crate::simd::add_assign_f32(d, s);
            return;
        }
        for (d, s) in dst.iter_mut().zip(src) {
            *d = self.combine(*d, *s);
        }
    }
    fn is_commutative(&self) -> bool {
        true
    }
    fn name(&self) -> &'static str {
        "add"
    }
}

/// `⊕ = ×` — product windows (used by tests as a second commutative monoid).
#[derive(Clone, Copy, Debug, Default)]
pub struct MulOp<T>(std::marker::PhantomData<T>);

impl<T> MulOp<T> {
    pub const fn new() -> Self {
        Self(std::marker::PhantomData)
    }
}

impl<T: Scalar> AssocOp for MulOp<T> {
    type Elem = T;
    #[inline(always)]
    fn identity(&self) -> T {
        T::ONE
    }
    #[inline(always)]
    fn combine(&self, a: T, b: T) -> T {
        a.mul(b)
    }
    fn is_commutative(&self) -> bool {
        true
    }
    fn name(&self) -> &'static str {
        "mul"
    }
}

/// `⊕ = max` — the max-pooling operator (paper §2.3).
#[derive(Clone, Copy, Debug, Default)]
pub struct MaxOp<T>(std::marker::PhantomData<T>);

impl<T> MaxOp<T> {
    pub const fn new() -> Self {
        Self(std::marker::PhantomData)
    }
}

impl<T: Scalar> AssocOp for MaxOp<T> {
    type Elem = T;
    #[inline(always)]
    fn identity(&self) -> T {
        T::MIN_VALUE
    }
    #[inline(always)]
    fn combine(&self, a: T, b: T) -> T {
        a.maximum(b)
    }
    #[inline]
    fn combine_assign_slices(&self, dst: &mut [T], src: &[T]) {
        if let (Some(d), Some(s)) = (crate::simd::as_f32_mut(dst), crate::simd::as_f32(src)) {
            crate::simd::max_assign_f32(d, s);
            return;
        }
        for (d, s) in dst.iter_mut().zip(src) {
            *d = self.combine(*d, *s);
        }
    }
    fn is_commutative(&self) -> bool {
        true
    }
    fn is_idempotent(&self) -> bool {
        true
    }
    fn name(&self) -> &'static str {
        "max"
    }
}

/// `⊕ = min` — sliding-window minimum, the minimizer-seed operator the
/// paper's §3 calls out ("since min is an associative operator...").
#[derive(Clone, Copy, Debug, Default)]
pub struct MinOp<T>(std::marker::PhantomData<T>);

impl<T> MinOp<T> {
    pub const fn new() -> Self {
        Self(std::marker::PhantomData)
    }
}

impl<T: Scalar> AssocOp for MinOp<T> {
    type Elem = T;
    #[inline(always)]
    fn identity(&self) -> T {
        T::MAX_VALUE
    }
    #[inline(always)]
    fn combine(&self, a: T, b: T) -> T {
        a.minimum(b)
    }
    #[inline]
    fn combine_assign_slices(&self, dst: &mut [T], src: &[T]) {
        if let (Some(d), Some(s)) = (crate::simd::as_f32_mut(dst), crate::simd::as_f32(src)) {
            crate::simd::min_assign_f32(d, s);
            return;
        }
        for (d, s) in dst.iter_mut().zip(src) {
            *d = self.combine(*d, *s);
        }
    }
    fn is_commutative(&self) -> bool {
        true
    }
    fn is_idempotent(&self) -> bool {
        true
    }
    fn name(&self) -> &'static str {
        "min"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_identity_and_combine() {
        let op = AddOp::<f32>::new();
        assert_eq!(op.identity(), 0.0);
        assert_eq!(op.combine(2.0, 3.5), 5.5);
        assert!(op.is_commutative());
    }

    #[test]
    fn mul_identity_and_combine() {
        let op = MulOp::<f64>::new();
        assert_eq!(op.identity(), 1.0);
        assert_eq!(op.combine(2.0, 3.5), 7.0);
    }

    #[test]
    fn max_identity_absorbs() {
        let op = MaxOp::<f32>::new();
        assert_eq!(op.combine(op.identity(), -1e30), -1e30);
        assert_eq!(op.combine(3.0, 7.0), 7.0);
    }

    #[test]
    fn min_identity_absorbs() {
        let op = MinOp::<i32>::new();
        assert_eq!(op.combine(op.identity(), i32::MAX - 1), i32::MAX - 1);
        assert_eq!(op.combine(3, 7), 3);
    }

    #[test]
    fn int_ops_associative_exactly() {
        let op = AddOp::<i64>::new();
        for (a, b, c) in [(1i64, 2, 3), (-5, 7, 11), (1 << 40, 3, -9)] {
            assert_eq!(
                op.combine(a, op.combine(b, c)),
                op.combine(op.combine(a, b), c)
            );
        }
    }

    #[test]
    fn names_are_stable() {
        assert_eq!(AddOp::<f32>::new().name(), "add");
        assert_eq!(MaxOp::<f32>::new().name(), "max");
        assert_eq!(MinOp::<f32>::new().name(), "min");
        assert_eq!(MulOp::<f32>::new().name(), "mul");
    }
}
