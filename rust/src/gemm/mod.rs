//! Blocked SGEMM — the substrate for the paper's im2col+GEMM comparator.
//!
//! The paper benchmarks against ONNX Runtime's `MlasConv`, which lowers
//! convolution to im2col followed by a hand-tuned GEMM. ONNX Runtime is
//! not available in this environment, so we rebuild the same structure:
//! a cache-blocked, register-tiled `C ← A·B + C` with packed panels
//! (BLIS-style MC/KC/NC blocking around an MR×NR microkernel). The conv
//! baseline in [`crate::conv::im2col`] drives this exactly like MlasConv
//! drives its GEMM, so the sliding-vs-GEMM *ratio* (Fig 1/Fig 2) is
//! preserved even though absolute GFLOPs differ from the authors' Xeon.

mod blocked;
mod naive;

pub use blocked::{
    gemm, gemm_bias, gemm_bias_epilogue_with, gemm_bias_with, gemm_blocked, gemm_blocked_with,
    gemm_with, GemmBlocking,
};
pub use naive::gemm_naive;

/// Row-major matrix view dims: `a` is m×k, `b` is k×n, `c` is m×n.
#[derive(Clone, Copy, Debug)]
pub struct GemmShape {
    pub m: usize,
    pub k: usize,
    pub n: usize,
}

impl GemmShape {
    pub fn flops(&self) -> u64 {
        2 * self.m as u64 * self.n as u64 * self.k as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flops_counts_fma_as_two() {
        let s = GemmShape { m: 3, k: 4, n: 5 };
        assert_eq!(s.flops(), 120);
    }
}
