//! Triple-loop reference GEMM (correctness oracle for the blocked kernel).

/// `c[m×n] += a[m×k] · b[k×n]`, all row-major, no blocking. O(mnk).
pub fn gemm_naive(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    assert_eq!(a.len(), m * k, "a shape");
    assert_eq!(b.len(), k * n, "b shape");
    assert_eq!(c.len(), m * n, "c shape");
    for i in 0..m {
        for p in 0..k {
            let aip = a[i * k + p];
            if aip == 0.0 {
                continue;
            }
            for j in 0..n {
                c[i * n + j] += aip * b[p * n + j];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_times_b_is_b() {
        let a = [1.0f32, 0.0, 0.0, 1.0]; // I2
        let b = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]; // 2×3
        let mut c = [0.0f32; 6];
        gemm_naive(2, 2, 3, &a, &b, &mut c);
        assert_eq!(c, b);
    }

    #[test]
    fn small_known_product() {
        // [[1,2],[3,4]] · [[5,6],[7,8]] = [[19,22],[43,50]]
        let a = [1.0f32, 2.0, 3.0, 4.0];
        let b = [5.0f32, 6.0, 7.0, 8.0];
        let mut c = [0.0f32; 4];
        gemm_naive(2, 2, 2, &a, &b, &mut c);
        assert_eq!(c, [19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn accumulates_into_c() {
        let a = [1.0f32];
        let b = [2.0f32];
        let mut c = [10.0f32];
        gemm_naive(1, 1, 1, &a, &b, &mut c);
        assert_eq!(c, [12.0]);
    }
}
