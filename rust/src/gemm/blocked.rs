//! Cache-blocked, register-tiled SGEMM (BLIS-style).
//!
//! Loop structure: NC → KC → MC blocking with packed A (MC×KC,
//! micro-panel major) and packed B (KC×NC, micro-panel major), around an
//! MR×NR microkernel kept entirely in registers. Tile sizes default to a
//! shape that fits L1/L2 on commodity x86; they are parameters so the
//! bench harness can expose the blocking ablation (TBL-A in DESIGN.md).

use crate::exec::{Executor, PAR_MIN_FANOUT};
use crate::ops::Epilogue;

use super::GemmShape;

/// Register microkernel tile: MR×NR accumulator block.
const MR: usize = 8;
const NR: usize = 8;

/// Cache blocking parameters.
#[derive(Clone, Copy, Debug)]
pub struct GemmBlocking {
    /// Rows of A per L2-resident packed block.
    pub mc: usize,
    /// Depth per L1-resident packed panel.
    pub kc: usize,
    /// Columns of B per L3-resident packed block.
    pub nc: usize,
}

impl Default for GemmBlocking {
    fn default() -> Self {
        Self { mc: 128, kc: 256, nc: 512 }
    }
}

/// `c[m×n] += a[m×k]·b[k×n]` with default blocking, parallel over output
/// rows on the shared worker pool.
pub fn gemm(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    gemm_with(Executor::global(), m, k, n, a, b, c)
}

/// [`gemm`] on an explicit executor (thread-pinned benches / parity
/// tests).
pub fn gemm_with(ex: &Executor, m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    gemm_blocked_with(ex, GemmShape { m, k, n }, GemmBlocking::default(), a, b, c)
}

/// GEMM followed by a broadcast bias add over rows: `c[i][j] += bias[i]`.
/// (Conv layers use one bias per output channel = per row of the
/// filter-matrix product.)
pub fn gemm_bias(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], bias: &[f32], c: &mut [f32]) {
    gemm_bias_with(Executor::global(), m, k, n, a, b, bias, c)
}

/// [`gemm_bias`] on an explicit executor.
#[allow(clippy::too_many_arguments)]
pub fn gemm_bias_with(
    ex: &Executor,
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    bias: &[f32],
    c: &mut [f32],
) {
    gemm_bias_epilogue_with(ex, m, k, n, a, b, Some(bias), Epilogue::None, 0, c);
}

/// GEMM with the bias broadcast *and* an element-wise [`Epilogue`] fused
/// into one pass over each C row (instead of gemm → bias pass → relu
/// pass → skip-add pass, four streams of C become two). `flat0` is the
/// flat index of `c[0]` in the full output tensor the epilogue's skip
/// slice is laid out against (the im2col conv path passes the batch
/// element's offset). Bias-then-epilogue per element matches the unfused
/// reference order bit-for-bit.
#[allow(clippy::too_many_arguments)]
pub fn gemm_bias_epilogue_with(
    ex: &Executor,
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    bias: Option<&[f32]>,
    epi: Epilogue<'_>,
    flat0: usize,
    c: &mut [f32],
) {
    gemm_with(ex, m, k, n, a, b, c);
    if let Some(bv) = bias {
        assert_eq!(bv.len(), m);
    }
    for i in 0..m {
        let row = &mut c[i * n..(i + 1) * n];
        if let Some(bv) = bias {
            let bi = bv[i];
            for v in row.iter_mut() {
                *v += bi;
            }
        }
        epi.apply(row, flat0 + i * n);
    }
}

/// Data-parallel entry point: partitions C (and A) into disjoint bands of
/// output rows and runs the serial blocked GEMM on each band concurrently
/// — every C element is computed by identical code on identical inputs,
/// so results are **bit-identical** to [`gemm_blocked`] for every thread
/// count (the honesty requirement for the Fig-1 im2col baseline). The
/// skinny-M case (fewer rows than a microtile, e.g. the single-row Fig-1
/// shape) parallelizes over output-column segments within each row
/// instead.
pub fn gemm_blocked_with(
    ex: &Executor,
    shape: GemmShape,
    blk: GemmBlocking,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
) {
    let GemmShape { m, k, n } = shape;
    let threads = ex.threads();
    if threads <= 1 || m * n < PAR_MIN_FANOUT || k == 0 {
        return gemm_blocked(shape, blk, a, b, c);
    }
    assert_eq!(a.len(), m * k, "a shape");
    assert_eq!(b.len(), k * n, "b shape");
    assert_eq!(c.len(), m * n, "c shape");
    if m < MR {
        // Skinny rows (gemv-like): split each C row into column segments.
        let seg = n.div_ceil(threads * 2).max(1024);
        // alloc-ok: one job closure per row segment (fan-out setup).
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
        for (i, crow) in c.chunks_mut(n).enumerate() {
            let arow = &a[i * k..(i + 1) * k];
            for (si, cseg) in crow.chunks_mut(seg).enumerate() {
                let j0 = si * seg;
                // alloc-ok: job closure box, amortized over a whole segment.
                jobs.push(Box::new(move || skinny_row_segment(arow, b, n, j0, cseg)));
            }
        }
        ex.scope(jobs);
        return;
    }
    // Row bands sized to ~2 jobs per thread, rounded to microtile rows.
    // The per-row accumulation in the packed microkernel is independent
    // of which band (and which micro-panel within it) a row lands in, so
    // any banding of ≥ MR rows reproduces the serial result bitwise. A
    // band *smaller* than MR would take the skinny gemv path instead of
    // the microkernel the serial reference uses — so the last band
    // absorbs any sub-MR tail rather than leaving them as their own job.
    let rows_per_job = m.div_ceil(threads * 2).div_ceil(MR) * MR;
    // alloc-ok: one job closure per row band (fan-out setup).
    let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
    let mut c_rest = c;
    let mut r0 = 0usize;
    while r0 < m {
        let remaining = m - r0;
        let rows = if remaining < rows_per_job + MR {
            remaining
        } else {
            rows_per_job
        };
        let (band, rest) = std::mem::take(&mut c_rest).split_at_mut(rows * n);
        let a_rows = &a[r0 * k..(r0 + rows) * k];
        // alloc-ok: job closure box, amortized over a whole band.
        jobs.push(Box::new(move || {
            gemm_blocked(GemmShape { m: rows, k, n }, blk, a_rows, b, band)
        }));
        c_rest = rest;
        r0 += rows;
    }
    ex.scope(jobs);
}

/// Fully parameterized *serial* entry point — the reference the
/// row-parallel dispatch is bit-identical to.
pub fn gemm_blocked(shape: GemmShape, blk: GemmBlocking, a: &[f32], b: &[f32], c: &mut [f32]) {
    let GemmShape { m, k, n } = shape;
    assert_eq!(a.len(), m * k, "a shape");
    assert_eq!(b.len(), k * n, "b shape");
    assert_eq!(c.len(), m * n, "c shape");
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    // Skinny-M fast path (gemv-like): the MR×NR microkernel would waste
    // (MR−m)/MR of its accumulators. MLAS/BLIS ship dedicated gemv
    // kernels; mirroring that keeps the im2col baseline honest for the
    // single-output-channel Fig-1 workload.
    if m < MR {
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let crow = &mut c[i * n..(i + 1) * n];
            skinny_row_segment(arow, b, n, 0, crow);
        }
        return;
    }

    // Panels are zero-padded to MR/NR multiples; round the buffers up so
    // non-multiple blocking parameters stay in bounds.
    let mc_pad = blk.mc.div_ceil(MR) * MR;
    let nc_pad = blk.nc.div_ceil(NR) * NR;
    // alloc-ok: BLIS-style pack buffers, one pair per gemm call (their
    // size depends on the blocking, not the problem; amortized over the
    // whole k·m·n sweep).
    let mut a_pack = vec![0.0f32; mc_pad * blk.kc];
    let mut b_pack = vec![0.0f32; blk.kc * nc_pad]; // alloc-ok: pack buffer

    let mut jc = 0;
    while jc < n {
        let nc = blk.nc.min(n - jc);
        let mut pc = 0;
        while pc < k {
            let kc = blk.kc.min(k - pc);
            pack_b(&mut b_pack, b, k, n, pc, jc, kc, nc);
            let mut ic = 0;
            while ic < m {
                let mc = blk.mc.min(m - ic);
                pack_a(&mut a_pack, a, k, ic, pc, mc, kc);
                macro_kernel(&a_pack, &b_pack, c, n, ic, jc, mc, nc, kc);
                ic += mc;
            }
            pc += kc;
        }
        jc += nc;
    }
}

/// One gemv-like row segment of the skinny-M path: accumulate
/// `cseg[j] += arow[p] · b[p][j0 + j]` over the full depth, skipping
/// zero taps (identically in the serial and parallel schedules).
fn skinny_row_segment(arow: &[f32], b: &[f32], ldb: usize, j0: usize, cseg: &mut [f32]) {
    for (p, &ap) in arow.iter().enumerate() {
        if ap == 0.0 {
            continue;
        }
        let brow = &b[p * ldb + j0..][..cseg.len()];
        for (cv, &bv) in cseg.iter_mut().zip(brow) {
            *cv = ap.mul_add(bv, *cv);
        }
    }
}

/// Pack an MC×KC block of A into MR-row micro-panels (column-major within
/// each panel) so the microkernel streams it contiguously.
fn pack_a(dst: &mut [f32], a: &[f32], lda: usize, ic: usize, pc: usize, mc: usize, kc: usize) {
    let mut out = 0;
    let mut ir = 0;
    while ir < mc {
        let mr = MR.min(mc - ir);
        for p in 0..kc {
            for r in 0..MR {
                dst[out] = if r < mr {
                    a[(ic + ir + r) * lda + pc + p]
                } else {
                    0.0
                };
                out += 1;
            }
        }
        ir += MR;
    }
}

/// Pack a KC×NC block of B into NR-column micro-panels (row-major within
/// each panel).
#[allow(clippy::too_many_arguments)]
fn pack_b(
    dst: &mut [f32],
    b: &[f32],
    _ldbk: usize,
    ldb: usize,
    pc: usize,
    jc: usize,
    kc: usize,
    nc: usize,
) {
    let mut out = 0;
    let mut jr = 0;
    while jr < nc {
        let nr = NR.min(nc - jr);
        for p in 0..kc {
            for cidx in 0..NR {
                dst[out] = if cidx < nr {
                    b[(pc + p) * ldb + jc + jr + cidx]
                } else {
                    0.0
                };
                out += 1;
            }
        }
        jr += NR;
    }
}

/// Macro kernel: sweep micro-panels, dispatching to the register kernel.
#[allow(clippy::too_many_arguments)]
fn macro_kernel(
    a_pack: &[f32],
    b_pack: &[f32],
    c: &mut [f32],
    ldc: usize,
    ic: usize,
    jc: usize,
    mc: usize,
    nc: usize,
    kc: usize,
) {
    let mut jr = 0;
    while jr < nc {
        let nr = NR.min(nc - jr);
        let bp = &b_pack[(jr / NR) * kc * NR..][..kc * NR];
        let mut ir = 0;
        while ir < mc {
            let mr = MR.min(mc - ir);
            let ap = &a_pack[(ir / MR) * kc * MR..][..kc * MR];
            micro_kernel(ap, bp, kc, c, ldc, ic + ir, jc + jr, mr, nr);
            ir += MR;
        }
        jr += NR;
    }
}

/// MR×NR register microkernel: `acc += ap·bp` over the packed panels,
/// then spill into C. The inner loop is a rank-1 update per depth step —
/// LLVM turns the NR-wide row updates into FMA vector ops.
#[allow(clippy::too_many_arguments)]
#[inline]
fn micro_kernel(
    ap: &[f32],
    bp: &[f32],
    kc: usize,
    c: &mut [f32],
    ldc: usize,
    row0: usize,
    col0: usize,
    mr: usize,
    nr: usize,
) {
    let mut acc = [[0.0f32; NR]; MR];
    for p in 0..kc {
        let arow = &ap[p * MR..p * MR + MR];
        let brow = &bp[p * NR..p * NR + NR];
        for r in 0..MR {
            let ar = arow[r];
            for cidx in 0..NR {
                acc[r][cidx] = ar.mul_add(brow[cidx], acc[r][cidx]);
            }
        }
    }
    for r in 0..mr {
        let crow = &mut c[(row0 + r) * ldc + col0..];
        for cidx in 0..nr {
            crow[cidx] += acc[r][cidx];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::gemm_naive;
    use super::*;

    fn xorshift_fill(buf: &mut [f32], seed: &mut u64) {
        for v in buf.iter_mut() {
            *seed ^= *seed << 13;
            *seed ^= *seed >> 7;
            *seed ^= *seed << 17;
            *v = ((*seed >> 40) as f32 / (1u64 << 24) as f32) * 2.0 - 1.0;
        }
    }

    fn check(m: usize, k: usize, n: usize) {
        let mut seed = 0x12345678abcdefu64 ^ ((m * 73 + k * 37 + n) as u64);
        let mut a = vec![0.0f32; m * k];
        let mut b = vec![0.0f32; k * n];
        xorshift_fill(&mut a, &mut seed);
        xorshift_fill(&mut b, &mut seed);
        let mut c1 = vec![0.0f32; m * n];
        let mut c2 = vec![0.0f32; m * n];
        gemm(m, k, n, &a, &b, &mut c1);
        gemm_naive(m, k, n, &a, &b, &mut c2);
        for i in 0..m * n {
            assert!(
                (c1[i] - c2[i]).abs() <= 1e-3 * (1.0 + c2[i].abs()),
                "({m},{k},{n}) idx {i}: {} vs {}",
                c1[i],
                c2[i]
            );
        }
    }

    #[test]
    fn matches_naive_small() {
        for (m, k, n) in [(1, 1, 1), (2, 3, 4), (8, 8, 8), (5, 7, 9)] {
            check(m, k, n);
        }
    }

    #[test]
    fn matches_naive_tile_edges() {
        // Exercise partial MR/NR tiles and blocking boundaries.
        for (m, k, n) in [(9, 17, 9), (16, 16, 16), (33, 65, 31), (130, 70, 100)] {
            check(m, k, n);
        }
    }

    #[test]
    fn matches_naive_bigger_than_blocks() {
        check(150, 300, 80); // crosses mc and kc
    }

    #[test]
    fn zero_dims_are_noops() {
        let mut c: Vec<f32> = vec![];
        gemm(0, 4, 0, &[], &[0.0; 0], &mut c);
        assert!(c.is_empty());
    }

    #[test]
    fn custom_blocking_agrees() {
        let m = 40;
        let k = 50;
        let n = 60;
        let mut seed = 99u64;
        let mut a = vec![0.0f32; m * k];
        let mut b = vec![0.0f32; k * n];
        xorshift_fill(&mut a, &mut seed);
        xorshift_fill(&mut b, &mut seed);
        let mut c1 = vec![0.0f32; m * n];
        let mut c2 = vec![0.0f32; m * n];
        gemm_blocked(
            GemmShape { m, k, n },
            GemmBlocking { mc: 16, kc: 24, nc: 32 },
            &a,
            &b,
            &mut c1,
        );
        gemm_naive(m, k, n, &a, &b, &mut c2);
        for i in 0..m * n {
            assert!((c1[i] - c2[i]).abs() <= 1e-3 * (1.0 + c2[i].abs()));
        }
    }

    #[test]
    fn parallel_bands_bit_identical_including_sub_microtile_tail() {
        // m=9 with threads>1 once split into an 8-row band plus a 1-row
        // tail that took the skinny gemv path; bands must stay ≥ MR rows
        // so every row goes through the same microkernel as the serial
        // reference. Also covers the skinny (m < MR) column-segment path.
        for (m, k, n) in [(9usize, 64usize, 1000usize), (17, 33, 700), (4, 16, 4096)] {
            let mut seed = 0xC0FFEE ^ ((m * 31 + k) as u64);
            let mut a = vec![0.0f32; m * k];
            let mut b = vec![0.0f32; k * n];
            xorshift_fill(&mut a, &mut seed);
            xorshift_fill(&mut b, &mut seed);
            let shape = GemmShape { m, k, n };
            let mut want = vec![0.0f32; m * n];
            gemm_blocked(shape, GemmBlocking::default(), &a, &b, &mut want);
            for t in [2usize, 3, 4, 8] {
                let ex = Executor::new(t);
                let mut got = vec![0.0f32; m * n];
                gemm_blocked_with(&ex, shape, GemmBlocking::default(), &a, &b, &mut got);
                assert_eq!(got, want, "m={m} k={k} n={n} threads={t}");
            }
        }
    }

    #[test]
    fn bias_broadcast_rows() {
        let a = [1.0f32, 0.0, 0.0, 1.0];
        let b = [1.0f32, 2.0, 3.0, 4.0];
        let bias = [10.0f32, 20.0];
        let mut c = [0.0f32; 4];
        gemm_bias(2, 2, 2, &a, &b, &bias, &mut c);
        assert_eq!(c, [11.0, 12.0, 23.0, 24.0]);
    }
}
