//! Artifact registry: discovers `artifacts/*.hlo.txt`, reads the
//! `manifest.toml` the AOT exporter writes, and compiles executables
//! lazily with a cache (one compile per model variant per process).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use super::{Executable, Runtime};
use crate::config;

/// TCN metadata from `manifest.toml` — the parameter layout contract
/// between `python/compile/model.py` and the rust coordinator.
#[derive(Clone, Debug, PartialEq)]
pub struct TcnManifest {
    pub params: usize,
    pub hidden: usize,
    pub n_blocks: usize,
    pub kernel: usize,
    pub stem_kernel: usize,
    pub seq_len: usize,
    pub c_in: usize,
    pub c_out: usize,
    pub receptive_field: usize,
}

impl TcnManifest {
    /// Ordered parameter shapes — mirrors `model.param_shapes`.
    pub fn param_shapes(&self) -> Vec<(String, Vec<usize>)> {
        let mut shapes = vec![
            ("stem_w".into(), vec![self.hidden, self.c_in, self.stem_kernel]),
            ("stem_b".into(), vec![self.hidden]),
        ];
        for i in 0..self.n_blocks {
            shapes.push((format!("block{i}_w1"), vec![self.hidden, self.hidden, self.kernel]));
            shapes.push((format!("block{i}_b1"), vec![self.hidden]));
            shapes.push((format!("block{i}_w2"), vec![self.hidden, self.hidden, self.kernel]));
            shapes.push((format!("block{i}_b2"), vec![self.hidden]));
        }
        shapes.push(("head_w".into(), vec![self.c_out, self.hidden, 1]));
        shapes.push(("head_b".into(), vec![self.c_out]));
        shapes
    }

    pub fn param_count(&self) -> usize {
        self.param_shapes()
            .iter()
            .map(|(_, s)| s.iter().product::<usize>())
            .sum()
    }
}

/// Registry over an artifacts directory.
pub struct ArtifactRegistry {
    dir: PathBuf,
    runtime: Runtime,
    cache: Mutex<HashMap<String, std::sync::Arc<Executable>>>,
    manifest: Option<TcnManifest>,
}

impl ArtifactRegistry {
    /// Open a registry rooted at `dir` (normally `artifacts/`).
    pub fn open(dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = dir.into();
        if !dir.is_dir() {
            bail!(
                "artifact directory {} not found — run `make artifacts` first",
                dir.display()
            );
        }
        let runtime = Runtime::cpu()?;
        let manifest = Self::read_manifest(&dir.join("manifest.toml")).ok();
        Ok(Self {
            dir,
            runtime,
            cache: Mutex::new(HashMap::new()),
            manifest,
        })
    }

    fn read_manifest(path: &Path) -> Result<TcnManifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let doc = config::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let get = |k: &str| -> Result<usize> {
            doc.get_int(&format!("tcn.{k}"))
                .map(|v| v as usize)
                .with_context(|| format!("manifest missing tcn.{k}"))
        };
        Ok(TcnManifest {
            params: get("params")?,
            hidden: get("hidden")?,
            n_blocks: get("n_blocks")?,
            kernel: get("kernel")?,
            stem_kernel: get("stem_kernel")?,
            seq_len: get("seq_len")?,
            c_in: get("c_in")?,
            c_out: get("c_out")?,
            receptive_field: get("receptive_field")?,
        })
    }

    pub fn manifest(&self) -> Option<&TcnManifest> {
        self.manifest.as_ref()
    }

    pub fn runtime(&self) -> &Runtime {
        &self.runtime
    }

    /// Artifact names present on disk (sorted).
    pub fn list(&self) -> Result<Vec<String>> {
        let mut names = Vec::new();
        for entry in std::fs::read_dir(&self.dir)? {
            let path = entry?.path();
            if let Some(fname) = path.file_name().and_then(|s| s.to_str()) {
                if let Some(stem) = fname.strip_suffix(".hlo.txt") {
                    names.push(stem.to_string());
                }
            }
        }
        names.sort();
        Ok(names)
    }

    /// Get (compile-once) an executable by artifact name.
    pub fn get(&self, name: &str) -> Result<std::sync::Arc<Executable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(name) {
            return Ok(std::sync::Arc::clone(exe));
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        if !path.is_file() {
            bail!(
                "artifact {name:?} not found in {} (have: {:?})",
                self.dir.display(),
                self.list().unwrap_or_default()
            );
        }
        let exe = std::sync::Arc::new(self.runtime.load(&path)?);
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), std::sync::Arc::clone(&exe));
        Ok(exe)
    }

    /// Whether an artifact exists without compiling it.
    pub fn contains(&self, name: &str) -> bool {
        self.dir.join(format!("{name}.hlo.txt")).is_file()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_param_layout_matches_python() {
        // Mirror of model.TcnConfig(): hidden 32, 4 blocks, k 3, stem 7.
        let m = TcnManifest {
            params: 25121,
            hidden: 32,
            n_blocks: 4,
            kernel: 3,
            stem_kernel: 7,
            seq_len: 512,
            c_in: 1,
            c_out: 1,
            receptive_field: 67,
        };
        assert_eq!(m.param_count(), m.params);
        let shapes = m.param_shapes();
        assert_eq!(shapes.len(), 2 + 4 * 4 + 2);
        assert_eq!(shapes[0].1, vec![32, 1, 7]);
        assert_eq!(shapes.last().unwrap().1, vec![1]);
    }

    #[test]
    fn open_missing_dir_errors() {
        let err = match ArtifactRegistry::open("/definitely/missing/dir") {
            Err(e) => e,
            Ok(_) => panic!("expected error"),
        };
        assert!(err.to_string().contains("make artifacts"));
    }
}
