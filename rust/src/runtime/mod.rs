//! PJRT runtime: load AOT artifacts (`artifacts/*.hlo.txt`) and execute
//! them from the rust request path.
//!
//! Flow (see /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! HLO **text** is the interchange format (xla_extension 0.5.1 rejects
//! jax≥0.5 serialized protos). All artifacts are lowered with
//! `return_tuple=True`, so results always unwrap through a tuple.
//!
//! The `xla` bindings are native and unavailable in offline/CI builds,
//! so everything touching them is gated behind the `pjrt` cargo feature.
//! Without it, this module compiles std-only stubs with the same API
//! that fail with a clear error at runtime — the rest of the crate (the
//! paper's kernels, the coordinator, the benches) is fully functional.

mod artifacts;
mod executable;

pub use artifacts::{ArtifactRegistry, TcnManifest};
pub use executable::{Executable, TensorView};

use anyhow::Result;

/// Shared PJRT CPU client. Creating a client is expensive (spins up the
/// TFRT runtime); share one per process.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    client: std::sync::Arc<xla::PjRtClient>,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Create a CPU-backed runtime.
    pub fn cpu() -> Result<Self> {
        use anyhow::Context;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self {
            client: std::sync::Arc::new(client),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load and compile one HLO-text artifact.
    pub fn load(&self, path: &std::path::Path) -> Result<Executable> {
        Executable::load(std::sync::Arc::clone(&self.client), path)
    }
}

/// Convenience used by smoke tests.
#[cfg(feature = "pjrt")]
pub fn cpu_client() -> Result<xla::PjRtClient> {
    Ok(xla::PjRtClient::cpu()?)
}

/// Stub runtime compiled without the `pjrt` feature: construction fails
/// with an actionable error instead of a missing native dependency.
#[cfg(not(feature = "pjrt"))]
pub struct Runtime {
    _private: (),
}

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    pub fn cpu() -> Result<Self> {
        anyhow::bail!(
            "PJRT runtime unavailable: swsnn was built without the `pjrt` feature \
             (rebuild with `--features pjrt` and a vendored xla crate)"
        )
    }

    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn load(&self, _path: &std::path::Path) -> Result<Executable> {
        anyhow::bail!("PJRT runtime unavailable: built without the `pjrt` feature")
    }
}
