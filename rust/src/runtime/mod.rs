//! PJRT runtime: load AOT artifacts (`artifacts/*.hlo.txt`) and execute
//! them from the rust request path.
//!
//! Flow (see /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! HLO **text** is the interchange format (xla_extension 0.5.1 rejects
//! jax≥0.5 serialized protos). All artifacts are lowered with
//! `return_tuple=True`, so results always unwrap through a tuple.

mod artifacts;
mod executable;

pub use artifacts::{ArtifactRegistry, TcnManifest};
pub use executable::{Executable, TensorView};

use std::sync::Arc;

use anyhow::{Context, Result};

/// Shared PJRT CPU client. Creating a client is expensive (spins up the
/// TFRT runtime); share one per process.
pub struct Runtime {
    client: Arc<xla::PjRtClient>,
}

impl Runtime {
    /// Create a CPU-backed runtime.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self {
            client: Arc::new(client),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load and compile one HLO-text artifact.
    pub fn load(&self, path: &std::path::Path) -> Result<Executable> {
        Executable::load(Arc::clone(&self.client), path)
    }
}

/// Convenience used by smoke tests.
pub fn cpu_client() -> Result<xla::PjRtClient> {
    Ok(xla::PjRtClient::cpu()?)
}
