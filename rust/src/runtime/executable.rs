//! A compiled AOT artifact plus typed f32 execute helpers. The xla-bound
//! half is gated behind the `pjrt` feature; [`TensorView`] itself is
//! plain rust and always available (the coordinator and tests use it).

use anyhow::Result;

/// An f32 tensor argument/result: shape + contiguous row-major data.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorView {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl TensorView {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, data.len(), "shape/data mismatch");
        Self { shape, data }
    }

    pub fn scalar(v: f32) -> Self {
        Self {
            shape: vec![],
            data: vec![v],
        }
    }

    pub fn element_count(&self) -> usize {
        self.data.len()
    }

    #[cfg(feature = "pjrt")]
    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = xla::Literal::vec1(&self.data);
        if self.shape.is_empty() {
            // rank-0: reshape to scalar
            Ok(lit.reshape(&[])?)
        } else {
            let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
            Ok(lit.reshape(&dims)?)
        }
    }

    #[cfg(feature = "pjrt")]
    fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<f32>()?;
        Ok(Self { shape: dims, data })
    }
}

/// A compiled HLO artifact bound to a PJRT client.
#[cfg(feature = "pjrt")]
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    name: String,
    _client: std::sync::Arc<xla::PjRtClient>,
}

#[cfg(feature = "pjrt")]
impl Executable {
    /// Load HLO text, reassigning instruction ids via the text parser
    /// (the 64-bit-id workaround), and JIT-compile it for the client.
    pub fn load(client: std::sync::Arc<xla::PjRtClient>, path: &std::path::Path) -> Result<Self> {
        use anyhow::Context;
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        let name = path
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_default()
            .trim_end_matches(".hlo")
            .to_string();
        Ok(Self {
            exe,
            name,
            _client: client,
        })
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Execute with f32 tensors; returns the flattened tuple elements.
    /// (All artifacts are lowered with `return_tuple=True`.)
    pub fn run(&self, inputs: &[TensorView]) -> Result<Vec<TensorView>> {
        use anyhow::{bail, Context};
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?;
        let first = result
            .first()
            .and_then(|r| r.first())
            .context("empty execution result")?;
        let lit = first.to_literal_sync()?;
        let parts = lit.to_tuple()?;
        if parts.is_empty() {
            bail!("artifact {} returned an empty tuple", self.name);
        }
        parts.iter().map(TensorView::from_literal).collect()
    }

    /// Execute expecting exactly one output tensor.
    pub fn run1(&self, inputs: &[TensorView]) -> Result<TensorView> {
        use anyhow::bail;
        let mut out = self.run(inputs)?;
        if out.len() != 1 {
            bail!(
                "artifact {} returned {} outputs, expected 1",
                self.name,
                out.len()
            );
        }
        Ok(out.pop().unwrap())
    }
}

/// Stub executable compiled without the `pjrt` feature. Never actually
/// constructed (the stub [`super::Runtime`] errors first); it exists so
/// code holding `Arc<Executable>` type-checks either way.
#[cfg(not(feature = "pjrt"))]
pub struct Executable {
    name: String,
}

#[cfg(not(feature = "pjrt"))]
impl Executable {
    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn run(&self, _inputs: &[TensorView]) -> Result<Vec<TensorView>> {
        anyhow::bail!(
            "artifact {}: PJRT execution unavailable (built without the `pjrt` feature)",
            self.name
        )
    }

    pub fn run1(&self, _inputs: &[TensorView]) -> Result<TensorView> {
        anyhow::bail!(
            "artifact {}: PJRT execution unavailable (built without the `pjrt` feature)",
            self.name
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_view_shape_checks() {
        let t = TensorView::new(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.element_count(), 6);
        let s = TensorView::scalar(1.5);
        assert_eq!(s.element_count(), 1);
        assert!(s.shape.is_empty());
    }

    #[test]
    #[should_panic]
    fn tensor_view_mismatch_panics() {
        TensorView::new(vec![2, 3], vec![0.0; 5]);
    }
}
