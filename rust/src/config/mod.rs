//! Minimal TOML-subset configuration substrate (serde is unavailable
//! offline). Supports the subset the framework needs: `[section]` and
//! `[section.sub]` headers, `key = value` with string / integer / float /
//! boolean / homogeneous-array values, `#` comments. Typed accessors with
//! defaulting; unknown keys are preserved (forward compatibility) and
//! listable for lint warnings.

mod parse;
mod types;

pub use parse::{parse, ParseError};
pub use types::{ConfigDoc, Value};

use crate::conv::{BackendChoice, ConvBackend};

/// Model configuration — a sequential 1-D network definition.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    /// Input channels of the first layer.
    pub c_in: usize,
    /// Input sequence length the AOT artifacts are specialized to.
    pub seq_len: usize,
    pub layers: Vec<LayerConfig>,
}

/// One layer of the model.
#[derive(Clone, Debug, PartialEq)]
pub enum LayerConfig {
    Conv {
        c_out: usize,
        k: usize,
        stride: usize,
        dilation: usize,
        same_pad: bool,
        relu: bool,
        /// Per-layer kernel override for the execution planner
        /// (`backend = "sliding" | "im2col_gemm" | "direct" |
        /// "sliding_pair"`; omit or `"auto"` to let the cost model
        /// choose). Beats the deployment-level backend either way.
        backend: Option<ConvBackend>,
        /// Per-layer opt-in to int8 quantized execution
        /// (`quantize = "int8"`). The planner never auto-picks the
        /// quantized kernel for layers that did not opt in; with
        /// autotune it is probed against f32 and only wins on measured
        /// time. Absent → f32 only.
        quantize: bool,
    },
    Pool {
        kind: String,
        w: usize,
        stride: usize,
    },
    Residual {
        /// Dilations of the two conv taps inside the TCN block.
        k: usize,
        dilation: usize,
        /// Per-layer kernel override for both convs of the block.
        backend: Option<ConvBackend>,
    },
    Dense {
        out: usize,
        relu: bool,
    },
}

/// Serving configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeConfig {
    pub max_batch: usize,
    pub batch_deadline_us: u64,
    /// Engine workers draining the request queue (each owns an engine).
    pub workers: usize,
    /// Kernel data-parallelism: worker-pool threads the conv/pool/
    /// sliding kernels fan out on. `0` = auto (all cores). Applied to
    /// the process-global [`crate::exec::Executor`] at serve startup.
    pub threads: usize,
    /// Backend selection for the native engine: `"auto"` (default) lets
    /// the execution planner pick a kernel per layer; a concrete
    /// backend name forces it on every layer without a per-layer
    /// `backend =` override.
    pub backend: BackendChoice,
    /// Measured-cost kernel selection (`serve.autotune` / `--autotune`):
    /// plan compiles micro-probe every candidate kernel against the
    /// layer's real shapes instead of trusting the shape heuristic.
    /// Only meaningful under the `auto` backend. Probe results are
    /// cached per (shape, SIMD tier, threads), so `SWSNN_SIMD` and
    /// `--threads` key the tune cache.
    pub autotune: bool,
    /// Batch buckets every engine precompiles at startup
    /// (`serve.batch_buckets = [1, 8, 32]` / `--buckets`): plans,
    /// autotune probes, and arenas are warmed before the first request.
    /// Empty (the default) = a power-of-two ladder up to `max_batch`;
    /// see [`ServeConfig::effective_buckets`].
    pub batch_buckets: Vec<usize>,
    pub queue_capacity: usize,
    /// Default request TTL in milliseconds (`serve.request_ttl_ms` /
    /// `--request-ttl`): the batcher sheds requests it can't start
    /// within this budget with `Shed::DeadlineExpired` instead of
    /// burning compute on them. `0` (default) = requests never expire.
    pub request_ttl_ms: u64,
    /// How many times a panicked worker may be restarted with a fresh
    /// engine (`serve.restart_budget` / `--restart-budget`). Past the
    /// budget the pool degrades to fewer workers.
    pub restart_budget: usize,
    /// Base delay before a worker restart; doubles per attempt
    /// (exponential backoff).
    pub restart_backoff_ms: u64,
    /// Default streaming-session idle TTL in milliseconds
    /// (`serve.session_ttl_ms`): a session not stepped within this
    /// budget is evicted (state recycled; the next step on it is shed
    /// with `DeadlineExpired`). `0` = sessions never expire.
    pub session_ttl_ms: u64,
    /// Maximum live streaming sessions per worker
    /// (`serve.session_capacity`); opens beyond it fail with a typed
    /// engine error.
    pub session_capacity: usize,
    /// Hard cap on concurrently served TCP connections
    /// (`serve.max_connections` / `--max-connections`); accepts beyond
    /// it are refused with the typed `ConnLimit` wire code (8).
    pub max_connections: usize,
    /// Per-connection idle (read) timeout in milliseconds
    /// (`serve.idle_timeout_ms` / `--idle-timeout`): a peer idle or
    /// stalled mid-frame longer than this gets its connection dropped.
    /// `0` = never time out.
    pub idle_timeout_ms: u64,
    /// Per-tenant admission quota in requests/second
    /// (`serve.quota_rps` / `--quota-rps`); over-quota frames get the
    /// typed `QuotaExceeded` wire code (9). `0` (default) = unlimited.
    pub quota_rps: u64,
    /// Token-bucket burst depth per tenant (`serve.quota_burst` /
    /// `--quota-burst`); `0` is treated as 1 when quotas are enabled.
    pub quota_burst: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            max_batch: 8,
            batch_deadline_us: 500,
            workers: 1,
            threads: 0,
            backend: BackendChoice::Auto,
            autotune: false,
            batch_buckets: Vec::new(),
            queue_capacity: 1024,
            request_ttl_ms: 0,
            restart_budget: 3,
            restart_backoff_ms: 10,
            session_ttl_ms: 30_000,
            session_capacity: 64,
            max_connections: 1024,
            idle_timeout_ms: 30_000,
            quota_rps: 0,
            quota_burst: 0,
        }
    }
}

impl ServeConfig {
    /// The batch buckets engines precompile at startup (and the batcher
    /// pads collected batches up to): the configured list with entries
    /// above `max_batch` clamped *down* to it (a too-big bucket means
    /// "warm the largest batch available", not "warm nothing") and
    /// `max_batch` itself always appended — an explicit list must cover
    /// every batch the batcher can form, or padded serving would fall
    /// back to compile-on-request for the sizes above its largest
    /// bucket — sorted and deduplicated. When no list is configured, a
    /// power-of-two ladder `1, 2, 4, …` capped by (and including)
    /// `max_batch`.
    pub fn effective_buckets(&self) -> Vec<usize> {
        let cap = self.max_batch.max(1);
        let mut v: Vec<usize> = if self.batch_buckets.is_empty() {
            let mut ladder = Vec::new();
            let mut b = 1usize;
            while b < cap {
                ladder.push(b);
                b *= 2;
            }
            ladder
        } else {
            self.batch_buckets.iter().map(|&b| b.min(cap)).collect()
        };
        v.push(cap);
        v.retain(|&b| b >= 1);
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Whether the deployment opted into bucketed execution: an explicit
    /// bucket list, or autotune under the `auto` backend (probe latency
    /// must never reach the request path; with a fixed backend nothing
    /// ever probes, so autotune alone changes nothing). Gates both the
    /// batcher's pad-to-bucket behavior and the full-ladder warm-up.
    pub fn bucketed_execution(&self) -> bool {
        (self.autotune && self.backend == BackendChoice::Auto) || !self.batch_buckets.is_empty()
    }

    /// The batch sizes engines warm at startup: every effective bucket
    /// under bucketed execution; otherwise just the endpoints
    /// `{1, max_batch}` (singletons and full batches dominate unbucketed
    /// traffic — intermediate sizes compile lazily either way).
    pub fn warmup_buckets(&self) -> Vec<usize> {
        if self.bucketed_execution() {
            self.effective_buckets()
        } else {
            let mut v = vec![1, self.max_batch.max(1)];
            v.dedup();
            v
        }
    }
}

/// Parse a full framework config (model + serve sections) from TOML text.
pub fn load_config(text: &str) -> Result<(ModelConfig, ServeConfig), String> {
    let doc = parse(text).map_err(|e| e.to_string())?;
    let model = model_from_doc(&doc)?;
    let serve = serve_from_doc(&doc)?;
    Ok((model, serve))
}

fn model_from_doc(doc: &ConfigDoc) -> Result<ModelConfig, String> {
    let name = doc.get_str("model.name").unwrap_or("model").to_string();
    let c_in = doc.get_int("model.c_in").unwrap_or(1) as usize;
    let seq_len = doc
        .get_int("model.seq_len")
        .ok_or("model.seq_len is required")? as usize;
    let mut layers = Vec::new();
    // Layers are numbered sections: [layer.0], [layer.1], …
    for idx in 0.. {
        let prefix = format!("layer.{idx}");
        let Some(ty) = doc.get_str(&format!("{prefix}.type")) else {
            break;
        };
        // Per-layer planner override: absent or "auto" → cost model.
        let layer_backend = || -> Result<Option<ConvBackend>, String> {
            match doc.get_str(&format!("{prefix}.backend")) {
                None | Some("auto") => Ok(None),
                Some(s) => ConvBackend::parse(s)
                    .map(Some)
                    .ok_or_else(|| format!("{prefix}.backend: unknown backend {s:?}")),
            }
        };
        let layer = match ty {
            "conv" => LayerConfig::Conv {
                c_out: doc
                    .get_int(&format!("{prefix}.c_out"))
                    .ok_or_else(|| format!("{prefix}.c_out required"))? as usize,
                k: doc
                    .get_int(&format!("{prefix}.k"))
                    .ok_or_else(|| format!("{prefix}.k required"))? as usize,
                stride: doc.get_int(&format!("{prefix}.stride")).unwrap_or(1) as usize,
                dilation: doc.get_int(&format!("{prefix}.dilation")).unwrap_or(1) as usize,
                same_pad: doc.get_bool(&format!("{prefix}.same_pad")).unwrap_or(true),
                relu: doc.get_bool(&format!("{prefix}.relu")).unwrap_or(true),
                backend: layer_backend()?,
                quantize: match doc.get_str(&format!("{prefix}.quantize")) {
                    // A mistyped scheme must fail loudly, mirroring
                    // serve.autotune: the operator believes int8 is on.
                    None | Some("none") => false,
                    Some("int8") => true,
                    Some(s) => {
                        return Err(format!("{prefix}.quantize: unknown scheme {s:?} (want \"int8\")"))
                    }
                },
            },
            "pool" => LayerConfig::Pool {
                kind: doc
                    .get_str(&format!("{prefix}.kind"))
                    .unwrap_or("max")
                    .to_string(),
                w: doc.get_int(&format!("{prefix}.w")).unwrap_or(2) as usize,
                stride: doc.get_int(&format!("{prefix}.stride")).unwrap_or(2) as usize,
            },
            "residual" => LayerConfig::Residual {
                k: doc.get_int(&format!("{prefix}.k")).unwrap_or(3) as usize,
                dilation: doc.get_int(&format!("{prefix}.dilation")).unwrap_or(1) as usize,
                backend: layer_backend()?,
            },
            "dense" => LayerConfig::Dense {
                out: doc
                    .get_int(&format!("{prefix}.out"))
                    .ok_or_else(|| format!("{prefix}.out required"))? as usize,
                relu: doc.get_bool(&format!("{prefix}.relu")).unwrap_or(false),
            },
            other => return Err(format!("unknown layer type {other:?}")),
        };
        layers.push(layer);
    }
    if layers.is_empty() {
        return Err("config defines no [layer.N] sections".into());
    }
    Ok(ModelConfig {
        name,
        c_in,
        seq_len,
        layers,
    })
}

fn serve_from_doc(doc: &ConfigDoc) -> Result<ServeConfig, String> {
    let d = ServeConfig::default();
    let backend = match doc.get_str("serve.backend") {
        None => d.backend,
        Some(s) => BackendChoice::parse(s).ok_or_else(|| format!("unknown backend {s:?}"))?,
    };
    // Counts must not wrap through `as usize` (a negative TOML value
    // would become ~2^64 and e.g. spawn threads until the process dies).
    let count = |key: &str| -> Result<Option<usize>, String> {
        match doc.get_int(key) {
            None => Ok(None),
            Some(v) if v < 0 => Err(format!("{key} must be >= 0, got {v}")),
            Some(v) => Ok(Some(v as usize)),
        }
    };
    // A mistyped value must fail loudly (like batch_buckets below) — an
    // operator who wrote `autotune = 1` believes probing is on; silently
    // falling back to the heuristic would hide that it is not.
    let autotune = match doc.get("serve.autotune") {
        None => d.autotune,
        Some(Value::Bool(b)) => *b,
        Some(other) => {
            return Err(format!(
                "serve.autotune must be a boolean, got {}",
                other.type_name()
            ))
        }
    };
    let batch_buckets = match doc.get("serve.batch_buckets") {
        None => d.batch_buckets.clone(),
        Some(Value::Array(items)) => {
            let mut v = Vec::with_capacity(items.len());
            for item in items {
                match item {
                    Value::Int(b) if *b >= 1 => v.push(*b as usize),
                    Value::Int(b) => {
                        return Err(format!("serve.batch_buckets entries must be >= 1, got {b}"))
                    }
                    other => {
                        return Err(format!(
                            "serve.batch_buckets must be an integer array, found a {} entry",
                            other.type_name()
                        ))
                    }
                }
            }
            v
        }
        Some(other) => {
            return Err(format!(
                "serve.batch_buckets must be an array, got {}",
                other.type_name()
            ))
        }
    };
    Ok(ServeConfig {
        max_batch: count("serve.max_batch")?.unwrap_or(d.max_batch),
        batch_deadline_us: count("serve.batch_deadline_us")?.unwrap_or(d.batch_deadline_us as usize)
            as u64,
        workers: count("serve.workers")?.unwrap_or(d.workers),
        threads: count("serve.threads")?.unwrap_or(d.threads),
        backend,
        autotune,
        batch_buckets,
        queue_capacity: count("serve.queue_capacity")?.unwrap_or(d.queue_capacity),
        request_ttl_ms: count("serve.request_ttl_ms")?.unwrap_or(d.request_ttl_ms as usize) as u64,
        restart_budget: count("serve.restart_budget")?.unwrap_or(d.restart_budget),
        restart_backoff_ms: count("serve.restart_backoff_ms")?
            .unwrap_or(d.restart_backoff_ms as usize) as u64,
        session_ttl_ms: count("serve.session_ttl_ms")?.unwrap_or(d.session_ttl_ms as usize) as u64,
        session_capacity: count("serve.session_capacity")?.unwrap_or(d.session_capacity),
        max_connections: count("serve.max_connections")?.unwrap_or(d.max_connections),
        idle_timeout_ms: count("serve.idle_timeout_ms")?.unwrap_or(d.idle_timeout_ms as usize)
            as u64,
        quota_rps: count("serve.quota_rps")?.unwrap_or(d.quota_rps as usize) as u64,
        quota_burst: count("serve.quota_burst")?.unwrap_or(d.quota_burst as usize) as u64,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const EXAMPLE: &str = r#"
# TCN for the serving demo
[model]
name = "tcn_demo"
c_in = 1
seq_len = 1024

[layer.0]
type = "conv"
c_out = 8
k = 7

[layer.1]
type = "residual"
k = 3
dilation = 2

[layer.2]
type = "pool"
kind = "max"
w = 2
stride = 2

[serve]
max_batch = 16
backend = "sliding"
"#;

    #[test]
    fn parses_model_and_serve() {
        let (m, s) = load_config(EXAMPLE).unwrap();
        assert_eq!(m.name, "tcn_demo");
        assert_eq!(m.seq_len, 1024);
        assert_eq!(m.layers.len(), 3);
        assert!(matches!(m.layers[0], LayerConfig::Conv { c_out: 8, k: 7, .. }));
        assert!(matches!(m.layers[1], LayerConfig::Residual { dilation: 2, .. }));
        assert_eq!(s.max_batch, 16);
        assert_eq!(s.backend, BackendChoice::Fixed(ConvBackend::Sliding));
        assert_eq!(s.workers, 1); // default
        assert_eq!(s.threads, 0); // default = auto
    }

    #[test]
    fn serve_backend_auto_and_default() {
        let auto = EXAMPLE.replace("\"sliding\"", "\"auto\"");
        let (_, s) = load_config(&auto).unwrap();
        assert_eq!(s.backend, BackendChoice::Auto);
        // Key absent → planner default.
        let absent = EXAMPLE.replace("backend = \"sliding\"", "");
        let (_, s) = load_config(&absent).unwrap();
        assert_eq!(s.backend, BackendChoice::Auto);
    }

    #[test]
    fn per_layer_backend_overrides() {
        let text = EXAMPLE.replace(
            "type = \"conv\"\nc_out = 8\nk = 7\n",
            "type = \"conv\"\nc_out = 8\nk = 7\nbackend = \"im2col_gemm\"\n",
        );
        let (m, _) = load_config(&text).unwrap();
        assert!(matches!(
            m.layers[0],
            LayerConfig::Conv { backend: Some(ConvBackend::Im2colGemm), .. }
        ));
        // Residual default: no override.
        assert!(matches!(m.layers[1], LayerConfig::Residual { backend: None, .. }));
        // Unknown per-layer backend is an error.
        let bad = text.replace("\"im2col_gemm\"", "\"magic\"");
        assert!(load_config(&bad).unwrap_err().contains("magic"));
    }

    #[test]
    fn per_layer_quantize_key() {
        // Absent → f32 only.
        let (m, _) = load_config(EXAMPLE).unwrap();
        assert!(matches!(m.layers[0], LayerConfig::Conv { quantize: false, .. }));
        let text = EXAMPLE.replace(
            "type = \"conv\"\nc_out = 8\nk = 7\n",
            "type = \"conv\"\nc_out = 8\nk = 7\nquantize = \"int8\"\n",
        );
        let (m, _) = load_config(&text).unwrap();
        assert!(matches!(m.layers[0], LayerConfig::Conv { quantize: true, .. }));
        // Explicit off and unknown scheme.
        let off = text.replace("\"int8\"", "\"none\"");
        let (m, _) = load_config(&off).unwrap();
        assert!(matches!(m.layers[0], LayerConfig::Conv { quantize: false, .. }));
        let bad = text.replace("\"int8\"", "\"int4\"");
        assert!(load_config(&bad).unwrap_err().contains("int4"));
    }

    #[test]
    fn parses_workers_and_threads() {
        let text = format!("{EXAMPLE}\nworkers = 4\nthreads = 8\n");
        let (_, s) = load_config(&text).unwrap();
        assert_eq!(s.workers, 4);
        assert_eq!(s.threads, 8);
    }

    #[test]
    fn negative_counts_rejected_not_wrapped() {
        let bad = format!("{EXAMPLE}\nthreads = -1\n");
        assert!(load_config(&bad).unwrap_err().contains("threads"));
        let bad = format!("{EXAMPLE}\nworkers = -4\n");
        assert!(load_config(&bad).unwrap_err().contains("workers"));
        let bad = format!("{EXAMPLE}\nrequest_ttl_ms = -5\n");
        assert!(load_config(&bad).unwrap_err().contains("request_ttl_ms"));
        let bad = format!("{EXAMPLE}\nrestart_budget = -1\n");
        assert!(load_config(&bad).unwrap_err().contains("restart_budget"));
    }

    #[test]
    fn robustness_fields_parse_with_defaults() {
        // Defaults: no TTL, 3 restarts, 10 ms base backoff.
        let (_, s) = load_config(EXAMPLE).unwrap();
        assert_eq!(s.request_ttl_ms, 0);
        assert_eq!(s.restart_budget, 3);
        assert_eq!(s.restart_backoff_ms, 10);
        let text =
            format!("{EXAMPLE}\nrequest_ttl_ms = 250\nrestart_budget = 5\nrestart_backoff_ms = 2\n");
        let (_, s) = load_config(&text).unwrap();
        assert_eq!(s.request_ttl_ms, 250);
        assert_eq!(s.restart_budget, 5);
        assert_eq!(s.restart_backoff_ms, 2);
    }

    #[test]
    fn session_fields_parse_with_defaults() {
        // Defaults: 30 s idle TTL, 64 sessions per worker.
        let (_, s) = load_config(EXAMPLE).unwrap();
        assert_eq!(s.session_ttl_ms, 30_000);
        assert_eq!(s.session_capacity, 64);
        let text = format!("{EXAMPLE}\nsession_ttl_ms = 1500\nsession_capacity = 4\n");
        let (_, s) = load_config(&text).unwrap();
        assert_eq!(s.session_ttl_ms, 1500);
        assert_eq!(s.session_capacity, 4);
        let bad = format!("{EXAMPLE}\nsession_ttl_ms = -1\n");
        assert!(load_config(&bad).unwrap_err().contains("session_ttl_ms"));
    }

    #[test]
    fn transport_fields_parse_with_defaults() {
        // Defaults: 1024 connections, 30 s idle timeout, quotas off.
        let (_, s) = load_config(EXAMPLE).unwrap();
        assert_eq!(s.max_connections, 1024);
        assert_eq!(s.idle_timeout_ms, 30_000);
        assert_eq!(s.quota_rps, 0);
        assert_eq!(s.quota_burst, 0);
        let text = format!(
            "{EXAMPLE}\nmax_connections = 16\nidle_timeout_ms = 500\nquota_rps = 100\nquota_burst = 8\n"
        );
        let (_, s) = load_config(&text).unwrap();
        assert_eq!(s.max_connections, 16);
        assert_eq!(s.idle_timeout_ms, 500);
        assert_eq!(s.quota_rps, 100);
        assert_eq!(s.quota_burst, 8);
        let bad = format!("{EXAMPLE}\nmax_connections = -1\n");
        assert!(load_config(&bad).unwrap_err().contains("max_connections"));
        let bad = format!("{EXAMPLE}\nquota_rps = -10\n");
        assert!(load_config(&bad).unwrap_err().contains("quota_rps"));
    }

    #[test]
    fn autotune_and_batch_buckets_parse() {
        let text = format!("{EXAMPLE}\nautotune = true\nbatch_buckets = [1, 4, 16]\n");
        let (_, s) = load_config(&text).unwrap();
        assert!(s.autotune);
        assert_eq!(s.batch_buckets, vec![1, 4, 16]);
        assert_eq!(s.effective_buckets(), vec![1, 4, 16]); // max_batch 16
        // Defaults: autotune off, bucket ladder derived from max_batch.
        let (_, s) = load_config(EXAMPLE).unwrap();
        assert!(!s.autotune);
        assert!(s.batch_buckets.is_empty());
        assert_eq!(s.effective_buckets(), vec![1, 2, 4, 8, 16]);
        // A mistyped autotune value errors instead of silently running
        // the heuristic.
        let bad = format!("{EXAMPLE}\nautotune = 1\n");
        assert!(load_config(&bad).unwrap_err().contains("autotune"));
        // Non-positive or non-integer entries are rejected.
        let bad = format!("{EXAMPLE}\nbatch_buckets = [4, -1]\n");
        assert!(load_config(&bad).unwrap_err().contains("batch_buckets"));
        let bad = format!("{EXAMPLE}\nbatch_buckets = [\"big\"]\n");
        assert!(load_config(&bad).unwrap_err().contains("batch_buckets"));
        let bad = format!("{EXAMPLE}\nbatch_buckets = 4\n");
        assert!(load_config(&bad).unwrap_err().contains("array"));
    }

    #[test]
    fn effective_buckets_clamped_sorted_deduped() {
        let d = ServeConfig::default(); // max_batch 8
        assert_eq!(d.effective_buckets(), vec![1, 2, 4, 8]);
        // Oversized entries clamp DOWN to max_batch (warm the largest
        // batch available) rather than silently disappearing.
        let s = ServeConfig {
            max_batch: 8,
            batch_buckets: vec![4, 4, 64, 2],
            ..Default::default()
        };
        assert_eq!(s.effective_buckets(), vec![2, 4, 8]);
        let oversized_only = ServeConfig {
            max_batch: 8,
            batch_buckets: vec![16, 32],
            ..Default::default()
        };
        assert_eq!(oversized_only.effective_buckets(), vec![8]);
        // An explicit list always covers max_batch, so padded serving
        // never falls back to compile-on-request above its top bucket.
        let uncovered = ServeConfig {
            max_batch: 32,
            batch_buckets: vec![1, 8],
            ..Default::default()
        };
        assert_eq!(uncovered.effective_buckets(), vec![1, 8, 32]);
        let one = ServeConfig {
            max_batch: 1,
            ..Default::default()
        };
        assert_eq!(one.effective_buckets(), vec![1]);
    }

    /// Bucketed execution (padding + full-ladder warm-up) is opt-in: an
    /// explicit bucket list, or autotune under the `auto` backend — a
    /// fixed backend never probes, so autotune alone must not enable
    /// per-batch padding.
    #[test]
    fn bucketed_execution_gate_and_warmup_buckets() {
        let d = ServeConfig::default();
        assert!(!d.bucketed_execution());
        assert_eq!(d.warmup_buckets(), vec![1, 8]); // endpoints only
        let tuned = ServeConfig {
            autotune: true,
            ..Default::default()
        };
        assert!(tuned.bucketed_execution());
        assert_eq!(tuned.warmup_buckets(), vec![1, 2, 4, 8]);
        let tuned_fixed = ServeConfig {
            autotune: true,
            backend: BackendChoice::Fixed(ConvBackend::Sliding),
            ..Default::default()
        };
        assert!(!tuned_fixed.bucketed_execution(), "fixed backend never probes");
        let explicit = ServeConfig {
            batch_buckets: vec![4],
            ..Default::default()
        };
        assert!(explicit.bucketed_execution());
        assert_eq!(explicit.warmup_buckets(), vec![4, 8]);
    }

    #[test]
    fn missing_seq_len_is_error() {
        let err = load_config("[model]\nname=\"x\"\n[layer.0]\ntype=\"dense\"\nout=4\n")
            .unwrap_err();
        assert!(err.contains("seq_len"));
    }

    #[test]
    fn unknown_backend_is_error() {
        let text = format!("{EXAMPLE}\n[serve2]\n");
        assert!(load_config(&text).is_ok());
        let bad = EXAMPLE.replace("\"sliding\"", "\"magic\"");
        assert!(load_config(&bad).unwrap_err().contains("magic"));
    }

    #[test]
    fn no_layers_is_error() {
        let err = load_config("[model]\nseq_len = 8\n").unwrap_err();
        assert!(err.contains("layer"));
    }
}
