//! Minimal TOML-subset configuration substrate (serde is unavailable
//! offline). Supports the subset the framework needs: `[section]` and
//! `[section.sub]` headers, `key = value` with string / integer / float /
//! boolean / homogeneous-array values, `#` comments. Typed accessors with
//! defaulting; unknown keys are preserved (forward compatibility) and
//! listable for lint warnings.

mod parse;
mod types;

pub use parse::{parse, ParseError};
pub use types::{ConfigDoc, Value};

use crate::conv::{BackendChoice, ConvBackend};

/// Model configuration — a sequential 1-D network definition.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    /// Input channels of the first layer.
    pub c_in: usize,
    /// Input sequence length the AOT artifacts are specialized to.
    pub seq_len: usize,
    pub layers: Vec<LayerConfig>,
}

/// One layer of the model.
#[derive(Clone, Debug, PartialEq)]
pub enum LayerConfig {
    Conv {
        c_out: usize,
        k: usize,
        stride: usize,
        dilation: usize,
        same_pad: bool,
        relu: bool,
        /// Per-layer kernel override for the execution planner
        /// (`backend = "sliding" | "im2col_gemm" | "direct" |
        /// "sliding_pair"`; omit or `"auto"` to let the cost model
        /// choose). Beats the deployment-level backend either way.
        backend: Option<ConvBackend>,
    },
    Pool {
        kind: String,
        w: usize,
        stride: usize,
    },
    Residual {
        /// Dilations of the two conv taps inside the TCN block.
        k: usize,
        dilation: usize,
        /// Per-layer kernel override for both convs of the block.
        backend: Option<ConvBackend>,
    },
    Dense {
        out: usize,
        relu: bool,
    },
}

/// Serving configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeConfig {
    pub max_batch: usize,
    pub batch_deadline_us: u64,
    /// Engine workers draining the request queue (each owns an engine).
    pub workers: usize,
    /// Kernel data-parallelism: worker-pool threads the conv/pool/
    /// sliding kernels fan out on. `0` = auto (all cores). Applied to
    /// the process-global [`crate::exec::Executor`] at serve startup.
    pub threads: usize,
    /// Backend selection for the native engine: `"auto"` (default) lets
    /// the execution planner pick a kernel per layer; a concrete
    /// backend name forces it on every layer without a per-layer
    /// `backend =` override.
    pub backend: BackendChoice,
    pub queue_capacity: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            max_batch: 8,
            batch_deadline_us: 500,
            workers: 1,
            threads: 0,
            backend: BackendChoice::Auto,
            queue_capacity: 1024,
        }
    }
}

/// Parse a full framework config (model + serve sections) from TOML text.
pub fn load_config(text: &str) -> Result<(ModelConfig, ServeConfig), String> {
    let doc = parse(text).map_err(|e| e.to_string())?;
    let model = model_from_doc(&doc)?;
    let serve = serve_from_doc(&doc)?;
    Ok((model, serve))
}

fn model_from_doc(doc: &ConfigDoc) -> Result<ModelConfig, String> {
    let name = doc.get_str("model.name").unwrap_or("model").to_string();
    let c_in = doc.get_int("model.c_in").unwrap_or(1) as usize;
    let seq_len = doc
        .get_int("model.seq_len")
        .ok_or("model.seq_len is required")? as usize;
    let mut layers = Vec::new();
    // Layers are numbered sections: [layer.0], [layer.1], …
    for idx in 0.. {
        let prefix = format!("layer.{idx}");
        let Some(ty) = doc.get_str(&format!("{prefix}.type")) else {
            break;
        };
        // Per-layer planner override: absent or "auto" → cost model.
        let layer_backend = || -> Result<Option<ConvBackend>, String> {
            match doc.get_str(&format!("{prefix}.backend")) {
                None | Some("auto") => Ok(None),
                Some(s) => ConvBackend::parse(s)
                    .map(Some)
                    .ok_or_else(|| format!("{prefix}.backend: unknown backend {s:?}")),
            }
        };
        let layer = match ty {
            "conv" => LayerConfig::Conv {
                c_out: doc
                    .get_int(&format!("{prefix}.c_out"))
                    .ok_or_else(|| format!("{prefix}.c_out required"))? as usize,
                k: doc
                    .get_int(&format!("{prefix}.k"))
                    .ok_or_else(|| format!("{prefix}.k required"))? as usize,
                stride: doc.get_int(&format!("{prefix}.stride")).unwrap_or(1) as usize,
                dilation: doc.get_int(&format!("{prefix}.dilation")).unwrap_or(1) as usize,
                same_pad: doc.get_bool(&format!("{prefix}.same_pad")).unwrap_or(true),
                relu: doc.get_bool(&format!("{prefix}.relu")).unwrap_or(true),
                backend: layer_backend()?,
            },
            "pool" => LayerConfig::Pool {
                kind: doc
                    .get_str(&format!("{prefix}.kind"))
                    .unwrap_or("max")
                    .to_string(),
                w: doc.get_int(&format!("{prefix}.w")).unwrap_or(2) as usize,
                stride: doc.get_int(&format!("{prefix}.stride")).unwrap_or(2) as usize,
            },
            "residual" => LayerConfig::Residual {
                k: doc.get_int(&format!("{prefix}.k")).unwrap_or(3) as usize,
                dilation: doc.get_int(&format!("{prefix}.dilation")).unwrap_or(1) as usize,
                backend: layer_backend()?,
            },
            "dense" => LayerConfig::Dense {
                out: doc
                    .get_int(&format!("{prefix}.out"))
                    .ok_or_else(|| format!("{prefix}.out required"))? as usize,
                relu: doc.get_bool(&format!("{prefix}.relu")).unwrap_or(false),
            },
            other => return Err(format!("unknown layer type {other:?}")),
        };
        layers.push(layer);
    }
    if layers.is_empty() {
        return Err("config defines no [layer.N] sections".into());
    }
    Ok(ModelConfig {
        name,
        c_in,
        seq_len,
        layers,
    })
}

fn serve_from_doc(doc: &ConfigDoc) -> Result<ServeConfig, String> {
    let d = ServeConfig::default();
    let backend = match doc.get_str("serve.backend") {
        None => d.backend,
        Some(s) => BackendChoice::parse(s).ok_or_else(|| format!("unknown backend {s:?}"))?,
    };
    // Counts must not wrap through `as usize` (a negative TOML value
    // would become ~2^64 and e.g. spawn threads until the process dies).
    let count = |key: &str| -> Result<Option<usize>, String> {
        match doc.get_int(key) {
            None => Ok(None),
            Some(v) if v < 0 => Err(format!("{key} must be >= 0, got {v}")),
            Some(v) => Ok(Some(v as usize)),
        }
    };
    Ok(ServeConfig {
        max_batch: count("serve.max_batch")?.unwrap_or(d.max_batch),
        batch_deadline_us: count("serve.batch_deadline_us")?.unwrap_or(d.batch_deadline_us as usize)
            as u64,
        workers: count("serve.workers")?.unwrap_or(d.workers),
        threads: count("serve.threads")?.unwrap_or(d.threads),
        backend,
        queue_capacity: count("serve.queue_capacity")?.unwrap_or(d.queue_capacity),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const EXAMPLE: &str = r#"
# TCN for the serving demo
[model]
name = "tcn_demo"
c_in = 1
seq_len = 1024

[layer.0]
type = "conv"
c_out = 8
k = 7

[layer.1]
type = "residual"
k = 3
dilation = 2

[layer.2]
type = "pool"
kind = "max"
w = 2
stride = 2

[serve]
max_batch = 16
backend = "sliding"
"#;

    #[test]
    fn parses_model_and_serve() {
        let (m, s) = load_config(EXAMPLE).unwrap();
        assert_eq!(m.name, "tcn_demo");
        assert_eq!(m.seq_len, 1024);
        assert_eq!(m.layers.len(), 3);
        assert!(matches!(m.layers[0], LayerConfig::Conv { c_out: 8, k: 7, .. }));
        assert!(matches!(m.layers[1], LayerConfig::Residual { dilation: 2, .. }));
        assert_eq!(s.max_batch, 16);
        assert_eq!(s.backend, BackendChoice::Fixed(ConvBackend::Sliding));
        assert_eq!(s.workers, 1); // default
        assert_eq!(s.threads, 0); // default = auto
    }

    #[test]
    fn serve_backend_auto_and_default() {
        let auto = EXAMPLE.replace("\"sliding\"", "\"auto\"");
        let (_, s) = load_config(&auto).unwrap();
        assert_eq!(s.backend, BackendChoice::Auto);
        // Key absent → planner default.
        let absent = EXAMPLE.replace("backend = \"sliding\"", "");
        let (_, s) = load_config(&absent).unwrap();
        assert_eq!(s.backend, BackendChoice::Auto);
    }

    #[test]
    fn per_layer_backend_overrides() {
        let text = EXAMPLE.replace(
            "type = \"conv\"\nc_out = 8\nk = 7\n",
            "type = \"conv\"\nc_out = 8\nk = 7\nbackend = \"im2col_gemm\"\n",
        );
        let (m, _) = load_config(&text).unwrap();
        assert!(matches!(
            m.layers[0],
            LayerConfig::Conv { backend: Some(ConvBackend::Im2colGemm), .. }
        ));
        // Residual default: no override.
        assert!(matches!(m.layers[1], LayerConfig::Residual { backend: None, .. }));
        // Unknown per-layer backend is an error.
        let bad = text.replace("\"im2col_gemm\"", "\"magic\"");
        assert!(load_config(&bad).unwrap_err().contains("magic"));
    }

    #[test]
    fn parses_workers_and_threads() {
        let text = format!("{EXAMPLE}\nworkers = 4\nthreads = 8\n");
        let (_, s) = load_config(&text).unwrap();
        assert_eq!(s.workers, 4);
        assert_eq!(s.threads, 8);
    }

    #[test]
    fn negative_counts_rejected_not_wrapped() {
        let bad = format!("{EXAMPLE}\nthreads = -1\n");
        assert!(load_config(&bad).unwrap_err().contains("threads"));
        let bad = format!("{EXAMPLE}\nworkers = -4\n");
        assert!(load_config(&bad).unwrap_err().contains("workers"));
    }

    #[test]
    fn missing_seq_len_is_error() {
        let err = load_config("[model]\nname=\"x\"\n[layer.0]\ntype=\"dense\"\nout=4\n")
            .unwrap_err();
        assert!(err.contains("seq_len"));
    }

    #[test]
    fn unknown_backend_is_error() {
        let text = format!("{EXAMPLE}\n[serve2]\n");
        assert!(load_config(&text).is_ok());
        let bad = EXAMPLE.replace("\"sliding\"", "\"magic\"");
        assert!(load_config(&bad).unwrap_err().contains("magic"));
    }

    #[test]
    fn no_layers_is_error() {
        let err = load_config("[model]\nseq_len = 8\n").unwrap_err();
        assert!(err.contains("layer"));
    }
}
