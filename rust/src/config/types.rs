//! Config value model: a flat map of dotted keys to typed values.

use std::collections::BTreeMap;

/// A parsed configuration value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn type_name(&self) -> &'static str {
        match self {
            Value::Str(_) => "string",
            Value::Int(_) => "integer",
            Value::Float(_) => "float",
            Value::Bool(_) => "boolean",
            Value::Array(_) => "array",
        }
    }
}

/// A parsed document: dotted-path → value (e.g. `serve.max_batch`).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ConfigDoc {
    pub entries: BTreeMap<String, Value>,
}

impl ConfigDoc {
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    pub fn get_str(&self, key: &str) -> Option<&str> {
        match self.get(key) {
            Some(Value::Str(s)) => Some(s),
            _ => None,
        }
    }

    pub fn get_int(&self, key: &str) -> Option<i64> {
        match self.get(key) {
            Some(Value::Int(v)) => Some(*v),
            _ => None,
        }
    }

    pub fn get_float(&self, key: &str) -> Option<f64> {
        match self.get(key) {
            Some(Value::Float(v)) => Some(*v),
            Some(Value::Int(v)) => Some(*v as f64),
            _ => None,
        }
    }

    pub fn get_bool(&self, key: &str) -> Option<bool> {
        match self.get(key) {
            Some(Value::Bool(v)) => Some(*v),
            _ => None,
        }
    }

    /// Keys under a dotted prefix (for unknown-key linting).
    pub fn keys_under<'a>(&'a self, prefix: &'a str) -> impl Iterator<Item = &'a str> + 'a {
        self.entries
            .keys()
            .filter(move |k| k.starts_with(prefix) && k[prefix.len()..].starts_with('.'))
            .map(|k| k.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn typed_accessors() {
        let mut doc = ConfigDoc::default();
        doc.entries.insert("a.b".into(), Value::Int(3));
        doc.entries.insert("a.c".into(), Value::Str("x".into()));
        doc.entries.insert("a.d".into(), Value::Bool(true));
        doc.entries.insert("a.e".into(), Value::Float(1.5));
        assert_eq!(doc.get_int("a.b"), Some(3));
        assert_eq!(doc.get_str("a.c"), Some("x"));
        assert_eq!(doc.get_bool("a.d"), Some(true));
        assert_eq!(doc.get_float("a.e"), Some(1.5));
        assert_eq!(doc.get_float("a.b"), Some(3.0)); // int coerces to float
        assert_eq!(doc.get_int("a.c"), None); // wrong type → None
        assert_eq!(doc.get_int("missing"), None);
    }

    #[test]
    fn keys_under_prefix() {
        let mut doc = ConfigDoc::default();
        doc.entries.insert("layer.0.type".into(), Value::Str("conv".into()));
        doc.entries.insert("layer.1.type".into(), Value::Str("pool".into()));
        doc.entries.insert("model.name".into(), Value::Str("m".into()));
        let keys: Vec<&str> = doc.keys_under("layer").collect();
        assert_eq!(keys.len(), 2);
    }

    #[test]
    fn value_type_names() {
        assert_eq!(Value::Int(1).type_name(), "integer");
        assert_eq!(Value::Array(vec![]).type_name(), "array");
    }
}
