//! Hand-rolled TOML-subset parser. Line-oriented: section headers,
//! `key = value` pairs, comments. Values: quoted strings, booleans,
//! integers (decimal, `_` separators), floats, flat arrays.

use super::types::{ConfigDoc, Value};

/// Parse failure with line context.
#[derive(Clone, Debug, PartialEq)]
pub struct ParseError {
    pub line: usize,
    pub message: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "config parse error on line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for ParseError {}

/// Parse TOML-subset text into a flat dotted-key document.
pub fn parse(text: &str) -> Result<ConfigDoc, ParseError> {
    let mut doc = ConfigDoc::default();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        let err = |m: String| ParseError {
            line: lineno + 1,
            message: m,
        };
        if let Some(rest) = line.strip_prefix('[') {
            let Some(name) = rest.strip_suffix(']') else {
                return Err(err(format!("unterminated section header {line:?}")));
            };
            let name = name.trim();
            if name.is_empty() {
                return Err(err("empty section name".into()));
            }
            validate_key(name).map_err(|m| err(m))?;
            section = name.to_string();
            continue;
        }
        let Some(eq) = line.find('=') else {
            return Err(err(format!("expected `key = value`, got {line:?}")));
        };
        let key = line[..eq].trim();
        validate_key(key).map_err(|m| err(m))?;
        let value = parse_value(line[eq + 1..].trim()).map_err(|m| err(m))?;
        let full = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        if doc.entries.insert(full.clone(), value).is_some() {
            return Err(err(format!("duplicate key {full:?}")));
        }
    }
    Ok(doc)
}

/// Remove a `#` comment, respecting quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn validate_key(key: &str) -> Result<(), String> {
    if key.is_empty() {
        return Err("empty key".into());
    }
    for part in key.split('.') {
        if part.is_empty()
            || !part
                .chars()
                .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-')
        {
            return Err(format!("invalid key {key:?}"));
        }
    }
    Ok(())
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("missing value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let Some(inner) = rest.strip_suffix('"') else {
            return Err(format!("unterminated string {s:?}"));
        };
        if inner.contains('"') {
            return Err(format!("stray quote inside string {s:?}"));
        }
        return Ok(Value::Str(unescape(inner)));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let Some(inner) = rest.strip_suffix(']') else {
            return Err(format!("unterminated array {s:?}"));
        };
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(Value::Array(Vec::new()));
        }
        let mut items = Vec::new();
        for part in inner.split(',') {
            items.push(parse_value(part.trim())?);
        }
        return Ok(Value::Array(items));
    }
    let cleaned = s.replace('_', "");
    if cleaned.contains('.') || cleaned.contains('e') || cleaned.contains('E') {
        if let Ok(f) = cleaned.parse::<f64>() {
            return Ok(Value::Float(f));
        }
    }
    if let Ok(i) = cleaned.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = cleaned.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value {s:?}"))
}

fn unescape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c == '\\' {
            match chars.next() {
                Some('n') => out.push('\n'),
                Some('t') => out.push('\t'),
                Some('\\') => out.push('\\'),
                Some(other) => {
                    out.push('\\');
                    out.push(other);
                }
                None => out.push('\\'),
            }
        } else {
            out.push(c);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sections_and_scalars() {
        let doc = parse(
            "top = 1\n[a]\nx = \"hi\"\ny = 2.5\nz = true\n[a.b]\nn = 1_000\n",
        )
        .unwrap();
        assert_eq!(doc.get_int("top"), Some(1));
        assert_eq!(doc.get_str("a.x"), Some("hi"));
        assert_eq!(doc.get_float("a.y"), Some(2.5));
        assert_eq!(doc.get_bool("a.z"), Some(true));
        assert_eq!(doc.get_int("a.b.n"), Some(1000));
    }

    #[test]
    fn comments_stripped_respecting_strings() {
        let doc = parse("x = \"a # b\" # trailing\ny = 3 # c\n").unwrap();
        assert_eq!(doc.get_str("x"), Some("a # b"));
        assert_eq!(doc.get_int("y"), Some(3));
    }

    #[test]
    fn arrays() {
        let doc = parse("ks = [3, 5, 7]\nnames = [\"a\", \"b\"]\nempty = []\n").unwrap();
        match doc.get("ks") {
            Some(Value::Array(v)) => assert_eq!(v.len(), 3),
            other => panic!("{other:?}"),
        }
        match doc.get("empty") {
            Some(Value::Array(v)) => assert!(v.is_empty()),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn negative_and_float_forms() {
        let doc = parse("a = -4\nb = -0.5\nc = 1e3\n").unwrap();
        assert_eq!(doc.get_int("a"), Some(-4));
        assert_eq!(doc.get_float("b"), Some(-0.5));
        assert_eq!(doc.get_float("c"), Some(1000.0));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = parse("ok = 1\nbroken\n").unwrap_err();
        assert_eq!(e.line, 2);
        let e = parse("[unterminated\n").unwrap_err();
        assert_eq!(e.line, 1);
        let e = parse("x = \"open\n").unwrap_err();
        assert_eq!(e.line, 1);
    }

    #[test]
    fn duplicate_keys_rejected() {
        let e = parse("[s]\na = 1\na = 2\n").unwrap_err();
        assert!(e.message.contains("duplicate"));
    }

    #[test]
    fn invalid_keys_rejected() {
        assert!(parse("bad key = 1\n").is_err());
        assert!(parse("[bad section]\n").is_err());
    }

    #[test]
    fn escapes_in_strings() {
        let doc = parse("x = \"a\\nb\"\n").unwrap();
        assert_eq!(doc.get_str("x"), Some("a\nb"));
    }
}
