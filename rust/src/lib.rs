//! # swsnn — Sliding Window Sum Algorithms for Deep Neural Networks
//!
//! A rust + JAX + Pallas reproduction of Snytsar 2023. The library
//! re-expresses DNN pooling and convolution as *sliding window sums*
//! (paper Eq. 3) and evaluates them with the vectorized algorithm family
//! of §3, displacing the im2col + GEMM path.
//!
//! Layer map (see DESIGN.md):
//! * L3 (this crate): algorithm family, conv/pool operators, NN stack,
//!   serving coordinator, benchmark harness.
//! * L2/L1 (build-time python): JAX model + Pallas kernels, AOT-lowered
//!   to `artifacts/*.hlo.txt`, executed by [`runtime`] via PJRT.
//!
//! Unsafe code is confined to [`exec`] (the scoped-lifetime job
//! transmute) and [`simd`] (the `std::arch` kernels + the f32 element
//! downcast); everything else is `#![deny(unsafe_code)]`, and
//! `cargo xtask check` statically enforces the kernel-core contracts
//! (see docs/invariants.md).
#![deny(unsafe_code)]

pub mod bench;
pub mod check;
pub mod cli;
pub mod config;
pub mod coordinator;
#[allow(unsafe_code)]
pub mod exec;
pub mod nn;
pub mod ops;
pub mod prop;
pub mod scan;
#[allow(unsafe_code)]
pub mod simd;
pub mod sliding;
pub mod conv;
pub mod pool;
pub mod gemm;
pub mod runtime;
pub mod telemetry;
pub mod workload;
