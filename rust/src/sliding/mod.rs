//! The paper's contribution: the sliding-window-sum algorithm family (§3).
//!
//! Given operator `⊕`, window `w`, and input `x₀…x_{N-1}`, compute
//! `yᵢ = xᵢ ⊕ xᵢ₊₁ ⊕ … ⊕ xᵢ₊w₋₁` for all `N − w + 1` valid positions
//! (Eq. 3). Implementations:
//!
//! | fn | paper | complexity | requires |
//! |----|-------|-----------|----------|
//! | [`naive::sliding_naive`] | baseline | `O(wN)` | monoid |
//! | [`scalar_input::sliding_scalar_input`] | Alg 1 | `O(N)` vector steps | monoid |
//! | [`vector_input::sliding_vector_input`] | Alg 2 | `O(N·w/P)` | monoid |
//! | [`vector_input::sliding_vector_input_log`] | Alg 2 + [3] | `O(N·log w/P)` | associative |
//! | [`ping_pong::sliding_ping_pong`] | Alg 3 | `O(N·w/P)`, ~30–50 % faster | monoid |
//! | [`vector_slide::sliding_vector_slide`] | Alg 4 | `O(N·w/P)` | monoid |
//! | [`vector_slide::sliding_vector_slide_tree`] | Alg 4 + reduction | `O(N·log w/P)` | associative |
//! | [`auto`] | dispatcher | best available | — |
//!
//! All functions compute *valid-mode* windows; [`boundary`] wraps them
//! with the padding/mirroring/periodic extensions DNN layers need.

pub mod boundary;
pub mod flat_tree;
pub mod naive;
pub mod ping_pong;
pub mod scalar_input;
pub mod streaming;
pub mod vector_input;
pub mod vector_slide;

pub use boundary::{extend, Boundary};
pub use flat_tree::{sliding_flat_tree, sliding_w2};
pub use naive::sliding_naive;
pub use ping_pong::sliding_ping_pong;
pub use scalar_input::sliding_scalar_input;
pub use streaming::StreamingSlidingSum;
pub use vector_input::{sliding_vector_input, sliding_vector_input_log};
pub use vector_slide::{sliding_vector_slide, sliding_vector_slide_tree};

use crate::ops::AssocOp;

/// Number of valid output windows, or 0 if the input is shorter than `w`.
#[inline]
pub fn out_len(n: usize, w: usize) -> usize {
    if w == 0 || n < w {
        0
    } else {
        n - w + 1
    }
}

/// Algorithm selector for [`auto`] and the bench harness.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Algo {
    Naive,
    ScalarInput,
    VectorInput,
    VectorInputLog,
    PingPong,
    VectorSlide,
    VectorSlideTree,
    /// Memory-resident doubling ladder (production dispatcher path).
    FlatTree,
}

impl Algo {
    pub const ALL: [Algo; 8] = [
        Algo::Naive,
        Algo::ScalarInput,
        Algo::VectorInput,
        Algo::VectorInputLog,
        Algo::PingPong,
        Algo::VectorSlide,
        Algo::VectorSlideTree,
        Algo::FlatTree,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Algo::Naive => "naive",
            Algo::ScalarInput => "scalar_input",
            Algo::VectorInput => "vector_input",
            Algo::VectorInputLog => "vector_input_log",
            Algo::PingPong => "ping_pong",
            Algo::VectorSlide => "vector_slide",
            Algo::VectorSlideTree => "vector_slide_tree",
            Algo::FlatTree => "flat_tree",
        }
    }

    pub fn parse(s: &str) -> Option<Algo> {
        Algo::ALL.iter().copied().find(|a| a.name() == s)
    }
}

/// Run a specific algorithm.
pub fn run<O: AssocOp>(algo: Algo, op: O, xs: &[O::Elem], w: usize, p: usize) -> Vec<O::Elem> {
    match algo {
        Algo::Naive => sliding_naive(op, xs, w),
        Algo::ScalarInput => sliding_scalar_input(op, xs, w, p),
        Algo::VectorInput => sliding_vector_input(op, xs, w, p),
        Algo::VectorInputLog => sliding_vector_input_log(op, xs, w, p),
        Algo::PingPong => sliding_ping_pong(op, xs, w, p),
        Algo::VectorSlide => sliding_vector_slide(op, xs, w, p),
        Algo::VectorSlideTree => sliding_vector_slide_tree(op, xs, w, p),
        Algo::FlatTree => sliding_flat_tree(op, xs, w),
    }
}

/// Dispatcher: pick the best implementation for `(w, P)` on a
/// memory-resident input.
///
/// Heuristics measured by `tbl_algorithms` (EXPERIMENTS.md TBL-A/§Perf):
/// * degenerate `w == 1` → copy; `w == 2` → one combine pass;
/// * otherwise the flat-buffer doubling ladder
///   ([`sliding_flat_tree`]) — the memory-resident realization of the
///   paper's log-depth algorithm; it beat every register-streaming
///   variant at all window sizes in the §Perf pass (the `Slide` becomes
///   an address offset). The register algorithms remain available via
///   [`run`] for streaming inputs and for the TBL-A reproduction.
pub fn auto<O: AssocOp>(op: O, xs: &[O::Elem], w: usize, _p: usize) -> Vec<O::Elem> {
    match w {
        1 => xs.to_vec(),
        2 => sliding_w2(op, xs),
        _ => sliding_flat_tree(op, xs, w),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::AddOp;

    #[test]
    fn out_len_edges() {
        assert_eq!(out_len(10, 3), 8);
        assert_eq!(out_len(3, 3), 1);
        assert_eq!(out_len(2, 3), 0);
        assert_eq!(out_len(0, 1), 0);
        assert_eq!(out_len(5, 0), 0);
    }

    #[test]
    fn algo_name_roundtrip() {
        for a in Algo::ALL {
            assert_eq!(Algo::parse(a.name()), Some(a));
        }
        assert_eq!(Algo::parse("bogus"), None);
    }

    #[test]
    fn auto_w1_is_copy() {
        let xs = [5f32, 6.0, 7.0];
        assert_eq!(auto(AddOp::<f32>::new(), &xs, 1, 8), xs.to_vec());
    }
}
