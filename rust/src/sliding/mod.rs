//! The paper's contribution: the sliding-window-sum algorithm family (§3).
//!
//! Given operator `⊕`, window `w`, and input `x₀…x_{N-1}`, compute
//! `yᵢ = xᵢ ⊕ xᵢ₊₁ ⊕ … ⊕ xᵢ₊w₋₁` for all `N − w + 1` valid positions
//! (Eq. 3). Implementations:
//!
//! | fn | paper | complexity | requires |
//! |----|-------|-----------|----------|
//! | [`naive::sliding_naive`] | baseline | `O(wN)` | monoid |
//! | [`scalar_input::sliding_scalar_input`] | Alg 1 | `O(N)` vector steps | monoid |
//! | [`vector_input::sliding_vector_input`] | Alg 2 | `O(N·w/P)` | monoid |
//! | [`vector_input::sliding_vector_input_log`] | Alg 2 + [3] | `O(N·log w/P)` | associative |
//! | [`ping_pong::sliding_ping_pong`] | Alg 3 | `O(N·w/P)`, ~30–50 % faster | monoid |
//! | [`vector_slide::sliding_vector_slide`] | Alg 4 | `O(N·w/P)` | monoid |
//! | [`vector_slide::sliding_vector_slide_tree`] | Alg 4 + reduction | `O(N·log w/P)` | associative |
//! | [`auto`] | dispatcher | best available, chunk+halo parallel | — |
//!
//! All functions compute *valid-mode* windows; [`boundary`] wraps them
//! with the padding/mirroring/periodic extensions DNN layers need.
//!
//! **Write-into-destination convention:** every kernel has an `_into`
//! variant (`sliding_flat_tree_into`, [`run_into`], [`auto_into`], …)
//! that writes a caller-provided `&mut [Elem]` of exactly
//! [`out_len`]`(n, w)` elements, overwriting every element — buffers may
//! be recycled dirty across calls. The `Vec`-returning entry points are
//! thin allocate-then-`_into` wrappers.
//!
//! **Parallel dispatch:** [`run`] and [`auto`] partition large inputs
//! into output chunks with `w − 1` input elements of halo overlap and
//! evaluate the chunks concurrently on the shared worker pool
//! ([`crate::exec::Executor`]) — the paper's multi-processor `P` on top
//! of the per-core vector `P`. Only algorithms whose per-window combine
//! tree is independent of absolute position are chunked (see
//! [`Algo::chunk_parallel_safe`]); those stay **bit-identical** to the
//! serial sweep. The rest ([`Algo::VectorInput`], [`Algo::VectorInputLog`],
//! [`Algo::PingPong`]) build their first-iteration carry differently from
//! steady state, so chunking would perturb f32 rounding — they always run
//! serially.

pub mod boundary;
pub mod flat_tree;
pub mod naive;
pub mod ping_pong;
pub mod scalar_input;
pub mod streaming;
pub mod vector_input;
pub mod vector_slide;

pub use boundary::{extend, Boundary};
pub use flat_tree::{sliding_flat_tree, sliding_flat_tree_into, sliding_w2, sliding_w2_into};
pub use naive::{sliding_naive, sliding_naive_into};
pub use ping_pong::sliding_ping_pong;
pub use scalar_input::{sliding_scalar_input, sliding_scalar_input_into};
pub use streaming::StreamingSlidingSum;
pub use vector_input::{sliding_vector_input, sliding_vector_input_log};
pub use vector_slide::{
    sliding_vector_slide, sliding_vector_slide_into, sliding_vector_slide_tree,
    sliding_vector_slide_tree_into,
};

use crate::exec::Executor;
use crate::ops::AssocOp;

/// Number of valid output windows, or 0 if the input is shorter than `w`.
#[inline]
pub fn out_len(n: usize, w: usize) -> usize {
    if w == 0 || n < w {
        0
    } else {
        n - w + 1
    }
}

/// Algorithm selector for [`auto`] and the bench harness.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Algo {
    Naive,
    ScalarInput,
    VectorInput,
    VectorInputLog,
    PingPong,
    VectorSlide,
    VectorSlideTree,
    /// Memory-resident doubling ladder (production dispatcher path).
    FlatTree,
}

impl Algo {
    pub const ALL: [Algo; 8] = [
        Algo::Naive,
        Algo::ScalarInput,
        Algo::VectorInput,
        Algo::VectorInputLog,
        Algo::PingPong,
        Algo::VectorSlide,
        Algo::VectorSlideTree,
        Algo::FlatTree,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Algo::Naive => "naive",
            Algo::ScalarInput => "scalar_input",
            Algo::VectorInput => "vector_input",
            Algo::VectorInputLog => "vector_input_log",
            Algo::PingPong => "ping_pong",
            Algo::VectorSlide => "vector_slide",
            Algo::VectorSlideTree => "vector_slide_tree",
            Algo::FlatTree => "flat_tree",
        }
    }

    pub fn parse(s: &str) -> Option<Algo> {
        Algo::ALL.iter().copied().find(|a| a.name() == s)
    }

    /// Whether chunk+halo data-parallel evaluation reproduces this
    /// algorithm's serial output bit-for-bit.
    ///
    /// True when every window is combined with a tree whose shape depends
    /// only on `w` (strict left folds, or the fixed doubling ladder) —
    /// then a chunk starting anywhere evaluates each window identically.
    /// The vector-input family and ping-pong build their first-iteration
    /// carry with a different association than steady state, making the
    /// combine tree a function of absolute position; chunking them would
    /// change f32 rounding, so they are excluded from parallel dispatch.
    pub fn chunk_parallel_safe(&self) -> bool {
        matches!(
            self,
            Algo::Naive
                | Algo::ScalarInput
                | Algo::VectorSlide
                | Algo::VectorSlideTree
                | Algo::FlatTree
        )
    }
}

/// Run a specific algorithm serially (no worker-pool dispatch) — the
/// reference the parallel path is tested bit-identical against.
pub fn run_serial<O: AssocOp>(
    algo: Algo,
    op: O,
    xs: &[O::Elem],
    w: usize,
    p: usize,
) -> Vec<O::Elem> {
    match algo {
        Algo::Naive => sliding_naive(op, xs, w),
        Algo::ScalarInput => sliding_scalar_input(op, xs, w, p),
        Algo::VectorInput => sliding_vector_input(op, xs, w, p),
        Algo::VectorInputLog => sliding_vector_input_log(op, xs, w, p),
        Algo::PingPong => sliding_ping_pong(op, xs, w, p),
        Algo::VectorSlide => sliding_vector_slide(op, xs, w, p),
        Algo::VectorSlideTree => sliding_vector_slide_tree(op, xs, w, p),
        Algo::FlatTree => sliding_flat_tree(op, xs, w),
    }
}

/// [`run_serial`] writing into a caller-provided buffer of length
/// [`out_len`]`(xs.len(), w)` — the per-chunk body of the parallel
/// dispatch. The chunk-parallel-safe algorithms write in place; the
/// register-carry family (vector-input, ping-pong) keeps its
/// `Vec`-returning form and is copied once (it is excluded from chunk
/// dispatch anyway).
pub fn run_serial_into<O: AssocOp>(
    algo: Algo,
    op: O,
    xs: &[O::Elem],
    w: usize,
    p: usize,
    out: &mut [O::Elem],
) {
    crate::check::poison(out);
    match algo {
        Algo::Naive => sliding_naive_into(op, xs, w, out),
        Algo::ScalarInput => sliding_scalar_input_into(op, xs, w, p, out),
        Algo::VectorInput => out.copy_from_slice(&sliding_vector_input(op, xs, w, p)),
        Algo::VectorInputLog => out.copy_from_slice(&sliding_vector_input_log(op, xs, w, p)),
        Algo::PingPong => out.copy_from_slice(&sliding_ping_pong(op, xs, w, p)),
        Algo::VectorSlide => sliding_vector_slide_into(op, xs, w, p, out),
        Algo::VectorSlideTree => sliding_vector_slide_tree_into(op, xs, w, p, out),
        Algo::FlatTree => sliding_flat_tree_into(op, xs, w, out),
    }
    crate::check::assert_no_poison(out, "run_serial_into");
}

/// Run a specific algorithm, fanning large inputs out over the shared
/// worker pool when the algorithm is chunk-parallel safe.
pub fn run<O: AssocOp>(algo: Algo, op: O, xs: &[O::Elem], w: usize, p: usize) -> Vec<O::Elem> {
    run_with(Executor::global(), algo, op, xs, w, p)
}

/// [`run`] on an explicit executor (scaling benches / parity tests).
pub fn run_with<O: AssocOp>(
    ex: &Executor,
    algo: Algo,
    op: O,
    xs: &[O::Elem],
    w: usize,
    p: usize,
) -> Vec<O::Elem> {
    // alloc-ok: Vec-returning wrapper; run_with_into is the hot path.
    let mut out = vec![op.identity(); out_len(xs.len(), w)];
    run_with_into(ex, algo, op, xs, w, p, &mut out);
    out
}

/// [`run`] writing into a caller-provided buffer (global pool).
pub fn run_into<O: AssocOp>(
    algo: Algo,
    op: O,
    xs: &[O::Elem],
    w: usize,
    p: usize,
    out: &mut [O::Elem],
) {
    run_with_into(Executor::global(), algo, op, xs, w, p, out)
}

/// The core dispatch: explicit executor and caller-provided destination.
/// Chunk-parallel-safe algorithms hand each worker a disjoint `&mut`
/// sub-slice of `out` to write directly (no intermediate buffers); the
/// rest run serially in place.
pub fn run_with_into<O: AssocOp>(
    ex: &Executor,
    algo: Algo,
    op: O,
    xs: &[O::Elem],
    w: usize,
    p: usize,
    out: &mut [O::Elem],
) {
    assert_eq!(out.len(), out_len(xs.len(), w), "dst length");
    crate::check::poison(out);
    if algo.chunk_parallel_safe() {
        chunked_halo_into(ex, xs, w, out, move |sub, dst| {
            run_serial_into(algo, op, sub, w, p, dst)
        });
    } else {
        run_serial_into(algo, op, xs, w, p, out);
    }
    crate::check::assert_no_poison(out, "run_with_into");
}

/// Dispatcher: pick the best implementation for `(w, P)` on a
/// memory-resident input, serial sweep.
///
/// Heuristics measured by `tbl_algorithms` (EXPERIMENTS.md TBL-A/§Perf):
/// * degenerate `w == 1` → copy; `w == 2` → one combine pass;
/// * otherwise the flat-buffer doubling ladder
///   ([`sliding_flat_tree`]) — the memory-resident realization of the
///   paper's log-depth algorithm; it beat every register-streaming
///   variant at all window sizes in the §Perf pass (the `Slide` becomes
///   an address offset). The register algorithms remain available via
///   [`run`] for streaming inputs and for the TBL-A reproduction.
pub fn auto_serial<O: AssocOp>(op: O, xs: &[O::Elem], w: usize, _p: usize) -> Vec<O::Elem> {
    match w {
        // alloc-ok: Vec-returning wrapper; auto_serial_into is the hot path.
        1 => xs.to_vec(),
        2 => sliding_w2(op, xs),
        _ => sliding_flat_tree(op, xs, w),
    }
}

/// [`auto_serial`] writing into a caller-provided buffer.
pub fn auto_serial_into<O: AssocOp>(
    op: O,
    xs: &[O::Elem],
    w: usize,
    _p: usize,
    out: &mut [O::Elem],
) {
    crate::check::poison(out);
    match w {
        1 => out.copy_from_slice(&xs[..out.len()]),
        2 => sliding_w2_into(op, xs, out),
        _ => sliding_flat_tree_into(op, xs, w, out),
    }
    crate::check::assert_no_poison(out, "auto_serial_into");
}

/// [`auto_serial`] with chunk+halo dispatch over the shared worker pool
/// (all of its paths are chunk-parallel safe). Bit-identical to the
/// serial sweep for every thread count.
pub fn auto<O: AssocOp>(op: O, xs: &[O::Elem], w: usize, p: usize) -> Vec<O::Elem> {
    auto_with(Executor::global(), op, xs, w, p)
}

/// [`auto`] on an explicit executor.
pub fn auto_with<O: AssocOp>(
    ex: &Executor,
    op: O,
    xs: &[O::Elem],
    w: usize,
    p: usize,
) -> Vec<O::Elem> {
    // alloc-ok: Vec-returning wrapper; auto_with_into is the hot path.
    let mut out = vec![op.identity(); out_len(xs.len(), w)];
    auto_with_into(ex, op, xs, w, p, &mut out);
    out
}

/// [`auto`] writing into a caller-provided buffer (global pool).
pub fn auto_into<O: AssocOp>(op: O, xs: &[O::Elem], w: usize, p: usize, out: &mut [O::Elem]) {
    auto_with_into(Executor::global(), op, xs, w, p, out)
}

/// The zero-allocation dispatcher core: explicit executor and
/// caller-provided destination. Workers write disjoint `&mut` sub-slices
/// of `out` directly.
pub fn auto_with_into<O: AssocOp>(
    ex: &Executor,
    op: O,
    xs: &[O::Elem],
    w: usize,
    p: usize,
    out: &mut [O::Elem],
) {
    assert_eq!(out.len(), out_len(xs.len(), w), "dst length");
    crate::check::poison(out);
    chunked_halo_into(ex, xs, w, out, move |sub, dst| {
        auto_serial_into(op, sub, w, p, dst)
    });
    crate::check::assert_no_poison(out, "auto_with_into");
}

/// Minimum output elements per parallel chunk — below 2× this the
/// dispatch overhead beats the win and the sweep stays serial.
const PAR_MIN_CHUNK: usize = 32 * 1024;

/// Chunk+halo evaluation into a caller-provided destination: split the
/// output range into per-thread chunks; each chunk re-runs `serial_into`
/// on its input slice extended by `w − 1` halo elements, writing its
/// disjoint `&mut` sub-slice of `out` directly. Chunk `c`'s windows see
/// exactly the same elements as in the monolithic sweep, and — unlike
/// the old `Vec`-returning formulation — there is no identity-fill pass
/// and no per-chunk `Vec` → dst copy.
fn chunked_halo_into<E, F>(ex: &Executor, xs: &[E], w: usize, out: &mut [E], serial_into: F)
where
    E: Send,
    F: Fn(&[E], &mut [E]) + Sync,
{
    let m = out.len();
    debug_assert_eq!(m, out_len(xs.len(), w));
    if ex.threads() <= 1 || m < 2 * PAR_MIN_CHUNK {
        serial_into(xs, out);
        return;
    }
    let chunks = ex.threads().min(m.div_ceil(PAR_MIN_CHUNK));
    let chunk_len = m.div_ceil(chunks);
    ex.parallel_chunks_mut(out, chunk_len, |ci, dst| {
        let start = ci * chunk_len;
        serial_into(&xs[start..start + dst.len() + w - 1], dst);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::AddOp;

    #[test]
    fn out_len_edges() {
        assert_eq!(out_len(10, 3), 8);
        assert_eq!(out_len(3, 3), 1);
        assert_eq!(out_len(2, 3), 0);
        assert_eq!(out_len(0, 1), 0);
        assert_eq!(out_len(5, 0), 0);
    }

    #[test]
    fn algo_name_roundtrip() {
        for a in Algo::ALL {
            assert_eq!(Algo::parse(a.name()), Some(a));
        }
        assert_eq!(Algo::parse("bogus"), None);
    }

    #[test]
    fn auto_w1_is_copy() {
        let xs = [5f32, 6.0, 7.0];
        assert_eq!(auto(AddOp::<f32>::new(), &xs, 1, 8), xs.to_vec());
    }
}
