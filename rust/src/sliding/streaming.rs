//! Streaming (online) sliding sums — Algorithm 1 as a push-based
//! iterator, for inputs that arrive one element (or one packet) at a
//! time: sensor streams, audio frames, network telemetry. This is the
//! paper's "input sequence elements become available one by one" setting
//! verbatim; state is the suffix-sum ring of [`sliding_scalar_input`],
//! so each push is `O(w)` lane work / `O(1)` vector steps and no history
//! buffer is kept.
//!
//! **Bit-exactness contract:** every emitted window sum is bit-identical
//! to [`sliding_scalar_input`] on the same prefix (register path,
//! `w ≤ p`). That requires reproducing Alg 1's lane seeding literally:
//! the per-element broadcast combines `x` into the *identity* lane
//! `w-1`, so a fresh suffix accumulator starts as `id ⊕ x` — not a bare
//! `x`. For operators where `id ⊕ x ≠ x` bitwise (`-0.0` under f32 add:
//! `0.0 + -0.0 = 0.0`), a bare seed re-associates the window fold and
//! drifts off the batch kernel; that drift is what the old 1e-3
//! tolerance in the tests was papering over.
//!
//! [`sliding_scalar_input`]: super::sliding_scalar_input

use crate::ops::AssocOp;

/// Online sliding-window accumulator: push elements, pop window sums.
pub struct StreamingSlidingSum<O: AssocOp> {
    op: O,
    w: usize,
    /// Suffix accumulators; logical lane `l` lives at `(head + l) % cap`.
    /// Empty for `w == 1` (a width-1 window has no carried state).
    ring: Vec<O::Elem>,
    head: usize,
    /// Elements consumed so far (windows start emitting at `w`).
    seen: usize,
}

impl<O: AssocOp> StreamingSlidingSum<O> {
    pub fn new(op: O, w: usize) -> Self {
        assert!(w >= 1, "window must be positive");
        Self {
            op,
            w,
            // w == 1 keeps no ring at all — `Vec::new` for an empty
            // window-1 state, O(w-1) lanes otherwise.
            ring: vec![op.identity(); w - 1], // alloc-ok: one-time O(w) state
            head: 0,
            seen: 0,
        }
    }

    pub fn window(&self) -> usize {
        self.w
    }

    /// Elements pushed so far.
    pub fn len_seen(&self) -> usize {
        self.seen
    }

    /// Window sums that pushing `n` more elements would emit (sizes the
    /// `dst` of [`StreamingSlidingSum::push_slice_into`]).
    pub fn pending_out_len(&self, n: usize) -> usize {
        (self.seen + n).saturating_sub((self.w - 1).max(self.seen))
    }

    /// Push one element; returns the completed window sum once `w`
    /// elements have been seen (i.e. from the `w`-th push onward).
    pub fn push(&mut self, x: O::Elem) -> Option<O::Elem> {
        self.seen += 1;
        if self.w == 1 {
            // Alg 1 with w == 1: the broadcast folds x into the identity
            // lane and emits it immediately — id ⊕ x, no ring state.
            return Some(self.op.combine(self.op.identity(), x));
        }
        let cap = self.ring.len();
        let front = self.op.combine(self.ring[self.head], x);
        // Broadcast x into every live suffix lane. The vacated slot
        // becomes the youngest lane, seeded the way Alg 1's broadcast
        // seeds lane w-1: combined into the identity (see module docs
        // for why `id ⊕ x`, not bare `x`, is load-bearing).
        self.ring[self.head] = self.op.combine(self.op.identity(), x);
        for l in 1..cap {
            let idx = (self.head + l) % cap;
            self.ring[idx] = self.op.combine(self.ring[idx], x);
        }
        self.head = (self.head + 1) % cap;
        if self.seen >= self.w {
            Some(front)
        } else {
            None
        }
    }

    /// Push a packet; collects completed sums (vector-input usage shape).
    pub fn push_slice(&mut self, xs: &[O::Elem]) -> Vec<O::Elem> {
        // alloc-ok: Vec-returning convenience wrapper over push_slice_into.
        let mut out = vec![self.op.identity(); self.pending_out_len(xs.len())];
        self.push_slice_into(xs, &mut out);
        out
    }

    /// Push a packet, writing the completed window sums into a
    /// caller-provided buffer of length exactly
    /// [`StreamingSlidingSum::pending_out_len`]`(xs.len())`. Every
    /// element of `dst` is overwritten; no allocation.
    pub fn push_slice_into(&mut self, xs: &[O::Elem], dst: &mut [O::Elem]) {
        assert_eq!(
            dst.len(),
            self.pending_out_len(xs.len()),
            "dst length (see pending_out_len)"
        );
        crate::check::poison(dst);
        let mut emitted = 0usize;
        for &x in xs {
            if let Some(y) = self.push(x) {
                dst[emitted] = y;
                emitted += 1;
            }
        }
        debug_assert_eq!(emitted, dst.len());
        crate::check::assert_no_poison(dst, "push_slice_into");
    }

    /// Reset to the empty-stream state.
    pub fn reset(&mut self) {
        for v in &mut self.ring {
            *v = self.op.identity();
        }
        self.head = 0;
        self.seen = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::{AddOp, ConvPair, MaxOp, Pair};
    use crate::simd::MAX_LANES;
    use crate::sliding::sliding_scalar_input;

    #[test]
    fn streaming_matches_batch() {
        let xs: Vec<f32> = (0..100).map(|i| ((i * 13 % 31) as f32) - 15.0).collect();
        for w in [1usize, 2, 3, 7, 16, 63] {
            let mut s = StreamingSlidingSum::new(AddOp::<f32>::new(), w);
            let got = s.push_slice(&xs);
            // Register path (w ≤ p) of the batch kernel: the oracle the
            // streaming state machine is bit-identical to.
            let want = sliding_scalar_input(AddOp::<f32>::new(), &xs, w, MAX_LANES);
            assert_eq!(got, want, "w={w}");
        }
    }

    /// `-0.0` under f32 add is the case where `id ⊕ x ≠ x` bitwise; a
    /// bare-`x` lane seed (the old code) diverges from the batch kernel
    /// here. Compare bit patterns — `-0.0 == 0.0` under `PartialEq`
    /// would mask the regression.
    #[test]
    fn negative_zero_lane_seed_is_bit_exact() {
        for w in [1usize, 3, 5] {
            let xs = vec![-0.0f32; 4 * w];
            let mut s = StreamingSlidingSum::new(AddOp::<f32>::new(), w);
            let got = s.push_slice(&xs);
            let want = sliding_scalar_input(AddOp::<f32>::new(), &xs, w, MAX_LANES);
            let gb: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
            let wb: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
            assert_eq!(gb, wb, "w={w}");
        }
    }

    #[test]
    fn window_one_keeps_no_ring() {
        let mut s = StreamingSlidingSum::new(AddOp::<f32>::new(), 1);
        assert_eq!(s.ring.capacity(), 0, "w == 1 must not allocate a ring");
        assert_eq!(s.push(4.5), Some(4.5));
        assert_eq!(s.push(-1.25), Some(-1.25));
        assert_eq!(s.len_seen(), 2);
    }

    #[test]
    fn emits_nothing_before_w_elements() {
        let mut s = StreamingSlidingSum::new(MaxOp::<f32>::new(), 4);
        assert!(s.push(1.0).is_none());
        assert!(s.push(5.0).is_none());
        assert!(s.push(2.0).is_none());
        assert_eq!(s.push(3.0), Some(5.0));
        assert_eq!(s.push(0.0), Some(5.0)); // window [5,2,3,0]
    }

    #[test]
    fn packets_split_arbitrarily() {
        let xs: Vec<f32> = (0..50).map(|i| i as f32).collect();
        let want = sliding_scalar_input(AddOp::<f32>::new(), &xs, 5, MAX_LANES);
        let mut s = StreamingSlidingSum::new(AddOp::<f32>::new(), 5);
        let mut got = Vec::new();
        for chunk in xs.chunks(7) {
            got.extend(s.push_slice(chunk));
        }
        assert_eq!(got, want);
    }

    #[test]
    fn push_slice_into_matches_push_slice() {
        let xs: Vec<f32> = (0..40).map(|i| (i as f32) * 0.5 - 7.0).collect();
        for w in [1usize, 3, 8] {
            let mut a = StreamingSlidingSum::new(AddOp::<f32>::new(), w);
            let mut b = StreamingSlidingSum::new(AddOp::<f32>::new(), w);
            for chunk in xs.chunks(6) {
                let want = a.push_slice(chunk);
                let mut got = vec![0.0f32; b.pending_out_len(chunk.len())];
                b.push_slice_into(chunk, &mut got);
                assert_eq!(got, want, "w={w}");
            }
        }
    }

    #[test]
    fn noncommutative_stream_order() {
        let xs: Vec<Pair> = (0..30)
            .map(|i| Pair::new(1.0 + 0.05 * ((i % 4) as f32), 0.2 * i as f32 - 3.0))
            .collect();
        let mut s = StreamingSlidingSum::new(ConvPair, 6);
        let got = s.push_slice(&xs);
        let want = sliding_scalar_input(ConvPair, &xs, 6, MAX_LANES);
        for (g, t) in got.iter().zip(&want) {
            assert!((g.u - t.u).abs() < 1e-3 && (g.v - t.v).abs() < 1e-3);
        }
    }

    #[test]
    fn reset_restarts_stream() {
        let mut s = StreamingSlidingSum::new(AddOp::<f32>::new(), 3);
        s.push_slice(&[1.0, 2.0, 3.0]);
        s.reset();
        assert_eq!(s.len_seen(), 0);
        assert!(s.push(1.0).is_none());
        assert!(s.push(1.0).is_none());
        assert_eq!(s.push(1.0), Some(3.0));
    }
}
