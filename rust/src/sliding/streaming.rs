//! Streaming (online) sliding sums — Algorithm 1 as a push-based
//! iterator, for inputs that arrive one element (or one packet) at a
//! time: sensor streams, audio frames, network telemetry. This is the
//! paper's "input sequence elements become available one by one" setting
//! verbatim; state is the suffix-sum ring of [`sliding_scalar_input`],
//! so each push is `O(w)` lane work / `O(1)` vector steps and no history
//! buffer is kept.
//!
//! [`sliding_scalar_input`]: super::sliding_scalar_input

use crate::ops::AssocOp;

/// Online sliding-window accumulator: push elements, pop window sums.
pub struct StreamingSlidingSum<O: AssocOp> {
    op: O,
    w: usize,
    /// Suffix accumulators; logical lane `l` lives at `(head + l) % cap`.
    ring: Vec<O::Elem>,
    head: usize,
    /// Elements consumed so far (windows start emitting at `w`).
    seen: usize,
}

impl<O: AssocOp> StreamingSlidingSum<O> {
    pub fn new(op: O, w: usize) -> Self {
        assert!(w >= 1, "window must be positive");
        Self {
            op,
            w,
            ring: vec![op.identity(); w.max(2) - 1], // alloc-ok: one-time O(w) state
            head: 0,
            seen: 0,
        }
    }

    pub fn window(&self) -> usize {
        self.w
    }

    /// Elements pushed so far.
    pub fn len_seen(&self) -> usize {
        self.seen
    }

    /// Push one element; returns the completed window sum once `w`
    /// elements have been seen (i.e. from the `w`-th push onward).
    pub fn push(&mut self, x: O::Elem) -> Option<O::Elem> {
        self.seen += 1;
        if self.w == 1 {
            return Some(x);
        }
        let cap = self.ring.len();
        let front = self.op.combine(self.ring[self.head], x);
        // Broadcast x into every live suffix lane; the vacated slot
        // becomes the youngest lane seeded with x (Alg 1's broadcast
        // touches lane w-1 too).
        self.ring[self.head] = x;
        for l in 1..cap {
            let idx = (self.head + l) % cap;
            self.ring[idx] = self.op.combine(self.ring[idx], x);
        }
        self.head = (self.head + 1) % cap;
        if self.seen >= self.w {
            Some(front)
        } else {
            None
        }
    }

    /// Push a packet; collects completed sums (vector-input usage shape).
    pub fn push_slice(&mut self, xs: &[O::Elem]) -> Vec<O::Elem> {
        // alloc-ok: Vec-returning convenience API, not on the plan run path.
        let mut out = Vec::with_capacity(xs.len());
        for &x in xs {
            if let Some(y) = self.push(x) {
                out.push(y);
            }
        }
        out
    }

    /// Reset to the empty-stream state.
    pub fn reset(&mut self) {
        for v in &mut self.ring {
            *v = self.op.identity();
        }
        self.head = 0;
        self.seen = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::{AddOp, ConvPair, MaxOp, Pair};
    use crate::sliding::sliding_naive;

    #[test]
    fn streaming_matches_batch() {
        let xs: Vec<f32> = (0..100).map(|i| ((i * 13 % 31) as f32) - 15.0).collect();
        for w in [1usize, 2, 3, 7, 16, 63] {
            let mut s = StreamingSlidingSum::new(AddOp::<f32>::new(), w);
            let got = s.push_slice(&xs);
            let want = sliding_naive(AddOp::<f32>::new(), &xs, w);
            assert_eq!(got.len(), want.len(), "w={w}");
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() < 1e-3, "w={w}");
            }
        }
    }

    #[test]
    fn emits_nothing_before_w_elements() {
        let mut s = StreamingSlidingSum::new(MaxOp::<f32>::new(), 4);
        assert!(s.push(1.0).is_none());
        assert!(s.push(5.0).is_none());
        assert!(s.push(2.0).is_none());
        assert_eq!(s.push(3.0), Some(5.0));
        assert_eq!(s.push(0.0), Some(5.0)); // window [5,2,3,0]
    }

    #[test]
    fn packets_split_arbitrarily() {
        let xs: Vec<f32> = (0..50).map(|i| i as f32).collect();
        let want = sliding_naive(AddOp::<f32>::new(), &xs, 5);
        let mut s = StreamingSlidingSum::new(AddOp::<f32>::new(), 5);
        let mut got = Vec::new();
        for chunk in xs.chunks(7) {
            got.extend(s.push_slice(chunk));
        }
        assert_eq!(got.len(), want.len());
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-3);
        }
    }

    #[test]
    fn noncommutative_stream_order() {
        let xs: Vec<Pair> = (0..30)
            .map(|i| Pair::new(1.0 + 0.05 * ((i % 4) as f32), 0.2 * i as f32 - 3.0))
            .collect();
        let mut s = StreamingSlidingSum::new(ConvPair, 6);
        let got = s.push_slice(&xs);
        let want = sliding_naive(ConvPair, &xs, 6);
        for (g, t) in got.iter().zip(&want) {
            assert!((g.u - t.u).abs() < 1e-3 && (g.v - t.v).abs() < 1e-3);
        }
    }

    #[test]
    fn reset_restarts_stream() {
        let mut s = StreamingSlidingSum::new(AddOp::<f32>::new(), 3);
        s.push_slice(&[1.0, 2.0, 3.0]);
        s.reset();
        assert_eq!(s.len_seen(), 0);
        assert!(s.push(1.0).is_none());
        assert!(s.push(1.0).is_none());
        assert_eq!(s.push(1.0), Some(3.0));
    }
}
