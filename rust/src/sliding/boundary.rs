//! Boundary conditions for sliding windows (paper §3: "padding,
//! mirroring, or periodicity").
//!
//! The algorithm family computes *valid-mode* windows. DNN layers need
//! `same`-size outputs, which we obtain by extending the input before the
//! sweep. Extension is `O(w)` extra memory — negligible against the
//! `O(N·w)` im2col expansion the paper is displacing.

use crate::ops::AssocOp;

/// How to synthesize elements beyond the input ends.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Boundary {
    /// No extension; output has `N − w + 1` elements.
    Valid,
    /// Pad both ends with the operator identity (zero padding for `+`,
    /// `−∞` for max …) so the output has `N` elements (`same` mode):
    /// `⌊(w−1)/2⌋` leading, `⌈(w−1)/2⌉` trailing pads.
    SamePad,
    /// Reflect without repeating the edge element (`abcd` → `cb|abcd|cb`).
    Mirror,
    /// Wrap around (`abcd` → `cd|abcd|ab`).
    Periodic,
}

impl Boundary {
    pub fn name(&self) -> &'static str {
        match self {
            Boundary::Valid => "valid",
            Boundary::SamePad => "same",
            Boundary::Mirror => "mirror",
            Boundary::Periodic => "periodic",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "valid" => Some(Boundary::Valid),
            "same" => Some(Boundary::SamePad),
            "mirror" => Some(Boundary::Mirror),
            "periodic" => Some(Boundary::Periodic),
            _ => None,
        }
    }
}

/// Extend `xs` for window `w` under `mode`. Returns the extended sequence;
/// running a valid-mode sliding sum over it yields exactly `xs.len()`
/// outputs for the non-valid modes (and `xs` unchanged for `Valid`).
pub fn extend<O: AssocOp>(op: O, xs: &[O::Elem], w: usize, mode: Boundary) -> Vec<O::Elem> {
    let n = xs.len();
    if mode == Boundary::Valid || w <= 1 || n == 0 {
        return xs.to_vec(); // alloc-ok: boundary extension is setup, not hot
    }
    let lead = (w - 1) / 2;
    let trail = w - 1 - lead;
    // alloc-ok: boundary extension is setup work, not on the tile loop.
    let mut out = Vec::with_capacity(n + w - 1);
    match mode {
        Boundary::Valid => unreachable!(),
        Boundary::SamePad => {
            out.extend(std::iter::repeat(op.identity()).take(lead));
            out.extend_from_slice(xs);
            out.extend(std::iter::repeat(op.identity()).take(trail));
        }
        Boundary::Mirror => {
            for k in 0..lead {
                // element at virtual index -(lead-k): reflect about 0
                // without repeating the edge: index (lead - k) clamped.
                let idx = (lead - k).min(n - 1);
                out.push(xs[idx]);
            }
            out.extend_from_slice(xs);
            for k in 0..trail {
                // virtual index n + k reflects to n-2-k.
                let idx = n.saturating_sub(2 + k).min(n - 1);
                out.push(xs[idx]);
            }
        }
        Boundary::Periodic => {
            for k in 0..lead {
                out.push(xs[(n - (lead - k) % n) % n]);
            }
            out.extend_from_slice(xs);
            for k in 0..trail {
                out.push(xs[k % n]);
            }
        }
    }
    out
}

/// Output length for a given input length/window/mode.
pub fn output_len(n: usize, w: usize, mode: Boundary) -> usize {
    match mode {
        Boundary::Valid => super::out_len(n, w),
        _ => n,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::{AddOp, MaxOp};
    use crate::sliding::sliding_naive;

    #[test]
    fn same_pad_lengths() {
        let xs = [1f32, 2.0, 3.0, 4.0, 5.0];
        for w in [2usize, 3, 4, 5] {
            let ext = extend(AddOp::<f32>::new(), &xs, w, Boundary::SamePad);
            assert_eq!(ext.len(), xs.len() + w - 1);
            let out = sliding_naive(AddOp::<f32>::new(), &ext, w);
            assert_eq!(out.len(), xs.len(), "w={w}");
        }
    }

    #[test]
    fn same_pad_w3_values() {
        let xs = [1f32, 2.0, 3.0];
        let ext = extend(AddOp::<f32>::new(), &xs, 3, Boundary::SamePad);
        assert_eq!(ext, vec![0.0, 1.0, 2.0, 3.0, 0.0]);
        let out = sliding_naive(AddOp::<f32>::new(), &ext, 3);
        assert_eq!(out, vec![3.0, 6.0, 5.0]);
    }

    #[test]
    fn max_pad_uses_neg_inf_identity() {
        let xs = [5f32, -2.0];
        let ext = extend(MaxOp::<f32>::new(), &xs, 3, Boundary::SamePad);
        assert_eq!(ext[0], f32::NEG_INFINITY);
        let out = sliding_naive(MaxOp::<f32>::new(), &ext, 3);
        assert_eq!(out, vec![5.0, 5.0]);
    }

    #[test]
    fn mirror_reflects_without_edge_repeat() {
        let xs = [1f32, 2.0, 3.0, 4.0];
        let ext = extend(AddOp::<f32>::new(), &xs, 3, Boundary::Mirror);
        // lead=1 → reflect of index 1 = 2.0; trail=1 → reflect = 3.0
        assert_eq!(ext, vec![2.0, 1.0, 2.0, 3.0, 4.0, 3.0]);
    }

    #[test]
    fn periodic_wraps() {
        let xs = [1f32, 2.0, 3.0, 4.0];
        let ext = extend(AddOp::<f32>::new(), &xs, 3, Boundary::Periodic);
        assert_eq!(ext, vec![4.0, 1.0, 2.0, 3.0, 4.0, 1.0]);
    }

    #[test]
    fn valid_is_identity() {
        let xs = [1f32, 2.0];
        assert_eq!(extend(AddOp::<f32>::new(), &xs, 3, Boundary::Valid), xs.to_vec());
        assert_eq!(output_len(10, 3, Boundary::Valid), 8);
        assert_eq!(output_len(10, 3, Boundary::SamePad), 10);
    }
}
