//! `O(wN)` baseline: evaluate every window independently (paper §2.2,
//! "the asymptotic complexity of a naive sliding sum algorithm is O(wN)").

use crate::ops::AssocOp;

use super::out_len;

/// Direct evaluation of Eq. 3. Works for any monoid; this is the
/// correctness oracle every other algorithm is tested against, and the
/// baseline the TBL-A bench normalizes speedups to.
pub fn sliding_naive<O: AssocOp>(op: O, xs: &[O::Elem], w: usize) -> Vec<O::Elem> {
    let m = out_len(xs.len(), w);
    // alloc-ok: Vec-returning oracle; sliding_naive_into is the hot path.
    let mut out = Vec::with_capacity(m);
    for i in 0..m {
        let mut acc = op.identity();
        for &x in &xs[i..i + w] {
            acc = op.combine(acc, x);
        }
        out.push(acc);
    }
    out
}

/// [`sliding_naive`] writing into a caller-provided buffer of length
/// [`out_len`]`(xs.len(), w)`. Every element is overwritten.
pub fn sliding_naive_into<O: AssocOp>(op: O, xs: &[O::Elem], w: usize, out: &mut [O::Elem]) {
    assert_eq!(out.len(), out_len(xs.len(), w), "dst length");
    crate::check::poison(out);
    for (i, o) in out.iter_mut().enumerate() {
        let mut acc = op.identity();
        for &x in &xs[i..i + w] {
            acc = op.combine(acc, x);
        }
        *o = acc;
    }
    crate::check::assert_no_poison(out, "sliding_naive_into");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::{AddOp, MaxOp};

    #[test]
    fn basic_sums() {
        let xs = [1f32, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(sliding_naive(AddOp::<f32>::new(), &xs, 2), vec![3.0, 5.0, 7.0, 9.0]);
        assert_eq!(sliding_naive(AddOp::<f32>::new(), &xs, 5), vec![15.0]);
    }

    #[test]
    fn window_larger_than_input_is_empty() {
        let xs = [1f32, 2.0];
        assert!(sliding_naive(AddOp::<f32>::new(), &xs, 3).is_empty());
    }

    #[test]
    fn max_windows() {
        let xs = [3i32, 1, 4, 1, 5, 9, 2, 6];
        assert_eq!(
            sliding_naive(MaxOp::<i32>::new(), &xs, 3),
            vec![4, 4, 5, 9, 9, 9]
        );
    }

    #[test]
    fn w1_is_identity_map() {
        let xs = [7f32, -2.0, 0.5];
        assert_eq!(sliding_naive(AddOp::<f32>::new(), &xs, 1), xs.to_vec());
    }
}
