//! Paper Algorithm 1 — *Scalar Input*.
//!
//! The input arrives one element at a time; a single vector register `Y`
//! of suffix sums is maintained. Per element: broadcast, one vector `⊕`,
//! emit lane 0, shift left. `O(N)` vector steps for any monoid — no
//! associativity needed, because every window is accumulated strictly
//! left-to-right.
//!
//! ```text
//! Y ← (Σ_{j=0}^{w-2} xⱼ, Σ_{j=1}^{w-2} xⱼ, …, x_{w-2}, id, …, id)
//! for i = w-1 .. N-1:
//!     X ← (xᵢ ×w, id …)        # broadcast to first w lanes
//!     Y ← Y ⊕ X
//!     emit Y[0]
//!     Y ← Y ≪ 1
//! ```

use crate::ops::AssocOp;
use crate::simd::{VecReg, MAX_LANES};

use super::out_len;

/// Algorithm 1 over the software vector machine. Requires `w ≤ P`;
/// for larger windows use [`sliding_scalar_input_unbounded`], which is the
/// identical recurrence on a multi-register (heap) working set.
pub fn sliding_scalar_input<O: AssocOp>(
    op: O,
    xs: &[O::Elem],
    w: usize,
    p: usize,
) -> Vec<O::Elem> {
    // alloc-ok: Vec-returning wrapper; sliding_scalar_input_into is the hot path.
    let mut out = vec![op.identity(); out_len(xs.len(), w)];
    sliding_scalar_input_into(op, xs, w, p, &mut out);
    out
}

/// [`sliding_scalar_input`] writing into a caller-provided buffer of
/// length [`out_len`]`(xs.len(), w)`. Every element is overwritten.
pub fn sliding_scalar_input_into<O: AssocOp>(
    op: O,
    xs: &[O::Elem],
    w: usize,
    p: usize,
    out: &mut [O::Elem],
) {
    if w > p || w > MAX_LANES {
        sliding_scalar_input_unbounded_into(op, xs, w, out);
        return;
    }
    let m = out_len(xs.len(), w);
    assert_eq!(out.len(), m, "dst length");
    if m == 0 {
        return;
    }
    crate::check::poison(out);
    let id = op.identity();

    // Initialize Y with the suffix sums of the first w-1 elements:
    // Y[l] = x_l ⊕ … ⊕ x_{w-2}.
    let mut y = VecReg::splat(p, id);
    for l in 0..w.saturating_sub(1) {
        let mut acc = op.identity();
        for &x in &xs[l..w - 1] {
            acc = op.combine(acc, x);
        }
        y.set(l, acc);
    }

    for i in (w - 1)..xs.len() {
        let x = VecReg::broadcast_prefix(p, xs[i], w, id);
        y.combine_assign(op, &x);
        out[i + 1 - w] = y.get(0);
        y.shift_left(1, id);
    }
    crate::check::assert_no_poison(out, "sliding_scalar_input_into");
}

/// Algorithm 1's recurrence on an unbounded working set (window larger
/// than the physical register). Each inner loop is the same lane-parallel
/// `⊕`/shift, just longer than one register — on real hardware this is
/// the multi-register strip-mined form.
pub fn sliding_scalar_input_unbounded<O: AssocOp>(
    op: O,
    xs: &[O::Elem],
    w: usize,
) -> Vec<O::Elem> {
    // alloc-ok: Vec-returning wrapper; the `_into` form is the hot path.
    let mut out = vec![op.identity(); out_len(xs.len(), w)];
    sliding_scalar_input_unbounded_into(op, xs, w, &mut out);
    out
}

/// [`sliding_scalar_input_unbounded`] into a caller-provided buffer.
pub fn sliding_scalar_input_unbounded_into<O: AssocOp>(
    op: O,
    xs: &[O::Elem],
    w: usize,
    out: &mut [O::Elem],
) {
    let m = out_len(xs.len(), w);
    assert_eq!(out.len(), m, "dst length");
    if m == 0 {
        return;
    }
    crate::check::poison(out);
    if w == 1 {
        out.copy_from_slice(xs);
        crate::check::assert_no_poison(out, "sliding_scalar_input_unbounded_into");
        return;
    }
    // Ring buffer of w-1 suffix accumulators; logical lane l of the paper's
    // register lives at ring[(head + l) % (w-1)] — the ≪1 becomes a head
    // bump instead of a data move.
    let cap = w - 1;
    let mut ring = vec![op.identity(); cap]; // alloc-ok: O(w) ring scratch
    for (l, slot) in ring.iter_mut().enumerate() {
        let mut acc = op.identity();
        for &x in &xs[l..w - 1] {
            acc = op.combine(acc, x);
        }
        *slot = acc;
    }
    let mut head = 0usize;
    for i in (w - 1)..xs.len() {
        let xi = xs[i];
        // Y ⊕ broadcast(x_i) over the live lanes, emit lane 0, shift.
        out[i + 1 - w] = op.combine(ring[head], xi);
        // The vacated slot becomes the youngest suffix lane: its
        // accumulation starts with x_i itself (the broadcast in Alg 1
        // touches the identity lane w-1 too, seeding the next window).
        ring[head] = xi;
        for l in 1..cap {
            let idx = (head + l) % cap;
            ring[idx] = op.combine(ring[idx], xi);
        }
        head = (head + 1) % cap;
    }
    crate::check::assert_no_poison(out, "sliding_scalar_input_unbounded_into");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::{AddOp, ConvPair, MaxOp, Pair};
    use crate::sliding::sliding_naive;

    #[test]
    fn matches_naive_add() {
        let xs: Vec<f32> = (0..40).map(|i| (i as f32) * 0.25 - 3.0).collect();
        for w in [1usize, 2, 3, 5, 8] {
            assert_eq!(
                sliding_scalar_input(AddOp::<f32>::new(), &xs, w, 16),
                sliding_naive(AddOp::<f32>::new(), &xs, w),
                "w={w}"
            );
        }
    }

    #[test]
    fn matches_naive_max() {
        let xs: Vec<i64> = (0..50).map(|i| (i * 37 % 23) as i64 - 11).collect();
        for w in [2usize, 4, 7] {
            assert_eq!(
                sliding_scalar_input(MaxOp::<i64>::new(), &xs, w, 8),
                sliding_naive(MaxOp::<i64>::new(), &xs, w)
            );
        }
    }

    #[test]
    fn noncommutative_operand_order_preserved() {
        let xs: Vec<Pair> = (0..20)
            .map(|i| Pair::new(1.0 + 0.05 * i as f32, (i as f32) * 0.3 - 1.0))
            .collect();
        let got = sliding_scalar_input(ConvPair, &xs, 4, 8);
        let want = sliding_naive(ConvPair, &xs, 4);
        for (g, w_) in got.iter().zip(&want) {
            assert!((g.u - w_.u).abs() < 1e-4 && (g.v - w_.v).abs() < 1e-4);
        }
    }

    #[test]
    fn unbounded_path_matches_naive() {
        let xs: Vec<f32> = (0..300).map(|i| ((i * 13 % 31) as f32) - 15.0).collect();
        for w in [65usize, 100, 128] {
            assert_eq!(
                sliding_scalar_input(AddOp::<f32>::new(), &xs, w, 8),
                sliding_naive(AddOp::<f32>::new(), &xs, w),
                "w={w}"
            );
        }
    }

    #[test]
    fn empty_and_degenerate() {
        let xs: [f32; 0] = [];
        assert!(sliding_scalar_input(AddOp::<f32>::new(), &xs, 3, 8).is_empty());
        let xs = [1f32, 2.0];
        assert!(sliding_scalar_input(AddOp::<f32>::new(), &xs, 3, 8).is_empty());
    }
}
