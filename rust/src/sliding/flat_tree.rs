//! Production realization of the log-depth sliding sum on flat buffers.
//!
//! The `VecReg`-based functions in this module's siblings are the
//! paper-faithful register-streaming algorithms (and what TBL-A
//! benches); this file is the same mathematics laid out for a memory-
//! resident input: a doubling ladder of whole arrays,
//!
//! ```text
//! D₀ = x                      (windows of size 1 starting at i)
//! D_{t+1}[i] = D_t[i] ⊕ D_t[i + 2^t]   (windows of size 2^{t+1})
//! ```
//!
//! `⌈log₂ w⌉` passes, each a unit-stride elementwise combine that LLVM
//! auto-vectorizes — no lane shuffles at all (the `Slide` becomes an
//! address offset, which is the whole advantage of operating on memory
//! rather than registers). Non-power-of-two windows finish with either
//! one overlapping combine (idempotent ⊕) or the binary decomposition
//! of `w` over the saved ladder levels (general associative ⊕).
//! `O(N log w)` work, `O(N log w)` scratch in the general case,
//! `O(N)` for idempotent/power-of-two.

use crate::ops::AssocOp;

use super::out_len;

/// Log-depth sliding sum over a flat buffer (associative `⊕`).
pub fn sliding_flat_tree<O: AssocOp>(op: O, xs: &[O::Elem], w: usize) -> Vec<O::Elem> {
    // alloc-ok: Vec-returning wrapper; sliding_flat_tree_into is the hot path.
    let mut out = vec![op.identity(); out_len(xs.len(), w)];
    sliding_flat_tree_into(op, xs, w, &mut out);
    out
}

/// One in-place doubling step: `d[i] ← d[i] ⊕ d[i + size]` for
/// `i < next_live`, expressed as `size`-wide disjoint (dst, src) chunk
/// pairs so the operator's slice kernel
/// ([`AssocOp::combine_assign_slices`] — runtime SIMD for f32
/// add/max/min) applies. Chunks ascend, so every element still reads its
/// source before any write reaches it — exactly the original
/// read-ahead-of-write sweep.
fn ladder_step<O: AssocOp>(op: O, d: &mut [O::Elem], size: usize, next_live: usize) {
    let mut c = 0;
    while c < next_live {
        let len = size.min(next_live - c);
        let (head, tail) = d.split_at_mut(c + size);
        op.combine_assign_slices(&mut head[c..c + len], &tail[..len]);
        c += len;
    }
}

/// [`sliding_flat_tree`] writing into a caller-provided buffer of length
/// [`out_len`]`(xs.len(), w)` — the final ladder pass lands directly in
/// `out`, so no result copy remains (the ladder itself still needs one
/// `O(N)` scratch clone of the input). Every element of `out` is
/// overwritten.
pub fn sliding_flat_tree_into<O: AssocOp>(op: O, xs: &[O::Elem], w: usize, out: &mut [O::Elem]) {
    let n = xs.len();
    let m = out_len(n, w);
    assert_eq!(out.len(), m, "dst length");
    if m == 0 {
        return;
    }
    if w == 1 {
        out.copy_from_slice(xs);
        return;
    }
    crate::check::poison(out);

    let t_max = usize::BITS - 1 - w.leading_zeros(); // floor(log2 w)
    let top = 1usize << t_max;
    let mut d = xs.to_vec(); // alloc-ok: the one O(N) ladder scratch clone
    let mut live = n; // valid prefix length of d

    if w == top {
        // Pure power of two: climb to size = top/2 in place, emit the
        // final doubling straight into the destination.
        let mut size = 1usize;
        while size < top / 2 {
            let next_live = live - size;
            ladder_step(op, &mut d, size, next_live);
            live = next_live;
            size <<= 1;
        }
        for (o, (a, b)) in out.iter_mut().zip(d.iter().zip(&d[size..])) {
            *o = op.combine(*a, *b);
        }
        crate::check::assert_no_poison(out, "sliding_flat_tree_into");
        return;
    }

    if op.is_idempotent() {
        // Full ladder to size = top, then the overlap combine into the
        // destination: window w = [i, i+top) ∪ [i+w-top, i+w).
        let mut size = 1usize;
        while size < top {
            let next_live = live - size;
            ladder_step(op, &mut d, size, next_live);
            live = next_live;
            size <<= 1;
        }
        let shift = w - top;
        for (o, (a, b)) in out.iter_mut().zip(d.iter().zip(&d[shift..])) {
            *o = op.combine(*a, *b);
        }
        crate::check::assert_no_poison(out, "sliding_flat_tree_into");
        return;
    }

    // General associative: fold the binary decomposition of w as the
    // ladder climbs, so only TWO buffers live at once (the in-place
    // ladder `d` and the output). Levels arrive smallest-first, i.e.
    // rightmost chunk first; each new (earlier) chunk is combined on the
    // LEFT, preserving order for non-commutative ⊕. The §Perf pass
    // measured the per-level-buffer version 5× slower (page faults on
    // log w fresh multi-MB allocations).
    let mut seeded = false;
    let mut suffix = 0usize; // total size of chunks already folded
    let mut size = 1usize;
    loop {
        if w & size != 0 {
            // Chunk of `size` ending `suffix` before the window end:
            // starts at i + w − suffix − size.
            let off = w - suffix - size;
            if seeded {
                for (i, ov) in out.iter_mut().enumerate() {
                    *ov = op.combine(d[off + i], *ov);
                }
            } else {
                out.copy_from_slice(&d[off..off + m]);
                seeded = true;
            }
            suffix += size;
        }
        if size >= top {
            break;
        }
        // In-place doubling step (reads stay ahead of writes).
        let next_live = live - size;
        ladder_step(op, &mut d, size, next_live);
        live = next_live;
        size <<= 1;
    }
    debug_assert!(seeded, "w >= 1 has at least one set bit");
    crate::check::assert_no_poison(out, "sliding_flat_tree_into");
}

/// Window-2 special case: one combine pass (used by the dispatcher).
pub fn sliding_w2<O: AssocOp>(op: O, xs: &[O::Elem]) -> Vec<O::Elem> {
    // alloc-ok: Vec-returning wrapper; sliding_w2_into is the hot path.
    let mut out = vec![op.identity(); out_len(xs.len(), 2)];
    sliding_w2_into(op, xs, &mut out);
    out
}

/// [`sliding_w2`] into a caller-provided buffer: one copy plus one
/// slice-kernel combine (`out[i] = xs[i] ⊕ xs[i+1]`).
pub fn sliding_w2_into<O: AssocOp>(op: O, xs: &[O::Elem], out: &mut [O::Elem]) {
    let m = out_len(xs.len(), 2);
    assert_eq!(out.len(), m, "dst length");
    if m == 0 {
        return;
    }
    crate::check::poison(out);
    out.copy_from_slice(&xs[..m]);
    op.combine_assign_slices(out, &xs[1..1 + m]);
    crate::check::assert_no_poison(out, "sliding_w2_into");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::{AddOp, ConvPair, MaxOp, MinOp, MulOp, Pair};
    use crate::sliding::sliding_naive;

    #[test]
    fn matches_naive_add_all_window_sizes() {
        let xs: Vec<f32> = (0..257).map(|i| ((i * 37 % 101) as f32) * 0.1 - 5.0).collect();
        for w in 1..=40 {
            let got = sliding_flat_tree(AddOp::<f32>::new(), &xs, w);
            let want = sliding_naive(AddOp::<f32>::new(), &xs, w);
            assert_eq!(got.len(), want.len(), "w={w}");
            for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()), "w={w} idx={i}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn matches_naive_max_min_exact() {
        let xs: Vec<f32> = (0..300).map(|i| ((i * 89 % 211) as f32) - 100.0).collect();
        for w in [2usize, 3, 5, 7, 8, 13, 16, 31, 33, 64, 100] {
            assert_eq!(
                sliding_flat_tree(MaxOp::<f32>::new(), &xs, w),
                sliding_naive(MaxOp::<f32>::new(), &xs, w),
                "max w={w}"
            );
            assert_eq!(
                sliding_flat_tree(MinOp::<f32>::new(), &xs, w),
                sliding_naive(MinOp::<f32>::new(), &xs, w),
                "min w={w}"
            );
        }
    }

    #[test]
    fn matches_naive_mul() {
        let xs: Vec<f32> = (0..120).map(|i| 1.0 + 0.02 * ((i % 7) as f32)).collect();
        for w in [3usize, 6, 11, 17] {
            let got = sliding_flat_tree(MulOp::<f32>::new(), &xs, w);
            let want = sliding_naive(MulOp::<f32>::new(), &xs, w);
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() < 1e-3 * b.abs(), "w={w}");
            }
        }
    }

    #[test]
    fn noncommutative_pairs_supported() {
        let xs: Vec<Pair> = (0..90)
            .map(|i| Pair::new(1.0 + 0.03 * ((i % 5) as f32), 0.1 * (i as f32) - 4.0))
            .collect();
        for w in [2usize, 3, 5, 6, 7, 12] {
            let got = sliding_flat_tree(ConvPair, &xs, w);
            let want = sliding_naive(ConvPair, &xs, w);
            for (g, t) in got.iter().zip(&want) {
                assert!(
                    (g.u - t.u).abs() < 1e-3 && (g.v - t.v).abs() < 1e-3,
                    "w={w}: {g:?} vs {t:?}"
                );
            }
        }
    }

    #[test]
    fn edge_cases() {
        let xs = [1f32, 2.0, 3.0];
        assert!(sliding_flat_tree(AddOp::<f32>::new(), &xs, 4).is_empty());
        assert_eq!(sliding_flat_tree(AddOp::<f32>::new(), &xs, 1), xs.to_vec());
        assert_eq!(sliding_w2(AddOp::<f32>::new(), &xs), vec![3.0, 5.0]);
        assert!(sliding_w2(AddOp::<f32>::new(), &xs[..1]).is_empty());
    }
}
