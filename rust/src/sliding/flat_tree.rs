//! Production realization of the log-depth sliding sum on flat buffers.
//!
//! The `VecReg`-based functions in this module's siblings are the
//! paper-faithful register-streaming algorithms (and what TBL-A
//! benches); this file is the same mathematics laid out for a memory-
//! resident input: a doubling ladder of whole arrays,
//!
//! ```text
//! D₀ = x                      (windows of size 1 starting at i)
//! D_{t+1}[i] = D_t[i] ⊕ D_t[i + 2^t]   (windows of size 2^{t+1})
//! ```
//!
//! `⌈log₂ w⌉` passes, each a unit-stride elementwise combine that LLVM
//! auto-vectorizes — no lane shuffles at all (the `Slide` becomes an
//! address offset, which is the whole advantage of operating on memory
//! rather than registers). Non-power-of-two windows finish with either
//! one overlapping combine (idempotent ⊕) or the binary decomposition
//! of `w` over the saved ladder levels (general associative ⊕).
//! `O(N log w)` work, `O(N log w)` scratch in the general case,
//! `O(N)` for idempotent/power-of-two.

use crate::ops::AssocOp;

use super::out_len;

/// Log-depth sliding sum over a flat buffer (associative `⊕`).
pub fn sliding_flat_tree<O: AssocOp>(op: O, xs: &[O::Elem], w: usize) -> Vec<O::Elem> {
    let n = xs.len();
    let m = out_len(n, w);
    if m == 0 {
        return Vec::new();
    }
    if w == 1 {
        return xs.to_vec();
    }

    let t_max = usize::BITS - 1 - w.leading_zeros(); // floor(log2 w)
    let top = 1usize << t_max;

    if w == top || op.is_idempotent() {
        // Single ladder, in place: ascending i never rereads a written
        // slot (writes at i, reads at i+size > i).
        let mut d = xs.to_vec();
        let mut size = 1usize;
        let mut live = n; // valid prefix length of d
        while size < top {
            let next_live = live - size;
            for i in 0..next_live {
                d[i] = op.combine(d[i], d[i + size]);
            }
            live = next_live;
            size <<= 1;
        }
        if w == top {
            d.truncate(m);
            return d;
        }
        // Idempotent overlap: window w = [i, i+top) ∪ [i+w-top, i+w).
        let shift = w - top;
        let mut out = Vec::with_capacity(m);
        for i in 0..m {
            out.push(op.combine(d[i], d[i + shift]));
        }
        return out;
    }

    // General associative: fold the binary decomposition of w as the
    // ladder climbs, so only TWO buffers live at once (the in-place
    // ladder `d` and the output). Levels arrive smallest-first, i.e.
    // rightmost chunk first; each new (earlier) chunk is combined on the
    // LEFT, preserving order for non-commutative ⊕. The §Perf pass
    // measured the per-level-buffer version 5× slower (page faults on
    // log w fresh multi-MB allocations).
    let mut d = xs.to_vec();
    let mut out: Option<Vec<O::Elem>> = None;
    let mut live = n; // valid prefix of d
    let mut suffix = 0usize; // total size of chunks already folded
    let mut size = 1usize;
    loop {
        if w & size != 0 {
            // Chunk of `size` ending `suffix` before the window end:
            // starts at i + w − suffix − size.
            let off = w - suffix - size;
            match out.as_mut() {
                None => {
                    out = Some(d[off..off + m].to_vec());
                }
                Some(o) => {
                    for (i, ov) in o.iter_mut().enumerate() {
                        *ov = op.combine(d[off + i], *ov);
                    }
                }
            }
            suffix += size;
        }
        if size >= top {
            break;
        }
        // In-place doubling step (safe ascending: reads are ahead of
        // writes).
        let next_live = live - size;
        for i in 0..next_live {
            d[i] = op.combine(d[i], d[i + size]);
        }
        live = next_live;
        size <<= 1;
    }
    out.expect("w >= 1 has at least one set bit")
}

/// Window-2 special case: one combine pass (used by the dispatcher).
pub fn sliding_w2<O: AssocOp>(op: O, xs: &[O::Elem]) -> Vec<O::Elem> {
    let m = out_len(xs.len(), 2);
    let mut out = Vec::with_capacity(m);
    for i in 0..m {
        out.push(op.combine(xs[i], xs[i + 1]));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::{AddOp, ConvPair, MaxOp, MinOp, MulOp, Pair};
    use crate::sliding::sliding_naive;

    #[test]
    fn matches_naive_add_all_window_sizes() {
        let xs: Vec<f32> = (0..257).map(|i| ((i * 37 % 101) as f32) * 0.1 - 5.0).collect();
        for w in 1..=40 {
            let got = sliding_flat_tree(AddOp::<f32>::new(), &xs, w);
            let want = sliding_naive(AddOp::<f32>::new(), &xs, w);
            assert_eq!(got.len(), want.len(), "w={w}");
            for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()), "w={w} idx={i}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn matches_naive_max_min_exact() {
        let xs: Vec<f32> = (0..300).map(|i| ((i * 89 % 211) as f32) - 100.0).collect();
        for w in [2usize, 3, 5, 7, 8, 13, 16, 31, 33, 64, 100] {
            assert_eq!(
                sliding_flat_tree(MaxOp::<f32>::new(), &xs, w),
                sliding_naive(MaxOp::<f32>::new(), &xs, w),
                "max w={w}"
            );
            assert_eq!(
                sliding_flat_tree(MinOp::<f32>::new(), &xs, w),
                sliding_naive(MinOp::<f32>::new(), &xs, w),
                "min w={w}"
            );
        }
    }

    #[test]
    fn matches_naive_mul() {
        let xs: Vec<f32> = (0..120).map(|i| 1.0 + 0.02 * ((i % 7) as f32)).collect();
        for w in [3usize, 6, 11, 17] {
            let got = sliding_flat_tree(MulOp::<f32>::new(), &xs, w);
            let want = sliding_naive(MulOp::<f32>::new(), &xs, w);
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() < 1e-3 * b.abs(), "w={w}");
            }
        }
    }

    #[test]
    fn noncommutative_pairs_supported() {
        let xs: Vec<Pair> = (0..90)
            .map(|i| Pair::new(1.0 + 0.03 * ((i % 5) as f32), 0.1 * (i as f32) - 4.0))
            .collect();
        for w in [2usize, 3, 5, 6, 7, 12] {
            let got = sliding_flat_tree(ConvPair, &xs, w);
            let want = sliding_naive(ConvPair, &xs, w);
            for (g, t) in got.iter().zip(&want) {
                assert!(
                    (g.u - t.u).abs() < 1e-3 && (g.v - t.v).abs() < 1e-3,
                    "w={w}: {g:?} vs {t:?}"
                );
            }
        }
    }

    #[test]
    fn edge_cases() {
        let xs = [1f32, 2.0, 3.0];
        assert!(sliding_flat_tree(AddOp::<f32>::new(), &xs, 4).is_empty());
        assert_eq!(sliding_flat_tree(AddOp::<f32>::new(), &xs, 1), xs.to_vec());
        assert_eq!(sliding_w2(AddOp::<f32>::new(), &xs), vec![3.0, 5.0]);
        assert!(sliding_w2(AddOp::<f32>::new(), &xs[..1]).is_empty());
    }
}
