//! Paper Algorithm 2 — *Vector Input*.
//!
//! The input arrives packed `P` elements per load. Per iteration the
//! register `X1` of *capped prefix sums* (windows growing to size `w`,
//! then sliding) is combined with the carry register `Y` of suffix sums
//! from the previous iteration, emitting `P` outputs at once:
//!
//! ```text
//! Y[l] = x_{i-w+1+l} ⊕ … ⊕ x_{i-1}      l < w-1   (carry invariant)
//! X1[j] = x_{i+max(0,j-w+1)} ⊕ … ⊕ x_{i+j}        (capped prefix)
//! out[j] = Y[j] ⊕ X1[j]                            (P outputs)
//! Y' [l] = x_{i+P-w+1+l} ⊕ … ⊕ x_{i+P-1}          (new carry = suffixes)
//! ```
//!
//! Linear variant: `X1` is built with `w−1` shifted combines →
//! `O(N·w/P)`. Log variant: `X1` and the carry are built with the
//! block-scan decomposition of [3] in `⌈log₂ w⌉` sweeps → `O(N·log w/P)`
//! (associative `⊕` required). Speedups `O(P/w)` → `O(P/log w)`, the
//! paper's headline complexity claims.

use crate::ops::AssocOp;
use crate::simd::{VecReg, MAX_LANES};

use super::{out_len, sliding_scalar_input};

/// Build the capped-prefix register `X1` from `X` with `w-1` shifted
/// combines (the linear, any-monoid construction).
///
/// `X1[j] = X[max(0,j-w+1)] ⊕ … ⊕ X[j]`, accumulated left-to-right so
/// non-commutative operators are safe: iterate tap `k = w-1 … 0`, each
/// step appending `X[j-k]`... wait, ordering: we must combine the
/// *earliest* element first, so we start from the slid copy with the
/// largest backward offset and fold toward offset 0.
fn capped_prefix_linear<O: AssocOp>(op: O, x: &VecReg<O::Elem>, w: usize) -> VecReg<O::Elem> {
    let p = x.width();
    let id = op.identity();
    // acc[j] starts as the farthest-back contribution X[j-(w-1)] (identity
    // where j < w-1), then folds X[j-k] for k = w-2 … 0 on the right.
    let idreg = VecReg::splat(p, id);
    let mut acc = VecReg::slide(&idreg, x, p.saturating_sub(w - 1));
    // ^ slide(id, X, p-(w-1)): lane j = X[j-(w-1)] for j ≥ w-1, id below.
    for k in (0..w - 1).rev() {
        let shifted = VecReg::slide(&idreg, x, p - k);
        acc.combine_assign(op, &shifted);
    }
    acc
}

/// Log-depth capped-prefix: doubling sweeps building windows of size
/// `2^t` ending at each lane, then a binary-decomposition fold for
/// non-power-of-two `w`. Requires associativity (always true for
/// [`AssocOp`]); uses the idempotence shortcut when available.
fn capped_prefix_log<O: AssocOp>(op: O, x: &VecReg<O::Elem>, w: usize) -> VecReg<O::Elem> {
    let p = x.width();
    let id = op.identity();
    let idreg = VecReg::splat(p, id);
    debug_assert!(w >= 1 && w <= p);
    if w == 1 {
        return x.clone();
    }
    // d[t]: lane j holds X[j-2^t+1 ..= j] (identity-padded below lane 0).
    let mut win = x.clone(); // window size 1
    let mut size = 1usize;
    let t_max = (w as f64).log2().floor() as u32;
    let target = 1usize << t_max;
    while size < target {
        // win2[j] = win[j-size] ⊕ win[j]
        let shifted = VecReg::slide(&idreg, &win, p - size);
        let mut win2 = shifted;
        win2.combine_assign(op, &win);
        win = win2;
        size *= 2;
    }
    if size == w {
        return win;
    }
    if op.is_idempotent() {
        // Overlapping union covers size w exactly for idempotent ops:
        // [j-w+1, j-w+size] ∪ [j-size+1, j] = [j-w+1, j] since 2·size ≥ w.
        let shifted = VecReg::slide(&idreg, &win, p - (w - size));
        let mut out = shifted;
        out.combine_assign(op, &win);
        return out;
    }
    // General associative: fold the remaining w-size elements using the
    // binary decomposition of (w - size) over the power-of-two windows we
    // can rebuild on the way down. Simpler equivalent: recurse.
    let rest = capped_prefix_log(op, x, w - size);
    // out[j] = rest[j-size] ⊕ win[j]  (earlier block ⊕ later block)
    let shifted_rest = VecReg::slide(&idreg, &rest, p - size);
    let mut out = shifted_rest;
    out.combine_assign(op, &win);
    out
}

fn vector_input_impl<O: AssocOp>(
    op: O,
    xs: &[O::Elem],
    w: usize,
    p: usize,
    log_variant: bool,
) -> Vec<O::Elem> {
    // The vector algorithms require w ≤ P (paper precondition P > w).
    if w > p || w > MAX_LANES || w <= 1 {
        return sliding_scalar_input(op, xs, w, p);
    }
    let n = xs.len();
    let m = out_len(n, w);
    // alloc-ok: Vec-returning algorithm (no `_into` form yet; the plan
    // run paths reach vector-input only through run_serial_into's copy arm).
    let mut out = vec![op.identity(); m];
    if m == 0 {
        return out;
    }
    let id = op.identity();

    // Carry register: Y[l] = x_l ⊕ … ⊕ x_{w-2} initially (suffixes of the
    // first w-1 elements), identity in lanes ≥ w-1.
    let mut y = VecReg::splat(p, id);
    for l in 0..w - 1 {
        let mut acc = op.identity();
        for &x in &xs[l..w - 1] {
            acc = op.combine(acc, x);
        }
        y.set(l, acc);
    }

    let mut i = w - 1; // input cursor: iteration consumes x_i .. x_{i+P-1}
    let mut emitted = 0usize;
    while emitted < m {
        let take = p.min(n - i);
        let x = VecReg::load(p, &xs[i..i + take], id);
        let x1 = if log_variant {
            capped_prefix_log(op, &x, w)
        } else {
            capped_prefix_linear(op, &x, w)
        };
        // out[j] = Y[j] ⊕ X1[j]
        let mut o = y.clone();
        o.combine_assign(op, &x1);
        let emit = take.min(m - emitted);
        o.store(&mut out[emitted..emitted + emit]);
        emitted += emit;

        // New carry: suffix sums of the last w-1 loaded elements,
        // Y'[l] = X[take-w+1+l] ⊕ … ⊕ X[take-1]. Built log-depth in
        // register via suffix_scan (associative) or linearly otherwise —
        // both are O(w) lanes of the register, matching the paper's Y1.
        let mut carry = x.clone();
        if take >= w {
            carry.suffix_scan_inclusive(op, take + 1 - w, take);
            let mut y2 = VecReg::splat(p, id);
            for l in 0..w - 1 {
                y2.set(l, carry.get(take + 1 - w + l));
            }
            y = y2;
        } else {
            // Tail iteration shorter than a register; nothing left to emit
            // after this pass, carry unused.
        }
        i += take;
    }
    out
}

/// Algorithm 2 (linear in-register construction): `O(N·w/P)`, speedup
/// `O(P/w)`, any monoid.
pub fn sliding_vector_input<O: AssocOp>(op: O, xs: &[O::Elem], w: usize, p: usize) -> Vec<O::Elem> {
    vector_input_impl(op, xs, w, p, false)
}

/// Algorithm 2 with the log-depth prefix construction of [3]:
/// `O(N·log w/P)`, speedup `O(P/log w)`, associative `⊕`.
pub fn sliding_vector_input_log<O: AssocOp>(
    op: O,
    xs: &[O::Elem],
    w: usize,
    p: usize,
) -> Vec<O::Elem> {
    vector_input_impl(op, xs, w, p, true)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::{AddOp, ConvPair, MaxOp, MinOp, MulOp, Pair};
    use crate::sliding::sliding_naive;

    fn check_f32<O: AssocOp<Elem = f32>>(op: O, xs: &[f32], w: usize, p: usize, log: bool) {
        let got = vector_input_impl(op, xs, w, p, log);
        let want = sliding_naive(op, xs, w);
        assert_eq!(got.len(), want.len(), "len w={w} p={p} log={log}");
        for (i, (g, t)) in got.iter().zip(&want).enumerate() {
            assert!(
                (g - t).abs() <= 1e-3 * (1.0 + t.abs()),
                "w={w} p={p} log={log} idx={i}: {g} vs {t}"
            );
        }
    }

    #[test]
    fn linear_matches_naive_add_sweep() {
        let xs: Vec<f32> = (0..137).map(|i| ((i * 17 % 29) as f32) * 0.3 - 4.0).collect();
        for p in [8usize, 16, 32] {
            for w in [2usize, 3, 4, 5, 7, 8] {
                if w < p {
                    check_f32(AddOp::<f32>::new(), &xs, w, p, false);
                }
            }
        }
    }

    #[test]
    fn log_matches_naive_add_sweep() {
        let xs: Vec<f32> = (0..137).map(|i| ((i * 11 % 37) as f32) * 0.2 - 3.0).collect();
        for p in [16usize, 32, 64] {
            for w in [2usize, 3, 4, 6, 8, 11, 15, 16] {
                if w < p {
                    check_f32(AddOp::<f32>::new(), &xs, w, p, true);
                }
            }
        }
    }

    #[test]
    fn log_idempotent_path_max_min() {
        let xs: Vec<f32> = (0..200).map(|i| ((i * 73 % 101) as f32) - 50.0).collect();
        for w in [2usize, 3, 5, 6, 7, 12, 13] {
            check_f32(MaxOp::<f32>::new(), &xs, w, 16, true);
            check_f32(MinOp::<f32>::new(), &xs, w, 16, true);
        }
    }

    #[test]
    fn product_windows_nonzero() {
        let xs: Vec<f32> = (0..60).map(|i| 1.0 + 0.01 * (i % 7) as f32).collect();
        for w in [2usize, 5, 9] {
            check_f32(MulOp::<f32>::new(), &xs, w, 16, false);
            check_f32(MulOp::<f32>::new(), &xs, w, 16, true);
        }
    }

    #[test]
    fn noncommutative_pairs_both_variants() {
        let xs: Vec<Pair> = (0..70)
            .map(|i| Pair::new(1.0 + 0.03 * (i % 5) as f32, 0.2 * i as f32 - 7.0))
            .collect();
        for w in [2usize, 3, 5, 8] {
            for log in [false, true] {
                let got = vector_input_impl(ConvPair, &xs, w, 16, log);
                let want = sliding_naive(ConvPair, &xs, w);
                assert_eq!(got.len(), want.len());
                for (g, t) in got.iter().zip(&want) {
                    assert!(
                        (g.u - t.u).abs() < 1e-3 && (g.v - t.v).abs() < 1e-3,
                        "w={w} log={log}: {g:?} vs {t:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn input_not_multiple_of_p() {
        for n in [17usize, 31, 33, 63, 65, 100] {
            let xs: Vec<f32> = (0..n).map(|i| i as f32).collect();
            check_f32(AddOp::<f32>::new(), &xs, 4, 16, false);
            check_f32(AddOp::<f32>::new(), &xs, 4, 16, true);
        }
    }

    #[test]
    fn w_equal_p_falls_back() {
        let xs: Vec<f32> = (0..64).map(|i| i as f32).collect();
        check_f32(AddOp::<f32>::new(), &xs, 16, 16, false);
    }
}
