//! Paper Algorithm 4 — *Vector Slide*.
//!
//! The simplest vector formulation: keep the previous register `Y` and
//! the current register `Y1`; every window sum ending inside `Y1` is the
//! fold of `w` slid views of the pair (`Slide` = SVE `EXT`, RISC-V
//! `vslideup/down`, AVX-512 `vperm*2ps`):
//!
//! ```text
//! for k = w-1 … 0:   X ⊕= Slide(Y, Y1, P-k)     # lane j = x_{i+j-k}
//! emit X[0 … P-1]  =  y_{i-w+1} … y_{i+P-w}
//! ```
//!
//! (The paper iterates k ascending; we fold descending so the earliest
//! element enters the accumulator first, making the algorithm valid for
//! non-commutative operators such as [`ConvPair`].)
//!
//! `sliding_vector_slide_tree` replaces the `w−1`-step inner loop with a
//! doubling ladder — `⌈log₂ w⌉` slide+combine steps per register, the
//! paper's "inner loop could be replaced by the parallel reduction for
//! maximum parallel speedup".
//!
//! [`ConvPair`]: crate::ops::ConvPair

use crate::ops::AssocOp;
use crate::simd::{VecReg, MAX_LANES};

use super::{out_len, sliding_scalar_input_into};

/// Algorithm 4, linear inner loop: `O(N·w/P)`, any monoid.
pub fn sliding_vector_slide<O: AssocOp>(op: O, xs: &[O::Elem], w: usize, p: usize) -> Vec<O::Elem> {
    // alloc-ok: Vec-returning wrapper; sliding_vector_slide_into is the hot path.
    let mut out = vec![op.identity(); out_len(xs.len(), w)];
    sliding_vector_slide_into(op, xs, w, p, &mut out);
    out
}

/// [`sliding_vector_slide`] writing into a caller-provided buffer of
/// length [`out_len`]`(xs.len(), w)`. Every element is overwritten.
pub fn sliding_vector_slide_into<O: AssocOp>(
    op: O,
    xs: &[O::Elem],
    w: usize,
    p: usize,
    out: &mut [O::Elem],
) {
    if w > p || w > MAX_LANES || w <= 1 {
        return sliding_scalar_input_into(op, xs, w, p, out);
    }
    let n = xs.len();
    let m = out_len(n, w);
    assert_eq!(out.len(), m, "dst length");
    if m == 0 {
        return;
    }
    crate::check::poison(out);
    let id = op.identity();

    // Pre-pad the stream with w-1 identities so the first register pair
    // already has a full backward horizon: y holds x_{i-P}..x_{i-1}.
    let mut y = VecReg::splat(p, id);
    let mut i = 0usize; // index of the first element in the current load
    let mut emitted = 0usize;
    while emitted < m {
        let take = p.min(n - i);
        let y1 = VecReg::load(p, &xs[i..i + take], id);
        // Fold slid views, earliest offset first.
        let mut x = VecReg::slide(&y, &y1, p - (w - 1));
        for k in (0..w - 1).rev() {
            let v = VecReg::slide(&y, &y1, p - k);
            x.combine_assign(op, &v);
        }
        // Lane j holds the window ending at x_{i+j}, i.e. y_{i+j-w+1}.
        // Valid outputs need i+j-w+1 ≥ 0 and i+j ≤ n-1.
        let lane_lo = if i == 0 { w - 1 } else { 0 };
        let start = i + lane_lo + 1 - w; // output index of lane_lo
        let avail = take.saturating_sub(lane_lo);
        let emit = avail.min(m - start);
        for j in 0..emit {
            out[start + j] = x.get(lane_lo + j);
        }
        emitted = start + emit;
        y = y1;
        i += take;
        if take < p {
            break;
        }
    }
    debug_assert_eq!(emitted, m);
    crate::check::assert_no_poison(out, "sliding_vector_slide_into");
}

/// Algorithm 4 with a log-depth doubling ladder: `O(N·log w/P)`,
/// associative `⊕` (idempotent shortcut for max/min).
///
/// Level `t` maintains a register pair `(prev_t, cur_t)` where lane `j`
/// holds the window of size `2^t` ending at stream position `j` of that
/// register. Doubling: `cur_{t+1} = Slide(prev_t, cur_t, P−2^t) ⊕ cur_t`.
/// For non-power-of-two `w = 2^T + r` the result folds the size-`r`
/// ladder output (computed the same way) slid back by `2^T`; idempotent
/// operators instead overlap two size-`2^T` windows.
pub fn sliding_vector_slide_tree<O: AssocOp>(
    op: O,
    xs: &[O::Elem],
    w: usize,
    p: usize,
) -> Vec<O::Elem> {
    // alloc-ok: Vec-returning wrapper; the `_into` form is the hot path.
    let mut out = vec![op.identity(); out_len(xs.len(), w)];
    sliding_vector_slide_tree_into(op, xs, w, p, &mut out);
    out
}

/// [`sliding_vector_slide_tree`] writing into a caller-provided buffer
/// of length [`out_len`]`(xs.len(), w)`. Every element is overwritten.
pub fn sliding_vector_slide_tree_into<O: AssocOp>(
    op: O,
    xs: &[O::Elem],
    w: usize,
    p: usize,
    out: &mut [O::Elem],
) {
    if w > p || w > MAX_LANES || w <= 1 {
        return sliding_scalar_input_into(op, xs, w, p, out);
    }
    // Required ladder sizes: the binary decomposition of w, folded from
    // the most significant chunk (earliest stream positions) down.
    // window_w(end j) = window_hi(end j - lo_total) ⊕ window_rest(end j).
    // We precompute for each register the full ladder up to 2^T and reuse
    // sub-windows for the remainder chain.
    let n = xs.len();
    let m = out_len(n, w);
    assert_eq!(out.len(), m, "dst length");
    if m == 0 {
        return;
    }
    crate::check::poison(out);
    let id = op.identity();

    // Decompose w into chunk sizes (powers of two, descending), e.g.
    // w=13 → [8,4,1]. Idempotent ops use two overlapping chunks instead.
    let t_max = usize::BITS - 1 - w.leading_zeros(); // floor(log2 w)
    let top = 1usize << t_max;
    let chunks: Vec<usize> = if w == top {
        vec![top] // alloc-ok: O(log w) chunk list
    } else if op.is_idempotent() {
        vec![top, top] // alloc-ok: two overlapping windows of size 2^T
    } else {
        let mut c = Vec::new(); // alloc-ok: O(log w) chunk list
        let rem = w;
        let mut bit = top;
        while bit > 0 {
            if rem & bit != 0 {
                c.push(bit);
            }
            bit >>= 1;
        }
        debug_assert_eq!(c.iter().sum::<usize>(), w);
        c
    };

    // alloc-ok: O(log w) register ladder scratch (per level t).
    let mut prev_ladder: Vec<VecReg<O::Elem>> = Vec::new();
    let mut i = 0usize;
    let mut emitted = 0usize;
    while emitted < m {
        let take = p.min(n - i);
        let cur0 = VecReg::load(p, &xs[i..i + take], id);
        // Build the doubling ladder for the current register.
        // alloc-ok: O(log w) register ladder scratch.
        let mut cur_ladder = Vec::with_capacity(t_max as usize + 1);
        cur_ladder.push(cur0.clone());
        for t in 0..t_max as usize {
            let size = 1usize << t;
            let prev_t = prev_ladder
                .get(t)
                .cloned()
                .unwrap_or_else(|| VecReg::splat(p, id));
            let slid = VecReg::slide(&prev_t, &cur_ladder[t], p - size);
            let mut next = slid;
            next.combine_assign(op, &cur_ladder[t]);
            cur_ladder.push(next);
        }

        // Fold the chunks: window of size w ending at lane j.
        // Offsets accumulate from the tail: the last chunk ends at j, the
        // one before it ends at j - (sum of later chunk sizes)…
        let level_of = |size: usize| size.trailing_zeros() as usize;
        let mut offset = 0usize; // distance from window end to chunk end
        let mut acc: Option<VecReg<O::Elem>> = None;
        if op.is_idempotent() && w != top {
            // chunks = [top, top] overlapping: ends at j-(w-top) and j.
            let a = &cur_ladder[level_of(top)];
            let prev_a = prev_ladder
                .get(level_of(top))
                .cloned()
                .unwrap_or_else(|| VecReg::splat(p, id));
            let mut v = VecReg::slide(&prev_a, a, p - (w - top));
            v.combine_assign(op, a);
            acc = Some(v);
        } else {
            for &size in chunks.iter().rev() {
                let lvl = level_of(size);
                let reg = &cur_ladder[lvl];
                let prev_reg = prev_ladder
                    .get(lvl)
                    .cloned()
                    .unwrap_or_else(|| VecReg::splat(p, id));
                let slid = if offset == 0 {
                    reg.clone()
                } else {
                    VecReg::slide(&prev_reg, reg, p - offset)
                };
                acc = Some(match acc {
                    // Earlier chunk (larger offset) goes on the LEFT.
                    Some(a) => {
                        let mut s = slid;
                        s.combine_assign(op, &a);
                        s
                    }
                    None => slid,
                });
                offset += size;
            }
        }
        let x = acc.unwrap();

        let lane_lo = if i == 0 { w - 1 } else { 0 };
        let start = i + lane_lo + 1 - w;
        let avail = take.saturating_sub(lane_lo);
        let emit = avail.min(m - start);
        for j in 0..emit {
            out[start + j] = x.get(lane_lo + j);
        }
        emitted = start + emit;
        prev_ladder = cur_ladder;
        i += take;
        if take < p {
            break;
        }
    }
    debug_assert_eq!(emitted, m);
    crate::check::assert_no_poison(out, "sliding_vector_slide_tree_into");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::{AddOp, ConvPair, MaxOp, MinOp, Pair};
    use crate::sliding::sliding_naive;

    fn check<O: AssocOp<Elem = f32>>(op: O, xs: &[f32], w: usize, p: usize, tree: bool) {
        let got = if tree {
            sliding_vector_slide_tree(op, xs, w, p)
        } else {
            sliding_vector_slide(op, xs, w, p)
        };
        let want = sliding_naive(op, xs, w);
        assert_eq!(got.len(), want.len(), "len w={w} p={p} tree={tree}");
        for (idx, (g, t)) in got.iter().zip(&want).enumerate() {
            assert!(
                (g - t).abs() <= 1e-3 * (1.0 + t.abs()),
                "w={w} p={p} tree={tree} n={} idx={idx}: {g} vs {t}",
                xs.len()
            );
        }
    }

    #[test]
    fn linear_matches_naive_sweep() {
        let xs: Vec<f32> = (0..211).map(|i| ((i * 31 % 53) as f32) * 0.2 - 5.0).collect();
        for p in [8usize, 16, 32] {
            for w in [2usize, 3, 5, 7] {
                if w < p {
                    check(AddOp::<f32>::new(), &xs, w, p, false);
                }
            }
        }
    }

    #[test]
    fn tree_matches_naive_sweep_pow2_and_not() {
        let xs: Vec<f32> = (0..211).map(|i| ((i * 13 % 61) as f32) * 0.3 - 9.0).collect();
        for w in [2usize, 3, 4, 5, 6, 7, 8, 11, 13, 15] {
            check(AddOp::<f32>::new(), &xs, w, 16, true);
        }
    }

    #[test]
    fn tree_idempotent_overlap_path() {
        let xs: Vec<f32> = (0..301).map(|i| ((i * 89 % 127) as f32) - 60.0).collect();
        for w in [3usize, 5, 6, 7, 9, 12, 15] {
            check(MaxOp::<f32>::new(), &xs, w, 16, true);
            check(MinOp::<f32>::new(), &xs, w, 16, true);
        }
    }

    #[test]
    fn ragged_lengths_both() {
        for n in [4usize, 16, 17, 31, 32, 33, 63, 64, 65, 100] {
            let xs: Vec<f32> = (0..n).map(|i| i as f32 * 0.7 - 2.0).collect();
            if n >= 4 {
                check(AddOp::<f32>::new(), &xs, 4, 16, false);
                check(AddOp::<f32>::new(), &xs, 4, 16, true);
            }
        }
    }

    #[test]
    fn noncommutative_pairs_both() {
        let xs: Vec<Pair> = (0..77)
            .map(|i| Pair::new(1.0 + 0.04 * (i % 6) as f32, 0.15 * i as f32 - 3.0))
            .collect();
        for w in [2usize, 3, 5, 6] {
            for tree in [false, true] {
                let got = if tree {
                    sliding_vector_slide_tree(ConvPair, &xs, w, 16)
                } else {
                    sliding_vector_slide(ConvPair, &xs, w, 16)
                };
                let want = sliding_naive(ConvPair, &xs, w);
                for (g, t) in got.iter().zip(&want) {
                    assert!(
                        (g.u - t.u).abs() < 1e-3 && (g.v - t.v).abs() < 1e-3,
                        "w={w} tree={tree}"
                    );
                }
            }
        }
    }

    #[test]
    fn large_w_falls_back() {
        let xs: Vec<f32> = (0..100).map(|i| i as f32).collect();
        check(AddOp::<f32>::new(), &xs, 20, 16, false); // w > p → fallback
        check(AddOp::<f32>::new(), &xs, 20, 16, true);
    }
}
