//! Paper Algorithm 3 — *Ping Pong*.
//!
//! Algorithm 2 wastes `P−(w−1)` lanes of its suffix register. Ping Pong
//! loads *two* registers per iteration and lets both the suffix-sum and
//! prefix-sum registers emit output lanes, producing `2P−w+1` outputs per
//! iteration. No asymptotic change, but the paper measures it 30–50 %
//! faster in practice. The cost: loads stride by `2P−w+1`, which is not
//! `P`-aligned — exactly the boundary-handling nuisance §3 warns about.
//!
//! Per iteration over chunk `x_i … x_{i+2P-1}` (registers `Y`, `X`):
//!
//! ```text
//! Y1[j] = Y[j] ⊕ … ⊕ Y[min(j+w-1, P-1)]     capped suffix sums of Y
//! emit y_i … y_{i+P-w}      = Y1[0 … P-w]    (windows inside Y)
//! Y1 ≪ (P-w+1)                               (truncated suffixes to front)
//! X1[j] = X[max(0, j-w+1)] ⊕ … ⊕ X[j]       capped prefix sums of X
//! emit y_{i+P-w+1} … y_{i+2P-w} = (Y1 ⊕ X1)[0 … P-1]  (boundary + inside X)
//! ```

use crate::ops::AssocOp;
use crate::simd::{VecReg, MAX_LANES};

use super::{out_len, sliding_scalar_input};

/// Capped suffix sums: `out[j] = X[j] ⊕ … ⊕ X[min(j+w-1, hi-1)]`,
/// lanes `hi..` identity. Linear construction (`w−1` slides), safe for
/// non-commutative `⊕` (later elements folded on the right).
fn capped_suffix_linear<O: AssocOp>(
    op: O,
    x: &VecReg<O::Elem>,
    w: usize,
    hi: usize,
) -> VecReg<O::Elem> {
    let p = x.width();
    let id = op.identity();
    let idreg = VecReg::splat(p, id);
    let mut acc = x.clone();
    // Mask lanes ≥ hi to identity.
    for j in hi..p {
        acc.set(j, id);
    }
    let masked = acc.clone();
    for k in 1..w {
        // shifted[j] = X[j+k] (identity beyond hi) — fold later elements
        // onto the right of the accumulator.
        let shifted = VecReg::slide(&masked, &idreg, k);
        acc.combine_assign(op, &shifted);
    }
    acc
}

/// Algorithm 3. Any monoid; `O(N·w/P)` with a ~2× lower loop overhead
/// than Algorithm 2 (two emits per two loads, no wasted suffix lanes).
pub fn sliding_ping_pong<O: AssocOp>(op: O, xs: &[O::Elem], w: usize, p: usize) -> Vec<O::Elem> {
    if w > p || w > MAX_LANES || w <= 1 {
        return sliding_scalar_input(op, xs, w, p);
    }
    let n = xs.len();
    let m = out_len(n, w);
    // alloc-ok: Vec-returning algorithm (no `_into` form yet; the plan
    // run paths reach ping-pong only through run_serial_into's copy arm).
    let mut out = vec![op.identity(); m];
    if m == 0 {
        return out;
    }
    let id = op.identity();
    let step = 2 * p - w + 1; // outputs per full iteration

    let mut i = 0usize; // window-start cursor
    while i < m {
        // Y covers x_i .. x_{i+P-1}; X covers the next P elements.
        let take_y = p.min(n - i);
        let y = VecReg::load(p, &xs[i..i + take_y], id);
        let x_lo = i + take_y;
        let take_x = if x_lo < n { p.min(n - x_lo) } else { 0 };
        let x = if take_x > 0 {
            VecReg::load(p, &xs[x_lo..x_lo + take_x], id)
        } else {
            VecReg::splat(p, id)
        };

        // Phase 1: windows fully inside Y — capped suffix sums.
        let mut y1 = capped_suffix_linear(op, &y, w, take_y);
        let full_in_y = take_y.saturating_sub(w - 1); // lanes 0..=take_y-w hold full windows
        let emit1 = full_in_y.min(m - i);
        for j in 0..emit1 {
            out[i + j] = y1.get(j);
        }

        // Phase 2: boundary windows (truncated Y-suffixes ⊕ X-prefixes)
        // plus windows fully inside X.
        if take_x > 0 {
            y1.shift_left(full_in_y, id); // truncated suffixes to lanes 0..w-2
            let x1 = capped_prefix_linear_pp(op, &x, w, take_x);
            let mut o = y1;
            o.combine_assign(op, &x1);
            let base = i + full_in_y; // first boundary window start
            let emit2 = (take_x).min(m.saturating_sub(base));
            for j in 0..emit2 {
                out[base + j] = o.get(j);
            }
        }
        i += step.min(m - i).max(1);
        // Full iterations advance by exactly `step`; the final ragged
        // iteration just terminates the loop.
        if take_y < p || take_x < p {
            break;
        }
    }

    // Ragged tail (input not a multiple of the 2P−w+1 stride): finish with
    // the scalar-input recurrence over the remaining suffix. This is the
    // paper's "two memory loads per iteration present a challenge while
    // implementing boundary conditions" caveat made concrete.
    if i < m {
        let tail_start = i;
        let tail = sliding_scalar_input(op, &xs[tail_start..], w, p);
        out[tail_start..m].copy_from_slice(&tail[..m - tail_start]);
    }
    out
}

/// Capped prefix sums over the first `hi` lanes (identity-padded), linear.
fn capped_prefix_linear_pp<O: AssocOp>(
    op: O,
    x: &VecReg<O::Elem>,
    w: usize,
    hi: usize,
) -> VecReg<O::Elem> {
    let p = x.width();
    let id = op.identity();
    let idreg = VecReg::splat(p, id);
    let mut masked = x.clone();
    for j in hi..p {
        masked.set(j, id);
    }
    let mut acc = VecReg::slide(&idreg, &masked, p - (w - 1));
    for k in (0..w - 1).rev() {
        let shifted = VecReg::slide(&idreg, &masked, p - k);
        acc.combine_assign(op, &shifted);
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::{AddOp, ConvPair, MaxOp, Pair};
    use crate::sliding::sliding_naive;

    fn check<O: AssocOp<Elem = f32>>(op: O, xs: &[f32], w: usize, p: usize) {
        let got = sliding_ping_pong(op, xs, w, p);
        let want = sliding_naive(op, xs, w);
        assert_eq!(got.len(), want.len(), "len w={w} p={p} n={}", xs.len());
        for (i, (g, t)) in got.iter().zip(&want).enumerate() {
            assert!(
                (g - t).abs() <= 1e-3 * (1.0 + t.abs()),
                "w={w} p={p} n={} idx={i}: {g} vs {t}",
                xs.len()
            );
        }
    }

    #[test]
    fn matches_naive_add_sweep() {
        let xs: Vec<f32> = (0..259).map(|i| ((i * 19 % 41) as f32) * 0.25 - 5.0).collect();
        for p in [8usize, 16, 32] {
            for w in [2usize, 3, 5, 7] {
                if w < p {
                    check(AddOp::<f32>::new(), &xs, w, p);
                }
            }
        }
    }

    #[test]
    fn matches_naive_max() {
        let xs: Vec<f32> = (0..300).map(|i| ((i * 53 % 97) as f32) - 48.0).collect();
        for w in [2usize, 4, 6, 10] {
            check(MaxOp::<f32>::new(), &xs, w, 16);
        }
    }

    #[test]
    fn ragged_lengths() {
        for n in [5usize, 16, 17, 29, 32, 33, 61, 64, 65, 127] {
            let xs: Vec<f32> = (0..n).map(|i| i as f32 * 0.5).collect();
            check(AddOp::<f32>::new(), &xs, 3, 16);
        }
    }

    #[test]
    fn noncommutative_safe() {
        let xs: Vec<Pair> = (0..90)
            .map(|i| Pair::new(1.0 + 0.02 * (i % 9) as f32, 0.1 * i as f32 - 4.0))
            .collect();
        let got = sliding_ping_pong(ConvPair, &xs, 5, 16);
        let want = sliding_naive(ConvPair, &xs, 5);
        for (g, t) in got.iter().zip(&want) {
            assert!((g.u - t.u).abs() < 1e-3 && (g.v - t.v).abs() < 1e-3);
        }
    }
}
