//! TCP transport layer: length-prefixed f32 frames over blocking
//! sockets, hardened against abusive peers.
//!
//! Layering (see `docs/robustness.md`, "Transport & admission"):
//!
//! ```text
//!   accept loop (serve_tcp_with) ── connection cap, handle reaping
//!        │ one thread per connection
//!   frame decode (read_frame) ───── typed decode errors, idle timeout
//!        │
//!   admission (Admission) ───────── per-tenant token-bucket quotas
//!        │
//!   batcher (Coordinator) ───────── bounded queue, terminal ledger
//! ```
//!
//! Wire format (little-endian):
//!   request:  u32 n | u32 ttl_ms | n × f32     (one input row; ttl_ms 0 = no deadline)
//!   response: u8 tag | u32 n | payload
//!
//! Control frames reuse the same channel, keyed by a magic first word
//! that can never be a valid row length (row lengths are capped at
//! `1 << 22` floats; the magics sit at the top of the u32 range):
//!   open:   u32 0xFFFF_FF01 | u32 ttl_ms              → ok payload: 1 × f32 (bits = session id)
//!   step:   u32 0xFFFF_FF02 | u32 id | u32 n | n × f32 → ok payload: newly final output samples
//!   close:  u32 0xFFFF_FF03 | u32 id                  → ok payload: empty
//!   stats:  u32 0xFFFF_FF04                           → ok payload: u32 *byte* length | utf8
//!                                                       `name value` lines (one metric per line)
//!   tenant: u32 0xFFFF_FF05 | u32 tenant              → ok payload: empty; tags every later
//!                                                       frame on this connection (0 = anonymous)
//!
//! Response tags (see [`super::ServeError::wire_code`] /
//! [`super::SubmitError::wire_code`] — payload is a utf8 message for
//! every non-zero tag):
//!   0 ok (payload: n × f32 output row; u32 *byte* length + utf8 for stats)
//!   1 engine error          2 bad input shape
//!   3 shed: queue full      4 shed: deadline expired
//!   5 shed: draining        6 shed: worker lost
//!   7 coordinator closed    8 shed: connection limit
//!   9 shed: quota exceeded  10 malformed frame (decode error)
//!
//! One thread per connection (the workload is CPU-bound inference; the
//! batcher serializes actual compute, so connection threads just park).
//! Abuse containment: the accept loop reaps finished handler threads
//! and refuses over-capacity connections with wire code 8; reads carry
//! the configured idle timeout so a slow-loris peer stalling mid-frame
//! gets its connection dropped (typed as a decode error) instead of
//! pinning a thread; oversized length prefixes and unknown magics get
//! wire code 10 and a close, never a listener death. The decode row is
//! double-buffered with the submitted request (the worker hands the
//! buffer back through the response slot), so the steady-state loop is
//! allocation-free.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::{Admission, Coordinator, QuotaConfig, ServeError, Shed};
use crate::config::ServeConfig;
use crate::telemetry::{Counter, Gauge, Histogram};

/// Per-connection socket *write* timeout. Reads use the configured idle
/// timeout; writes always carry this cap so a peer that stops draining
/// its receive buffer can't pin a handler thread forever.
const WRITE_TIMEOUT: Duration = Duration::from_secs(30);

/// Frames are capped at this many floats (16 MiB); larger length
/// prefixes are rejected as malformed without allocating.
const MAX_FRAME_FLOATS: u32 = 1 << 22;

/// Start of the reserved control-magic range. A first word at or above
/// this that is not a known magic is a protocol error (wire code 10),
/// not an oversized row.
const CONTROL_BASE: u32 = 0xFFFF_FF00;

/// Magic first word of a session-open frame. All control magics exceed
/// the `1 << 22` row-length cap, so they can never collide with an
/// inference frame's length prefix.
pub const SESSION_OPEN_MAGIC: u32 = 0xFFFF_FF01;
/// Magic first word of a session-step frame.
pub const SESSION_STEP_MAGIC: u32 = 0xFFFF_FF02;
/// Magic first word of a session-close frame.
pub const SESSION_CLOSE_MAGIC: u32 = 0xFFFF_FF03;
/// Magic first word of a stats frame: the response is a utf8 text
/// export of the coordinator + transport counters.
pub const STATS_MAGIC: u32 = 0xFFFF_FF04;
/// Magic first word of a tenant frame: sets the tenant id metered by
/// admission for every subsequent frame on this connection.
pub const TENANT_MAGIC: u32 = 0xFFFF_FF05;

/// Wire code for a malformed frame (oversized length prefix, unknown
/// magic, truncation mid-frame). The connection is closed after this
/// response — the stream cannot be resynchronized.
pub const WIRE_DECODE_ERROR: u8 = 10;

/// Transport-layer knobs, derived from [`ServeConfig`] in production
/// (`TransportConfig::from_serve`) or defaulted for tests.
#[derive(Clone, Debug)]
pub struct TransportConfig {
    /// Hard cap on concurrently served connections; accepts beyond it
    /// get wire code 8 ([`Shed::ConnLimit`]) and an immediate close.
    pub max_connections: usize,
    /// Per-connection read timeout. A peer idle (or stalled mid-frame)
    /// longer than this gets its connection dropped. `ZERO` = never.
    pub idle_timeout: Duration,
    /// Per-tenant admission quotas (`rate_per_sec == 0` = unlimited).
    pub quota: QuotaConfig,
}

impl Default for TransportConfig {
    fn default() -> Self {
        Self {
            max_connections: 1024,
            idle_timeout: Duration::from_secs(30),
            quota: QuotaConfig::default(),
        }
    }
}

impl TransportConfig {
    pub fn from_serve(cfg: &ServeConfig) -> Self {
        Self {
            max_connections: cfg.max_connections.max(1),
            idle_timeout: Duration::from_millis(cfg.idle_timeout_ms),
            quota: QuotaConfig {
                rate_per_sec: cfg.quota_rps,
                burst: cfg.quota_burst,
            },
        }
    }
}

/// Transport-tier counters, exported over the wire by the stats frame.
/// These sit *in front of* the coordinator ledger: `conns_rejected` and
/// `quota_shed` count work refused before submission, so they are
/// intentionally not part of `CoordinatorStats::terminal()`.
#[derive(Default)]
struct TransportMetrics {
    /// Connections currently being served (gauge).
    conns_open: Gauge,
    conns_accepted: Counter,
    /// Connections refused at the capacity cap (wire code 8).
    conns_rejected: Counter,
    /// Handler join-handles held by the accept loop after the last reap
    /// (gauge; the churn regression test pins this ≤ `max_connections`).
    handles_live: Gauge,
    /// Malformed frames: oversized prefix, unknown magic, truncation,
    /// mid-frame stall. Each one closes its connection.
    decode_errors: Counter,
    /// Frames refused by per-tenant quota (wire code 9).
    quota_shed: Counter,
    /// Data-plane frames fully served (any response tag).
    frames: Counter,
    /// Wire-level latency per served frame: decode done → response
    /// written (includes queue wait + inference for data frames).
    frame_time: Histogram,
    tenants: Mutex<BTreeMap<u32, TenantCounters>>,
}

#[derive(Clone, Copy, Default)]
struct TenantCounters {
    accepted: u64,
    shed: u64,
}

impl TransportMetrics {
    fn tenant_accepted(&self, tenant: u32) {
        self.tenants.lock().unwrap().entry(tenant).or_default().accepted += 1;
    }

    fn tenant_shed(&self, tenant: u32) {
        self.tenants.lock().unwrap().entry(tenant).or_default().shed += 1;
    }
}

/// Panic-safe `conns_open` scope: incremented when a handler starts,
/// decremented on *any* exit (return, decode error, injected panic).
struct ConnGuard {
    metrics: Arc<TransportMetrics>,
}

impl ConnGuard {
    fn new(metrics: &Arc<TransportMetrics>) -> Self {
        metrics.conns_open.inc();
        Self {
            metrics: Arc::clone(metrics),
        }
    }
}

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.metrics.conns_open.dec();
    }
}

/// One decoded request frame; float payloads land in the caller's
/// reused `row` buffer.
enum Frame {
    Infer { ttl: Option<Duration> },
    Open { ttl_ms: u32 },
    Step { session: u32 },
    Close { session: u32 },
    Stats,
    Tenant { tenant: u32 },
}

/// Typed decode outcome for one frame. Everything except `Io` is a
/// per-connection condition: the handler responds (where the protocol
/// allows) and closes that connection; the listener never sees it.
enum FrameError {
    /// Clean EOF at a frame boundary — normal disconnect.
    Eof,
    /// Read timeout at a frame boundary — idle peer, close quietly.
    Idle,
    /// EOF or stall *mid-frame* (truncated frame, slow-loris partial
    /// write). No response is possible; counted as a decode error.
    Truncated(std::io::Error),
    /// Length prefix over the frame cap; responded with wire code 10.
    Oversized { n: u32, max: u32 },
    /// First word in the reserved control range but not a known magic;
    /// responded with wire code 10.
    UnknownMagic(u32),
    /// Transport failure writing/reading beyond the cases above.
    Io(std::io::Error),
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Eof => write!(f, "eof"),
            FrameError::Idle => write!(f, "idle timeout"),
            FrameError::Truncated(e) => write!(f, "truncated frame: {e}"),
            FrameError::Oversized { n, max } => {
                write!(f, "frame of {n} floats exceeds limit {max}")
            }
            FrameError::UnknownMagic(m) => write!(f, "unknown frame magic 0x{m:08X}"),
            FrameError::Io(e) => write!(f, "{e}"),
        }
    }
}

fn is_timeout(kind: std::io::ErrorKind) -> bool {
    matches!(
        kind,
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
    )
}

/// Read the first word of a frame. EOF/timeout here happen at a frame
/// boundary and are benign (disconnect / idle peer).
fn read_head_u32(stream: &mut TcpStream) -> Result<u32, FrameError> {
    let mut buf = [0u8; 4];
    match stream.read_exact(&mut buf) {
        Ok(()) => Ok(u32::from_le_bytes(buf)),
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => Err(FrameError::Eof),
        Err(e) if is_timeout(e.kind()) => Err(FrameError::Idle),
        Err(e) => Err(FrameError::Io(e)),
    }
}

/// Read a word *inside* a frame. EOF/timeout here mean the peer sent a
/// partial frame (truncation or slow-loris) — a decode error.
fn read_body_u32(stream: &mut TcpStream) -> Result<u32, FrameError> {
    let mut buf = [0u8; 4];
    match stream.read_exact(&mut buf) {
        Ok(()) => Ok(u32::from_le_bytes(buf)),
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof || is_timeout(e.kind()) => {
            Err(FrameError::Truncated(e))
        }
        Err(e) => Err(FrameError::Io(e)),
    }
}

/// Read the `n × f32` payload section into the reused buffers.
fn read_floats(
    stream: &mut TcpStream,
    n: u32,
    bytes: &mut Vec<u8>,
    row: &mut Vec<f32>,
) -> Result<(), FrameError> {
    bytes.clear();
    bytes.resize(n as usize * 4, 0);
    if let Err(e) = stream.read_exact(bytes) {
        if e.kind() == std::io::ErrorKind::UnexpectedEof || is_timeout(e.kind()) {
            return Err(FrameError::Truncated(e));
        }
        return Err(FrameError::Io(e));
    }
    row.clear();
    row.reserve(n as usize);
    for chunk in bytes.chunks_exact(4) {
        row.push(f32::from_le_bytes(chunk.try_into().unwrap()));
    }
    Ok(())
}

/// Read one request frame into the reused buffers: `bytes` holds the
/// raw payload, `row` the decoded floats.
fn read_frame(
    stream: &mut TcpStream,
    bytes: &mut Vec<u8>,
    row: &mut Vec<f32>,
) -> Result<Frame, FrameError> {
    let head = read_head_u32(stream)?;
    row.clear();
    match head {
        SESSION_OPEN_MAGIC => Ok(Frame::Open {
            ttl_ms: read_body_u32(stream)?,
        }),
        SESSION_CLOSE_MAGIC => Ok(Frame::Close {
            session: read_body_u32(stream)?,
        }),
        SESSION_STEP_MAGIC => {
            let session = read_body_u32(stream)?;
            let n = read_body_u32(stream)?;
            if n > MAX_FRAME_FLOATS {
                return Err(FrameError::Oversized {
                    n,
                    max: MAX_FRAME_FLOATS,
                });
            }
            read_floats(stream, n, bytes, row)?;
            Ok(Frame::Step { session })
        }
        STATS_MAGIC => Ok(Frame::Stats),
        TENANT_MAGIC => Ok(Frame::Tenant {
            tenant: read_body_u32(stream)?,
        }),
        m if m >= CONTROL_BASE => Err(FrameError::UnknownMagic(m)),
        n if n > MAX_FRAME_FLOATS => Err(FrameError::Oversized {
            n,
            max: MAX_FRAME_FLOATS,
        }),
        n => {
            let ttl_ms = read_body_u32(stream)?;
            read_floats(stream, n, bytes, row)?;
            Ok(Frame::Infer {
                ttl: if ttl_ms == 0 {
                    None
                } else {
                    Some(Duration::from_millis(u64::from(ttl_ms)))
                },
            })
        }
    }
}

fn write_ok(stream: &mut TcpStream, buf: &mut Vec<u8>, row: &[f32]) -> std::io::Result<()> {
    buf.clear();
    buf.push(0u8);
    buf.extend_from_slice(&(row.len() as u32).to_le_bytes());
    for v in row {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    stream.write_all(buf)
}

/// Write a tagged message frame: error responses (nonzero tag) and the
/// stats text export (tag 0) share this byte-length + utf8 layout.
fn write_msg(
    stream: &mut TcpStream,
    buf: &mut Vec<u8>,
    tag: u8,
    msg: &str,
) -> std::io::Result<()> {
    let bytes = msg.as_bytes();
    buf.clear();
    buf.push(tag);
    buf.extend_from_slice(&(bytes.len() as u32).to_le_bytes());
    buf.extend_from_slice(bytes);
    stream.write_all(buf)
}

fn write_err(
    stream: &mut TcpStream,
    buf: &mut Vec<u8>,
    code: u8,
    msg: &str,
) -> std::io::Result<()> {
    write_msg(stream, buf, code, msg)
}

/// Render the stats-frame text: one `name value` line per metric, the
/// full [`super::CoordinatorStats`] snapshot followed by the transport
/// counters and per-tenant admission tallies.
fn render_stats(coord: &Coordinator, tm: &TransportMetrics) -> String {
    use std::fmt::Write as _;
    let s = coord.stats();
    let mut out = String::with_capacity(1024);
    let _ = writeln!(out, "submitted {}", s.submitted);
    let _ = writeln!(out, "completed {}", s.completed);
    let _ = writeln!(out, "failed {}", s.failed);
    let _ = writeln!(out, "rejected {}", s.rejected);
    let _ = writeln!(out, "shed_queue_full {}", s.shed_queue_full);
    let _ = writeln!(out, "shed_draining {}", s.shed_draining);
    let _ = writeln!(out, "shed_deadline {}", s.shed_deadline);
    let _ = writeln!(out, "worker_lost {}", s.worker_lost);
    let _ = writeln!(out, "drained {}", s.drained);
    let _ = writeln!(out, "worker_panics {}", s.worker_panics);
    let _ = writeln!(out, "worker_restarts {}", s.worker_restarts);
    let _ = writeln!(out, "batches {}", s.batches);
    let _ = writeln!(out, "mean_batch {:.3}", s.mean_batch);
    let _ = writeln!(out, "sessions_opened {}", s.sessions_opened);
    let _ = writeln!(out, "sessions_closed {}", s.sessions_closed);
    let _ = writeln!(out, "session_steps {}", s.session_steps);
    let _ = writeln!(out, "sessions_evicted {}", s.sessions_evicted);
    let _ = writeln!(out, "queue_wait_p50_us {:.3}", s.queue_wait_p50_us);
    let _ = writeln!(out, "inference_p50_us {:.3}", s.inference_p50_us);
    let _ = writeln!(out, "e2e_p50_us {:.3}", s.e2e_p50_us);
    let _ = writeln!(out, "e2e_p99_us {:.3}", s.e2e_p99_us);
    let _ = writeln!(out, "live_workers {}", s.live_workers);
    let _ = writeln!(out, "queue_depth {}", s.queue_depth);
    let _ = writeln!(out, "drain_ms {:.3}", s.drain_ms);
    let _ = writeln!(out, "conns_open {}", tm.conns_open.get());
    let _ = writeln!(out, "conns_accepted {}", tm.conns_accepted.get());
    let _ = writeln!(out, "conns_rejected {}", tm.conns_rejected.get());
    let _ = writeln!(out, "handles_live {}", tm.handles_live.get());
    let _ = writeln!(out, "decode_errors {}", tm.decode_errors.get());
    let _ = writeln!(out, "quota_shed {}", tm.quota_shed.get());
    let wire = tm.frame_time.snapshot();
    let _ = writeln!(out, "wire_frames {}", wire.count);
    let _ = writeln!(out, "wire_frame_mean_us {:.3}", wire.mean_us);
    let _ = writeln!(out, "wire_frame_p50_us {:.3}", wire.p50_us);
    let _ = writeln!(out, "wire_frame_p99_us {:.3}", wire.p99_us);
    for (tenant, c) in tm.tenants.lock().unwrap().iter() {
        let _ = writeln!(out, "tenant.{tenant}.accepted {}", c.accepted);
        let _ = writeln!(out, "tenant.{tenant}.shed {}", c.shed);
    }
    out
}

/// Serve until `stop` is set (checked between accepts), with default
/// transport limits. Returns the bound address immediately via the
/// callback so tests can connect.
pub fn serve_tcp(
    coordinator: Arc<Coordinator>,
    addr: &str,
    stop: Arc<AtomicBool>,
    on_bound: impl FnOnce(std::net::SocketAddr),
) -> Result<()> {
    serve_tcp_with(coordinator, addr, TransportConfig::default(), stop, on_bound)
}

/// Serve with explicit transport limits: bounded connection capacity,
/// per-connection idle timeout, per-tenant quotas. The accept loop owns
/// the handler threads and reaps finished ones every iteration, so the
/// handle vector is bounded by the number of *live* connections.
pub fn serve_tcp_with(
    coordinator: Arc<Coordinator>,
    addr: &str,
    cfg: TransportConfig,
    stop: Arc<AtomicBool>,
    on_bound: impl FnOnce(std::net::SocketAddr),
) -> Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    listener.set_nonblocking(true)?;
    on_bound(listener.local_addr()?);
    let metrics = Arc::new(TransportMetrics::default());
    let admission = Arc::new(Admission::new(cfg.quota));
    let idle = if cfg.idle_timeout.is_zero() {
        None
    } else {
        Some(cfg.idle_timeout)
    };
    let max_conns = cfg.max_connections.max(1);
    let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
    let mut wbuf: Vec<u8> = Vec::new();
    while !stop.load(Ordering::SeqCst) {
        // Reap finished handler threads. Joining here (not just dropping
        // the handle) also surfaces their panics to nobody — injected
        // handler faults must never propagate into the accept loop.
        let mut i = 0;
        while i < conns.len() {
            if conns[i].is_finished() {
                let _ = conns.swap_remove(i).join();
            } else {
                i += 1;
            }
        }
        metrics.handles_live.set(conns.len() as u64);
        match listener.accept() {
            Ok((mut stream, _peer)) => {
                stream.set_nonblocking(false)?;
                stream.set_read_timeout(idle)?;
                stream.set_write_timeout(Some(WRITE_TIMEOUT))?;
                if conns.len() >= max_conns {
                    // Typed refusal (wire code 8), then close: the peer
                    // learns why instead of seeing a silent reset.
                    metrics.conns_rejected.inc();
                    let e = ServeError::Shed(Shed::ConnLimit);
                    let _ = write_err(&mut stream, &mut wbuf, e.wire_code(), &e.to_string());
                    continue;
                }
                metrics.conns_accepted.inc();
                let coord = Arc::clone(&coordinator);
                let m = Arc::clone(&metrics);
                let adm = Arc::clone(&admission);
                conns.push(std::thread::spawn(move || {
                    let _ = handle_conn(stream, coord, m, adm);
                }));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(e) => return Err(e.into()),
        }
    }
    for c in conns {
        let _ = c.join();
    }
    Ok(())
}

fn handle_conn(
    mut stream: TcpStream,
    coord: Arc<Coordinator>,
    metrics: Arc<TransportMetrics>,
    admission: Arc<Admission>,
) -> Result<()> {
    let _open = ConnGuard::new(&metrics);
    crate::fault_point!("transport.accept");
    // Reused across every request on this connection. `row` ping-pongs
    // with the coordinator: submission takes it, the worker returns it
    // through the response slot, `reclaim_input` takes it back.
    let mut rbytes: Vec<u8> = Vec::new();
    let mut row: Vec<f32> = Vec::new();
    let mut wbuf: Vec<u8> = Vec::new();
    let mut tenant: u32 = 0;
    loop {
        let frame = match read_frame(&mut stream, &mut rbytes, &mut row) {
            Ok(f) => f,
            Err(FrameError::Eof) | Err(FrameError::Idle) => return Ok(()),
            Err(FrameError::Truncated(_)) => {
                // Partial frame: no response possible (the peer may
                // never read it) — count it and drop the connection.
                metrics.decode_errors.inc();
                return Ok(());
            }
            Err(e @ FrameError::Oversized { .. }) | Err(e @ FrameError::UnknownMagic(_)) => {
                metrics.decode_errors.inc();
                let _ = write_err(&mut stream, &mut wbuf, WIRE_DECODE_ERROR, &e.to_string());
                return Ok(());
            }
            Err(FrameError::Io(e)) => return Err(e.into()),
        };
        crate::fault_point!("transport.frame");
        let t0 = Instant::now();
        match frame {
            Frame::Tenant { tenant: t } => {
                tenant = t;
                write_ok(&mut stream, &mut wbuf, &[])?;
            }
            Frame::Stats => {
                let text = render_stats(&coord, &metrics);
                write_msg(&mut stream, &mut wbuf, 0, &text)?;
            }
            frame => {
                // Data plane: metered by the per-tenant token bucket
                // (control frames above are exempt). A quota rejection
                // sheds only this frame; the connection stays usable.
                if !admission.admit(tenant, Instant::now()) {
                    metrics.quota_shed.inc();
                    metrics.tenant_shed(tenant);
                    let e = ServeError::Shed(Shed::QuotaExceeded);
                    write_err(&mut stream, &mut wbuf, e.wire_code(), &e.to_string())?;
                    continue;
                }
                metrics.tenant_accepted(tenant);
                let reclaims_row = matches!(frame, Frame::Infer { .. } | Frame::Step { .. });
                let submitted = match frame {
                    // A wire TTL of 0 falls back to the coordinator's
                    // configured default (plain `try_submit`); a nonzero
                    // TTL overrides it.
                    Frame::Infer { ttl: Some(t) } => {
                        coord.try_submit_with_ttl(std::mem::take(&mut row), Some(t))
                    }
                    Frame::Infer { ttl: None } => coord.try_submit(std::mem::take(&mut row)),
                    Frame::Open { ttl_ms } => coord.open_session(ttl_ms),
                    Frame::Step { session } => {
                        coord.step_session(session, std::mem::take(&mut row))
                    }
                    Frame::Close { session } => coord.close_session(session),
                    Frame::Stats | Frame::Tenant { .. } => unreachable!("handled above"),
                };
                match submitted {
                    Ok(ticket) => {
                        let resp = ticket.wait();
                        crate::fault_point!("transport.respond");
                        if reclaims_row {
                            if let Some(buf) = ticket.reclaim_input() {
                                row = buf;
                            }
                        }
                        match resp {
                            Ok(out) => write_ok(&mut stream, &mut wbuf, &out)?,
                            Err(e) => {
                                write_err(&mut stream, &mut wbuf, e.wire_code(), &e.to_string())?
                            }
                        }
                    }
                    Err(e) => write_err(&mut stream, &mut wbuf, e.wire_code(), &e.to_string())?,
                }
            }
        }
        metrics.frames.inc();
        metrics.frame_time.record(t0.elapsed());
    }
}

/// Blocking client for examples/tests/benches.
pub struct TcpClient {
    stream: TcpStream,
}

impl TcpClient {
    pub fn connect(addr: std::net::SocketAddr) -> Result<Self> {
        Ok(Self {
            stream: TcpStream::connect(addr)?,
        })
    }

    /// Send one row, wait for the response.
    pub fn infer(&mut self, row: &[f32]) -> Result<Vec<f32>> {
        self.infer_with_ttl(row, None)
    }

    /// Send one row with a per-request TTL; the server sheds the
    /// request with a typed error if it can't start compute in time.
    pub fn infer_with_ttl(&mut self, row: &[f32], ttl: Option<Duration>) -> Result<Vec<f32>> {
        let ttl_ms: u32 = ttl.map_or(0, |t| t.as_millis().clamp(1, u32::MAX as u128) as u32);
        let mut buf = Vec::with_capacity(8 + row.len() * 4);
        buf.extend_from_slice(&(row.len() as u32).to_le_bytes());
        buf.extend_from_slice(&ttl_ms.to_le_bytes());
        for v in row {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        self.stream.write_all(&buf)?;
        self.read_response()
    }

    /// Declare this connection's tenant id for admission quotas
    /// (`0` = the shared anonymous pool). Applies to every subsequent
    /// frame on this connection.
    pub fn set_tenant(&mut self, tenant: u32) -> Result<()> {
        let mut buf = Vec::with_capacity(8);
        buf.extend_from_slice(&TENANT_MAGIC.to_le_bytes());
        buf.extend_from_slice(&tenant.to_le_bytes());
        self.stream.write_all(&buf)?;
        self.read_response().map(|_| ())
    }

    /// Fetch the server's stats export: utf8 text, one `name value`
    /// line per metric (coordinator ledger + transport counters).
    pub fn stats(&mut self) -> Result<String> {
        self.stream.write_all(&STATS_MAGIC.to_le_bytes())?;
        let mut tag = [0u8; 1];
        self.stream.read_exact(&mut tag)?;
        let mut len = [0u8; 4];
        self.stream.read_exact(&mut len)?;
        let n = u32::from_le_bytes(len) as usize;
        let mut bytes = vec![0u8; n];
        self.stream.read_exact(&mut bytes)?;
        if tag[0] != 0 {
            bail!(
                "server error (code {}): {}",
                tag[0],
                String::from_utf8_lossy(&bytes)
            );
        }
        Ok(String::from_utf8_lossy(&bytes).into_owned())
    }

    /// [`TcpClient::stats`], parsed into a name → value map (every
    /// exported metric is numeric).
    pub fn stats_map(&mut self) -> Result<std::collections::HashMap<String, f64>> {
        let text = self.stats()?;
        let mut map = std::collections::HashMap::new();
        for line in text.lines() {
            if let Some((k, v)) = line.split_once(' ') {
                if let Ok(num) = v.trim().parse::<f64>() {
                    map.insert(k.to_string(), num);
                }
            }
        }
        Ok(map)
    }

    /// Open a streaming session; `ttl` is the *idle* TTL between steps
    /// (`None` = server default). Returns the session id.
    pub fn session_open(&mut self, ttl: Option<Duration>) -> Result<u32> {
        let ttl_ms: u32 = ttl.map_or(0, |t| t.as_millis().clamp(1, u32::MAX as u128) as u32);
        let mut buf = Vec::with_capacity(8);
        buf.extend_from_slice(&SESSION_OPEN_MAGIC.to_le_bytes());
        buf.extend_from_slice(&ttl_ms.to_le_bytes());
        self.stream.write_all(&buf)?;
        let out = self.read_response()?;
        // The id rides as the raw bit pattern of one f32 — bit-exact
        // through serialization, unlike a numeric cast.
        if out.len() != 1 {
            bail!("session open returned {} floats, expected 1", out.len());
        }
        Ok(out[0].to_bits())
    }

    /// Push a packet of input samples (interleaved `[t, c]`) into the
    /// session; returns the newly finalized output samples (interleaved,
    /// possibly empty).
    pub fn session_step(&mut self, session: u32, packet: &[f32]) -> Result<Vec<f32>> {
        let mut buf = Vec::with_capacity(12 + packet.len() * 4);
        buf.extend_from_slice(&SESSION_STEP_MAGIC.to_le_bytes());
        buf.extend_from_slice(&session.to_le_bytes());
        buf.extend_from_slice(&(packet.len() as u32).to_le_bytes());
        for v in packet {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        self.stream.write_all(&buf)?;
        self.read_response()
    }

    /// Close the session, recycling its server-side state.
    pub fn session_close(&mut self, session: u32) -> Result<()> {
        let mut buf = Vec::with_capacity(8);
        buf.extend_from_slice(&SESSION_CLOSE_MAGIC.to_le_bytes());
        buf.extend_from_slice(&session.to_le_bytes());
        self.stream.write_all(&buf)?;
        self.read_response().map(|_| ())
    }

    fn read_response(&mut self) -> Result<Vec<f32>> {
        let mut tag = [0u8; 1];
        self.stream.read_exact(&mut tag)?;
        let mut len = [0u8; 4];
        self.stream.read_exact(&mut len)?;
        let n = u32::from_le_bytes(len) as usize;
        if tag[0] == 0 {
            let mut bytes = vec![0u8; n * 4];
            self.stream.read_exact(&mut bytes)?;
            Ok(bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect())
        } else {
            let mut bytes = vec![0u8; n];
            self.stream.read_exact(&mut bytes)?;
            bail!(
                "server error (code {}): {}",
                tag[0],
                String::from_utf8_lossy(&bytes)
            )
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn control_magics_sit_above_the_row_cap() {
        for magic in [
            SESSION_OPEN_MAGIC,
            SESSION_STEP_MAGIC,
            SESSION_CLOSE_MAGIC,
            STATS_MAGIC,
            TENANT_MAGIC,
        ] {
            assert!(magic >= CONTROL_BASE);
            assert!(magic > MAX_FRAME_FLOATS);
        }
    }

    #[test]
    fn frame_error_messages_are_typed() {
        let e = FrameError::Oversized { n: 5_000_000, max: MAX_FRAME_FLOATS };
        assert!(e.to_string().contains("exceeds limit"));
        let e = FrameError::UnknownMagic(0xFFFF_FFEE);
        assert!(e.to_string().contains("0xFFFFFFEE"));
    }

    #[test]
    fn transport_config_from_serve_clamps() {
        let cfg = ServeConfig {
            max_connections: 0,
            idle_timeout_ms: 0,
            ..Default::default()
        };
        let t = TransportConfig::from_serve(&cfg);
        assert_eq!(t.max_connections, 1, "cap of 0 would refuse everything");
        assert!(t.idle_timeout.is_zero(), "0 = no idle timeout");
    }
}
