//! Inference engines the coordinator can drive.

use anyhow::{bail, ensure, Context, Result};

/// Constructor run on the coordinator's worker thread.
pub type EngineFactory = Box<dyn FnOnce() -> Result<Box<dyn Engine>> + Send + 'static>;

use std::collections::HashMap;

use crate::conv::{BackendChoice, ConvBackend};
use crate::nn::{
    EagerScratch, Model, Plan, PlanCache, PlanScratch, PlannerConfig, SessionArena, SessionId,
};
use crate::runtime::{ArtifactRegistry, TensorView};

/// A batched inference engine with a fixed per-row input/output shape.
///
/// Engines are **not** required to be `Send`/`Sync`: the PJRT wrapper
/// types hold `Rc` internals, so the coordinator constructs its engine
/// *on* the worker thread via an [`EngineFactory`] and never moves it.
pub trait Engine {
    /// Elements per input row.
    fn input_len(&self) -> usize;
    /// Elements per output row.
    fn output_len(&self) -> usize;
    /// Run `batch` rows (input length `batch * input_len()`).
    fn infer(&self, x: &[f32], batch: usize) -> Result<Vec<f32>>;
    /// Run `batch` rows into a reusable output buffer (resized to
    /// `batch * output_len()`; stale contents are overwritten). The
    /// default delegates to [`Engine::infer`]; engines with
    /// allocation-free forward paths override it so one output tensor
    /// and all intermediate activations recycle across requests.
    fn infer_into(&mut self, x: &[f32], batch: usize, y: &mut Vec<f32>) -> Result<()> {
        *y = self.infer(x, batch)?;
        Ok(())
    }
    /// Startup warm-up, run by the coordinator on the worker thread
    /// right after construction and before the first request — and
    /// again on every supervised restart: when a worker panics, the
    /// supervisor builds a *fresh* engine from the respawn factory and
    /// re-runs `warmup` before the replacement takes any traffic, so a
    /// restarted worker is as warm as a freshly booted one.
    /// Specifically:
    /// precompile whatever per-bucket state the engine keeps (plans,
    /// probe results, arenas) for the configured batch buckets so
    /// steady-state inference at a bucketed batch size never pays
    /// compile, autotune-probe, or allocation latency. A failure here
    /// fails coordinator startup. Default: no-op.
    fn warmup(&mut self, _batch_buckets: &[usize]) -> Result<()> {
        Ok(())
    }
    /// Human-readable backend tag for metrics/logs.
    fn name(&self) -> String;

    // --- Streaming sessions (optional capability) ---------------------
    //
    // Engines that can hold per-stream halo state between requests
    // (see `nn::session`) override these; the defaults report the
    // capability as absent so the coordinator sheds session traffic
    // with a typed engine error instead of a protocol crash.

    /// Open a streaming session; returns an engine-scoped session id.
    /// Ids are never reused within an engine's lifetime, so a stale id
    /// (closed or evicted) fails instead of silently hitting a
    /// recycled slot.
    fn session_open(&mut self) -> Result<u32> {
        bail!("engine '{}' does not support streaming sessions", self.name())
    }

    /// Advance session `id` by the packet `x` (interleaved `[t, c]`),
    /// writing the newly final output samples into `out` (resized to
    /// exactly the emitted length) and returning the emitted *sample*
    /// count.
    fn session_step(&mut self, id: u32, _x: &[f32], _out: &mut Vec<f32>) -> Result<usize> {
        bail!("unknown session id {id} (engine '{}' has no sessions)", self.name())
    }

    /// Close session `id`, recycling its state slot.
    fn session_close(&mut self, id: u32) -> Result<()> {
        bail!("unknown session id {id} (engine '{}' has no sessions)", self.name())
    }

    /// Live (open) session count — feeds `CoordinatorStats`.
    fn live_sessions(&self) -> usize {
        0
    }

    /// Session-state slab growths (see `SessionArena::grows`); serving
    /// tests assert this stays flat across steady-state stepping.
    fn session_grows(&self) -> u64 {
        0
    }
}

/// Rust-native engine: the [`Model`] layer stack executed through
/// compiled [`Plan`]s, cached per batch size — each incoming batch
/// bucket compiles once, then every request runs through the cached
/// plan's single scratch arena with fused epilogues and zero per-request
/// allocation (sliding/im2col/small-k/direct kernels). `Clone`
/// replicates the model (plans and scratch clone along, staying
/// per-instance) so N coordinator workers can each own an instance
/// ([`crate::coordinator::Coordinator::start_replicated`]).
#[derive(Clone)]
pub struct NativeEngine {
    model: Model,
    choice: BackendChoice,
    /// Measured-cost kernel selection: plan compiles micro-probe the
    /// candidate kernels instead of trusting the shape heuristic
    /// (probe results cached process-wide, so replicated workers and
    /// repeated buckets probe each shape once).
    autotune: bool,
    /// Plan-level chain fusion (on by default; the `chain_fusion` bench
    /// turns it off for its unfused comparison arm).
    fuse: bool,
    max_batch: usize,
    /// Eager mode skips the planner and runs the layer-by-layer
    /// reference path — the baseline arm of the `eager_vs_planned`
    /// bench (requires a fixed backend).
    eager: bool,
    /// Compiled plans keyed by batch size (batch buckets are
    /// ≤ max_batch).
    plans: PlanCache<usize>,
    /// Per-engine plan arena (each coordinator worker owns its engine,
    /// so the scratch recycles across that worker's requests without
    /// synchronization).
    scratch: PlanScratch,
    eager_scratch: EagerScratch,
    /// Streaming-session state, built lazily from the batch-1 plan on
    /// the first `session_open` (chain-only models; see `nn::session`).
    sessions: Option<SessionArena>,
    /// Wire session id → arena slot. Wire ids are monotonic and never
    /// reused, so stale ids fail cleanly even after slot recycling.
    session_ids: HashMap<u32, SessionId>,
    next_session: u32,
}

impl NativeEngine {
    /// Planned engine with a fixed backend on every layer (the
    /// pre-plan constructor, kept source-compatible).
    pub fn new(model: Model, backend: ConvBackend, max_batch: usize) -> Self {
        Self::with_choice(model, BackendChoice::Fixed(backend), max_batch)
    }

    /// Planned engine: `Auto` lets the planner's cost model pick a
    /// kernel per layer; `Fixed` forces one backend (per-layer TOML
    /// overrides beat either).
    pub fn with_choice(model: Model, choice: BackendChoice, max_batch: usize) -> Self {
        Self {
            model,
            choice,
            autotune: false,
            fuse: true,
            max_batch: max_batch.max(1),
            eager: false,
            plans: PlanCache::default(),
            scratch: PlanScratch::default(),
            eager_scratch: EagerScratch::default(),
            sessions: None,
            session_ids: HashMap::new(),
            next_session: 0,
        }
    }

    /// Builder: switch the planner to measured-cost kernel selection
    /// (`serve.autotune` / `--autotune`). Only meaningful under the
    /// `auto` backend — fixed backends and per-layer overrides never
    /// probe.
    pub fn autotuned(mut self, on: bool) -> Self {
        self.autotune = on;
        self
    }

    /// Builder: toggle plan-level chain fusion (default on). The
    /// `chain_fusion` bench's unfused arm is the only production caller
    /// that turns it off.
    pub fn fused(mut self, on: bool) -> Self {
        self.fuse = on;
        self
    }

    /// Eager reference engine (no plan compilation; separate bias/ReLU/
    /// skip-add passes and ping-pong buffers) — the `eager_vs_planned`
    /// baseline.
    pub fn eager(model: Model, backend: ConvBackend, max_batch: usize) -> Self {
        Self {
            eager: true,
            ..Self::new(model, backend, max_batch)
        }
    }

    pub fn model(&self) -> &Model {
        &self.model
    }

    fn planner_cfg(&self) -> PlannerConfig {
        PlannerConfig {
            backend: self.choice,
            autotune: self.autotune,
            fuse: self.fuse,
            ..PlannerConfig::default()
        }
    }

    /// Number of compiled plans currently cached (one per batch size
    /// seen).
    pub fn cached_plans(&self) -> usize {
        self.plans.len()
    }

    /// Plan compilations performed so far (plan-cache misses). Serving
    /// tests assert this stays flat after [`Engine::warmup`].
    pub fn plan_compiles(&self) -> u64 {
        self.plans.compiles()
    }

    /// Plan-cache hits (requests served without compiling).
    pub fn plan_cache_hits(&self) -> u64 {
        self.plans.hits()
    }

    /// Current plan-arena size in elements — steady-state inference at
    /// a warmed bucket must never grow it (zero per-request
    /// allocations).
    pub fn arena_len(&self) -> usize {
        self.scratch.capacity()
    }

    /// The cached plan for `batch`, compiling (and caching) on first
    /// use.
    pub fn plan_for(&mut self, batch: usize) -> Result<&Plan> {
        let cfg = self.planner_cfg();
        let model = &self.model;
        self.plans
            .get_or_compile(batch, || Plan::compile(model, batch, &cfg))
    }

    fn fixed_backend(&self) -> Result<ConvBackend> {
        match self.choice {
            BackendChoice::Fixed(b) => Ok(b),
            BackendChoice::Auto => bail!("eager mode needs a fixed backend"),
        }
    }
}

impl Engine for NativeEngine {
    fn input_len(&self) -> usize {
        self.model.c_in * self.model.seq_len
    }

    fn output_len(&self) -> usize {
        let (c, n) = self.model.out_shape();
        c * n
    }

    fn infer(&self, x: &[f32], batch: usize) -> Result<Vec<f32>> {
        let mut y = Vec::new();
        if self.eager {
            self.model.forward_eager_into(
                x,
                batch,
                self.fixed_backend()?,
                &mut EagerScratch::default(),
                &mut y,
            )?;
        } else {
            // Shared-reference path (no cache access): compile fresh.
            let plan = Plan::compile(&self.model, batch, &self.planner_cfg())?;
            plan.run_into(&self.model, x, &mut PlanScratch::default(), &mut y)?;
        }
        Ok(y)
    }

    fn infer_into(&mut self, x: &[f32], batch: usize, y: &mut Vec<f32>) -> Result<()> {
        if self.eager {
            let backend = self.fixed_backend()?;
            self.model
                .forward_eager_into(x, batch, backend, &mut self.eager_scratch, y)?;
            return Ok(());
        }
        let cfg = self.planner_cfg();
        let model = &self.model;
        let plan = self
            .plans
            .get_or_compile(batch, || Plan::compile(model, batch, &cfg))?;
        plan.run_into(model, x, &mut self.scratch, y)?;
        Ok(())
    }

    /// Precompile one plan per configured batch bucket (running the
    /// autotune probes now if enabled) and pre-grow the shared arena to
    /// the largest plan, so the first request at any bucketed batch
    /// size compiles nothing and allocates nothing. Buckets outside
    /// `[1, max_batch]` are ignored.
    fn warmup(&mut self, batch_buckets: &[usize]) -> Result<()> {
        if self.eager {
            return Ok(()); // the eager reference path has no plans
        }
        let mut arena = 0usize;
        for &b in batch_buckets {
            if b < 1 || b > self.max_batch {
                continue;
            }
            let plan = self.plan_for(b)?;
            arena = arena.max(plan.arena_len());
        }
        self.scratch.reserve(arena);
        Ok(())
    }

    fn name(&self) -> String {
        let mode = if self.eager { "eager" } else { "planned" };
        let tune = if self.autotune && !self.eager { "+tune" } else { "" };
        let fuse = if !self.fuse && !self.eager { "+nofuse" } else { "" };
        format!("native/{mode}/{}{tune}{fuse}", self.choice.name())
    }

    fn session_open(&mut self) -> Result<u32> {
        ensure!(!self.eager, "eager engines do not support streaming sessions");
        if self.sessions.is_none() {
            // Sessions stream one sample row at a time, so the halo
            // geometry comes from the batch-1 plan (cached — steady
            // traffic after warmup never compiles here).
            let plan = self.plan_for(1)?.clone();
            self.sessions = Some(SessionArena::new(&plan, &self.model)?);
        }
        let arena = self.sessions.as_mut().unwrap();
        let slot = arena.open();
        let id = self.next_session;
        self.next_session += 1;
        self.session_ids.insert(id, slot);
        Ok(id)
    }

    fn session_step(&mut self, id: u32, x: &[f32], out: &mut Vec<f32>) -> Result<usize> {
        let slot = *self
            .session_ids
            .get(&id)
            .with_context(|| format!("unknown session id {id}"))?;
        let arena = self
            .sessions
            .as_mut()
            .expect("a mapped session id implies an arena");
        let spec = arena.spec();
        let (c_in, c_out) = (spec.in_channels(), spec.out_channels());
        ensure!(
            x.len() % c_in == 0,
            "session packet length {} is not a multiple of c_in = {c_in}",
            x.len()
        );
        // The emit count is deterministic from the cursor state, so the
        // output buffer is sized exactly up front (it reaches its
        // high-water mark after the first full-tile packet and is
        // allocation-free from then on).
        let r = arena.pending_out_samples(slot, x.len() / c_in);
        out.resize(r * c_out, 0.0);
        let got = arena.step_into(slot, &self.model, x, out)?;
        debug_assert_eq!(got, r);
        Ok(got)
    }

    fn session_close(&mut self, id: u32) -> Result<()> {
        let slot = self
            .session_ids
            .remove(&id)
            .with_context(|| format!("unknown session id {id}"))?;
        self.sessions
            .as_mut()
            .expect("a mapped session id implies an arena")
            .close(slot)
    }

    fn live_sessions(&self) -> usize {
        self.sessions.as_ref().map_or(0, |a| a.live_sessions())
    }

    fn session_grows(&self) -> u64 {
        self.sessions.as_ref().map_or(0, |a| a.grows())
    }
}

/// PJRT engine: the AOT TCN artifacts (`tcn_forward_b{1,4,8}`), executed
/// through the xla runtime. Parameters are loaded once (deterministic He
/// init from the manifest shapes, or externally trained weights).
pub struct PjrtTcnEngine {
    registry: ArtifactRegistry,
    params: Vec<TensorView>,
    seq_len: usize,
    c_in: usize,
    c_out: usize,
    buckets: Vec<usize>,
}

impl PjrtTcnEngine {
    /// Build from an artifacts directory, generating params with `seed`.
    pub fn from_artifacts(dir: impl Into<std::path::PathBuf>, seed: u64) -> Result<Self> {
        let registry = ArtifactRegistry::open(dir)?;
        let manifest = registry
            .manifest()
            .context("manifest.toml missing — rerun `make artifacts`")?
            .clone();
        let mut rng = crate::workload::Rng::new(seed);
        let params: Vec<TensorView> = manifest
            .param_shapes()
            .iter()
            .map(|(name, s)| {
                let n: usize = s.iter().product();
                if name.ends_with("_w") || name.contains("_w") {
                    let fan_in: usize = s[1..].iter().product();
                    TensorView::new(s.clone(), rng.vec_normal(n, (2.0 / fan_in as f32).sqrt()))
                } else {
                    TensorView::new(s.clone(), vec![0.0; n])
                }
            })
            .collect();
        let mut buckets = Vec::new();
        for b in [1usize, 4, 8] {
            if registry.contains(&format!("tcn_forward_b{b}_n{}", manifest.seq_len)) {
                buckets.push(b);
            }
        }
        if buckets.is_empty() {
            bail!("no tcn_forward_b*.hlo.txt artifacts found");
        }
        // Pre-compile every bucket now: serving latency must not pay the
        // first-request JIT cost (it dominated p99 by ~100x before this).
        for b in &buckets {
            registry.get(&format!("tcn_forward_b{b}_n{}", manifest.seq_len))?;
        }
        Ok(Self {
            registry,
            params,
            seq_len: manifest.seq_len,
            c_in: manifest.c_in,
            c_out: manifest.c_out,
            buckets,
        })
    }

    /// Replace parameters (e.g. after rust-driven training).
    pub fn set_params(&mut self, params: Vec<TensorView>) {
        assert_eq!(params.len(), self.params.len());
        self.params = params;
    }

    pub fn params(&self) -> &[TensorView] {
        &self.params
    }

    pub fn registry(&self) -> &ArtifactRegistry {
        &self.registry
    }

    fn bucket_for(&self, batch: usize) -> Result<usize> {
        self.buckets
            .iter()
            .copied()
            .find(|b| *b >= batch)
            .with_context(|| format!("batch {batch} exceeds largest bucket {:?}", self.buckets))
    }
}

impl Engine for PjrtTcnEngine {
    fn input_len(&self) -> usize {
        self.c_in * self.seq_len
    }

    fn output_len(&self) -> usize {
        self.c_out * self.seq_len
    }

    fn infer(&self, x: &[f32], batch: usize) -> Result<Vec<f32>> {
        let bucket = self.bucket_for(batch)?;
        let exe = self
            .registry
            .get(&format!("tcn_forward_b{bucket}_n{}", self.seq_len))?;
        // Pad to bucket with zero rows.
        let row = self.input_len();
        let mut xb = x.to_vec();
        xb.resize(bucket * row, 0.0);
        let mut args = self.params.clone();
        args.push(TensorView::new(vec![bucket, self.c_in, self.seq_len], xb));
        let out = exe.run1(&args)?;
        let out_row = self.output_len();
        Ok(out.data[..batch * out_row].to_vec())
    }

    fn name(&self) -> String {
        format!("pjrt/tcn_n{}", self.seq_len)
    }
}
