//! Admission layer: per-tenant token-bucket quotas, sitting between the
//! transport decoder and `Coordinator::try_submit`.
//!
//! Each tenant id (a `u32` set per connection via the `TENANT_MAGIC`
//! wire frame; `0` = the shared anonymous pool) gets an independent
//! bucket, so a flooding tenant exhausts only its *own* budget and
//! cannot starve a well-behaved one — the fairness property the tests
//! below pin. Rejections surface as [`Shed::QuotaExceeded`] (wire code
//! 9) on that frame only; the connection stays usable.
//!
//! Quota rejections happen *before* the request enters the bounded
//! queue, so — like `Shed::QueueFull` — they are not part of the
//! coordinator's terminal-state ledger. They are counted separately in
//! the transport metrics (`quota_shed`, per-tenant shed).
//!
//! [`Shed::QuotaExceeded`]: super::Shed::QuotaExceeded

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Instant;

/// Token-bucket quota parameters, per tenant. `rate_per_sec == 0`
/// disables quota enforcement entirely (the default).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct QuotaConfig {
    /// Sustained admission rate per tenant, in requests/second.
    pub rate_per_sec: u64,
    /// Bucket depth: how many requests a tenant may burst above the
    /// sustained rate. `0` is treated as `1` (no burst headroom).
    pub burst: u64,
}

impl QuotaConfig {
    pub fn unlimited(&self) -> bool {
        self.rate_per_sec == 0
    }
}

struct Bucket {
    tokens: f64,
    last: Instant,
}

/// Per-tenant token buckets. Buckets are created lazily on first sight
/// of a tenant id, pre-filled to the burst depth.
pub struct Admission {
    cfg: QuotaConfig,
    buckets: Mutex<HashMap<u32, Bucket>>,
}

impl Admission {
    pub fn new(cfg: QuotaConfig) -> Self {
        Self {
            cfg,
            buckets: Mutex::new(HashMap::new()),
        }
    }

    /// Try to admit one request for `tenant` at time `now`. Takes the
    /// clock as an argument so tests can drive it deterministically.
    pub fn admit(&self, tenant: u32, now: Instant) -> bool {
        if self.cfg.unlimited() {
            return true;
        }
        let rate = self.cfg.rate_per_sec as f64;
        let burst = self.cfg.burst.max(1) as f64;
        let mut buckets = self.buckets.lock().unwrap();
        let b = buckets.entry(tenant).or_insert(Bucket {
            tokens: burst,
            last: now,
        });
        // `saturating_duration_since`: `admit` may be called with
        // out-of-order `now` values from racing connections.
        let dt = now.saturating_duration_since(b.last).as_secs_f64();
        b.tokens = (b.tokens + dt * rate).min(burst);
        if now > b.last {
            b.last = now;
        }
        if b.tokens >= 1.0 {
            b.tokens -= 1.0;
            true
        } else {
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn zero_rate_means_unlimited() {
        let adm = Admission::new(QuotaConfig::default());
        let t0 = Instant::now();
        for _ in 0..10_000 {
            assert!(adm.admit(0, t0));
        }
    }

    #[test]
    fn burst_then_refill_at_rate() {
        let adm = Admission::new(QuotaConfig {
            rate_per_sec: 10, // one token per 100ms
            burst: 3,
        });
        let t0 = Instant::now();
        // Burst depth admits exactly 3 back-to-back.
        assert!(adm.admit(1, t0));
        assert!(adm.admit(1, t0));
        assert!(adm.admit(1, t0));
        assert!(!adm.admit(1, t0));
        // 100ms later: exactly one token has refilled.
        let t1 = t0 + Duration::from_millis(100);
        assert!(adm.admit(1, t1));
        assert!(!adm.admit(1, t1));
        // A long quiet period refills to the burst cap, no further.
        let t2 = t1 + Duration::from_secs(60);
        assert!(adm.admit(1, t2));
        assert!(adm.admit(1, t2));
        assert!(adm.admit(1, t2));
        assert!(!adm.admit(1, t2));
    }

    /// The fairness property: tenant buckets are independent, so a
    /// flooding tenant drains only its own budget and a well-behaved
    /// tenant pacing under its rate is never rejected.
    #[test]
    fn flooding_tenant_cannot_starve_paced_tenant() {
        let adm = Admission::new(QuotaConfig {
            rate_per_sec: 10,
            burst: 2,
        });
        let t0 = Instant::now();
        let mut flood_ok = 0;
        let mut polite_ok = 0;
        for step in 0..50u64 {
            let now = t0 + Duration::from_millis(10 * step);
            // Tenant 7 floods: 10 requests per 10ms tick (1000/s >> 10/s).
            for _ in 0..10 {
                if adm.admit(7, now) {
                    flood_ok += 1;
                }
            }
            // Tenant 8 is polite: one request per 200ms (5/s < 10/s).
            if step % 20 == 0 && adm.admit(8, now) {
                polite_ok += 1;
            }
        }
        // The flooder got its burst plus the sustained rate over 0.5s…
        assert!(flood_ok <= 2 + 10, "flooder over-admitted: {flood_ok}");
        assert!(flood_ok >= 2, "flooder lost even its burst: {flood_ok}");
        // …while the polite tenant was never rejected.
        assert_eq!(polite_ok, 3, "paced tenant must never be shed");
    }

    #[test]
    fn out_of_order_clock_is_safe() {
        let adm = Admission::new(QuotaConfig {
            rate_per_sec: 10,
            burst: 1,
        });
        let t0 = Instant::now();
        let t1 = t0 + Duration::from_millis(200);
        assert!(adm.admit(1, t1));
        // An earlier timestamp arriving late must not panic or refill.
        assert!(!adm.admit(1, t0));
        assert!(adm.admit(1, t1 + Duration::from_millis(100)));
    }
}
