//! Fault-injection harness for the chaos tests (`tests/chaos.rs`).
//!
//! A process-global registry maps *site names* (string literals baked
//! into the coordinator via the [`crate::fault_point!`] macro) to armed
//! faults. The entire module — and every `fault_point!` expansion — is
//! compiled only under `cfg(any(test, feature = "fault-injection"))`, so
//! release serving builds carry zero injection branches. The static
//! checker (`cargo xtask check`, rule `fault-confinement`) keeps
//! `faults::` references and `fault_point!` sites out of every other
//! module.
//!
//! Sites currently wired into the coordinator:
//!
//! | site                     | where                                        |
//! |--------------------------|----------------------------------------------|
//! | `transport.accept`       | connection handler start, before first read  |
//! | `transport.frame`        | frame decoded, before admission/submit       |
//! | `transport.respond`      | response in hand, before the wire write      |
//! | `admission.submit`       | after admission checks, before enqueue       |
//! | `worker.batch_collected` | batch assembled, before deadline shedding    |
//! | `worker.infer`           | immediately before `Engine::infer_into`      |
//! | `worker.distribute`      | after inference, before slot completion      |
//! | `worker.session_step`    | before each `Engine::session_step` call      |
//! | `supervisor.respawn`     | inside the worker-restart path               |
//!
//! `Sleep` at `worker.batch_collected` models a queue stall; `Panic` at
//! `worker.infer`/`worker.distribute` models an engine crash before/after
//! compute (the second exercises the drop-guard with results already in
//! hand). The `transport.*` sites live on connection-handler threads
//! (`coordinator/transport.rs`): a `Panic` there kills one connection —
//! never the listener — before submission (`accept`/`frame`) or after
//! the request is already terminal (`respond`), so the ledger must stay
//! balanced either way; a `Sleep` models a stalled handler.

use std::collections::HashMap;
use std::sync::{Mutex, OnceLock};
use std::time::Duration;

/// What an armed site does when it fires.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// `panic!` at the site — exercises `catch_unwind` + the
    /// `WorkerLost` drop-guard.
    Panic,
    /// Sleep at the site — models engine latency spikes / queue stalls.
    Sleep(Duration),
}

#[derive(Debug)]
struct Armed {
    kind: FaultKind,
    /// Pass through this many hits before firing (lets a schedule target
    /// "the 3rd batch" deterministically).
    skip: usize,
    /// Fire at most this many times, then disarm.
    fires_left: usize,
}

#[derive(Debug, Default)]
struct SiteState {
    armed: Option<Armed>,
    hits: u64,
    fired: u64,
}

fn registry() -> &'static Mutex<HashMap<&'static str, SiteState>> {
    static REG: OnceLock<Mutex<HashMap<&'static str, SiteState>>> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Arm `site`: after `skip` pass-through hits, fire `kind` up to `fires`
/// times. Re-arming a site replaces any previous schedule (hit/fire
/// counts are kept).
pub fn arm(site: &'static str, kind: FaultKind, skip: usize, fires: usize) {
    let mut reg = registry().lock().unwrap();
    reg.entry(site).or_default().armed = Some(Armed {
        kind,
        skip,
        fires_left: fires,
    });
}

/// Disarm every site and zero all counters. Chaos tests call this
/// between schedules (they serialize on a global lock — the registry is
/// process-wide).
pub fn reset() {
    let mut reg = registry().lock().unwrap();
    reg.clear();
}

/// How many times `site` was reached (armed or not).
pub fn hits(site: &'static str) -> u64 {
    registry().lock().unwrap().get(site).map_or(0, |s| s.hits)
}

/// How many times `site` actually fired its fault.
pub fn fired(site: &'static str) -> u64 {
    registry().lock().unwrap().get(site).map_or(0, |s| s.fired)
}

/// Hot entry point expanded by [`crate::fault_point!`]. Never holds the
/// registry lock across the injected action (a sleeping site must not
/// serialize unrelated sites, and a panic must not poison the registry).
pub fn fire(site: &'static str) {
    let action = {
        let mut reg = registry().lock().unwrap();
        let st = reg.entry(site).or_default();
        st.hits += 1;
        match &mut st.armed {
            Some(a) if a.skip > 0 => {
                a.skip -= 1;
                None
            }
            Some(a) if a.fires_left > 0 => {
                a.fires_left -= 1;
                st.fired += 1;
                let kind = a.kind;
                if a.fires_left == 0 {
                    st.armed = None;
                }
                Some(kind)
            }
            _ => None,
        }
    };
    match action {
        Some(FaultKind::Panic) => panic!("injected fault at {site}"),
        Some(FaultKind::Sleep(d)) => std::thread::sleep(d),
        None => {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The registry is process-global; this test serializes with any
    /// other registry user via reset-at-start (lib unit tests only —
    /// integration chaos tests hold their own global lock).
    #[test]
    fn skip_then_fire_then_disarm() {
        reset();
        arm("test.site", FaultKind::Sleep(Duration::from_millis(1)), 2, 1);
        fire("test.site"); // skip 1
        fire("test.site"); // skip 2
        assert_eq!(fired("test.site"), 0);
        fire("test.site"); // fires
        assert_eq!(fired("test.site"), 1);
        fire("test.site"); // disarmed
        assert_eq!(fired("test.site"), 1);
        assert_eq!(hits("test.site"), 4);
        reset();
        assert_eq!(hits("test.site"), 0);
    }

    #[test]
    fn panic_fault_panics_and_keeps_registry_usable() {
        reset();
        arm("test.panic", FaultKind::Panic, 0, 1);
        let r = std::panic::catch_unwind(|| fire("test.panic"));
        assert!(r.is_err(), "armed panic site must panic");
        assert_eq!(fired("test.panic"), 1);
        // Registry not poisoned: next fire is a pass-through.
        fire("test.panic");
        assert_eq!(hits("test.panic"), 2);
        reset();
    }
}
