//! The coordinator core: bounded queue + deadline batcher + supervised
//! worker loop.
//!
//! Fault-tolerance contract (`docs/robustness.md`): every request
//! accepted by [`Coordinator::submit`]/[`Coordinator::try_submit`]
//! reaches exactly one terminal state — `Ok(row)`,
//! [`ServeError::Engine`], or a [`Shed`] variant. Worker panics are
//! caught per batch; a drop-guard completes the in-flight slots with
//! [`Shed::WorkerLost`] and the supervisor restarts the worker with a
//! fresh engine (re-running warm-up) under a bounded budget with
//! exponential backoff. Past the budget the pool degrades to fewer
//! workers; when the *last* worker dies the queue is closed and drained
//! so no submitter ever hangs.

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::config::ServeConfig;
use crate::exec::{Channel, ChannelError};
use crate::telemetry::{Counter, Histogram};

use super::engine::{Engine, EngineFactory};
use super::{ReqKind, Request, ResponseSlot, ServeError, Shed, Ticket};

/// Submission (admission) failure modes surfaced to clients.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// Queue full — backpressure; client should retry/shed.
    Overloaded,
    /// Coordinator shut down.
    Closed,
    /// Coordinator is draining: admission is stopped, in-flight requests
    /// are being run to completion.
    Draining,
    /// Input row has the wrong length for the deployed model.
    BadShape { expected: usize, got: usize },
}

impl SubmitError {
    /// Stable wire error code (`coordinator/transport.rs` response tag).
    /// Admission sheds share codes with the matching [`Shed`] variants.
    pub fn wire_code(&self) -> u8 {
        match self {
            SubmitError::BadShape { .. } => 2,
            SubmitError::Overloaded => Shed::QueueFull.wire_code(),
            SubmitError::Draining => Shed::Draining.wire_code(),
            SubmitError::Closed => 7,
        }
    }
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Overloaded => write!(f, "queue full (backpressure)"),
            SubmitError::Closed => write!(f, "coordinator closed"),
            SubmitError::Draining => write!(f, "coordinator draining"),
            SubmitError::BadShape { expected, got } => {
                write!(f, "bad input shape: expected {expected} floats, got {got}")
            }
        }
    }
}

/// Aggregated serving metrics.
///
/// Terminal-state ledger: for every accepted request exactly one of
/// `completed`, `failed`, `shed_deadline`, `worker_lost`, `drained`
/// increments, so once the coordinator is quiescent
/// `submitted == completed + failed + shed_deadline + worker_lost +
/// drained` (asserted by `tests/chaos.rs`).
#[derive(Debug, Default)]
pub struct Metrics {
    pub submitted: Counter,
    pub completed: Counter,
    /// Accepted, ran, engine returned an error.
    pub failed: Counter,
    /// Admission rejections (bad shape, queue full, draining, closed).
    pub rejected: Counter,
    /// Admission rejections due to a full queue (subset of `rejected`).
    pub shed_queue_full: Counter,
    /// Admission rejections while draining (subset of `rejected`).
    pub shed_draining: Counter,
    /// Accepted requests dropped before compute: TTL expired.
    pub shed_deadline: Counter,
    /// Accepted requests terminated because their worker died.
    pub worker_lost: Counter,
    /// Accepted requests terminated with `Shed::Draining` by shutdown.
    pub drained: Counter,
    /// Worker batch-loop panics caught by the supervisor.
    pub worker_panics: Counter,
    /// Successful worker restarts (fresh engine + warm-up).
    pub worker_restarts: Counter,
    pub batches: Counter,
    pub batched_rows: Counter,
    /// Streaming sessions opened by workers.
    pub sessions_opened: Counter,
    /// Streaming sessions closed by explicit client request.
    pub sessions_closed: Counter,
    /// Session step operations run (each also increments `completed`
    /// or `failed` — steps are ordinary accepted requests).
    pub session_steps: Counter,
    /// Sessions evicted after their idle TTL lapsed (state recycled
    /// without a client close).
    pub sessions_evicted: Counter,
    pub queue_wait: Histogram,
    pub inference: Histogram,
    pub e2e: Histogram,
    /// Wall time of `Coordinator::shutdown` (stop admission → workers
    /// joined → queue empty).
    pub drain: Histogram,
}

/// Snapshot for reporting.
#[derive(Clone, Debug)]
pub struct CoordinatorStats {
    pub submitted: u64,
    pub completed: u64,
    pub failed: u64,
    pub rejected: u64,
    pub shed_queue_full: u64,
    pub shed_draining: u64,
    pub shed_deadline: u64,
    pub worker_lost: u64,
    pub drained: u64,
    pub worker_panics: u64,
    pub worker_restarts: u64,
    pub batches: u64,
    pub mean_batch: f64,
    pub sessions_opened: u64,
    pub sessions_closed: u64,
    pub session_steps: u64,
    pub sessions_evicted: u64,
    pub queue_wait_p50_us: f64,
    pub inference_p50_us: f64,
    pub e2e_p50_us: f64,
    pub e2e_p99_us: f64,
    /// Workers still draining the queue (shrinks when a worker exhausts
    /// its restart budget).
    pub live_workers: usize,
    pub queue_depth: usize,
    /// Wall time of the graceful drain (0 until `shutdown` ran).
    pub drain_ms: f64,
}

impl CoordinatorStats {
    /// Accepted requests that reached a terminal state so far.
    pub fn terminal(&self) -> u64 {
        self.completed + self.failed + self.shed_deadline + self.worker_lost + self.drained
    }
}

/// Factory re-invoked by the supervisor to replace a panicked worker's
/// engine (unlike [`EngineFactory`] it is `Fn`, not `FnOnce`). Runs on
/// the worker thread — engines need not be `Send`-constructed elsewhere.
pub type RespawnFactory = Box<dyn Fn() -> anyhow::Result<Box<dyn Engine>> + Send + 'static>;

/// One worker: the startup factory plus an optional respawn factory.
/// Without a respawn factory a panicked worker is lost (its in-flight
/// requests still complete with [`Shed::WorkerLost`]).
pub struct WorkerSpec {
    pub factory: EngineFactory,
    pub respawn: Option<RespawnFactory>,
}

impl WorkerSpec {
    pub fn new(factory: EngineFactory) -> Self {
        Self {
            factory,
            respawn: None,
        }
    }

    pub fn with_respawn(factory: EngineFactory, respawn: RespawnFactory) -> Self {
        Self {
            factory,
            respawn: Some(respawn),
        }
    }
}

/// State shared between the coordinator handle and every worker thread.
struct Shared {
    queue: Channel<Request>,
    metrics: Metrics,
    shutdown: AtomicBool,
    /// Admission gate: set before `shutdown` so new submissions see
    /// `Draining` while queued work runs to completion.
    draining: AtomicBool,
    live_workers: AtomicUsize,
}

/// Per-worker parameters (identical across the pool).
#[derive(Clone)]
struct WorkerParams {
    max_batch: usize,
    deadline: Duration,
    warm_buckets: Vec<usize>,
    pad_buckets: Vec<usize>,
    restart_budget: usize,
    restart_backoff: Duration,
    /// Default streaming-session idle TTL (`Duration::ZERO` = never
    /// expire).
    session_ttl: Duration,
    /// Live streaming sessions allowed per worker.
    session_capacity: usize,
}

/// The running coordinator. Submit rows, get [`Ticket`]s; N background
/// workers (each owning its engine instance — PJRT types are not `Send`,
/// so every engine is constructed *on* its worker thread) drain a shared
/// MPMC queue in deadline-bounded batches, so a burst is served with up
/// to N batches in flight.
pub struct Coordinator {
    shared: Arc<Shared>,
    next_id: AtomicU64,
    workers: Vec<std::thread::JoinHandle<()>>,
    input_len: usize,
    output_len: usize,
    engine_name: String,
    /// Default TTL stamped on every submission (`serve.request_ttl_ms`);
    /// `None` = requests never expire unless submitted `_with_ttl`.
    default_ttl: Option<Duration>,
}

impl Coordinator {
    /// Start with a single worker thread; the engine is constructed *on*
    /// it via the factory (fails fast if the factory errors). For N
    /// workers use [`Coordinator::start_multi`] /
    /// [`Coordinator::start_replicated`]; for supervised restart-capable
    /// workers use [`Coordinator::start_supervised`].
    pub fn start(factory: EngineFactory, cfg: &ServeConfig) -> anyhow::Result<Self> {
        Self::start_multi(vec![factory], cfg)
    }

    /// Start one worker per factory, all draining the shared request
    /// queue (no respawn — a panicked worker is not replaced).
    pub fn start_multi(factories: Vec<EngineFactory>, cfg: &ServeConfig) -> anyhow::Result<Self> {
        Self::start_supervised(factories.into_iter().map(WorkerSpec::new).collect(), cfg)
    }

    /// Convenience for engines that are already `Send` (rust-native):
    /// a single worker owning the given engine.
    pub fn start_native(
        engine: impl Engine + Send + 'static,
        cfg: &ServeConfig,
    ) -> anyhow::Result<Self> {
        Self::start(Box::new(move || Ok(Box::new(engine) as Box<dyn Engine>)), cfg)
    }

    /// `cfg.workers` workers, each owning a clone of the given engine —
    /// the N-worker serving path for rust-native (cloneable) engines.
    /// Workers are fully supervised: a panicked worker is restarted with
    /// a fresh clone (re-running warm-up) within `cfg.restart_budget`.
    pub fn start_replicated<E>(engine: E, cfg: &ServeConfig) -> anyhow::Result<Self>
    where
        E: Engine + Clone + Send + 'static,
    {
        let n = cfg.workers.max(1);
        let mut specs: Vec<WorkerSpec> = Vec::with_capacity(n);
        for _ in 0..n {
            let boot = engine.clone();
            let proto = engine.clone();
            specs.push(WorkerSpec::with_respawn(
                Box::new(move || Ok(Box::new(boot) as Box<dyn Engine>)),
                Box::new(move || Ok(Box::new(proto.clone()) as Box<dyn Engine>)),
            ));
        }
        Self::start_supervised(specs, cfg)
    }

    /// Start one supervised worker per spec, all draining the shared
    /// request queue. Every factory must produce an engine of the same
    /// deployed shape — the shapes are cross-checked at startup and a
    /// mismatch (like any engine-construction failure) tears everything
    /// down and returns the error.
    pub fn start_supervised(specs: Vec<WorkerSpec>, cfg: &ServeConfig) -> anyhow::Result<Self> {
        anyhow::ensure!(!specs.is_empty(), "need at least one engine factory");
        let n_workers = specs.len();
        let shared = Arc::new(Shared {
            queue: Channel::with_capacity(cfg.queue_capacity),
            metrics: Metrics::default(),
            shutdown: AtomicBool::new(false),
            draining: AtomicBool::new(false),
            live_workers: AtomicUsize::new(n_workers),
        });
        let (meta_tx, meta_rx) =
            std::sync::mpsc::channel::<anyhow::Result<(usize, usize, String)>>();

        // Bucketed execution is opt-in ([`ServeConfig::bucketed_execution`]:
        // an explicit bucket list, or autotune under the auto backend).
        // When on, every configured bucket is warmed at startup (plans,
        // probes, arenas) and the batcher pads each collected batch up to
        // the next bucket, so engines only ever execute warmed batch
        // sizes. When off, pad rows would cost recurring compute to avoid
        // a once-per-size microsecond heuristic compile — so batches run
        // at their natural size and warm-up covers just the endpoints
        // {1, max_batch}.
        let warm_buckets = cfg.warmup_buckets();
        let pad_buckets = if cfg.bucketed_execution() {
            warm_buckets.clone()
        } else {
            Vec::new()
        };
        let params = WorkerParams {
            max_batch: cfg.max_batch.max(1),
            deadline: Duration::from_micros(cfg.batch_deadline_us),
            warm_buckets,
            pad_buckets,
            restart_budget: cfg.restart_budget,
            restart_backoff: Duration::from_millis(cfg.restart_backoff_ms),
            session_ttl: Duration::from_millis(cfg.session_ttl_ms),
            session_capacity: cfg.session_capacity.max(1),
        };
        let mut workers = Vec::with_capacity(n_workers);
        for (wi, spec) in specs.into_iter().enumerate() {
            let shared = Arc::clone(&shared);
            let params = params.clone();
            let meta_tx = meta_tx.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("swsnn-batcher-{wi}"))
                    .spawn(move || {
                        let WorkerSpec { factory, respawn } = spec;
                        let mut engine = match factory() {
                            Ok(e) => e,
                            Err(err) => {
                                let _ = meta_tx.send(Err(err));
                                shared.live_workers.fetch_sub(1, Ordering::SeqCst);
                                return;
                            }
                        };
                        if let Err(err) = engine.warmup(&params.warm_buckets) {
                            let _ = meta_tx.send(Err(err.context("engine warm-up failed")));
                            shared.live_workers.fetch_sub(1, Ordering::SeqCst);
                            return;
                        }
                        let _ = meta_tx.send(Ok((
                            engine.input_len(),
                            engine.output_len(),
                            engine.name(),
                        )));
                        drop(meta_tx);
                        supervised_loop(&shared, &params, engine, respawn)
                    })
                    .expect("spawn batcher"),
            );
        }
        drop(meta_tx);

        // One meta message per worker (or a channel hangup if its thread
        // died); fail fast on the first engine-construction error, and on
        // any shape disagreement between workers — the router validates
        // against a single deployed shape, so mixed shapes would hand
        // some batches to an engine expecting different row lengths.
        let mut meta: Option<(usize, usize, String)> = None;
        let mut error: Option<anyhow::Error> = None;
        for _ in 0..n_workers {
            match meta_rx.recv() {
                Ok(Ok(m)) => match &meta {
                    None => meta = Some(m),
                    Some(first) => {
                        if (first.0, first.1) != (m.0, m.1) && error.is_none() {
                            error = Some(anyhow::anyhow!(
                                "engine shape mismatch across workers: in/out ({}, {}) vs ({}, {})",
                                first.0,
                                first.1,
                                m.0,
                                m.1
                            ));
                        }
                    }
                },
                Ok(Err(e)) => {
                    if error.is_none() {
                        error = Some(e);
                    }
                }
                Err(_) => {
                    if error.is_none() {
                        error = Some(anyhow::anyhow!("engine thread died during construction"));
                    }
                }
            }
        }
        if let Some(err) = error {
            shared.shutdown.store(true, Ordering::SeqCst);
            shared.draining.store(true, Ordering::SeqCst);
            shared.queue.close();
            for h in workers {
                let _ = h.join();
            }
            return Err(err);
        }
        let (input_len, output_len, engine_name) = meta.expect("workers reported no metadata");

        Ok(Self {
            shared,
            next_id: AtomicU64::new(1),
            workers,
            input_len,
            output_len,
            engine_name,
            default_ttl: if cfg.request_ttl_ms == 0 {
                None
            } else {
                Some(Duration::from_millis(cfg.request_ttl_ms))
            },
        })
    }

    /// Blocking submit (applies backpressure by waiting). Stamps the
    /// configured default TTL, if any.
    pub fn submit(&self, input: Vec<f32>) -> Result<Ticket, SubmitError> {
        self.submit_inner(input, self.default_ttl, true, ReqKind::Infer)
    }

    /// Non-blocking submit; `Overloaded` when the queue is full.
    pub fn try_submit(&self, input: Vec<f32>) -> Result<Ticket, SubmitError> {
        self.submit_inner(input, self.default_ttl, false, ReqKind::Infer)
    }

    /// Blocking submit with an explicit TTL override (`None` = never
    /// expires, regardless of the configured default).
    pub fn submit_with_ttl(
        &self,
        input: Vec<f32>,
        ttl: Option<Duration>,
    ) -> Result<Ticket, SubmitError> {
        self.submit_inner(input, ttl, true, ReqKind::Infer)
    }

    /// Non-blocking submit with an explicit TTL override.
    pub fn try_submit_with_ttl(
        &self,
        input: Vec<f32>,
        ttl: Option<Duration>,
    ) -> Result<Ticket, SubmitError> {
        self.submit_inner(input, ttl, false, ReqKind::Infer)
    }

    /// Open a streaming session (idle TTL `ttl_ms`; `0` = server
    /// default). The response payload is one f32 whose **bits** are the
    /// session id — decode with `f32::to_bits`.
    pub fn open_session(&self, ttl_ms: u32) -> Result<Ticket, SubmitError> {
        self.submit_inner(Vec::new(), self.default_ttl, true, ReqKind::SessionOpen { ttl_ms })
    }

    /// Advance session `session` by a packet of input samples
    /// (interleaved `[t, c]`; any prefix of the stream, not a full
    /// row). The response carries the newly finalized output samples.
    pub fn step_session(&self, session: u32, input: Vec<f32>) -> Result<Ticket, SubmitError> {
        self.submit_inner(input, self.default_ttl, true, ReqKind::SessionStep { session })
    }

    /// Close session `session`, recycling its state slot.
    pub fn close_session(&self, session: u32) -> Result<Ticket, SubmitError> {
        self.submit_inner(Vec::new(), self.default_ttl, true, ReqKind::SessionClose { session })
    }

    fn submit_inner(
        &self,
        input: Vec<f32>,
        ttl: Option<Duration>,
        blocking: bool,
        kind: ReqKind,
    ) -> Result<Ticket, SubmitError> {
        let m = &self.shared.metrics;
        if self.shared.draining.load(Ordering::SeqCst) {
            m.rejected.inc();
            m.shed_draining.inc();
            return Err(SubmitError::Draining);
        }
        // Shape gate per request kind: full rows for stateless
        // inference; session packets are bounded by a row (the engine
        // validates channel alignment and stream overrun); control ops
        // carry no payload.
        let shape_ok = match kind {
            ReqKind::Infer => input.len() == self.input_len,
            ReqKind::SessionStep { .. } => input.len() <= self.input_len,
            ReqKind::SessionOpen { .. } | ReqKind::SessionClose { .. } => input.is_empty(),
        };
        if !shape_ok {
            m.rejected.inc();
            return Err(SubmitError::BadShape {
                expected: self.input_len,
                got: input.len(),
            });
        }
        crate::fault_point!("admission.submit");
        let slot = ResponseSlot::new();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let now = Instant::now();
        let req = Request {
            id,
            input,
            kind,
            enqueued: now,
            deadline: ttl.map(|t| now + t),
            slot: Arc::clone(&slot),
        };
        let res = if blocking {
            self.shared.queue.send(req).map_err(|e| match e {
                ChannelError::Closed => SubmitError::Closed,
                ChannelError::Full => SubmitError::Overloaded,
            })
        } else {
            self.shared.queue.try_send(req).map_err(|(_, e)| match e {
                ChannelError::Closed => SubmitError::Closed,
                ChannelError::Full => SubmitError::Overloaded,
            })
        };
        match res {
            Ok(()) => {
                m.submitted.inc();
                Ok(Ticket { id, slot })
            }
            Err(e) => {
                m.rejected.inc();
                if e == SubmitError::Overloaded {
                    m.shed_queue_full.inc();
                }
                Err(e)
            }
        }
    }

    /// Convenience: submit and wait.
    pub fn infer(&self, input: Vec<f32>) -> Result<Vec<f32>, String> {
        let ticket = self.submit(input).map_err(|e| e.to_string())?;
        ticket.wait().map_err(|e| e.to_string())
    }

    pub fn engine_name(&self) -> String {
        self.engine_name.clone()
    }

    /// Elements per output row.
    pub fn output_len(&self) -> usize {
        self.output_len
    }

    /// Elements per input row.
    pub fn input_len(&self) -> usize {
        self.input_len
    }

    pub fn queue_depth(&self) -> usize {
        self.shared.queue.len()
    }

    pub fn stats(&self) -> CoordinatorStats {
        let m = &self.shared.metrics;
        let batches = m.batches.get();
        CoordinatorStats {
            submitted: m.submitted.get(),
            completed: m.completed.get(),
            failed: m.failed.get(),
            rejected: m.rejected.get(),
            shed_queue_full: m.shed_queue_full.get(),
            shed_draining: m.shed_draining.get(),
            shed_deadline: m.shed_deadline.get(),
            worker_lost: m.worker_lost.get(),
            drained: m.drained.get(),
            worker_panics: m.worker_panics.get(),
            worker_restarts: m.worker_restarts.get(),
            batches,
            mean_batch: if batches == 0 {
                0.0
            } else {
                m.batched_rows.get() as f64 / batches as f64
            },
            sessions_opened: m.sessions_opened.get(),
            sessions_closed: m.sessions_closed.get(),
            session_steps: m.session_steps.get(),
            sessions_evicted: m.sessions_evicted.get(),
            queue_wait_p50_us: m.queue_wait.quantile_ns(0.5) / 1_000.0,
            inference_p50_us: m.inference.quantile_ns(0.5) / 1_000.0,
            e2e_p50_us: m.e2e.quantile_ns(0.5) / 1_000.0,
            e2e_p99_us: m.e2e.quantile_ns(0.99) / 1_000.0,
            live_workers: self.shared.live_workers.load(Ordering::SeqCst),
            queue_depth: self.shared.queue.len(),
            drain_ms: m.drain.mean_ns() / 1_000_000.0,
        }
    }

    /// Number of engine workers started (the pool may have degraded
    /// since — see [`CoordinatorStats::live_workers`]).
    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }

    /// Graceful shutdown: stop admission (new submissions get
    /// [`SubmitError::Draining`]), run queued work to completion, join
    /// workers, and complete any leftover requests with
    /// [`Shed::Draining`] — no waiter is ever leaked.
    pub fn shutdown(mut self) -> CoordinatorStats {
        self.shutdown_inner();
        self.stats()
    }

    fn shutdown_inner(&mut self) {
        let start = Instant::now();
        // First caller wins the drain-latency record (`drop` re-enters
        // after an explicit `shutdown`).
        let first = !self.shared.draining.swap(true, Ordering::SeqCst);
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.queue.close();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        // Live workers drained the queue to terminal responses before
        // exiting; anything still here had no worker left to run it.
        while let Some(req) = self.shared.queue.recv() {
            if req.slot.complete(Err(ServeError::Shed(Shed::Draining))) {
                self.shared.metrics.drained.inc();
            }
        }
        if first {
            self.shared.metrics.drain.record(start.elapsed());
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

/// Supervisor wrapper around [`batch_loop`]: catches panics, restarts
/// the worker with a fresh engine (re-running warm-up) within the
/// budget, and on permanent death makes sure nobody can hang on this
/// pool — the last dying worker closes the queue and completes every
/// queued request with [`Shed::WorkerLost`].
fn supervised_loop(
    shared: &Shared,
    params: &WorkerParams,
    mut engine: Box<dyn Engine>,
    respawn: Option<RespawnFactory>,
) {
    let mut restarts_used = 0usize;
    let died = loop {
        let run = catch_unwind(AssertUnwindSafe(|| {
            batch_loop(shared, params, engine.as_mut());
        }));
        match run {
            Ok(()) => break false, // clean exit: queue closed and drained
            Err(_) => {
                shared.metrics.worker_panics.inc();
                // In-flight slots were already completed with
                // `WorkerLost` by the BatchGuard during unwind. Try to
                // come back with a fresh engine.
                match respawn_engine(shared, params, respawn.as_ref(), &mut restarts_used) {
                    Some(e) => engine = e,
                    None => break true, // budget exhausted / no factory
                }
            }
        }
    };
    let remaining = shared.live_workers.fetch_sub(1, Ordering::SeqCst) - 1;
    if died && remaining == 0 {
        // Last worker is gone: nothing will ever drain the queue again.
        // Close it (senders now fail with `Closed`) and complete every
        // queued request so no submitter blocks forever.
        shared.queue.close();
        while let Some(req) = shared.queue.recv() {
            if req.slot.complete(Err(ServeError::Shed(Shed::WorkerLost))) {
                shared.metrics.worker_lost.inc();
            }
        }
    }
}

/// One restart attempt sequence: exponential backoff, fresh engine from
/// the respawn factory, warm-up. Returns `None` once the budget is
/// exhausted (or there is no factory / the coordinator is shutting
/// down with an empty queue — nothing left to serve).
fn respawn_engine(
    shared: &Shared,
    params: &WorkerParams,
    respawn: Option<&RespawnFactory>,
    restarts_used: &mut usize,
) -> Option<Box<dyn Engine>> {
    let factory = respawn?;
    while *restarts_used < params.restart_budget {
        *restarts_used += 1;
        // Exponential backoff: base × 2^(attempt-1), shift-capped.
        let backoff = params
            .restart_backoff
            .saturating_mul(1u32 << (*restarts_used - 1).min(10) as u32);
        if !backoff.is_zero() {
            std::thread::sleep(backoff);
        }
        if shared.shutdown.load(Ordering::SeqCst) && shared.queue.is_empty() {
            return None;
        }
        let attempt = catch_unwind(AssertUnwindSafe(|| {
            crate::fault_point!("supervisor.respawn");
            factory().and_then(|mut e| {
                e.warmup(&params.warm_buckets)
                    .map_err(|err| err.context("engine warm-up failed"))?;
                Ok(e)
            })
        }));
        if let Ok(Ok(e)) = attempt {
            shared.metrics.worker_restarts.inc();
            return Some(e);
        }
        // Failed attempt (factory error, warm-up error, or panic):
        // burn a budget slot and back off harder.
    }
    None
}

/// Completes every request still held by a worker batch with
/// [`Shed::WorkerLost`] when dropped mid-flight (panic unwind). On the
/// normal path all slots are already terminal, so first-wins
/// `complete` makes the drop a no-op.
struct BatchGuard<'a> {
    batch: Vec<Request>,
    metrics: &'a Metrics,
}

impl Drop for BatchGuard<'_> {
    fn drop(&mut self) {
        for req in self.batch.drain(..) {
            if req.slot.complete(Err(ServeError::Shed(Shed::WorkerLost))) {
                self.metrics.worker_lost.inc();
            }
        }
    }
}

/// Worker: collect a batch (first request blocks, then wait up to the
/// deadline for more, capped at `max_batch`), shed expired requests,
/// pad the rest up to the smallest bucket in `pad_buckets`, run the
/// engine, distribute. `pad_buckets` is sorted ascending — a subset of
/// what [`Engine::warmup`] precompiled, so padded requests only ever
/// execute warmed batch sizes; empty = no padding (batches run at their
/// natural size).
fn batch_loop(shared: &Shared, params: &WorkerParams, engine: &mut dyn Engine) {
    let queue = &shared.queue;
    let metrics = &shared.metrics;
    let row = engine.input_len();
    let out_row = engine.output_len();
    let max_batch = params.max_batch;
    // Per-worker buffer pool: the gathered input batch and the output
    // tensor recycle their allocations across requests (the engine's
    // `infer_into` recycles the intermediate activations too) instead of
    // a fresh `vec![0.0; n]` per call.
    let mut xbuf: Vec<f32> = Vec::new();
    let mut ybuf: Vec<f32> = Vec::new();
    // Streaming sessions are worker-owned: the engine holds the halo
    // state, this map holds each session's idle deadline + TTL for
    // eviction. Both die with the loop — after a worker panic the
    // respawned engine starts sessionless, and stale ids fail with a
    // typed engine error (documented single-worker requirement: with
    // N > 1 workers a step may land on a worker that doesn't own the
    // session and fail the same honest way).
    let mut sessions: HashMap<u32, (Instant, Duration)> = HashMap::new();
    let mut sbuf: Vec<f32> = Vec::new();
    loop {
        // Block for the first request. `None` means the queue is closed
        // *and* drained — nothing will ever arrive again.
        let Some(first) = queue.recv() else {
            return;
        };
        // From here until the batch is distributed the guard owns the
        // requests: if anything below panics, its Drop completes every
        // still-pending slot with `WorkerLost`.
        let mut guard = BatchGuard {
            batch: vec![first],
            metrics,
        };
        let batch = &mut guard.batch;
        // Fill until deadline or max_batch.
        let batch_deadline = Instant::now() + params.deadline;
        while batch.len() < max_batch {
            let now = Instant::now();
            if now >= batch_deadline {
                break;
            }
            // Fast path: grab whatever is queued.
            let grabbed = queue.drain_up_to(max_batch - batch.len());
            if !grabbed.is_empty() {
                batch.extend(grabbed);
                continue;
            }
            match queue.recv_timeout(batch_deadline - now) {
                Ok(Some(req)) => batch.push(req),
                Ok(None) => break,        // deadline
                Err(_) => break,          // closed: run what we have
            }
        }
        crate::fault_point!("worker.batch_collected");

        // Deadline shedding: complete expired requests with a typed
        // error *before* spending compute on them.
        let now = Instant::now();
        batch.retain(|req| {
            if req.expired(now) {
                if req.slot.complete(Err(ServeError::Shed(Shed::DeadlineExpired))) {
                    metrics.shed_deadline.inc();
                }
                false
            } else {
                true
            }
        });
        // Partition session control ops out of the infer batch. They
        // run per-request in collection order under their own panic
        // guard, so a mid-op panic still completes every pending slot
        // with `WorkerLost` (same exactly-one-terminal contract as
        // batched inference).
        let mut sess_guard = BatchGuard {
            batch: Vec::new(),
            metrics,
        };
        let mut i = 0;
        while i < batch.len() {
            if matches!(batch[i].kind, ReqKind::Infer) {
                i += 1;
            } else {
                sess_guard.batch.push(batch.remove(i));
            }
        }
        if !sess_guard.batch.is_empty() {
            run_session_ops(
                metrics,
                params,
                engine,
                &mut sess_guard.batch,
                &mut sessions,
                &mut sbuf,
            );
            sess_guard.batch.clear(); // all slots terminal — drop quietly
        }
        // Idle-TTL sweep: evict sessions nobody stepped in time. Runs
        // after the ops so an expired step sheds as `DeadlineExpired`
        // (above) rather than turning into an unknown-id error here.
        let now = Instant::now();
        sessions.retain(|&sid, &mut (deadline, _)| {
            if now >= deadline {
                let _ = engine.session_close(sid);
                metrics.sessions_evicted.inc();
                false
            } else {
                true
            }
        });

        if batch.is_empty() {
            if shared.shutdown.load(Ordering::SeqCst) && queue.is_empty() {
                return;
            }
            continue;
        }

        let b = batch.len();
        // Pad up to the smallest configured bucket ≥ b: the engine then
        // only ever executes precompiled batch sizes, so no request pays
        // plan-compile or autotune-probe latency. Rows are independent —
        // the zero pad rows change nothing and are dropped below. A
        // batch no bucket covers (or an empty pad list) runs unpadded
        // and may compile lazily, once per size.
        let bucket = params
            .pad_buckets
            .iter()
            .copied()
            .find(|&k| k >= b)
            .unwrap_or(b);
        let infer_start = Instant::now();
        for req in batch.iter() {
            metrics
                .queue_wait
                .record(infer_start.duration_since(req.enqueued));
        }
        xbuf.clear();
        xbuf.reserve(bucket * row);
        for req in batch.iter() {
            xbuf.extend_from_slice(&req.input);
        }
        xbuf.resize(bucket * row, 0.0);
        crate::fault_point!("worker.infer");
        let result = engine.infer_into(&xbuf, bucket, &mut ybuf);
        metrics.inference.record(infer_start.elapsed());
        metrics.batches.inc();
        metrics.batched_rows.add(b as u64);
        crate::fault_point!("worker.distribute");

        match result {
            Ok(()) => {
                debug_assert_eq!(ybuf.len(), bucket * out_row);
                for (i, req) in batch.iter_mut().enumerate() {
                    // Record metrics BEFORE waking the waiter so stats()
                    // observed after wait() always include this request.
                    metrics.completed.inc();
                    metrics.e2e.record(req.enqueued.elapsed());
                    // Hand the input buffer back (before `complete` —
                    // the waiter may reclaim as soon as it wakes) so the
                    // transport can reuse the allocation.
                    req.slot.return_input(std::mem::take(&mut req.input));
                    req.slot
                        .complete(Ok(ybuf[i * out_row..(i + 1) * out_row].to_vec()));
                }
            }
            Err(e) => {
                let msg = format!("inference failed: {e:#}");
                for req in batch.iter_mut() {
                    metrics.failed.inc();
                    req.slot.return_input(std::mem::take(&mut req.input));
                    req.slot.complete(Err(ServeError::Engine(msg.clone())));
                }
            }
        }
        if shared.shutdown.load(Ordering::SeqCst) && queue.is_empty() {
            return;
        }
    }
}

/// Run a collected slice of session control ops in order, completing
/// every slot. Requests stay owned by the caller's guard: if this
/// panics mid-op, the guard completes the rest with [`Shed::WorkerLost`]
/// (already-completed slots are first-wins no-ops).
///
/// Ledger accounting mirrors the infer path: success → `completed`,
/// engine failure (including unknown ids and capacity exhaustion) →
/// `failed`, idle-TTL-expired step → `shed_deadline` — so
/// `CoordinatorStats::terminal()` stays exact with sessions in play.
fn run_session_ops(
    metrics: &Metrics,
    params: &WorkerParams,
    engine: &mut dyn Engine,
    ops: &mut [Request],
    sessions: &mut HashMap<u32, (Instant, Duration)>,
    sbuf: &mut Vec<f32>,
) {
    for req in ops.iter_mut() {
        let now = Instant::now();
        match req.kind {
            ReqKind::Infer => unreachable!("infer requests are batched, not session ops"),
            ReqKind::SessionOpen { ttl_ms } => {
                if engine.live_sessions() >= params.session_capacity {
                    metrics.failed.inc();
                    req.slot.complete(Err(ServeError::Engine(format!(
                        "session capacity ({}) exhausted",
                        params.session_capacity
                    ))));
                    continue;
                }
                match engine.session_open() {
                    Ok(sid) => {
                        let ttl = if ttl_ms == 0 {
                            params.session_ttl
                        } else {
                            Duration::from_millis(u64::from(ttl_ms))
                        };
                        // ZERO TTL (from config) = never expire: park the
                        // deadline far out and never refresh-check it.
                        let deadline = if ttl.is_zero() {
                            now + Duration::from_secs(u64::MAX / 4)
                        } else {
                            now + ttl
                        };
                        sessions.insert(sid, (deadline, ttl));
                        metrics.sessions_opened.inc();
                        metrics.completed.inc();
                        metrics.e2e.record(req.enqueued.elapsed());
                        req.slot.complete(Ok(vec![f32::from_bits(sid)]));
                    }
                    Err(e) => {
                        metrics.failed.inc();
                        req.slot.complete(Err(ServeError::Engine(format!(
                            "session open failed: {e:#}"
                        ))));
                    }
                }
            }
            ReqKind::SessionStep { session } => {
                crate::fault_point!("worker.session_step");
                let Some(&(deadline, ttl)) = sessions.get(&session) else {
                    metrics.failed.inc();
                    req.slot.complete(Err(ServeError::Engine(format!(
                        "unknown session id {session}"
                    ))));
                    continue;
                };
                if now >= deadline {
                    // Idle TTL lapsed: recycle the state and shed the
                    // step through the standard deadline taxonomy.
                    sessions.remove(&session);
                    let _ = engine.session_close(session);
                    metrics.sessions_evicted.inc();
                    metrics.shed_deadline.inc();
                    req.slot.complete(Err(ServeError::Shed(Shed::DeadlineExpired)));
                    continue;
                }
                match engine.session_step(session, &req.input, sbuf) {
                    Ok(_) => {
                        if !ttl.is_zero() {
                            sessions.insert(session, (now + ttl, ttl));
                        }
                        metrics.session_steps.inc();
                        metrics.completed.inc();
                        metrics.e2e.record(req.enqueued.elapsed());
                        // Return the packet buffer (before `complete`)
                        // so the transport reuses the allocation.
                        req.slot.return_input(std::mem::take(&mut req.input));
                        req.slot.complete(Ok(sbuf.clone()));
                    }
                    Err(e) => {
                        metrics.failed.inc();
                        req.slot.return_input(std::mem::take(&mut req.input));
                        req.slot.complete(Err(ServeError::Engine(format!(
                            "session step failed: {e:#}"
                        ))));
                    }
                }
            }
            ReqKind::SessionClose { session } => {
                sessions.remove(&session);
                match engine.session_close(session) {
                    Ok(()) => {
                        metrics.sessions_closed.inc();
                        metrics.completed.inc();
                        metrics.e2e.record(req.enqueued.elapsed());
                        req.slot.complete(Ok(Vec::new()));
                    }
                    Err(e) => {
                        metrics.failed.inc();
                        req.slot.complete(Err(ServeError::Engine(format!(
                            "session close failed: {e:#}"
                        ))));
                    }
                }
            }
        }
    }
}
