//! The coordinator core: bounded queue + deadline batcher + worker loop.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::config::ServeConfig;
use crate::exec::{Channel, ChannelError};
use crate::telemetry::{Counter, Histogram};

use super::engine::{Engine, EngineFactory};
use super::{Request, ResponseSlot, Ticket};

/// Submission failure modes surfaced to clients.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// Queue full — backpressure; client should retry/shed.
    Overloaded,
    /// Coordinator shut down.
    Closed,
    /// Input row has the wrong length for the deployed model.
    BadShape { expected: usize, got: usize },
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Overloaded => write!(f, "queue full (backpressure)"),
            SubmitError::Closed => write!(f, "coordinator closed"),
            SubmitError::BadShape { expected, got } => {
                write!(f, "bad input shape: expected {expected} floats, got {got}")
            }
        }
    }
}

/// Aggregated serving metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    pub submitted: Counter,
    pub completed: Counter,
    pub rejected: Counter,
    pub batches: Counter,
    pub batched_rows: Counter,
    pub queue_wait: Histogram,
    pub inference: Histogram,
    pub e2e: Histogram,
}

/// Snapshot for reporting.
#[derive(Clone, Debug)]
pub struct CoordinatorStats {
    pub submitted: u64,
    pub completed: u64,
    pub rejected: u64,
    pub batches: u64,
    pub mean_batch: f64,
    pub queue_wait_p50_us: f64,
    pub inference_p50_us: f64,
    pub e2e_p50_us: f64,
    pub e2e_p99_us: f64,
}

/// The running coordinator. Submit rows, get [`Ticket`]s; N background
/// workers (each owning its engine instance — PJRT types are not `Send`,
/// so every engine is constructed *on* its worker thread) drain a shared
/// MPMC queue in deadline-bounded batches, so a burst is served with up
/// to N batches in flight.
pub struct Coordinator {
    queue: Arc<Channel<Request>>,
    metrics: Arc<Metrics>,
    next_id: AtomicU64,
    shutdown: Arc<AtomicBool>,
    workers: Vec<std::thread::JoinHandle<()>>,
    input_len: usize,
    output_len: usize,
    engine_name: String,
}

impl Coordinator {
    /// Start with a single worker thread; the engine is constructed *on*
    /// it via the factory (fails fast if the factory errors). For N
    /// workers use [`Coordinator::start_multi`] /
    /// [`Coordinator::start_replicated`].
    pub fn start(factory: EngineFactory, cfg: &ServeConfig) -> anyhow::Result<Self> {
        Self::start_multi(vec![factory], cfg)
    }

    /// Start one worker per factory, all draining the shared request
    /// queue. Every factory must produce an engine of the same deployed
    /// shape — the shapes are cross-checked at startup and a mismatch
    /// (like any engine-construction failure) tears everything down and
    /// returns the error.
    pub fn start_multi(factories: Vec<EngineFactory>, cfg: &ServeConfig) -> anyhow::Result<Self> {
        anyhow::ensure!(!factories.is_empty(), "need at least one engine factory");
        let queue: Arc<Channel<Request>> = Channel::new(cfg.queue_capacity);
        let metrics = Arc::new(Metrics::default());
        let shutdown = Arc::new(AtomicBool::new(false));
        let (meta_tx, meta_rx) =
            std::sync::mpsc::channel::<anyhow::Result<(usize, usize, String)>>();

        let n_workers = factories.len();
        // Bucketed execution is opt-in ([`ServeConfig::bucketed_execution`]:
        // an explicit bucket list, or autotune under the auto backend).
        // When on, every configured bucket is warmed at startup (plans,
        // probes, arenas) and the batcher pads each collected batch up to
        // the next bucket, so engines only ever execute warmed batch
        // sizes. When off, pad rows would cost recurring compute to avoid
        // a once-per-size microsecond heuristic compile — so batches run
        // at their natural size and warm-up covers just the endpoints
        // {1, max_batch}.
        let warm_buckets = cfg.warmup_buckets();
        let pad_buckets = if cfg.bucketed_execution() {
            warm_buckets.clone()
        } else {
            Vec::new()
        };
        let mut workers = Vec::with_capacity(n_workers);
        for (wi, factory) in factories.into_iter().enumerate() {
            let queue = Arc::clone(&queue);
            let metrics = Arc::clone(&metrics);
            let shutdown = Arc::clone(&shutdown);
            let meta_tx = meta_tx.clone();
            let warm_buckets = warm_buckets.clone();
            let pad_buckets = pad_buckets.clone();
            let max_batch = cfg.max_batch.max(1);
            let deadline = Duration::from_micros(cfg.batch_deadline_us);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("swsnn-batcher-{wi}"))
                    .spawn(move || {
                        let mut engine = match factory() {
                            Ok(e) => e,
                            Err(err) => {
                                let _ = meta_tx.send(Err(err));
                                return;
                            }
                        };
                        if let Err(err) = engine.warmup(&warm_buckets) {
                            let _ = meta_tx.send(Err(err.context("engine warm-up failed")));
                            return;
                        }
                        let _ = meta_tx.send(Ok((
                            engine.input_len(),
                            engine.output_len(),
                            engine.name(),
                        )));
                        drop(meta_tx);
                        batch_loop(
                            queue,
                            engine,
                            metrics,
                            shutdown,
                            max_batch,
                            deadline,
                            pad_buckets,
                        )
                    })
                    .expect("spawn batcher"),
            );
        }
        drop(meta_tx);

        // One meta message per worker (or a channel hangup if its thread
        // died); fail fast on the first engine-construction error, and on
        // any shape disagreement between workers — the router validates
        // against a single deployed shape, so mixed shapes would hand
        // some batches to an engine expecting different row lengths.
        let mut meta: Option<(usize, usize, String)> = None;
        let mut error: Option<anyhow::Error> = None;
        for _ in 0..n_workers {
            match meta_rx.recv() {
                Ok(Ok(m)) => match &meta {
                    None => meta = Some(m),
                    Some(first) => {
                        if (first.0, first.1) != (m.0, m.1) && error.is_none() {
                            error = Some(anyhow::anyhow!(
                                "engine shape mismatch across workers: in/out ({}, {}) vs ({}, {})",
                                first.0,
                                first.1,
                                m.0,
                                m.1
                            ));
                        }
                    }
                },
                Ok(Err(e)) => {
                    if error.is_none() {
                        error = Some(e);
                    }
                }
                Err(_) => {
                    if error.is_none() {
                        error = Some(anyhow::anyhow!("engine thread died during construction"));
                    }
                }
            }
        }
        if let Some(err) = error {
            shutdown.store(true, Ordering::SeqCst);
            queue.close();
            for h in workers {
                let _ = h.join();
            }
            return Err(err);
        }
        let (input_len, output_len, engine_name) = meta.expect("workers reported no metadata");

        Ok(Self {
            queue,
            metrics,
            next_id: AtomicU64::new(1),
            shutdown,
            workers,
            input_len,
            output_len,
            engine_name,
        })
    }

    /// Convenience for engines that are already `Send` (rust-native):
    /// a single worker owning the given engine.
    pub fn start_native(
        engine: impl Engine + Send + 'static,
        cfg: &ServeConfig,
    ) -> anyhow::Result<Self> {
        Self::start(Box::new(move || Ok(Box::new(engine) as Box<dyn Engine>)), cfg)
    }

    /// `cfg.workers` workers, each owning a clone of the given engine —
    /// the N-worker serving path for rust-native (cloneable) engines.
    pub fn start_replicated<E>(engine: E, cfg: &ServeConfig) -> anyhow::Result<Self>
    where
        E: Engine + Clone + Send + 'static,
    {
        let n = cfg.workers.max(1);
        let mut factories: Vec<EngineFactory> = Vec::with_capacity(n);
        for _ in 0..n {
            let e = engine.clone();
            factories.push(Box::new(move || Ok(Box::new(e) as Box<dyn Engine>)));
        }
        Self::start_multi(factories, cfg)
    }

    /// Blocking submit (applies backpressure by waiting).
    pub fn submit(&self, input: Vec<f32>) -> Result<Ticket, SubmitError> {
        self.submit_inner(input, true)
    }

    /// Non-blocking submit; `Overloaded` when the queue is full.
    pub fn try_submit(&self, input: Vec<f32>) -> Result<Ticket, SubmitError> {
        self.submit_inner(input, false)
    }

    fn submit_inner(&self, input: Vec<f32>, blocking: bool) -> Result<Ticket, SubmitError> {
        if input.len() != self.input_len {
            self.metrics.rejected.inc();
            return Err(SubmitError::BadShape {
                expected: self.input_len,
                got: input.len(),
            });
        }
        let slot = ResponseSlot::new();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let req = Request {
            id,
            input,
            enqueued: Instant::now(),
            slot: Arc::clone(&slot),
        };
        let res = if blocking {
            self.queue.send(req).map_err(|e| match e {
                ChannelError::Closed => SubmitError::Closed,
                ChannelError::Full => SubmitError::Overloaded,
            })
        } else {
            self.queue.try_send(req).map_err(|(_, e)| match e {
                ChannelError::Closed => SubmitError::Closed,
                ChannelError::Full => SubmitError::Overloaded,
            })
        };
        match res {
            Ok(()) => {
                self.metrics.submitted.inc();
                Ok(Ticket { id, slot })
            }
            Err(e) => {
                self.metrics.rejected.inc();
                Err(e)
            }
        }
    }

    /// Convenience: submit and wait.
    pub fn infer(&self, input: Vec<f32>) -> Result<Vec<f32>, String> {
        let ticket = self.submit(input).map_err(|e| e.to_string())?;
        ticket.wait()
    }

    pub fn engine_name(&self) -> String {
        self.engine_name.clone()
    }

    /// Elements per output row.
    pub fn output_len(&self) -> usize {
        self.output_len
    }

    /// Elements per input row.
    pub fn input_len(&self) -> usize {
        self.input_len
    }

    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    pub fn stats(&self) -> CoordinatorStats {
        let m = &self.metrics;
        let batches = m.batches.get();
        CoordinatorStats {
            submitted: m.submitted.get(),
            completed: m.completed.get(),
            rejected: m.rejected.get(),
            batches,
            mean_batch: if batches == 0 {
                0.0
            } else {
                m.batched_rows.get() as f64 / batches as f64
            },
            queue_wait_p50_us: m.queue_wait.quantile_ns(0.5) / 1_000.0,
            inference_p50_us: m.inference.quantile_ns(0.5) / 1_000.0,
            e2e_p50_us: m.e2e.quantile_ns(0.5) / 1_000.0,
            e2e_p99_us: m.e2e.quantile_ns(0.99) / 1_000.0,
        }
    }

    /// Number of engine workers draining the queue.
    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }

    /// Graceful shutdown: drain the queue, stop all workers.
    pub fn shutdown(mut self) -> CoordinatorStats {
        self.shutdown_inner();
        self.stats()
    }

    fn shutdown_inner(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        self.queue.close();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

/// Worker: collect a batch (first request blocks, then wait up to the
/// deadline for more, capped at `max_batch`), pad it up to the smallest
/// bucket in `pad_buckets`, run the engine, distribute. `pad_buckets`
/// is sorted ascending — a subset of what [`Engine::warmup`]
/// precompiled, so padded requests only ever execute warmed batch
/// sizes; empty = no padding (batches run at their natural size).
#[allow(clippy::too_many_arguments)]
fn batch_loop(
    queue: Arc<Channel<Request>>,
    mut engine: Box<dyn Engine>,
    metrics: Arc<Metrics>,
    shutdown: Arc<AtomicBool>,
    max_batch: usize,
    deadline: Duration,
    pad_buckets: Vec<usize>,
) {
    let row = engine.input_len();
    let out_row = engine.output_len();
    // Per-worker buffer pool: the gathered input batch and the output
    // tensor recycle their allocations across requests (the engine's
    // `infer_into` recycles the intermediate activations too) instead of
    // a fresh `vec![0.0; n]` per call.
    let mut xbuf: Vec<f32> = Vec::new();
    let mut ybuf: Vec<f32> = Vec::new();
    loop {
        // Block for the first request. `None` means the queue is closed
        // *and* drained — nothing will ever arrive again.
        let Some(first) = queue.recv() else {
            return;
        };
        let mut batch = vec![first];
        // Fill until deadline or max_batch.
        let batch_deadline = Instant::now() + deadline;
        while batch.len() < max_batch {
            let now = Instant::now();
            if now >= batch_deadline {
                break;
            }
            // Fast path: grab whatever is queued.
            let grabbed = queue.drain_up_to(max_batch - batch.len());
            if !grabbed.is_empty() {
                batch.extend(grabbed);
                continue;
            }
            match queue.recv_timeout(batch_deadline - now) {
                Ok(Some(req)) => batch.push(req),
                Ok(None) => break,        // deadline
                Err(_) => break,          // closed: run what we have
            }
        }

        let b = batch.len();
        // Pad up to the smallest configured bucket ≥ b: the engine then
        // only ever executes precompiled batch sizes, so no request pays
        // plan-compile or autotune-probe latency. Rows are independent —
        // the zero pad rows change nothing and are dropped below. A
        // batch no bucket covers (or an empty pad list) runs unpadded
        // and may compile lazily, once per size.
        let bucket = pad_buckets.iter().copied().find(|&k| k >= b).unwrap_or(b);
        let infer_start = Instant::now();
        for req in &batch {
            metrics
                .queue_wait
                .record(infer_start.duration_since(req.enqueued));
        }
        xbuf.clear();
        xbuf.reserve(bucket * row);
        for req in &batch {
            xbuf.extend_from_slice(&req.input);
        }
        xbuf.resize(bucket * row, 0.0);
        let result = engine.infer_into(&xbuf, bucket, &mut ybuf);
        metrics.inference.record(infer_start.elapsed());
        metrics.batches.inc();
        metrics.batched_rows.add(b as u64);

        match result {
            Ok(()) => {
                debug_assert_eq!(ybuf.len(), bucket * out_row);
                for (i, req) in batch.iter().enumerate() {
                    // Record metrics BEFORE waking the waiter so stats()
                    // observed after wait() always include this request.
                    metrics.completed.inc();
                    metrics.e2e.record(req.enqueued.elapsed());
                    req.slot
                        .fill(Ok(ybuf[i * out_row..(i + 1) * out_row].to_vec()));
                }
            }
            Err(e) => {
                let msg = format!("inference failed: {e:#}");
                for req in &batch {
                    req.slot.fill(Err(msg.clone()));
                }
            }
        }
        if shutdown.load(Ordering::SeqCst) && queue.is_empty() {
            return;
        }
    }
}
