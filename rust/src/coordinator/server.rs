//! TCP front-end: length-prefixed f32 frames over a blocking socket.
//!
//! Wire format (little-endian):
//!   request:  u32 n | u32 ttl_ms | n × f32     (one input row; ttl_ms 0 = no deadline)
//!   response: u8 tag | u32 n | payload
//!
//! Session frames reuse the same channel, keyed by a magic first word
//! that can never be a valid row length (row lengths are capped at
//! `1 << 22` floats; the magics sit at the top of the u32 range):
//!   open:  u32 0xFFFF_FF01 | u32 ttl_ms              → ok payload: 1 × f32 (bits = session id)
//!   step:  u32 0xFFFF_FF02 | u32 id | u32 n | n × f32 → ok payload: newly final output samples
//!   close: u32 0xFFFF_FF03 | u32 id                  → ok payload: empty
//!
//! Response tags (see [`super::ServeError::wire_code`] /
//! [`super::SubmitError::wire_code`] — payload is a utf8 message for
//! every non-zero tag):
//!   0 ok (payload: n × f32 output row)
//!   1 engine error          2 bad input shape
//!   3 shed: queue full      4 shed: deadline expired
//!   5 shed: draining        6 shed: worker lost
//!   7 coordinator closed
//!
//! One thread per connection (the workload is CPU-bound inference; the
//! batcher serializes actual compute, so connection threads just park).
//! Sockets carry read/write timeouts so a stalled or hostile peer can't
//! pin its thread forever, and each connection reuses one frame buffer
//! for reads and one for writes instead of allocating per request.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use super::Coordinator;

/// Per-connection socket read/write timeout. A peer that stalls longer
/// than this mid-frame gets its connection dropped (the thread exits)
/// instead of parking forever.
const SOCKET_TIMEOUT: Duration = Duration::from_secs(30);

/// Magic first word of a session-open frame. All session magics exceed
/// the `1 << 22` row-length cap, so they can never collide with an
/// inference frame's length prefix.
pub const SESSION_OPEN_MAGIC: u32 = 0xFFFF_FF01;
/// Magic first word of a session-step frame.
pub const SESSION_STEP_MAGIC: u32 = 0xFFFF_FF02;
/// Magic first word of a session-close frame.
pub const SESSION_CLOSE_MAGIC: u32 = 0xFFFF_FF03;

fn read_exact_u32(stream: &mut TcpStream) -> std::io::Result<u32> {
    let mut buf = [0u8; 4];
    stream.read_exact(&mut buf)?;
    Ok(u32::from_le_bytes(buf))
}

/// One decoded request frame; float payloads land in the caller's
/// reused `row` buffer.
enum Frame {
    Infer { ttl: Option<Duration> },
    Open { ttl_ms: u32 },
    Step { session: u32 },
    Close { session: u32 },
}

/// Read the `n × f32` payload section into the reused buffers.
fn read_floats(
    stream: &mut TcpStream,
    n: u32,
    bytes: &mut Vec<u8>,
    row: &mut Vec<f32>,
) -> Result<()> {
    bytes.clear();
    bytes.resize(n as usize * 4, 0);
    stream.read_exact(bytes)?;
    row.clear();
    row.reserve(n as usize);
    for chunk in bytes.chunks_exact(4) {
        row.push(f32::from_le_bytes(chunk.try_into().unwrap()));
    }
    Ok(())
}

/// Read one request frame into the reused buffers: `bytes` holds the
/// raw payload, `row` the decoded floats. Returns the decoded frame, or
/// `None` on a clean EOF at a frame boundary.
fn read_frame(
    stream: &mut TcpStream,
    max_floats: u32,
    bytes: &mut Vec<u8>,
    row: &mut Vec<f32>,
) -> Result<Option<Frame>> {
    let head = match read_exact_u32(stream) {
        Ok(n) => n,
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(e.into()),
    };
    row.clear();
    match head {
        SESSION_OPEN_MAGIC => {
            let ttl_ms = read_exact_u32(stream)?;
            return Ok(Some(Frame::Open { ttl_ms }));
        }
        SESSION_CLOSE_MAGIC => {
            let session = read_exact_u32(stream)?;
            return Ok(Some(Frame::Close { session }));
        }
        SESSION_STEP_MAGIC => {
            let session = read_exact_u32(stream)?;
            let n = read_exact_u32(stream)?;
            if n > max_floats {
                bail!("frame of {n} floats exceeds limit {max_floats}");
            }
            read_floats(stream, n, bytes, row)?;
            return Ok(Some(Frame::Step { session }));
        }
        _ => {}
    }
    let n = head;
    if n > max_floats {
        bail!("frame of {n} floats exceeds limit {max_floats}");
    }
    let ttl_ms = read_exact_u32(stream)?;
    read_floats(stream, n, bytes, row)?;
    Ok(Some(Frame::Infer {
        ttl: if ttl_ms == 0 {
            None
        } else {
            Some(Duration::from_millis(ttl_ms as u64))
        },
    }))
}

fn write_ok(stream: &mut TcpStream, buf: &mut Vec<u8>, row: &[f32]) -> std::io::Result<()> {
    buf.clear();
    buf.push(0u8);
    buf.extend_from_slice(&(row.len() as u32).to_le_bytes());
    for v in row {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    stream.write_all(buf)
}

fn write_err(
    stream: &mut TcpStream,
    buf: &mut Vec<u8>,
    code: u8,
    msg: &str,
) -> std::io::Result<()> {
    let bytes = msg.as_bytes();
    buf.clear();
    buf.push(code);
    buf.extend_from_slice(&(bytes.len() as u32).to_le_bytes());
    buf.extend_from_slice(bytes);
    stream.write_all(buf)
}

/// Serve until `stop` is set (checked between accepts). Returns the bound
/// address immediately via the callback so tests can connect.
pub fn serve_tcp(
    coordinator: Arc<Coordinator>,
    addr: &str,
    stop: Arc<AtomicBool>,
    on_bound: impl FnOnce(std::net::SocketAddr),
) -> Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    listener.set_nonblocking(true)?;
    on_bound(listener.local_addr()?);
    let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                stream.set_nonblocking(false)?;
                stream.set_read_timeout(Some(SOCKET_TIMEOUT))?;
                stream.set_write_timeout(Some(SOCKET_TIMEOUT))?;
                let coord = Arc::clone(&coordinator);
                conns.push(std::thread::spawn(move || {
                    let _ = handle_conn(stream, coord);
                }));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            Err(e) => return Err(e.into()),
        }
    }
    for c in conns {
        let _ = c.join();
    }
    Ok(())
}

fn handle_conn(mut stream: TcpStream, coord: Arc<Coordinator>) -> Result<()> {
    let max = 1 << 22; // 16 MiB of floats per frame is plenty
    // Reused across every request on this connection.
    let mut rbytes: Vec<u8> = Vec::new();
    let mut row: Vec<f32> = Vec::new();
    let mut wbuf: Vec<u8> = Vec::new();
    while let Some(frame) = read_frame(&mut stream, max, &mut rbytes, &mut row)? {
        let submitted = match frame {
            // A wire TTL of 0 falls back to the coordinator's configured
            // default (plain `try_submit`); a nonzero TTL overrides it.
            Frame::Infer { ttl: Some(t) } => coord.try_submit_with_ttl(row.clone(), Some(t)),
            Frame::Infer { ttl: None } => coord.try_submit(row.clone()),
            Frame::Open { ttl_ms } => coord.open_session(ttl_ms),
            Frame::Step { session } => coord.step_session(session, row.clone()),
            Frame::Close { session } => coord.close_session(session),
        };
        match submitted {
            Ok(ticket) => match ticket.wait() {
                Ok(out) => write_ok(&mut stream, &mut wbuf, &out)?,
                Err(e) => write_err(&mut stream, &mut wbuf, e.wire_code(), &e.to_string())?,
            },
            Err(e) => write_err(&mut stream, &mut wbuf, e.wire_code(), &e.to_string())?,
        }
    }
    Ok(())
}

/// Blocking client for examples/tests/benches.
pub struct TcpClient {
    stream: TcpStream,
}

impl TcpClient {
    pub fn connect(addr: std::net::SocketAddr) -> Result<Self> {
        Ok(Self {
            stream: TcpStream::connect(addr)?,
        })
    }

    /// Send one row, wait for the response.
    pub fn infer(&mut self, row: &[f32]) -> Result<Vec<f32>> {
        self.infer_with_ttl(row, None)
    }

    /// Send one row with a per-request TTL; the server sheds the
    /// request with a typed error if it can't start compute in time.
    pub fn infer_with_ttl(&mut self, row: &[f32], ttl: Option<Duration>) -> Result<Vec<f32>> {
        let ttl_ms: u32 = ttl.map_or(0, |t| t.as_millis().clamp(1, u32::MAX as u128) as u32);
        let mut buf = Vec::with_capacity(8 + row.len() * 4);
        buf.extend_from_slice(&(row.len() as u32).to_le_bytes());
        buf.extend_from_slice(&ttl_ms.to_le_bytes());
        for v in row {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        self.stream.write_all(&buf)?;
        self.read_response()
    }

    /// Open a streaming session; `ttl` is the *idle* TTL between steps
    /// (`None` = server default). Returns the session id.
    pub fn session_open(&mut self, ttl: Option<Duration>) -> Result<u32> {
        let ttl_ms: u32 = ttl.map_or(0, |t| t.as_millis().clamp(1, u32::MAX as u128) as u32);
        let mut buf = Vec::with_capacity(8);
        buf.extend_from_slice(&SESSION_OPEN_MAGIC.to_le_bytes());
        buf.extend_from_slice(&ttl_ms.to_le_bytes());
        self.stream.write_all(&buf)?;
        let out = self.read_response()?;
        // The id rides as the raw bit pattern of one f32 — bit-exact
        // through serialization, unlike a numeric cast.
        if out.len() != 1 {
            bail!("session open returned {} floats, expected 1", out.len());
        }
        Ok(out[0].to_bits())
    }

    /// Push a packet of input samples (interleaved `[t, c]`) into the
    /// session; returns the newly finalized output samples (interleaved,
    /// possibly empty).
    pub fn session_step(&mut self, session: u32, packet: &[f32]) -> Result<Vec<f32>> {
        let mut buf = Vec::with_capacity(12 + packet.len() * 4);
        buf.extend_from_slice(&SESSION_STEP_MAGIC.to_le_bytes());
        buf.extend_from_slice(&session.to_le_bytes());
        buf.extend_from_slice(&(packet.len() as u32).to_le_bytes());
        for v in packet {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        self.stream.write_all(&buf)?;
        self.read_response()
    }

    /// Close the session, recycling its server-side state.
    pub fn session_close(&mut self, session: u32) -> Result<()> {
        let mut buf = Vec::with_capacity(8);
        buf.extend_from_slice(&SESSION_CLOSE_MAGIC.to_le_bytes());
        buf.extend_from_slice(&session.to_le_bytes());
        self.stream.write_all(&buf)?;
        self.read_response().map(|_| ())
    }

    fn read_response(&mut self) -> Result<Vec<f32>> {
        let mut tag = [0u8; 1];
        self.stream.read_exact(&mut tag)?;
        let mut len = [0u8; 4];
        self.stream.read_exact(&mut len)?;
        let n = u32::from_le_bytes(len) as usize;
        if tag[0] == 0 {
            let mut bytes = vec![0u8; n * 4];
            self.stream.read_exact(&mut bytes)?;
            Ok(bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect())
        } else {
            let mut bytes = vec![0u8; n];
            self.stream.read_exact(&mut bytes)?;
            bail!(
                "server error (code {}): {}",
                tag[0],
                String::from_utf8_lossy(&bytes)
            )
        }
    }
}
