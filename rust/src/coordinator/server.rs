//! TCP front-end: length-prefixed f32 frames over a blocking socket.
//!
//! Wire format (little-endian):
//!   request:  u32 n | u32 ttl_ms | n × f32     (one input row; ttl_ms 0 = no deadline)
//!   response: u8 tag | u32 n | payload
//!
//! Response tags (see [`super::ServeError::wire_code`] /
//! [`super::SubmitError::wire_code`] — payload is a utf8 message for
//! every non-zero tag):
//!   0 ok (payload: n × f32 output row)
//!   1 engine error          2 bad input shape
//!   3 shed: queue full      4 shed: deadline expired
//!   5 shed: draining        6 shed: worker lost
//!   7 coordinator closed
//!
//! One thread per connection (the workload is CPU-bound inference; the
//! batcher serializes actual compute, so connection threads just park).
//! Sockets carry read/write timeouts so a stalled or hostile peer can't
//! pin its thread forever, and each connection reuses one frame buffer
//! for reads and one for writes instead of allocating per request.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use super::Coordinator;

/// Per-connection socket read/write timeout. A peer that stalls longer
/// than this mid-frame gets its connection dropped (the thread exits)
/// instead of parking forever.
const SOCKET_TIMEOUT: Duration = Duration::from_secs(30);

fn read_exact_u32(stream: &mut TcpStream) -> std::io::Result<u32> {
    let mut buf = [0u8; 4];
    stream.read_exact(&mut buf)?;
    Ok(u32::from_le_bytes(buf))
}

/// Read one request frame into the reused buffers: `bytes` holds the
/// raw payload, `row` the decoded floats. Returns the TTL field, or
/// `None` on a clean EOF at a frame boundary.
fn read_frame(
    stream: &mut TcpStream,
    max_floats: u32,
    bytes: &mut Vec<u8>,
    row: &mut Vec<f32>,
) -> Result<Option<Option<Duration>>> {
    let n = match read_exact_u32(stream) {
        Ok(n) => n,
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(e.into()),
    };
    if n > max_floats {
        bail!("frame of {n} floats exceeds limit {max_floats}");
    }
    let ttl_ms = read_exact_u32(stream)?;
    bytes.clear();
    bytes.resize(n as usize * 4, 0);
    stream.read_exact(bytes)?;
    row.clear();
    row.reserve(n as usize);
    for chunk in bytes.chunks_exact(4) {
        row.push(f32::from_le_bytes(chunk.try_into().unwrap()));
    }
    Ok(Some(if ttl_ms == 0 {
        None
    } else {
        Some(Duration::from_millis(ttl_ms as u64))
    }))
}

fn write_ok(stream: &mut TcpStream, buf: &mut Vec<u8>, row: &[f32]) -> std::io::Result<()> {
    buf.clear();
    buf.push(0u8);
    buf.extend_from_slice(&(row.len() as u32).to_le_bytes());
    for v in row {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    stream.write_all(buf)
}

fn write_err(
    stream: &mut TcpStream,
    buf: &mut Vec<u8>,
    code: u8,
    msg: &str,
) -> std::io::Result<()> {
    let bytes = msg.as_bytes();
    buf.clear();
    buf.push(code);
    buf.extend_from_slice(&(bytes.len() as u32).to_le_bytes());
    buf.extend_from_slice(bytes);
    stream.write_all(buf)
}

/// Serve until `stop` is set (checked between accepts). Returns the bound
/// address immediately via the callback so tests can connect.
pub fn serve_tcp(
    coordinator: Arc<Coordinator>,
    addr: &str,
    stop: Arc<AtomicBool>,
    on_bound: impl FnOnce(std::net::SocketAddr),
) -> Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    listener.set_nonblocking(true)?;
    on_bound(listener.local_addr()?);
    let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                stream.set_nonblocking(false)?;
                stream.set_read_timeout(Some(SOCKET_TIMEOUT))?;
                stream.set_write_timeout(Some(SOCKET_TIMEOUT))?;
                let coord = Arc::clone(&coordinator);
                conns.push(std::thread::spawn(move || {
                    let _ = handle_conn(stream, coord);
                }));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            Err(e) => return Err(e.into()),
        }
    }
    for c in conns {
        let _ = c.join();
    }
    Ok(())
}

fn handle_conn(mut stream: TcpStream, coord: Arc<Coordinator>) -> Result<()> {
    let max = 1 << 22; // 16 MiB of floats per frame is plenty
    // Reused across every request on this connection.
    let mut rbytes: Vec<u8> = Vec::new();
    let mut row: Vec<f32> = Vec::new();
    let mut wbuf: Vec<u8> = Vec::new();
    while let Some(ttl) = read_frame(&mut stream, max, &mut rbytes, &mut row)? {
        // A wire TTL of 0 falls back to the coordinator's configured
        // default (plain `try_submit`); a nonzero TTL overrides it.
        let submitted = match ttl {
            Some(t) => coord.try_submit_with_ttl(row.clone(), Some(t)),
            None => coord.try_submit(row.clone()),
        };
        match submitted {
            Ok(ticket) => match ticket.wait() {
                Ok(out) => write_ok(&mut stream, &mut wbuf, &out)?,
                Err(e) => write_err(&mut stream, &mut wbuf, e.wire_code(), &e.to_string())?,
            },
            Err(e) => write_err(&mut stream, &mut wbuf, e.wire_code(), &e.to_string())?,
        }
    }
    Ok(())
}

/// Blocking client for examples/tests/benches.
pub struct TcpClient {
    stream: TcpStream,
}

impl TcpClient {
    pub fn connect(addr: std::net::SocketAddr) -> Result<Self> {
        Ok(Self {
            stream: TcpStream::connect(addr)?,
        })
    }

    /// Send one row, wait for the response.
    pub fn infer(&mut self, row: &[f32]) -> Result<Vec<f32>> {
        self.infer_with_ttl(row, None)
    }

    /// Send one row with a per-request TTL; the server sheds the
    /// request with a typed error if it can't start compute in time.
    pub fn infer_with_ttl(&mut self, row: &[f32], ttl: Option<Duration>) -> Result<Vec<f32>> {
        let ttl_ms: u32 = ttl.map_or(0, |t| t.as_millis().clamp(1, u32::MAX as u128) as u32);
        let mut buf = Vec::with_capacity(8 + row.len() * 4);
        buf.extend_from_slice(&(row.len() as u32).to_le_bytes());
        buf.extend_from_slice(&ttl_ms.to_le_bytes());
        for v in row {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        self.stream.write_all(&buf)?;

        let mut tag = [0u8; 1];
        self.stream.read_exact(&mut tag)?;
        let mut len = [0u8; 4];
        self.stream.read_exact(&mut len)?;
        let n = u32::from_le_bytes(len) as usize;
        if tag[0] == 0 {
            let mut bytes = vec![0u8; n * 4];
            self.stream.read_exact(&mut bytes)?;
            Ok(bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect())
        } else {
            let mut bytes = vec![0u8; n];
            self.stream.read_exact(&mut bytes)?;
            bail!(
                "server error (code {}): {}",
                tag[0],
                String::from_utf8_lossy(&bytes)
            )
        }
    }
}
