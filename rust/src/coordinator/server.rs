//! TCP front-end: length-prefixed f32 frames over a blocking socket.
//!
//! Wire format (little-endian):
//!   request:  u32 n  | n × f32            (one input row)
//!   response: u8 tag | u32 n | payload    (tag 0 = ok row, 1 = error utf8)
//!
//! One thread per connection (the workload is CPU-bound inference; the
//! batcher serializes actual compute, so connection threads just park).

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::Coordinator;

fn read_exact_u32(stream: &mut TcpStream) -> std::io::Result<u32> {
    let mut buf = [0u8; 4];
    stream.read_exact(&mut buf)?;
    Ok(u32::from_le_bytes(buf))
}

fn read_frame(stream: &mut TcpStream, max_floats: u32) -> Result<Option<Vec<f32>>> {
    let n = match read_exact_u32(stream) {
        Ok(n) => n,
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(e.into()),
    };
    if n > max_floats {
        bail!("frame of {n} floats exceeds limit {max_floats}");
    }
    let mut bytes = vec![0u8; n as usize * 4];
    stream.read_exact(&mut bytes)?;
    let mut out = Vec::with_capacity(n as usize);
    for chunk in bytes.chunks_exact(4) {
        out.push(f32::from_le_bytes(chunk.try_into().unwrap()));
    }
    Ok(Some(out))
}

fn write_ok(stream: &mut TcpStream, row: &[f32]) -> std::io::Result<()> {
    let mut buf = Vec::with_capacity(5 + row.len() * 4);
    buf.push(0u8);
    buf.extend_from_slice(&(row.len() as u32).to_le_bytes());
    for v in row {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    stream.write_all(&buf)
}

fn write_err(stream: &mut TcpStream, msg: &str) -> std::io::Result<()> {
    let bytes = msg.as_bytes();
    let mut buf = Vec::with_capacity(5 + bytes.len());
    buf.push(1u8);
    buf.extend_from_slice(&(bytes.len() as u32).to_le_bytes());
    buf.extend_from_slice(bytes);
    stream.write_all(&buf)
}

/// Serve until `stop` is set (checked between accepts). Returns the bound
/// address immediately via the callback so tests can connect.
pub fn serve_tcp(
    coordinator: Arc<Coordinator>,
    addr: &str,
    stop: Arc<AtomicBool>,
    on_bound: impl FnOnce(std::net::SocketAddr),
) -> Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    listener.set_nonblocking(true)?;
    on_bound(listener.local_addr()?);
    let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                stream.set_nonblocking(false)?;
                let coord = Arc::clone(&coordinator);
                conns.push(std::thread::spawn(move || {
                    let _ = handle_conn(stream, coord);
                }));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            Err(e) => return Err(e.into()),
        }
    }
    for c in conns {
        let _ = c.join();
    }
    Ok(())
}

fn handle_conn(mut stream: TcpStream, coord: Arc<Coordinator>) -> Result<()> {
    let max = 1 << 22; // 16 MiB of floats per frame is plenty
    while let Some(row) = read_frame(&mut stream, max)? {
        match coord.try_submit(row) {
            Ok(ticket) => match ticket.wait() {
                Ok(out) => write_ok(&mut stream, &out)?,
                Err(e) => write_err(&mut stream, &e)?,
            },
            Err(e) => write_err(&mut stream, &e.to_string())?,
        }
    }
    Ok(())
}

/// Blocking client for examples/tests/benches.
pub struct TcpClient {
    stream: TcpStream,
}

impl TcpClient {
    pub fn connect(addr: std::net::SocketAddr) -> Result<Self> {
        Ok(Self {
            stream: TcpStream::connect(addr)?,
        })
    }

    /// Send one row, wait for the response.
    pub fn infer(&mut self, row: &[f32]) -> Result<Vec<f32>> {
        let mut buf = Vec::with_capacity(4 + row.len() * 4);
        buf.extend_from_slice(&(row.len() as u32).to_le_bytes());
        for v in row {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        self.stream.write_all(&buf)?;

        let mut tag = [0u8; 1];
        self.stream.read_exact(&mut tag)?;
        let mut len = [0u8; 4];
        self.stream.read_exact(&mut len)?;
        let n = u32::from_le_bytes(len) as usize;
        if tag[0] == 0 {
            let mut bytes = vec![0u8; n * 4];
            self.stream.read_exact(&mut bytes)?;
            Ok(bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect())
        } else {
            let mut bytes = vec![0u8; n];
            self.stream.read_exact(&mut bytes)?;
            bail!("server error: {}", String::from_utf8_lossy(&bytes))
        }
    }
}
