//! L3 serving coordinator: request queue → shape-checked router →
//! deadline-based dynamic batcher → N engine workers → response
//! distribution.
//!
//! The paper's contribution is the kernel, so the coordinator's job is to
//! make the kernel *deployable*: it owns the event loop, batches
//! same-shape requests (dynamic batching with a deadline, vLLM-router
//! style), runs them on a selectable [`Engine`] — the rust-native sliding
//! kernels, the im2col+GEMM baseline, or the AOT PJRT TCN artifacts —
//! and reports latency/throughput via [`crate::telemetry`].
//!
//! Shapes are fixed per deployment (AOT artifacts are shape-specialized),
//! so the router's job reduces to validating input length and enforcing
//! backpressure (bounded queue + `try_submit`).

mod batcher;
mod engine;
mod server;

pub use batcher::{Coordinator, CoordinatorStats, SubmitError};
pub use engine::{Engine, EngineFactory, NativeEngine, PjrtTcnEngine};
pub use server::{serve_tcp, TcpClient};

use std::sync::{Arc, Condvar, Mutex};

/// An inference request: one input row of the deployed model shape.
pub struct Request {
    pub id: u64,
    pub input: Vec<f32>,
    pub enqueued: std::time::Instant,
    slot: Arc<ResponseSlot>,
}

/// Response payload (output row) or failure message.
pub type Response = Result<Vec<f32>, String>;

/// One-shot response rendezvous (std has no oneshot channel).
#[derive(Debug)]
pub struct ResponseSlot {
    value: Mutex<Option<Response>>,
    ready: Condvar,
}

impl ResponseSlot {
    fn new() -> Arc<Self> {
        Arc::new(Self {
            value: Mutex::new(None),
            ready: Condvar::new(),
        })
    }

    fn fill(&self, resp: Response) {
        let mut g = self.value.lock().unwrap();
        *g = Some(resp);
        self.ready.notify_all();
    }

    /// Block until the response arrives.
    pub fn wait(&self) -> Response {
        let mut g = self.value.lock().unwrap();
        loop {
            if let Some(resp) = g.take() {
                return resp;
            }
            g = self.ready.wait(g).unwrap();
        }
    }

    /// Wait with a timeout; `None` on timeout.
    pub fn wait_timeout(&self, dur: std::time::Duration) -> Option<Response> {
        let deadline = std::time::Instant::now() + dur;
        let mut g = self.value.lock().unwrap();
        loop {
            if let Some(resp) = g.take() {
                return Some(resp);
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, _) = self.ready.wait_timeout(g, deadline - now).unwrap();
            g = guard;
        }
    }
}

/// Handle returned to the submitter.
#[derive(Debug)]
pub struct Ticket {
    pub id: u64,
    slot: Arc<ResponseSlot>,
}

impl Ticket {
    pub fn wait(&self) -> Response {
        self.slot.wait()
    }

    pub fn wait_timeout(&self, dur: std::time::Duration) -> Option<Response> {
        self.slot.wait_timeout(dur)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn response_slot_rendezvous() {
        let slot = ResponseSlot::new();
        let s2 = Arc::clone(&slot);
        let t = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(10));
            s2.fill(Ok(vec![1.0, 2.0]));
        });
        assert_eq!(slot.wait().unwrap(), vec![1.0, 2.0]);
        t.join().unwrap();
    }

    #[test]
    fn response_slot_timeout() {
        let slot = ResponseSlot::new();
        assert!(slot
            .wait_timeout(std::time::Duration::from_millis(5))
            .is_none());
        slot.fill(Err("boom".into()));
        let got = slot.wait_timeout(std::time::Duration::from_millis(5)).unwrap();
        assert_eq!(got.unwrap_err(), "boom");
    }
}
