//! L3 serving coordinator: request queue → shape-checked router →
//! deadline-based dynamic batcher → N engine workers → response
//! distribution.
//!
//! The paper's contribution is the kernel, so the coordinator's job is to
//! make the kernel *deployable*: it owns the event loop, batches
//! same-shape requests (dynamic batching with a deadline, vLLM-router
//! style), runs them on a selectable [`Engine`] — the rust-native sliding
//! kernels, the im2col+GEMM baseline, or the AOT PJRT TCN artifacts —
//! and reports latency/throughput via [`crate::telemetry`].
//!
//! Shapes are fixed per deployment (AOT artifacts are shape-specialized),
//! so the router's job reduces to validating input length and enforcing
//! backpressure (bounded queue + `try_submit`).
//!
//! **Fault tolerance** (see `docs/robustness.md`): every accepted request
//! reaches *exactly one* terminal state — a response, a typed
//! [`ServeError`], never a leaked waiter. Requests carry an optional
//! deadline and are shed before compute once expired; worker panics are
//! caught, their in-flight slots completed with [`Shed::WorkerLost`], and
//! the worker restarted with a fresh engine under a bounded budget;
//! shutdown stops admission ([`Shed::Draining`]) and drains the queue to
//! terminal responses before joining workers.

mod admission;
mod batcher;
mod engine;
#[cfg(any(test, feature = "fault-injection"))]
pub mod faults;
mod transport;

pub use admission::{Admission, QuotaConfig};
pub use batcher::{Coordinator, CoordinatorStats, RespawnFactory, SubmitError, WorkerSpec};
pub use engine::{Engine, EngineFactory, NativeEngine, PjrtTcnEngine};
pub use transport::{
    serve_tcp, serve_tcp_with, TcpClient, TransportConfig, SESSION_CLOSE_MAGIC,
    SESSION_OPEN_MAGIC, SESSION_STEP_MAGIC, STATS_MAGIC, TENANT_MAGIC, WIRE_DECODE_ERROR,
};

use std::sync::{Arc, Condvar, Mutex};

/// Named fault-injection site. Compiles to nothing unless the crate is
/// built with `cfg(test)` or `--features fault-injection` — release
/// serving builds carry no injection branches (enforced by
/// `cargo xtask check`, rule `fault-confinement`).
#[macro_export]
macro_rules! fault_point {
    ($site:expr) => {{
        #[cfg(any(test, feature = "fault-injection"))]
        {
            $crate::coordinator::faults::fire($site);
        }
    }};
}

/// Why a request was shed without running inference. Every variant is a
/// *terminal* state for the request, with a distinct wire error code.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Shed {
    /// Admission rejected: the bounded queue was full (backpressure).
    QueueFull,
    /// The request's TTL expired before an engine picked it up; the
    /// batcher drops it without burning compute.
    DeadlineExpired,
    /// The coordinator is shutting down: admission is stopped and
    /// already-queued requests are drained to this terminal state.
    Draining,
    /// The worker holding this request died (panic) and no replacement
    /// could take over in time.
    WorkerLost,
    /// Transport-level shed: the listener is at `max_connections`; the
    /// connection is refused with this code before any frame is read.
    /// Emitted *before* admission, so it is counted in the transport
    /// counters (`conns_rejected`), not the coordinator terminal ledger.
    ConnLimit,
    /// Admission-level shed: the frame's tenant exhausted its
    /// token-bucket quota. Also pre-queue: counted as `quota_shed` in
    /// the transport counters, not in the terminal ledger.
    QuotaExceeded,
}

impl Shed {
    /// Stable wire error code (`coordinator/transport.rs` response tag).
    pub fn wire_code(self) -> u8 {
        match self {
            Shed::QueueFull => 3,
            Shed::DeadlineExpired => 4,
            Shed::Draining => 5,
            Shed::WorkerLost => 6,
            Shed::ConnLimit => 8,
            Shed::QuotaExceeded => 9,
        }
    }
}

impl std::fmt::Display for Shed {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Shed::QueueFull => write!(f, "shed: queue full (backpressure)"),
            Shed::DeadlineExpired => write!(f, "shed: request deadline expired"),
            Shed::Draining => write!(f, "shed: coordinator draining"),
            Shed::WorkerLost => write!(f, "shed: worker lost (engine panic)"),
            Shed::ConnLimit => write!(f, "shed: connection limit reached"),
            Shed::QuotaExceeded => write!(f, "shed: tenant quota exceeded"),
        }
    }
}

/// Terminal failure for an *accepted* request: either the engine ran and
/// failed, or the request was shed before/without compute.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// The engine executed the batch and returned an error.
    Engine(String),
    /// The request never ran — see [`Shed`].
    Shed(Shed),
}

impl ServeError {
    /// Stable wire error code (`coordinator/transport.rs` response tag).
    pub fn wire_code(&self) -> u8 {
        match self {
            ServeError::Engine(_) => 1,
            ServeError::Shed(s) => s.wire_code(),
        }
    }
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Engine(msg) => write!(f, "{msg}"),
            ServeError::Shed(s) => write!(f, "{s}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// What an accepted request asks the worker to do. Everything rides the
/// same bounded queue, response slots, and panic guards, so the
/// exactly-one-terminal-state ledger covers session traffic for free.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReqKind {
    /// Stateless batched inference on one full input row (the default).
    Infer,
    /// Open a streaming session; the response payload is one f32 whose
    /// *bits* are the session id. `ttl_ms = 0` = server default idle
    /// TTL.
    SessionOpen { ttl_ms: u32 },
    /// Advance a session by a packet of samples; the response is the
    /// newly finalized output samples (possibly empty).
    SessionStep { session: u32 },
    /// Close a session, recycling its state slot (empty response).
    SessionClose { session: u32 },
}

/// An inference request: one input row of the deployed model shape, or a
/// session control operation (see [`ReqKind`]).
pub struct Request {
    pub id: u64,
    pub input: Vec<f32>,
    pub kind: ReqKind,
    pub enqueued: std::time::Instant,
    /// Shed-by deadline: if the batcher reaches this request after the
    /// deadline, it completes it with [`Shed::DeadlineExpired`] instead
    /// of running it. `None` = no TTL.
    pub deadline: Option<std::time::Instant>,
    slot: Arc<ResponseSlot>,
}

impl Request {
    fn expired(&self, now: std::time::Instant) -> bool {
        self.deadline.is_some_and(|d| now >= d)
    }
}

/// Response payload (output row) or typed terminal failure.
pub type Response = Result<Vec<f32>, ServeError>;

/// One-shot response rendezvous (std has no oneshot channel).
///
/// Completion is **first-wins**: the first `complete` call decides the
/// request's terminal state; later calls are no-ops. This is what makes
/// the exactly-one-terminal-state invariant cheap to enforce — the
/// normal distribution path, the panic drop-guard, and the shutdown
/// drain can all race to complete a slot without double-reporting.
#[derive(Debug)]
pub struct ResponseSlot {
    value: Mutex<SlotState>,
    ready: Condvar,
}

#[derive(Debug)]
struct SlotState {
    resp: Option<Response>,
    /// Set once a terminal state has been decided (survives `take` by
    /// the waiter, so late completers stay no-ops).
    done: bool,
    /// The request's input buffer, handed back by the worker once it is
    /// done reading it so the submitter can reuse the allocation
    /// (transport double-buffering). Must be deposited *before*
    /// `complete` — the waiter may reclaim immediately after waking.
    input_back: Option<Vec<f32>>,
}

impl ResponseSlot {
    fn new() -> Arc<Self> {
        Arc::new(Self {
            value: Mutex::new(SlotState {
                resp: None,
                done: false,
                input_back: None,
            }),
            ready: Condvar::new(),
        })
    }

    /// Hand the (no longer needed) input buffer back to the submitter.
    /// Call before `complete` so a reclaim racing the wakeup sees it.
    fn return_input(&self, buf: Vec<f32>) {
        self.value.lock().unwrap().input_back = Some(buf);
    }

    /// First-wins completion: records `resp` as the terminal state if no
    /// prior completion happened, and returns whether this call won.
    fn complete(&self, resp: Response) -> bool {
        let mut g = self.value.lock().unwrap();
        if g.done {
            return false;
        }
        g.done = true;
        g.resp = Some(resp);
        self.ready.notify_all();
        true
    }

    /// Block until the response arrives.
    pub fn wait(&self) -> Response {
        let mut g = self.value.lock().unwrap();
        loop {
            if let Some(resp) = g.resp.take() {
                return resp;
            }
            g = self.ready.wait(g).unwrap();
        }
    }

    /// Wait with a timeout; `None` on timeout.
    pub fn wait_timeout(&self, dur: std::time::Duration) -> Option<Response> {
        let deadline = std::time::Instant::now() + dur;
        let mut g = self.value.lock().unwrap();
        loop {
            if let Some(resp) = g.resp.take() {
                return Some(resp);
            }
            // `saturating_duration_since` instead of `deadline - now`: a
            // wakeup (spurious or racing a completer) can land *after*
            // the deadline, and bare subtraction of Instants panics on
            // underflow. Saturating to zero keeps the late-wakeup path a
            // clean timeout.
            let remaining = deadline.saturating_duration_since(std::time::Instant::now());
            if remaining.is_zero() {
                return None;
            }
            let (guard, _) = self.ready.wait_timeout(g, remaining).unwrap();
            g = guard;
        }
    }
}

/// Handle returned to the submitter.
#[derive(Debug)]
pub struct Ticket {
    pub id: u64,
    slot: Arc<ResponseSlot>,
}

impl Ticket {
    pub fn wait(&self) -> Response {
        self.slot.wait()
    }

    pub fn wait_timeout(&self, dur: std::time::Duration) -> Option<Response> {
        self.slot.wait_timeout(dur)
    }

    /// Take back the request's input buffer if the worker returned it
    /// (it does so on every successful completion path). Lets the TCP
    /// connection loop double-buffer decode rows instead of cloning per
    /// request. `None` if the request failed before the worker finished
    /// with the buffer — the caller then just allocates a fresh row.
    pub fn reclaim_input(&self) -> Option<Vec<f32>> {
        self.slot.value.lock().unwrap().input_back.take()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn response_slot_rendezvous() {
        let slot = ResponseSlot::new();
        let s2 = Arc::clone(&slot);
        let t = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(10));
            s2.complete(Ok(vec![1.0, 2.0]));
        });
        assert_eq!(slot.wait().unwrap(), vec![1.0, 2.0]);
        t.join().unwrap();
    }

    #[test]
    fn response_slot_timeout() {
        let slot = ResponseSlot::new();
        assert!(slot
            .wait_timeout(std::time::Duration::from_millis(5))
            .is_none());
        slot.complete(Err(ServeError::Engine("boom".into())));
        let got = slot.wait_timeout(std::time::Duration::from_millis(5)).unwrap();
        assert_eq!(got.unwrap_err().to_string(), "boom");
    }

    #[test]
    fn response_slot_first_completion_wins() {
        let slot = ResponseSlot::new();
        assert!(slot.complete(Ok(vec![1.0])));
        assert!(!slot.complete(Err(ServeError::Shed(Shed::WorkerLost))));
        assert_eq!(slot.wait().unwrap(), vec![1.0]);
        // Late completion after the waiter consumed the value is still a
        // no-op — the slot stays terminal.
        assert!(!slot.complete(Ok(vec![9.0])));
        assert!(slot
            .wait_timeout(std::time::Duration::from_millis(2))
            .is_none());
    }

    /// Regression: `wait_timeout` used `deadline - now` after the
    /// condvar wakeup, which panics (Instant subtraction underflow) when
    /// a wakeup lands after the deadline. Race many completers right at
    /// the timeout boundary — both outcomes (response or clean `None`)
    /// are fine; a panic is the bug.
    #[test]
    fn wait_timeout_survives_deadline_race() {
        for i in 0..64 {
            let slot = ResponseSlot::new();
            let s2 = Arc::clone(&slot);
            let dur = std::time::Duration::from_micros(200 + 17 * i);
            let t = std::thread::spawn(move || {
                // Notify right around the waiter's deadline so some runs
                // wake the waiter after the deadline has passed.
                std::thread::sleep(dur);
                s2.complete(Ok(vec![i as f32]));
            });
            match slot.wait_timeout(dur) {
                Some(resp) => assert_eq!(resp.unwrap(), vec![i as f32]),
                None => {} // timed out cleanly — the point is no panic
            }
            t.join().unwrap();
        }
    }

    #[test]
    fn wire_codes_are_distinct() {
        let codes = [
            ServeError::Engine("x".into()).wire_code(),
            ServeError::Shed(Shed::QueueFull).wire_code(),
            ServeError::Shed(Shed::DeadlineExpired).wire_code(),
            ServeError::Shed(Shed::Draining).wire_code(),
            ServeError::Shed(Shed::WorkerLost).wire_code(),
            ServeError::Shed(Shed::ConnLimit).wire_code(),
            ServeError::Shed(Shed::QuotaExceeded).wire_code(),
            WIRE_DECODE_ERROR,
        ];
        for (i, a) in codes.iter().enumerate() {
            assert_ne!(*a, 0, "0 is the ok tag");
            for b in &codes[i + 1..] {
                assert_ne!(a, b, "wire codes must be distinct");
            }
        }
        // Pin the transport-tier codes: clients match on the numbers.
        assert_eq!(Shed::ConnLimit.wire_code(), 8);
        assert_eq!(Shed::QuotaExceeded.wire_code(), 9);
        assert_eq!(WIRE_DECODE_ERROR, 10);
    }

    #[test]
    fn ticket_reclaims_input_buffer() {
        let slot = ResponseSlot::new();
        let ticket = Ticket {
            id: 1,
            slot: Arc::clone(&slot),
        };
        assert!(ticket.reclaim_input().is_none());
        let mut buf = vec![1.0f32, 2.0];
        buf.reserve(64);
        let cap = buf.capacity();
        slot.return_input(buf);
        slot.complete(Ok(vec![3.0]));
        assert_eq!(ticket.wait().unwrap(), vec![3.0]);
        let back = ticket.reclaim_input().expect("buffer returned");
        assert_eq!(back.capacity(), cap);
        assert!(ticket.reclaim_input().is_none(), "reclaim is one-shot");
    }
}
