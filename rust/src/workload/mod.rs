//! Synthetic workload generation for benches, examples and the serving
//! driver — replaces the paper's proprietary inputs (DESIGN.md §2
//! substitutions table).

pub mod rng;

pub use rng::Rng;

use crate::conv::Conv1dParams;

/// A Fig-1-style workload: one long 1-D signal and a filter of size `k`.
pub fn fig1_signal(rng: &mut Rng, n: usize) -> Vec<f32> {
    // Smooth-ish signal: AR(1) noise, mimicking audio/sensor streams the
    // paper's intro motivates.
    let mut x = vec![0.0f32; n];
    let mut prev = 0.0f32;
    for v in x.iter_mut() {
        prev = 0.95 * prev + 0.3 * rng.normal();
        *v = prev;
    }
    x
}

/// The Chaudhary et al. [4] dilated-conv scenario recreated synthetically:
/// layer shapes spanning their published sweep (seq 2k–32k, kernels 3–127,
/// dilations 1–64). Returns (name, params) rows for Fig 2.
pub fn chaudhary_dilated_suite() -> Vec<(String, Conv1dParams)> {
    let mut rows = Vec::new();
    // "Small data set" — short sequences, large dilated receptive fields
    // (where the paper reports up to 6.8×).
    for (n, k, d) in [
        (2048usize, 15usize, 8usize),
        (2048, 31, 8),
        (2048, 63, 16),
        (4096, 31, 16),
        (4096, 63, 16),
    ] {
        rows.push((
            format!("small/n{n}_k{k}_d{d}"),
            Conv1dParams::new(1, 1, n, k).with_dilation(d).with_same_pad(),
        ));
    }
    // "Across the board" — longer sequences, multi-channel, mixed dilation
    // (where the paper reports ≈4×).
    for (n, c, k, d) in [
        (8192usize, 4usize, 7usize, 2usize),
        (8192, 4, 15, 4),
        (16384, 8, 31, 8),
        (16384, 8, 63, 32),
        (32768, 4, 127, 64),
        (32768, 8, 15, 16),
    ] {
        rows.push((
            format!("board/n{n}_c{c}_k{k}_d{d}"),
            Conv1dParams::new(c, c, n, k).with_dilation(d).with_same_pad(),
        ));
    }
    rows
}

/// Random DNA sequence (A/C/G/T) for the minimizer example.
pub fn dna_sequence(rng: &mut Rng, n: usize) -> Vec<u8> {
    const BASES: [u8; 4] = *b"ACGT";
    (0..n).map(|_| BASES[rng.below(4)]).collect()
}

/// 2-bit pack + rolling k-mer hash (invertible multiply), the standard
/// minimizer-seed prep.
pub fn kmer_hashes(seq: &[u8], k: usize) -> Vec<u64> {
    if seq.len() < k {
        return Vec::new();
    }
    let code = |b: u8| -> u64 {
        match b {
            b'A' => 0,
            b'C' => 1,
            b'G' => 2,
            b'T' => 3,
            _ => 0,
        }
    };
    let mask = if 2 * k >= 64 { u64::MAX } else { (1u64 << (2 * k)) - 1 };
    let mut h = 0u64;
    let mut out = Vec::with_capacity(seq.len() - k + 1);
    for (i, &b) in seq.iter().enumerate() {
        h = ((h << 2) | code(b)) & mask;
        if i + 1 >= k {
            // Finalizer (splitmix-style) decorrelates lexicographic order.
            let mut z = h.wrapping_add(0x9e3779b97f4a7c15);
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            out.push(z ^ (z >> 31));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_signal_is_deterministic() {
        let a = fig1_signal(&mut Rng::new(5), 100);
        let b = fig1_signal(&mut Rng::new(5), 100);
        assert_eq!(a, b);
        assert_eq!(a.len(), 100);
    }

    #[test]
    fn chaudhary_suite_shapes_valid() {
        let suite = chaudhary_dilated_suite();
        assert!(suite.len() >= 10);
        for (name, p) in &suite {
            assert!(p.n_out() > 0, "{name}");
            assert_eq!(p.n_out(), p.n, "{name} same-pad must preserve length");
        }
    }

    #[test]
    fn dna_and_kmers() {
        let seq = dna_sequence(&mut Rng::new(9), 64);
        assert!(seq.iter().all(|b| b"ACGT".contains(b)));
        let hashes = kmer_hashes(&seq, 15);
        assert_eq!(hashes.len(), 64 - 15 + 1);
        // same k-mer → same hash
        let h2 = kmer_hashes(&seq, 15);
        assert_eq!(hashes, h2);
    }

    #[test]
    fn kmer_short_input_empty() {
        assert!(kmer_hashes(b"ACG", 15).is_empty());
    }
}
