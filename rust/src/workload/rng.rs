//! Deterministic PRNG substrate (no `rand` crate offline) —
//! SplitMix64 for seeding, xoshiro256++ for the stream. Both are the
//! reference algorithms from Blackman & Vigna; outputs are reproducible
//! across runs so every bench/test workload is seed-stable.

/// SplitMix64 — used to expand a user seed into xoshiro state.
#[derive(Clone, Debug)]
pub struct SplitMix64(u64);

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self(seed)
    }

    pub fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9e3779b97f4a7c15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — fast, high-quality 64-bit generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Standard normal via Box–Muller (pairs cached would complicate the
    /// state; the single-value form is fine for workload generation).
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.next_f32();
            if u1 > 1e-7 {
                let u2 = self.next_f32();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos();
            }
        }
    }

    /// Uniform integer in `[0, n)`.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Fill a slice with uniform `[lo, hi)` values.
    pub fn fill_uniform(&mut self, buf: &mut [f32], lo: f32, hi: f32) {
        for v in buf {
            *v = self.uniform(lo, hi);
        }
    }

    /// Fresh uniform vector.
    pub fn vec_uniform(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        let mut v = vec![0.0f32; n];
        self.fill_uniform(&mut v, lo, hi);
        v
    }

    /// Fresh standard-normal vector scaled by `std`.
    pub fn vec_normal(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() * std).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let v = r.uniform(-2.0, 3.0);
            assert!((-2.0..3.0).contains(&v));
        }
    }

    #[test]
    fn normal_mean_and_var_sane() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean: f32 = xs.iter().sum::<f32>() / n as f32;
        let var: f32 = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }
}
