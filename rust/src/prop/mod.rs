//! Mini property-testing substrate (proptest is unavailable offline).
//! Seeded generators + a case runner that reports the failing seed so
//! any counterexample is reproducible. Shrinking is size-based: each
//! failing case is retried at smaller sizes before reporting.

use crate::workload::Rng;

/// Property-test configuration.
#[derive(Clone, Copy, Debug)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
    /// Maximum collection size generators should produce.
    pub max_size: usize,
}

impl Default for PropConfig {
    fn default() -> Self {
        Self {
            cases: 128,
            seed: 0x5eed_cafe,
            max_size: 200,
        }
    }
}

/// Per-case generation context.
pub struct Gen<'a> {
    pub rng: &'a mut Rng,
    /// Current size budget (shrinks on failure retries).
    pub size: usize,
}

impl Gen<'_> {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below(hi.saturating_sub(lo).max(1))
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.uniform(lo, hi)
    }

    pub fn vec_f32(&mut self, lo: f32, hi: f32) -> Vec<f32> {
        let n = self.usize_in(0, self.size + 1);
        self.rng.vec_uniform(n, lo, hi)
    }

    pub fn vec_f32_len(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        self.rng.vec_uniform(n, lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.below(2) == 1
    }

    pub fn choose<'b, T>(&mut self, items: &'b [T]) -> &'b T {
        &items[self.rng.below(items.len())]
    }
}

/// Run `prop` over `cfg.cases` generated cases. On failure, retry at
/// smaller sizes to find a smaller counterexample, then panic with the
/// seed + case index + size so the exact case can be replayed.
pub fn check<F>(cfg: PropConfig, name: &str, prop: F)
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let case_seed = cfg.seed ^ (case as u64).wrapping_mul(0x9e3779b97f4a7c15);
        let run = |size: usize| -> Result<(), String> {
            let mut rng = Rng::new(case_seed);
            let mut gen = Gen {
                rng: &mut rng,
                size,
            };
            prop(&mut gen)
        };
        if let Err(msg) = run(cfg.max_size) {
            // Size-shrink pass: find the smallest size that still fails.
            let mut failing_size = cfg.max_size;
            let mut failing_msg = msg;
            let mut size = cfg.max_size / 2;
            while size >= 1 {
                match run(size) {
                    Err(m) => {
                        failing_size = size;
                        failing_msg = m;
                        size /= 2;
                    }
                    Ok(()) => break,
                }
            }
            panic!(
                "property {name:?} failed (case {case}, seed {case_seed:#x}, size {failing_size}): {failing_msg}"
            );
        }
    }
}

/// Convenience assertion helpers for property bodies.
pub fn ensure(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

pub fn ensure_close(a: f32, b: f32, tol: f32, ctx: &str) -> Result<(), String> {
    if (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())) {
        Ok(())
    } else {
        Err(format!("{ctx}: {a} vs {b} (tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check(PropConfig::default(), "reverse twice", |g| {
            let v = g.vec_f32(-1.0, 1.0);
            let mut r = v.clone();
            r.reverse();
            r.reverse();
            ensure(r == v, "reverse∘reverse ≠ id")
        });
    }

    #[test]
    #[should_panic(expected = "always fails")]
    fn failing_property_reports_seed() {
        check(
            PropConfig {
                cases: 3,
                ..Default::default()
            },
            "always fails",
            |_g| Err("nope".into()),
        );
    }

    #[test]
    fn gen_ranges_respected() {
        check(PropConfig::default(), "ranges", |g| {
            let n = g.usize_in(3, 10);
            ensure(n >= 3 && n < 10, format!("n={n}"))?;
            let x = g.f32_in(-2.0, 5.0);
            ensure((-2.0..5.0).contains(&x), format!("x={x}"))
        });
    }

    #[test]
    fn ensure_close_tolerance() {
        assert!(ensure_close(1.0, 1.0 + 1e-6, 1e-4, "x").is_ok());
        assert!(ensure_close(1.0, 2.0, 1e-4, "x").is_err());
    }
}
