//! Checked-build invariants (the `check-invariants` cargo feature).
//!
//! The static pass (`cargo xtask check`) enforces what a line scanner
//! can see; this module compiles in the runtime assertions for the
//! contracts it can't (docs/invariants.md):
//!
//! * the NaN-sentinel **full-overwrite poison check**: every `_into`
//!   kernel pre-fills its destination with [`sentinel`] and asserts on
//!   exit that no sentinel bits survive — i.e. the kernel really did
//!   overwrite every element, which is what makes recycling buffers
//!   dirty sound;
//! * the [`invariant!`] macro behind the arena-layout audit at
//!   `Plan::compile` and the fused-chain halo/ring-capacity bounds at
//!   every tile step.
//!
//! Everything here compiles to nothing unless the feature is on (the
//! bodies sit behind `cfg!(feature = "check-invariants")`, which the
//! optimizer folds away), so the hot paths keep their release-build
//! codegen. CI runs the whole test suite once with the feature enabled.

/// Bit pattern of the poison value: a *signaling* NaN (quiet bit
/// clear, non-zero payload) so the sentinel can never be produced by
/// ordinary kernel arithmetic on real inputs. Detection compares exact
/// bits — arithmetic on a poisoned lane would quieten the NaN, so a
/// kernel that *reads* its uninitialized destination trips the check
/// too (the result is a different bit pattern only if it was written;
/// an untouched lane keeps these exact bits).
pub const SENTINEL_BITS: u32 = 0x7FA5_DEAD;

/// The poison value itself.
#[inline]
pub fn sentinel() -> f32 {
    f32::from_bits(SENTINEL_BITS)
}

/// Exact-bits sentinel test (NaN `==` would be always-false).
#[inline]
pub fn is_sentinel(v: f32) -> bool {
    v.to_bits() == SENTINEL_BITS
}

/// Whether the checked build is active.
#[inline]
pub fn enabled() -> bool {
    cfg!(feature = "check-invariants")
}

/// Pre-fill an `_into` destination with the poison pattern. Works on
/// the `f32` instantiations of the generic kernels (routed through
/// [`crate::simd::as_f32_mut`]); other element types pass through
/// untouched. No-op unless `check-invariants` is on.
#[inline]
pub fn poison<T: Copy + 'static>(dst: &mut [T]) {
    if cfg!(feature = "check-invariants") {
        if let Some(d) = crate::simd::as_f32_mut(dst) {
            d.fill(sentinel());
        }
    }
}

/// Assert that no poison survives in `dst` — i.e. the kernel between
/// [`poison`] and this call overwrote every element. `what` names the
/// kernel in the panic message. No-op unless `check-invariants` is on.
#[inline]
pub fn assert_no_poison<T: Copy + 'static>(dst: &[T], what: &str) {
    if cfg!(feature = "check-invariants") {
        if let Some(d) = crate::simd::as_f32(dst) {
            if let Some(i) = d.iter().position(|v| is_sentinel(*v)) {
                panic!(
                    "check-invariants: `{what}` left dst[{i}] (of {}) unwritten",
                    d.len()
                );
            }
        }
    }
}

/// `assert!` that is compiled in for debug builds *and* checked builds
/// (`check-invariants`), and compiled out entirely otherwise — a
/// strict strengthening of `debug_assert!` for the arena/halo/ring
/// contracts. The condition must be side-effect free.
#[macro_export]
macro_rules! invariant {
    ($cond:expr $(, $arg:tt)* $(,)?) => {
        if cfg!(debug_assertions) || cfg!(feature = "check-invariants") {
            assert!($cond $(, $arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sentinel_is_signaling_nan_with_stable_bits() {
        let s = sentinel();
        assert!(s.is_nan());
        assert!(is_sentinel(s));
        // The quiet bit (mantissa MSB) is clear: signaling.
        assert_eq!(SENTINEL_BITS & 0x0040_0000, 0);
        // Ordinary values never match.
        for v in [0.0f32, -0.0, 1.0, f32::NAN, f32::INFINITY, f32::MIN] {
            assert!(!is_sentinel(v) || v.to_bits() == SENTINEL_BITS);
        }
    }

    #[test]
    fn poison_roundtrip_matches_feature_state() {
        let mut buf = [1.0f32; 8];
        poison(&mut buf);
        if enabled() {
            assert!(buf.iter().all(|v| is_sentinel(*v)));
        } else {
            assert_eq!(buf, [1.0f32; 8]);
        }
        buf.fill(2.0);
        assert_no_poison(&buf, "test");
    }

    #[test]
    fn non_f32_elements_pass_through() {
        let mut buf = [7i32; 4];
        poison(&mut buf);
        assert_eq!(buf, [7i32; 4]);
        assert_no_poison(&buf, "test-i32");
    }

    #[test]
    #[cfg_attr(not(feature = "check-invariants"), ignore)]
    fn unwritten_lane_is_caught() {
        let mut buf = [0.0f32; 4];
        poison(&mut buf);
        buf[0] = 1.0;
        buf[1] = 2.0;
        buf[3] = 3.0;
        let caught = std::panic::catch_unwind(|| assert_no_poison(&buf, "hole")).is_err();
        assert!(caught, "sentinel at index 2 must be detected");
    }

    #[test]
    fn invariant_macro_passes_on_true() {
        invariant!(1 + 1 == 2, "arithmetic holds");
    }
}
