//! Prefix-sum substrate (paper §2.1).
//!
//! A prefix sum over an associative `⊕` can be computed in `O(log N)`
//! parallel steps (Blelloch 1993 — the paper's [3]). This module provides
//! the scan/reduce toolbox the sliding-window algorithms build on:
//!
//! * [`scan_inclusive`] / [`scan_exclusive`] — sequential recurrences
//!   (Eq. 2), the work-optimal baseline.
//! * [`scan_hillis_steele`] — log-depth, `O(N log N)` work; the shape used
//!   *inside* a vector register.
//! * [`scan_blelloch`] — log-depth, `O(N)` work (up-sweep/down-sweep).
//! * [`reduce_tree`] — log-depth reduction (paper §2.4 evaluates δ_M
//!   this way).
//! * [`suffix_scan_inclusive`] — the mirrored scan the vector-input
//!   algorithm needs for its `Y1` register.
//! * [`scan_windowed`] — per-window prefix restart, a building block for
//!   the strided variants.

use crate::ops::AssocOp;

/// Sequential inclusive scan: `out[i] = x₀ ⊕ … ⊕ xᵢ` (paper Eq. 1–2).
pub fn scan_inclusive<O: AssocOp>(op: O, xs: &[O::Elem]) -> Vec<O::Elem> {
    let mut out = Vec::with_capacity(xs.len());
    let mut acc = op.identity();
    for &x in xs {
        acc = op.combine(acc, x);
        out.push(acc);
    }
    out
}

/// Sequential exclusive scan: `out[i] = x₀ ⊕ … ⊕ xᵢ₋₁`, `out[0] = id`.
pub fn scan_exclusive<O: AssocOp>(op: O, xs: &[O::Elem]) -> Vec<O::Elem> {
    let mut out = Vec::with_capacity(xs.len());
    let mut acc = op.identity();
    for &x in xs {
        out.push(acc);
        acc = op.combine(acc, x);
    }
    out
}

/// Hillis–Steele inclusive scan: `⌈log₂ N⌉` sweeps, each a full-width
/// shifted combine. `O(N log N)` work but every sweep is a perfectly
/// vectorizable loop — this is the in-register scan shape.
pub fn scan_hillis_steele<O: AssocOp>(op: O, xs: &[O::Elem]) -> Vec<O::Elem> {
    let n = xs.len();
    let mut cur = xs.to_vec();
    let mut nxt = vec![op.identity(); n];
    let mut d = 1;
    while d < n {
        // nxt[i] = cur[i-d] ⊕ cur[i] for i >= d, else cur[i]
        nxt[..d].copy_from_slice(&cur[..d]);
        for i in d..n {
            nxt[i] = op.combine(cur[i - d], cur[i]);
        }
        std::mem::swap(&mut cur, &mut nxt);
        d <<= 1;
    }
    cur
}

/// Blelloch work-efficient scan (up-sweep + down-sweep), returned
/// *inclusive* to match the other scans. `O(N)` work, `2⌈log₂ N⌉` depth.
pub fn scan_blelloch<O: AssocOp>(op: O, xs: &[O::Elem]) -> Vec<O::Elem> {
    let n = xs.len();
    if n == 0 {
        return Vec::new();
    }
    let m = n.next_power_of_two();
    let mut tree = vec![op.identity(); m];
    tree[..n].copy_from_slice(xs);

    // Up-sweep (reduce).
    let mut d = 1;
    while d < m {
        let stride = d * 2;
        let mut i = stride - 1;
        while i < m {
            tree[i] = op.combine(tree[i - d], tree[i]);
            i += stride;
        }
        d = stride;
    }

    // Down-sweep producing an exclusive scan.
    tree[m - 1] = op.identity();
    let mut d = m / 2;
    while d >= 1 {
        let stride = d * 2;
        let mut i = stride - 1;
        while i < m {
            let left = tree[i - d];
            tree[i - d] = tree[i];
            tree[i] = op.combine(tree[i], left);
            i += stride;
        }
        d /= 2;
    }

    // Inclusive = exclusive ⊕ input.
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        out.push(op.combine(tree[i], xs[i]));
    }
    out
}

/// Log-depth tree reduction of the whole slice.
pub fn reduce_tree<O: AssocOp>(op: O, xs: &[O::Elem]) -> O::Elem {
    match xs.len() {
        0 => op.identity(),
        1 => xs[0],
        n => {
            let mid = n / 2;
            // Recursion depth is log N; the two halves are independent
            // (this is the parallel shape even though we run sequentially).
            op.combine(reduce_tree(op, &xs[..mid]), reduce_tree(op, &xs[mid..]))
        }
    }
}

/// Sequential reduction (the work-optimal baseline for benches).
pub fn reduce_seq<O: AssocOp>(op: O, xs: &[O::Elem]) -> O::Elem {
    let mut acc = op.identity();
    for &x in xs {
        acc = op.combine(acc, x);
    }
    acc
}

/// Inclusive *suffix* scan: `out[i] = xᵢ ⊕ … ⊕ x_{N-1}`.
///
/// Note `⊕` may be non-commutative (ConvPair!), so operand order matters:
/// the accumulator goes on the *right*.
pub fn suffix_scan_inclusive<O: AssocOp>(op: O, xs: &[O::Elem]) -> Vec<O::Elem> {
    let n = xs.len();
    let mut out = vec![op.identity(); n];
    let mut acc = op.identity();
    for i in (0..n).rev() {
        acc = op.combine(xs[i], acc);
        out[i] = acc;
    }
    out
}

/// Windowed prefix restart: the scan restarts at every multiple of `w`.
/// `out[i] = x_{⌊i/w⌋·w} ⊕ … ⊕ xᵢ`. Used by the block-decomposed sliding
/// variants and by tests as an oracle for in-register partial scans.
pub fn scan_windowed<O: AssocOp>(op: O, xs: &[O::Elem], w: usize) -> Vec<O::Elem> {
    assert!(w >= 1);
    let mut out = Vec::with_capacity(xs.len());
    let mut acc = op.identity();
    for (i, &x) in xs.iter().enumerate() {
        if i % w == 0 {
            acc = op.identity();
        }
        acc = op.combine(acc, x);
        out.push(acc);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::{AddOp, ConvPair, MaxOp, MinOp, Pair};

    fn close(a: &[f32], b: &[f32]) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!(
                (x - y).abs() <= 1e-3 * (1.0 + x.abs().max(y.abs())),
                "idx {i}: {x} vs {y}"
            );
        }
    }

    #[test]
    fn inclusive_exclusive_relationship() {
        let xs = [1f32, 2.0, 3.0, 4.0];
        let inc = scan_inclusive(AddOp::<f32>::new(), &xs);
        let exc = scan_exclusive(AddOp::<f32>::new(), &xs);
        assert_eq!(inc, vec![1.0, 3.0, 6.0, 10.0]);
        assert_eq!(exc, vec![0.0, 1.0, 3.0, 6.0]);
    }

    #[test]
    fn hillis_steele_matches_sequential() {
        for n in [0usize, 1, 2, 3, 7, 8, 16, 31, 100] {
            let xs: Vec<f32> = (0..n).map(|i| (i as f32) * 0.5 - 3.0).collect();
            close(
                &scan_hillis_steele(AddOp::<f32>::new(), &xs),
                &scan_inclusive(AddOp::<f32>::new(), &xs),
            );
        }
    }

    #[test]
    fn blelloch_matches_sequential() {
        for n in [0usize, 1, 2, 3, 7, 8, 16, 31, 100, 257] {
            let xs: Vec<f32> = (0..n).map(|i| ((i * 7 + 3) % 13) as f32 - 6.0).collect();
            close(
                &scan_blelloch(AddOp::<f32>::new(), &xs),
                &scan_inclusive(AddOp::<f32>::new(), &xs),
            );
        }
    }

    #[test]
    fn blelloch_max_exact() {
        let xs: Vec<i64> = vec![3, -1, 7, 7, 2, 9, 0, 9, 1];
        assert_eq!(
            scan_blelloch(MaxOp::<i64>::new(), &xs),
            scan_inclusive(MaxOp::<i64>::new(), &xs)
        );
    }

    #[test]
    fn scans_handle_noncommutative_convpair() {
        // ConvPair is associative but NOT commutative — the log-depth scans
        // must still agree with the sequential recurrence.
        let xs: Vec<Pair> = (0..17)
            .map(|i| Pair::new(1.0 + 0.1 * i as f32, 0.5 * i as f32 - 2.0))
            .collect();
        let seq = scan_inclusive(ConvPair, &xs);
        let hs = scan_hillis_steele(ConvPair, &xs);
        let bl = scan_blelloch(ConvPair, &xs);
        for i in 0..xs.len() {
            assert!((seq[i].u - hs[i].u).abs() < 1e-2, "hs u at {i}");
            assert!((seq[i].v - hs[i].v).abs() < 1e-2, "hs v at {i}");
            assert!((seq[i].u - bl[i].u).abs() < 1e-2, "bl u at {i}");
            assert!((seq[i].v - bl[i].v).abs() < 1e-2, "bl v at {i}");
        }
    }

    #[test]
    fn reduce_tree_matches_seq() {
        let xs: Vec<i64> = (0..101).map(|i| (i * 31 % 17) - 8).collect();
        assert_eq!(
            reduce_tree(AddOp::<i64>::new(), &xs),
            reduce_seq(AddOp::<i64>::new(), &xs)
        );
        assert_eq!(reduce_tree(AddOp::<i64>::new(), &[]), 0);
        assert_eq!(reduce_tree(MinOp::<i64>::new(), &[5]), 5);
    }

    #[test]
    fn suffix_scan_mirrors_prefix() {
        let xs = [1f32, 2.0, 3.0, 4.0];
        let suf = suffix_scan_inclusive(AddOp::<f32>::new(), &xs);
        assert_eq!(suf, vec![10.0, 9.0, 7.0, 4.0]);
    }

    #[test]
    fn suffix_scan_noncommutative_order() {
        // For non-commutative ⊕ the suffix must be x_i ⊕ (x_{i+1} ⊕ ...).
        let xs = [Pair::new(2.0, 1.0), Pair::new(3.0, -1.0), Pair::new(0.5, 4.0)];
        let suf = suffix_scan_inclusive(ConvPair, &xs);
        let manual = ConvPair.combine(xs[0], ConvPair.combine(xs[1], xs[2]));
        assert!((suf[0].u - manual.u).abs() < 1e-6);
        assert!((suf[0].v - manual.v).abs() < 1e-6);
    }

    #[test]
    fn windowed_scan_restarts() {
        let xs = [1f32, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0];
        let out = scan_windowed(AddOp::<f32>::new(), &xs, 3);
        assert_eq!(out, vec![1.0, 2.0, 3.0, 1.0, 2.0, 3.0, 1.0]);
    }

    #[test]
    fn empty_inputs() {
        assert!(scan_inclusive(AddOp::<f32>::new(), &[]).is_empty());
        assert!(scan_blelloch(AddOp::<f32>::new(), &[]).is_empty());
        assert!(suffix_scan_inclusive(AddOp::<f32>::new(), &[]).is_empty());
    }
}
