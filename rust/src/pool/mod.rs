//! Pooling operators as sliding window sums (paper §2.3).
//!
//! "The average pooling operator is trivially the sliding window sum with
//! the associative operator +. By analogy, the max pooling operator is a
//! sliding window sum with the associative operator max."
//!
//! Strided pooling (the common DNN case, stride = w) decimates the dense
//! sliding output; stride < w reuses overlapping windows — exactly where
//! the sliding formulation beats recomputation. Also here:
//! [`sliding_minimum`], the minimizer-seed primitive from the
//! bioinformatics work the algorithms originated in (paper §2.2, [11]).

mod pool2d;

pub use pool2d::{pool2d, pool2d_into, pool2d_naive, pool2d_with, pool2d_with_into, Pool2dParams};

use crate::exec::{Executor, PAR_MIN_FANOUT};
use crate::ops::{AddOp, AssocOp, MaxOp, MinOp};
use crate::sliding::{self, Boundary};

/// Pooling kind.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PoolKind {
    Avg,
    Max,
    Min,
}

impl PoolKind {
    pub fn name(&self) -> &'static str {
        match self {
            PoolKind::Avg => "avg",
            PoolKind::Max => "max",
            PoolKind::Min => "min",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "avg" => Some(PoolKind::Avg),
            "max" => Some(PoolKind::Max),
            "min" => Some(PoolKind::Min),
            _ => None,
        }
    }
}

/// Pooling parameters over `[batch, channels, n]` tensors.
#[derive(Clone, Copy, Debug)]
pub struct Pool1dParams {
    pub batch: usize,
    pub channels: usize,
    pub n: usize,
    pub w: usize,
    pub stride: usize,
    pub boundary: Boundary,
}

impl Pool1dParams {
    pub fn new(channels: usize, n: usize, w: usize) -> Self {
        Self {
            batch: 1,
            channels,
            n,
            w,
            stride: 1,
            boundary: Boundary::Valid,
        }
    }

    pub fn with_batch(mut self, b: usize) -> Self {
        self.batch = b;
        self
    }

    pub fn with_stride(mut self, s: usize) -> Self {
        assert!(s >= 1);
        self.stride = s;
        self
    }

    pub fn with_boundary(mut self, m: Boundary) -> Self {
        self.boundary = m;
        self
    }

    /// Dense (stride-1) output length under the boundary mode.
    pub fn dense_len(&self) -> usize {
        sliding::boundary::output_len(self.n, self.w, self.boundary)
    }

    /// Output length after striding.
    pub fn n_out(&self) -> usize {
        let d = self.dense_len();
        if d == 0 {
            0
        } else {
            (d - 1) / self.stride + 1
        }
    }

    pub fn y_len(&self) -> usize {
        self.batch * self.channels * self.n_out()
    }
}

/// 1-D pooling via the sliding-sum machinery (auto-dispatched algorithm,
/// P = 64 logical lanes), parallel over `(batch × channel)` rows on the
/// shared worker pool. Average pooling divides by the window size
/// *after* the windowed sum — identical to frameworks'
/// `count_include_pad` semantics under zero padding.
pub fn pool1d(kind: PoolKind, x: &[f32], p: &Pool1dParams) -> Vec<f32> {
    pool1d_with(Executor::global(), kind, x, p)
}

/// [`pool1d`] writing into a caller-provided buffer of length
/// [`Pool1dParams::y_len`] (every element overwritten — the buffer may
/// be recycled dirty across requests).
pub fn pool1d_into(kind: PoolKind, x: &[f32], p: &Pool1dParams, y: &mut [f32]) {
    pool1d_with_into(Executor::global(), kind, x, p, y)
}

/// [`pool1d`] on an explicit executor (scaling benches / parity tests).
pub fn pool1d_with(ex: &Executor, kind: PoolKind, x: &[f32], p: &Pool1dParams) -> Vec<f32> {
    // alloc-ok: Vec-returning wrapper; pool1d_with_into is the hot path.
    let mut y = vec![0.0f32; p.y_len()];
    pool1d_with_into(ex, kind, x, p, &mut y);
    y
}

/// The core kernel: explicit executor and caller-provided destination.
/// One task per `(batch, channel)` row, each writing its disjoint `&mut`
/// row of `y` directly; the single-row case instead parallelizes inside
/// the row through [`sliding::auto_with_into`]'s chunk+halo dispatch on
/// the same executor. Either way results are bit-identical to the serial
/// sweep.
pub fn pool1d_with_into(ex: &Executor, kind: PoolKind, x: &[f32], p: &Pool1dParams, y: &mut [f32]) {
    assert_eq!(x.len(), p.batch * p.channels * p.n, "input shape");
    assert_eq!(y.len(), p.y_len(), "dst length");
    crate::check::poison(y);
    let n_out = p.n_out();
    if n_out == 0 {
        return;
    }
    let rows = p.batch * p.channels;
    if ex.threads() <= 1 || rows == 1 || rows * n_out < PAR_MIN_FANOUT {
        for (r, yrow) in y.chunks_mut(n_out).enumerate() {
            pool1d_row(ex, kind, x, p, r, yrow);
        }
        crate::check::assert_no_poison(y, "pool1d_with_into");
        return;
    }
    // alloc-ok: one job closure per (batch, channel) row (fan-out setup).
    let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(rows);
    for (r, yrow) in y.chunks_mut(n_out).enumerate() {
        // alloc-ok: job closure box, amortized over a whole row.
        jobs.push(Box::new(move || pool1d_row(ex, kind, x, p, r, yrow)));
    }
    ex.scope(jobs);
    crate::check::assert_no_poison(y, "pool1d_with_into");
}

/// One `(batch, channel)` row: dense sliding pass + stride decimation.
/// Stride 1 writes the dense pass straight into the output row; the
/// common DNN case `stride ≥ w` (non-overlapping windows, e.g. 2×
/// down-sampling) folds each window directly — windows share no
/// elements, so the sliding machinery has nothing to reuse and the
/// direct fold is allocation-free (the serving path's strided pool
/// layers stop allocating a dense row per request). Overlapping strided
/// windows still go through the dense pass + decimation (the execution
/// plan routes them through [`pool1d_overlap_strided_with_into`], which
/// runs the same two steps out of the plan arena instead of a per-row
/// `Vec`).
fn pool1d_row(
    ex: &Executor,
    kind: PoolKind,
    x: &[f32],
    p: &Pool1dParams,
    r: usize,
    yrow: &mut [f32],
) {
    let xrow = &x[r * p.n..][..p.n];
    if p.stride == 1 {
        pool1d_row_dense_into(ex, kind, xrow, p.w, p.boundary, yrow);
        return;
    }
    if p.stride >= p.w && p.boundary == Boundary::Valid {
        pool1d_row_nonoverlap(kind, xrow, p, yrow);
        return;
    }
    let dense = pool1d_row_dense_with(ex, kind, xrow, p.w, p.boundary);
    for (t, v) in yrow.iter_mut().enumerate() {
        *v = dense[t * p.stride];
    }
}

/// Fold one window in ascending element order — the shared body of the
/// non-overlapping fast paths. Max/min match the naive sweep exactly;
/// avg matches up to the `·(1/w)` identity it shares with the dense
/// path.
#[inline]
fn fold_window(kind: PoolKind, win: &[f32], inv: f32) -> f32 {
    match kind {
        PoolKind::Avg => {
            let op = AddOp::<f32>::new();
            win.iter().fold(op.identity(), |acc, &x| op.combine(acc, x)) * inv
        }
        PoolKind::Max => {
            let op = MaxOp::<f32>::new();
            win.iter().fold(op.identity(), |acc, &x| op.combine(acc, x))
        }
        PoolKind::Min => {
            let op = MinOp::<f32>::new();
            win.iter().fold(op.identity(), |acc, &x| op.combine(acc, x))
        }
    }
}

/// Non-overlapping strided pooling: each output folds its window's
/// elements in ascending order (the naive-sweep order, so values match
/// [`pool1d_naive`] exactly for max/min and up to the usual FP identity
/// for avg). No scratch, no allocation.
pub(crate) fn pool1d_row_nonoverlap(
    kind: PoolKind,
    xrow: &[f32],
    p: &Pool1dParams,
    yrow: &mut [f32],
) {
    pool1d_row_nonoverlap_tile(kind, xrow, 0, p, 0, yrow);
}

/// Outputs `[t0, t0 + yseg.len())` of a non-overlapping strided pool
/// row whose input is held *partially*: `xrow` holds conceptual
/// positions `[x0, x0 + xrow.len())` of the full length-`p.n` row.
/// Exactly [`pool1d_row_nonoverlap`]'s fold with the window addresses
/// rebased — crate-visible because the execution plan's fused-chain
/// step folds pool stages with this routine out of its ring buffers;
/// reusing the fold (rather than reimplementing it) is what keeps fused
/// and unfused pooling bit-identical.
pub(crate) fn pool1d_row_nonoverlap_tile(
    kind: PoolKind,
    xrow: &[f32],
    x0: usize,
    p: &Pool1dParams,
    t0: usize,
    yseg: &mut [f32],
) {
    let inv = 1.0 / p.w as f32;
    for (i, v) in yseg.iter_mut().enumerate() {
        let win = &xrow[(t0 + i) * p.stride - x0..][..p.w];
        *v = fold_window(kind, win, inv);
    }
}

/// Upper bound on concurrent dense-row scratch buffers for
/// [`pool1d_overlap_strided_with_into`] — bounds the plan arena's pool
/// region to `POOL_SCRATCH_TASKS · dense_len` elements instead of one
/// dense row per `(batch, channel)` row.
pub const POOL_SCRATCH_TASKS: usize = 16;

/// Strided *overlapping*-window pooling (`1 < stride < w`, valid mode)
/// with caller-provided dense scratch: the same dense-sliding-pass +
/// stride-decimation steps as [`pool1d_with_into`]'s per-row fallback,
/// minus its per-row `Vec` allocation — the plan path hands in a slice
/// of the arena's pool region instead. `dense` must hold at least
/// `min(rows, POOL_SCRATCH_TASKS) · (n − w + 1)` elements. Values are
/// bit-identical to [`pool1d_with_into`] (same dense sweep, same
/// decimation) for every thread count.
pub fn pool1d_overlap_strided_with_into(
    ex: &Executor,
    kind: PoolKind,
    x: &[f32],
    p: &Pool1dParams,
    dense: &mut [f32],
    y: &mut [f32],
) {
    assert!(
        p.stride > 1 && p.stride < p.w && p.boundary == Boundary::Valid,
        "overlap-strided pool path needs 1 < stride < w, valid mode"
    );
    assert_eq!(x.len(), p.batch * p.channels * p.n, "input shape");
    assert_eq!(y.len(), p.y_len(), "dst length");
    // Poison `y` only: `dense` is scratch and legitimately holds a
    // partially-meaningful tail when rows differ in length.
    crate::check::poison(y);
    let n_out = p.n_out();
    if n_out == 0 {
        return;
    }
    let dense_len = p.dense_len();
    let rows = p.batch * p.channels;
    let tasks = rows.min(POOL_SCRATCH_TASKS);
    let dense = &mut dense[..tasks * dense_len];
    if ex.threads() <= 1 || tasks <= 1 || rows * n_out < PAR_MIN_FANOUT {
        let drow = &mut dense[..dense_len];
        for (r, yrow) in y.chunks_mut(n_out).enumerate() {
            let xrow = &x[r * p.n..][..p.n];
            pool1d_row_dense_into(ex, kind, xrow, p.w, p.boundary, drow);
            for (t, v) in yrow.iter_mut().enumerate() {
                *v = drow[t * p.stride];
            }
        }
        crate::check::assert_no_poison(y, "pool1d_overlap_strided_with_into");
        return;
    }
    // Balanced contiguous row chunks, one dense scratch row per task.
    // alloc-ok: one job closure per scratch task (fan-out setup).
    let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(tasks);
    let mut rest = &mut y[..];
    let mut bufs = dense.chunks_mut(dense_len);
    let mut r0 = 0usize;
    for ti in 0..tasks {
        let take = (rows - r0).div_ceil(tasks - ti);
        let rem = rest;
        let (ychunk, tail) = rem.split_at_mut(take * n_out);
        rest = tail;
        let drow = bufs.next().expect("one dense buffer per task");
        // alloc-ok: job closure box, amortized over a whole row chunk.
        jobs.push(Box::new(move || {
            for (j, yrow) in ychunk.chunks_mut(n_out).enumerate() {
                let xrow = &x[(r0 + j) * p.n..][..p.n];
                pool1d_row_dense_into(ex, kind, xrow, p.w, p.boundary, drow);
                for (t, v) in yrow.iter_mut().enumerate() {
                    *v = drow[t * p.stride];
                }
            }
        }));
        r0 += take;
    }
    ex.scope(jobs);
    crate::check::assert_no_poison(y, "pool1d_overlap_strided_with_into");
}

/// Dense stride-1 pooling of one row (shared worker pool).
pub fn pool1d_row_dense(kind: PoolKind, xrow: &[f32], w: usize, mode: Boundary) -> Vec<f32> {
    pool1d_row_dense_with(Executor::global(), kind, xrow, w, mode)
}

/// [`pool1d_row_dense`] on an explicit executor, so thread-scaling
/// measurements and parity tests control *all* parallelism, including
/// the in-row chunk+halo dispatch.
pub fn pool1d_row_dense_with(
    ex: &Executor,
    kind: PoolKind,
    xrow: &[f32],
    w: usize,
    mode: Boundary,
) -> Vec<f32> {
    // alloc-ok: Vec-returning wrapper; pool1d_row_dense_into is the hot path.
    let mut dst = vec![0.0f32; sliding::boundary::output_len(xrow.len(), w, mode)];
    pool1d_row_dense_into(ex, kind, xrow, w, mode, &mut dst);
    dst
}

/// [`pool1d_row_dense`] into a caller-provided buffer. Valid mode reads
/// the row in place; the other boundary modes materialize the `O(w)`
/// extension before the sweep.
pub fn pool1d_row_dense_into(
    ex: &Executor,
    kind: PoolKind,
    xrow: &[f32],
    w: usize,
    mode: Boundary,
    dst: &mut [f32],
) {
    crate::check::poison(dst);
    match kind {
        PoolKind::Avg => {
            extend_then_sweep(ex, AddOp::<f32>::new(), xrow, w, mode, dst);
            let inv = 1.0 / w as f32;
            for v in dst.iter_mut() {
                *v *= inv;
            }
        }
        PoolKind::Max => extend_then_sweep(ex, MaxOp::<f32>::new(), xrow, w, mode, dst),
        PoolKind::Min => extend_then_sweep(ex, MinOp::<f32>::new(), xrow, w, mode, dst),
    }
    crate::check::assert_no_poison(dst, "pool1d_row_dense_into");
}

/// Boundary-extend (borrowing the row in place for `Valid`) and run the
/// auto-dispatched sliding sweep into `dst` — the shared body of every
/// pooling kind.
fn extend_then_sweep<O: AssocOp<Elem = f32>>(
    ex: &Executor,
    op: O,
    xrow: &[f32],
    w: usize,
    mode: Boundary,
    dst: &mut [f32],
) {
    const P: usize = 64;
    let ext_store;
    let ext: &[f32] = if mode == Boundary::Valid {
        xrow
    } else {
        ext_store = sliding::extend(op, xrow, w, mode);
        &ext_store
    };
    sliding::auto_with_into(ex, op, ext, w, P, dst);
}

/// Naive pooling baseline (recompute every window) for benches/tests.
pub fn pool1d_naive(kind: PoolKind, x: &[f32], p: &Pool1dParams) -> Vec<f32> {
    assert_eq!(x.len(), p.batch * p.channels * p.n);
    let n_out = p.n_out();
    // alloc-ok: naive baseline for benches/tests, not on the plan run path.
    let mut y = vec![0.0f32; p.y_len()];
    for b in 0..p.batch {
        for c in 0..p.channels {
            let xrow = &x[(b * p.channels + c) * p.n..][..p.n];
            let dense = match kind {
                PoolKind::Avg => {
                    let op = AddOp::<f32>::new();
                    let ext = sliding::extend(op, xrow, p.w, p.boundary);
                    let mut s = sliding::sliding_naive(op, &ext, p.w);
                    for v in &mut s {
                        *v /= p.w as f32;
                    }
                    s
                }
                PoolKind::Max => {
                    let op = MaxOp::<f32>::new();
                    let ext = sliding::extend(op, xrow, p.w, p.boundary);
                    sliding::sliding_naive(op, &ext, p.w)
                }
                PoolKind::Min => {
                    let op = MinOp::<f32>::new();
                    let ext = sliding::extend(op, xrow, p.w, p.boundary);
                    sliding::sliding_naive(op, &ext, p.w)
                }
            };
            let yrow = &mut y[(b * p.channels + c) * n_out..][..n_out];
            for (t, v) in yrow.iter_mut().enumerate() {
                *v = dense[t * p.stride];
            }
        }
    }
    y
}

/// Sliding-window minimum over integer hash values — the minimizer-seed
/// primitive ([11]). Returns, for every window, the minimum value; the
/// classic genomics use selects the *position* of the minimum, recovered
/// here as well for the example binary.
pub fn sliding_minimum(xs: &[u64], w: usize) -> Vec<u64> {
    use crate::ops::MinOp;
    sliding::auto(MinOp::<u64>::new(), xs, w, 64)
}

/// Positions of each window's minimum (leftmost tie-break) — minimizer
/// sampling. O(N) via monotone deque, the classical streaming algorithm,
/// used to cross-check the sliding-sum variant in tests.
pub fn minimizer_positions(xs: &[u64], w: usize) -> Vec<usize> {
    let n = xs.len();
    if w == 0 || n < w {
        return Vec::new(); // alloc-ok: minimizer example path, not a DNN layer
    }
    // alloc-ok: minimizer example path (genomics cross-check), not on the
    // plan run path.
    let mut deque: std::collections::VecDeque<usize> = std::collections::VecDeque::new();
    let mut out = Vec::with_capacity(n - w + 1); // alloc-ok: example path
    for i in 0..n {
        while let Some(&back) = deque.back() {
            if xs[back] > xs[i] {
                deque.pop_back();
            } else {
                break;
            }
        }
        deque.push_back(i);
        if let Some(&front) = deque.front() {
            if front + w <= i {
                deque.pop_front();
            }
        }
        if i + 1 >= w {
            out.push(*deque.front().unwrap());
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn avg_pool_basic() {
        let p = Pool1dParams::new(1, 5, 2);
        let y = pool1d(PoolKind::Avg, &[2.0, 4.0, 6.0, 8.0, 10.0], &p);
        assert_eq!(y, vec![3.0, 5.0, 7.0, 9.0]);
    }

    #[test]
    fn max_pool_stride_equals_window() {
        let p = Pool1dParams::new(1, 6, 2).with_stride(2);
        let y = pool1d(PoolKind::Max, &[1.0, 5.0, 2.0, 2.0, 9.0, 0.0], &p);
        assert_eq!(y, vec![5.0, 2.0, 9.0]);
    }

    #[test]
    fn same_boundary_preserves_len() {
        let p = Pool1dParams::new(1, 7, 3).with_boundary(Boundary::SamePad);
        let x = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0];
        let y = pool1d(PoolKind::Max, &x, &p);
        assert_eq!(y.len(), 7);
        assert_eq!(y[0], 2.0); // max(-inf, 1, 2)
        assert_eq!(y[6], 7.0);
    }

    #[test]
    fn matches_naive_sweep() {
        let x: Vec<f32> = (0..200).map(|i| ((i * 31 % 53) as f32) - 26.0).collect();
        for kind in [PoolKind::Avg, PoolKind::Max, PoolKind::Min] {
            for w in [2usize, 3, 5, 8, 16] {
                for stride in [1usize, 2, 3] {
                    for mode in [Boundary::Valid, Boundary::SamePad] {
                        let p = Pool1dParams::new(1, 200, w).with_stride(stride).with_boundary(mode);
                        let a = pool1d(kind, &x, &p);
                        let b = pool1d_naive(kind, &x, &p);
                        assert_eq!(a.len(), b.len());
                        for (u, v) in a.iter().zip(&b) {
                            assert!((u - v).abs() < 1e-3, "{kind:?} w={w} s={stride} {mode:?}");
                        }
                    }
                }
            }
        }
    }

    /// The non-overlapping fast path (stride ≥ w, valid mode) folds in
    /// the naive sweep's order: max/min match the naive oracle exactly;
    /// avg matches up to the `·(1/w)` vs `/w` rounding identity it
    /// shares with the dense path.
    #[test]
    fn nonoverlap_strided_matches_naive() {
        let x: Vec<f32> = (0..300).map(|i| ((i * 37 % 101) as f32) - 50.0).collect();
        for (w, stride) in [(2usize, 2usize), (3, 3), (2, 5), (4, 4), (1, 3)] {
            let p = Pool1dParams::new(1, 300, w).with_stride(stride);
            for kind in [PoolKind::Max, PoolKind::Min] {
                assert_eq!(
                    pool1d(kind, &x, &p),
                    pool1d_naive(kind, &x, &p),
                    "{kind:?} w={w} s={stride}"
                );
            }
            let got = pool1d(PoolKind::Avg, &x, &p);
            let want = pool1d_naive(PoolKind::Avg, &x, &p);
            assert_eq!(got.len(), want.len());
            for (g, t) in got.iter().zip(&want) {
                assert!((g - t).abs() <= 1e-5 * (1.0 + t.abs()), "avg w={w} s={stride}");
            }
        }
    }

    #[test]
    fn multichannel_batched() {
        let p = Pool1dParams::new(2, 4, 2).with_batch(2);
        let x: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let y = pool1d(PoolKind::Avg, &x, &p);
        assert_eq!(y.len(), 2 * 2 * 3);
        assert_eq!(y[0], 0.5); // channel 0 row [0,1,2,3] → [0.5,1.5,2.5]
        assert_eq!(y[3], 4.5); // channel 1 row starts at 4
    }

    #[test]
    fn sliding_minimum_matches_positions() {
        let xs: Vec<u64> = (0..100).map(|i| (i * 2654435761u64) % 1000).collect();
        let mins = sliding_minimum(&xs, 7);
        let pos = minimizer_positions(&xs, 7);
        assert_eq!(mins.len(), pos.len());
        for (m, p_) in mins.iter().zip(&pos) {
            assert_eq!(*m, xs[*p_]);
        }
    }

    #[test]
    fn minimizer_positions_leftmost_tie() {
        let xs = [5u64, 1, 1, 5, 5];
        let pos = minimizer_positions(&xs, 3);
        assert_eq!(pos, vec![1, 1, 2]);
    }

    #[test]
    fn empty_window_edge() {
        assert!(minimizer_positions(&[1, 2], 3).is_empty());
        let p = Pool1dParams::new(1, 2, 3);
        assert_eq!(p.n_out(), 0);
    }
}
