//! 2-D pooling via *separable* sliding sums: max/avg pooling windows are
//! separable operators (`max` over a rectangle = `max` over rows then
//! over columns; sums likewise), so a `wh×ww` pool is two 1-D sliding
//! passes — `O(HW·(log wh + log ww))` instead of `O(HW·wh·ww)`. This is
//! the multi-dimensional extension sketched in the paper's §5, where the
//! arithmetic-per-load ratio "improves in the multiple dimensions".

use crate::ops::{AddOp, MaxOp, MinOp};
use crate::sliding;

use super::PoolKind;

/// 2-D pooling parameters over `[batch, c, h, w]`.
#[derive(Clone, Copy, Debug)]
pub struct Pool2dParams {
    pub batch: usize,
    pub channels: usize,
    pub h: usize,
    pub w: usize,
    pub wh: usize,
    pub ww: usize,
    pub stride_h: usize,
    pub stride_w: usize,
}

impl Pool2dParams {
    pub fn new(channels: usize, h: usize, w: usize, wh: usize, ww: usize) -> Self {
        Self {
            batch: 1,
            channels,
            h,
            w,
            wh,
            ww,
            stride_h: wh,
            stride_w: ww,
        }
    }

    pub fn with_batch(mut self, b: usize) -> Self {
        self.batch = b;
        self
    }

    pub fn with_strides(mut self, sh: usize, sw: usize) -> Self {
        assert!(sh >= 1 && sw >= 1);
        self.stride_h = sh;
        self.stride_w = sw;
        self
    }

    pub fn h_out(&self) -> usize {
        if self.h < self.wh {
            0
        } else {
            (self.h - self.wh) / self.stride_h + 1
        }
    }

    pub fn w_out(&self) -> usize {
        if self.w < self.ww {
            0
        } else {
            (self.w - self.ww) / self.stride_w + 1
        }
    }

    pub fn y_len(&self) -> usize {
        self.batch * self.channels * self.h_out() * self.w_out()
    }
}

/// Separable 2-D pooling (valid mode), parallel over `(batch × channel)`
/// planes on the shared worker pool.
pub fn pool2d(kind: PoolKind, x: &[f32], p: &Pool2dParams) -> Vec<f32> {
    pool2d_with(crate::exec::Executor::global(), kind, x, p)
}

/// [`pool2d`] writing into a caller-provided buffer of length
/// [`Pool2dParams::y_len`] (every element overwritten).
pub fn pool2d_into(kind: PoolKind, x: &[f32], p: &Pool2dParams, y: &mut [f32]) {
    pool2d_with_into(crate::exec::Executor::global(), kind, x, p, y)
}

/// [`pool2d`] on an explicit executor (scaling benches / parity tests).
pub fn pool2d_with(
    ex: &crate::exec::Executor,
    kind: PoolKind,
    x: &[f32],
    p: &Pool2dParams,
) -> Vec<f32> {
    // alloc-ok: Vec-returning wrapper; pool2d_with_into is the hot path.
    let mut y = vec![0.0f32; p.y_len()];
    pool2d_with_into(ex, kind, x, p, &mut y);
    y
}

/// The core kernel: explicit executor and caller-provided destination.
/// Planes are independent and each worker writes its disjoint `&mut`
/// plane of `y` directly, so any partitioning is bit-identical to the
/// serial sweep.
pub fn pool2d_with_into(
    ex: &crate::exec::Executor,
    kind: PoolKind,
    x: &[f32],
    p: &Pool2dParams,
    y: &mut [f32],
) {
    assert_eq!(x.len(), p.batch * p.channels * p.h * p.w, "input shape");
    assert_eq!(y.len(), p.y_len(), "dst length");
    crate::check::poison(y);
    let (h_out, w_out) = (p.h_out(), p.w_out());
    if h_out == 0 || w_out == 0 {
        return;
    }
    let plane_len = h_out * w_out;
    if ex.threads() <= 1 || y.len() < crate::exec::PAR_MIN_FANOUT {
        // Serial path reuses one set of scratch buffers across planes.
        let mut scratch = PlaneScratch::default();
        for (pi, out_plane) in y.chunks_mut(plane_len).enumerate() {
            pool2d_plane(ex, kind, x, p, pi, out_plane, &mut scratch);
        }
        crate::check::assert_no_poison(y, "pool2d_with_into");
        return;
    }
    // alloc-ok: one job closure per (batch, channel) plane (fan-out setup).
    let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(p.batch * p.channels);
    for (pi, out_plane) in y.chunks_mut(plane_len).enumerate() {
        // alloc-ok: job closure box, amortized over a whole plane.
        jobs.push(Box::new(move || {
            let mut scratch = PlaneScratch::default();
            pool2d_plane(ex, kind, x, p, pi, out_plane, &mut scratch);
        }));
    }
    ex.scope(jobs);
    crate::check::assert_no_poison(y, "pool2d_with_into");
}

/// Reusable per-plane scratch: row-pass buffer, column gather buffer,
/// and the vertical dense-window buffer.
#[derive(Default)]
struct PlaneScratch {
    rowbuf: Vec<f32>,
    col: Vec<f32>,
    dense_v: Vec<f32>,
}

/// One `(batch, channel)` plane: separable row pass then column pass.
fn pool2d_plane(
    ex: &crate::exec::Executor,
    kind: PoolKind,
    x: &[f32],
    p: &Pool2dParams,
    pi: usize,
    out_plane: &mut [f32],
    scratch: &mut PlaneScratch,
) {
    let (h_out, w_out) = (p.h_out(), p.w_out());
    let w_dense = p.w - p.ww + 1;
    let plane = &x[pi * p.h * p.w..][..p.h * p.w];
    // Row pass buffer: dense column windows for every row. `resize`
    // reuses capacity when the scratch is shared across planes; every
    // element is overwritten below, so the fill value is irrelevant.
    let rowbuf = &mut scratch.rowbuf;
    rowbuf.resize(p.h * w_dense, 0.0);
    // Column gather buffer for the vertical pass.
    let col = &mut scratch.col;
    col.resize(p.h, 0.0);
    // Horizontal 1-D sliding pass per row, written straight into the
    // reusable row buffer (no per-row Vec).
    for r in 0..p.h {
        let row = &plane[r * p.w..][..p.w];
        row_windows_into(ex, kind, row, p.ww, &mut rowbuf[r * w_dense..(r + 1) * w_dense]);
    }
    // Vertical 1-D sliding pass per (strided) output column.
    let dense_v = &mut scratch.dense_v;
    dense_v.resize(p.h - p.wh + 1, 0.0);
    for oc in 0..w_out {
        let src_col = oc * p.stride_w;
        for r in 0..p.h {
            col[r] = rowbuf[r * w_dense + src_col];
        }
        row_windows_into(ex, kind, col, p.wh, dense_v);
        for or in 0..h_out {
            out_plane[or * w_out + oc] = dense_v[or * p.stride_h];
        }
    }
    // avg: normalize by window area (both passes summed).
    if kind == PoolKind::Avg {
        let inv = 1.0 / (p.wh * p.ww) as f32;
        for v in out_plane.iter_mut() {
            *v *= inv;
        }
    }
}

/// Dense 1-D windows for the separable passes, written into the reusable
/// destination (sums stay unnormalized for avg; normalization happens
/// once at the end). Uses the caller's executor so scaling benches /
/// parity tests control all parallelism.
fn row_windows_into(
    ex: &crate::exec::Executor,
    kind: PoolKind,
    row: &[f32],
    w: usize,
    dst: &mut [f32],
) {
    match kind {
        PoolKind::Avg => sliding::auto_with_into(ex, AddOp::<f32>::new(), row, w, 64, dst),
        PoolKind::Max => sliding::auto_with_into(ex, MaxOp::<f32>::new(), row, w, 64, dst),
        PoolKind::Min => sliding::auto_with_into(ex, MinOp::<f32>::new(), row, w, 64, dst),
    }
}

/// Naive 2-D pooling oracle.
pub fn pool2d_naive(kind: PoolKind, x: &[f32], p: &Pool2dParams) -> Vec<f32> {
    assert_eq!(x.len(), p.batch * p.channels * p.h * p.w);
    let (h_out, w_out) = (p.h_out(), p.w_out());
    // alloc-ok: naive oracle for benches/tests, not on the plan run path.
    let mut y = vec![0.0f32; p.y_len()];
    for b in 0..p.batch {
        for c in 0..p.channels {
            let plane = &x[((b * p.channels + c) * p.h) * p.w..][..p.h * p.w];
            for or in 0..h_out {
                for oc in 0..w_out {
                    let mut acc = match kind {
                        PoolKind::Avg => 0.0f32,
                        PoolKind::Max => f32::NEG_INFINITY,
                        PoolKind::Min => f32::INFINITY,
                    };
                    for dy in 0..p.wh {
                        for dx in 0..p.ww {
                            let v = plane
                                [(or * p.stride_h + dy) * p.w + oc * p.stride_w + dx];
                            acc = match kind {
                                PoolKind::Avg => acc + v,
                                PoolKind::Max => acc.max(v),
                                PoolKind::Min => acc.min(v),
                            };
                        }
                    }
                    if kind == PoolKind::Avg {
                        acc /= (p.wh * p.ww) as f32;
                    }
                    y[((b * p.channels + c) * h_out + or) * w_out + oc] = acc;
                }
            }
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Rng;

    #[test]
    fn known_2x2_max() {
        let p = Pool2dParams::new(1, 4, 4, 2, 2);
        #[rustfmt::skip]
        let x = [
            1.0f32, 2.0, 5.0, 1.0,
            3.0,    4.0, 0.0, 2.0,
            9.0,    0.0, 1.0, 1.0,
            0.0,    8.0, 1.0, 7.0,
        ];
        let y = pool2d(PoolKind::Max, &x, &p);
        assert_eq!(y, vec![4.0, 5.0, 9.0, 7.0]);
    }

    #[test]
    fn matches_naive_sweep() {
        let mut rng = Rng::new(0x2DF);
        for (h, w, wh, ww, sh, sw) in [
            (8usize, 8usize, 2usize, 2usize, 2usize, 2usize),
            (9, 7, 3, 2, 1, 1),
            (16, 16, 4, 4, 4, 4),
            (12, 20, 3, 5, 2, 3),
            (6, 6, 6, 6, 1, 1),
        ] {
            let p = Pool2dParams::new(2, h, w, wh, ww)
                .with_batch(2)
                .with_strides(sh, sw);
            let x = rng.vec_uniform(2 * 2 * h * w, -3.0, 3.0);
            for kind in [PoolKind::Max, PoolKind::Avg, PoolKind::Min] {
                let a = pool2d(kind, &x, &p);
                let b = pool2d_naive(kind, &x, &p);
                assert_eq!(a.len(), b.len(), "{kind:?} {h}x{w}");
                for (u, v) in a.iter().zip(&b) {
                    assert!((u - v).abs() < 1e-3, "{kind:?} {h}x{w}/{wh}x{ww}: {u} vs {v}");
                }
            }
        }
    }

    #[test]
    fn too_small_input_is_empty() {
        let p = Pool2dParams::new(1, 2, 2, 3, 3);
        assert_eq!(pool2d(PoolKind::Max, &[0.0; 4], &p).len(), 0);
    }
}
