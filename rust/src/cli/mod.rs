//! Argument-parsing substrate (clap is unavailable offline).
//! Subcommand + `--flag value` / `--flag=value` / boolean switches, with
//! typed accessors, defaulting, and generated usage text.

use std::collections::BTreeMap;

/// Parsed command line: a subcommand plus flags and positionals.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: Option<String>,
    pub flags: BTreeMap<String, String>,
    pub switches: Vec<String>,
    pub positional: Vec<String>,
}

/// Declarative flag spec used for usage text + unknown-flag detection.
#[derive(Clone, Debug)]
pub struct FlagSpec {
    pub name: &'static str,
    pub value: Option<&'static str>,
    pub help: &'static str,
}

/// Parse `argv[1..]`. The first non-flag token becomes the subcommand;
/// `--name value`, `--name=value` and bare `--switch` are supported.
/// Known switches must be listed so `--switch value` is not mis-eaten.
pub fn parse_args<I: IntoIterator<Item = String>>(argv: I, known_switches: &[&str]) -> Args {
    let mut args = Args::default();
    let mut iter = argv.into_iter().peekable();
    while let Some(tok) = iter.next() {
        if let Some(flag) = tok.strip_prefix("--") {
            if let Some((name, value)) = flag.split_once('=') {
                args.flags.insert(name.to_string(), value.to_string());
            } else if known_switches.contains(&flag) {
                args.switches.push(flag.to_string());
            } else if let Some(next) = iter.peek() {
                if next.starts_with("--") {
                    args.switches.push(flag.to_string());
                } else {
                    let v = iter.next().unwrap();
                    args.flags.insert(flag.to_string(), v);
                }
            } else {
                args.switches.push(flag.to_string());
            }
        } else if args.command.is_none() {
            args.command = Some(tok);
        } else {
            args.positional.push(tok);
        }
    }
    args
}

impl Args {
    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    pub fn get(&self, flag: &str) -> Option<&str> {
        self.flags.get(flag).map(|s| s.as_str())
    }

    pub fn get_usize(&self, flag: &str, default: usize) -> Result<usize, String> {
        match self.get(flag) {
            None => Ok(default),
            Some(v) => v
                .parse::<usize>()
                .map_err(|_| format!("--{flag} expects an unsigned integer, got {v:?}")),
        }
    }

    pub fn get_u64(&self, flag: &str, default: u64) -> Result<u64, String> {
        match self.get(flag) {
            None => Ok(default),
            Some(v) => v
                .parse::<u64>()
                .map_err(|_| format!("--{flag} expects an unsigned integer, got {v:?}")),
        }
    }

    pub fn get_f64(&self, flag: &str, default: f64) -> Result<f64, String> {
        match self.get(flag) {
            None => Ok(default),
            Some(v) => v
                .parse::<f64>()
                .map_err(|_| format!("--{flag} expects a number, got {v:?}")),
        }
    }

    pub fn get_str(&self, flag: &str, default: &str) -> String {
        self.get(flag).unwrap_or(default).to_string()
    }

    /// Error if any flag is not in `specs` (catches typos).
    pub fn reject_unknown(&self, specs: &[FlagSpec]) -> Result<(), String> {
        let known: Vec<&str> = specs.iter().map(|s| s.name).collect();
        for k in self.flags.keys() {
            if !known.contains(&k.as_str()) {
                return Err(format!("unknown flag --{k}"));
            }
        }
        for s in &self.switches {
            if !known.contains(&s.as_str()) {
                return Err(format!("unknown switch --{s}"));
            }
        }
        Ok(())
    }
}

/// Render usage text for a subcommand.
pub fn usage(cmd: &str, about: &str, specs: &[FlagSpec]) -> String {
    let mut out = format!("swsnn {cmd} — {about}\n\nflags:\n");
    for s in specs {
        let lhs = match s.value {
            Some(v) => format!("--{} <{}>", s.name, v),
            None => format!("--{}", s.name),
        };
        out.push_str(&format!("  {lhs:<28} {}\n", s.help));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str], switches: &[&str]) -> Args {
        parse_args(toks.iter().map(|s| s.to_string()), switches)
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse(&["bench-fig1", "--n", "1000", "--algo=sliding"], &[]);
        assert_eq!(a.command.as_deref(), Some("bench-fig1"));
        assert_eq!(a.get("n"), Some("1000"));
        assert_eq!(a.get("algo"), Some("sliding"));
    }

    #[test]
    fn known_switch_not_eats_value() {
        let a = parse(&["run", "--verbose", "file.toml"], &["verbose"]);
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["file.toml"]);
    }

    #[test]
    fn unknown_trailing_flag_is_switch() {
        let a = parse(&["run", "--fast"], &[]);
        assert!(a.has("fast"));
    }

    #[test]
    fn typed_accessors_and_defaults() {
        let a = parse(&["x", "--n", "5"], &[]);
        assert_eq!(a.get_usize("n", 1).unwrap(), 5);
        assert_eq!(a.get_usize("m", 7).unwrap(), 7);
        assert!(parse(&["x", "--n", "zz"], &[]).get_usize("n", 1).is_err());
    }

    #[test]
    fn reject_unknown_flags() {
        let specs = [FlagSpec {
            name: "n",
            value: Some("int"),
            help: "",
        }];
        assert!(parse(&["x", "--n", "1"], &[]).reject_unknown(&specs).is_ok());
        assert!(parse(&["x", "--m", "1"], &[]).reject_unknown(&specs).is_err());
    }

    #[test]
    fn usage_renders() {
        let text = usage(
            "serve",
            "run the server",
            &[FlagSpec {
                name: "port",
                value: Some("u16"),
                help: "listen port",
            }],
        );
        assert!(text.contains("--port <u16>"));
        assert!(text.contains("listen port"));
    }
}
