//! Figure/table regeneration harnesses (DESIGN.md §4 experiment index).
//!
//! Each function reproduces one of the paper's evaluation artifacts and
//! returns the rendered [`Table`]; the `cargo bench` targets and the CLI
//! subcommands are thin wrappers. Absolute numbers differ from the
//! authors' Xeon testbed — the *shape* criteria are asserted by
//! `rust/tests/integration.rs` and recorded in EXPERIMENTS.md.

use crate::bench::{bench, BenchConfig, Table};
use crate::conv::{conv1d, conv1d_im2col_with, conv1d_sliding_with, Conv1dParams, ConvBackend};
use crate::exec::Executor;
use crate::ops::{AddOp, MaxOp, MinOp};
use crate::pool::{pool1d_naive, pool1d_with, Pool1dParams, PoolKind};
use crate::scan;
use crate::sliding::{self, Algo};
use crate::workload::{chaudhary_dilated_suite, fig1_signal, Rng};

/// Run one conv backend with kernel parallelism pinned to a single
/// thread. The paper-reproduction tables (Fig 1/2, ABL-B) compare
/// *algorithms*, so the sliding kernel must not get a multicore edge
/// over the serial im2col baseline — the worker-pool axis is measured
/// separately by [`fig1_scaling`] / [`tbl_sliding_scaling`].
fn conv1d_1t(
    ex1: &Executor,
    backend: ConvBackend,
    x: &[f32],
    w: &[f32],
    p: &Conv1dParams,
) -> Vec<f32> {
    match backend {
        ConvBackend::Sliding => conv1d_sliding_with(ex1, x, w, None, p),
        // The GEMM under im2col is row-parallel on the global pool now,
        // so the baseline must be pinned to the same executor too.
        ConvBackend::Im2colGemm => conv1d_im2col_with(ex1, x, w, None, p),
        other => conv1d(other, x, w, None, p),
    }
}

/// One Fig-1 row: filter size → im2col/sliding times and speedup.
#[derive(Clone, Debug)]
pub struct Fig1Row {
    pub k: usize,
    pub im2col_ns: f64,
    pub sliding_ns: f64,
    pub speedup: f64,
}

/// Figure 1 — speedup of sliding 1-D convolution over the im2col+GEMM
/// baseline on a large 1-D input, across filter sizes. Paper claim: the
/// speedup is "approximately proportional to the logarithm of the kernel
/// size".
pub fn fig1(cfg: &BenchConfig, n: usize, ks: &[usize]) -> (Table, Vec<Fig1Row>) {
    let mut rng = Rng::new(0xF161);
    let ex1 = Executor::new(1);
    let x = fig1_signal(&mut rng, n);
    let mut table = Table::new(
        &format!("Fig 1 — 1-D convolution speedup vs MlasConv-style im2col+GEMM (N={n}, 1 thread)"),
        &["k", "im2col+gemm", "sliding", "speedup", "Gmac/s sliding"],
    );
    let mut rows = Vec::new();
    for &k in ks {
        let w = rng.vec_uniform(k, -1.0, 1.0);
        let p = Conv1dParams::new(1, 1, n, k);
        let macs = p.macs() as f64;

        let m_gemm = bench(cfg, || {
            std::hint::black_box(conv1d_1t(
                &ex1,
                ConvBackend::Im2colGemm,
                std::hint::black_box(&x),
                &w,
                &p,
            ));
        });
        let m_slide = bench(cfg, || {
            std::hint::black_box(conv1d_1t(
                &ex1,
                ConvBackend::Sliding,
                std::hint::black_box(&x),
                &w,
                &p,
            ));
        });
        let speedup = m_gemm.median_ns() / m_slide.median_ns();
        table.row(vec![
            k.to_string(),
            crate::bench::fmt_duration(m_gemm.median),
            crate::bench::fmt_duration(m_slide.median),
            format!("{speedup:.2}x"),
            format!("{:.2}", macs / m_slide.median_ns()),
        ]);
        rows.push(Fig1Row {
            k,
            im2col_ns: m_gemm.median_ns(),
            sliding_ns: m_slide.median_ns(),
            speedup,
        });
    }
    (table, rows)
}

/// One thread-scaling row.
#[derive(Clone, Debug)]
pub struct ScalingRow {
    pub threads: usize,
    pub median_ns: f64,
    /// Speedup vs the 1-thread row (the paper's `P` axis, measured).
    pub speedup: f64,
}

/// Fig 1b — thread scaling of the sliding conv hot path on the Fig-1
/// shape (single row, long signal: the worst case for row-parallelism,
/// covered by within-row column segmentation). Reports measured speedup
/// vs 1 thread; the paper's model predicts ~linear in P until the memory
/// bandwidth roof.
pub fn fig1_scaling(
    cfg: &BenchConfig,
    n: usize,
    k: usize,
    threads: &[usize],
) -> (Table, Vec<ScalingRow>) {
    let mut rng = Rng::new(0xF163);
    let x = fig1_signal(&mut rng, n);
    let w = rng.vec_uniform(k, -1.0, 1.0);
    let p = Conv1dParams::new(1, 1, n, k);
    let macs = p.macs() as f64;
    let mut table = Table::new(
        &format!("Fig 1b — conv1d sliding thread scaling (N={n}, k={k})"),
        &["threads", "median", "Gmac/s", "speedup vs 1T"],
    );
    let mut measured = Vec::new();
    for &t in threads {
        let ex = Executor::new(t);
        let m = bench(cfg, || {
            std::hint::black_box(conv1d_sliding_with(
                &ex,
                std::hint::black_box(&x),
                &w,
                None,
                &p,
            ));
        });
        measured.push((t, m));
    }
    let base_ns = scaling_base_ns(&measured);
    let mut rows = Vec::new();
    for (t, m) in measured {
        let speedup = base_ns / m.median_ns();
        table.row(vec![
            t.to_string(),
            crate::bench::fmt_duration(m.median),
            format!("{:.2}", macs / m.median_ns()),
            format!("{speedup:.2}x"),
        ]);
        rows.push(ScalingRow {
            threads: t,
            median_ns: m.median_ns(),
            speedup,
        });
    }
    (table, rows)
}

/// Baseline for "speedup vs 1T" columns: the `threads == 1` row's
/// median, falling back to the first row if the sweep omits 1.
fn scaling_base_ns(measured: &[(usize, crate::bench::Measurement)]) -> f64 {
    measured
        .iter()
        .find(|(t, _)| *t == 1)
        .or_else(|| measured.first())
        .map(|(_, m)| m.median_ns())
        .unwrap_or(f64::NAN)
}

/// TBL-A3 — thread scaling of the chunk+halo parallel sliding-sum
/// dispatch (flat_tree and the auto dispatcher) on one operator.
pub fn tbl_sliding_scaling(
    cfg: &BenchConfig,
    n: usize,
    w: usize,
    threads: &[usize],
) -> Table {
    let mut rng = Rng::new(0xA163);
    let xs = rng.vec_uniform(n, -1.0, 1.0);
    let op = AddOp::<f32>::new();
    let mut table = Table::new(
        &format!("TBL-A3 — sliding-sum thread scaling (op=add, N={n}, w={w})"),
        &["threads", "flat_tree", "auto", "flat_tree speedup vs 1T"],
    );
    let mut measured = Vec::new();
    for &t in threads {
        let ex = Executor::new(t);
        let m_ft = bench(cfg, || {
            std::hint::black_box(sliding::run_with(
                &ex,
                Algo::FlatTree,
                op,
                std::hint::black_box(&xs),
                w,
                64,
            ));
        });
        let m_auto = bench(cfg, || {
            std::hint::black_box(sliding::auto_with(&ex, op, std::hint::black_box(&xs), w, 64));
        });
        measured.push((t, m_ft, m_auto));
    }
    let base_ns = {
        let fts: Vec<(usize, crate::bench::Measurement)> =
            measured.iter().map(|(t, ft, _)| (*t, ft.clone())).collect();
        scaling_base_ns(&fts)
    };
    for (t, m_ft, m_auto) in measured {
        table.row(vec![
            t.to_string(),
            crate::bench::fmt_duration(m_ft.median),
            crate::bench::fmt_duration(m_auto.median),
            format!("{:.2}x", base_ns / m_ft.median_ns()),
        ]);
    }
    table
}

/// One Fig-2 row.
#[derive(Clone, Debug)]
pub struct Fig2Row {
    pub name: String,
    pub speedup: f64,
    pub small_set: bool,
}

/// Figure 2 — dilated-convolution speedup on the Chaudhary et al. [4]
/// scenario. Paper claims: up to 6.8× on the small set, ≈4× across the
/// board.
pub fn fig2(cfg: &BenchConfig) -> (Table, Vec<Fig2Row>) {
    let mut rng = Rng::new(0xF162);
    let ex1 = Executor::new(1);
    let mut table = Table::new(
        "Fig 2 — dilated convolution speedup (Chaudhary scenario, 1 thread)",
        &["workload", "im2col+gemm", "sliding", "speedup"],
    );
    let mut rows = Vec::new();
    for (name, p) in chaudhary_dilated_suite() {
        let x = rng.vec_uniform(p.x_len(), -1.0, 1.0);
        let w = rng.vec_uniform(p.w_len(), -1.0, 1.0);
        let m_gemm = bench(cfg, || {
            std::hint::black_box(conv1d_1t(
                &ex1,
                ConvBackend::Im2colGemm,
                std::hint::black_box(&x),
                &w,
                &p,
            ));
        });
        let m_slide = bench(cfg, || {
            std::hint::black_box(conv1d_1t(
                &ex1,
                ConvBackend::Sliding,
                std::hint::black_box(&x),
                &w,
                &p,
            ));
        });
        let speedup = m_gemm.median_ns() / m_slide.median_ns();
        table.row(vec![
            name.clone(),
            crate::bench::fmt_duration(m_gemm.median),
            crate::bench::fmt_duration(m_slide.median),
            format!("{speedup:.2}x"),
        ]);
        rows.push(Fig2Row {
            small_set: name.starts_with("small/"),
            name,
            speedup,
        });
    }
    (table, rows)
}

/// TBL-A — the §3 algorithm family compared on one operator: time per
/// element for each algorithm across window sizes, normalized speedup vs
/// naive. Also demonstrates the `O(P/w)` → `O(P/log w)` gap (linear vs
/// log variants at large w).
/// Every algorithm runs serially here: `run` would give the chunk-safe
/// algorithms a multicore edge the vector-input/ping-pong family cannot
/// have (they are excluded from parallel dispatch), which would corrupt
/// the intra-family comparison. The worker-pool axis is measured by
/// [`tbl_sliding_scaling`].
pub fn tbl_algorithms(cfg: &BenchConfig, n: usize, p_width: usize, ws: &[usize]) -> Table {
    let mut rng = Rng::new(0xA160);
    let xs = rng.vec_uniform(n, -1.0, 1.0);
    let op = AddOp::<f32>::new();
    let mut table = Table::new(
        &format!("TBL-A — sliding-sum algorithms (op=add, N={n}, P={p_width}, 1 thread)"),
        &["w", "naive", "scalar_input", "vector_input", "vector_input_log", "ping_pong", "vector_slide", "vector_slide_tree", "flat_tree", "best_speedup"],
    );
    for &w in ws {
        let mut cells = vec![w.to_string()];
        let naive_m = bench(cfg, || {
            let xs = std::hint::black_box(&xs);
            std::hint::black_box(sliding::run_serial(Algo::Naive, op, xs, w, p_width));
        });
        cells.push(crate::bench::fmt_duration(naive_m.median));
        let mut best = f64::INFINITY;
        for algo in [
            Algo::ScalarInput,
            Algo::VectorInput,
            Algo::VectorInputLog,
            Algo::PingPong,
            Algo::VectorSlide,
            Algo::VectorSlideTree,
            Algo::FlatTree,
        ] {
            let m = bench(cfg, || {
                let xs = std::hint::black_box(&xs);
                std::hint::black_box(sliding::run_serial(algo, op, xs, w, p_width));
            });
            best = best.min(m.median_ns());
            cells.push(crate::bench::fmt_duration(m.median));
        }
        cells.push(format!("{:.2}x", naive_m.median_ns() / best));
        table.row(cells);
    }
    table
}

/// TBL-A2 — sliding minimum (associative, idempotent) across algorithms,
/// the paper's "sliding window minimum can be computed using the faster
/// version" example.
pub fn tbl_sliding_min(cfg: &BenchConfig, n: usize, p_width: usize, ws: &[usize]) -> Table {
    let mut rng = Rng::new(0xA161);
    let xs = rng.vec_uniform(n, -100.0, 100.0);
    let op = MinOp::<f32>::new();
    let mut table = Table::new(
        &format!("TBL-A2 — sliding minimum (op=min, N={n}, P={p_width}, 1 thread)"),
        &["w", "naive", "vector_slide", "vector_slide_tree", "flat_tree", "tree_vs_naive"],
    );
    for &w in ws {
        let naive_m = bench(cfg, || {
            let xs = std::hint::black_box(&xs);
            std::hint::black_box(sliding::run_serial(Algo::Naive, op, xs, w, p_width));
        });
        let lin_m = bench(cfg, || {
            let xs = std::hint::black_box(&xs);
            std::hint::black_box(sliding::run_serial(Algo::VectorSlide, op, xs, w, p_width));
        });
        let tree_m = bench(cfg, || {
            let xs = std::hint::black_box(&xs);
            std::hint::black_box(sliding::run_serial(Algo::VectorSlideTree, op, xs, w, p_width));
        });
        let flat_m = bench(cfg, || {
            let xs = std::hint::black_box(&xs);
            std::hint::black_box(sliding::run_serial(Algo::FlatTree, op, xs, w, p_width));
        });
        table.row(vec![
            w.to_string(),
            crate::bench::fmt_duration(naive_m.median),
            crate::bench::fmt_duration(lin_m.median),
            crate::bench::fmt_duration(tree_m.median),
            crate::bench::fmt_duration(flat_m.median),
            format!("{:.2}x", naive_m.median_ns() / flat_m.median_ns()),
        ]);
    }
    table
}

/// TBL-P — pooling via sliding sums vs naive recomputation (§2.3),
/// single-threaded so the comparison isolates the algorithm (the naive
/// baseline is serial).
pub fn tbl_pooling(cfg: &BenchConfig, n: usize, ws: &[usize]) -> Table {
    let mut rng = Rng::new(0xB001);
    let ex1 = Executor::new(1);
    let x = rng.vec_uniform(n, -1.0, 1.0);
    let mut table = Table::new(
        &format!("TBL-P — pooling as sliding sum vs naive (N={n}, stride=1, 1 thread)"),
        &["kind", "w", "naive", "sliding", "speedup"],
    );
    for kind in [PoolKind::Avg, PoolKind::Max] {
        for &w in ws {
            let p = Pool1dParams::new(1, n, w);
            let m_naive = bench(cfg, || {
                std::hint::black_box(pool1d_naive(kind, std::hint::black_box(&x), &p));
            });
            let m_slide = bench(cfg, || {
                std::hint::black_box(pool1d_with(&ex1, kind, std::hint::black_box(&x), &p));
            });
            table.row(vec![
                kind.name().to_string(),
                w.to_string(),
                crate::bench::fmt_duration(m_naive.median),
                crate::bench::fmt_duration(m_slide.median),
                format!("{:.2}x", m_naive.median_ns() / m_slide.median_ns()),
            ]);
        }
    }
    table
}

/// TBL-S — scan/reduce substrate (§2.1): sequential vs Hillis–Steele vs
/// Blelloch, plus tree/sequential reduce.
pub fn tbl_scan(cfg: &BenchConfig, ns: &[usize]) -> Table {
    let mut rng = Rng::new(0x5CA9);
    let mut table = Table::new(
        "TBL-S — prefix-sum substrate (op=add)",
        &["N", "scan_seq", "scan_hillis_steele", "scan_blelloch", "reduce_seq", "reduce_tree"],
    );
    let op = AddOp::<f32>::new();
    for &n in ns {
        let xs = rng.vec_uniform(n, -1.0, 1.0);
        let m1 = bench(cfg, || {
            std::hint::black_box(scan::scan_inclusive(op, std::hint::black_box(&xs)));
        });
        let m2 = bench(cfg, || {
            std::hint::black_box(scan::scan_hillis_steele(op, std::hint::black_box(&xs)));
        });
        let m3 = bench(cfg, || {
            std::hint::black_box(scan::scan_blelloch(op, std::hint::black_box(&xs)));
        });
        let m4 = bench(cfg, || {
            std::hint::black_box(scan::reduce_seq(op, std::hint::black_box(&xs)));
        });
        let m5 = bench(cfg, || {
            std::hint::black_box(scan::reduce_tree(op, std::hint::black_box(&xs)));
        });
        table.row(vec![
            n.to_string(),
            crate::bench::fmt_duration(m1.median),
            crate::bench::fmt_duration(m2.median),
            crate::bench::fmt_duration(m3.median),
            crate::bench::fmt_duration(m4.median),
            crate::bench::fmt_duration(m5.median),
        ]);
    }
    table
}

/// ABL-B — backend ablation at a fixed shape: all four conv backends,
/// including the literal pair-operator formulation. Single-threaded,
/// like every cross-algorithm table.
pub fn tbl_backends(cfg: &BenchConfig, n: usize, ks: &[usize]) -> Table {
    let mut rng = Rng::new(0xAB1E);
    let ex1 = Executor::new(1);
    let x = rng.vec_uniform(n, -1.0, 1.0);
    let mut table = Table::new(
        &format!("ABL-B — conv backend ablation (N={n}, 1 thread)"),
        &["k", "direct", "im2col_gemm", "sliding", "sliding_pair"],
    );
    for &k in ks {
        let w = rng.vec_uniform(k, -1.0, 1.0);
        let p = Conv1dParams::new(1, 1, n, k);
        let mut cells = vec![k.to_string()];
        for backend in ConvBackend::ALL {
            let m = bench(cfg, || {
                std::hint::black_box(conv1d_1t(&ex1, backend, std::hint::black_box(&x), &w, &p));
            });
            cells.push(crate::bench::fmt_duration(m.median));
        }
        table.row(cells);
    }
    table
}

/// Sliding-sum max-op table used by the CLI `pool` subcommand demo.
pub fn quick_max_demo(n: usize, w: usize) -> f64 {
    let mut rng = Rng::new(1);
    let xs = rng.vec_uniform(n, -1.0, 1.0);
    let out = sliding::auto(MaxOp::<f32>::new(), &xs, w, 64);
    out.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64
}
