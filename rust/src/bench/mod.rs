//! Measurement substrate (criterion is unavailable offline): warmup +
//! repetition timing with median/MAD statistics, throughput computation,
//! and markdown/CSV table emission used by every `cargo bench` target.

pub mod figs;

use std::time::{Duration, Instant};

/// Measurement configuration.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    /// Minimum wall time spent warming up.
    pub warmup: Duration,
    /// Target wall time for the measurement phase.
    pub measure: Duration,
    /// Hard cap on measured iterations.
    pub max_iters: u64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(100),
            measure: Duration::from_millis(400),
            max_iters: 10_000,
        }
    }
}

impl BenchConfig {
    /// Faster profile for CI/self-tests.
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(20),
            measure: Duration::from_millis(80),
            max_iters: 2_000,
        }
    }

    /// Honor `SWSNN_BENCH_QUICK=1` for fast smoke runs.
    pub fn from_env() -> Self {
        if std::env::var("SWSNN_BENCH_QUICK").is_ok_and(|v| v == "1") {
            Self::quick()
        } else {
            Self::default()
        }
    }
}

/// Whether machine-readable JSON output was requested: a `--json` argv
/// flag on the bench target / CLI subcommand, or `SWSNN_BENCH_JSON=1`.
/// When on, [`Table::emit`] also writes `bench_results/BENCH_<table>.json`
/// so the perf trajectory can be tracked across PRs.
pub fn json_enabled() -> bool {
    std::env::args().any(|a| a == "--json")
        || std::env::var("SWSNN_BENCH_JSON").is_ok_and(|v| v == "1")
}

/// One benchmark's result.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub iters: u64,
    /// Median per-iteration time.
    pub median: Duration,
    /// Median absolute deviation.
    pub mad: Duration,
    pub min: Duration,
}

impl Measurement {
    pub fn median_ns(&self) -> f64 {
        self.median.as_nanos() as f64
    }

    /// Items (elements, MACs…) per second given per-iteration item count.
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.median.as_secs_f64()
    }
}

/// Time `f`, returning robust statistics. `f` must perform one complete
/// unit of work per call; use `std::hint::black_box` on its inputs and
/// outputs to defeat DCE.
pub fn bench<F: FnMut()>(cfg: &BenchConfig, mut f: F) -> Measurement {
    // Warmup.
    let start = Instant::now();
    while start.elapsed() < cfg.warmup {
        f();
    }
    // Measure individual iterations (coarse ones) or batched (fast ones).
    let probe = {
        let t = Instant::now();
        f();
        t.elapsed()
    };
    // Batch so each sample is ≥ ~20µs, bounding timer overhead to <1%.
    let batch = (Duration::from_micros(20).as_nanos() / probe.as_nanos().max(1)).max(1) as u64;
    let mut samples = Vec::new();
    let begin = Instant::now();
    let mut total_iters = 0u64;
    while begin.elapsed() < cfg.measure && total_iters < cfg.max_iters {
        let t = Instant::now();
        for _ in 0..batch {
            f();
        }
        samples.push(t.elapsed() / batch as u32);
        total_iters += batch;
    }
    samples.sort_unstable();
    let median = samples[samples.len() / 2];
    let min = samples[0];
    let mut devs: Vec<Duration> = samples
        .iter()
        .map(|s| {
            if *s > median {
                *s - median
            } else {
                median - *s
            }
        })
        .collect();
    devs.sort_unstable();
    let mad = devs[devs.len() / 2];
    Measurement {
        iters: total_iters,
        median,
        mad,
        min,
    }
}

/// A result table with aligned markdown rendering + CSV dump.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "column count");
        self.rows.push(cells);
    }

    /// Render aligned markdown.
    pub fn markdown(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            let padded: Vec<String> = cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect();
            format!("| {} |", padded.join(" | "))
        };
        let sep = format!(
            "|{}|",
            widths
                .iter()
                .map(|w| "-".repeat(w + 2))
                .collect::<Vec<_>>()
                .join("|")
        );
        let mut out = format!("\n## {}\n\n", self.title);
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out
    }

    /// CSV for downstream plotting.
    pub fn csv(&self) -> String {
        let mut out = self.headers.join(",");
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.join(","));
            out.push('\n');
        }
        out
    }

    /// Machine-readable JSON (`{"title", "headers", "rows"}`), hand
    /// rolled because serde is unavailable offline.
    pub fn json(&self) -> String {
        fn esc(s: &str) -> String {
            let mut out = String::with_capacity(s.len() + 2);
            for ch in s.chars() {
                match ch {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\r' => out.push_str("\\r"),
                    '\t' => out.push_str("\\t"),
                    c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                    c => out.push(c),
                }
            }
            out
        }
        let list = |cells: &[String]| -> String {
            let quoted: Vec<String> = cells.iter().map(|c| format!("\"{}\"", esc(c))).collect();
            format!("[{}]", quoted.join(","))
        };
        let rows: Vec<String> = self.rows.iter().map(|r| list(r)).collect();
        format!(
            "{{\"title\":\"{}\",\"headers\":{},\"rows\":[{}]}}\n",
            esc(&self.title),
            list(&self.headers),
            rows.join(",")
        )
    }

    /// Print markdown to stdout and write CSV (plus, with `--json` /
    /// `SWSNN_BENCH_JSON=1`, a `BENCH_<table>.json` twin) under
    /// `bench_results/`.
    pub fn emit(&self, csv_name: &str) {
        println!("{}", self.markdown());
        let dir = std::path::Path::new("bench_results");
        if std::fs::create_dir_all(dir).is_ok() {
            let path = dir.join(csv_name);
            if let Err(e) = std::fs::write(&path, self.csv()) {
                eprintln!("warn: could not write {}: {e}", path.display());
            } else {
                println!("(csv written to {})", path.display());
            }
            if json_enabled() {
                let stem = csv_name.strip_suffix(".csv").unwrap_or(csv_name);
                let jpath = dir.join(format!("BENCH_{stem}.json"));
                if let Err(e) = std::fs::write(&jpath, self.json()) {
                    eprintln!("warn: could not write {}: {e}", jpath.display());
                } else {
                    println!("(json written to {})", jpath.display());
                }
            }
        }
    }
}

/// Format a duration human-readably (ns/µs/ms/s).
pub fn fmt_duration(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns}ns")
    } else if ns < 1_000_000 {
        format!("{:.2}µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else {
        format!("{:.2}s", ns as f64 / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let cfg = BenchConfig::quick();
        let mut acc = 0u64;
        let m = bench(&cfg, || {
            for i in 0..100u64 {
                acc = acc.wrapping_add(std::hint::black_box(i));
            }
        });
        assert!(m.iters > 0);
        assert!(m.median > Duration::ZERO);
        std::hint::black_box(acc);
    }

    #[test]
    fn throughput_math() {
        let m = Measurement {
            iters: 10,
            median: Duration::from_millis(2),
            mad: Duration::ZERO,
            min: Duration::from_millis(2),
        };
        assert!((m.throughput(1000.0) - 500_000.0).abs() < 1.0);
    }

    #[test]
    fn table_render_and_csv() {
        let mut t = Table::new("Demo", &["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        let md = t.markdown();
        assert!(md.contains("## Demo"));
        assert!(md.contains("| a  | bb |") || md.contains("| a | bb |"));
        assert_eq!(t.csv(), "a,bb\n1,2\n");
    }

    #[test]
    fn table_json_escapes_and_structures() {
        let mut t = Table::new("Fig \"1\" — spe\\edup", &["k", "t"]);
        t.row(vec!["3".into(), "1.2µs".into()]);
        t.row(vec!["5".into(), "2.4µs".into()]);
        let j = t.json();
        assert!(j.starts_with('{') && j.ends_with("}\n"), "{j}");
        assert!(j.contains("\"title\":\"Fig \\\"1\\\" — spe\\\\edup\""), "{j}");
        assert!(j.contains("\"headers\":[\"k\",\"t\"]"), "{j}");
        assert!(j.contains("\"rows\":[[\"3\",\"1.2µs\"],[\"5\",\"2.4µs\"]]"), "{j}");
    }

    #[test]
    #[should_panic]
    fn table_row_arity_checked() {
        let mut t = Table::new("x", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn fmt_duration_units() {
        assert_eq!(fmt_duration(Duration::from_nanos(10)), "10ns");
        assert!(fmt_duration(Duration::from_micros(15)).contains("µs"));
        assert!(fmt_duration(Duration::from_millis(15)).contains("ms"));
        assert!(fmt_duration(Duration::from_secs(2)).ends_with('s'));
    }
}
