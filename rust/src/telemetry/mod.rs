//! Metrics substrate: counters, log-bucketed latency histograms, timers.
//! Used by the coordinator (per-request latency, batch sizes, queue
//! depth) and the bench harness (percentile reporting).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// Monotonic counter, shareable across threads.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub const fn new() -> Self {
        Self(AtomicU64::new(0))
    }

    pub fn inc(&self) {
        self.add(1)
    }

    pub fn add(&self, v: u64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Up/down gauge for live counts (open connections, live thread
/// handles). `dec` saturates at zero instead of wrapping so a racy
/// extra decrement can never report ~2^64 open connections.
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub const fn new() -> Self {
        Self(AtomicU64::new(0))
    }

    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn dec(&self) {
        let _ = self
            .0
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
                Some(v.saturating_sub(1))
            });
    }

    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Log₂-bucketed histogram of nanosecond durations: bucket `i` covers
/// `[2^i, 2^{i+1})` ns. 64 buckets span ns → ~584 years; quantiles are
/// estimated at bucket midpoints (≤ 2× relative error, fine for latency
/// reporting; the bench harness uses exact sample sets instead).
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_ns: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self {
            buckets: (0..64).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
        }
    }

    pub fn record(&self, d: Duration) {
        let ns = d.as_nanos().min(u64::MAX as u128) as u64;
        let idx = (64 - ns.max(1).leading_zeros() as usize).saturating_sub(1);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean in nanoseconds.
    pub fn mean_ns(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return 0.0;
        }
        self.sum_ns.load(Ordering::Relaxed) as f64 / c as f64
    }

    /// Approximate quantile (`q ∈ [0,1]`) in nanoseconds.
    pub fn quantile_ns(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * total as f64).ceil() as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target.max(1) {
                // Midpoint of [2^i, 2^{i+1}).
                return 1.5 * (1u64 << i) as f64;
            }
        }
        1.5 * (1u64 << 63) as f64
    }

    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            count: self.count(),
            mean_us: self.mean_ns() / 1_000.0,
            p50_us: self.quantile_ns(0.50) / 1_000.0,
            p95_us: self.quantile_ns(0.95) / 1_000.0,
            p99_us: self.quantile_ns(0.99) / 1_000.0,
        }
    }
}

/// Point-in-time histogram summary (microseconds).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct HistogramSnapshot {
    pub count: u64,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
}

impl std::fmt::Display for HistogramSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "n={} mean={:.1}µs p50={:.1}µs p95={:.1}µs p99={:.1}µs",
            self.count, self.mean_us, self.p50_us, self.p95_us, self.p99_us
        )
    }
}

/// Scope timer recording into a histogram on drop.
pub struct ScopedTimer<'a> {
    hist: &'a Histogram,
    start: Instant,
}

impl<'a> ScopedTimer<'a> {
    pub fn new(hist: &'a Histogram) -> Self {
        Self {
            hist,
            start: Instant::now(),
        }
    }
}

impl Drop for ScopedTimer<'_> {
    fn drop(&mut self) {
        self.hist.record(self.start.elapsed());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_accumulates() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn histogram_records_and_means() {
        let h = Histogram::new();
        h.record(Duration::from_nanos(1000));
        h.record(Duration::from_nanos(3000));
        assert_eq!(h.count(), 2);
        assert!((h.mean_ns() - 2000.0).abs() < 1.0);
    }

    #[test]
    fn quantiles_monotone() {
        let h = Histogram::new();
        for i in 1..=1000u64 {
            h.record(Duration::from_nanos(i * 100));
        }
        let p50 = h.quantile_ns(0.5);
        let p95 = h.quantile_ns(0.95);
        let p99 = h.quantile_ns(0.99);
        assert!(p50 <= p95 && p95 <= p99);
        // log-bucket estimate within 2× of true (50_000 ns)
        assert!(p50 > 25_000.0 && p50 < 100_000.0, "p50 {p50}");
    }

    #[test]
    fn empty_histogram_zeroes() {
        let h = Histogram::new();
        assert_eq!(h.quantile_ns(0.9), 0.0);
        assert_eq!(h.mean_ns(), 0.0);
    }

    #[test]
    fn scoped_timer_records() {
        let h = Histogram::new();
        {
            let _t = ScopedTimer::new(&h);
            std::hint::black_box(42);
        }
        assert_eq!(h.count(), 1);
    }

    #[test]
    fn snapshot_display() {
        let h = Histogram::new();
        h.record(Duration::from_micros(5));
        let s = h.snapshot();
        assert_eq!(s.count, 1);
        assert!(format!("{s}").contains("n=1"));
    }
}
