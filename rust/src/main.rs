//! swsnn CLI — the L3 leader entrypoint.
//!
//! Subcommands:
//!   serve             run the TCP inference server (native or PJRT engine)
//!   train             drive the AOT train-step artifact from rust
//!   bench-fig1        regenerate Figure 1 (conv speedup vs filter size)
//!   bench-fig2        regenerate Figure 2 (dilated conv speedup)
//!   bench-algos       regenerate TBL-A/TBL-A2 (algorithm family)
//!   bench-pool        regenerate TBL-P (pooling)
//!   bench-scan        regenerate TBL-S (scan substrate)
//!   conv              run one convolution and report timing
//!   minimizers        genomics sliding-minimum demo
//!   artifacts         list AOT artifacts + manifest
//!   selftest          quick cross-backend consistency check

use std::sync::atomic::AtomicBool;
use std::sync::Arc;

use swsnn::bench::{figs, BenchConfig};
use swsnn::cli::{parse_args, Args, FlagSpec};
use swsnn::config::{load_config, ServeConfig};
use swsnn::conv::{conv1d, BackendChoice, Conv1dParams, ConvBackend};
use swsnn::coordinator::{
    serve_tcp_with, Coordinator, NativeEngine, PjrtTcnEngine, TransportConfig,
};
use swsnn::nn::{Model, Plan, PlannerConfig};
use swsnn::pool::{minimizer_positions, sliding_minimum};
use swsnn::runtime::{ArtifactRegistry, TensorView};
use swsnn::workload::{dna_sequence, kmer_hashes, Rng};

fn main() {
    let args = parse_args(
        std::env::args().skip(1),
        &["quick", "pjrt", "help", "json", "autotune"],
    );
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn bench_cfg(args: &Args) -> BenchConfig {
    if args.has("quick") {
        BenchConfig::quick()
    } else {
        BenchConfig::from_env()
    }
}

fn run(args: &Args) -> anyhow::Result<()> {
    // Global kernel parallelism: must be pinned before the first kernel
    // touches the shared worker pool.
    if let Some(t) = args.get("threads") {
        let t: usize = t
            .parse()
            .map_err(|_| anyhow::anyhow!("--threads expects a positive integer, got {t:?}"))?;
        anyhow::ensure!(t >= 1, "--threads must be >= 1");
        swsnn::exec::set_global_threads(t);
    }
    match args.command.as_deref() {
        Some("serve") => cmd_serve(args),
        Some("train") => cmd_train(args),
        Some("bench-fig1") => {
            let n = args.get_usize("n", 1_000_000).map_err(anyhow::Error::msg)?;
            let (table, _) = figs::fig1(&bench_cfg(args), n, &[2, 3, 5, 7, 15, 31, 63, 127, 255]);
            table.emit("fig1.csv");
            let (scaling, _) = figs::fig1_scaling(&bench_cfg(args), n, 63, &[1, 2, 4, 8]);
            scaling.emit("fig1_scaling.csv");
            Ok(())
        }
        Some("bench-fig2") => {
            let (table, _) = figs::fig2(&bench_cfg(args));
            table.emit("fig2.csv");
            Ok(())
        }
        Some("bench-algos") => {
            let n = args.get_usize("n", 1_000_000).map_err(anyhow::Error::msg)?;
            let p = args.get_usize("p", 16).map_err(anyhow::Error::msg)?;
            figs::tbl_algorithms(&bench_cfg(args), n, p, &[2, 4, 8, 12, 15]).emit("tbl_algorithms.csv");
            figs::tbl_sliding_min(&bench_cfg(args), n, p, &[4, 8, 15]).emit("tbl_sliding_min.csv");
            Ok(())
        }
        Some("bench-pool") => {
            let n = args.get_usize("n", 1_000_000).map_err(anyhow::Error::msg)?;
            figs::tbl_pooling(&bench_cfg(args), n, &[2, 4, 8, 16, 32, 64]).emit("tbl_pooling.csv");
            Ok(())
        }
        Some("bench-scan") => {
            figs::tbl_scan(&bench_cfg(args), &[1_000, 100_000, 1_000_000]).emit("tbl_scan.csv");
            Ok(())
        }
        Some("conv") => cmd_conv(args),
        Some("minimizers") => cmd_minimizers(args),
        Some("artifacts") => cmd_artifacts(args),
        Some("selftest") => cmd_selftest(),
        Some("help") | None => {
            print_help();
            Ok(())
        }
        Some(other) => {
            print_help();
            anyhow::bail!("unknown subcommand {other:?}")
        }
    }
}

fn print_help() {
    println!(
        "swsnn — Sliding Window Sum algorithms for DNNs (Snytsar 2023 reproduction)\n\n\
         usage: swsnn <subcommand> [--flags]\n\n\
         subcommands:\n\
           serve         TCP inference server (--config cfg.toml | --pjrt)\n\
           train         run the AOT SGD train step from rust (--steps N)\n\
           bench-fig1    Figure 1: conv speedup vs filter size\n\
           bench-fig2    Figure 2: dilated conv speedup\n\
           bench-algos   TBL-A: the \u{00a7}3 algorithm family\n\
           bench-pool    TBL-P: pooling via sliding sums\n\
           bench-scan    TBL-S: prefix-sum substrate\n\
           conv          one-off convolution timing\n\
           minimizers    genomics sliding-minimum demo\n\
           artifacts     list AOT artifacts\n\
           selftest      cross-backend consistency check\n\n\
         common flags: --threads N (kernel worker-pool width), --quick (short bench),\n\
                       --json (also write bench_results/BENCH_<table>.json), --help\n\
         serve flags:  --autotune (measure kernel choices per layer),\n\
                       --buckets 1,8,32 (batch buckets precompiled at startup),\n\
                       --max-connections N, --idle-timeout MS, --quota-rps N, --quota-burst N\n\
         env: SWSNN_THREADS, SWSNN_SIMD=off|generic|sse2|avx2|avx512|neon, SWSNN_BENCH_QUICK, SWSNN_BENCH_JSON"
    );
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let specs = [
        FlagSpec { name: "config", value: Some("path"), help: "model TOML (native engine)" },
        FlagSpec { name: "artifacts", value: Some("dir"), help: "artifacts dir (default artifacts/)" },
        FlagSpec { name: "addr", value: Some("host:port"), help: "listen address (default 127.0.0.1:7878)" },
        FlagSpec { name: "backend", value: Some("name"), help: "native backend: auto (per-layer planner) or a fixed kernel" },
        FlagSpec { name: "threads", value: Some("n"), help: "kernel worker-pool threads (default: all cores)" },
        FlagSpec { name: "workers", value: Some("n"), help: "engine workers (default: serve.workers)" },
        FlagSpec { name: "autotune", value: None, help: "micro-probe kernel choices per layer instead of the heuristic" },
        FlagSpec { name: "buckets", value: Some("1,8,…"), help: "batch buckets precompiled at startup" },
        FlagSpec { name: "request-ttl", value: Some("ms"), help: "default request TTL: shed requests not started within this budget (0 = never)" },
        FlagSpec { name: "max-queue", value: Some("n"), help: "admission queue capacity (default: serve.queue_capacity)" },
        FlagSpec { name: "restart-budget", value: Some("n"), help: "worker restarts after an engine panic before degrading the pool" },
        FlagSpec { name: "max-connections", value: Some("n"), help: "concurrent TCP connection cap; refused connections get wire code 8" },
        FlagSpec { name: "idle-timeout", value: Some("ms"), help: "per-connection idle/stall read timeout (0 = never)" },
        FlagSpec { name: "quota-rps", value: Some("n"), help: "per-tenant admission quota in requests/second (0 = unlimited)" },
        FlagSpec { name: "quota-burst", value: Some("n"), help: "per-tenant token-bucket burst depth" },
        FlagSpec { name: "pjrt", value: None, help: "serve the AOT TCN via PJRT" },
        FlagSpec { name: "quick", value: None, help: "" },
    ];
    args.reject_unknown(&specs).map_err(anyhow::Error::msg)?;
    let addr = args.get_str("addr", "127.0.0.1:7878");

    let mut serve_cfg;
    let coord = if args.has("pjrt") {
        let d = ServeConfig::default();
        serve_cfg = ServeConfig {
            workers: args.get_usize("workers", d.workers).map_err(anyhow::Error::msg)?,
            request_ttl_ms: args
                .get_u64("request-ttl", d.request_ttl_ms)
                .map_err(anyhow::Error::msg)?,
            queue_capacity: args
                .get_usize("max-queue", d.queue_capacity)
                .map_err(anyhow::Error::msg)?,
            restart_budget: args
                .get_usize("restart-budget", d.restart_budget)
                .map_err(anyhow::Error::msg)?,
            ..d
        };
        // PJRT engines share one runtime and are constructed on a single
        // worker thread; reject a silently-ignored --workers > 1.
        anyhow::ensure!(
            serve_cfg.workers <= 1,
            "--pjrt serving is single-worker for now (one PJRT engine per process); drop --workers"
        );
        let dir = args.get_str("artifacts", "artifacts");
        Coordinator::start(
            Box::new(move || Ok(Box::new(PjrtTcnEngine::from_artifacts(dir, 42)?) as _)),
            &serve_cfg,
        )?
    } else {
        let path = args.get_str("config", "configs/tcn_demo.toml");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
        let (mc, mut sc) = load_config(&text).map_err(anyhow::Error::msg)?;
        sc.workers = args.get_usize("workers", sc.workers).map_err(anyhow::Error::msg)?;
        sc.request_ttl_ms = args
            .get_u64("request-ttl", sc.request_ttl_ms)
            .map_err(anyhow::Error::msg)?;
        sc.queue_capacity = args
            .get_usize("max-queue", sc.queue_capacity)
            .map_err(anyhow::Error::msg)?;
        sc.restart_budget = args
            .get_usize("restart-budget", sc.restart_budget)
            .map_err(anyhow::Error::msg)?;
        if args.has("autotune") {
            sc.autotune = true;
        }
        if let Some(list) = args.get("buckets") {
            let mut buckets = Vec::new();
            for part in list.split(',') {
                let b: usize = part.trim().parse().map_err(|_| {
                    anyhow::anyhow!("--buckets expects comma-separated batch sizes, got {part:?}")
                })?;
                anyhow::ensure!(b >= 1, "--buckets entries must be >= 1");
                buckets.push(b);
            }
            sc.batch_buckets = buckets;
        }
        // --threads (handled globally) wins; otherwise serve.threads > 0
        // pins the kernel pool width before the first forward pass.
        if args.get("threads").is_none() && sc.threads > 0 {
            swsnn::exec::set_global_threads(sc.threads);
        }
        let backend = BackendChoice::parse(&args.get_str("backend", sc.backend.name()))
            .ok_or_else(|| {
                anyhow::anyhow!("unknown backend (try auto/sliding/im2col_gemm/direct/sliding_pair)")
            })?;
        // Write the CLI-resolved backend back: `bucketed_execution` (the
        // pad/warm-up gate) must see the backend actually served, not
        // whatever the TOML said before `--backend` overrode it.
        sc.backend = backend;
        serve_cfg = sc;
        let mut rng = Rng::new(42);
        let model = Model::init(&mc, &mut rng)?;
        println!(
            "model {} — {} layers, {} params, backend {}{}",
            mc.name,
            model.layer_count(),
            model.param_count(),
            backend.name(),
            if serve_cfg.autotune { " (autotuned)" } else { "" }
        );
        // Persist probe results across restarts: load whatever a
        // previous serve recorded for this CPU/tier/thread-count, and
        // write every new decision through. `SWSNN_TUNE_CACHE` points
        // at the file (or disables with `off`); the default is
        // bench_results/tunecache.json.
        if serve_cfg.autotune {
            let loaded = swsnn::nn::TuneCache::global().enable_persistence(None);
            if loaded > 0 {
                println!("tune cache: {loaded} persisted decision(s) loaded");
            }
        }
        // Audit surface for the planner: print the per-layer kernel
        // choices the serving plans will execute with (probing now also
        // seeds the tune cache for the batch-1 bucket; other buckets
        // probe during engine warm-up — the tune key includes batch).
        let plan = Plan::compile(
            &model,
            1,
            &PlannerConfig {
                backend,
                autotune: serve_cfg.autotune,
                ..PlannerConfig::default()
            },
        )?;
        println!("plan (batch 1): {}", plan.describe());
        for t in plan.tuning() {
            if t.cached {
                println!("  layer {}: {} (tune cache)", t.layer, t.chosen.name());
            } else {
                let probes: Vec<String> = t
                    .probes
                    .iter()
                    .map(|p| format!("{}:{:.1}µs", p.kernel.name(), p.micros))
                    .collect();
                println!(
                    "  layer {}: {} [{}]",
                    t.layer,
                    t.chosen.name(),
                    probes.join(" ")
                );
            }
        }
        for s in plan.segment_tuning() {
            if s.cached {
                println!(
                    "  segment {}..={}: fused={} (tune cache)",
                    s.layers.0, s.layers.1, s.fused
                );
            } else {
                println!(
                    "  segment {}..={}: fused={} [fused:{:.1}µs unfused:{:.1}µs]",
                    s.layers.0, s.layers.1, s.fused, s.fused_micros, s.unfused_micros
                );
            }
        }
        println!(
            "precompiling batch sizes {:?} on {} worker(s){}",
            serve_cfg.warmup_buckets(),
            serve_cfg.workers.max(1),
            if serve_cfg.bucketed_execution() {
                " — batches pad to the next bucket"
            } else {
                " — other sizes compile lazily on first use"
            }
        );
        Coordinator::start_replicated(
            NativeEngine::with_choice(model, backend, serve_cfg.max_batch)
                .autotuned(serve_cfg.autotune),
            &serve_cfg,
        )?
    };
    println!(
        "engine {} ready (in={} out={}, {} engine workers, {} kernel threads), serving on {addr} — Ctrl-C to stop",
        coord.engine_name(),
        coord.input_len(),
        coord.output_len(),
        coord.worker_count(),
        swsnn::exec::Executor::global().threads()
    );
    // Transport-layer flags apply to both engine paths.
    serve_cfg.max_connections = args
        .get_usize("max-connections", serve_cfg.max_connections)
        .map_err(anyhow::Error::msg)?;
    serve_cfg.idle_timeout_ms = args
        .get_u64("idle-timeout", serve_cfg.idle_timeout_ms)
        .map_err(anyhow::Error::msg)?;
    serve_cfg.quota_rps = args
        .get_u64("quota-rps", serve_cfg.quota_rps)
        .map_err(anyhow::Error::msg)?;
    serve_cfg.quota_burst = args
        .get_u64("quota-burst", serve_cfg.quota_burst)
        .map_err(anyhow::Error::msg)?;
    let stop = Arc::new(AtomicBool::new(false));
    serve_tcp_with(
        Arc::new(coord),
        &addr,
        TransportConfig::from_serve(&serve_cfg),
        stop,
        |bound| {
            println!("listening on {bound}");
        },
    )
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let dir = args.get_str("artifacts", "artifacts");
    let steps = args.get_usize("steps", 50).map_err(anyhow::Error::msg)?;
    let reg = ArtifactRegistry::open(dir)?;
    let m = reg
        .manifest()
        .ok_or_else(|| anyhow::anyhow!("manifest.toml missing"))?
        .clone();
    let exe = reg.get(&format!("tcn_train_step_b8_n{}", m.seq_len))?;
    let mut rng = Rng::new(7);
    let mut params: Vec<TensorView> = m
        .param_shapes()
        .iter()
        .map(|(name, s)| {
            let n: usize = s.iter().product();
            if name.contains("_b") {
                TensorView::new(s.clone(), vec![0.0; n])
            } else {
                let fan_in: usize = s[1..].iter().product();
                TensorView::new(s.clone(), rng.vec_normal(n, (2.0 / fan_in as f32).sqrt()))
            }
        })
        .collect();
    println!("training TCN ({} params) for {steps} steps on synthetic AR(1) data", m.params);
    let start = std::time::Instant::now();
    for step in 0..steps {
        let mut x = vec![0.0f32; 8 * m.seq_len];
        let mut prev = 0.0f32;
        for v in x.iter_mut() {
            prev = 0.9 * prev + 0.2 * rng.normal();
            *v = prev;
        }
        let mut a = params.clone();
        a.push(TensorView::new(vec![8, m.c_in, m.seq_len], x));
        let mut out = exe.run(&a)?;
        let loss = out.remove(0).data[0];
        params = out;
        if step % 10 == 0 || step == steps - 1 {
            println!("step {step:>4}  loss {loss:.6}");
        }
    }
    println!(
        "done in {:.2}s ({:.1} steps/s)",
        start.elapsed().as_secs_f64(),
        steps as f64 / start.elapsed().as_secs_f64()
    );
    Ok(())
}

fn cmd_conv(args: &Args) -> anyhow::Result<()> {
    let n = args.get_usize("n", 1_000_000).map_err(anyhow::Error::msg)?;
    let k = args.get_usize("k", 31).map_err(anyhow::Error::msg)?;
    let dilation = args.get_usize("dilation", 1).map_err(anyhow::Error::msg)?;
    let backend = ConvBackend::parse(&args.get_str("backend", "sliding"))
        .ok_or_else(|| anyhow::anyhow!("unknown backend (try sliding/im2col_gemm/direct/sliding_pair)"))?;
    let mut rng = Rng::new(1);
    let x = rng.vec_uniform(n, -1.0, 1.0);
    let w = rng.vec_uniform(k, -1.0, 1.0);
    let p = Conv1dParams::new(1, 1, n, k).with_dilation(dilation);
    let cfg = bench_cfg(args);
    let m = swsnn::bench::bench(&cfg, || {
        std::hint::black_box(conv1d(backend, std::hint::black_box(&x), &w, None, &p));
    });
    println!(
        "conv1d n={n} k={k} d={dilation} backend={}: median {} ({:.2} Gmac/s)",
        backend.name(),
        swsnn::bench::fmt_duration(m.median),
        p.macs() as f64 / m.median_ns()
    );
    Ok(())
}

fn cmd_minimizers(args: &Args) -> anyhow::Result<()> {
    let n = args.get_usize("n", 1_000_000).map_err(anyhow::Error::msg)?;
    let kmer = args.get_usize("kmer", 15).map_err(anyhow::Error::msg)?;
    let w = args.get_usize("w", 10).map_err(anyhow::Error::msg)?;
    let mut rng = Rng::new(13);
    let seq = dna_sequence(&mut rng, n);
    let hashes = kmer_hashes(&seq, kmer);
    let start = std::time::Instant::now();
    let mins = sliding_minimum(&hashes, w);
    let dt = start.elapsed();
    let pos = minimizer_positions(&hashes, w);
    let distinct: std::collections::HashSet<usize> = pos.iter().copied().collect();
    println!(
        "sequence {n}bp, k-mer {kmer}, window {w}: {} windows in {} ({:.1} Mwin/s), {} distinct minimizers ({:.2}% density)",
        mins.len(),
        swsnn::bench::fmt_duration(dt),
        mins.len() as f64 / dt.as_secs_f64() / 1e6,
        distinct.len(),
        100.0 * distinct.len() as f64 / hashes.len() as f64
    );
    Ok(())
}

fn cmd_artifacts(args: &Args) -> anyhow::Result<()> {
    let dir = args.get_str("artifacts", "artifacts");
    let reg = ArtifactRegistry::open(dir)?;
    println!("platform: {} ({} devices)", reg.runtime().platform(), reg.runtime().device_count());
    if let Some(m) = reg.manifest() {
        println!(
            "tcn manifest: {} params, seq_len {}, receptive field {}",
            m.params, m.seq_len, m.receptive_field
        );
    }
    for name in reg.list()? {
        println!("  {name}");
    }
    Ok(())
}

fn cmd_selftest() -> anyhow::Result<()> {
    use swsnn::ops::AddOp;
    use swsnn::sliding::{self, Algo};
    let mut rng = Rng::new(99);
    let xs = rng.vec_uniform(10_000, -1.0, 1.0);
    let want = sliding::sliding_naive(AddOp::<f32>::new(), &xs, 7);
    for algo in Algo::ALL {
        let got = sliding::run(algo, AddOp::<f32>::new(), &xs, 7, 16);
        anyhow::ensure!(got.len() == want.len(), "{algo:?} length");
        for (a, b) in got.iter().zip(&want) {
            anyhow::ensure!((a - b).abs() < 1e-3, "{algo:?} mismatch");
        }
        println!("  {:<18} ok", algo.name());
    }
    let x = rng.vec_uniform(4096, -1.0, 1.0);
    let w = rng.vec_uniform(9, -1.0, 1.0);
    let p = Conv1dParams::new(1, 1, 4096, 9);
    let want = conv1d(ConvBackend::Direct, &x, &w, None, &p);
    for backend in ConvBackend::ALL {
        let got = conv1d(backend, &x, &w, None, &p);
        for (a, b) in got.iter().zip(&want) {
            anyhow::ensure!((a - b).abs() < 1e-2, "{backend:?} mismatch: {a} vs {b}");
        }
        println!("  conv/{:<12} ok", backend.name());
    }
    println!("selftest passed");
    Ok(())
}
