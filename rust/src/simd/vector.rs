//! A `P`-lane vector register over an arbitrary element type.
//!
//! All mutating primitives correspond 1:1 to the vector-ISA operations the
//! paper's Algorithms 1–4 are expressed in:
//!
//! | paper                | here                         | hardware           |
//! |----------------------|------------------------------|--------------------|
//! | `X ← (x,x,…,x,0,…)`  | [`VecReg::broadcast_prefix`] | `vbroadcast`+mask  |
//! | `Y ← Y ⊕ X`          | [`VecReg::combine_assign`]   | lane-wise op       |
//! | `Y ≪ k`              | [`VecReg::shift_left`]       | `valign`/`EXT`     |
//! | `Slide(Y1,Y2,off)`   | [`VecReg::slide`]            | SVE `EXT`, `vslide`|
//! | load / store         | [`VecReg::load`]/[`store`]   | `vle`/`vse`        |
//!
//! [`store`]: VecReg::store

use crate::ops::AssocOp;
use crate::simd::MAX_LANES;

/// Fixed-capacity vector register with logical width `p ≤ MAX_LANES`.
///
/// Lanes `p..MAX_LANES` always hold the operator identity so that a wider
/// physical register can carry a narrower logical computation — the same
/// trick masked ISAs (SVE predicates, AVX-512 `k` registers) use.
#[derive(Clone, Debug)]
pub struct VecReg<T: Copy> {
    lanes: [T; MAX_LANES],
    p: usize,
}

impl<T: Copy + PartialEq + std::fmt::Debug> VecReg<T> {
    /// A register of logical width `p` filled with `fill` (normally the
    /// operator identity).
    pub fn splat(p: usize, fill: T) -> Self {
        assert!(p >= 1 && p <= MAX_LANES, "width {p} out of range");
        Self {
            lanes: [fill; MAX_LANES],
            p,
        }
    }

    /// Logical width `P`.
    #[inline(always)]
    pub fn width(&self) -> usize {
        self.p
    }

    /// Load `min(p, src.len())` contiguous elements; remaining lanes get
    /// `pad` (vector load with tail predication).
    pub fn load(p: usize, src: &[T], pad: T) -> Self {
        let mut r = Self::splat(p, pad);
        let n = src.len().min(p);
        r.lanes[..n].copy_from_slice(&src[..n]);
        r
    }

    /// Store the first `min(p, dst.len())` lanes into `dst`.
    pub fn store(&self, dst: &mut [T]) {
        let n = dst.len().min(self.p);
        dst[..n].copy_from_slice(&self.lanes[..n]);
    }

    /// Lane accessor (`Y[i]`).
    #[inline(always)]
    pub fn get(&self, i: usize) -> T {
        debug_assert!(i < self.p);
        self.lanes[i]
    }

    /// Lane mutator.
    #[inline(always)]
    pub fn set(&mut self, i: usize, v: T) {
        debug_assert!(i < self.p);
        self.lanes[i] = v;
    }

    /// First `k` lanes as a slice.
    pub fn prefix(&self, k: usize) -> &[T] {
        debug_assert!(k <= self.p);
        &self.lanes[..k]
    }

    /// Paper Alg 1: `X ← (x, x, …, x, id, …, id)` — broadcast `x` to the
    /// first `k` lanes, identity elsewhere.
    pub fn broadcast_prefix(p: usize, x: T, k: usize, id: T) -> Self {
        let mut r = Self::splat(p, id);
        let k = k.min(p);
        for lane in &mut r.lanes[..k] {
            *lane = x;
        }
        r
    }

    /// `Y ← Y ⊕ X`, lane-wise, through the operator's slice kernel
    /// ([`AssocOp::combine_assign_slices`]) — runtime-dispatched
    /// AVX2/SSE2/NEON for f32 add/max/min, a plain fold otherwise.
    #[inline]
    pub fn combine_assign<O: AssocOp<Elem = T>>(&mut self, op: O, rhs: &Self) {
        debug_assert_eq!(self.p, rhs.p);
        let p = self.p;
        op.combine_assign_slices(&mut self.lanes[..p], &rhs.lanes[..p]);
    }

    /// `Y ← Y ≪ k`: shift lanes left by `k`, filling vacated tail lanes
    /// with `fill` (the operator identity in the paper's algorithms).
    pub fn shift_left(&mut self, k: usize, fill: T) {
        let p = self.p;
        let k = k.min(p);
        self.lanes.copy_within(k..p, 0);
        for lane in &mut self.lanes[p - k..p] {
            *lane = fill;
        }
    }

    /// `Slide(a, b, offset)` (paper Alg 4): lanes `offset..offset+P` of
    /// the concatenation `a ∥ b`. Maps to SVE `EXT` / RISC-V `vslide` /
    /// AVX-512 `vperm*2ps`.
    pub fn slide(a: &Self, b: &Self, offset: usize) -> Self {
        debug_assert_eq!(a.p, b.p);
        let p = a.p;
        debug_assert!(offset <= p, "slide offset {offset} > width {p}");
        let mut r = Self::splat(p, a.lanes[0]);
        let head = p - offset;
        r.lanes[..head].copy_from_slice(&a.lanes[offset..p]);
        r.lanes[head..p].copy_from_slice(&b.lanes[..offset]);
        r
    }

    /// In-register *inclusive prefix scan* of the first `k` lanes:
    /// lane i ← x₀ ⊕ … ⊕ xᵢ. Log-depth shift-and-combine (Hillis–Steele),
    /// the paper's "[3]" in-register scan. `O(log k)` vector ops.
    pub fn prefix_scan_inclusive<O: AssocOp<Elem = T>>(&mut self, op: O, k: usize) {
        let k = k.min(self.p);
        let id = op.identity();
        let mut d = 1;
        while d < k {
            // lane i gets lanes[i-d] ⊕ lanes[i] for i >= d.
            let snapshot = self.lanes;
            for i in d..k {
                self.lanes[i] = op.combine(snapshot[i - d], snapshot[i]);
            }
            let _ = id;
            d <<= 1;
        }
    }

    /// In-register *suffix scan* of lanes `lo..hi`: lane i ← xᵢ ⊕ … ⊕ x_{hi-1}.
    pub fn suffix_scan_inclusive<O: AssocOp<Elem = T>>(&mut self, op: O, lo: usize, hi: usize) {
        let hi = hi.min(self.p);
        if lo >= hi {
            return;
        }
        let mut d = 1;
        while d < hi - lo {
            let snapshot = self.lanes;
            for i in lo..hi - d {
                self.lanes[i] = op.combine(snapshot[i], snapshot[i + d]);
            }
            d <<= 1;
        }
    }

    /// Tree-reduce the first `k` lanes to a single value. `O(log k)` steps.
    pub fn reduce<O: AssocOp<Elem = T>>(&self, op: O, k: usize) -> T {
        let k = k.min(self.p);
        if k == 0 {
            return op.identity();
        }
        let mut buf = self.lanes;
        let mut n = k;
        while n > 1 {
            let half = n / 2;
            for i in 0..half {
                buf[i] = op.combine(buf[2 * i], buf[2 * i + 1]);
            }
            if n % 2 == 1 {
                buf[half] = buf[n - 1];
                n = half + 1;
            } else {
                n = half;
            }
        }
        buf[0]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::{AddOp, MaxOp};

    #[test]
    fn splat_and_width() {
        let v = VecReg::splat(8, 1.5f32);
        assert_eq!(v.width(), 8);
        for i in 0..8 {
            assert_eq!(v.get(i), 1.5);
        }
    }

    #[test]
    #[should_panic]
    fn zero_width_panics() {
        let _ = VecReg::splat(0, 0f32);
    }

    #[test]
    fn load_store_roundtrip_with_tail() {
        let src = [1f32, 2.0, 3.0];
        let v = VecReg::load(8, &src, 0.0);
        assert_eq!(v.get(0), 1.0);
        assert_eq!(v.get(2), 3.0);
        assert_eq!(v.get(3), 0.0); // tail pad
        let mut dst = [9f32; 5];
        v.store(&mut dst);
        assert_eq!(dst, [1.0, 2.0, 3.0, 0.0, 0.0]);
    }

    #[test]
    fn broadcast_prefix_masks_tail() {
        let v = VecReg::broadcast_prefix(8, 7f32, 3, 0.0);
        assert_eq!(v.prefix(8), &[7.0, 7.0, 7.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn combine_assign_lanewise() {
        let mut a = VecReg::load(8, &[1f32, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0], 0.0);
        let b = VecReg::load(8, &[10f32; 8], 0.0);
        a.combine_assign(AddOp::<f32>::new(), &b);
        assert_eq!(a.prefix(4), &[11.0, 12.0, 13.0, 14.0]);
    }

    #[test]
    fn shift_left_fills_identity() {
        let mut v = VecReg::load(8, &[1f32, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0], 0.0);
        v.shift_left(3, 0.0);
        assert_eq!(v.prefix(8), &[4.0, 5.0, 6.0, 7.0, 8.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn shift_by_zero_is_noop() {
        let mut v = VecReg::load(8, &[1f32, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0], 0.0);
        v.shift_left(0, 0.0);
        assert_eq!(v.get(0), 1.0);
        assert_eq!(v.get(7), 8.0);
    }

    #[test]
    fn slide_concatenates() {
        let a = VecReg::load(8, &[0f32, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0], 0.0);
        let b = VecReg::load(8, &[8f32, 9.0, 10.0, 11.0, 12.0, 13.0, 14.0, 15.0], 0.0);
        let s = VecReg::slide(&a, &b, 3);
        assert_eq!(s.prefix(8), &[3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0, 10.0]);
        // offset 0 == a, offset P == b
        assert_eq!(VecReg::slide(&a, &b, 0).prefix(8), a.prefix(8));
        assert_eq!(VecReg::slide(&a, &b, 8).prefix(8), b.prefix(8));
    }

    #[test]
    fn prefix_scan_matches_sequential() {
        let data: Vec<f32> = (1..=16).map(|x| x as f32).collect();
        let mut v = VecReg::load(16, &data, 0.0);
        v.prefix_scan_inclusive(AddOp::<f32>::new(), 16);
        let mut acc = 0.0;
        for i in 0..16 {
            acc += data[i];
            assert!((v.get(i) - acc).abs() < 1e-4, "lane {i}");
        }
    }

    #[test]
    fn prefix_scan_partial_k_leaves_tail() {
        let data: Vec<f32> = (1..=8).map(|x| x as f32).collect();
        let mut v = VecReg::load(8, &data, 0.0);
        v.prefix_scan_inclusive(AddOp::<f32>::new(), 4);
        assert_eq!(v.get(3), 10.0);
        assert_eq!(v.get(4), 5.0); // untouched
    }

    #[test]
    fn suffix_scan_matches_sequential() {
        let data: Vec<f32> = (1..=8).map(|x| x as f32).collect();
        let mut v = VecReg::load(8, &data, 0.0);
        v.suffix_scan_inclusive(AddOp::<f32>::new(), 2, 8);
        // lane i = sum of data[i..8] for i in 2..8
        for i in 2..8 {
            let expect: f32 = data[i..8].iter().sum();
            assert!((v.get(i) - expect).abs() < 1e-4, "lane {i}");
        }
        assert_eq!(v.get(0), 1.0); // untouched below lo
    }

    #[test]
    fn reduce_max() {
        let v = VecReg::load(8, &[3f32, 9.0, -2.0, 7.0, 9.5, 0.0, 1.0, 2.0], f32::NEG_INFINITY);
        assert_eq!(v.reduce(MaxOp::<f32>::new(), 8), 9.5);
        assert_eq!(v.reduce(MaxOp::<f32>::new(), 3), 9.0);
        assert_eq!(v.reduce(MaxOp::<f32>::new(), 0), f32::NEG_INFINITY);
    }
}
