//! Runtime SIMD dispatch for the f32 hot loops.
//!
//! The generic kernels in this crate are written so LLVM *can*
//! auto-vectorize them, but the guarantee is only as strong as the
//! optimizer's alias analysis on any given day. This module pins the
//! inner loops down with explicit `std::arch` intrinsics, selected once
//! at startup by runtime feature detection:
//!
//! | tier | ISA | used by |
//! |------|-----|---------|
//! | [`SimdTier::Avx512`] | x86_64 AVX-512F | add/max/min combine, fused conv taps, i8 dot |
//! | [`SimdTier::Avx2`] | x86_64 AVX2 + FMA | add/max/min combine, fused conv taps, i8 dot |
//! | [`SimdTier::Sse2`] | x86_64 baseline SSE2 | add/max/min combine (no fused ops) |
//! | [`SimdTier::Neon`] | aarch64 NEON | add/max/min combine, fused conv taps, i8 dot |
//! | [`SimdTier::Generic`] | portable scalar | everything (fallback + parity oracle) |
//!
//! Every specialized kernel is **bit-identical** to its generic
//! counterpart for non-NaN inputs (asserted by `tests/simd_parity.rs`):
//! the add/max/min lane ops have identical rounding on every ISA, and
//! the conv kernels only run where a *fused* multiply-add exists
//! (AVX-512F, AVX2+FMA, NEON), matching the scalar `f32::mul_add`
//! chain. SSE2 has no fused multiply-add, so the conv taps stay generic
//! under that tier rather than silently changing rounding.
//!
//! Set `SWSNN_SIMD=off` (or `generic`) to force the portable fallback
//! for debugging; `avx512` / `avx2` / `sse2` / `neon` pin a specific
//! tier when the host supports it. [`force_tier`] overrides the choice
//! at runtime (used by the parity tests).

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// SIMD implementation tier, ordered best-first per architecture.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdTier {
    /// x86_64 AVX-512F: 16 f32 lanes with fused multiply-add.
    Avx512,
    /// x86_64 AVX2 + FMA: 8 f32 lanes with fused multiply-add.
    Avx2,
    /// x86_64 baseline SSE2: 4 f32 lanes, no fused ops (conv taps fall
    /// back to the generic path under this tier).
    Sse2,
    /// aarch64 NEON: 4 f32 lanes with fused multiply-add.
    Neon,
    /// Portable scalar/auto-vectorized code — the parity oracle.
    Generic,
}

impl SimdTier {
    pub fn name(&self) -> &'static str {
        match self {
            SimdTier::Avx512 => "avx512",
            SimdTier::Avx2 => "avx2",
            SimdTier::Sse2 => "sse2",
            SimdTier::Neon => "neon",
            SimdTier::Generic => "generic",
        }
    }

    /// Parse an `SWSNN_SIMD` value. `off` is an alias for `generic`.
    pub fn parse(s: &str) -> Option<SimdTier> {
        match s {
            "avx512" => Some(SimdTier::Avx512),
            "avx2" => Some(SimdTier::Avx2),
            "sse2" => Some(SimdTier::Sse2),
            "neon" => Some(SimdTier::Neon),
            "generic" | "off" => Some(SimdTier::Generic),
            _ => None,
        }
    }

    /// Whether the current host can execute this tier.
    pub fn is_supported(&self) -> bool {
        match self {
            SimdTier::Avx512 => avx512f_available(),
            SimdTier::Avx2 => avx2_fma_available(),
            SimdTier::Sse2 => cfg!(target_arch = "x86_64"),
            SimdTier::Neon => cfg!(target_arch = "aarch64"),
            SimdTier::Generic => true,
        }
    }

    /// Whether the tier provides a *fused* vector multiply-add. Only
    /// fused tiers may take the SIMD conv-tap path: an unfused mul+add
    /// would change rounding vs the scalar `f32::mul_add` chain.
    pub fn has_fused_fma(&self) -> bool {
        matches!(self, SimdTier::Avx512 | SimdTier::Avx2 | SimdTier::Neon)
    }
}

#[cfg(target_arch = "x86_64")]
fn avx2_fma_available() -> bool {
    std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma")
}

#[cfg(not(target_arch = "x86_64"))]
fn avx2_fma_available() -> bool {
    false
}

// Every intrinsic the Avx512 tier uses (f32 loads/stores/arith/fmadd,
// i8→i32 widen + mullo/add) is in the AVX-512 *Foundation* subset, so
// one feature bit is the whole support check.
#[cfg(target_arch = "x86_64")]
fn avx512f_available() -> bool {
    std::arch::is_x86_feature_detected!("avx512f")
}

#[cfg(not(target_arch = "x86_64"))]
fn avx512f_available() -> bool {
    false
}

/// Forced-tier encoding for the atomic override: 0 = auto-detect.
const FORCE_AUTO: u8 = 0;

static FORCED: AtomicU8 = AtomicU8::new(FORCE_AUTO);

fn encode(t: SimdTier) -> u8 {
    match t {
        SimdTier::Avx2 => 1,
        SimdTier::Sse2 => 2,
        SimdTier::Neon => 3,
        SimdTier::Generic => 4,
        // Appended (not renumbered) so any stale encoded value stays valid.
        SimdTier::Avx512 => 5,
    }
}

fn decode(v: u8) -> Option<SimdTier> {
    match v {
        1 => Some(SimdTier::Avx2),
        2 => Some(SimdTier::Sse2),
        3 => Some(SimdTier::Neon),
        4 => Some(SimdTier::Generic),
        5 => Some(SimdTier::Avx512),
        _ => None,
    }
}

/// Override the dispatched tier (`None` restores auto-detection).
/// Forcing an unsupported tier is ignored — executing its kernels would
/// fault. Intended for parity tests and debugging; the production path
/// uses the `SWSNN_SIMD` environment variable instead.
pub fn force_tier(t: Option<SimdTier>) {
    let v = match t {
        Some(t) if t.is_supported() => encode(t),
        _ => FORCE_AUTO,
    };
    FORCED.store(v, Ordering::SeqCst);
}

/// The active SIMD tier: the [`force_tier`] override if set, else the
/// startup detection (honoring `SWSNN_SIMD`), cached after first use.
pub fn tier() -> SimdTier {
    if let Some(t) = decode(FORCED.load(Ordering::Relaxed)) {
        return t;
    }
    detected()
}

fn detected() -> SimdTier {
    static DETECTED: OnceLock<SimdTier> = OnceLock::new();
    *DETECTED.get_or_init(|| {
        if let Ok(v) = std::env::var("SWSNN_SIMD") {
            if let Some(t) = SimdTier::parse(&v) {
                if t.is_supported() {
                    return t;
                }
            }
        }
        best_available()
    })
}

#[cfg(target_arch = "x86_64")]
fn best_available() -> SimdTier {
    if avx512f_available() {
        SimdTier::Avx512
    } else if avx2_fma_available() {
        SimdTier::Avx2
    } else {
        SimdTier::Sse2
    }
}

#[cfg(target_arch = "aarch64")]
fn best_available() -> SimdTier {
    SimdTier::Neon
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn best_available() -> SimdTier {
    SimdTier::Generic
}

// ───────────────────────── element downcasts ──────────────────────────

/// View a generic element slice as `&[f32]` when `T` *is* `f32`
/// (runtime type check; resolved at monomorphization time). Lets the
/// generic operator code route its f32 instantiations to the SIMD
/// kernels without specialization.
pub fn as_f32<T: 'static>(xs: &[T]) -> Option<&[f32]> {
    if std::any::TypeId::of::<T>() == std::any::TypeId::of::<f32>() {
        // SAFETY: T and f32 are the same type, so layout and validity
        // invariants are identical; lifetimes are preserved.
        Some(unsafe { &*(xs as *const [T] as *const [f32]) })
    } else {
        None
    }
}

/// Mutable variant of [`as_f32`].
pub fn as_f32_mut<T: 'static>(xs: &mut [T]) -> Option<&mut [f32]> {
    if std::any::TypeId::of::<T>() == std::any::TypeId::of::<f32>() {
        // SAFETY: see `as_f32`.
        Some(unsafe { &mut *(xs as *mut [T] as *mut [f32]) })
    } else {
        None
    }
}

// ───────────────────────── combine kernels ────────────────────────────
//
// dst[i] ← dst[i] ⊕ src[i] over min(dst.len(), src.len()). The scalar
// semantics match `Scalar::{add, maximum, minimum}` exactly: `maximum`
// is `if a > b { a } else { b }`, which is precisely x86 `maxps`.

/// Lane-wise `dst[i] += src[i]`, runtime-dispatched.
pub fn add_assign_f32(dst: &mut [f32], src: &[f32]) {
    match tier() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: tier() returns Avx512 only after runtime AVX-512F detection.
        SimdTier::Avx512 => unsafe { x86::add_assign_avx512(dst, src) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: tier() returns Avx2 only after runtime AVX2 detection.
        SimdTier::Avx2 => unsafe { x86::add_assign_avx2(dst, src) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: SSE2 is baseline on x86_64.
        SimdTier::Sse2 => unsafe { x86::add_assign_sse2(dst, src) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64.
        SimdTier::Neon => unsafe { neon::add_assign_neon(dst, src) },
        _ => add_assign_f32_generic(dst, src),
    }
}

/// Lane-wise `dst[i] = max(dst[i], src[i])`, runtime-dispatched.
pub fn max_assign_f32(dst: &mut [f32], src: &[f32]) {
    match tier() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: tier() returns Avx512 only after runtime AVX-512F detection.
        SimdTier::Avx512 => unsafe { x86::max_assign_avx512(dst, src) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: tier() returns Avx2 only after runtime AVX2 detection.
        SimdTier::Avx2 => unsafe { x86::max_assign_avx2(dst, src) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: SSE2 is baseline on x86_64.
        SimdTier::Sse2 => unsafe { x86::max_assign_sse2(dst, src) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64.
        SimdTier::Neon => unsafe { neon::max_assign_neon(dst, src) },
        _ => max_assign_f32_generic(dst, src),
    }
}

/// Lane-wise `dst[i] = min(dst[i], src[i])`, runtime-dispatched.
pub fn min_assign_f32(dst: &mut [f32], src: &[f32]) {
    match tier() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: tier() returns Avx512 only after runtime AVX-512F detection.
        SimdTier::Avx512 => unsafe { x86::min_assign_avx512(dst, src) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: tier() returns Avx2 only after runtime AVX2 detection.
        SimdTier::Avx2 => unsafe { x86::min_assign_avx2(dst, src) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: SSE2 is baseline on x86_64.
        SimdTier::Sse2 => unsafe { x86::min_assign_sse2(dst, src) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64.
        SimdTier::Neon => unsafe { neon::min_assign_neon(dst, src) },
        _ => min_assign_f32_generic(dst, src),
    }
}

/// Portable oracle for [`add_assign_f32`].
pub fn add_assign_f32_generic(dst: &mut [f32], src: &[f32]) {
    for (d, s) in dst.iter_mut().zip(src) {
        *d += *s;
    }
}

/// Portable oracle for [`max_assign_f32`] (`maxps` select semantics).
pub fn max_assign_f32_generic(dst: &mut [f32], src: &[f32]) {
    for (d, s) in dst.iter_mut().zip(src) {
        *d = if *d > *s { *d } else { *s };
    }
}

/// Portable oracle for [`min_assign_f32`] (`minps` select semantics).
pub fn min_assign_f32_generic(dst: &mut [f32], src: &[f32]) {
    for (d, s) in dst.iter_mut().zip(src) {
        *d = if *d < *s { *d } else { *s };
    }
}

// ───────────────────────── fused conv-tap kernels ─────────────────────
//
// One slid FMA pass of the sliding convolution's hot loop. Every output
// folds its taps in ascending order with one *fused* multiply-add per
// tap, so any tap grouping composes to the same per-output chain as the
// scalar `f32::mul_add` code — bit-identical across tiers.

/// `yb[t] = wk.mul_add(xs[t], yb[t])` for every output.
/// Requires `xs.len() >= yb.len()`.
pub fn fma_tap1_f32(yb: &mut [f32], xs: &[f32], wk: f32) {
    debug_assert!(xs.len() >= yb.len());
    match tier() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: the Avx512 tier requires AVX-512F at detection time; the
        // caller contract `xs.len() >= yb.len()` keeps loads in bounds.
        SimdTier::Avx512 => unsafe { x86::fma_tap1_avx512(yb, xs, wk) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: the Avx2 tier requires AVX2+FMA at detection time; the
        // caller contract `xs.len() >= yb.len()` keeps loads in bounds.
        SimdTier::Avx2 => unsafe { x86::fma_tap1_avx2(yb, xs, wk) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64; same length contract.
        SimdTier::Neon => unsafe { neon::fma_tap1_neon(yb, xs, wk) },
        _ => fma_tap1_f32_generic(yb, xs, wk),
    }
}

/// Four contiguous taps: `yb[t]` folds `w[j]·xs[t+j]` for `j = 0..4`,
/// fused, ascending. Requires `xs.len() >= yb.len() + 3`.
pub fn fma_tap4_f32(yb: &mut [f32], xs: &[f32], w: [f32; 4]) {
    debug_assert!(xs.len() >= yb.len() + 3);
    match tier() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: the Avx512 tier requires AVX-512F at detection time; the
        // caller contract `xs.len() >= yb.len() + 3` keeps loads in bounds.
        SimdTier::Avx512 => unsafe { x86::fma_tap4_avx512(yb, xs, w) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: the Avx2 tier requires AVX2+FMA at detection time; the
        // caller contract `xs.len() >= yb.len() + 3` keeps loads in bounds.
        SimdTier::Avx2 => unsafe { x86::fma_tap4_avx2(yb, xs, w) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64; same length contract.
        SimdTier::Neon => unsafe { neon::fma_tap4_neon(yb, xs, w) },
        _ => fma_tap4_f32_generic(yb, xs, w),
    }
}

/// Portable oracle for [`fma_tap1_f32`].
pub fn fma_tap1_f32_generic(yb: &mut [f32], xs: &[f32], wk: f32) {
    for (y, &x) in yb.iter_mut().zip(xs) {
        *y = wk.mul_add(x, *y);
    }
}

/// Portable oracle for [`fma_tap4_f32`].
pub fn fma_tap4_f32_generic(yb: &mut [f32], xs: &[f32], w: [f32; 4]) {
    for (t, y) in yb.iter_mut().enumerate() {
        let acc = w[0].mul_add(xs[t], *y);
        let acc = w[1].mul_add(xs[t + 1], acc);
        let acc = w[2].mul_add(xs[t + 2], acc);
        *y = w[3].mul_add(xs[t + 3], acc);
    }
}

// ───────────────────────── x86_64 back ends ───────────────────────────

#[cfg(target_arch = "x86_64")]
mod x86 {
    use std::arch::x86_64::*;

    macro_rules! assign_avx {
        ($name:ident, $vop:ident, $scalar:expr) => {
            #[target_feature(enable = "avx2")]
            // SAFETY: caller must guarantee AVX2 (dispatch does, via the
            // Avx2 tier). All pointer offsets stay below
            // `n = min(dst.len(), src.len())`, within both slices.
            pub unsafe fn $name(dst: &mut [f32], src: &[f32]) {
                let n = dst.len().min(src.len());
                let dp = dst.as_mut_ptr();
                let sp = src.as_ptr();
                let mut i = 0;
                while i + 8 <= n {
                    let d = _mm256_loadu_ps(dp.add(i));
                    let s = _mm256_loadu_ps(sp.add(i));
                    _mm256_storeu_ps(dp.add(i), $vop(d, s));
                    i += 8;
                }
                while i < n {
                    let f: fn(f32, f32) -> f32 = $scalar;
                    dst[i] = f(dst[i], src[i]);
                    i += 1;
                }
            }
        };
    }

    macro_rules! assign_sse {
        ($name:ident, $vop:ident, $scalar:expr) => {
            #[target_feature(enable = "sse2")]
            // SAFETY: caller must guarantee SSE2 (baseline on x86_64).
            // All pointer offsets stay below
            // `n = min(dst.len(), src.len())`, within both slices.
            pub unsafe fn $name(dst: &mut [f32], src: &[f32]) {
                let n = dst.len().min(src.len());
                let dp = dst.as_mut_ptr();
                let sp = src.as_ptr();
                let mut i = 0;
                while i + 4 <= n {
                    let d = _mm_loadu_ps(dp.add(i));
                    let s = _mm_loadu_ps(sp.add(i));
                    _mm_storeu_ps(dp.add(i), $vop(d, s));
                    i += 4;
                }
                while i < n {
                    let f: fn(f32, f32) -> f32 = $scalar;
                    dst[i] = f(dst[i], src[i]);
                    i += 1;
                }
            }
        };
    }

    macro_rules! assign_avx512 {
        ($name:ident, $vop:ident, $scalar:expr) => {
            #[target_feature(enable = "avx512f")]
            // SAFETY: caller must guarantee AVX-512F (dispatch does, via
            // the Avx512 tier). All pointer offsets stay below
            // `n = min(dst.len(), src.len())`, within both slices.
            pub unsafe fn $name(dst: &mut [f32], src: &[f32]) {
                let n = dst.len().min(src.len());
                let dp = dst.as_mut_ptr();
                let sp = src.as_ptr();
                let mut i = 0;
                while i + 16 <= n {
                    let d = _mm512_loadu_ps(dp.add(i));
                    let s = _mm512_loadu_ps(sp.add(i));
                    _mm512_storeu_ps(dp.add(i), $vop(d, s));
                    i += 16;
                }
                while i < n {
                    let f: fn(f32, f32) -> f32 = $scalar;
                    dst[i] = f(dst[i], src[i]);
                    i += 1;
                }
            }
        };
    }

    assign_avx512!(add_assign_avx512, _mm512_add_ps, |a, b| a + b);
    assign_avx512!(max_assign_avx512, _mm512_max_ps, |a, b| if a > b { a } else { b });
    assign_avx512!(min_assign_avx512, _mm512_min_ps, |a, b| if a < b { a } else { b });
    assign_avx!(add_assign_avx2, _mm256_add_ps, |a, b| a + b);
    assign_avx!(max_assign_avx2, _mm256_max_ps, |a, b| if a > b { a } else { b });
    assign_avx!(min_assign_avx2, _mm256_min_ps, |a, b| if a < b { a } else { b });
    assign_sse!(add_assign_sse2, _mm_add_ps, |a, b| a + b);
    assign_sse!(max_assign_sse2, _mm_max_ps, |a, b| if a > b { a } else { b });
    assign_sse!(min_assign_sse2, _mm_min_ps, |a, b| if a < b { a } else { b });

    #[target_feature(enable = "avx512f")]
    // SAFETY: caller must guarantee AVX-512F (dispatch does, via the
    // Avx512 tier) and `xs.len() >= yb.len()`; offsets stay below
    // `yb.len()`.
    pub unsafe fn fma_tap1_avx512(yb: &mut [f32], xs: &[f32], wk: f32) {
        let n = yb.len();
        let yp = yb.as_mut_ptr();
        let xp = xs.as_ptr();
        let wv = _mm512_set1_ps(wk);
        let mut t = 0;
        while t + 16 <= n {
            let acc = _mm512_loadu_ps(yp.add(t));
            let x = _mm512_loadu_ps(xp.add(t));
            _mm512_storeu_ps(yp.add(t), _mm512_fmadd_ps(wv, x, acc));
            t += 16;
        }
        while t < n {
            yb[t] = wk.mul_add(xs[t], yb[t]);
            t += 1;
        }
    }

    #[target_feature(enable = "avx512f")]
    // SAFETY: caller must guarantee AVX-512F (dispatch does, via the
    // Avx512 tier) and `xs.len() >= yb.len() + 3`, covering the `t + 3`
    // loads.
    pub unsafe fn fma_tap4_avx512(yb: &mut [f32], xs: &[f32], w: [f32; 4]) {
        let n = yb.len();
        let yp = yb.as_mut_ptr();
        let xp = xs.as_ptr();
        let w0 = _mm512_set1_ps(w[0]);
        let w1 = _mm512_set1_ps(w[1]);
        let w2 = _mm512_set1_ps(w[2]);
        let w3 = _mm512_set1_ps(w[3]);
        let mut t = 0;
        while t + 16 <= n {
            let mut acc = _mm512_loadu_ps(yp.add(t));
            acc = _mm512_fmadd_ps(w0, _mm512_loadu_ps(xp.add(t)), acc);
            acc = _mm512_fmadd_ps(w1, _mm512_loadu_ps(xp.add(t + 1)), acc);
            acc = _mm512_fmadd_ps(w2, _mm512_loadu_ps(xp.add(t + 2)), acc);
            acc = _mm512_fmadd_ps(w3, _mm512_loadu_ps(xp.add(t + 3)), acc);
            _mm512_storeu_ps(yp.add(t), acc);
            t += 16;
        }
        while t < n {
            let acc = w[0].mul_add(xs[t], yb[t]);
            let acc = w[1].mul_add(xs[t + 1], acc);
            let acc = w[2].mul_add(xs[t + 2], acc);
            yb[t] = w[3].mul_add(xs[t + 3], acc);
            t += 1;
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    // SAFETY: caller must guarantee AVX2+FMA (dispatch does, via the Avx2
    // tier) and `xs.len() >= yb.len()`; offsets stay below `yb.len()`.
    pub unsafe fn fma_tap1_avx2(yb: &mut [f32], xs: &[f32], wk: f32) {
        let n = yb.len();
        let yp = yb.as_mut_ptr();
        let xp = xs.as_ptr();
        let wv = _mm256_set1_ps(wk);
        let mut t = 0;
        while t + 8 <= n {
            let acc = _mm256_loadu_ps(yp.add(t));
            let x = _mm256_loadu_ps(xp.add(t));
            _mm256_storeu_ps(yp.add(t), _mm256_fmadd_ps(wv, x, acc));
            t += 8;
        }
        while t < n {
            yb[t] = wk.mul_add(xs[t], yb[t]);
            t += 1;
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    // SAFETY: caller must guarantee AVX2+FMA (dispatch does, via the Avx2
    // tier) and `xs.len() >= yb.len() + 3`, covering the `t + 3` loads.
    pub unsafe fn fma_tap4_avx2(yb: &mut [f32], xs: &[f32], w: [f32; 4]) {
        let n = yb.len();
        let yp = yb.as_mut_ptr();
        let xp = xs.as_ptr();
        let w0 = _mm256_set1_ps(w[0]);
        let w1 = _mm256_set1_ps(w[1]);
        let w2 = _mm256_set1_ps(w[2]);
        let w3 = _mm256_set1_ps(w[3]);
        let mut t = 0;
        while t + 8 <= n {
            let mut acc = _mm256_loadu_ps(yp.add(t));
            acc = _mm256_fmadd_ps(w0, _mm256_loadu_ps(xp.add(t)), acc);
            acc = _mm256_fmadd_ps(w1, _mm256_loadu_ps(xp.add(t + 1)), acc);
            acc = _mm256_fmadd_ps(w2, _mm256_loadu_ps(xp.add(t + 2)), acc);
            acc = _mm256_fmadd_ps(w3, _mm256_loadu_ps(xp.add(t + 3)), acc);
            _mm256_storeu_ps(yp.add(t), acc);
            t += 8;
        }
        while t < n {
            let acc = w[0].mul_add(xs[t], yb[t]);
            let acc = w[1].mul_add(xs[t + 1], acc);
            let acc = w[2].mul_add(xs[t + 2], acc);
            yb[t] = w[3].mul_add(xs[t + 3], acc);
            t += 1;
        }
    }
}

// ───────────────────────── aarch64 back end ───────────────────────────

#[cfg(target_arch = "aarch64")]
mod neon {
    use std::arch::aarch64::*;

    macro_rules! assign_neon {
        ($name:ident, $vop:ident, $scalar:expr) => {
            #[target_feature(enable = "neon")]
            // SAFETY: caller must guarantee NEON (baseline on aarch64).
            // All pointer offsets stay below
            // `n = min(dst.len(), src.len())`, within both slices.
            pub unsafe fn $name(dst: &mut [f32], src: &[f32]) {
                let n = dst.len().min(src.len());
                let dp = dst.as_mut_ptr();
                let sp = src.as_ptr();
                let mut i = 0;
                while i + 4 <= n {
                    let d = vld1q_f32(dp.add(i));
                    let s = vld1q_f32(sp.add(i));
                    vst1q_f32(dp.add(i), $vop(d, s));
                    i += 4;
                }
                while i < n {
                    let f: fn(f32, f32) -> f32 = $scalar;
                    dst[i] = f(dst[i], src[i]);
                    i += 1;
                }
            }
        };
    }

    assign_neon!(add_assign_neon, vaddq_f32, |a, b| a + b);
    assign_neon!(max_assign_neon, vmaxq_f32, |a, b| if a > b { a } else { b });
    assign_neon!(min_assign_neon, vminq_f32, |a, b| if a < b { a } else { b });

    #[target_feature(enable = "neon")]
    // SAFETY: caller must guarantee NEON (baseline on aarch64) and
    // `xs.len() >= yb.len()`; offsets stay below `yb.len()`.
    pub unsafe fn fma_tap1_neon(yb: &mut [f32], xs: &[f32], wk: f32) {
        let n = yb.len();
        let yp = yb.as_mut_ptr();
        let xp = xs.as_ptr();
        let mut t = 0;
        while t + 4 <= n {
            let acc = vld1q_f32(yp.add(t));
            let x = vld1q_f32(xp.add(t));
            vst1q_f32(yp.add(t), vfmaq_n_f32(acc, x, wk));
            t += 4;
        }
        while t < n {
            yb[t] = wk.mul_add(xs[t], yb[t]);
            t += 1;
        }
    }

    #[target_feature(enable = "neon")]
    // SAFETY: caller must guarantee NEON (baseline on aarch64) and
    // `xs.len() >= yb.len() + 3`, covering the `t + 3` loads.
    pub unsafe fn fma_tap4_neon(yb: &mut [f32], xs: &[f32], w: [f32; 4]) {
        let n = yb.len();
        let yp = yb.as_mut_ptr();
        let xp = xs.as_ptr();
        let mut t = 0;
        while t + 4 <= n {
            let mut acc = vld1q_f32(yp.add(t));
            acc = vfmaq_n_f32(acc, vld1q_f32(xp.add(t)), w[0]);
            acc = vfmaq_n_f32(acc, vld1q_f32(xp.add(t + 1)), w[1]);
            acc = vfmaq_n_f32(acc, vld1q_f32(xp.add(t + 2)), w[2]);
            acc = vfmaq_n_f32(acc, vld1q_f32(xp.add(t + 3)), w[3]);
            vst1q_f32(yp.add(t), acc);
            t += 4;
        }
        while t < n {
            let acc = w[0].mul_add(xs[t], yb[t]);
            let acc = w[1].mul_add(xs[t + 1], acc);
            let acc = w[2].mul_add(xs[t + 2], acc);
            yb[t] = w[3].mul_add(xs[t + 3], acc);
            t += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tier_names_roundtrip() {
        for t in [
            SimdTier::Avx512,
            SimdTier::Avx2,
            SimdTier::Sse2,
            SimdTier::Neon,
            SimdTier::Generic,
        ] {
            assert_eq!(SimdTier::parse(t.name()), Some(t));
        }
        assert_eq!(SimdTier::parse("off"), Some(SimdTier::Generic));
        assert_eq!(SimdTier::parse("avx1024"), None);
    }

    #[test]
    fn generic_tier_always_supported() {
        assert!(SimdTier::Generic.is_supported());
        assert!(!SimdTier::Generic.has_fused_fma());
        assert!(tier().is_supported());
    }

    #[test]
    fn as_f32_downcasts_only_f32() {
        let xs = [1.0f32, 2.0];
        assert_eq!(as_f32(&xs), Some(&xs[..]));
        let ys = [1.0f64, 2.0];
        assert!(as_f32(&ys).is_none());
        let mut zs = [3.0f32];
        assert!(as_f32_mut(&mut zs).is_some());
    }

    #[test]
    fn generic_kernels_match_scalar_ops() {
        let src: Vec<f32> = (0..37).map(|i| (i as f32) * 0.5 - 9.0).collect();
        let base: Vec<f32> = (0..37).map(|i| 8.0 - i as f32).collect();

        let mut add = base.clone();
        add_assign_f32_generic(&mut add, &src);
        let mut max = base.clone();
        max_assign_f32_generic(&mut max, &src);
        let mut min = base.clone();
        min_assign_f32_generic(&mut min, &src);
        for i in 0..src.len() {
            assert_eq!(add[i], base[i] + src[i]);
            assert_eq!(max[i], base[i].max(src[i]));
            assert_eq!(min[i], base[i].min(src[i]));
        }
    }

    #[test]
    fn dispatched_kernels_match_generic() {
        // Whatever tier detection picked, results must equal the oracle.
        let src: Vec<f32> = (0..131).map(|i| ((i * 37) % 19) as f32 - 9.0).collect();
        let base: Vec<f32> = (0..131).map(|i| ((i * 13) % 23) as f32 - 11.0).collect();

        let mut a = base.clone();
        add_assign_f32(&mut a, &src);
        let mut a_ref = base.clone();
        add_assign_f32_generic(&mut a_ref, &src);
        assert_eq!(a, a_ref);

        let mut m = base.clone();
        max_assign_f32(&mut m, &src);
        let mut m_ref = base.clone();
        max_assign_f32_generic(&mut m_ref, &src);
        assert_eq!(m, m_ref);

        let mut y = base.clone();
        fma_tap1_f32(&mut y, &src, 0.37);
        let mut y_ref = base.clone();
        fma_tap1_f32_generic(&mut y_ref, &src, 0.37);
        assert_eq!(y, y_ref);

        let w = [0.25f32, -0.5, 1.5, 0.125];
        let n = base.len() - 3;
        let mut z = base[..n].to_vec();
        fma_tap4_f32(&mut z, &src, w);
        let mut z_ref = base[..n].to_vec();
        fma_tap4_f32_generic(&mut z_ref, &src, w);
        assert_eq!(z, z_ref);
    }
}
