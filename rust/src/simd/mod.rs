//! Software vector machine — the substrate for the paper's Algorithms 1–4
//! — plus the runtime `std::arch` SIMD dispatch behind the f32 hot loops.
//!
//! The paper is written against a CPU vector ISA: a register of `P` lanes
//! supporting broadcast, element shift (`≪`), lane-wise `⊕`, and the
//! `Slide` operation (SVE `EXT` / RISC-V `vslideup`/`vslidedown` /
//! AVX-512 `vperm*2ps`). [`VecReg`] provides exactly that abstraction as
//! a fixed-capacity lane array; `P` is a runtime-chosen *logical* width
//! ≤ [`MAX_LANES`], letting the benches sweep the paper's `O(P/w)`
//! scaling law.
//!
//! The lane-wise `⊕` no longer relies on LLVM auto-vectorization alone:
//! [`dispatch`] selects AVX-512F/AVX2/SSE2 (x86_64) or NEON (aarch64)
//! kernels at startup via runtime feature detection, with the generic
//! code as the portable fallback (`SWSNN_SIMD=off` forces it). See
//! [`SimdTier`] for the tier table and the bit-exactness contract.
//! [`qdot`] carries the int8 twin loops for the quantized conv backend.

mod dispatch;
mod qdot;
mod vector;

pub use dispatch::{
    add_assign_f32, add_assign_f32_generic, as_f32, as_f32_mut, fma_tap1_f32,
    fma_tap1_f32_generic, fma_tap4_f32, fma_tap4_f32_generic, force_tier, max_assign_f32,
    max_assign_f32_generic, min_assign_f32, min_assign_f32_generic, tier, SimdTier,
};
pub use qdot::{dot_i8_tap, dot_i8_tap_generic, sum_i8_tap, sum_i8_tap_generic};
pub use vector::VecReg;

/// Maximum logical lane count of the software vector machine.
pub const MAX_LANES: usize = 64;

/// Supported logical widths (powers of two, matching real ISAs:
/// 8 ≈ AVX2 f32, 16 ≈ AVX-512 f32, 32/64 ≈ SVE-1024/RVV LMUL>1).
pub const WIDTHS: [usize; 4] = [8, 16, 32, 64];

/// Validates a logical width.
pub fn is_valid_width(p: usize) -> bool {
    WIDTHS.contains(&p)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn widths_are_powers_of_two_and_bounded() {
        for w in WIDTHS {
            assert!(w.is_power_of_two());
            assert!(w <= MAX_LANES);
            assert!(is_valid_width(w));
        }
        assert!(!is_valid_width(7));
        assert!(!is_valid_width(128));
    }
}
