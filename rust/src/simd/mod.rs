//! Software vector machine — the substrate for the paper's Algorithms 1–4.
//!
//! The paper is written against a CPU vector ISA: a register of `P` lanes
//! supporting broadcast, element shift (`≪`), lane-wise `⊕`, and the
//! `Slide` operation (SVE `EXT` / RISC-V `vslideup`/`vslidedown` /
//! AVX-512 `vperm*2ps`). This module provides exactly that abstraction as
//! a fixed-capacity lane array. The lane loops are written branch-free
//! over `P` contiguous elements so LLVM auto-vectorizes them to the host's
//! real SIMD (verified by the `tbl_scan`/`tbl_algorithms` benches); `P` is
//! a runtime-chosen *logical* width ≤ [`MAX_LANES`], letting the benches
//! sweep the paper's `O(P/w)` scaling law.

mod vector;
pub use vector::VecReg;

/// Maximum logical lane count of the software vector machine.
pub const MAX_LANES: usize = 64;

/// Supported logical widths (powers of two, matching real ISAs:
/// 8 ≈ AVX2 f32, 16 ≈ AVX-512 f32, 32/64 ≈ SVE-1024/RVV LMUL>1).
pub const WIDTHS: [usize; 4] = [8, 16, 32, 64];

/// Validates a logical width.
pub fn is_valid_width(p: usize) -> bool {
    WIDTHS.contains(&p)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn widths_are_powers_of_two_and_bounded() {
        for w in WIDTHS {
            assert!(w.is_power_of_two());
            assert!(w <= MAX_LANES);
            assert!(is_valid_width(w));
        }
        assert!(!is_valid_width(7));
        assert!(!is_valid_width(128));
    }
}
