//! Runtime-dispatched i8 → i32 inner loops for the quantized sliding
//! convolution (`conv::conv1d_quantized_into`).
//!
//! Two primitives, both accumulating into an `i32` row:
//!
//! * [`dot_i8_tap`] — one broadcast tap of the sliding schedule:
//!   `acc[t] += wq · xs[t]` (the int8 twin of `fma_tap1_f32`);
//! * [`sum_i8_tap`] — `acc[t] += xs[t]`, the per-window Σqx correction
//!   sum the affine zero-point folding needs (see docs/quantization.md).
//!
//! Unlike the f32 kernels, **every** tier is bit-identical here by
//! construction, not just by matching rounding: an i8×i8 product is at
//! most 127·127 = 16129 (exact in i16 and i32 alike) and i32 addition
//! is exactly associative, so lane width and tap grouping cannot change
//! a single bit. The generic oracle uses `wrapping_add` so debug builds
//! agree with the (wrapping) vector adds even if a caller overflows the
//! documented headroom (|acc| stays below `taps · 2^14`, far from i32
//! range for every model shape the planner emits).

use super::dispatch::{tier, SimdTier};

/// `acc[t] += wq · xs[t]` for every accumulator element.
/// Requires `xs.len() >= acc.len()`.
pub fn dot_i8_tap(acc: &mut [i32], xs: &[i8], wq: i8) {
    debug_assert!(xs.len() >= acc.len());
    match tier() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: the Avx512 tier requires AVX-512F at detection time;
        // the caller contract `xs.len() >= acc.len()` keeps loads in
        // bounds.
        SimdTier::Avx512 => unsafe { x86::dot_i8_tap_avx512(acc, xs, wq) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: the Avx2 tier requires AVX2 at detection time; same
        // length contract.
        SimdTier::Avx2 => unsafe { x86::dot_i8_tap_avx2(acc, xs, wq) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64; same length contract.
        SimdTier::Neon => unsafe { neon::dot_i8_tap_neon(acc, xs, wq) },
        // SSE2 lacks i8→i32 widening (cvtepi8 is SSE4.1): generic path.
        _ => dot_i8_tap_generic(acc, xs, wq),
    }
}

/// `acc[t] += xs[t]` for every accumulator element.
/// Requires `xs.len() >= acc.len()`.
pub fn sum_i8_tap(acc: &mut [i32], xs: &[i8]) {
    debug_assert!(xs.len() >= acc.len());
    match tier() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: the Avx512 tier requires AVX-512F at detection time;
        // the caller contract `xs.len() >= acc.len()` keeps loads in
        // bounds.
        SimdTier::Avx512 => unsafe { x86::sum_i8_tap_avx512(acc, xs) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: the Avx2 tier requires AVX2 at detection time; same
        // length contract.
        SimdTier::Avx2 => unsafe { x86::sum_i8_tap_avx2(acc, xs) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64; same length contract.
        SimdTier::Neon => unsafe { neon::sum_i8_tap_neon(acc, xs) },
        _ => sum_i8_tap_generic(acc, xs),
    }
}

/// Portable oracle for [`dot_i8_tap`].
pub fn dot_i8_tap_generic(acc: &mut [i32], xs: &[i8], wq: i8) {
    let w = wq as i32;
    for (a, &x) in acc.iter_mut().zip(xs) {
        *a = a.wrapping_add(w * x as i32);
    }
}

/// Portable oracle for [`sum_i8_tap`].
pub fn sum_i8_tap_generic(acc: &mut [i32], xs: &[i8]) {
    for (a, &x) in acc.iter_mut().zip(xs) {
        *a = a.wrapping_add(x as i32);
    }
}

// ───────────────────────── x86_64 back ends ───────────────────────────

#[cfg(target_arch = "x86_64")]
mod x86 {
    use std::arch::x86_64::*;

    #[target_feature(enable = "avx512f")]
    // SAFETY: caller must guarantee AVX-512F (dispatch does, via the
    // Avx512 tier) and `xs.len() >= acc.len()`; all offsets stay below
    // `acc.len()`.
    pub unsafe fn dot_i8_tap_avx512(acc: &mut [i32], xs: &[i8], wq: i8) {
        let n = acc.len();
        let ap = acc.as_mut_ptr();
        let xp = xs.as_ptr();
        let wv = _mm512_set1_epi32(wq as i32);
        let mut t = 0;
        while t + 16 <= n {
            // 16 × i8 → 16 × i32, exact product in 32 bits.
            let x = _mm512_cvtepi8_epi32(_mm_loadu_si128(xp.add(t) as *const __m128i));
            let a = _mm512_loadu_epi32(ap.add(t));
            _mm512_storeu_epi32(ap.add(t), _mm512_add_epi32(a, _mm512_mullo_epi32(wv, x)));
            t += 16;
        }
        let w = wq as i32;
        while t < n {
            acc[t] = acc[t].wrapping_add(w * xs[t] as i32);
            t += 1;
        }
    }

    #[target_feature(enable = "avx512f")]
    // SAFETY: caller must guarantee AVX-512F (dispatch does, via the
    // Avx512 tier) and `xs.len() >= acc.len()`; all offsets stay below
    // `acc.len()`.
    pub unsafe fn sum_i8_tap_avx512(acc: &mut [i32], xs: &[i8]) {
        let n = acc.len();
        let ap = acc.as_mut_ptr();
        let xp = xs.as_ptr();
        let mut t = 0;
        while t + 16 <= n {
            let x = _mm512_cvtepi8_epi32(_mm_loadu_si128(xp.add(t) as *const __m128i));
            let a = _mm512_loadu_epi32(ap.add(t));
            _mm512_storeu_epi32(ap.add(t), _mm512_add_epi32(a, x));
            t += 16;
        }
        while t < n {
            acc[t] = acc[t].wrapping_add(xs[t] as i32);
            t += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    // SAFETY: caller must guarantee AVX2 (dispatch does, via the Avx2
    // tier) and `xs.len() >= acc.len()`; all offsets stay below
    // `acc.len()`.
    pub unsafe fn dot_i8_tap_avx2(acc: &mut [i32], xs: &[i8], wq: i8) {
        let n = acc.len();
        let ap = acc.as_mut_ptr();
        let xp = xs.as_ptr();
        let wv = _mm256_set1_epi16(wq as i16);
        let mut t = 0;
        while t + 16 <= n {
            // 16 × i8 → i16, multiply exactly in i16 (|wq·x| ≤ 16129),
            // then widen each half to i32 and add.
            let x16 = _mm256_cvtepi8_epi16(_mm_loadu_si128(xp.add(t) as *const __m128i));
            let prod = _mm256_mullo_epi16(wv, x16);
            let lo = _mm256_cvtepi16_epi32(_mm256_castsi256_si128(prod));
            let hi = _mm256_cvtepi16_epi32(_mm256_extracti128_si256::<1>(prod));
            let a0 = _mm256_loadu_si256(ap.add(t) as *const __m256i);
            let a1 = _mm256_loadu_si256(ap.add(t + 8) as *const __m256i);
            _mm256_storeu_si256(ap.add(t) as *mut __m256i, _mm256_add_epi32(a0, lo));
            _mm256_storeu_si256(ap.add(t + 8) as *mut __m256i, _mm256_add_epi32(a1, hi));
            t += 16;
        }
        let w = wq as i32;
        while t < n {
            acc[t] = acc[t].wrapping_add(w * xs[t] as i32);
            t += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    // SAFETY: caller must guarantee AVX2 (dispatch does, via the Avx2
    // tier) and `xs.len() >= acc.len()`; all offsets stay below
    // `acc.len()`.
    pub unsafe fn sum_i8_tap_avx2(acc: &mut [i32], xs: &[i8]) {
        let n = acc.len();
        let ap = acc.as_mut_ptr();
        let xp = xs.as_ptr();
        let mut t = 0;
        while t + 16 <= n {
            let x16 = _mm256_cvtepi8_epi16(_mm_loadu_si128(xp.add(t) as *const __m128i));
            let lo = _mm256_cvtepi16_epi32(_mm256_castsi256_si128(x16));
            let hi = _mm256_cvtepi16_epi32(_mm256_extracti128_si256::<1>(x16));
            let a0 = _mm256_loadu_si256(ap.add(t) as *const __m256i);
            let a1 = _mm256_loadu_si256(ap.add(t + 8) as *const __m256i);
            _mm256_storeu_si256(ap.add(t) as *mut __m256i, _mm256_add_epi32(a0, lo));
            _mm256_storeu_si256(ap.add(t + 8) as *mut __m256i, _mm256_add_epi32(a1, hi));
            t += 16;
        }
        while t < n {
            acc[t] = acc[t].wrapping_add(xs[t] as i32);
            t += 1;
        }
    }
}

// ───────────────────────── aarch64 back end ───────────────────────────

#[cfg(target_arch = "aarch64")]
mod neon {
    use std::arch::aarch64::*;

    #[target_feature(enable = "neon")]
    // SAFETY: caller must guarantee NEON (baseline on aarch64) and
    // `xs.len() >= acc.len()`; all offsets stay below `acc.len()`.
    pub unsafe fn dot_i8_tap_neon(acc: &mut [i32], xs: &[i8], wq: i8) {
        let n = acc.len();
        let ap = acc.as_mut_ptr();
        let xp = xs.as_ptr();
        let wv = vdup_n_s8(wq);
        let mut t = 0;
        while t + 8 <= n {
            // 8 × i8 widening multiply → i16 (exact), then widening adds
            // into the two i32 accumulator quads.
            let prod = vmull_s8(vld1_s8(xp.add(t)), wv);
            let a0 = vld1q_s32(ap.add(t));
            let a1 = vld1q_s32(ap.add(t + 4));
            vst1q_s32(ap.add(t), vaddw_s16(a0, vget_low_s16(prod)));
            vst1q_s32(ap.add(t + 4), vaddw_s16(a1, vget_high_s16(prod)));
            t += 8;
        }
        let w = wq as i32;
        while t < n {
            acc[t] = acc[t].wrapping_add(w * xs[t] as i32);
            t += 1;
        }
    }

    #[target_feature(enable = "neon")]
    // SAFETY: caller must guarantee NEON (baseline on aarch64) and
    // `xs.len() >= acc.len()`; all offsets stay below `acc.len()`.
    pub unsafe fn sum_i8_tap_neon(acc: &mut [i32], xs: &[i8]) {
        let n = acc.len();
        let ap = acc.as_mut_ptr();
        let xp = xs.as_ptr();
        let mut t = 0;
        while t + 8 <= n {
            let x16 = vmovl_s8(vld1_s8(xp.add(t)));
            let a0 = vld1q_s32(ap.add(t));
            let a1 = vld1q_s32(ap.add(t + 4));
            vst1q_s32(ap.add(t), vaddw_s16(a0, vget_low_s16(x16)));
            vst1q_s32(ap.add(t + 4), vaddw_s16(a1, vget_high_s16(x16)));
            t += 8;
        }
        while t < n {
            acc[t] = acc[t].wrapping_add(xs[t] as i32);
            t += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn i8_pattern(n: usize, salt: i32) -> Vec<i8> {
        (0..n).map(|i| (((i as i32 * 37 + salt) % 255) - 127) as i8).collect()
    }

    #[test]
    fn dispatched_dot_matches_generic() {
        // Whatever tier detection picked, results must equal the oracle
        // exactly (i32 arithmetic — no rounding story at all).
        let xs = i8_pattern(133, 5);
        let base: Vec<i32> = (0..133).map(|i| (i as i32 * 91) % 1000 - 500).collect();
        for wq in [-128i8, -7, 0, 1, 127] {
            let mut a = base.clone();
            dot_i8_tap(&mut a, &xs, wq);
            let mut a_ref = base.clone();
            dot_i8_tap_generic(&mut a_ref, &xs, wq);
            assert_eq!(a, a_ref, "wq={wq}");
        }
        let mut s = base.clone();
        sum_i8_tap(&mut s, &xs);
        let mut s_ref = base;
        sum_i8_tap_generic(&mut s_ref, &xs);
        assert_eq!(s, s_ref);
    }

    #[test]
    fn generic_matches_scalar_math() {
        let xs = i8_pattern(40, 11);
        let mut acc = vec![3i32; 37];
        dot_i8_tap_generic(&mut acc, &xs, -9);
        for (t, a) in acc.iter().enumerate() {
            assert_eq!(*a, 3 + (-9) * xs[t] as i32);
        }
        let mut acc = vec![-2i32; 37];
        sum_i8_tap_generic(&mut acc, &xs);
        for (t, a) in acc.iter().enumerate() {
            assert_eq!(*a, -2 + xs[t] as i32);
        }
    }
}
