//! Custom kernels for small filters (paper §5): "the most common filter
//! sizes in the DNN applications are 3 and 5 in every dimension. With
//! the filter this small the current sliding convolution algorithms
//! demonstrate very modest speedup since the number of arithmetic
//! instructions per memory load is low … could require custom compute
//! kernels for the small filter sizes."
//!
//! The custom kernels raise arithmetic intensity by *register-blocking
//! the taps*: all k coefficients live in registers and each input
//! element is loaded once, contributing to k outputs within one fused
//! loop — one pass over the input instead of k. The compiler keeps the
//! k-wide accumulation window in vector registers (we hand it fully
//! unrolled bodies for k = 3 and 5).

use crate::ops::Epilogue;

use super::Conv1dParams;

/// Fused single-pass conv for k=3, stride 1, no padding (valid mode).
/// One load per input element, 3 FMAs — versus 3 passes (3 loads per
/// element position) in the generic slid-accumulate schedule.
pub fn conv1d_k3(x: &[f32], w: &[f32; 3], bias: f32, y: &mut [f32]) {
    let n_out = x.len() - 2;
    assert!(y.len() >= n_out);
    let (w0, w1, w2) = (w[0], w[1], w[2]);
    // y[t] = w0·x[t] + w1·x[t+1] + w2·x[t+2]; the three loads share a
    // sliding register window the vectorizer materializes as shuffles of
    // one stream.
    for t in 0..n_out {
        let acc = w0.mul_add(x[t], bias);
        let acc = w1.mul_add(x[t + 1], acc);
        y[t] = w2.mul_add(x[t + 2], acc);
    }
}

/// Fused single-pass conv for k=5, stride 1, no padding (valid mode).
pub fn conv1d_k5(x: &[f32], w: &[f32; 5], bias: f32, y: &mut [f32]) {
    let n_out = x.len() - 4;
    assert!(y.len() >= n_out);
    let (w0, w1, w2, w3, w4) = (w[0], w[1], w[2], w[3], w[4]);
    for t in 0..n_out {
        let acc = w0.mul_add(x[t], bias);
        let acc = w1.mul_add(x[t + 1], acc);
        let acc = w2.mul_add(x[t + 2], acc);
        let acc = w3.mul_add(x[t + 3], acc);
        y[t] = w4.mul_add(x[t + 4], acc);
    }
}

/// Whether the fused small-k kernels can execute this shape: single
/// channel, unit stride/dilation, valid mode, k ∈ {3, 5}. Any batch size
/// qualifies — the `_into` path runs one fused pass per batch row.
pub fn small_k_qualifies(p: &Conv1dParams) -> bool {
    p.c_in == 1
        && p.c_out == 1
        && p.stride == 1
        && p.dilation == 1
        && p.pad == 0
        && matches!(p.k, 3 | 5)
}

/// Dispatch wrapper: uses the fused small-k kernel when the shape
/// qualifies (single channel, stride 1, k ∈ {3,5}), padding handled by
/// edge patch-up with the generic path. Returns `None` if the shape
/// doesn't qualify — the caller falls back to the generic sliding conv.
pub fn conv1d_small_k(
    x: &[f32],
    w: &[f32],
    bias: Option<&[f32]>,
    p: &Conv1dParams,
) -> Option<Vec<f32>> {
    if p.batch != 1 || !small_k_qualifies(p) {
        return None;
    }
    // alloc-ok: Vec-returning wrapper; conv1d_small_k_into is the hot path.
    let mut y = vec![0.0f32; p.y_len()];
    conv1d_small_k_into(x, w, bias, p, Epilogue::None, &mut y).then_some(y)
}

/// Small-k kernel into a caller-provided buffer (any batch size; one
/// fused pass per batch row), with the [`Epilogue`] applied to each row
/// right after its pass. Returns `false` without touching `y` when the
/// shape does not qualify — the planner never selects this kernel for
/// such shapes, so a `false` here is a plan bug, not a fallback path.
pub fn conv1d_small_k_into(
    x: &[f32],
    w: &[f32],
    bias: Option<&[f32]>,
    p: &Conv1dParams,
    epi: Epilogue<'_>,
    y: &mut [f32],
) -> bool {
    if !small_k_qualifies(p) {
        return false;
    }
    p.validate(x, w, bias);
    assert_eq!(y.len(), p.y_len(), "dst length");
    epi.check_len(y.len());
    let n_out = p.n_out();
    if n_out == 0 {
        return true; // input shorter than the filter: empty output
    }
    crate::check::poison(y);
    let b = bias.map_or(0.0, |bv| bv[0]);
    for bi in 0..p.batch {
        let xr = &x[bi * p.n..][..p.n];
        let yr = &mut y[bi * n_out..][..n_out];
        match p.k {
            3 => conv1d_k3(xr, &[w[0], w[1], w[2]], b, yr),
            5 => conv1d_k5(xr, &[w[0], w[1], w[2], w[3], w[4]], b, yr),
            _ => unreachable!("small_k_qualifies checked k"),
        }
        epi.apply(yr, bi * n_out);
    }
    crate::check::assert_no_poison(y, "conv1d_small_k_into");
    true
}

#[cfg(test)]
mod tests {
    use super::super::conv1d_direct;
    use super::*;
    use crate::workload::Rng;

    #[test]
    fn k3_matches_direct() {
        let mut rng = Rng::new(0x53);
        let x = rng.vec_uniform(300, -1.0, 1.0);
        let w = rng.vec_uniform(3, -1.0, 1.0);
        let p = Conv1dParams::new(1, 1, 300, 3);
        let got = conv1d_small_k(&x, &w, None, &p).expect("qualifies");
        let want = conv1d_direct(&x, &w, None, &p);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn k5_matches_direct_with_bias() {
        let mut rng = Rng::new(0x55);
        let x = rng.vec_uniform(128, -1.0, 1.0);
        let w = rng.vec_uniform(5, -1.0, 1.0);
        let bias = [0.75f32];
        let p = Conv1dParams::new(1, 1, 128, 5);
        let got = conv1d_small_k(&x, &w, Some(&bias), &p).expect("qualifies");
        let want = conv1d_direct(&x, &w, Some(&bias), &p);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn non_qualifying_shapes_fall_back() {
        let p = Conv1dParams::new(2, 1, 64, 3);
        assert!(conv1d_small_k(&[0.0; 128], &[0.0; 6], None, &p).is_none());
        let p = Conv1dParams::new(1, 1, 64, 7);
        assert!(conv1d_small_k(&[0.0; 64], &[0.0; 7], None, &p).is_none());
        let p = Conv1dParams::new(1, 1, 64, 3).with_stride(2);
        assert!(conv1d_small_k(&[0.0; 64], &[0.0; 3], None, &p).is_none());
    }
}
