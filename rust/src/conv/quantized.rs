//! Quantized (int8 → int32) sliding convolution.
//!
//! The paper's §1 observes that quantization "is not entangled with GEMM
//! and could be equally successfully applied to the original convolution
//! problem" — this module is that claim made concrete: the identical
//! slid-accumulate schedule over `i8` activations/weights with `i32`
//! accumulation and per-tensor affine (scale, zero-point)
//! (de)quantization. The operator genericity of the sliding family is
//! what makes this a ~100-line addition rather than a new kernel stack.

use super::Conv1dParams;

/// Per-tensor affine quantization parameters: `real = scale·(q − zp)`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QuantParams {
    pub scale: f32,
    pub zero_point: i32,
}

impl QuantParams {
    /// Choose symmetric-ish parameters covering `[lo, hi]`.
    pub fn from_range(lo: f32, hi: f32) -> Self {
        let lo = lo.min(0.0);
        let hi = hi.max(0.0);
        let scale = ((hi - lo) / 255.0).max(1e-8);
        let zero_point = (-128.0 - lo / scale).round().clamp(-128.0, 127.0) as i32;
        Self { scale, zero_point }
    }

    pub fn quantize(&self, x: f32) -> i8 {
        ((x / self.scale).round() as i32 + self.zero_point).clamp(-128, 127) as i8
    }

    pub fn dequantize(&self, q: i32) -> f32 {
        (q - self.zero_point) as f32 * self.scale
    }

    pub fn quantize_slice(&self, xs: &[f32]) -> Vec<i8> {
        // alloc-ok: one-time quantization of inputs/weights (setup).
        xs.iter().map(|&x| self.quantize(x)).collect()
    }
}

/// Quantized 1-D convolution (single channel per pair, batched/channelled
/// like the f32 backends): i8 inputs/weights, i32 accumulators, f32 out.
///
/// Zero-point handling: with `x = sx(qx − zx)` and `w = sw(qw − zw)`,
/// `Σ w·x = sx·sw·Σ (qx−zx)(qw−zw)` — the cross terms are folded by
/// accumulating `Σ qw·qx − zw·Σ qx − zx·Σ qw + k·zx·zw` where `Σ qx`
/// per window is *itself a sliding window sum* (Eq. 3 with + over i32),
/// so even the correction term rides the paper's machinery.
pub fn conv1d_quantized(
    qx: &[i8],
    qw: &[i8],
    x_params: QuantParams,
    w_params: QuantParams,
    p: &Conv1dParams,
) -> Vec<f32> {
    assert_eq!(p.stride, 1, "quantized path implements stride 1");
    assert_eq!(p.pad, 0, "quantized path implements valid mode");
    assert_eq!(qx.len(), p.x_len(), "input shape");
    assert_eq!(qw.len(), p.w_len(), "filter shape");
    let n_out = p.n_out();
    // alloc-ok: Vec-returning i8 study path, not on the plan run path.
    let mut y = vec![0.0f32; p.y_len()];
    if n_out == 0 {
        return y;
    }
    let zx = x_params.zero_point;
    let zw = w_params.zero_point;
    let s = x_params.scale * w_params.scale;

    for b in 0..p.batch {
        for co in 0..p.c_out {
            let yrow = &mut y[(b * p.c_out + co) * n_out..][..n_out];
            let mut acc = vec![0i32; n_out]; // alloc-ok: study-path scratch
            // alloc-ok: Σ qx per window (sliding!) — study-path scratch.
            let mut qx_winsum = vec![0i32; n_out];
            let mut qw_sum = 0i32;
            for ci in 0..p.c_in {
                let xrow = &qx[(b * p.c_in + ci) * p.n..][..p.n];
                let wrow = &qw[(co * p.c_in + ci) * p.k..][..p.k];
                for (tap, &wq) in wrow.iter().enumerate() {
                    let off = tap * p.dilation;
                    let wq = wq as i32;
                    qw_sum += wq;
                    let xs = &xrow[off..off + n_out];
                    for t in 0..n_out {
                        let xq = xs[t] as i32;
                        acc[t] += wq * xq;
                        if tap == 0 {
                            // start the Σ qx sliding accumulation
                        }
                        qx_winsum[t] += xq;
                    }
                }
            }
            let k_total = (p.c_in * p.k) as i32;
            for t in 0..n_out {
                // Σ(qx−zx)(qw−zw) = Σqxqw − zw·Σqx − zx·Σqw + k·zx·zw
                let exact = acc[t] - zw * qx_winsum[t] - zx * qw_sum + k_total * zx * zw;
                yrow[t] = (exact as f32) * s;
            }
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::super::conv1d_direct;
    use super::*;
    use crate::workload::Rng;

    #[test]
    fn quant_roundtrip_error_bounded() {
        let qp = QuantParams::from_range(-2.0, 2.0);
        for x in [-2.0f32, -1.0, 0.0, 0.5, 1.999] {
            let q = qp.quantize(x);
            let back = qp.dequantize(q as i32);
            assert!((back - x).abs() <= qp.scale, "{x} → {q} → {back}");
        }
    }

    #[test]
    fn quantized_conv_tracks_f32_reference() {
        let mut rng = Rng::new(0x0_8);
        for (c_in, c_out, n, k, d) in [(1usize, 1usize, 200usize, 5usize, 1usize), (2, 3, 96, 3, 2)] {
            let p = Conv1dParams::new(c_in, c_out, n, k).with_dilation(d);
            let x = rng.vec_uniform(p.x_len(), -1.0, 1.0);
            let w = rng.vec_uniform(p.w_len(), -0.5, 0.5);
            let xq_p = QuantParams::from_range(-1.0, 1.0);
            let wq_p = QuantParams::from_range(-0.5, 0.5);
            let qx = xq_p.quantize_slice(&x);
            let qw = wq_p.quantize_slice(&w);
            // Reference uses the *dequantized* tensors so the comparison
            // isolates accumulation correctness from quantization error.
            let x_deq: Vec<f32> = qx.iter().map(|&q| xq_p.dequantize(q as i32)).collect();
            let w_deq: Vec<f32> = qw.iter().map(|&q| wq_p.dequantize(q as i32)).collect();
            let want = conv1d_direct(&x_deq, &w_deq, None, &p);
            let got = conv1d_quantized(&qx, &qw, xq_p, wq_p, &p);
            assert_eq!(got.len(), want.len());
            for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                assert!(
                    (a - b).abs() < 1e-3 * (1.0 + b.abs()),
                    "({c_in},{c_out},{n},{k},{d}) idx {i}: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn end_to_end_quantization_error_small() {
        // Against the true f32 conv, error is bounded by the quant grid.
        let mut rng = Rng::new(0x0_9);
        let p = Conv1dParams::new(1, 1, 500, 7);
        let x = rng.vec_uniform(p.x_len(), -1.0, 1.0);
        let w = rng.vec_uniform(p.w_len(), -0.5, 0.5);
        let xq_p = QuantParams::from_range(-1.0, 1.0);
        let wq_p = QuantParams::from_range(-0.5, 0.5);
        let got = conv1d_quantized(&xq_p.quantize_slice(&x), &wq_p.quantize_slice(&w), xq_p, wq_p, &p);
        let want = conv1d_direct(&x, &w, None, &p);
        let mut worst = 0.0f32;
        for (a, b) in got.iter().zip(&want) {
            worst = worst.max((a - b).abs());
        }
        // 7 taps × per-product grid error — generous bound.
        assert!(worst < 0.05, "quantization error {worst}");
    }
}
