//! Quantized (int8 → int32) sliding convolution.
//!
//! The paper's §1 observes that quantization "is not entangled with GEMM
//! and could be equally successfully applied to the original convolution
//! problem" — this module is that claim made concrete: the identical
//! slid-accumulate schedule over `i8` activations/weights with `i32`
//! accumulation and per-tensor affine (scale, zero-point)
//! (de)quantization. Since PR 8 this is a real planner backend
//! ([`conv1d_quantized_into`]: full stride/dilation/pad, fused
//! [`Epilogue`], `_into` contract, runtime-dispatched int8 SIMD inner
//! loops), not just the PR 0 stride-1 study path. The arithmetic is
//! pure `i32` — exactly associative — so every SIMD tier is
//! **bit-identical**, a strictly stronger parity story than the f32
//! kernels'. See docs/quantization.md for the affine scheme and the
//! zero-point folding argument.

use crate::ops::Epilogue;

use super::Conv1dParams;

/// Per-tensor affine quantization parameters: `real = scale·(q − zp)`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QuantParams {
    pub scale: f32,
    pub zero_point: i32,
}

impl QuantParams {
    /// Choose symmetric-ish parameters covering `[lo, hi]`.
    pub fn from_range(lo: f32, hi: f32) -> Self {
        let lo = lo.min(0.0);
        let hi = hi.max(0.0);
        let scale = ((hi - lo) / 255.0).max(1e-8);
        let zero_point = (-128.0 - lo / scale).round().clamp(-128.0, 127.0) as i32;
        Self { scale, zero_point }
    }

    /// Parameters covering the observed range of `xs` (the dynamic
    /// activation-quantization pass; non-finite values are skipped so a
    /// stray NaN cannot poison the scale).
    pub fn from_slice(xs: &[f32]) -> Self {
        let mut lo = 0.0f32;
        let mut hi = 0.0f32;
        for &x in xs {
            if x < lo {
                lo = x;
            }
            if x > hi {
                hi = x;
            }
        }
        Self::from_range(lo, hi)
    }

    pub fn quantize(&self, x: f32) -> i8 {
        ((x / self.scale).round() as i32 + self.zero_point).clamp(-128, 127) as i8
    }

    pub fn dequantize(&self, q: i32) -> f32 {
        (q - self.zero_point) as f32 * self.scale
    }

    /// Quantize a slice into a caller-provided destination (the hot
    /// form: the planner recycles its activation-quant scratch).
    /// `dst.len()` must equal `xs.len()`; every element is overwritten.
    pub fn quantize_slice_into(&self, xs: &[f32], dst: &mut [i8]) {
        assert_eq!(dst.len(), xs.len(), "dst length");
        for (d, &x) in dst.iter_mut().zip(xs) {
            *d = self.quantize(x);
        }
    }

    pub fn quantize_slice(&self, xs: &[f32]) -> Vec<i8> {
        // alloc-ok: Vec-returning wrapper; quantize_slice_into is the hot path.
        let mut dst = vec![0i8; xs.len()];
        self.quantize_slice_into(xs, &mut dst);
        dst
    }
}

/// Scratch length [`conv1d_quantized_into`] requires: the i32
/// accumulator row plus the Σqx window-sum row.
pub fn quantized_scratch_len(p: &Conv1dParams) -> usize {
    2 * p.n_out()
}

/// Quantized 1-D convolution, `Vec`-returning study/demo form (no bias,
/// no epilogue). The planner path is [`conv1d_quantized_into`].
pub fn conv1d_quantized(
    qx: &[i8],
    qw: &[i8],
    x_params: QuantParams,
    w_params: QuantParams,
    p: &Conv1dParams,
) -> Vec<f32> {
    // alloc-ok: Vec-returning wrapper; conv1d_quantized_into is the hot path.
    let mut y = vec![0.0f32; p.y_len()];
    // alloc-ok: wrapper-owned i32 scratch (acc + winsum rows).
    let mut acc = vec![0i32; quantized_scratch_len(p)];
    conv1d_quantized_into(qx, qw, x_params, w_params, None, p, Epilogue::None, &mut acc, &mut y);
    y
}

/// Quantized 1-D convolution into a caller-provided destination: i8
/// inputs/weights, i32 accumulators, f32 out. Full stride/dilation/pad
/// (padded positions behave as real value 0.0 — see below), fused
/// bias + [`Epilogue`] on the destination write.
///
/// Zero-point handling: with `x = sx(qx − zx)` and `w = sw(qw − zw)`,
/// `Σ w·x = sx·sw·Σ (qx−zx)(qw−zw)` — the cross terms are folded by
/// accumulating `Σ qw·qx − zw·Σ qx − zx·Σ qw + k·zx·zw` where `Σ qx`
/// per window is *itself a sliding window sum* (Eq. 3 with + over i32),
/// so even the correction term rides the paper's machinery. A padded
/// position contributes `qx = zx`, whose per-tap term
/// `zx·qw − zw·zx − zx·qw + zx·zw` cancels to exactly 0 — i.e. zero
/// padding in real space falls out of the folding for free.
///
/// `acc` is caller-provided i32 scratch of at least
/// [`quantized_scratch_len`] elements (contents irrelevant — fully
/// rewritten per output row). The interior of each row runs the
/// runtime-dispatched int8 SIMD loops ([`crate::simd::dot_i8_tap`] /
/// [`crate::simd::sum_i8_tap`]); all tiers are bit-identical because
/// every accumulator element receives exactly the same i32 products
/// and i32 addition is exactly associative.
#[allow(clippy::too_many_arguments)]
pub fn conv1d_quantized_into(
    qx: &[i8],
    qw: &[i8],
    x_params: QuantParams,
    w_params: QuantParams,
    bias: Option<&[f32]>,
    p: &Conv1dParams,
    epi: Epilogue<'_>,
    acc: &mut [i32],
    y: &mut [f32],
) {
    assert_eq!(qx.len(), p.x_len(), "input shape");
    assert_eq!(qw.len(), p.w_len(), "filter shape");
    assert_eq!(y.len(), p.y_len(), "dst length");
    if let Some(b) = bias {
        assert_eq!(b.len(), p.c_out, "bias shape");
    }
    assert!(p.k >= 1 && p.stride >= 1 && p.dilation >= 1);
    epi.check_len(y.len());
    crate::check::poison(y);
    let n_out = p.n_out();
    if n_out == 0 {
        return;
    }
    assert!(acc.len() >= quantized_scratch_len(p), "acc scratch length");
    let (accs, winsum) = acc.split_at_mut(n_out);
    let accs = &mut accs[..n_out];
    let winsum = &mut winsum[..n_out];

    let zx = x_params.zero_point;
    let zw = w_params.zero_point;
    let s = x_params.scale * w_params.scale;
    let k_total = (p.c_in * p.k) as i32;
    let corr = k_total * zx * zw;

    for b in 0..p.batch {
        for co in 0..p.c_out {
            let row = b * p.c_out + co;
            let yrow = &mut y[row * n_out..][..n_out];
            accs.fill(0);
            winsum.fill(0);
            let mut qw_sum = 0i32;
            for ci in 0..p.c_in {
                let xrow = &qx[(b * p.c_in + ci) * p.n..][..p.n];
                let wrow = &qw[(co * p.c_in + ci) * p.k..][..p.k];
                for (tap, &wq) in wrow.iter().enumerate() {
                    qw_sum += wq as i32;
                    accumulate_quantized_tap(accs, winsum, xrow, wq, tap, zx, p);
                }
            }
            let bias_v = bias.map_or(0.0, |bv| bv[co]);
            for t in 0..n_out {
                // Σ(qx−zx)(qw−zw) = Σqxqw − zw·Σqx − zx·Σqw + k·zx·zw
                let exact = accs[t]
                    .wrapping_sub(zw.wrapping_mul(winsum[t]))
                    .wrapping_sub(zx * qw_sum)
                    .wrapping_add(corr);
                yrow[t] = (exact as f32) * s + bias_v;
            }
            epi.apply(yrow, row * n_out);
        }
    }
    crate::check::assert_no_poison(y, "conv1d_quantized_into");
}

/// One filter tap over one channel row: for every output `t`, fold the
/// input position `t·stride + tap·dilation − pad` into both the product
/// accumulator and the Σqx window sum. Out-of-range positions (zero
/// padding) contribute the activation zero point. The in-range interior
/// takes the SIMD lanes at stride 1 and a scalar gather otherwise; both
/// add identical i32 terms, so the split never changes a bit.
fn accumulate_quantized_tap(
    accs: &mut [i32],
    winsum: &mut [i32],
    xrow: &[i8],
    wq: i8,
    tap: usize,
    zx: i32,
    p: &Conv1dParams,
) {
    let n_out = accs.len();
    let n = p.n;
    // x index for output t: t·stride + tap·dilation − pad ∈ [0, n)
    let base = tap as isize * p.dilation as isize - p.pad as isize;
    let t_lo = if base >= 0 {
        0usize
    } else {
        ((-base) as usize).div_ceil(p.stride)
    }
    .min(n_out);
    let t_hi = if (n as isize) <= base {
        0usize
    } else {
        (((n as isize - base) as usize).div_ceil(p.stride)).min(n_out)
    }
    .max(t_lo);

    // Padded head/tail: the position reads as the zero point.
    let pad_acc = wq as i32 * zx;
    for t in (0..t_lo).chain(t_hi..n_out) {
        accs[t] = accs[t].wrapping_add(pad_acc);
        winsum[t] = winsum[t].wrapping_add(zx);
    }
    if t_lo >= t_hi {
        return;
    }
    if p.stride == 1 {
        let x_off = (t_lo as isize + base) as usize;
        let xs = &xrow[x_off..x_off + (t_hi - t_lo)];
        crate::simd::dot_i8_tap(&mut accs[t_lo..t_hi], xs, wq);
        crate::simd::sum_i8_tap(&mut winsum[t_lo..t_hi], xs);
    } else {
        let w = wq as i32;
        let mut xi = (t_lo as isize * p.stride as isize + base) as usize;
        for t in t_lo..t_hi {
            let xq = xrow[xi] as i32;
            accs[t] = accs[t].wrapping_add(w * xq);
            winsum[t] = winsum[t].wrapping_add(xq);
            xi += p.stride;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::{conv1d_direct, conv1d_sliding};
    use super::*;
    use crate::workload::Rng;

    #[test]
    fn quant_roundtrip_error_bounded() {
        let qp = QuantParams::from_range(-2.0, 2.0);
        for x in [-2.0f32, -1.0, 0.0, 0.5, 1.999] {
            let q = qp.quantize(x);
            let back = qp.dequantize(q as i32);
            assert!((back - x).abs() <= qp.scale, "{x} → {q} → {back}");
        }
    }

    #[test]
    fn from_slice_covers_range_and_ignores_nan() {
        let qp = QuantParams::from_slice(&[-1.5, 0.25, f32::NAN, 3.0]);
        let want = QuantParams::from_range(-1.5, 3.0);
        assert_eq!(qp, want);
        // Empty/degenerate input still yields a usable (tiny) scale.
        let qp = QuantParams::from_slice(&[]);
        assert!(qp.scale > 0.0);
    }

    #[test]
    fn quantize_slice_into_matches_vec() {
        let mut rng = Rng::new(0x0_A);
        let xs = rng.vec_uniform(301, -2.0, 2.0);
        let qp = QuantParams::from_range(-2.0, 2.0);
        let want = qp.quantize_slice(&xs);
        let mut dst = vec![77i8; xs.len()];
        qp.quantize_slice_into(&xs, &mut dst);
        assert_eq!(dst, want);
    }

    #[test]
    fn quantized_conv_tracks_f32_reference() {
        let mut rng = Rng::new(0x0_8);
        for (c_in, c_out, n, k, d) in [(1usize, 1usize, 200usize, 5usize, 1usize), (2, 3, 96, 3, 2)] {
            let p = Conv1dParams::new(c_in, c_out, n, k).with_dilation(d);
            let x = rng.vec_uniform(p.x_len(), -1.0, 1.0);
            let w = rng.vec_uniform(p.w_len(), -0.5, 0.5);
            let xq_p = QuantParams::from_range(-1.0, 1.0);
            let wq_p = QuantParams::from_range(-0.5, 0.5);
            let qx = xq_p.quantize_slice(&x);
            let qw = wq_p.quantize_slice(&w);
            // Reference uses the *dequantized* tensors so the comparison
            // isolates accumulation correctness from quantization error.
            let x_deq: Vec<f32> = qx.iter().map(|&q| xq_p.dequantize(q as i32)).collect();
            let w_deq: Vec<f32> = qw.iter().map(|&q| wq_p.dequantize(q as i32)).collect();
            let want = conv1d_direct(&x_deq, &w_deq, None, &p);
            let got = conv1d_quantized(&qx, &qw, xq_p, wq_p, &p);
            assert_eq!(got.len(), want.len());
            for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                assert!(
                    (a - b).abs() < 1e-3 * (1.0 + b.abs()),
                    "({c_in},{c_out},{n},{k},{d}) idx {i}: {a} vs {b}"
                );
            }
        }
    }

    /// Full-generality shapes (stride, dilation, padding, batch, bias,
    /// epilogue): the `_into` form against the dequantized f32 sliding
    /// reference, exact up to f32 rounding of the final rescale.
    #[test]
    fn quantized_into_full_params_tracks_dequantized_reference() {
        let mut rng = Rng::new(0x0_B);
        let shapes = [
            Conv1dParams::new(1, 1, 120, 5).with_pad(2),
            Conv1dParams::new(2, 3, 90, 3).with_stride(2).with_pad(1).with_batch(2),
            Conv1dParams::new(2, 2, 100, 5).with_dilation(3).with_same_pad(),
            Conv1dParams::new(3, 2, 64, 7).with_stride(3).with_dilation(2).with_pad(4),
        ];
        for p in shapes {
            let x = rng.vec_uniform(p.x_len(), -1.0, 1.0);
            let w = rng.vec_uniform(p.w_len(), -0.5, 0.5);
            let b = rng.vec_uniform(p.c_out, -0.25, 0.25);
            let xq_p = QuantParams::from_range(-1.0, 1.0);
            let wq_p = QuantParams::from_range(-0.5, 0.5);
            let qx = xq_p.quantize_slice(&x);
            let qw = wq_p.quantize_slice(&w);
            let x_deq: Vec<f32> = qx.iter().map(|&q| xq_p.dequantize(q as i32)).collect();
            let w_deq: Vec<f32> = qw.iter().map(|&q| wq_p.dequantize(q as i32)).collect();
            let mut want = conv1d_sliding(&x_deq, &w_deq, Some(&b), &p);
            for v in want.iter_mut() {
                if *v < 0.0 {
                    *v = 0.0;
                }
            }
            let mut acc = vec![-7i32; quantized_scratch_len(&p)];
            let mut got = vec![777.75f32; p.y_len()];
            conv1d_quantized_into(
                &qx,
                &qw,
                xq_p,
                wq_p,
                Some(&b),
                &p,
                Epilogue::Relu,
                &mut acc,
                &mut got,
            );
            for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                assert!(
                    (a - b).abs() < 1e-3 * (1.0 + b.abs()),
                    "{p:?} idx {i}: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn end_to_end_quantization_error_small() {
        // Against the true f32 conv, error is bounded by the quant grid.
        let mut rng = Rng::new(0x0_9);
        let p = Conv1dParams::new(1, 1, 500, 7);
        let x = rng.vec_uniform(p.x_len(), -1.0, 1.0);
        let w = rng.vec_uniform(p.w_len(), -0.5, 0.5);
        let xq_p = QuantParams::from_range(-1.0, 1.0);
        let wq_p = QuantParams::from_range(-0.5, 0.5);
        let got = conv1d_quantized(&xq_p.quantize_slice(&x), &wq_p.quantize_slice(&w), xq_p, wq_p, &p);
        let want = conv1d_direct(&x, &w, None, &p);
        let mut worst = 0.0f32;
        for (a, b) in got.iter().zip(&want) {
            worst = worst.max((a - b).abs());
        }
        // 7 taps × per-product grid error — generous bound.
        assert!(worst < 0.05, "quantization error {worst}");
    }

    #[test]
    fn empty_output_ok() {
        let p = Conv1dParams::new(1, 1, 3, 5);
        assert!(conv1d_quantized(&[0i8; 3], &[0i8; 5], QuantParams::from_range(-1.0, 1.0), QuantParams::from_range(-1.0, 1.0), &p).is_empty());
    }
}
