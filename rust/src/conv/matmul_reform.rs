//! Matmul reformulation (paper §5, final future-work item): "since the
//! accelerators for matrix multiplication are already present in the
//! current generation of hardware, it would be wise re-using them. Thus,
//! it is important to re-formulate our algorithms in terms of the small
//! matrix multiplication completing the circle."
//!
//! The reformulation (and what the L1 Pallas kernel does on the MXU):
//! multi-channel convolution is evaluated as **k small GEMMs over the
//! unmodified input** — one `(c_out × c_in) · (c_in × n_out)` product
//! per tap, each reading a *shifted view* of the input tensor:
//!
//! ```text
//! Y[co, t] = Σ_tap  W[:, :, tap] @ X[:, t + tap·d]
//! ```
//!
//! This keeps GEMM's arithmetic density (the accelerator-friendly
//! shape) while preserving the sliding property — no im2col matrix is
//! ever materialized. The per-tap products reuse the blocked microkernel
//! from [`crate::gemm`].

use crate::gemm;

use super::Conv1dParams;

/// Convolution as k tap-GEMMs on shifted input views (stride 1 path;
/// strided shapes fall back to the caller's generic backend).
///
/// Requires channel-major input `[b, c_in, n]` like every other backend;
/// per tap we hand GEMM the submatrix `X[:, off .. off+n_out]`, which is
/// a *strided* view — so we repack rows once per tap into a contiguous
/// panel (cost `c_in·n_out` copies per tap, amortized by the
/// `c_out·c_in·n_out` FMAs when channels are non-trivial).
pub fn conv1d_tap_gemm(
    x: &[f32],
    w: &[f32],
    bias: Option<&[f32]>,
    p: &Conv1dParams,
) -> Option<Vec<f32>> {
    if p.stride != 1 {
        return None;
    }
    p.validate(x, w, bias);
    let n_out = p.n_out();
    // alloc-ok: Vec-returning reformulation study path, not on the plan
    // run path (the planner never selects tap-GEMM).
    let mut y = vec![0.0f32; p.y_len()];
    if n_out == 0 {
        return Some(y);
    }
    let padded_n = p.n + 2 * p.pad;
    let mut xpad = vec![0.0f32; p.c_in * padded_n]; // alloc-ok: study path
    let mut panel = vec![0.0f32; p.c_in * n_out]; // alloc-ok: study path
    // Per-tap filter matrix W_tap[c_out, c_in], gathered once.
    let mut w_tap = vec![0.0f32; p.c_out * p.c_in]; // alloc-ok: study path

    for b in 0..p.batch {
        // Pad the batch element once (channel-major).
        for ci in 0..p.c_in {
            let src = &x[(b * p.c_in + ci) * p.n..][..p.n];
            let dst = &mut xpad[ci * padded_n..][..padded_n];
            dst[..p.pad].fill(0.0);
            dst[p.pad..p.pad + p.n].copy_from_slice(src);
            dst[p.pad + p.n..].fill(0.0);
        }
        let yb = &mut y[b * p.c_out * n_out..][..p.c_out * n_out];
        if let Some(bv) = bias {
            for co in 0..p.c_out {
                yb[co * n_out..(co + 1) * n_out].fill(bv[co]);
            }
        }
        for tap in 0..p.k {
            let off = tap * p.dilation;
            // Pack the shifted view into a contiguous (c_in × n_out) panel.
            for ci in 0..p.c_in {
                panel[ci * n_out..(ci + 1) * n_out]
                    .copy_from_slice(&xpad[ci * padded_n + off..][..n_out]);
            }
            // Gather W[:, :, tap].
            for co in 0..p.c_out {
                for ci in 0..p.c_in {
                    w_tap[co * p.c_in + ci] = w[(co * p.c_in + ci) * p.k + tap];
                }
            }
            // Y += W_tap · panel  — the small matmul per tap.
            gemm::gemm(p.c_out, p.c_in, n_out, &w_tap, &panel, yb);
        }
    }
    Some(y)
}

#[cfg(test)]
mod tests {
    use super::super::conv1d_direct;
    use super::*;
    use crate::workload::Rng;

    fn check(p: &Conv1dParams, with_bias: bool) {
        let mut rng = Rng::new(0x7a9 ^ (p.k as u64));
        let x = rng.vec_uniform(p.x_len(), -1.0, 1.0);
        let w = rng.vec_uniform(p.w_len(), -1.0, 1.0);
        let b = rng.vec_uniform(p.c_out, -0.5, 0.5);
        let bias = with_bias.then_some(b.as_slice());
        let got = conv1d_tap_gemm(&x, &w, bias, p).expect("stride-1 qualifies");
        let want = conv1d_direct(&x, &w, bias, p);
        assert_eq!(got.len(), want.len());
        for (i, (a, c)) in got.iter().zip(&want).enumerate() {
            assert!(
                (a - c).abs() <= 1e-3 * (1.0 + c.abs()),
                "{p:?} idx {i}: {a} vs {c}"
            );
        }
    }

    #[test]
    fn matches_direct_multichannel() {
        check(&Conv1dParams::new(4, 8, 64, 3), false);
        check(&Conv1dParams::new(8, 16, 50, 5).with_same_pad(), true);
        check(&Conv1dParams::new(3, 3, 40, 7).with_dilation(2).with_pad(6), true);
        check(&Conv1dParams::new(2, 2, 33, 3).with_batch(3), false);
    }

    #[test]
    fn strided_falls_back() {
        let p = Conv1dParams::new(1, 1, 32, 3).with_stride(2);
        assert!(conv1d_tap_gemm(&[0.0; 32], &[0.0; 3], None, &p).is_none());
    }
}
