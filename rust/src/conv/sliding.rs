//! Sliding-window convolution — the paper's contribution (§2.4–2.5, §3).
//!
//! Two realizations of the same math:
//!
//! * [`conv1d_sliding`] — the production hot path. This is Algorithm 4's
//!   schedule specialized to the FMA operator: for every tap `k`, the
//!   *whole output row* accumulates `w[k] · x[t·s + k·d]` in one
//!   vectorizable sweep (a broadcast multiply of a slid input view).
//!   The input is read in its original layout — no im2col matrix, no
//!   copy; exactly `k` passes of unit-stride loads. Arithmetic intensity
//!   per load matches the GEMM microkernel, but the k× memory expansion
//!   and its cache misses are gone — this is where the Fig 1 speedup
//!   comes from.
//! * [`conv1d_pair`] — the literal Eq. 7–9 construction: encode (filter,
//!   window) pairs γᵢ = (αᵢ₋₁/αᵢ, βᵢ) and sliding-prefix-scan them with
//!   the non-commutative [`ConvPair`] operator. Kept as the faithful
//!   paper formulation and exercised by tests/benches; the broadcast-FMA
//!   schedule is algebraically the same scan with the ratio chain
//!   pre-multiplied out.
//!
//! [`ConvPair`]: crate::ops::ConvPair

use crate::exec::{Executor, PAR_MIN_FANOUT};
use crate::ops::{AssocOp, ConvPair, Epilogue, Pair};

use super::Conv1dParams;

/// Sliding-window convolution, broadcast-FMA schedule (Algorithm 4),
/// data-parallel over the shared worker pool ([`Executor::global`]).
///
/// Layout `[b, c_in, n] ⊛ [c_out, c_in, k] → [b, c_out, n_out]`.
/// Stride 1 runs the slid-accumulate over the full row; stride > 1
/// accumulates into the strided output gather (still one pass per tap).
pub fn conv1d_sliding(x: &[f32], w: &[f32], bias: Option<&[f32]>, p: &Conv1dParams) -> Vec<f32> {
    conv1d_sliding_with(Executor::global(), x, w, bias, p)
}

/// [`conv1d_sliding`] writing into a caller-provided buffer of length
/// [`Conv1dParams::y_len`] (zero allocation on the hot path). Every
/// output element is overwritten — the buffer may hold stale data. The
/// [`Epilogue`] is fused into each output span's final write (applied
/// per row segment right after its taps accumulate), bit-identical to
/// running the same element-wise tail as a separate pass.
pub fn conv1d_sliding_into(
    x: &[f32],
    w: &[f32],
    bias: Option<&[f32]>,
    p: &Conv1dParams,
    epi: Epilogue<'_>,
    y: &mut [f32],
) {
    conv1d_sliding_with_into(Executor::global(), x, w, bias, p, epi, y)
}

/// Minimum output-column segment when splitting inside a row.
const PAR_MIN_SEG: usize = 8192;

/// How many column segments to cut each output row into: 1 unless the
/// row count alone cannot feed the pool (the Fig-1 shape is a single
/// `batch=1, c_out=1` row over 1M columns).
fn column_segments(ex: &Executor, rows: usize, n_out: usize) -> usize {
    let target = ex.threads() * 4;
    if ex.threads() <= 1 || rows >= target || n_out < 2 * PAR_MIN_SEG {
        1
    } else {
        target.div_ceil(rows).min(n_out.div_ceil(PAR_MIN_SEG)).max(1)
    }
}

/// [`conv1d_sliding`] on an explicit executor (thread-scaling benches and
/// parity tests).
pub fn conv1d_sliding_with(
    ex: &Executor,
    x: &[f32],
    w: &[f32],
    bias: Option<&[f32]>,
    p: &Conv1dParams,
) -> Vec<f32> {
    // alloc-ok: Vec-returning wrapper; conv1d_sliding_with_into is the hot path.
    let mut y = vec![0.0f32; p.y_len()];
    conv1d_sliding_with_into(ex, x, w, bias, p, Epilogue::None, &mut y);
    y
}

/// The core kernel: explicit executor *and* caller-provided destination.
/// Work is partitioned over `(batch × c_out)` output rows and, when rows
/// are scarce, over output-column segments within a row — each worker
/// writes a disjoint `&mut` sub-slice of `y` directly. Each output
/// element accumulates its taps in exactly the serial order, so results
/// are **bit-identical** to the serial path for every partitioning (and
/// therefore for every thread count).
pub fn conv1d_sliding_with_into(
    ex: &Executor,
    x: &[f32],
    w: &[f32],
    bias: Option<&[f32]>,
    p: &Conv1dParams,
    epi: Epilogue<'_>,
    y: &mut [f32],
) {
    p.validate(x, w, bias);
    assert_eq!(y.len(), p.y_len(), "dst length");
    epi.check_len(y.len());
    crate::check::poison(y);
    let n_out = p.n_out();
    if n_out == 0 {
        return;
    }
    let rows = p.batch * p.c_out;
    if rows == 0 {
        return;
    }
    let segs = column_segments(ex, rows, n_out);
    if ex.threads() <= 1 || (segs == 1 && (rows == 1 || rows * n_out < PAR_MIN_FANOUT)) {
        for (r, yrow) in y.chunks_mut(n_out).enumerate() {
            compute_row_segment(yrow, 0, r, x, w, bias, p, epi);
        }
        crate::check::assert_no_poison(y, "conv1d_sliding_with_into");
        return;
    }
    let seg_len = n_out.div_ceil(segs);
    // alloc-ok: one job closure per row segment (fan-out setup, O(rows·segs)).
    let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(rows * segs);
    for (r, yrow) in y.chunks_mut(n_out).enumerate() {
        for (si, yseg) in yrow.chunks_mut(seg_len).enumerate() {
            let t0 = si * seg_len;
            // alloc-ok: job closure box, amortized over a whole segment.
            jobs.push(Box::new(move || {
                compute_row_segment(yseg, t0, r, x, w, bias, p, epi);
            }));
        }
    }
    ex.scope(jobs);
    crate::check::assert_no_poison(y, "conv1d_sliding_with_into");
}

/// Compute output columns `[t0, t0 + yseg.len())` of conv output
/// channel `co` for **one batch element** whose input channels live in
/// `src` as `c_in` consecutive rows of pitch `src_len`, each holding
/// conceptual input positions `[src0, src0 + src_len)` of the full
/// length-`p.n` row. With `src0 = 0` and `src_len = p.n` this is
/// exactly the unfused kernel's per-row-segment body; a non-zero `src0`
/// lets the execution plan's fused-chain step feed the *same* code from
/// a small ring buffer holding only the tile + halo window of the
/// input. Same bias seed, same ascending tap order, same epilogue
/// application — **bit-identical** to the unfused kernel for every
/// partitioning and every buffering.
///
/// Contract: `src` must cover the conceptual range
/// `[max(0, t0·s − pad), min(n, (t1−1)·s − pad + eff_k))` for
/// `t1 = t0 + yseg.len()`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn conv1d_sliding_row_tile_into(
    yseg: &mut [f32],
    t0: usize,
    co: usize,
    src: &[f32],
    src0: usize,
    src_len: usize,
    w: &[f32],
    bias: Option<&[f32]>,
    p: &Conv1dParams,
    epi: Epilogue<'_>,
    epi_flat: usize,
) {
    // Seed with bias (or zero) unconditionally: the destination may be a
    // recycled buffer holding stale values.
    yseg.fill(bias.map_or(0.0, |bv| bv[co]));
    for ci in 0..p.c_in {
        let xrow = &src[ci * src_len..][..src_len];
        let wrow = &w[(co * p.c_in + ci) * p.k..][..p.k];
        accumulate_row_segment(yseg, t0, xrow, src0, wrow, p);
    }
    epi.apply(yseg, epi_flat);
}

/// Compute output columns `[t0, t0 + yseg.len())` of flat output row
/// `row = b·c_out + co` — the per-task body of both the serial loop and
/// the parallel fan-out. The epilogue runs once the segment's taps have
/// all accumulated, while the segment is still cache-resident.
#[allow(clippy::too_many_arguments)]
fn compute_row_segment(
    yseg: &mut [f32],
    t0: usize,
    row: usize,
    x: &[f32],
    w: &[f32],
    bias: Option<&[f32]>,
    p: &Conv1dParams,
    epi: Epilogue<'_>,
) {
    let b = row / p.c_out;
    let co = row % p.c_out;
    let src = &x[b * p.c_in * p.n..][..p.c_in * p.n];
    conv1d_sliding_row_tile_into(
        yseg,
        t0,
        co,
        src,
        0,
        p.n,
        w,
        bias,
        p,
        epi,
        row * p.n_out() + t0,
    );
}

/// Accumulate one channel's taps into global output range
/// `[t0, t0 + yseg.len())`: unit fast path when stride 1 / no pad,
/// interior/edge split when padded, clipped per-tap loop otherwise.
/// `xrow` holds conceptual input positions `[x0, x0 + xrow.len())` of
/// the full length-`p.n` channel row (`x0 = 0` for a fully materialized
/// row); the clipping math runs on conceptual indices, so partial and
/// full source rows take identical per-element paths.
fn accumulate_row_segment(
    yseg: &mut [f32],
    t0: usize,
    xrow: &[f32],
    x0: usize,
    wrow: &[f32],
    p: &Conv1dParams,
) {
    let t1 = t0 + yseg.len();
    if p.stride == 1 && p.pad == 0 {
        accumulate_taps_unit(yseg, &xrow[t0 - x0..], wrow, p.dilation);
        return;
    }
    if p.stride == 1 {
        let k = wrow.len();
        let n = p.n;
        // Interior: 0 ≤ t + tap·d − pad < n for all taps ⇔
        // t ∈ [pad, n − (k−1)·d + pad), intersected with this segment.
        let lo = p.pad.clamp(t0, t1);
        let hi = (n + p.pad).saturating_sub((k - 1) * p.dilation).clamp(t0, t1);
        if lo < hi {
            let interior = &mut yseg[lo - t0..hi - t0];
            accumulate_taps_unit(interior, &xrow[lo - p.pad - x0..], wrow, p.dilation);
            edge_taps(yseg, t0, xrow, x0, wrow, p, t0, lo);
            edge_taps(yseg, t0, xrow, x0, wrow, p, hi, t1);
            return;
        }
    }
    edge_taps(yseg, t0, xrow, x0, wrow, p, t0, t1);
}

/// Hot loop, stride 1 / no pad: for each tap, `y[t] += w_k · x[t + k·d]`
/// over the whole row — a unit-stride slid view, zero shuffles. This is
/// `Slide(Y, Y1, P−k)` with the slide amount absorbed into the load
/// address (the "memory slide" available to CPUs that the in-register
/// formulation emulates).
///
/// Unit dilation on a fused-FMA SIMD tier (AVX2+FMA / NEON) takes the
/// explicit-intrinsics path; everything else runs the generic code.
/// Both fold each output's taps in ascending order with one fused
/// multiply-add per tap, so the paths are bit-identical.
#[inline]
fn accumulate_taps_unit(yrow: &mut [f32], xrow: &[f32], wrow: &[f32], dilation: usize) {
    if dilation == 1 && crate::simd::tier().has_fused_fma() {
        accumulate_taps_unit_simd(yrow, xrow, wrow);
        return;
    }
    accumulate_taps_unit_generic(yrow, xrow, wrow, dilation);
}

/// Fused-SIMD realization of the unit-stride hot loop: same 4096-element
/// output block (y tile stays L1-resident across all taps), taps grouped
/// ×4 through [`crate::simd::fma_tap4_f32`] and singly through
/// [`crate::simd::fma_tap1_f32`]. Tap grouping never changes the
/// per-output accumulation chain, so any grouping is bit-identical to
/// the generic 8/4/1 unroll.
fn accumulate_taps_unit_simd(yrow: &mut [f32], xrow: &[f32], wrow: &[f32]) {
    const BLOCK: usize = 4096;
    let n_out = yrow.len();
    let k = wrow.len();
    let mut t0 = 0;
    while t0 < n_out {
        let bl = BLOCK.min(n_out - t0);
        let yb = &mut yrow[t0..t0 + bl];
        let mut tap = 0;
        while tap + 4 <= k {
            let base = t0 + tap;
            crate::simd::fma_tap4_f32(
                yb,
                &xrow[base..base + bl + 3],
                [wrow[tap], wrow[tap + 1], wrow[tap + 2], wrow[tap + 3]],
            );
            tap += 4;
        }
        while tap < k {
            let base = t0 + tap;
            crate::simd::fma_tap1_f32(yb, &xrow[base..base + bl], wrow[tap]);
            tap += 1;
        }
        t0 += bl;
    }
}

/// Portable fallback (and the SIMD parity oracle): blocked, taps
/// unrolled ×8/×4 so each loaded x lane feeds multiple FMAs.
fn accumulate_taps_unit_generic(yrow: &mut [f32], xrow: &[f32], wrow: &[f32], dilation: usize) {
    // Cache-block the output so the y tile stays L1-resident across all
    // k taps (one y stream instead of k — §Perf: 3.2 → 9+ Gmac/s at
    // k=63), and unroll taps ×4 so each loaded x lane feeds 4 FMAs.
    const BLOCK: usize = 4096;
    let n_out = yrow.len();
    let k = wrow.len();
    let mut t0 = 0;
    while t0 < n_out {
        let bl = BLOCK.min(n_out - t0);
        let yb = &mut yrow[t0..t0 + bl];
        let mut tap = 0;
        while tap + 8 <= k {
            let (w0, w1, w2, w3) = (wrow[tap], wrow[tap + 1], wrow[tap + 2], wrow[tap + 3]);
            let (w4, w5, w6, w7) = (wrow[tap + 4], wrow[tap + 5], wrow[tap + 6], wrow[tap + 7]);
            let base = t0 + tap * dilation;
            if dilation == 1 {
                let xs = &xrow[base..base + bl + 7];
                for t in 0..bl {
                    let acc = w0.mul_add(xs[t], yb[t]);
                    let acc = w1.mul_add(xs[t + 1], acc);
                    let acc = w2.mul_add(xs[t + 2], acc);
                    let acc = w3.mul_add(xs[t + 3], acc);
                    let acc = w4.mul_add(xs[t + 4], acc);
                    let acc = w5.mul_add(xs[t + 5], acc);
                    let acc = w6.mul_add(xs[t + 6], acc);
                    yb[t] = w7.mul_add(xs[t + 7], acc);
                }
                tap += 8;
                continue;
            }
            // dilated: fall through to the 4-tap path below
            break;
        }
        while tap + 4 <= k {
            let (w0, w1, w2, w3) = (wrow[tap], wrow[tap + 1], wrow[tap + 2], wrow[tap + 3]);
            let base = t0 + tap * dilation;
            if dilation == 1 {
                // Contiguous taps: one load region, 4 shifted views.
                let xs = &xrow[base..base + bl + 3];
                for t in 0..bl {
                    let acc = w0.mul_add(xs[t], yb[t]);
                    let acc = w1.mul_add(xs[t + 1], acc);
                    let acc = w2.mul_add(xs[t + 2], acc);
                    yb[t] = w3.mul_add(xs[t + 3], acc);
                }
            } else {
                let x0 = &xrow[base..base + bl];
                let x1 = &xrow[base + dilation..base + dilation + bl];
                let x2 = &xrow[base + 2 * dilation..base + 2 * dilation + bl];
                let x3 = &xrow[base + 3 * dilation..base + 3 * dilation + bl];
                for t in 0..bl {
                    let acc = w0.mul_add(x0[t], yb[t]);
                    let acc = w1.mul_add(x1[t], acc);
                    let acc = w2.mul_add(x2[t], acc);
                    yb[t] = w3.mul_add(x3[t], acc);
                }
            }
            tap += 4;
        }
        while tap < k {
            let wk = wrow[tap];
            let off = t0 + tap * dilation;
            let xs = &xrow[off..off + bl];
            for t in 0..bl {
                yb[t] = wk.mul_add(xs[t], yb[t]);
            }
            tap += 1;
        }
        t0 += bl;
    }
}

/// Clipped per-tap accumulation restricted to the *global* output range
/// `[r_lo, r_hi)`; `yseg[0]` holds global output index `seg_off` and
/// `xrow[0]` holds conceptual input index `x0`. The per-output tap
/// order is identical to the fast path, so edge columns and interior
/// columns compose bit-identically however the row is cut.
#[allow(clippy::too_many_arguments)]
fn edge_taps(
    yseg: &mut [f32],
    seg_off: usize,
    xrow: &[f32],
    x0: usize,
    wrow: &[f32],
    p: &Conv1dParams,
    r_lo: usize,
    r_hi: usize,
) {
    if r_lo >= r_hi {
        return;
    }
    let n = p.n;
    for (tap, &wk) in wrow.iter().enumerate() {
        // x index for output t: t·stride + tap·dilation − pad ∈ [0, n)
        let base = tap as isize * p.dilation as isize - p.pad as isize;
        // t range with valid x index:
        //   0 ≤ t·s + base < n  →  t ≥ ceil(−base/s), t < ceil((n−base)/s)
        let t_lo = if base >= 0 {
            0usize
        } else {
            ((-base) as usize).div_ceil(p.stride)
        }
        .max(r_lo);
        let t_hi_excl = if (n as isize) <= base {
            0usize
        } else {
            (((n as isize - base) as usize).div_ceil(p.stride)).min(r_hi)
        };
        if t_lo >= t_hi_excl {
            continue;
        }
        if p.stride == 1 {
            // Unit stride: express the tap as two aligned subslices so the
            // loop auto-vectorizes (a runtime-stride induction variable
            // blocks LLVM's vectorizer and costs ~25× — see §Perf log).
            let len = t_hi_excl - t_lo;
            let x_off = (t_lo as isize + base) as usize - x0;
            let ys = &mut yseg[t_lo - seg_off..t_hi_excl - seg_off];
            let xs = &xrow[x_off..x_off + len];
            for (y, &xv) in ys.iter_mut().zip(xs) {
                *y = wk.mul_add(xv, *y);
            }
        } else {
            let mut xi = (t_lo as isize * p.stride as isize + base) as usize - x0;
            for t in t_lo..t_hi_excl {
                let yv = &mut yseg[t - seg_off];
                *yv = wk.mul_add(xrow[xi], *yv);
                xi += p.stride;
            }
        }
    }
}

/// Literal paper formulation: every output is the Eq. 7–9 γ-pair prefix
/// sum, evaluated *simultaneously for all windows* with the Algorithm-4
/// fold. At fold step `j` the whole output row combines the pair
/// `γⱼ = (αⱼ₋₁/αⱼ, βⱼ(x_{t+j}))` on the right — the tap index `j` is
/// uniform across lanes, so the filter-dependent `u`-chain is injected at
/// the slide step exactly as Algorithm 4 injects its slid views. A final
/// combine with the closing pair `(α_{M-1}, 0)` (Eq. 7, `i = M`)
/// normalizes the ratio chain and leaves the dot product in `v`.
///
/// [`conv1d_pair_tree`] evaluates the same fold with pairwise (log-depth)
/// chunk merging — the "reduce algorithm in log(M) parallel steps" of
/// §2.4. Dilation runs `d` interleaved phases over decimated sequences
/// (the decomposition [4] uses); stride decimates the output lanes.
pub fn conv1d_pair(x: &[f32], w: &[f32], bias: Option<&[f32]>, p: &Conv1dParams) -> Vec<f32> {
    conv1d_pair_impl(x, w, bias, p, false)
}

/// Log-depth (tree) evaluation of the γ-pair formulation. Same contract
/// as [`conv1d_pair`]; combine depth `⌈log₂ k⌉` per lane instead of `k`
/// (paper: speedup `O(P/log w)` for associative `⊕`).
pub fn conv1d_pair_tree(x: &[f32], w: &[f32], bias: Option<&[f32]>, p: &Conv1dParams) -> Vec<f32> {
    conv1d_pair_impl(x, w, bias, p, true)
}

fn conv1d_pair_impl(
    x: &[f32],
    w: &[f32],
    bias: Option<&[f32]>,
    p: &Conv1dParams,
    tree: bool,
) -> Vec<f32> {
    p.validate(x, w, bias);
    let n_out = p.n_out();
    // alloc-ok: paper-faithful γ-pair formulation (tests/benches only;
    // the production path is the broadcast-FMA kernel above).
    let mut y = vec![0.0f32; p.y_len()];
    if n_out == 0 {
        return y;
    }
    let padded_n = p.n + 2 * p.pad;
    let mut xpad = vec![0.0f32; padded_n]; // alloc-ok: pair-path scratch

    for b in 0..p.batch {
        for co in 0..p.c_out {
            let yrow_base = (b * p.c_out + co) * n_out;
            if let Some(bv) = bias {
                y[yrow_base..yrow_base + n_out].fill(bv[co]);
            }
            for ci in 0..p.c_in {
                let xrow = &x[(b * p.c_in + ci) * p.n..][..p.n];
                xpad[..p.pad].fill(0.0);
                xpad[p.pad..p.pad + p.n].copy_from_slice(xrow);
                xpad[p.pad + p.n..].fill(0.0);
                let wrow = &w[(co * p.c_in + ci) * p.k..][..p.k];
                let (ratios, alpha_last) = gamma_ratios(wrow);

                for phase in 0..p.dilation {
                    if phase >= xpad.len() {
                        break; // padded input shorter than the dilation
                    }
                    // alloc-ok: pair-path phase decimation scratch.
                    let dec: Vec<f32> =
                        xpad[phase..].iter().step_by(p.dilation).copied().collect();
                    if dec.len() < p.k {
                        continue;
                    }
                    let lanes = dec.len() - p.k + 1; // windows in this phase
                    let sums = if tree {
                        pair_fold_tree(wrow, &ratios, &dec, lanes)
                    } else {
                        pair_fold_linear(wrow, &ratios, &dec, lanes)
                    };
                    let closing = Pair::new(alpha_last, 0.0);
                    for t in 0..n_out {
                        let pos = t * p.stride;
                        if pos % p.dilation != phase {
                            continue;
                        }
                        let di = pos / p.dilation;
                        if di < lanes {
                            y[yrow_base + t] += ConvPair.combine(sums[di], closing).v;
                        }
                    }
                }
            }
        }
    }
    y
}

/// Eq. 7 `u` chain after the Eq. 5 zero-tap patch: `ratios[j] =
/// αⱼ₋₁/αⱼ` (`ratios[0] = 1`), plus `α_{M-1}` for the closing pair.
fn gamma_ratios(w: &[f32]) -> (Vec<f32>, f32) {
    let alpha = |j: usize| if w[j] == 0.0 { 1.0 } else { w[j] };
    let mut ratios = Vec::with_capacity(w.len()); // alloc-ok: pair-path setup
    ratios.push(1.0);
    for j in 1..w.len() {
        ratios.push(alpha(j - 1) / alpha(j));
    }
    (ratios, alpha(w.len() - 1))
}

/// β after the Eq. 5 patch: 0 where the tap is 0, else the signal value.
#[inline(always)]
fn beta(wj: f32, xv: f32) -> f32 {
    if wj == 0.0 {
        0.0
    } else {
        xv
    }
}

/// Linear fold: `acc[t] ← acc[t] ⊕ γⱼ(x[t+j])` for `j = 0…k−1`.
/// One lanewise pair-combine per tap (`k` vector steps).
fn pair_fold_linear(w: &[f32], ratios: &[f32], dec: &[f32], lanes: usize) -> Vec<Pair> {
    let op = ConvPair;
    let mut acc = vec![op.identity(); lanes]; // alloc-ok: pair-path scratch
    for (j, (&wj, &uj)) in w.iter().zip(ratios).enumerate() {
        let xs = &dec[j..j + lanes];
        for t in 0..lanes {
            acc[t] = op.combine(acc[t], Pair::new(uj, beta(wj, xs[t])));
        }
    }
    acc
}

/// Log-depth fold: leaves `γⱼ` are merged pairwise with a size-balanced
/// stack (pairwise-summation shape), giving `⌈log₂ k⌉` combine depth and
/// `O(log k · lanes)` scratch instead of `k` sequential dependencies.
fn pair_fold_tree(w: &[f32], ratios: &[f32], dec: &[f32], lanes: usize) -> Vec<Pair> {
    let op = ConvPair;
    // Stack of (chunk_size, folded array); merge equal sizes eagerly —
    // the binary-counter pairwise reduction.
    // alloc-ok: pair-path scratch (tests/benches only).
    let mut stack: Vec<(usize, Vec<Pair>)> = Vec::new();
    for (j, (&wj, &uj)) in w.iter().zip(ratios).enumerate() {
        let xs = &dec[j..j + lanes];
        let mut leaf = Vec::with_capacity(lanes); // alloc-ok: pair-path scratch
        for t in 0..lanes {
            leaf.push(Pair::new(uj, beta(wj, xs[t])));
        }
        let mut cur = (1usize, leaf);
        while let Some(top) = stack.last() {
            if top.0 != cur.0 {
                break;
            }
            let (sz, left) = stack.pop().unwrap();
            // left chunk covers earlier taps → left operand.
            let mut merged = left;
            for t in 0..lanes {
                merged[t] = op.combine(merged[t], cur.1[t]);
            }
            cur = (sz * 2, merged);
        }
        stack.push(cur);
    }
    // Drain remaining (unequal) chunks left-to-right.
    let mut iter = stack.into_iter();
    let (_, mut acc) = iter.next().expect("k >= 1");
    for (_, chunk) in iter {
        for t in 0..lanes {
            acc[t] = ConvPair.combine(acc[t], chunk[t]);
        }
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::super::conv1d_direct;
    use super::*;

    fn fill(buf: &mut [f32], seed: &mut u64) {
        for v in buf.iter_mut() {
            *seed ^= *seed << 13;
            *seed ^= *seed >> 7;
            *seed ^= *seed << 17;
            *v = ((*seed >> 40) as f32 / (1u64 << 24) as f32) * 2.0 - 1.0;
        }
    }

    fn check_backend(p: &Conv1dParams, with_bias: bool, pair: bool, tol: f32) {
        let mut seed = 0xabcd1234u64 ^ ((p.n * 31 + p.k * 7 + p.dilation) as u64);
        let mut x = vec![0.0f32; p.x_len()];
        let mut w = vec![0.0f32; p.w_len()];
        let mut b = vec![0.0f32; p.c_out];
        fill(&mut x, &mut seed);
        fill(&mut w, &mut seed);
        fill(&mut b, &mut seed);
        let bias = with_bias.then_some(b.as_slice());
        let got = if pair {
            conv1d_pair(&x, &w, bias, p)
        } else {
            conv1d_sliding(&x, &w, bias, p)
        };
        let want = conv1d_direct(&x, &w, bias, p);
        assert_eq!(got.len(), want.len(), "{p:?}");
        for (i, (g, t)) in got.iter().zip(&want).enumerate() {
            assert!(
                (g - t).abs() <= tol * (1.0 + t.abs()),
                "pair={pair} {p:?} idx {i}: {g} vs {t}"
            );
        }
    }

    #[test]
    fn sliding_matches_direct_basic() {
        for k in [1usize, 2, 3, 5, 9, 16] {
            check_backend(&Conv1dParams::new(1, 1, 100, k), false, false, 1e-4);
        }
    }

    #[test]
    fn sliding_matches_direct_channels_batch() {
        check_backend(&Conv1dParams::new(3, 5, 40, 3).with_batch(2), true, false, 1e-4);
        check_backend(&Conv1dParams::new(8, 4, 64, 7), false, false, 1e-3);
    }

    #[test]
    fn sliding_matches_direct_stride_pad_dilation() {
        check_backend(&Conv1dParams::new(1, 1, 50, 3).with_pad(2), false, false, 1e-4);
        check_backend(&Conv1dParams::new(2, 2, 50, 3).with_stride(2).with_pad(1), true, false, 1e-4);
        check_backend(&Conv1dParams::new(1, 2, 64, 5).with_dilation(4).with_same_pad(), true, false, 1e-4);
        check_backend(
            &Conv1dParams::new(2, 3, 75, 7).with_dilation(3).with_stride(2).with_pad(4),
            false,
            false,
            1e-3,
        );
    }

    #[test]
    fn pair_matches_direct_basic() {
        for k in [1usize, 2, 3, 5, 8] {
            check_backend(&Conv1dParams::new(1, 1, 60, k), false, true, 1e-2);
        }
    }

    #[test]
    fn pair_matches_direct_channels() {
        check_backend(&Conv1dParams::new(2, 2, 40, 3), true, true, 1e-2);
    }

    #[test]
    fn pair_matches_direct_dilation_phases() {
        check_backend(&Conv1dParams::new(1, 1, 60, 3).with_dilation(2), false, true, 1e-2);
        check_backend(&Conv1dParams::new(1, 1, 60, 3).with_dilation(5).with_same_pad(), false, true, 1e-2);
    }

    #[test]
    fn pair_handles_zero_taps() {
        // Filters with zeros exercise the Eq. 5 patch.
        let p = Conv1dParams::new(1, 1, 20, 4);
        let x: Vec<f32> = (0..20).map(|i| (i as f32) * 0.3 - 2.0).collect();
        let w = [0.5, 0.0, 0.0, -1.0];
        let got = conv1d_pair(&x, &w, None, &p);
        let want = conv1d_direct(&x, &w, None, &p);
        for (g, t) in got.iter().zip(&want) {
            assert!((g - t).abs() < 1e-3, "{g} vs {t}");
        }
    }

    #[test]
    fn empty_output_ok() {
        let p = Conv1dParams::new(1, 1, 3, 5);
        assert!(conv1d_sliding(&[0.0; 3], &[0.0; 5], None, &p).is_empty());
        assert!(conv1d_pair(&[0.0; 3], &[0.0; 5], None, &p).is_empty());
    }

    /// Audit for the tap-unrolled fast path: n_out straddling the 4096
    /// cache block (±1 and one extra block), every k mod 8 residue, and
    /// dilation > 1 (which demotes the 8-tap unroll to the 4-tap path).
    #[test]
    fn sliding_block_and_unroll_edges() {
        for k in 8usize..=16 {
            for &n_out in &[4095usize, 4096, 4097, 8193] {
                let p = Conv1dParams::new(1, 1, n_out + k - 1, k);
                check_backend(&p, false, false, 1e-3);
            }
        }
        for d in [2usize, 3, 5] {
            for k in [4usize, 8, 9, 12, 15] {
                let n = 4097 + (k - 1) * d;
                let p = Conv1dParams::new(1, 1, n, k).with_dilation(d);
                check_backend(&p, false, false, 1e-3);
            }
        }
    }

    /// Fused epilogues are bit-identical to the same tail run as a
    /// separate pass, for every partitioning (thread count).
    #[test]
    fn fused_epilogue_matches_separate_pass() {
        let p = Conv1dParams::new(2, 3, 9000, 5).with_batch(2).with_same_pad();
        let mut seed = 0xE91u64;
        let mut x = vec![0.0f32; p.x_len()];
        let mut w = vec![0.0f32; p.w_len()];
        let mut b = vec![0.0f32; p.c_out];
        let mut skip = vec![0.0f32; p.y_len()];
        fill(&mut x, &mut seed);
        fill(&mut w, &mut seed);
        fill(&mut b, &mut seed);
        fill(&mut skip, &mut seed);
        for threads in [1usize, 2, 4, 8] {
            let ex = Executor::new(threads);
            let mut want = conv1d_sliding_with(&ex, &x, &w, Some(&b), &p);
            for v in want.iter_mut() {
                if *v < 0.0 {
                    *v = 0.0;
                }
            }
            let mut got = vec![777.75f32; p.y_len()];
            conv1d_sliding_with_into(&ex, &x, &w, Some(&b), &p, Epilogue::Relu, &mut got);
            assert_eq!(got, want, "relu threads={threads}");

            for (v, s) in want.iter_mut().zip(&skip) {
                *v += s;
            }
            let mut got = vec![777.75f32; p.y_len()];
            conv1d_sliding_with_into(&ex, &x, &w, Some(&b), &p, Epilogue::ReluAdd(&skip), &mut got);
            assert_eq!(got, want, "relu+add threads={threads}");
        }
    }

    /// Final-block bounds with padding: the interior/edge split must stop
    /// the fast loop exactly where a tap would run past the input.
    #[test]
    fn sliding_padded_block_edges() {
        for k in [8usize, 9, 15, 16] {
            let p = Conv1dParams::new(1, 1, 4100, k).with_same_pad();
            check_backend(&p, true, false, 1e-3);
        }
        for d in [2usize, 4] {
            let p = Conv1dParams::new(1, 1, 4099, 9).with_dilation(d).with_same_pad();
            check_backend(&p, false, false, 1e-3);
        }
    }
}
