//! Nested-loop reference convolution — the oracle all backends test
//! against. Handles stride/dilation/padding/batching with no cleverness.

use super::Conv1dParams;

/// Direct `O(B·Cout·Nout·Cin·k)` convolution (cross-correlation).
pub fn conv1d_direct(x: &[f32], w: &[f32], bias: Option<&[f32]>, p: &Conv1dParams) -> Vec<f32> {
    // alloc-ok: Vec-returning oracle; conv1d_direct_into is the hot path.
    let mut y = vec![0.0f32; p.y_len()];
    conv1d_direct_into(x, w, bias, p, &mut y);
    y
}

/// [`conv1d_direct`] into a caller-provided buffer of length
/// [`Conv1dParams::y_len`]. Every element is overwritten (the buffer may
/// be recycled dirty); accumulation order is identical to the allocating
/// wrapper, so the two are bit-identical.
pub fn conv1d_direct_into(
    x: &[f32],
    w: &[f32],
    bias: Option<&[f32]>,
    p: &Conv1dParams,
    y: &mut [f32],
) {
    p.validate(x, w, bias);
    assert_eq!(y.len(), p.y_len(), "dst length");
    crate::check::poison(y);
    let n_out = p.n_out();
    for b in 0..p.batch {
        for co in 0..p.c_out {
            let bias_v = bias.map_or(0.0, |bv| bv[co]);
            for t in 0..n_out {
                let mut acc = 0.0f32;
                for ci in 0..p.c_in {
                    let xrow = &x[(b * p.c_in + ci) * p.n..][..p.n];
                    let wrow = &w[(co * p.c_in + ci) * p.k..][..p.k];
                    for tap in 0..p.k {
                        // Input index with padding offset.
                        let xi = t * p.stride + tap * p.dilation;
                        let xi = xi as isize - p.pad as isize;
                        if xi >= 0 && (xi as usize) < p.n {
                            acc += wrow[tap] * xrow[xi as usize];
                        }
                    }
                }
                y[(b * p.c_out + co) * n_out + t] = acc + bias_v;
            }
        }
    }
    crate::check::assert_no_poison(y, "conv1d_direct_into");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn k1_is_scaled_copy() {
        let p = Conv1dParams::new(1, 1, 4, 1);
        let y = conv1d_direct(&[1.0, 2.0, 3.0, 4.0], &[2.0], None, &p);
        assert_eq!(y, vec![2.0, 4.0, 6.0, 8.0]);
    }

    #[test]
    fn known_k3() {
        // x = [1,2,3,4], w = [1,0,-1]: y_t = x_t - x_{t+2}
        let p = Conv1dParams::new(1, 1, 4, 3);
        let y = conv1d_direct(&[1.0, 2.0, 3.0, 4.0], &[1.0, 0.0, -1.0], None, &p);
        assert_eq!(y, vec![-2.0, -2.0]);
    }

    #[test]
    fn padding_zeros_outside() {
        let p = Conv1dParams::new(1, 1, 3, 3).with_pad(1);
        // x=[1,1,1], w=[1,1,1] → [0+1+1, 1+1+1, 1+1+0]
        let y = conv1d_direct(&[1.0; 3], &[1.0; 3], None, &p);
        assert_eq!(y, vec![2.0, 3.0, 2.0]);
    }

    #[test]
    fn stride_skips() {
        let p = Conv1dParams::new(1, 1, 6, 2).with_stride(2);
        // windows at t=0,2,4: sums of adjacent pairs
        let y = conv1d_direct(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[1.0, 1.0], None, &p);
        assert_eq!(y, vec![3.0, 7.0, 11.0]);
    }

    #[test]
    fn dilation_spreads_taps() {
        let p = Conv1dParams::new(1, 1, 5, 2).with_dilation(3);
        // taps at offset 0 and 3: y_t = x_t + x_{t+3}, t=0,1
        let y = conv1d_direct(&[1.0, 2.0, 3.0, 4.0, 5.0], &[1.0, 1.0], None, &p);
        assert_eq!(y, vec![5.0, 7.0]);
    }

    #[test]
    fn multichannel_sums_over_cin() {
        let p = Conv1dParams::new(2, 1, 3, 1);
        // two input channels, filter picks 1·ch0 + 10·ch1
        let x = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let y = conv1d_direct(&x, &[1.0, 10.0], None, &p);
        assert_eq!(y, vec![41.0, 52.0, 63.0]);
    }

    #[test]
    fn bias_per_cout() {
        let p = Conv1dParams::new(1, 2, 3, 1);
        let y = conv1d_direct(&[1.0, 2.0, 3.0], &[1.0, 1.0], Some(&[10.0, 20.0]), &p);
        assert_eq!(y, vec![11.0, 12.0, 13.0, 21.0, 22.0, 23.0]);
    }

    #[test]
    fn batch_independent() {
        let p = Conv1dParams::new(1, 1, 3, 2).with_batch(2);
        let y = conv1d_direct(&[1.0, 2.0, 3.0, 10.0, 20.0, 30.0], &[1.0, 1.0], None, &p);
        assert_eq!(y, vec![3.0, 5.0, 30.0, 50.0]);
    }
}
