//! Convolution hyper-parameters and backend selection.

/// 1-D convolution parameters (cross-correlation convention, as in every
/// DNN framework).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Conv1dParams {
    /// Batch size.
    pub batch: usize,
    /// Input channels.
    pub c_in: usize,
    /// Output channels.
    pub c_out: usize,
    /// Input spatial length.
    pub n: usize,
    /// Filter taps per channel.
    pub k: usize,
    /// Stride ≥ 1.
    pub stride: usize,
    /// Dilation ≥ 1 (the Fig 2 scenario sweeps this).
    pub dilation: usize,
    /// Symmetric zero padding on both spatial ends.
    pub pad: usize,
}

impl Conv1dParams {
    /// Minimal constructor: unit batch/stride/dilation, no padding.
    pub fn new(c_in: usize, c_out: usize, n: usize, k: usize) -> Self {
        Self {
            batch: 1,
            c_in,
            c_out,
            n,
            k,
            stride: 1,
            dilation: 1,
            pad: 0,
        }
    }

    pub fn with_batch(mut self, b: usize) -> Self {
        self.batch = b;
        self
    }

    pub fn with_stride(mut self, s: usize) -> Self {
        assert!(s >= 1);
        self.stride = s;
        self
    }

    pub fn with_dilation(mut self, d: usize) -> Self {
        assert!(d >= 1);
        self.dilation = d;
        self
    }

    pub fn with_pad(mut self, p: usize) -> Self {
        self.pad = p;
        self
    }

    /// "Same" padding for odd effective kernels at stride 1.
    pub fn with_same_pad(mut self) -> Self {
        self.pad = (self.effective_k() - 1) / 2;
        self
    }

    /// Effective receptive field: `(k−1)·dilation + 1`.
    pub fn effective_k(&self) -> usize {
        (self.k - 1) * self.dilation + 1
    }

    /// Output spatial length.
    pub fn n_out(&self) -> usize {
        let padded = self.n + 2 * self.pad;
        let eff = self.effective_k();
        if padded < eff {
            0
        } else {
            (padded - eff) / self.stride + 1
        }
    }

    /// Input element count.
    pub fn x_len(&self) -> usize {
        self.batch * self.c_in * self.n
    }

    /// Filter element count.
    pub fn w_len(&self) -> usize {
        self.c_out * self.c_in * self.k
    }

    /// Output element count.
    pub fn y_len(&self) -> usize {
        self.batch * self.c_out * self.n_out()
    }

    /// Multiply-accumulate count (for roofline/throughput reporting).
    pub fn macs(&self) -> u64 {
        self.batch as u64 * self.c_out as u64 * self.n_out() as u64 * self.c_in as u64 * self.k as u64
    }

    pub fn validate(&self, x: &[f32], w: &[f32], bias: Option<&[f32]>) {
        assert_eq!(x.len(), self.x_len(), "input shape");
        assert_eq!(w.len(), self.w_len(), "filter shape");
        if let Some(b) = bias {
            assert_eq!(b.len(), self.c_out, "bias shape");
        }
        assert!(self.k >= 1 && self.stride >= 1 && self.dilation >= 1);
    }
}

/// Which convolution implementation executes the layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ConvBackend {
    /// Nested-loop reference.
    Direct,
    /// im2col + blocked GEMM (the paper's MlasConv-shaped baseline).
    Im2colGemm,
    /// Sliding-window broadcast-FMA kernels (the paper's contribution).
    Sliding,
    /// Literal Eq. 7–9 pair-operator prefix-sum formulation.
    SlidingPair,
}

impl ConvBackend {
    pub const ALL: [ConvBackend; 4] = [
        ConvBackend::Direct,
        ConvBackend::Im2colGemm,
        ConvBackend::Sliding,
        ConvBackend::SlidingPair,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            ConvBackend::Direct => "direct",
            ConvBackend::Im2colGemm => "im2col_gemm",
            ConvBackend::Sliding => "sliding",
            ConvBackend::SlidingPair => "sliding_pair",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Self::ALL.iter().copied().find(|b| b.name() == s)
    }
}

/// Deployment-level backend selection: either one fixed [`ConvBackend`]
/// for every layer (the pre-plan behaviour, and what the paper's tables
/// measure), or `Auto` — let the execution planner pick a kernel per
/// layer from its shape-based cost model. Per-layer `backend = "..."`
/// keys in the model TOML override either choice for that layer.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum BackendChoice {
    /// Per-layer cost-model selection at plan-compile time.
    #[default]
    Auto,
    /// Force this backend on every layer without an explicit override.
    Fixed(ConvBackend),
}

impl BackendChoice {
    pub fn name(&self) -> &'static str {
        match self {
            BackendChoice::Auto => "auto",
            BackendChoice::Fixed(b) => b.name(),
        }
    }

    /// Parse `"auto"` or any [`ConvBackend::parse`] name.
    pub fn parse(s: &str) -> Option<Self> {
        if s == "auto" {
            Some(BackendChoice::Auto)
        } else {
            ConvBackend::parse(s).map(BackendChoice::Fixed)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn out_len_basic() {
        let p = Conv1dParams::new(1, 1, 10, 3);
        assert_eq!(p.n_out(), 8);
        assert_eq!(p.effective_k(), 3);
    }

    #[test]
    fn out_len_stride_dilation_pad() {
        let p = Conv1dParams::new(1, 1, 32, 3).with_stride(2).with_dilation(4).with_pad(4);
        // effective k = 9, padded = 40 → (40-9)/2+1 = 16
        assert_eq!(p.effective_k(), 9);
        assert_eq!(p.n_out(), 16);
    }

    #[test]
    fn same_pad_preserves_length() {
        let p = Conv1dParams::new(2, 3, 100, 5).with_same_pad();
        assert_eq!(p.n_out(), 100);
        let p = Conv1dParams::new(1, 1, 64, 3).with_dilation(8).with_same_pad();
        assert_eq!(p.n_out(), 64);
    }

    #[test]
    fn too_small_input_yields_zero() {
        let p = Conv1dParams::new(1, 1, 2, 5);
        assert_eq!(p.n_out(), 0);
        assert_eq!(p.y_len(), 0);
    }

    #[test]
    fn macs_counting() {
        let p = Conv1dParams::new(2, 4, 10, 3).with_batch(2);
        assert_eq!(p.macs(), 2 * 4 * 8 * 2 * 3);
    }

    #[test]
    fn backend_name_roundtrip() {
        for b in ConvBackend::ALL {
            assert_eq!(ConvBackend::parse(b.name()), Some(b));
        }
    }

    #[test]
    fn backend_choice_parse() {
        assert_eq!(BackendChoice::parse("auto"), Some(BackendChoice::Auto));
        for b in ConvBackend::ALL {
            assert_eq!(BackendChoice::parse(b.name()), Some(BackendChoice::Fixed(b)));
        }
        assert_eq!(BackendChoice::parse("magic"), None);
        assert_eq!(BackendChoice::default(), BackendChoice::Auto);
    }
}
