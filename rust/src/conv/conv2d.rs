//! 2-D convolution — the paper's first "next step": "extending the
//! sliding convolution approach to more than one dimension covering the
//! majority of the DNN applications" (§5).
//!
//! The sliding decomposition generalizes row-wise: a `kh×kw` filter is
//! `kh` 1-D sliding convolutions (one per filter row, each over a
//! different input row band), accumulated into the output row. Every
//! inner loop is the same unit-stride slid FMA as the 1-D hot path, so
//! the im2col blow-up (`kh·kw×` memory) is avoided entirely — in 2-D the
//! expansion factor is *worse* than 1-D, which is why the paper expects
//! the approach to shine here ("the situation improves in the multiple
//! dimensions").
//!
//! Layouts: input `[b, c_in, h, w]`, filters `[c_out, c_in, kh, kw]`,
//! output `[b, c_out, h_out, w_out]`, row-major.

use crate::gemm;
use crate::ops::Epilogue;

/// 2-D convolution parameters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Conv2dParams {
    pub batch: usize,
    pub c_in: usize,
    pub c_out: usize,
    pub h: usize,
    pub w: usize,
    pub kh: usize,
    pub kw: usize,
    pub stride: usize,
    pub pad: usize,
}

impl Conv2dParams {
    pub fn new(c_in: usize, c_out: usize, h: usize, w: usize, kh: usize, kw: usize) -> Self {
        Self {
            batch: 1,
            c_in,
            c_out,
            h,
            w,
            kh,
            kw,
            stride: 1,
            pad: 0,
        }
    }

    pub fn with_batch(mut self, b: usize) -> Self {
        self.batch = b;
        self
    }

    pub fn with_stride(mut self, s: usize) -> Self {
        assert!(s >= 1);
        self.stride = s;
        self
    }

    pub fn with_pad(mut self, p: usize) -> Self {
        self.pad = p;
        self
    }

    pub fn with_same_pad(mut self) -> Self {
        assert_eq!(self.kh, self.kw, "same-pad assumes square filters");
        self.pad = (self.kh - 1) / 2;
        self
    }

    pub fn h_out(&self) -> usize {
        let padded = self.h + 2 * self.pad;
        if padded < self.kh {
            0
        } else {
            (padded - self.kh) / self.stride + 1
        }
    }

    pub fn w_out(&self) -> usize {
        let padded = self.w + 2 * self.pad;
        if padded < self.kw {
            0
        } else {
            (padded - self.kw) / self.stride + 1
        }
    }

    pub fn x_len(&self) -> usize {
        self.batch * self.c_in * self.h * self.w
    }

    pub fn w_len(&self) -> usize {
        self.c_out * self.c_in * self.kh * self.kw
    }

    pub fn y_len(&self) -> usize {
        self.batch * self.c_out * self.h_out() * self.w_out()
    }

    pub fn macs(&self) -> u64 {
        self.batch as u64
            * self.c_out as u64
            * self.h_out() as u64
            * self.w_out() as u64
            * self.c_in as u64
            * (self.kh * self.kw) as u64
    }

    fn validate(&self, x: &[f32], w: &[f32], bias: Option<&[f32]>) {
        assert_eq!(x.len(), self.x_len(), "input shape");
        assert_eq!(w.len(), self.w_len(), "filter shape");
        if let Some(b) = bias {
            assert_eq!(b.len(), self.c_out, "bias shape");
        }
    }
}

/// Direct (oracle) 2-D convolution.
pub fn conv2d_direct(x: &[f32], w: &[f32], bias: Option<&[f32]>, p: &Conv2dParams) -> Vec<f32> {
    p.validate(x, w, bias);
    let (h_out, w_out) = (p.h_out(), p.w_out());
    // alloc-ok: Vec-returning oracle, not on the plan run path.
    let mut y = vec![0.0f32; p.y_len()];
    for b in 0..p.batch {
        for co in 0..p.c_out {
            let bias_v = bias.map_or(0.0, |bv| bv[co]);
            for oy in 0..h_out {
                for ox in 0..w_out {
                    let mut acc = 0.0f32;
                    for ci in 0..p.c_in {
                        let plane = &x[((b * p.c_in + ci) * p.h) * p.w..][..p.h * p.w];
                        let filt = &w[((co * p.c_in + ci) * p.kh) * p.kw..][..p.kh * p.kw];
                        for fy in 0..p.kh {
                            let iy = (oy * p.stride + fy) as isize - p.pad as isize;
                            if iy < 0 || iy as usize >= p.h {
                                continue;
                            }
                            for fx in 0..p.kw {
                                let ix = (ox * p.stride + fx) as isize - p.pad as isize;
                                if ix < 0 || ix as usize >= p.w {
                                    continue;
                                }
                                acc += filt[fy * p.kw + fx] * plane[iy as usize * p.w + ix as usize];
                            }
                        }
                    }
                    y[((b * p.c_out + co) * h_out + oy) * w_out + ox] = acc + bias_v;
                }
            }
        }
    }
    y
}

/// Sliding 2-D convolution: per output row, `kh·kw` slid unit-stride FMA
/// passes over the unmodified input (stride 1) or clipped strided passes.
/// Parallel over `(batch × c_out)` output planes (and groups of output
/// rows within a plane) on the shared worker pool; outputs are
/// bit-identical to the serial schedule for every partitioning.
pub fn conv2d_sliding(x: &[f32], w: &[f32], bias: Option<&[f32]>, p: &Conv2dParams) -> Vec<f32> {
    conv2d_sliding_with(crate::exec::Executor::global(), x, w, bias, p)
}

/// [`conv2d_sliding`] on an explicit executor (scaling benches / parity
/// tests).
pub fn conv2d_sliding_with(
    ex: &crate::exec::Executor,
    x: &[f32],
    w: &[f32],
    bias: Option<&[f32]>,
    p: &Conv2dParams,
) -> Vec<f32> {
    // alloc-ok: Vec-returning wrapper; conv2d_sliding_with_into is the hot path.
    let mut y = vec![0.0f32; p.y_len()];
    conv2d_sliding_with_into(ex, x, w, bias, p, Epilogue::None, &mut y);
    y
}

/// [`conv2d_sliding`] writing into a caller-provided buffer of length
/// [`Conv2dParams::y_len`]. Every output element is overwritten, so the
/// buffer may hold stale data from a previous request. The [`Epilogue`]
/// is fused into each plane-row group's final write.
pub fn conv2d_sliding_into(
    x: &[f32],
    w: &[f32],
    bias: Option<&[f32]>,
    p: &Conv2dParams,
    epi: Epilogue<'_>,
    y: &mut [f32],
) {
    conv2d_sliding_with_into(crate::exec::Executor::global(), x, w, bias, p, epi, y)
}

/// The core kernel: explicit executor and caller-provided destination;
/// workers write disjoint `&mut` row groups of `y` directly.
pub fn conv2d_sliding_with_into(
    ex: &crate::exec::Executor,
    x: &[f32],
    w: &[f32],
    bias: Option<&[f32]>,
    p: &Conv2dParams,
    epi: Epilogue<'_>,
    y: &mut [f32],
) {
    p.validate(x, w, bias);
    assert_eq!(y.len(), p.y_len(), "dst length");
    epi.check_len(y.len());
    crate::check::poison(y);
    let (h_out, w_out) = (p.h_out(), p.w_out());
    if h_out == 0 || w_out == 0 {
        return;
    }
    let planes = p.batch * p.c_out;
    let plane_len = h_out * w_out;
    // Tiny problems: the boxed-job + latch overhead beats the work, so
    // run the per-plane body directly on the caller.
    if ex.threads() <= 1 || planes * plane_len < crate::exec::PAR_MIN_FANOUT {
        for (plane_idx, yplane) in y.chunks_mut(plane_len).enumerate() {
            conv2d_plane_rows(yplane, plane_idx, 0, x, w, bias, p, epi);
        }
        crate::check::assert_no_poison(y, "conv2d_sliding_with_into");
        return;
    }
    // Group output rows so the pool sees ~4 tasks per thread even when
    // there are few planes.
    let group_rows = h_out.div_ceil((ex.threads() * 4).div_ceil(planes)).max(1);
    // alloc-ok: one job closure per plane-row group (fan-out setup).
    let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
    for (plane_idx, yplane) in y.chunks_mut(plane_len).enumerate() {
        for (gi, yrows) in yplane.chunks_mut(group_rows * w_out).enumerate() {
            let oy0 = gi * group_rows;
            // alloc-ok: job closure box, amortized over a whole row group.
            jobs.push(Box::new(move || {
                conv2d_plane_rows(yrows, plane_idx, oy0, x, w, bias, p, epi);
            }));
        }
    }
    ex.scope(jobs);
    crate::check::assert_no_poison(y, "conv2d_sliding_with_into");
}

/// Compute output rows `[oy0, oy0 + yrows.len()/w_out)` of one
/// `(b, c_out)` plane — the per-task body of the fan-out above. The
/// epilogue runs after the group's accumulation, offset by the group's
/// flat position in the full output.
#[allow(clippy::too_many_arguments)]
fn conv2d_plane_rows(
    yrows: &mut [f32],
    plane_idx: usize,
    oy0: usize,
    x: &[f32],
    w: &[f32],
    bias: Option<&[f32]>,
    p: &Conv2dParams,
    epi: Epilogue<'_>,
) {
    let w_out = p.w_out();
    let b = plane_idx / p.c_out;
    let co = plane_idx % p.c_out;
    let bias_v = bias.map_or(0.0, |bv| bv[co]);
    yrows.fill(bias_v);
    for ci in 0..p.c_in {
        let plane_x = &x[((b * p.c_in + ci) * p.h) * p.w..][..p.h * p.w];
        let filt = &w[((co * p.c_in + ci) * p.kh) * p.kw..][..p.kh * p.kw];
        for (j, yrow) in yrows.chunks_mut(w_out).enumerate() {
            let oy = oy0 + j;
            for fy in 0..p.kh {
                let iy = (oy * p.stride + fy) as isize - p.pad as isize;
                if iy < 0 || iy as usize >= p.h {
                    continue;
                }
                let xrow = &plane_x[iy as usize * p.w..][..p.w];
                for fx in 0..p.kw {
                    let wk = filt[fy * p.kw + fx];
                    if wk == 0.0 {
                        continue;
                    }
                    accumulate_row(yrow, xrow, wk, fx, p.stride, p.pad, w_out);
                }
            }
        }
    }
    epi.apply(yrows, plane_idx * p.h_out() * w_out + oy0 * w_out);
}

/// One slid FMA pass: `yrow[t] += wk · xrow[t·stride + fx − pad]`, range
/// clipped, unit-stride fast path (same shape as the 1-D hot loop).
#[inline]
fn accumulate_row(
    yrow: &mut [f32],
    xrow: &[f32],
    wk: f32,
    fx: usize,
    stride: usize,
    pad: usize,
    w_out: usize,
) {
    let n = xrow.len();
    let base = fx as isize - pad as isize;
    let t_lo = if base >= 0 {
        0usize
    } else {
        ((-base) as usize).div_ceil(stride)
    };
    let t_hi = if (n as isize) <= base {
        0usize
    } else {
        (((n as isize - base) as usize).div_ceil(stride)).min(w_out)
    };
    if t_lo >= t_hi {
        return;
    }
    if stride == 1 {
        let len = t_hi - t_lo;
        let off = (t_lo as isize + base) as usize;
        let ys = &mut yrow[t_lo..t_hi];
        let xs = &xrow[off..off + len];
        for (yv, &xv) in ys.iter_mut().zip(xs) {
            *yv = wk.mul_add(xv, *yv);
        }
    } else {
        let mut xi = (t_lo as isize * stride as isize + base) as usize;
        for t in t_lo..t_hi {
            yrow[t] = wk.mul_add(xrow[xi], yrow[t]);
            xi += stride;
        }
    }
}

/// im2col + GEMM baseline for 2-D (the standard Caffe lowering — the
/// expansion here is `kh·kw×` the input, the worst case the paper calls
/// out in §1).
pub fn conv2d_im2col(x: &[f32], w: &[f32], bias: Option<&[f32]>, p: &Conv2dParams) -> Vec<f32> {
    p.validate(x, w, bias);
    let (h_out, w_out) = (p.h_out(), p.w_out());
    let cols_rows = p.c_in * p.kh * p.kw;
    let cols_n = h_out * w_out;
    // alloc-ok: im2col comparator baseline, not on the plan run path.
    let mut y = vec![0.0f32; p.y_len()];
    if cols_n == 0 {
        return y;
    }
    let mut cols = vec![0.0f32; cols_rows * cols_n]; // alloc-ok: baseline scratch
    for b in 0..p.batch {
        cols.fill(0.0);
        for ci in 0..p.c_in {
            let plane = &x[((b * p.c_in + ci) * p.h) * p.w..][..p.h * p.w];
            for fy in 0..p.kh {
                for fx in 0..p.kw {
                    let r = (ci * p.kh + fy) * p.kw + fx;
                    let dst = &mut cols[r * cols_n..][..cols_n];
                    for oy in 0..h_out {
                        let iy = (oy * p.stride + fy) as isize - p.pad as isize;
                        if iy < 0 || iy as usize >= p.h {
                            continue;
                        }
                        let xrow = &plane[iy as usize * p.w..][..p.w];
                        let drow = &mut dst[oy * w_out..][..w_out];
                        for ox in 0..w_out {
                            let ix = (ox * p.stride + fx) as isize - p.pad as isize;
                            if ix >= 0 && (ix as usize) < p.w {
                                drow[ox] = xrow[ix as usize];
                            }
                        }
                    }
                }
            }
        }
        let yb = &mut y[b * p.c_out * cols_n..][..p.c_out * cols_n];
        match bias {
            Some(bv) => gemm::gemm_bias(p.c_out, cols_rows, cols_n, w, &cols, bv, yb),
            None => gemm::gemm(p.c_out, cols_rows, cols_n, w, &cols, yb),
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Rng;

    fn check(p: &Conv2dParams, with_bias: bool) {
        let mut rng = Rng::new(0x2D ^ ((p.h * 31 + p.kw) as u64));
        let x = rng.vec_uniform(p.x_len(), -1.0, 1.0);
        let w = rng.vec_uniform(p.w_len(), -1.0, 1.0);
        let b = rng.vec_uniform(p.c_out, -0.5, 0.5);
        let bias = with_bias.then_some(b.as_slice());
        let want = conv2d_direct(&x, &w, bias, p);
        for (name, got) in [
            ("sliding", conv2d_sliding(&x, &w, bias, p)),
            ("im2col", conv2d_im2col(&x, &w, bias, p)),
        ] {
            assert_eq!(got.len(), want.len(), "{name} {p:?}");
            for (i, (a, c)) in got.iter().zip(&want).enumerate() {
                assert!(
                    (a - c).abs() <= 1e-3 * (1.0 + c.abs()),
                    "{name} {p:?} idx {i}: {a} vs {c}"
                );
            }
        }
    }

    #[test]
    fn identity_1x1() {
        let p = Conv2dParams::new(1, 1, 3, 3, 1, 1);
        let x: Vec<f32> = (1..=9).map(|v| v as f32).collect();
        let y = conv2d_sliding(&x, &[2.0], None, &p);
        assert_eq!(y, x.iter().map(|v| v * 2.0).collect::<Vec<_>>());
    }

    #[test]
    fn known_3x3_sum_filter() {
        // all-ones 3x3 filter over a 3x3 ones image, same-pad →
        // corner 4, edge 6, center 9.
        let p = Conv2dParams::new(1, 1, 3, 3, 3, 3).with_same_pad();
        let y = conv2d_sliding(&[1.0; 9], &[1.0; 9], None, &p);
        assert_eq!(y, vec![4.0, 6.0, 4.0, 6.0, 9.0, 6.0, 4.0, 6.0, 4.0]);
    }

    #[test]
    fn backends_agree_shapes() {
        check(&Conv2dParams::new(1, 1, 8, 8, 3, 3), false);
        check(&Conv2dParams::new(2, 3, 9, 7, 3, 3).with_same_pad(), true);
        check(&Conv2dParams::new(3, 2, 12, 10, 5, 5).with_pad(2), true);
        check(&Conv2dParams::new(1, 2, 11, 13, 3, 5), false);
    }

    #[test]
    fn backends_agree_stride_batch() {
        check(&Conv2dParams::new(2, 2, 12, 12, 3, 3).with_stride(2).with_pad(1), true);
        check(&Conv2dParams::new(1, 1, 10, 10, 3, 3).with_batch(3).with_same_pad(), false);
    }

    #[test]
    fn output_dims() {
        let p = Conv2dParams::new(1, 1, 32, 32, 3, 3).with_same_pad();
        assert_eq!((p.h_out(), p.w_out()), (32, 32));
        let p = Conv2dParams::new(1, 1, 32, 32, 3, 3).with_stride(2).with_pad(1);
        assert_eq!((p.h_out(), p.w_out()), (16, 16));
        let p = Conv2dParams::new(1, 1, 2, 2, 3, 3);
        assert_eq!(p.y_len(), 0);
    }

    #[test]
    fn macs_count() {
        let p = Conv2dParams::new(2, 4, 8, 8, 3, 3);
        assert_eq!(p.macs(), 4 * 6 * 6 * 2 * 9);
    }
}
