//! 1-D (and row-decomposed 2-D) convolution — the paper's target operator.
//!
//! Convolution is "a sliding window sum (dot product) with the associative
//! operator defined by equation 8" (§2.5). This module provides:
//!
//! * [`direct`] — textbook nested-loop convolution (correctness oracle).
//! * [`im2col`] — the paper's *comparator*: expand the input into a column
//!   matrix (k× memory blow-up) and call the blocked GEMM, exactly the
//!   MlasConv structure.
//! * [`sliding`] — the *contribution*: sliding-window kernels on the
//!   unmodified input. Two realizations:
//!   * `conv1d_sliding` — the broadcast-FMA schedule of Algorithm 4 (one
//!     slid multiply-accumulate per tap, vectorized over outputs);
//!   * `conv1d_pair` — the literal Eq. 7–9 pair-operator prefix sum, kept
//!     as the faithful (and testable) form of the paper's math.
//! * dilation, stride, multi-channel, batch on every path.
//!
//! Shapes follow the 1-D DNN convention: input `[batch, c_in, n]`,
//! filters `[c_out, c_in, k]`, output `[batch, c_out, n_out]`, all
//! row-major contiguous.

mod conv2d;
mod direct;
mod quantized;
mod im2col;
mod matmul_reform;
mod params;
mod sliding;
mod small_k;

pub use conv2d::{
    conv2d_direct, conv2d_im2col, conv2d_sliding, conv2d_sliding_into, conv2d_sliding_with,
    conv2d_sliding_with_into, Conv2dParams,
};
pub use direct::{conv1d_direct, conv1d_direct_into};
pub use im2col::{
    conv1d_im2col, conv1d_im2col_epilogue_into, conv1d_im2col_with, im2col_expand,
    im2col_expand_into,
};
pub use matmul_reform::conv1d_tap_gemm;
pub use params::{BackendChoice, Conv1dParams, ConvBackend};
pub use quantized::{conv1d_quantized, conv1d_quantized_into, quantized_scratch_len, QuantParams};
pub use sliding::{
    conv1d_pair, conv1d_pair_tree, conv1d_sliding, conv1d_sliding_into, conv1d_sliding_with,
    conv1d_sliding_with_into,
};
pub(crate) use sliding::conv1d_sliding_row_tile_into;
pub use small_k::{conv1d_k3, conv1d_k5, conv1d_small_k, conv1d_small_k_into, small_k_qualifies};

/// Dispatch a 1-D convolution to the selected backend.
///
/// All backends take the same `[b, c_in, n] ⊛ [c_out, c_in, k]`
/// layout and produce identical (up to FP rounding) outputs.
pub fn conv1d(
    backend: ConvBackend,
    x: &[f32],
    w: &[f32],
    bias: Option<&[f32]>,
    p: &Conv1dParams,
) -> Vec<f32> {
    match backend {
        ConvBackend::Direct => conv1d_direct(x, w, bias, p),
        ConvBackend::Im2colGemm => conv1d_im2col(x, w, bias, p),
        ConvBackend::Sliding => conv1d_sliding(x, w, bias, p),
        ConvBackend::SlidingPair => conv1d_pair(x, w, bias, p),
    }
}

/// [`conv1d`] writing into a caller-provided buffer (resized to
/// [`Conv1dParams::y_len`]). The sliding backend writes in place with no
/// intermediate allocation; im2col reuses `col` for its column matrix
/// (resized to `c_in·k·n_out` once, recycled dirty afterwards) so
/// choosing the GEMM backend no longer reintroduces a per-call k×
/// allocation; direct computes straight into `y`. Only the
/// faithful-math `SlidingPair` backend still allocates internally.
pub fn conv1d_into(
    backend: ConvBackend,
    x: &[f32],
    w: &[f32],
    bias: Option<&[f32]>,
    p: &Conv1dParams,
    col: &mut Vec<f32>,
    y: &mut Vec<f32>,
) {
    use crate::ops::Epilogue;
    match backend {
        ConvBackend::Sliding => {
            y.resize(p.y_len(), 0.0);
            conv1d_sliding_into(x, w, bias, p, Epilogue::None, y);
        }
        ConvBackend::Im2colGemm => {
            y.resize(p.y_len(), 0.0);
            col.resize(p.c_in * p.k * p.n_out(), 0.0);
            conv1d_im2col_epilogue_into(
                crate::exec::Executor::global(),
                x,
                w,
                bias,
                p,
                Epilogue::None,
                col,
                y,
            );
        }
        ConvBackend::Direct => {
            y.resize(p.y_len(), 0.0);
            conv1d_direct_into(x, w, bias, p, y);
        }
        ConvBackend::SlidingPair => *y = conv1d_pair(x, w, bias, p),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dispatch_all_backends_agree() {
        let p = Conv1dParams::new(1, 1, 16, 3);
        let x: Vec<f32> = (0..16).map(|i| i as f32 * 0.5 - 4.0).collect();
        let w = vec![0.25f32, 0.5, -1.0];
        let d = conv1d(ConvBackend::Direct, &x, &w, None, &p);
        for b in [ConvBackend::Im2colGemm, ConvBackend::Sliding, ConvBackend::SlidingPair] {
            let got = conv1d(b, &x, &w, None, &p);
            assert_eq!(got.len(), d.len());
            for (g, t) in got.iter().zip(&d) {
                assert!((g - t).abs() < 1e-4, "{b:?}");
            }
        }
    }

    /// `conv1d_into` must be bit-identical to the allocating dispatch for
    /// every backend, even with dirty recycled destination/column buffers.
    #[test]
    fn into_dispatch_matches_alloc_with_dirty_buffers() {
        let p = Conv1dParams::new(2, 3, 64, 5).with_batch(2).with_same_pad();
        let mut rng = crate::workload::Rng::new(0xC0);
        let x = rng.vec_uniform(p.x_len(), -1.0, 1.0);
        let w = rng.vec_uniform(p.w_len(), -1.0, 1.0);
        let b = rng.vec_uniform(p.c_out, -0.5, 0.5);
        let mut col = vec![777.75f32; 7]; // wrong size + garbage: must be fixed up
        let mut y = vec![777.75f32; 3];
        for backend in ConvBackend::ALL {
            let want = conv1d(backend, &x, &w, Some(&b), &p);
            conv1d_into(backend, &x, &w, Some(&b), &p, &mut col, &mut y);
            assert_eq!(y, want, "{backend:?}");
        }
    }
}
