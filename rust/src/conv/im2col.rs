//! The paper's comparator: im2col + GEMM (MlasConv's structure).
//!
//! "A common approach to implementing convolutional layers is to expand
//! the input into a column matrix (im2col) and then call a highly tuned
//! GEMM" (§1). The expansion costs `k×` the input memory and destroys
//! locality — the very overheads the sliding path removes. We keep this
//! implementation honest and competitive (blocked GEMM, §gemm) because
//! Fig 1/Fig 2 speedups are measured *against* it.

use crate::gemm;
use crate::ops::Epilogue;

use super::Conv1dParams;

/// Expand `[c_in, n]` (single batch element) into the `[c_in·k, n_out]`
/// column matrix: column `t` stacks the k taps of every input channel at
/// output position `t`. Memory: `c_in·k·n_out` floats — the k× blow-up.
pub fn im2col_expand(x: &[f32], p: &Conv1dParams) -> Vec<f32> {
    // alloc-ok: Vec-returning wrapper; im2col_expand_into is the hot path.
    let mut cols = vec![0.0f32; p.c_in * p.k * p.n_out()];
    im2col_expand_into(x, p, &mut cols);
    cols
}

/// [`im2col_expand`] into a caller-provided column buffer of length
/// `c_in·k·n_out`. Every element is written (pad positions get `0.0`),
/// so the buffer may be recycled dirty across calls — this is what lets
/// the execution plan keep one column region in its arena instead of
/// re-allocating the k×-expanded matrix per request.
pub fn im2col_expand_into(x: &[f32], p: &Conv1dParams, cols: &mut [f32]) {
    let n_out = p.n_out();
    assert_eq!(cols.len(), p.c_in * p.k * n_out, "column buffer shape");
    crate::check::poison(cols);
    for ci in 0..p.c_in {
        let xrow = &x[ci * p.n..][..p.n];
        for tap in 0..p.k {
            let r = ci * p.k + tap;
            let dst = &mut cols[r * n_out..][..n_out];
            for t in 0..n_out {
                let xi = (t * p.stride + tap * p.dilation) as isize - p.pad as isize;
                dst[t] = if xi >= 0 && (xi as usize) < p.n {
                    xrow[xi as usize]
                } else {
                    0.0
                };
            }
        }
    }
    crate::check::assert_no_poison(cols, "im2col_expand_into");
}

/// Convolution via im2col + blocked GEMM:
/// `Y[c_out, n_out] = W[c_out, c_in·k] · cols[c_in·k, n_out]`.
/// The GEMM fans out over output rows (and, skinny, column segments) on
/// the shared worker pool so the baseline stays honest at high `P`.
pub fn conv1d_im2col(x: &[f32], w: &[f32], bias: Option<&[f32]>, p: &Conv1dParams) -> Vec<f32> {
    conv1d_im2col_with(crate::exec::Executor::global(), x, w, bias, p)
}

/// [`conv1d_im2col`] on an explicit executor (the single-thread paper
/// tables pin both comparands to one thread through this).
pub fn conv1d_im2col_with(
    ex: &crate::exec::Executor,
    x: &[f32],
    w: &[f32],
    bias: Option<&[f32]>,
    p: &Conv1dParams,
) -> Vec<f32> {
    // alloc-ok: Vec-returning wrapper; the epilogue `_into` form below is
    // the hot path (the plan owns both buffers in its arena).
    let mut col = vec![0.0f32; p.c_in * p.k * p.n_out()];
    let mut y = vec![0.0f32; p.y_len()]; // alloc-ok: Vec-returning wrapper.
    conv1d_im2col_epilogue_into(ex, x, w, bias, p, Epilogue::None, &mut col, &mut y);
    y
}

/// The zero-allocation im2col path: expand into a caller-provided column
/// buffer (`c_in·k·n_out` floats, reused across batch elements and
/// calls), GEMM into a caller-provided destination, and fuse the bias +
/// [`Epilogue`] tail into the GEMM's C sweep. This is what the execution
/// plan runs for layers whose cost model picks the GEMM backend —
/// backend choice no longer reintroduces per-call allocation.
#[allow(clippy::too_many_arguments)]
pub fn conv1d_im2col_epilogue_into(
    ex: &crate::exec::Executor,
    x: &[f32],
    w: &[f32],
    bias: Option<&[f32]>,
    p: &Conv1dParams,
    epi: Epilogue<'_>,
    col: &mut [f32],
    y: &mut [f32],
) {
    p.validate(x, w, bias);
    assert_eq!(y.len(), p.y_len(), "dst length");
    epi.check_len(y.len());
    crate::check::poison(y);
    let n_out = p.n_out();
    if n_out == 0 {
        return;
    }
    let rows = p.c_in * p.k;
    // The plan hands in one shared column region sized for its largest
    // im2col layer; use this layer's prefix.
    assert!(col.len() >= rows * n_out, "column scratch too small");
    let col = &mut col[..rows * n_out];
    for b in 0..p.batch {
        let xb = &x[b * p.c_in * p.n..][..p.c_in * p.n];
        im2col_expand_into(xb, p, col);
        let yb = &mut y[b * p.c_out * n_out..][..p.c_out * n_out];
        // The GEMM accumulates into C, so a recycled destination must be
        // cleared first (allocating callers used to get this for free).
        yb.fill(0.0);
        gemm::gemm_bias_epilogue_with(
            ex,
            p.c_out,
            rows,
            n_out,
            w,
            col,
            bias,
            epi,
            b * p.c_out * n_out,
            yb,
        );
    }
    crate::check::assert_no_poison(y, "conv1d_im2col_epilogue_into");
}

#[cfg(test)]
mod tests {
    use super::super::conv1d_direct;
    use super::*;

    fn fill(buf: &mut [f32], seed: &mut u64) {
        for v in buf.iter_mut() {
            *seed ^= *seed << 13;
            *seed ^= *seed >> 7;
            *seed ^= *seed << 17;
            *v = ((*seed >> 40) as f32 / (1u64 << 24) as f32) * 2.0 - 1.0;
        }
    }

    fn check(p: &Conv1dParams, with_bias: bool) {
        let mut seed = 0xfeedbeefu64 ^ (p.n as u64) << 3 ^ (p.k as u64);
        let mut x = vec![0.0f32; p.x_len()];
        let mut w = vec![0.0f32; p.w_len()];
        let mut b = vec![0.0f32; p.c_out];
        fill(&mut x, &mut seed);
        fill(&mut w, &mut seed);
        fill(&mut b, &mut seed);
        let bias = with_bias.then_some(b.as_slice());
        let got = conv1d_im2col(&x, &w, bias, p);
        let want = conv1d_direct(&x, &w, bias, p);
        assert_eq!(got.len(), want.len());
        for (i, (g, t)) in got.iter().zip(&want).enumerate() {
            assert!(
                (g - t).abs() <= 1e-3 * (1.0 + t.abs()),
                "{p:?} idx {i}: {g} vs {t}"
            );
        }
    }

    #[test]
    fn expand_shape_and_values() {
        let p = Conv1dParams::new(1, 1, 5, 3);
        let cols = im2col_expand(&[1.0, 2.0, 3.0, 4.0, 5.0], &p);
        // 3 rows × 3 cols: row r holds x[r..r+3]
        assert_eq!(cols.len(), 9);
        assert_eq!(&cols[0..3], &[1.0, 2.0, 3.0]);
        assert_eq!(&cols[3..6], &[2.0, 3.0, 4.0]);
        assert_eq!(&cols[6..9], &[3.0, 4.0, 5.0]);
    }

    #[test]
    fn expand_memory_blowup_is_k_times() {
        let p = Conv1dParams::new(4, 8, 1000, 7).with_same_pad();
        let cols = im2col_expand(&vec![0.0; p.c_in * p.n], &p);
        assert_eq!(cols.len(), p.c_in * p.k * p.n_out()); // k× per channel
    }

    #[test]
    fn matches_direct_basic() {
        check(&Conv1dParams::new(1, 1, 64, 5), false);
        check(&Conv1dParams::new(3, 2, 33, 3), true);
    }

    #[test]
    fn matches_direct_stride_dilation_pad() {
        check(&Conv1dParams::new(2, 4, 50, 3).with_stride(2).with_pad(2), true);
        check(&Conv1dParams::new(1, 1, 64, 5).with_dilation(4).with_same_pad(), false);
        check(&Conv1dParams::new(2, 3, 41, 7).with_dilation(3).with_stride(2).with_pad(5), true);
    }

    #[test]
    fn matches_direct_batched() {
        check(&Conv1dParams::new(2, 2, 30, 3).with_batch(3).with_same_pad(), true);
    }
}
