//! Thread/channel execution substrate (tokio is unavailable offline; the
//! request path is CPU-bound anyway, so blocking workers + bounded
//! channels are the right shape). Provides a bounded MPMC channel and a
//! small worker pool used by the coordinator.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};

/// Error returned by channel operations after close.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChannelError {
    Closed,
    Full,
}

struct ChanInner<T> {
    queue: VecDeque<T>,
    closed: bool,
    capacity: usize,
}

/// Bounded MPMC blocking channel.
pub struct Channel<T> {
    inner: Mutex<ChanInner<T>>,
    not_empty: Condvar,
    not_full: Condvar,
}

impl<T> Channel<T> {
    pub fn new(capacity: usize) -> Arc<Self> {
        Arc::new(Self {
            inner: Mutex::new(ChanInner {
                queue: VecDeque::new(),
                closed: false,
                capacity: capacity.max(1),
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
        })
    }

    /// Blocking send; errors if closed.
    pub fn send(&self, item: T) -> Result<(), ChannelError> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.closed {
                return Err(ChannelError::Closed);
            }
            if g.queue.len() < g.capacity {
                g.queue.push_back(item);
                self.not_empty.notify_one();
                return Ok(());
            }
            g = self.not_full.wait(g).unwrap();
        }
    }

    /// Non-blocking send (backpressure signal for the router).
    pub fn try_send(&self, item: T) -> Result<(), (T, ChannelError)> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err((item, ChannelError::Closed));
        }
        if g.queue.len() >= g.capacity {
            return Err((item, ChannelError::Full));
        }
        g.queue.push_back(item);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking receive; `None` once closed *and* drained.
    pub fn recv(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.queue.pop_front() {
                self.not_full.notify_one();
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
    }

    /// Receive with a deadline; `Ok(None)` on timeout.
    pub fn recv_timeout(&self, dur: std::time::Duration) -> Result<Option<T>, ChannelError> {
        let deadline = std::time::Instant::now() + dur;
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.queue.pop_front() {
                self.not_full.notify_one();
                return Ok(Some(item));
            }
            if g.closed {
                return Err(ChannelError::Closed);
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return Ok(None);
            }
            let (guard, res) = self.not_empty.wait_timeout(g, deadline - now).unwrap();
            g = guard;
            if res.timed_out() && g.queue.is_empty() {
                if g.closed {
                    return Err(ChannelError::Closed);
                }
                return Ok(None);
            }
        }
    }

    /// Drain up to `max` queued items without blocking (batch collection).
    pub fn drain_up_to(&self, max: usize) -> Vec<T> {
        let mut g = self.inner.lock().unwrap();
        let take = g.queue.len().min(max);
        let out: Vec<T> = g.queue.drain(..take).collect();
        if !out.is_empty() {
            self.not_full.notify_all();
        }
        out
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Close: senders fail, receivers drain then get `None`.
    pub fn close(&self) {
        let mut g = self.inner.lock().unwrap();
        g.closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }
}

/// A joinable set of named worker threads.
pub struct WorkerPool {
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerPool {
    pub fn spawn<F>(count: usize, name: &str, f: F) -> Self
    where
        F: Fn(usize) + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let handles = (0..count)
            .map(|i| {
                let f = Arc::clone(&f);
                std::thread::Builder::new()
                    .name(format!("{name}-{i}"))
                    .spawn(move || f(i))
                    .expect("spawn worker")
            })
            .collect();
        Self { handles }
    }

    pub fn join(self) {
        for h in self.handles {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn send_recv_roundtrip() {
        let ch = Channel::new(4);
        ch.send(1).unwrap();
        ch.send(2).unwrap();
        assert_eq!(ch.recv(), Some(1));
        assert_eq!(ch.recv(), Some(2));
    }

    #[test]
    fn try_send_backpressure() {
        let ch = Channel::new(1);
        ch.try_send(1).unwrap();
        match ch.try_send(2) {
            Err((2, ChannelError::Full)) => {}
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn close_drains_then_none() {
        let ch = Channel::new(4);
        ch.send(7).unwrap();
        ch.close();
        assert_eq!(ch.send(8), Err(ChannelError::Closed));
        assert_eq!(ch.recv(), Some(7));
        assert_eq!(ch.recv(), None);
    }

    #[test]
    fn recv_timeout_times_out() {
        let ch: Arc<Channel<i32>> = Channel::new(1);
        let got = ch.recv_timeout(Duration::from_millis(10)).unwrap();
        assert_eq!(got, None);
    }

    #[test]
    fn drain_up_to_batches() {
        let ch = Channel::new(8);
        for i in 0..5 {
            ch.send(i).unwrap();
        }
        let batch = ch.drain_up_to(3);
        assert_eq!(batch, vec![0, 1, 2]);
        assert_eq!(ch.len(), 2);
    }

    #[test]
    fn cross_thread_handoff() {
        let ch = Channel::new(2);
        let ch2 = Arc::clone(&ch);
        let t = std::thread::spawn(move || {
            for i in 0..100 {
                ch2.send(i).unwrap();
            }
            ch2.close();
        });
        let mut got = Vec::new();
        while let Some(v) = ch.recv() {
            got.push(v);
        }
        t.join().unwrap();
        assert_eq!(got.len(), 100);
        assert_eq!(got[99], 99);
    }

    #[test]
    fn worker_pool_runs_all() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let counter = Arc::new(AtomicUsize::new(0));
        let c2 = Arc::clone(&counter);
        let pool = WorkerPool::spawn(4, "t", move |_i| {
            c2.fetch_add(1, Ordering::SeqCst);
        });
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 4);
    }
}
