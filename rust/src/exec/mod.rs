//! Thread/channel execution substrate (tokio is unavailable offline; the
//! request path is CPU-bound anyway, so blocking workers + bounded
//! channels are the right shape). Provides a bounded MPMC channel, a
//! small joinable [`WorkerPool`] helper, and the shared data-parallel
//! [`Executor`] the kernels fan out on — the paper's `P` made real
//! (speedup `O(P/w)`, `O(P/log w)` for associative `⊕`).

use std::cell::Cell;
use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Error returned by channel operations after close.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChannelError {
    Closed,
    Full,
}

struct ChanInner<T> {
    queue: VecDeque<T>,
    closed: bool,
    capacity: usize,
}

/// Bounded MPMC blocking channel.
pub struct Channel<T> {
    inner: Mutex<ChanInner<T>>,
    not_empty: Condvar,
    not_full: Condvar,
}

impl<T> Channel<T> {
    pub fn new(capacity: usize) -> Arc<Self> {
        Arc::new(Self::with_capacity(capacity))
    }

    /// Plain (non-`Arc`) constructor for embedding in a larger shared
    /// structure (the coordinator keeps one inside its worker-shared
    /// state instead of a second `Arc` indirection).
    pub fn with_capacity(capacity: usize) -> Self {
        Self {
            inner: Mutex::new(ChanInner {
                queue: VecDeque::new(),
                closed: false,
                capacity: capacity.max(1),
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
        }
    }

    /// Blocking send; errors if closed.
    pub fn send(&self, item: T) -> Result<(), ChannelError> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.closed {
                return Err(ChannelError::Closed);
            }
            if g.queue.len() < g.capacity {
                g.queue.push_back(item);
                self.not_empty.notify_one();
                return Ok(());
            }
            g = self.not_full.wait(g).unwrap();
        }
    }

    /// Non-blocking send (backpressure signal for the router).
    pub fn try_send(&self, item: T) -> Result<(), (T, ChannelError)> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err((item, ChannelError::Closed));
        }
        if g.queue.len() >= g.capacity {
            return Err((item, ChannelError::Full));
        }
        g.queue.push_back(item);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking receive; `None` once closed *and* drained.
    pub fn recv(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.queue.pop_front() {
                self.not_full.notify_one();
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
    }

    /// Receive with a deadline; `Ok(None)` on timeout.
    pub fn recv_timeout(&self, dur: std::time::Duration) -> Result<Option<T>, ChannelError> {
        let deadline = std::time::Instant::now() + dur;
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.queue.pop_front() {
                self.not_full.notify_one();
                return Ok(Some(item));
            }
            if g.closed {
                return Err(ChannelError::Closed);
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return Ok(None);
            }
            let (guard, res) = self.not_empty.wait_timeout(g, deadline - now).unwrap();
            g = guard;
            if res.timed_out() && g.queue.is_empty() {
                if g.closed {
                    return Err(ChannelError::Closed);
                }
                return Ok(None);
            }
        }
    }

    /// Drain up to `max` queued items without blocking (batch collection).
    pub fn drain_up_to(&self, max: usize) -> Vec<T> {
        let mut g = self.inner.lock().unwrap();
        let take = g.queue.len().min(max);
        let out: Vec<T> = g.queue.drain(..take).collect();
        if !out.is_empty() {
            self.not_full.notify_all();
        }
        out
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Close: senders fail, receivers drain then get `None`.
    pub fn close(&self) {
        let mut g = self.inner.lock().unwrap();
        g.closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }
}

/// A joinable set of named worker threads.
pub struct WorkerPool {
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerPool {
    pub fn spawn<F>(count: usize, name: &str, f: F) -> Self
    where
        F: Fn(usize) + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let handles = (0..count)
            .map(|i| {
                let f = Arc::clone(&f);
                std::thread::Builder::new()
                    .name(format!("{name}-{i}"))
                    .spawn(move || f(i))
                    .expect("spawn worker")
            })
            .collect();
        Self { handles }
    }

    pub fn join(self) {
        for h in self.handles {
            let _ = h.join();
        }
    }
}

// ───────────────────────── data-parallel executor ─────────────────────

/// A boxed unit of work executed on a pool worker.
type Job = Box<dyn FnOnce() + Send + 'static>;

/// Shared fan-out floor: below this many total output elements the
/// boxed-job + latch overhead beats the kernel work, so the conv/pool
/// dispatchers run inline instead of scoping jobs onto the pool.
pub const PAR_MIN_FANOUT: usize = 4096;

thread_local! {
    /// Set on executor worker threads so nested fan-out runs inline
    /// (prevents pool-starvation deadlock and oversubscription).
    static IN_POOL_WORKER: Cell<bool> = const { Cell::new(false) };
}

/// Completion latch for one [`Executor::scope`] call: counts outstanding
/// jobs and carries the first panic message back to the caller.
struct ScopeSync {
    state: Mutex<ScopeState>,
    done: Condvar,
}

struct ScopeState {
    remaining: usize,
    panic: Option<String>,
}

impl ScopeSync {
    fn new(n: usize) -> Self {
        Self {
            state: Mutex::new(ScopeState {
                remaining: n,
                panic: None,
            }),
            done: Condvar::new(),
        }
    }

    fn complete(&self, panic: Option<String>) {
        let mut g = self.state.lock().unwrap();
        g.remaining -= 1;
        if g.panic.is_none() {
            g.panic = panic;
        }
        if g.remaining == 0 {
            self.done.notify_all();
        }
    }

    fn wait(&self) -> Option<String> {
        let mut g = self.state.lock().unwrap();
        while g.remaining > 0 {
            g = self.done.wait(g).unwrap();
        }
        g.panic.take()
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Shared scoped worker pool: persistent threads fed through the bounded
/// MPMC [`Channel`], executing *borrowed* closures batch-at-a-time.
///
/// [`Executor::scope`] is the primitive: submit a batch of jobs that may
/// borrow the caller's stack (including disjoint `&mut` output chunks),
/// block until every job completes. Safety rests on that blocking — the
/// pool threads are `'static`, but no job outlives its scope call.
///
/// The process-wide instance ([`Executor::global`]) is lazily initialized
/// from `--threads` / `serve.threads` / `SWSNN_THREADS`, defaulting to
/// all cores. Kernels with a `_with` variant also accept a local
/// executor, which is what the thread-scaling benches use.
pub struct Executor {
    injector: Arc<Channel<Job>>,
    threads: usize,
    workers: Vec<std::thread::JoinHandle<()>>,
}

static GLOBAL_EXECUTOR: OnceLock<Executor> = OnceLock::new();

fn default_threads() -> usize {
    if let Ok(v) = std::env::var("SWSNN_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Pin the global executor's parallelism before first use. Returns
/// `false` (no-op) if the pool is already running — the pool cannot be
/// resized once threads exist.
pub fn set_global_threads(threads: usize) -> bool {
    let mut applied = false;
    GLOBAL_EXECUTOR.get_or_init(|| {
        applied = true;
        Executor::new(threads)
    });
    applied
}

impl Executor {
    /// A pool with `threads` degree of parallelism. `threads <= 1` spawns
    /// no workers; every scope then runs inline on the caller. The count
    /// is clamped to a sane ceiling so a misconfigured value can never
    /// turn into a thread bomb.
    pub fn new(threads: usize) -> Self {
        let threads = threads.clamp(1, 1024);
        let injector: Arc<Channel<Job>> = Channel::new((threads * 64).max(1024));
        let workers = if threads > 1 {
            (0..threads)
                .map(|i| {
                    let inj = Arc::clone(&injector);
                    std::thread::Builder::new()
                        .name(format!("swsnn-exec-{i}"))
                        .spawn(move || {
                            IN_POOL_WORKER.with(|f| f.set(true));
                            while let Some(job) = inj.recv() {
                                job();
                            }
                        })
                        .expect("spawn executor worker")
                })
                .collect()
        } else {
            Vec::new()
        };
        Self {
            injector,
            threads,
            workers,
        }
    }

    /// The lazily-initialized process-wide pool.
    pub fn global() -> &'static Executor {
        GLOBAL_EXECUTOR.get_or_init(|| Executor::new(default_threads()))
    }

    /// Degree of parallelism (worker count; 1 = inline execution).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run a batch of borrowed jobs to completion. Jobs may mutably
    /// borrow disjoint parts of the caller's data; this call does not
    /// return until every job has finished. A panicking job does not
    /// kill its worker; the panic message is re-raised here.
    pub fn scope<'a>(&self, jobs: Vec<Box<dyn FnOnce() + Send + 'a>>) {
        let n = jobs.len();
        if n == 0 {
            return;
        }
        // Inline when there is nothing to fan out to, or when already on
        // a pool worker (a blocked worker could starve the pool).
        if self.threads <= 1 || n == 1 || IN_POOL_WORKER.with(|f| f.get()) {
            for job in jobs {
                job();
            }
            return;
        }
        let sync = Arc::new(ScopeSync::new(n));
        for job in jobs {
            // SAFETY: the transmute only erases the borrow lifetime `'a`.
            // `sync.wait()` below blocks until every submitted job has run
            // (the completion callback fires even on panic), so no job —
            // and nothing it borrows — outlives this stack frame.
            let job: Job = unsafe {
                std::mem::transmute::<Box<dyn FnOnce() + Send + 'a>, Box<dyn FnOnce() + Send>>(job)
            };
            let sync2 = Arc::clone(&sync);
            let task: Job = Box::new(move || {
                let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
                sync2.complete(result.err().map(|e| panic_message(&*e)));
            });
            match self.injector.try_send(task) {
                Ok(()) => {}
                // Queue full (or pool shutting down): caller runs it.
                Err((task, _)) => task(),
            }
        }
        if let Some(msg) = sync.wait() {
            panic!("executor task panicked: {msg}");
        }
    }

    /// Apply `f` to consecutive `chunk_len`-sized mutable chunks of
    /// `data` in parallel; `f` receives the chunk index and the chunk.
    pub fn parallel_chunks_mut<T, F>(&self, data: &mut [T], chunk_len: usize, f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        if data.is_empty() || chunk_len == 0 {
            return;
        }
        let fref: &(dyn Fn(usize, &mut [T]) + Sync) = &f;
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
            Vec::with_capacity(data.len().div_ceil(chunk_len));
        for (i, chunk) in data.chunks_mut(chunk_len).enumerate() {
            jobs.push(Box::new(move || fref(i, chunk)));
        }
        self.scope(jobs);
    }

    /// Run `f(0) … f(n-1)` in parallel (read-only fan-out).
    pub fn parallel_for<F>(&self, n: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        let fref: &(dyn Fn(usize) + Sync) = &f;
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(n);
        for i in 0..n {
            jobs.push(Box::new(move || fref(i)));
        }
        self.scope(jobs);
    }
}

impl Drop for Executor {
    fn drop(&mut self) {
        self.injector.close();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn send_recv_roundtrip() {
        let ch = Channel::new(4);
        ch.send(1).unwrap();
        ch.send(2).unwrap();
        assert_eq!(ch.recv(), Some(1));
        assert_eq!(ch.recv(), Some(2));
    }

    #[test]
    fn try_send_backpressure() {
        let ch = Channel::new(1);
        ch.try_send(1).unwrap();
        match ch.try_send(2) {
            Err((2, ChannelError::Full)) => {}
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn close_drains_then_none() {
        let ch = Channel::new(4);
        ch.send(7).unwrap();
        ch.close();
        assert_eq!(ch.send(8), Err(ChannelError::Closed));
        assert_eq!(ch.recv(), Some(7));
        assert_eq!(ch.recv(), None);
    }

    #[test]
    fn recv_timeout_times_out() {
        let ch: Arc<Channel<i32>> = Channel::new(1);
        let got = ch.recv_timeout(Duration::from_millis(10)).unwrap();
        assert_eq!(got, None);
    }

    #[test]
    fn drain_up_to_batches() {
        let ch = Channel::new(8);
        for i in 0..5 {
            ch.send(i).unwrap();
        }
        let batch = ch.drain_up_to(3);
        assert_eq!(batch, vec![0, 1, 2]);
        assert_eq!(ch.len(), 2);
    }

    #[test]
    fn cross_thread_handoff() {
        let ch = Channel::new(2);
        let ch2 = Arc::clone(&ch);
        let t = std::thread::spawn(move || {
            for i in 0..100 {
                ch2.send(i).unwrap();
            }
            ch2.close();
        });
        let mut got = Vec::new();
        while let Some(v) = ch.recv() {
            got.push(v);
        }
        t.join().unwrap();
        assert_eq!(got.len(), 100);
        assert_eq!(got[99], 99);
    }

    #[test]
    fn worker_pool_runs_all() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let counter = Arc::new(AtomicUsize::new(0));
        let c2 = Arc::clone(&counter);
        let pool = WorkerPool::spawn(4, "t", move |_i| {
            c2.fetch_add(1, Ordering::SeqCst);
        });
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn executor_parallel_chunks_cover_all_data() {
        for threads in [1usize, 2, 4, 8] {
            let ex = Executor::new(threads);
            let mut data = vec![0u32; 10_007];
            ex.parallel_chunks_mut(&mut data, 1024, |ci, chunk| {
                for (j, v) in chunk.iter_mut().enumerate() {
                    *v = (ci * 1024 + j) as u32;
                }
            });
            for (i, v) in data.iter().enumerate() {
                assert_eq!(*v, i as u32, "threads={threads}");
            }
        }
    }

    #[test]
    fn executor_parallel_for_runs_every_index() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let ex = Executor::new(4);
        let hits = AtomicUsize::new(0);
        ex.parallel_for(137, |_i| {
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 137);
    }

    #[test]
    fn executor_scope_borrows_disjoint_chunks() {
        let ex = Executor::new(3);
        let mut data = vec![1.0f32; 9000];
        let chunk = 2500;
        ex.parallel_chunks_mut(&mut data, chunk, |_ci, c| {
            for v in c.iter_mut() {
                *v *= 2.0;
            }
        });
        assert!(data.iter().all(|v| *v == 2.0));
    }

    #[test]
    fn executor_nested_scope_runs_inline() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let ex = Arc::new(Executor::new(2));
        let total = AtomicUsize::new(0);
        // Outer fan-out; inner fan-out from pool workers must not
        // deadlock (it runs inline on the worker).
        ex.parallel_for(4, |_| {
            ex.parallel_for(4, |_| {
                total.fetch_add(1, Ordering::SeqCst);
            });
        });
        assert_eq!(total.load(Ordering::SeqCst), 16);
    }

    #[test]
    #[should_panic(expected = "executor task panicked")]
    fn executor_propagates_job_panics() {
        let ex = Executor::new(4);
        ex.parallel_for(8, |i| {
            if i == 5 {
                panic!("boom in job");
            }
        });
    }

    #[test]
    fn executor_single_thread_is_inline() {
        let ex = Executor::new(1);
        assert_eq!(ex.threads(), 1);
        let mut acc = 0u64;
        // Inline execution can mutate captured state through a scope of
        // one job (no Sync requirement exercised).
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
        jobs.push(Box::new(|| acc += 7));
        ex.scope(jobs);
        assert_eq!(acc, 7);
    }
}
