//! Model: an ordered layer stack built from [`ModelConfig`].

use anyhow::{bail, Result};

use crate::config::{LayerConfig, ModelConfig};
use crate::conv::ConvBackend;
use crate::pool::PoolKind;
use crate::workload::Rng;

use super::layers::{Layer, LayerOutput};

/// Output tensor of a forward pass: `shape = [batch, features…]`.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

/// A built model: layers + the (c, n) shape trace used for validation.
/// `Clone` replicates the parameters — used to hand one engine instance
/// to each coordinator worker.
#[derive(Clone)]
pub struct Model {
    pub name: String,
    pub c_in: usize,
    pub seq_len: usize,
    layers: Vec<Layer>,
    /// (channels, n) after each layer.
    shape_trace: Vec<(usize, usize)>,
}

impl Model {
    /// Build and initialize from config (He init via the given RNG).
    pub fn init(cfg: &ModelConfig, rng: &mut Rng) -> Result<Self> {
        let mut layers = Vec::new();
        let mut c = cfg.c_in;
        let mut n = cfg.seq_len;
        let mut trace = Vec::new();
        for (idx, lc) in cfg.layers.iter().enumerate() {
            let layer = match lc {
                LayerConfig::Conv {
                    c_out,
                    k,
                    stride,
                    dilation,
                    same_pad,
                    relu,
                } => Layer::conv(rng, c, *c_out, *k, *stride, *dilation, *same_pad, *relu),
                LayerConfig::Pool { kind, w, stride } => {
                    let Some(kind) = PoolKind::parse(kind) else {
                        bail!("layer {idx}: unknown pool kind {kind:?}");
                    };
                    Layer::Pool {
                        kind,
                        w: *w,
                        stride: *stride,
                    }
                }
                LayerConfig::Residual { k, dilation } => Layer::residual(rng, c, *k, *dilation),
                LayerConfig::Dense { out, relu } => Layer::dense(rng, c * n, *out, *relu),
            };
            let (c2, n2) = layer.out_shape(c, n);
            if n2 == 0 {
                bail!("layer {idx} produces empty output (c={c}, n={n})");
            }
            c = c2;
            n = n2;
            trace.push((c, n));
            layers.push(layer);
        }
        Ok(Self {
            name: cfg.name.clone(),
            c_in: cfg.c_in,
            seq_len: cfg.seq_len,
            layers,
            shape_trace: trace,
        })
    }

    pub fn param_count(&self) -> usize {
        self.layers.iter().map(|l| l.param_count()).sum()
    }

    pub fn layer_count(&self) -> usize {
        self.layers.len()
    }

    /// Final (channels, n) shape per input row.
    pub fn out_shape(&self) -> (usize, usize) {
        *self.shape_trace.last().unwrap_or(&(self.c_in, self.seq_len))
    }

    /// Forward a batch: `x` is `[batch, c_in, seq_len]` flattened.
    pub fn forward(&self, x: &[f32], batch: usize, backend: ConvBackend) -> Result<TensorSpec> {
        let expect = batch * self.c_in * self.seq_len;
        if x.len() != expect {
            bail!(
                "input length {} != batch {} × c_in {} × seq_len {}",
                x.len(),
                batch,
                self.c_in,
                self.seq_len
            );
        }
        let mut act = LayerOutput {
            channels: self.c_in,
            n: self.seq_len,
            data: x.to_vec(),
        };
        for layer in &self.layers {
            act = layer.forward(&act, batch, backend);
        }
        let shape = if act.n == 1 {
            vec![batch, act.channels]
        } else {
            vec![batch, act.channels, act.n]
        };
        Ok(TensorSpec {
            shape,
            data: act.data,
        })
    }

    /// Total MACs per input row (for throughput reporting).
    pub fn macs_per_row(&self) -> u64 {
        let mut c = self.c_in;
        let mut n = self.seq_len;
        let mut macs = 0u64;
        for layer in &self.layers {
            match layer {
                Layer::Conv {
                    c_out, k, ..
                } => {
                    let (c2, n2) = layer.out_shape(c, n);
                    macs += (c2 * n2 * c * k) as u64;
                    c = *c_out;
                    n = n2;
                }
                Layer::Residual { k, .. } => {
                    macs += 2 * (c * n * c * k) as u64;
                }
                Layer::Dense { in_features, out, .. } => {
                    macs += (*in_features * *out) as u64;
                    c = *out;
                    n = 1;
                }
                Layer::Pool { .. } => {
                    let (c2, n2) = layer.out_shape(c, n);
                    c = c2;
                    n = n2;
                }
            }
        }
        macs
    }
}
