//! Model: an ordered layer stack built from [`ModelConfig`].

use anyhow::{bail, Result};

use crate::config::{LayerConfig, ModelConfig};
use crate::conv::ConvBackend;
use crate::pool::PoolKind;
use crate::workload::Rng;

use super::layers::Layer;

/// Output tensor of a forward pass: `shape = [batch, features…]`.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

/// Reusable activation buffers for [`Model::forward_into`]: ping/pong
/// activations plus a residual-block temp. One scratch per engine
/// worker recycles every intermediate tensor across requests — after
/// warm-up a forward pass allocates nothing.
#[derive(Clone, Debug, Default)]
pub struct ForwardScratch {
    ping: Vec<f32>,
    pong: Vec<f32>,
    tmp: Vec<f32>,
}

/// A built model: layers + the (c, n) shape trace used for validation.
/// `Clone` replicates the parameters — used to hand one engine instance
/// to each coordinator worker.
#[derive(Clone)]
pub struct Model {
    pub name: String,
    pub c_in: usize,
    pub seq_len: usize,
    layers: Vec<Layer>,
    /// (channels, n) after each layer.
    shape_trace: Vec<(usize, usize)>,
}

impl Model {
    /// Build and initialize from config (He init via the given RNG).
    pub fn init(cfg: &ModelConfig, rng: &mut Rng) -> Result<Self> {
        let mut layers = Vec::new();
        let mut c = cfg.c_in;
        let mut n = cfg.seq_len;
        let mut trace = Vec::new();
        for (idx, lc) in cfg.layers.iter().enumerate() {
            let layer = match lc {
                LayerConfig::Conv {
                    c_out,
                    k,
                    stride,
                    dilation,
                    same_pad,
                    relu,
                } => Layer::conv(rng, c, *c_out, *k, *stride, *dilation, *same_pad, *relu),
                LayerConfig::Pool { kind, w, stride } => {
                    let Some(kind) = PoolKind::parse(kind) else {
                        bail!("layer {idx}: unknown pool kind {kind:?}");
                    };
                    Layer::Pool {
                        kind,
                        w: *w,
                        stride: *stride,
                    }
                }
                LayerConfig::Residual { k, dilation } => Layer::residual(rng, c, *k, *dilation),
                LayerConfig::Dense { out, relu } => Layer::dense(rng, c * n, *out, *relu),
            };
            let (c2, n2) = layer.out_shape(c, n);
            if n2 == 0 {
                bail!("layer {idx} produces empty output (c={c}, n={n})");
            }
            c = c2;
            n = n2;
            trace.push((c, n));
            layers.push(layer);
        }
        Ok(Self {
            name: cfg.name.clone(),
            c_in: cfg.c_in,
            seq_len: cfg.seq_len,
            layers,
            shape_trace: trace,
        })
    }

    pub fn param_count(&self) -> usize {
        self.layers.iter().map(|l| l.param_count()).sum()
    }

    pub fn layer_count(&self) -> usize {
        self.layers.len()
    }

    /// Final (channels, n) shape per input row.
    pub fn out_shape(&self) -> (usize, usize) {
        *self.shape_trace.last().unwrap_or(&(self.c_in, self.seq_len))
    }

    /// Forward a batch: `x` is `[batch, c_in, seq_len]` flattened.
    /// Allocating wrapper over [`Model::forward_into`].
    pub fn forward(&self, x: &[f32], batch: usize, backend: ConvBackend) -> Result<TensorSpec> {
        let mut scratch = ForwardScratch::default();
        let mut data = Vec::new();
        let (c, n) = self.forward_into(x, batch, backend, &mut scratch, &mut data)?;
        let shape = if n == 1 {
            vec![batch, c]
        } else {
            vec![batch, c, n]
        };
        Ok(TensorSpec { shape, data })
    }

    /// Forward a batch into a reusable output buffer, recycling every
    /// intermediate activation through `scratch`. Returns the per-row
    /// output `(channels, n)`; `out` holds `[batch, channels, n]`
    /// flattened. Numerically identical to [`Model::forward`].
    pub fn forward_into(
        &self,
        x: &[f32],
        batch: usize,
        backend: ConvBackend,
        scratch: &mut ForwardScratch,
        out: &mut Vec<f32>,
    ) -> Result<(usize, usize)> {
        let expect = batch * self.c_in * self.seq_len;
        if x.len() != expect {
            bail!(
                "input length {} != batch {} × c_in {} × seq_len {}",
                x.len(),
                batch,
                self.c_in,
                self.seq_len
            );
        }
        scratch.ping.clear();
        scratch.ping.extend_from_slice(x);
        let (mut c, mut n) = (self.c_in, self.seq_len);
        for layer in &self.layers {
            let (c2, n2) = layer.forward_into(
                &scratch.ping,
                c,
                n,
                batch,
                backend,
                &mut scratch.pong,
                &mut scratch.tmp,
            );
            std::mem::swap(&mut scratch.ping, &mut scratch.pong);
            c = c2;
            n = n2;
        }
        // Hand the result out and recycle the caller's old buffer as the
        // next pass's scratch — no copy either way.
        std::mem::swap(out, &mut scratch.ping);
        Ok((c, n))
    }

    /// Total MACs per input row (for throughput reporting).
    pub fn macs_per_row(&self) -> u64 {
        let mut c = self.c_in;
        let mut n = self.seq_len;
        let mut macs = 0u64;
        for layer in &self.layers {
            match layer {
                Layer::Conv {
                    c_out, k, ..
                } => {
                    let (c2, n2) = layer.out_shape(c, n);
                    macs += (c2 * n2 * c * k) as u64;
                    c = *c_out;
                    n = n2;
                }
                Layer::Residual { k, .. } => {
                    macs += 2 * (c * n * c * k) as u64;
                }
                Layer::Dense { in_features, out, .. } => {
                    macs += (*in_features * *out) as u64;
                    c = *out;
                    n = 1;
                }
                Layer::Pool { .. } => {
                    let (c2, n2) = layer.out_shape(c, n);
                    c = c2;
                    n = n2;
                }
            }
        }
        macs
    }
}
