//! Model: an ordered layer stack built from [`ModelConfig`].

use anyhow::{bail, Result};

use crate::config::{LayerConfig, ModelConfig};
use crate::conv::{BackendChoice, ConvBackend};
use crate::pool::PoolKind;
use crate::workload::Rng;

use super::layers::Layer;
use super::plan::{Plan, PlanCache, PlanScratch, PlannerConfig};

/// Output tensor of a forward pass: `shape = [batch, features…]`.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

/// Reusable state for [`Model::forward_into`]: the compiled-plan cache
/// (keyed by batch size and backend) plus the single scratch arena the
/// plans execute in. One scratch per engine worker recycles every
/// intermediate tensor across requests — after warm-up a forward pass
/// compiles nothing and allocates nothing.
#[derive(Clone, Debug, Default)]
pub struct ForwardScratch {
    plans: PlanCache<(usize, ConvBackend)>,
    scratch: PlanScratch,
}

/// Reusable activation buffers for the *eager reference path*
/// ([`Model::forward_eager_into`]): ping/pong activations, a residual
/// temp, and the im2col column buffer. Kept as the layer-by-layer
/// oracle the compiled plans are parity-tested against (and as the
/// "eager" arm of the `eager_vs_planned` bench).
#[derive(Clone, Debug, Default)]
pub struct EagerScratch {
    ping: Vec<f32>,
    pong: Vec<f32>,
    tmp: Vec<f32>,
    col: Vec<f32>,
}

/// A built model: layers + the (c, n) shape trace used for validation,
/// plus any per-layer backend overrides from the config (`backend =`
/// keys on conv/residual layers). `Clone` replicates the parameters —
/// used to hand one engine instance to each coordinator worker.
#[derive(Clone)]
pub struct Model {
    pub name: String,
    pub c_in: usize,
    pub seq_len: usize,
    layers: Vec<Layer>,
    /// (channels, n) after each layer.
    shape_trace: Vec<(usize, usize)>,
    /// Per-layer backend override (None = planner decides).
    backend_overrides: Vec<Option<ConvBackend>>,
    /// Per-layer int8 opt-in (`quantize = "int8"` on conv layers). The
    /// planner only considers the quantized kernel where this is true.
    quantize_flags: Vec<bool>,
}

impl Model {
    /// Build and initialize from config (He init via the given RNG).
    /// Fails on an empty layer list — a model with no layers has no
    /// output shape, and that must surface here, not at serve time.
    pub fn init(cfg: &ModelConfig, rng: &mut Rng) -> Result<Self> {
        if cfg.layers.is_empty() {
            bail!("model {:?} defines no layers", cfg.name);
        }
        let mut layers = Vec::new();
        let mut overrides = Vec::new();
        let mut quantize_flags = Vec::new();
        let mut c = cfg.c_in;
        let mut n = cfg.seq_len;
        let mut trace = Vec::new();
        for (idx, lc) in cfg.layers.iter().enumerate() {
            quantize_flags.push(matches!(lc, LayerConfig::Conv { quantize: true, .. }));
            let (layer, over) = match lc {
                LayerConfig::Conv {
                    c_out,
                    k,
                    stride,
                    dilation,
                    same_pad,
                    relu,
                    backend,
                    quantize: _,
                } => (
                    Layer::conv(rng, c, *c_out, *k, *stride, *dilation, *same_pad, *relu),
                    *backend,
                ),
                LayerConfig::Pool { kind, w, stride } => {
                    let Some(kind) = PoolKind::parse(kind) else {
                        bail!("layer {idx}: unknown pool kind {kind:?}");
                    };
                    (
                        Layer::Pool {
                            kind,
                            w: *w,
                            stride: *stride,
                        },
                        None,
                    )
                }
                LayerConfig::Residual { k, dilation, backend } => {
                    (Layer::residual(rng, c, *k, *dilation), *backend)
                }
                LayerConfig::Dense { out, relu } => (Layer::dense(rng, c * n, *out, *relu), None),
            };
            let (c2, n2) = layer.out_shape(c, n);
            if n2 == 0 {
                bail!("layer {idx} produces empty output (c={c}, n={n})");
            }
            c = c2;
            n = n2;
            trace.push((c, n));
            layers.push(layer);
            overrides.push(over);
        }
        Ok(Self {
            name: cfg.name.clone(),
            c_in: cfg.c_in,
            seq_len: cfg.seq_len,
            layers,
            shape_trace: trace,
            backend_overrides: overrides,
            quantize_flags,
        })
    }

    pub fn param_count(&self) -> usize {
        self.layers.iter().map(|l| l.param_count()).sum()
    }

    pub fn layer_count(&self) -> usize {
        self.layers.len()
    }

    /// The layer stack (read-only; the plan executor resolves weights
    /// through this).
    pub(crate) fn layers(&self) -> &[Layer] {
        &self.layers
    }

    /// Config-level backend override for layer `i`, if any.
    pub(crate) fn backend_override(&self, i: usize) -> Option<ConvBackend> {
        self.backend_overrides.get(i).copied().flatten()
    }

    /// Whether layer `i` opted into int8 execution (`quantize = "int8"`).
    pub(crate) fn quantize_hint(&self, i: usize) -> bool {
        self.quantize_flags.get(i).copied().unwrap_or(false)
    }

    /// Final (channels, n) shape per input row. [`Model::init`] rejects
    /// empty models, so the trace always has a last entry.
    pub fn out_shape(&self) -> (usize, usize) {
        *self
            .shape_trace
            .last()
            .expect("Model::init rejects empty layer lists")
    }

    /// Forward a batch: `x` is `[batch, c_in, seq_len]` flattened.
    /// Allocating wrapper over [`Model::forward_into`].
    pub fn forward(&self, x: &[f32], batch: usize, backend: ConvBackend) -> Result<TensorSpec> {
        let mut scratch = ForwardScratch::default();
        let mut data = Vec::new();
        let (c, n) = self.forward_into(x, batch, backend, &mut scratch, &mut data)?;
        let shape = if n == 1 {
            vec![batch, c]
        } else {
            vec![batch, c, n]
        };
        Ok(TensorSpec { shape, data })
    }

    /// Forward a batch into a reusable output buffer. Since the plan
    /// refactor this is a compile-then-run wrapper: the (batch, backend)
    /// pair resolves to a cached compiled [`Plan`] in `scratch` (compiled
    /// on first use), which executes all layers through the single
    /// scratch arena with fused epilogues. Bit-identical to the eager
    /// reference path ([`Model::forward_eager_into`], enforced by
    /// `tests/plan_parity.rs`). Returns the per-row output
    /// `(channels, n)`; `out` holds `[batch, channels, n]` flattened.
    pub fn forward_into(
        &self,
        x: &[f32],
        batch: usize,
        backend: ConvBackend,
        scratch: &mut ForwardScratch,
        out: &mut Vec<f32>,
    ) -> Result<(usize, usize)> {
        let cfg = PlannerConfig {
            backend: BackendChoice::Fixed(backend),
            ..PlannerConfig::default()
        };
        let plan = scratch
            .plans
            .get_or_compile((batch, backend), || Plan::compile(self, batch, &cfg))?;
        plan.run_into(self, x, &mut scratch.scratch, out)
    }

    /// The eager layer-by-layer reference path: ping/pong buffer swaps,
    /// separate bias/ReLU/skip-add passes. Semantically and bitwise
    /// equal to the planned [`Model::forward_into`]; kept as the parity
    /// oracle and the baseline arm of the `eager_vs_planned` bench.
    pub fn forward_eager_into(
        &self,
        x: &[f32],
        batch: usize,
        backend: ConvBackend,
        scratch: &mut EagerScratch,
        out: &mut Vec<f32>,
    ) -> Result<(usize, usize)> {
        let expect = batch * self.c_in * self.seq_len;
        if x.len() != expect {
            bail!(
                "input length {} != batch {} × c_in {} × seq_len {}",
                x.len(),
                batch,
                self.c_in,
                self.seq_len
            );
        }
        scratch.ping.clear();
        scratch.ping.extend_from_slice(x);
        let (mut c, mut n) = (self.c_in, self.seq_len);
        for (i, layer) in self.layers.iter().enumerate() {
            let backend = self.backend_override(i).unwrap_or(backend);
            let (c2, n2) = layer.forward_into(
                &scratch.ping,
                c,
                n,
                batch,
                backend,
                &mut scratch.pong,
                &mut scratch.tmp,
                &mut scratch.col,
            );
            std::mem::swap(&mut scratch.ping, &mut scratch.pong);
            c = c2;
            n = n2;
        }
        // Hand the result out and recycle the caller's old buffer as the
        // next pass's scratch — no copy either way.
        std::mem::swap(out, &mut scratch.ping);
        Ok((c, n))
    }

    /// Total MACs per input row (for throughput reporting).
    pub fn macs_per_row(&self) -> u64 {
        let mut c = self.c_in;
        let mut n = self.seq_len;
        let mut macs = 0u64;
        for layer in &self.layers {
            match layer {
                Layer::Conv {
                    c_out, k, ..
                } => {
                    let (c2, n2) = layer.out_shape(c, n);
                    macs += (c2 * n2 * c * k) as u64;
                    c = *c_out;
                    n = n2;
                }
                Layer::Residual { k, .. } => {
                    macs += 2 * (c * n * c * k) as u64;
                }
                Layer::Dense { in_features, out, .. } => {
                    macs += (*in_features * *out) as u64;
                    c = *out;
                    n = 1;
                }
                Layer::Pool { .. } => {
                    let (c2, n2) = layer.out_shape(c, n);
                    c = c2;
                    n = n2;
                }
            }
        }
        macs
    }
}
