//! Individual layers. Each layer owns its parameters and knows how to
//! run forward on a `[batch, c, n]` activation with a chosen conv
//! backend.

use crate::conv::{conv1d_into, Conv1dParams, ConvBackend};
use crate::gemm;
use crate::ops::Epilogue;
use crate::pool::{pool1d_into, Pool1dParams, PoolKind};
use crate::workload::Rng;

/// Activation tensor passed between layers.
#[derive(Clone, Debug)]
pub struct LayerOutput {
    pub channels: usize,
    pub n: usize,
    pub data: Vec<f32>, // [batch, channels, n]
}

/// A single layer with parameters.
#[derive(Clone, Debug)]
pub enum Layer {
    Conv {
        c_in: usize,
        c_out: usize,
        k: usize,
        stride: usize,
        dilation: usize,
        same_pad: bool,
        relu: bool,
        w: Vec<f32>,
        b: Vec<f32>,
    },
    Pool {
        kind: PoolKind,
        w: usize,
        stride: usize,
    },
    /// TCN residual block: two same-pad convs with shared width.
    Residual {
        c: usize,
        k: usize,
        dilation: usize,
        w1: Vec<f32>,
        b1: Vec<f32>,
        w2: Vec<f32>,
        b2: Vec<f32>,
    },
    /// Dense over flattened (channels × n) features.
    Dense {
        in_features: usize,
        out: usize,
        relu: bool,
        w: Vec<f32>, // [out, in_features]
        b: Vec<f32>,
    },
}

fn he_init(rng: &mut Rng, fan_in: usize, n: usize) -> Vec<f32> {
    let std = (2.0 / fan_in as f32).sqrt();
    rng.vec_normal(n, std)
}

impl Layer {
    pub fn conv(
        rng: &mut Rng,
        c_in: usize,
        c_out: usize,
        k: usize,
        stride: usize,
        dilation: usize,
        same_pad: bool,
        relu: bool,
    ) -> Self {
        Layer::Conv {
            c_in,
            c_out,
            k,
            stride,
            dilation,
            same_pad,
            relu,
            w: he_init(rng, c_in * k, c_out * c_in * k),
            b: vec![0.0; c_out],
        }
    }

    pub fn residual(rng: &mut Rng, c: usize, k: usize, dilation: usize) -> Self {
        Layer::Residual {
            c,
            k,
            dilation,
            w1: he_init(rng, c * k, c * c * k),
            b1: vec![0.0; c],
            w2: he_init(rng, c * k, c * c * k),
            b2: vec![0.0; c],
        }
    }

    pub fn dense(rng: &mut Rng, in_features: usize, out: usize, relu: bool) -> Self {
        Layer::Dense {
            in_features,
            out,
            relu,
            w: he_init(rng, in_features, out * in_features),
            b: vec![0.0; out],
        }
    }

    pub fn param_count(&self) -> usize {
        match self {
            Layer::Conv { w, b, .. } => w.len() + b.len(),
            Layer::Pool { .. } => 0,
            Layer::Residual { w1, b1, w2, b2, .. } => w1.len() + b1.len() + w2.len() + b2.len(),
            Layer::Dense { w, b, .. } => w.len() + b.len(),
        }
    }

    /// Output (channels, n) for an input (channels, n).
    pub fn out_shape(&self, c: usize, n: usize) -> (usize, usize) {
        match self {
            Layer::Conv {
                c_out,
                k,
                stride,
                dilation,
                same_pad,
                ..
            } => {
                let mut p = Conv1dParams::new(c, *c_out, n, *k)
                    .with_stride(*stride)
                    .with_dilation(*dilation);
                if *same_pad {
                    p = p.with_same_pad();
                }
                (*c_out, p.n_out())
            }
            Layer::Pool { w, stride, .. } => {
                let p = Pool1dParams::new(c, n, *w).with_stride(*stride);
                (c, p.n_out())
            }
            Layer::Residual { .. } => (c, n),
            Layer::Dense { out, .. } => (*out, 1),
        }
    }

    /// Forward one batch of activations (allocating wrapper over
    /// [`Layer::forward_into`]).
    pub fn forward(&self, x: &LayerOutput, batch: usize, backend: ConvBackend) -> LayerOutput {
        let mut y = Vec::new();
        let mut tmp = Vec::new();
        let mut col = Vec::new();
        let (c2, n2) = self.forward_into(
            &x.data, x.channels, x.n, batch, backend, &mut y, &mut tmp, &mut col,
        );
        LayerOutput {
            channels: c2,
            n: n2,
            data: y,
        }
    }

    /// Forward one batch from `x` (flattened `[batch, c, n]`) into `y`,
    /// reusing `tmp` for intermediate activations (residual blocks) and
    /// `col` for the im2col backend's column matrix. All buffers are
    /// resized as needed and every output element is overwritten, so
    /// they can be recycled dirty across calls. Returns the output
    /// `(channels, n)`. Numerically identical to [`Layer::forward`].
    ///
    /// This is the *eager reference* step the compiled plan is tested
    /// against: each conv kernel's epilogue-fused form must reproduce
    /// the separate bias/ReLU/skip-add passes here bit-for-bit.
    #[allow(clippy::too_many_arguments)]
    pub fn forward_into(
        &self,
        x: &[f32],
        c: usize,
        n: usize,
        batch: usize,
        backend: ConvBackend,
        y: &mut Vec<f32>,
        tmp: &mut Vec<f32>,
        col: &mut Vec<f32>,
    ) -> (usize, usize) {
        match self {
            Layer::Conv {
                c_in,
                c_out,
                k,
                stride,
                dilation,
                same_pad,
                relu,
                w,
                b,
            } => {
                assert_eq!(c, *c_in, "conv input channels");
                let mut p = Conv1dParams::new(*c_in, *c_out, n, *k)
                    .with_batch(batch)
                    .with_stride(*stride)
                    .with_dilation(*dilation);
                if *same_pad {
                    p = p.with_same_pad();
                }
                conv1d_into(backend, x, w, Some(b), &p, col, y);
                if *relu {
                    relu_inplace(y);
                }
                (*c_out, p.n_out())
            }
            Layer::Pool { kind, w, stride } => {
                let p = Pool1dParams::new(c, n, *w)
                    .with_batch(batch)
                    .with_stride(*stride);
                y.resize(p.y_len(), 0.0);
                pool1d_into(*kind, x, &p, y);
                (c, p.n_out())
            }
            Layer::Residual {
                c: cr,
                k,
                dilation,
                w1,
                b1,
                w2,
                b2,
            } => {
                assert_eq!(c, *cr, "residual channels");
                let p = Conv1dParams::new(*cr, *cr, n, *k)
                    .with_batch(batch)
                    .with_dilation(*dilation)
                    .with_same_pad();
                conv1d_into(backend, x, w1, Some(b1), &p, col, tmp);
                relu_inplace(tmp);
                conv1d_into(backend, tmp, w2, Some(b2), &p, col, y);
                relu_inplace(y);
                for (o, xv) in y.iter_mut().zip(x) {
                    *o += xv;
                }
                (c, n)
            }
            Layer::Dense {
                in_features,
                out,
                relu,
                w,
                b,
            } => {
                let feat = c * n;
                assert_eq!(feat, *in_features, "dense input features");
                y.resize(batch * out, 0.0);
                dense_forward(
                    crate::exec::Executor::global(),
                    x,
                    w,
                    b,
                    batch,
                    feat,
                    *out,
                    *relu,
                    y,
                );
                (*out, 1)
            }
        }
    }
}

/// Dense layer forward: one blocked-GEMM gemv per batch row
/// (`y[out] = W[out, feat] · x[feat] + b`, relu fused into the C sweep)
/// on the given executor — replacing the former naive scalar triple
/// loop. The plan's dense step calls this exact routine, so planned and
/// eager execution agree bitwise.
#[allow(clippy::too_many_arguments)]
pub(crate) fn dense_forward(
    ex: &crate::exec::Executor,
    x: &[f32],
    w: &[f32],
    b: &[f32],
    batch: usize,
    feat: usize,
    out: usize,
    relu: bool,
    y: &mut [f32],
) {
    let epi = if relu { Epilogue::Relu } else { Epilogue::None };
    for bi in 0..batch {
        let xrow = &x[bi * feat..][..feat];
        let yrow = &mut y[bi * out..][..out];
        // The GEMM accumulates into C; clear the recycled row first.
        yrow.fill(0.0);
        gemm::gemm_bias_epilogue_with(ex, out, feat, 1, w, xrow, Some(b), epi, 0, yrow);
    }
}

fn relu_inplace(xs: &mut [f32]) {
    for v in xs {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_layer_shapes_and_relu() {
        let mut rng = Rng::new(4);
        let layer = Layer::conv(&mut rng, 2, 3, 3, 1, 1, true, true);
        let x = LayerOutput {
            channels: 2,
            n: 16,
            data: rng.vec_uniform(2 * 16, -1.0, 1.0),
        };
        let y = layer.forward(&x, 1, ConvBackend::Direct);
        assert_eq!((y.channels, y.n), layer.out_shape(2, 16));
        assert!(y.data.iter().all(|v| *v >= 0.0), "relu clamps");
    }

    #[test]
    fn pool_layer_halves() {
        let layer = Layer::Pool {
            kind: PoolKind::Max,
            w: 2,
            stride: 2,
        };
        assert_eq!(layer.out_shape(4, 16), (4, 8));
        assert_eq!(layer.param_count(), 0);
    }

    #[test]
    fn residual_preserves_shape() {
        let mut rng = Rng::new(5);
        let layer = Layer::residual(&mut rng, 3, 3, 2);
        let x = LayerOutput {
            channels: 3,
            n: 20,
            data: rng.vec_uniform(3 * 20, -1.0, 1.0),
        };
        let y = layer.forward(&x, 1, ConvBackend::Sliding);
        assert_eq!((y.channels, y.n), (3, 20));
    }

    #[test]
    fn dense_flattens() {
        let mut rng = Rng::new(6);
        let layer = Layer::dense(&mut rng, 12, 5, false);
        let x = LayerOutput {
            channels: 3,
            n: 4,
            data: rng.vec_uniform(2 * 12, -1.0, 1.0),
        };
        let y = layer.forward(&x, 2, ConvBackend::Direct);
        assert_eq!(y.channels, 5);
        assert_eq!(y.data.len(), 10);
    }
}
