//! Composable NN layer stack executed by the rust backends.
//!
//! This is the "framework" face of the library: a [`Model`] is built
//! from a [`ModelConfig`] (the TOML config system), holds its
//! parameters, and runs forward inference through a compiled
//! [`Plan`] — [`Plan::compile`] resolves shapes, picks a kernel per
//! layer (sliding / im2col+GEMM / small-k / direct, overridable per
//! layer from the TOML and globally via
//! [`BackendChoice`](crate::conv::BackendChoice)), lays out one flat
//! scratch arena, and fuses the bias/ReLU/skip-add epilogues into the
//! kernels' destination writes. [`Model::forward_into`] is a
//! compile-then-run wrapper over a cached plan;
//! [`Model::forward_eager_into`] keeps the layer-by-layer reference
//! path the plans are parity-tested against. The serving coordinator
//! batches requests into plan executions; the PJRT path (AOT TCN
//! artifacts) lives in [`crate::coordinator`], sharing the same
//! request types.

mod layers;
mod model;
pub mod plan;
pub mod session;

pub use layers::{Layer, LayerOutput};
pub use model::{EagerScratch, ForwardScratch, Model, TensorSpec};
pub use plan::{
    LayerTune, Plan, PlanCache, PlanKernel, PlanScratch, PlannerConfig, ProbeResult, SegmentTune,
    TuneCache,
};
pub use session::{Session, SessionArena, SessionId, StreamSpec, SESSION_TILE};

#[cfg(test)]
mod tests {
    use crate::config::load_config;
    use crate::conv::ConvBackend;
    use crate::workload::Rng;

    use super::*;

    const CFG: &str = r#"
[model]
name = "t"
c_in = 1
seq_len = 64

[layer.0]
type = "conv"
c_out = 4
k = 5

[layer.1]
type = "residual"
k = 3
dilation = 2

[layer.2]
type = "pool"
kind = "max"
w = 2
stride = 2

[layer.3]
type = "conv"
c_out = 2
k = 3

[layer.4]
type = "dense"
out = 3
"#;

    #[test]
    fn model_builds_and_runs_all_backends() {
        let (mc, _) = load_config(CFG).unwrap();
        let mut rng = Rng::new(1);
        let model = Model::init(&mc, &mut rng).unwrap();
        let x = rng.vec_uniform(64, -1.0, 1.0);
        let y_direct = model.forward(&x, 1, ConvBackend::Direct).unwrap();
        assert_eq!(y_direct.shape, vec![1, 3]);
        for backend in [ConvBackend::Sliding, ConvBackend::Im2colGemm, ConvBackend::SlidingPair] {
            let y = model.forward(&x, 1, backend).unwrap();
            assert_eq!(y.shape, y_direct.shape);
            for (a, b) in y.data.iter().zip(&y_direct.data) {
                assert!((a - b).abs() < 1e-3, "{backend:?}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn batched_forward_rows_independent() {
        let (mc, _) = load_config(CFG).unwrap();
        let mut rng = Rng::new(2);
        let model = Model::init(&mc, &mut rng).unwrap();
        let x0 = rng.vec_uniform(64, -1.0, 1.0);
        let x1 = rng.vec_uniform(64, -1.0, 1.0);
        let mut xb = x0.clone();
        xb.extend_from_slice(&x1);
        let yb = model.forward(&xb, 2, ConvBackend::Sliding).unwrap();
        let y1 = model.forward(&x1, 1, ConvBackend::Sliding).unwrap();
        let per = y1.data.len();
        for (a, b) in yb.data[per..].iter().zip(&y1.data) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn param_count_reported() {
        let (mc, _) = load_config(CFG).unwrap();
        let mut rng = Rng::new(3);
        let model = Model::init(&mc, &mut rng).unwrap();
        assert!(model.param_count() > 0);
        // conv0: 4*1*5+4 = 24 params at least
        assert!(model.param_count() >= 24);
    }
}
